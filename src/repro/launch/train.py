"""Production train driver: ``python -m repro.launch.train --arch qwen3-4b
--smoke --steps 50 --ckpt-dir /tmp/ckpt``.

Wires every substrate layer together: config registry -> model zoo ->
deterministic data pipeline -> sharded train step -> async checkpointing ->
preemption handling -> auto-resume.  On the container this runs reduced
(--smoke) configs on the local device; on a fleet the same file runs the
full configs on the production mesh (--mesh pod).
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed import sharding as shd
from repro.launch import ft
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.model import build_model
from repro.train import optimizer as opt
from repro.train import trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--mesh", choices=["local", "pod", "multipod"],
                    default="local")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.family in ("encdec", "vlm"):
        raise SystemExit("train driver covers LM families; see examples/")
    model = build_model(cfg)

    if args.mesh == "local":
        mesh = make_local_mesh()
        rules = shd.ShardingRules(rules={"batch": "data"})
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))
        rules = shd.fsdp_rules(multi_pod=(args.mesh == "multipod"))

    opt_cfg = opt.AdamWConfig(lr=args.lr, warmup_steps=10,
                              total_steps=args.steps)
    step_fn = trainer.make_train_step(model, opt_cfg,
                                      microbatches=args.microbatches)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq_len + 1,
                                  global_batch=args.batch))

    mgr = (CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir
           else None)
    handler = ft.PreemptionHandler()

    with shd.use_rules(rules, mesh), mesh:
        def init():
            return trainer.init_state(model, jax.random.PRNGKey(0))

        if mgr is not None:
            state, start = ft.restore_or_init(mgr, init)
            if start:
                print(f"[resume] from step {start}")
        else:
            state, start = init(), 0

        jit_step = jax.jit(step_fn, donate_argnums=(0,))
        t0 = time.time()
        for step in range(start, args.steps):
            batch = data.batch_at(step)
            state, metrics = jit_step(state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                print(f"step {step:5d} loss {loss:.4f} "
                      f"lr {float(metrics.get('lr', 0)):.2e} "
                      f"gnorm {float(metrics.get('grad_norm', 0)):.2f} "
                      f"({time.time() - t0:.1f}s)", flush=True)
            if mgr is not None and (
                    (step + 1) % args.ckpt_every == 0 or handler.requested
                    or step == args.steps - 1):
                mgr.save(step + 1, state)
                if handler.requested:
                    print(f"[preempt] checkpoint at step {step + 1}; bye")
                    mgr.wait()
                    return state
        if mgr is not None:
            mgr.wait()
        print("done.")
        return state


if __name__ == "__main__":
    main()
