"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (smoke tests see 1 CPU device; only dryrun.py forces
512 host devices via XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh():
    """Whatever devices exist locally, as a 1-D 'data' mesh (examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
