import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Patch existing dry-run JSONs with honest (unrolled-probe) cost_true
without redoing the full-depth compiles.

    PYTHONPATH=src python -m repro.launch.probe_costs [--mesh 16x16|both]
"""
import argparse
import json
import time

from repro.configs import SHAPES, get_config
from repro.launch.policy import microbatches_for


def probe(arch, shape_name, multi_pod, cfg_overrides=None,
          rule_overrides=None, mb_override=None):
    from repro.launch.dryrun import lower_cell
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    probe_cfg = {"scan_layers": False, "chunk_unroll": True}
    if cfg_overrides:
        probe_cfg = dict(probe_cfg, **cfg_overrides)
    if cfg.family == "zamba2":
        d1, d2 = cfg.attn_every, 2 * cfg.attn_every
        units = (cfg.n_layers // cfg.attn_every
                 + (cfg.n_layers % cfg.attn_every) / cfg.attn_every)
    else:
        d1, d2 = 1, 2
        units = cfg.n_layers
    mb = (mb_override if mb_override is not None else
          microbatches_for(arch, shape.kind, shape.global_batch,
                           multi_pod))
    ra = lower_cell(arch, shape_name, multi_pod, n_layers=d1,
                    cfg_overrides=probe_cfg, microbatches=1,
                    rule_overrides=rule_overrides, unroll_accum=True)
    rb = lower_cell(arch, shape_name, multi_pod, n_layers=d2,
                    cfg_overrides=probe_cfg, microbatches=1,
                    rule_overrides=rule_overrides, unroll_accum=True)
    rc_ = None
    if shape.kind == "train" and mb > 1:
        rc_ = lower_cell(arch, shape_name, multi_pod, n_layers=d1,
                         cfg_overrides=probe_cfg, microbatches=2,
                         rule_overrides=rule_overrides, unroll_accum=True)

    def metric(r, key):
        return (r["collective_bytes_total"] if key == "collective_bytes"
                else r["cost"][key])

    out = {}
    for key in ("flops", "bytes_accessed", "collective_bytes"):
        A, B = metric(ra, key), metric(rb, key)
        P = B - A
        total = A + (units - 1) * P
        if key == "collective_bytes" and rc_ is not None:
            g = max(metric(rc_, key) - A, 0.0)
            total += (mb - 1) * units * g
        out[key] = max(total, A)
    out["per_layer_flops"] = metric(rb, "flops") - metric(ra, "flops")
    out["probe_depths"] = [d1, d2]
    out["microbatches"] = mb
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16", choices=["16x16", "2x16x16",
                                                        "both"])
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    meshes = (["16x16", "2x16x16"] if args.mesh == "both"
              else [args.mesh])
    from repro.configs import all_cells
    for arch, shape in all_cells():
        for mesh in meshes:
            path = os.path.join(args.dir, f"{arch}__{shape}__{mesh}.json")
            if not os.path.exists(path):
                print(f"[miss] {path}")
                continue
            with open(path) as f:
                cell = json.load(f)
            if "cost_true" in cell:
                print(f"[skip] {arch} {shape} {mesh}", flush=True)
                continue
            t0 = time.time()
            try:
                cell["cost_true"] = probe(arch, shape, mesh == "2x16x16")
                with open(path, "w") as f:
                    json.dump(cell, f, indent=1)
                print(f"[ok]   {arch} {shape} {mesh} "
                      f"({time.time()-t0:.0f}s)", flush=True)
            except Exception as e:  # noqa: BLE001
                print(f"[FAIL] {arch} {shape} {mesh}: {e!r}", flush=True)


if __name__ == "__main__":
    main()
