"""Launch policy tables shared by dryrun.py and benchmarks/roofline.py.

Kept free of import side effects (dryrun.py sets XLA_FLAGS at import;
analysis code must be able to read these tables without that).
"""
from __future__ import annotations

# microbatch accumulation per arch for the train cells; constraint:
# (global_batch / microbatches) stays divisible by the DP extent.
MICROBATCHES = {
    "whisper_base": 16, "rwkv6_3b": 4, "grok1_314b": 8, "phi35_moe": 8,
    "qwen2_vl_72b": 8, "qwen3_4b": 4, "nemotron4_340b": 8,
    "minitron_4b": 16, "qwen3_8b": 8, "zamba2_1p2b": 4,
}

# optimizer-state / grad-accumulator storage precision per arch (>=300B
# cells cannot hold f32 AdamW triples in 256 x 16 GB).
TRAIN_DTYPES = {
    "grok1_314b": ("bfloat16", "bfloat16"),
    "nemotron4_340b": ("bfloat16", "bfloat16"),
    "qwen2_vl_72b": ("bfloat16", "float32"),
}

# archs whose train cells shard the residual-stream sequence dim over
# "model" (Megatron-style sequence parallelism).
TRAIN_SEQ_PARALLEL = {"nemotron4_340b", "qwen2_vl_72b", "grok1_314b"}


def microbatches_for(arch: str, shape_kind: str, global_batch: int,
                     multi_pod: bool) -> int:
    if shape_kind != "train":
        return 1
    mb = MICROBATCHES.get(arch, 1)
    dp = 32 if multi_pod else 16
    return min(mb, max(1, global_batch // dp))
