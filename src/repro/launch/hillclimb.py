import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: run a named set of variants against a cell and
report the roofline-term deltas.

    PYTHONPATH=src python -m repro.launch.hillclimb --cell nemotron_train
    PYTHONPATH=src python -m repro.launch.hillclimb --cell qwen2vl_decode
    PYTHONPATH=src python -m repro.launch.hillclimb --cell grok_prefill

Each variant is ONE change vs the cell baseline (the per-iteration
discipline of the §Perf methodology); results append to
experiments/hillclimb/<cell>.json.
"""
import argparse
import dataclasses
import json
import time
from typing import Any, Dict, Optional

import jax.numpy as jnp

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def measure(cell: Dict[str, Any], variant) -> Dict[str, float]:
    """Loop-corrected roofline terms for a variant: unrolled cost probes
    (probe_costs.probe) + a full-depth compile for the memory truth."""
    from repro.launch.dryrun import lower_cell
    from repro.launch.probe_costs import probe
    ct = probe(cell["arch"], cell["shape"], False,
               cfg_overrides=variant.cfg_overrides,
               rule_overrides=variant.rule_overrides,
               mb_override=variant.microbatches)
    full = lower_cell(cell["arch"], cell["shape"], False,
                      cfg_overrides=variant.cfg_overrides,
                      rule_overrides=variant.rule_overrides,
                      microbatches=variant.microbatches)
    return {
        "compute_s": ct["flops"] / PEAK_FLOPS,
        "memory_s": ct["bytes_accessed"] / HBM_BW,
        "collective_s": ct["collective_bytes"] / LINK_BW,
        "peak_gib": full["memory"]["peak_bytes_per_device"] / 2**30,
    }


@dataclasses.dataclass
class Variant:
    name: str
    hypothesis: str
    cfg_overrides: Optional[Dict[str, Any]] = None
    rule_overrides: Optional[Dict[str, Any]] = None
    microbatches: Optional[int] = None


CELLS = {
    # hillclimb cell 1: biggest dense train step (worst roofline candidate,
    # collective-heavy) — the fleet-scale training workload.
    "nemotron_train": {
        "arch": "nemotron4_340b", "shape": "train_4k",
        "variants": [
            Variant("remat_dots",
                    "selective remat (keep matmul outputs w/o batch dims) "
                    "cuts the bwd recompute ~1.3x of fwd flops at the cost "
                    "of more saved activation bytes",
                    cfg_overrides={"remat": "dots"}),
            Variant("remat_none",
                    "no remat: pure compute floor (memory will blow past "
                    "16G; measures the recompute tax exactly)",
                    cfg_overrides={"remat": "none"}),
            Variant("mb4",
                    "fewer microbatches amortize per-mb FSDP weight "
                    "gathers: collective term down ~2x, activations up 2x",
                    microbatches=4),
            Variant("no_sp",
                    "drop sequence parallelism: removes the per-layer SP "
                    "all-gather/reduce-scatter pair (the largest "
                    "collective class) but re-inflates saved activations "
                    "16x",
                    rule_overrides={"seq": None}),
            Variant("tp_bf16",
                    "Megatron-style bf16 partial-sum psum on the MLP "
                    "projection via shard_map (pjit cannot legally demote "
                    "the reduce dtype); on TPU halves that AR's bytes — "
                    "CPU lowering legalizes collectives to f32, so the "
                    "dry-run delta under-reports (DESIGN.md)",
                    rule_overrides={"_tp_bf16_reduce": True}),
        ],
    },
    # hillclimb cell 2: MoE prefill — most collective-bound cell family
    # (dispatch + TP + FSDP interact).
    "grok_prefill": {
        "arch": "grok1_314b", "shape": "prefill_32k",
        "variants": [
            Variant("expert_fsdp",
                    "shard experts over data instead of TP-within-expert: "
                    "8 experts | 16 data -> no; over model? 8!|16. Shard "
                    "expert d_ff over data AND model (2-axis) to halve the "
                    "per-layer gather",
                    rule_overrides={"experts": None,
                                    "expert_mlp": ("data", "model")}),
            Variant("cap1.0",
                    "capacity factor 1.25->1.0: dispatch buffers and "
                    "expert flops shrink 20%, more drops",
                    cfg_overrides={"moe_capacity": 1.0}),
            Variant("qchunk4096",
                    "larger q-chunk (2048->4096): fewer scan steps, bigger "
                    "scores — trade memory for fewer fusion boundaries",
                    cfg_overrides={"q_chunk": 4096}),
            Variant("kv_fp8",
                    "fp8 KV cache write: halves prefill cache output bytes",
                    cfg_overrides={"kv_dtype": jnp.float8_e4m3fn}),
        ],
    },
    # hillclimb cell 3: decode — the paper-representative cell (weight/KV
    # streaming == deep-net mode's read/write overlap budget).
    "qwen2vl_decode": {
        "arch": "qwen2_vl_72b", "shape": "decode_32k",
        "variants": [
            Variant("kv_fp8",
                    "fp8 KV cache: cache is the dominant memory term at "
                    "32k x 128; expect ~2x cut of cache bytes, upcast "
                    "fused into the attention dot",
                    cfg_overrides={"kv_dtype": jnp.float8_e4m3fn}),
            Variant("kv_seq_shard",
                    "flash-decode layout: shard the cache SEQUENCE over "
                    "model and replicate KV heads — distributed-softmax "
                    "collectives replace head-sharding; wins when "
                    "kv_heads < tp",
                    rule_overrides={"kv_seq": "model",
                                    "act_kv_heads": None,
                                    "kv_heads": None}),
            Variant("kv_fp8_seqshard",
                    "compose fp8 cache + seq-sharded layout: both memory "
                    "levers at once",
                    cfg_overrides={"kv_dtype": jnp.float8_e4m3fn},
                    rule_overrides={"kv_seq": "model",
                                    "act_kv_heads": None,
                                    "kv_heads": None}),
        ],
    },
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=list(CELLS))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variants", default=None,
                    help="comma list; default all")
    ap.add_argument("--out", default="experiments/hillclimb")
    args = ap.parse_args()

    spec = CELLS[args.cell]
    os.makedirs(args.out, exist_ok=True)
    log_path = os.path.join(args.out, f"{args.cell}.json")
    log = []
    if os.path.exists(log_path):
        with open(log_path) as f:
            log = json.load(f)

    print(f"=== {args.cell}: baseline ===", flush=True)
    base_v = Variant("baseline", "cell defaults")
    bt = measure(spec, base_v)
    print(" ".join(f"{k}={v:.4g}" for k, v in bt.items()), flush=True)
    log.append({"variant": "baseline", "terms": bt})

    wanted = (args.variants.split(",") if args.variants else
              [v.name for v in spec["variants"]])
    for v in spec["variants"]:
        if v.name not in wanted:
            continue
        print(f"--- variant {v.name}: {v.hypothesis[:70]}", flush=True)
        t0 = time.time()
        try:
            vt = measure(spec, v)
            delta = {k: (vt[k] - bt[k]) / bt[k] if bt[k] else 0.0
                     for k in vt}
            print("   " + " ".join(f"{k}={vt[k]:.4g}({delta[k]:+.1%})"
                                   for k in vt)
                  + f"  ({time.time()-t0:.0f}s)", flush=True)
            log.append({"variant": v.name, "hypothesis": v.hypothesis,
                        "terms": vt, "delta_vs_base": delta})
        except Exception as e:  # noqa: BLE001
            print(f"   FAIL {e!r}", flush=True)
            log.append({"variant": v.name, "error": repr(e)})

    with open(log_path, "w") as f:
        json.dump(log, f, indent=1, default=str)
    print(f"log -> {log_path}")


if __name__ == "__main__":
    main()
