import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: lower + compile every (architecture x input-shape)
cell on the production meshes and record memory / cost / collective
analyses for EXPERIMENTS.md.

MUST be the process entry point (python -m repro.launch.dryrun ...): the
XLA_FLAGS line above runs before any jax import anywhere, because jax locks
the device count on first init.  Smoke tests and benches never import this
module, so they keep seeing 1 CPU device.

Per cell this lowers the right step function:
  train_4k     -> train_step (AdamW + bf16 compute, donated state)
  prefill_32k  -> prefill_step (bf16 weights, cache write-out)
  decode_32k / long_500k -> serve_step (one token, seq_len-deep cache,
                            donated cache)

and emits <out>/<arch>__<shape>__<mesh>.json with:
  memory_analysis (per-device bytes), cost_analysis (flops/bytes, raw and
  layer-extrapolated — XLA counts a scan body once; see hlo_analysis),
  per-collective traffic, op histogram, compile wall time.
"""
import argparse
import dataclasses
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import (ALIASES, ARCH_IDS, SHAPES, get_config,
                           shape_applicable)
from repro.configs.inputs import input_specs
from repro.distributed import sharding as shd
from repro.launch import hlo_analysis as hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.policy import (MICROBATCHES as _POLICY_MB,  # noqa: F401
                                 TRAIN_DTYPES as _POLICY_TD,
                                 TRAIN_SEQ_PARALLEL as _POLICY_SP,
                                 microbatches_for)
from repro.models.model import ModelConfig, build_model
from repro.train import optimizer as opt
from repro.train import trainer


# ---------------------------------------------------------------------------
# per-arch / per-shape sharding-rule construction
# ---------------------------------------------------------------------------

def rules_for(arch: str, shape_name: str, multi_pod: bool,
              overrides: Optional[Dict[str, Any]] = None
              ) -> shd.ShardingRules:
    shape = SHAPES[shape_name]
    seq_shard = (shape_name == "long_500k")
    base = dict(shd.fsdp_rules(multi_pod=multi_pod,
                               seq_shard=seq_shard).rules)
    a = ALIASES.get(arch, arch).replace("-", "_")
    if a in ("whisper_base", "minitron_4b"):
        # whisper: 8 heads of 64; minitron: 24 heads — neither divides the
        # 16-way TP axis.  Replicate attention, TP the FFN, and shard the
        # KV cache along the SEQUENCE dim instead (flash-decode style; XLA
        # inserts the distributed-softmax collectives).
        for k in ("heads", "kv_heads", "act_heads", "act_kv_heads"):
            base[k] = None
        if shape.kind in ("decode", "prefill"):
            base["kv_seq"] = "model"
    if a == "rwkv6_3b":
        base["lin_heads"] = None      # 40 heads !| 16 -> shard dv instead
        base["lin_dv"] = "model"
    if a == "zamba2_1p2b":
        base["ssm_inner"] = "model"
        base["ssm_heads"] = "model"
        base["lin_heads"] = "model"   # 64 SSD heads | 16
        base["lin_dv"] = None
        base["act_ssm"] = "model"
    if shape.global_batch == 1:
        base["batch"] = None          # batch=1: nothing to shard over DP
    if shape_name == "train_4k" and a in TRAIN_SEQ_PARALLEL:
        base["seq"] = "model"         # SP on the residual stream
    if overrides:
        base.update(overrides)
    return shd.ShardingRules(rules=base)


# dry-run shape-dependent model tweaks: chunked attention for long prefill
# (bounds the scores working set; unrolled so FLOP accounting stays honest)
def cfg_for_cell(cfg: ModelConfig, shape_name: str) -> ModelConfig:
    if shape_name == "prefill_32k" and cfg.family in (
            "dense", "moe", "vlm", "encdec", "zamba2"):
        cfg = dataclasses.replace(cfg, q_chunk=2048, chunk_unroll=False)
    if shape_name == "train_4k" and cfg.family in (
            "dense", "moe", "vlm", "encdec", "zamba2") and cfg.n_heads:
        # bound the train-time scores working set too
        cfg = dataclasses.replace(cfg, q_chunk=1024, chunk_unroll=False)
    if shape_name in ("prefill_32k", "train_4k") and cfg.family in (
            "rwkv6", "zamba2"):
        cfg = dataclasses.replace(cfg, lin_chunk=64)
    if shape_name == "train_4k":
        # training at 1M tokens/step needs activation rematerialization;
        # "full" (save block inputs only) is the fits-everywhere baseline —
        # §Perf revisits the remat/recompute trade per hillclimbed cell.
        cfg = dataclasses.replace(cfg, remat="full")
    return cfg


# microbatch accumulation per arch for the train cells (activation-memory
# control at global batch 256 x 4096 = 1M tokens/step; §Perf tunes these)
# constraint: (global_batch / microbatches) must remain divisible by the
# DP extent (16 single-pod, 32 multi-pod) or batch sharding degenerates.
MICROBATCHES = _POLICY_MB

# archs whose train cells additionally shard the residual-stream sequence
# dim over "model" (Megatron-style sequence parallelism): at d_model 18432
# the remat-saved layer inputs alone are 96 x 151 MB per device otherwise.
TRAIN_SEQ_PARALLEL = _POLICY_SP

# optimizer-state / grad-accumulator storage precision per arch: the
# >=300B cells cannot hold f32 AdamW triples in 256 x 16 GB (4 TB of
# optimizer state alone) — bf16 moments + bf16 accumulation is the
# documented large-model trade (moments are upcast inside the update).
TRAIN_DTYPES = _POLICY_TD


# ---------------------------------------------------------------------------
# step builders (what actually gets lowered)
# ---------------------------------------------------------------------------

def build_step(model, kind: str, rules: shd.ShardingRules, mesh,
               opt_cfg: Optional[opt.AdamWConfig] = None,
               microbatches: int = 1, arch: str = "",
               unroll_accum: bool = False):
    """Returns (fn, in_shardings, donate_argnums, arg_structs_fn)."""
    cfg = model.cfg

    def shard_of(spec_tree_):
        return jax.tree.map(
            lambda names: rules.sharding(mesh, names), spec_tree_,
            is_leaf=lambda x: type(x) is tuple)

    if kind == "train":
        opt_cfg = opt_cfg or opt.AdamWConfig()
        opt_dt, acc_dt = TRAIN_DTYPES.get(arch, ("float32", "float32"))
        step = trainer.make_train_step(
            model, opt_cfg, microbatches=microbatches,
            grad_accum_dtype=jnp.dtype(acc_dt),
            unroll_accum=unroll_accum)
        state_specs = trainer.state_specs(model)
        state_specs = trainer.TrainState(
            params=state_specs.params,
            opt=opt.OptState(m=state_specs.opt.m, v=state_specs.opt.v,
                             step=()))
        state_shard = shard_of(state_specs)

        def structs(batch_struct):
            st = jax.eval_shape(
                lambda k: trainer.init_state(model, k,
                                             jnp.dtype(opt_dt)),
                jax.ShapeDtypeStruct((2,), jnp.uint32))
            return (st, batch_struct)

        return step, state_shard, (0,), structs

    if kind == "prefill":
        def prefill_step(params, batch, cache):
            logits, cache = model.prefill(params, batch, cache)
            nxt = jnp.argmax(
                logits[:, -1].astype(jnp.float32), axis=-1)
            return nxt.astype(jnp.int32), cache

        return prefill_step, None, (2,), None

    def serve_step(params, tokens, cache):
        logits, cache = model.decode_step(params, tokens, cache)
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return nxt[:, None].astype(jnp.int32), cache

    return serve_step, None, (2,), None


def _bf16_params_struct(model):
    st = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if s.dtype == jnp.float32
            and len(s.shape) >= 2 else s.dtype), st)


# ---------------------------------------------------------------------------
# one cell
# ---------------------------------------------------------------------------

def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               n_layers: Optional[int] = None,
               rule_overrides: Optional[Dict[str, Any]] = None,
               cfg_overrides: Optional[Dict[str, Any]] = None,
               microbatches: Optional[int] = None,
               unroll_accum: bool = False,
               keep_hlo: bool = False) -> Dict[str, Any]:
    shape = SHAPES[shape_name]
    cfg = cfg_for_cell(get_config(arch), shape_name)
    if n_layers is not None:
        cfg = dataclasses.replace(cfg, n_layers=n_layers)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    if microbatches is None:
        a = ALIASES.get(arch, arch).replace("-", "_")
        microbatches = microbatches_for(a, shape.kind, shape.global_batch,
                                        multi_pod)
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(arch, shape_name, multi_pod, rule_overrides)

    kind, args, logical = input_specs(model, shape)
    t0 = time.time()
    with shd.use_rules(rules, mesh), mesh:
        a = ALIASES.get(arch, arch).replace("-", "_")
        step, state_shard, donate, structs = build_step(
            model, kind, rules, mesh, microbatches=microbatches, arch=a,
            unroll_accum=unroll_accum)

        def shard_of(tree):
            return jax.tree.map(
                lambda names: rules.sharding(mesh, names), tree,
                is_leaf=lambda x: type(x) is tuple)

        if kind == "train":
            arg_structs = structs(args[0])
            in_sh = (state_shard, shard_of(logical[0]))
            # donated state must come back with identical shardings or the
            # buffers cannot alias (peak would double)
            out_sh = (state_shard, None)
        else:
            params = _bf16_params_struct(model)
            param_sh = shard_of(model.param_specs())
            arg_structs = (params,) + args
            in_sh = (param_sh,) + tuple(shard_of(l) for l in logical)
            cache_sh = shard_of(logical[-1])
            out_sh = (None, cache_sh)

        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*arg_structs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    txt = compiled.as_text()
    colls = hlo.collective_bytes(txt)
    hist = hlo.op_histogram(txt)

    out = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": kind, "n_layers": cfg.n_layers,
        "microbatches": microbatches,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_per_device": ma.argument_size_in_bytes
            + ma.output_size_in_bytes + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
        },
        "cost": {"flops": ca.get("flops", 0.0),
                 "bytes_accessed": ca.get("bytes accessed", 0.0),
                 "transcendentals": ca.get("transcendentals", 0.0)},
        "collectives": colls,
        "collective_bytes_total": sum(v["bytes"] for v in colls.values()),
        "op_histogram": hist,
        "hlo_chars": len(txt),
    }
    if keep_hlo:
        out["hlo_text"] = txt
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             extrapolate: bool = True) -> Dict[str, Any]:
    """Full-depth compile (memory truth) + shallow UNROLLED cost probes.

    XLA cost analysis counts a while-loop body once regardless of trip
    count, so per-layer costs are measured on 1- and 2-layer UNROLLED
    variants (scan_layers=False, chunk loops unrolled, microbatches=1):

      flops/bytes:  total = A + (U - 1) * (B - A)
        (microbatch-independent: splitting the batch reorders the same
         arithmetic)
      collectives:  per-layer delta P = B - A mixes the per-microbatch
        weight gathers g with the once-per-step gradient reduction r; a
        third probe C at (1 layer, 2 microbatches) isolates g = C - A, so
          total = A + (U - 1) * P + (mb - 1) * U * g

    with U = layer units (superblocks for zamba) and mb the production
    microbatch count.
    """
    res = lower_cell(arch, shape_name, multi_pod)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if extrapolate:
        a = ALIASES.get(arch, arch).replace("-", "_")
        probe_cfg = {"scan_layers": False, "chunk_unroll": True}
        if cfg.family == "zamba2":
            d1, d2 = cfg.attn_every, 2 * cfg.attn_every  # 1 vs 2 supers
            units = (cfg.n_layers // cfg.attn_every
                     + (cfg.n_layers % cfg.attn_every) / cfg.attn_every)
        else:
            d1, d2 = 1, 2
            units = cfg.n_layers
        mb_prod = microbatches_for(a, shape.kind, shape.global_batch,
                                   multi_pod)
        ra = lower_cell(arch, shape_name, multi_pod, n_layers=d1,
                        cfg_overrides=probe_cfg, microbatches=1)
        rb = lower_cell(arch, shape_name, multi_pod, n_layers=d2,
                        cfg_overrides=probe_cfg, microbatches=1)
        rc_ = None
        if shape.kind == "train" and mb_prod > 1:
            rc_ = lower_cell(arch, shape_name, multi_pod, n_layers=d1,
                             cfg_overrides=probe_cfg, microbatches=2)

        def metric(r, key):
            if key == "collective_bytes":
                return r["collective_bytes_total"]
            return r["cost"][key]

        true_cost = {}
        for key in ("flops", "bytes_accessed", "collective_bytes"):
            A, B = metric(ra, key), metric(rb, key)
            P = B - A
            total = A + (units - 1) * P
            if key == "collective_bytes" and rc_ is not None:
                g = max(metric(rc_, key) - A, 0.0)  # per-mb weight gathers
                total += (mb_prod - 1) * units * g
            true_cost[key] = max(total, metric(ra, key))
        true_cost["per_layer_flops"] = metric(rb, "flops") - metric(
            ra, "flops")
        true_cost["probe_depths"] = [d1, d2]
        true_cost["microbatches"] = mb_prod
        res["cost_true"] = true_cost
    os.makedirs(out_dir, exist_ok=True)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_tag}.json")
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-extrapolate", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                if shape_applicable(arch, shape):
                    cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(ALIASES.get(args.arch, args.arch).replace("-", "_"),
                  args.shape)]

    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            mesh_tag = "2x16x16" if mp else "16x16"
            tag = f"{arch} x {shape} x {mesh_tag}"
            if args.skip_existing and os.path.exists(os.path.join(
                    args.out, f"{arch}__{shape}__{mesh_tag}.json")):
                print(f"[SKIP] {tag}", flush=True)
                continue
            try:
                t0 = time.time()
                res = run_cell(arch, shape, mp, args.out,
                               extrapolate=not args.no_extrapolate)
                peak = res["memory"]["peak_bytes_per_device"] / 2**30
                print(f"[OK]   {tag:55s} peak={peak:7.2f} GiB  "
                      f"flops={res['cost']['flops']:.3e}  "
                      f"coll={res['collective_bytes_total']/2**20:9.1f} MiB "
                      f"({time.time()-t0:.0f}s)", flush=True)
            except Exception as e:  # noqa: BLE001 — record and continue
                failures.append((tag, repr(e)))
                print(f"[FAIL] {tag:55s} {e!r}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nALL CELLS COMPILED.")


if __name__ == "__main__":
    main()
