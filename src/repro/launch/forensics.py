"""HLO buffer forensics: find what dominates a cell's peak memory.

Usage (must be the process entry point, like dryrun):
    PYTHONPATH=src python -m repro.launch.forensics --arch whisper-base \
        --shape decode_32k [--layers 2]

Prints the largest tensors in the partitioned module grouped by shape,
with their defining op and computation context — the "profile" of the
dry-run world (DESIGN.md §5): since there is no wall-clock trace, memory
and collective forensics of the lowered IR are the profiler.
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import re
from collections import Counter

_DT = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
       "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8}


def big_buffers(hlo_text: str, min_bytes: float = 100e6, top: int = 24):
    agg = Counter()
    example = {}
    ctx = None
    for ln in hlo_text.splitlines():
        if ln.startswith("%") or ln.startswith("ENTRY"):
            m = re.match(r"(%[\w.\-]+|ENTRY \S+)", ln)
            if m:
                ctx = m.group(1)
        m = re.match(r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*([a-z0-9]+)"
                     r"\[([\d,]*)\]", ln)
        if not m:
            continue
        name, dt, dims = m.groups()
        if dt not in _DT or not dims:
            continue
        n = 1
        for d in dims.split(","):
            n *= int(d)
        b = n * _DT[dt]
        if b >= min_bytes:
            key = f"{dt}[{dims}]"
            agg[key] += 1
            if key not in example:
                op = re.search(r"\]\{?[^=]*?\}?\s+([\w\-]+)\(", ln)
                example[key] = (b, ctx, op.group(1) if op else "?",
                                ln.strip()[:110])
    rows = []
    for key, cnt in agg.most_common(top):
        b, ctx, op, ln = example[key]
        rows.append({"shape": key, "count": cnt, "gib": b / 2**30,
                     "op": op, "ctx": ctx, "line": ln})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--min-mb", type=float, default=100.0)
    args = ap.parse_args()

    from repro.launch.dryrun import lower_cell
    res = lower_cell(args.arch, args.shape, args.multi_pod,
                     n_layers=args.layers, keep_hlo=True)
    txt = res.pop("hlo_text")
    m = res["memory"]
    print(f"peak={m['peak_bytes_per_device']/2**30:.2f} GiB  "
          f"arg={m['argument_bytes']/2**30:.2f} out="
          f"{m['output_bytes']/2**30:.2f} temp={m['temp_bytes']/2**30:.2f} "
          f"alias={m['alias_bytes']/2**30:.2f}")
    for r in big_buffers(txt, args.min_mb * 2**20):
        print(f"x{r['count']:3d} {r['gib']:7.2f}GiB {r['shape'][:44]:46s} "
              f"op={r['op'][:18]:18s} ctx={str(r['ctx'])[:40]}")


if __name__ == "__main__":
    main()
