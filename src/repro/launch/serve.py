"""Serving driver: ``python -m repro.launch.serve --arch qwen3-4b --smoke``.

Continuous-batching decode over the BatchScheduler with synthetic prompts;
on a fleet the same file serves the full config on the production mesh
(params would come from checkpoint/manager.py instead of random init).

``--backend crossbar`` serves every linear layer from weight-resident
crossbar tiles: weights are programmed once at scheduler construction and
every decode step is a read-only bit-serial MAC (core/executor.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.model import build_model
from repro.serve.engine import BatchScheduler, Request, greedy_generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--backend", default="digital",
                    choices=["digital", "crossbar"],
                    help="crossbar = weight-resident tiles, program-once")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.family in ("encdec", "vlm", "rwkv6", "zamba2"):
        raise SystemExit("scheduler demo targets decoder LMs; "
                         "see examples/serve_batch.py for other families")
    cfg = dataclasses.replace(cfg, backend=args.backend)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    sched = BatchScheduler(model, params, n_slots=args.slots,
                           max_len=args.max_len)
    if model.executor is not None:
        ex = model.executor
        print(f"crossbar backend: {ex.n_resident} resident weight grids, "
              f"{ex.n_devices} programmed devices "
              f"(programmed={ex.stats['programmed']}, "
              f"cache_hits={ex.stats['cache_hits']})")
    key = jax.random.PRNGKey(1)
    for rid in range(args.requests):
        key, k = jax.random.split(key)
        prompt = jax.random.randint(k, (args.prompt_len,), 0,
                                    cfg.vocab - 1).astype(jnp.int32)
        sched.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new))

    t0 = time.time()
    done, steps = [], 0
    while len(done) < args.requests and steps < 10_000:
        done += sched.step()
        steps += 1
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens in "
          f"{steps} decode steps, {dt:.2f}s "
          f"({total_tokens / max(dt, 1e-9):.1f} tok/s)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.out[:8]}...")
    return done


if __name__ == "__main__":
    main()
