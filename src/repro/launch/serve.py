"""Serving driver: ``python -m repro.launch.serve --arch qwen3-4b --smoke``.

Continuous-batching decode over the BatchScheduler with synthetic prompts;
on a fleet the same file serves the full config on the production mesh
(params would come from checkpoint/manager.py instead of random init).

``--backend crossbar`` serves every linear layer from weight-resident
crossbar tiles: weights are programmed once at scheduler construction and
every decode step is a read-only bit-serial MAC (core/executor.py).

KV storage defaults to the block-paged pool (``--kv paged``): fixed
``--page-size`` pages, per-slot page tables, free-list alloc/reclaim;
prompts of any length stream into the running batch as ``--chunk``-token
prefill chunks through ONE compiled closure per tenant (no length
buckets, zero re-traces for any prompt mix).  ``--kv dense`` keeps the
per-slot dense cache — same closure and bit-identical streams.
``--stream-pages N`` routes decode attention through the
block-streamed online-softmax kernel lane once a row's page table is
at least N pages wide (peak VMEM bounded by ``--block-pages``
regardless of window length; bounded-ulp + argmax-stable vs the
default bitwise gather-scratch lane) and prints the per-lane traced
closure counts after the run.

``--hot-swap SPEC`` deploys a second checkpoint under live traffic
(deep-net mode at the serving tier, serve/hotswap.py): the new weights
program onto the write-shadow planes between decode steps and an atomic
flip promotes them with zero dropped requests.  SPEC is ``ft:<scale>``
(the serving params plus a scaled fine-tune delta), ``seed:<int>`` (a
fresh init — e.g. a recalibration sweep), a checkpoint directory
written by checkpoint/manager.py, or ``init`` (the serving params).

``--multiplex SPEC,SPEC[,SPEC...]`` serves N checkpoints from the plane
bank of one crossbar executor (multi-tenant plane multiplexing):
requests round-robin across tenants A, B, C, ..., each tenant decodes
from its own resident plane, and the physical device count is 1.0x a
single deployment's stacks instead of the N.0x dedicated arrays would
burn.  ``--stack-planes N`` sets the bank height (the paper's geometry
is 2; taller stacks host more tenants and/or a free staging plane);
``--qos W,W,...`` gives per-tenant QoS weights driving the scheduler's
slot split and admission order.  Combined with ``--hot-swap``, the swap
targets the LAST tenant: its planes reprogram under the other tenants'
uninterrupted traffic.

``--prefix-share`` turns on refcounted prompt-prefix sharing in the
paged pool: requests whose prompt head token-matches fully-written
pages of a resident request alias those physical pages (per-page
refcounts; copy-on-write when a row would write inside a shared page),
skipping the aliased prefill compute entirely.  ``--common-prefix N``
prepends the same N-token head to every synthetic prompt so the demo
has a shared system prompt to find.  ``--preemption`` adds QoS
preemption: when the pool (or a tenant's budget) saturates, the
lowest-QoS in-flight request is evicted — pages reclaim, the request
re-enters the queue and replays through chunked prefill with a
bit-identical output stream and zero drops.  Both require ``--kv
paged`` and compose with everything below.

``--mode-policy auto|expansion|deepnet|name=mode,...`` makes read mode a
per-weight bank policy (the paper's expansion mode at the serving tier):
expansion-programmed weights fuse two planes into one doubled-input
crossbar — both RE high, cutting worst-case IR drop by ~22% but giving
up the write shadow — while deep-net weights keep overlapped hot-swaps.
``auto`` picks expansion for accuracy-critical layers (attention/head)
and deep-net for swap-heavy MLP mats, scored by the exact nodal IR-drop
solves; the per-layer choices and deltas print via ``mode_report()``.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs import get_config
from repro.models.model import build_model
from repro.serve.engine import BatchScheduler, Request


def parse_mode_policy(spec):
    """``--mode-policy`` parsing: ``auto`` | ``expansion`` | ``deepnet``
    | ``name=mode[,name=mode...]`` (names may be dotted fragments like
    ``attn`` or ``blocks.0.mlp.wi``; ``default=<mode>`` covers the rest;
    mapped modes may themselves be ``auto``)."""
    if spec is None:
        return None
    if spec in ("auto", "expansion", "deepnet"):
        return spec
    policy = {}
    for item in spec.split(","):
        name, sep, mode = item.partition("=")
        name, mode = name.strip(), mode.strip()
        if not sep or not name or mode not in ("expansion", "deepnet",
                                               "auto"):
            raise SystemExit(
                f"--mode-policy: bad entry {item!r} (want auto | "
                f"expansion | deepnet | name=mode,... with mode one of "
                f"expansion/deepnet/auto)")
        policy[name] = mode
    return policy


def resolve_swap_params(spec: str, model, params):
    """Checkpoint-spec resolution for ``--hot-swap`` / ``--multiplex``."""
    if spec == "init":
        return params
    if spec.startswith("seed:"):
        try:
            seed = int(spec[5:])
        except ValueError:
            raise SystemExit(f"--hot-swap: {spec!r} needs an integer seed")
        return model.init(jax.random.PRNGKey(seed))
    if spec.startswith("ft:"):
        try:
            scale = float(spec[3:])
        except ValueError:
            raise SystemExit(f"--hot-swap: {spec!r} needs a float scale")
        from repro.serve.hotswap import finetune_delta
        return finetune_delta(params, scale=scale)
    if os.path.isdir(spec):
        from repro.checkpoint.manager import CheckpointManager
        return CheckpointManager(spec).restore(target=params)
    raise SystemExit(f"--hot-swap: unknown spec {spec!r} "
                     f"(want init, ft:<scale>, seed:<int>, or a "
                     f"checkpoint dir)")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--backend", default="digital",
                    choices=["digital", "crossbar"],
                    help="crossbar = weight-resident tiles, program-once")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--kv", default="paged", choices=["paged", "dense"],
                    help="KV storage: paged = block-paged pool with "
                         "per-slot page tables (serve/kv_pool.py); dense "
                         "= per-slot dense cache (the bit-exactness "
                         "oracle)")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per KV page (must divide --max-len)")
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="per-tenant page-pool budget for the "
                         "QoS-weighted split (default: slots * max_len "
                         "/ page_size pages per lane, i.e. no "
                         "oversubscription)")
    ap.add_argument("--chunk", type=int, default=4,
                    help="prompt tokens fed per step while a request "
                         "prefills inside the running decode batch")
    ap.add_argument("--stream-pages", type=int, default=0, metavar="N",
                    help="route paged decode attention through the "
                         "block-streamed online-softmax kernel lane "
                         "whenever a row's page table is >= N pages "
                         "wide (0 = keep the bitwise gather-scratch "
                         "lane; implies the paged Pallas kernel; "
                         "requires --kv paged)")
    ap.add_argument("--block-pages", type=int, default=16, metavar="N",
                    help="pages fetched per streamed attention block "
                         "(clamped to a divisor of the table width)")
    ap.add_argument("--prefix-share", action="store_true",
                    help="refcounted prefix sharing: requests whose "
                         "prompt head matches fully-written pages of a "
                         "resident request alias those pages instead of "
                         "re-filling them (copy-on-write on sub-page "
                         "divergence); requires --kv paged")
    ap.add_argument("--preemption", action="store_true",
                    help="QoS preemption: when the page pool or a "
                         "tenant budget saturates, evict the lowest-QoS "
                         "in-flight request (its pages reclaim; the "
                         "request re-admits via chunked prefill with a "
                         "bit-identical stream); requires --kv paged")
    ap.add_argument("--common-prefix", type=int, default=0, metavar="N",
                    help="prepend the same N-token head to every "
                         "synthetic prompt (a shared system prompt) so "
                         "--prefix-share has aliasable pages to find")
    ap.add_argument("--stagger", type=int, default=0, metavar="N",
                    help="submit request i at decode step i*N instead "
                         "of all upfront; sharing needs the head "
                         "request's prompt pages written (and still "
                         "resident) before a follower admits")
    ap.add_argument("--hot-swap", default=None, metavar="SPEC",
                    help="second checkpoint to deploy mid-serving "
                         "(ft:<scale> | seed:<int> | checkpoint dir); "
                         "requires --backend crossbar; under --multiplex "
                         "the swap targets the last tenant")
    ap.add_argument("--multiplex", default=None,
                    metavar="SPEC,SPEC[,SPEC...]",
                    help="serve N checkpoints (tenants A,B,C,...) from "
                         "the plane bank of one executor (specs as in "
                         "--hot-swap, plus 'init'); requires --backend "
                         "crossbar and stack-planes >= N")
    ap.add_argument("--stack-planes", type=int, default=None,
                    help="bank height: planes stacked per cell site "
                         "(default: the device model's 2, the paper "
                         "geometry)")
    ap.add_argument("--qos", default=None, metavar="W,W[,W...]",
                    help="per-tenant QoS weights for --multiplex (one "
                         "float per spec, e.g. 2,1,1): weighted slot "
                         "split + admission order in the scheduler")
    ap.add_argument("--mode-policy", default=None, metavar="POLICY",
                    help="per-weight crossbar read mode: auto (IR-drop-"
                         "aware — expansion for attention/head, deep-net "
                         "for swap-heavy MLP), expansion, deepnet, or "
                         "name=mode,... (e.g. head=expansion,default="
                         "auto); requires --backend crossbar")
    ap.add_argument("--tile-rows", type=int, default=None,
                    help="override crossbar tile rows (wordlines per "
                         "plane); expansion fusing pairs row-tiles "
                         "across the two planes, so it needs an even "
                         "count >= 2 per weight — e.g. --tile-rows 32 "
                         "splits the smoke model's d_model=64 weights "
                         "into 2 row-tiles")
    ap.add_argument("--swap-after", type=int, default=None,
                    help="begin the swap once this many requests finished "
                         "(default: half)")
    ap.add_argument("--swap-chunks", type=int, default=8,
                    help="shadow-plane chunks programmed per decode step")
    ap.add_argument("--metrics-out", default=None, metavar="FILE.jsonl",
                    help="write the telemetry trace at exit: one JSON "
                         "object per line — request/swap spans, then "
                         "every metric sample (docs/OBSERVABILITY.md)")
    ap.add_argument("--metrics-interval", type=int, default=0,
                    metavar="N",
                    help="print a one-line stats banner every N decode "
                         "steps (0 = off)")
    ap.add_argument("--no-telemetry", action="store_true",
                    help="disable scheduler metrics/span collection "
                         "(the overhead-baseline configuration)")
    args = ap.parse_args(argv)
    if args.no_telemetry and (args.metrics_out or args.metrics_interval):
        raise SystemExit("--no-telemetry contradicts --metrics-out / "
                         "--metrics-interval")
    if args.hot_swap and args.backend != "crossbar":
        raise SystemExit("--hot-swap requires --backend crossbar")
    if args.multiplex and args.backend != "crossbar":
        raise SystemExit("--multiplex requires --backend crossbar")
    if args.mode_policy and args.backend != "crossbar":
        raise SystemExit("--mode-policy requires --backend crossbar")
    if (args.prefix_share or args.preemption) and args.kv != "paged":
        raise SystemExit("--prefix-share/--preemption operate on the "
                         "page pool; they require --kv paged")
    if args.stream_pages and args.kv != "paged":
        raise SystemExit("--stream-pages routes the paged-attention "
                         "kernel; it requires --kv paged")
    mode_policy = parse_mode_policy(args.mode_policy)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.family in ("encdec", "vlm", "rwkv6", "zamba2"):
        raise SystemExit("scheduler demo targets decoder LMs; "
                         "see examples/serve_batch.py for other families")
    cfg = dataclasses.replace(cfg, backend=args.backend)
    if args.stream_pages:
        cfg = dataclasses.replace(cfg, paged_kernel=True,
                                  paged_stream_pages=args.stream_pages,
                                  paged_block_pages=args.block_pages)
    if args.tile_rows is not None:
        cfg = dataclasses.replace(
            cfg, xbar=dataclasses.replace(cfg.xbar,
                                          tile_rows=args.tile_rows))
    if args.stack_planes is not None:
        from repro.core.device import DeviceConfig
        cfg = dataclasses.replace(
            cfg, xbar=dataclasses.replace(
                cfg.xbar, device=DeviceConfig(
                    stack_planes=args.stack_planes)))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    tenants = None
    tenant_ids = ["A"]
    if args.multiplex:
        specs = args.multiplex.split(",")
        if len(specs) < 2:
            raise SystemExit("--multiplex wants >= 2 comma-separated "
                             "specs, e.g. init,ft:0.02 or "
                             "init,ft:0.02,seed:7")
        names = model.executor.tenant_names
        if len(specs) > len(names):
            raise SystemExit(
                f"--multiplex {len(specs)} tenants > {len(names)} plane "
                f"slots; raise --stack-planes to {len(specs)}")
        tenant_ids = list(names[:len(specs)])
        weights = [1.0] * len(specs)
        if args.qos:
            try:
                weights = [float(w) for w in args.qos.split(",")]
            except ValueError:
                raise SystemExit(f"--qos: {args.qos!r} wants floats")
            if len(weights) != len(specs):
                raise SystemExit(f"--qos wants one weight per "
                                 f"--multiplex spec ({len(specs)})")
        tenants = {
            t: (resolve_swap_params(s, model, params), w)
            for t, s, w in zip(tenant_ids, specs, weights)}
        params = tenants["A"][0]
    elif args.qos:
        raise SystemExit("--qos only applies under --multiplex")
    sched = BatchScheduler(model, params, n_slots=args.slots,
                           max_len=args.max_len, tenants=tenants,
                           mode_policy=mode_policy,
                           telemetry=not args.no_telemetry,
                           kv=args.kv, page_size=args.page_size,
                           kv_pages=args.kv_pages, chunk=args.chunk,
                           prefix_share=args.prefix_share,
                           preemption=args.preemption)
    if args.kv == "paged":
        pools = sched.kv_report()
        desc = ", ".join(f"{t}:{r['n_pages']}p" for t, r in pools.items())
        print(f"paged KV: page_size={args.page_size} tokens, pools "
              f"[{desc}], chunk={args.chunk} prompt tokens/step")
    if model.executor is not None:
        ex = model.executor
        print(f"crossbar backend: {ex.n_resident} resident weight grids, "
              f"{ex.n_devices} programmed devices/plane, "
              f"{ex.stack_planes}-plane banks "
              f"({ex.n_devices_physical} physical devices; "
              f"programmed={ex.stats['programmed']}, "
              f"cache_hits={ex.stats['cache_hits']})")
        for t, entry in ex.residency().items():
            m = entry["modes"]
            print(f"  resident tenant {t}: v{entry['version']} "
                  f"fingerprint={entry['fingerprint']} "
                  f"modes={m['expansion']} expansion / "
                  f"{m['deepnet']} deep-net")
        if mode_policy is not None:
            rep = sched.mode_report()
            agg = rep["aggregate"]
            print(f"mode policy: {agg['n_expansion']} expansion-fused / "
                  f"{agg['n_deepnet']} deep-net weight grids; mean "
                  f"worst-case IR-drop reduction on expansion layers "
                  f"{agg['ir_drop_reduction_expansion'] * 100:.1f}% "
                  f"(paper: 22%)")
            for name, entry in list(rep["layers"].items())[:6]:
                gain = (f"-{entry['ir_drop_reduction'] * 100:.1f}% IR drop"
                        if entry["mode"] == "expansion" else
                        f"-{entry['ir_drop_reduction'] * 100:.1f}% if fused")
                print(f"  {name}: {entry['mode']:9s} "
                      f"dev {entry['dev_deepnet']:.4f} -> "
                      f"{entry['dev_expansion']:.4f} ({gain})  "
                      f"[{entry['reason']}]")
            if len(rep["layers"]) > 6:
                print(f"  ... {len(rep['layers']) - 6} more weight grids "
                      f"(sched.mode_report() for the full table)")
    key = jax.random.PRNGKey(1)
    head = jax.random.randint(jax.random.PRNGKey(2),
                              (args.common_prefix,), 0,
                              cfg.vocab - 1).astype(jnp.int32)
    reqs = []
    for rid in range(args.requests):
        key, k = jax.random.split(key)
        prompt = jax.random.randint(k, (args.prompt_len,), 0,
                                    cfg.vocab - 1).astype(jnp.int32)
        if args.common_prefix:
            # a shared system prompt: identical head, distinct tails
            prompt = jnp.concatenate([head, prompt])
        # multiplexed serving round-robins the tenants' token streams
        model_id = tenant_ids[rid % len(tenant_ids)]
        # under --preemption the later half arrives at a higher QoS so a
        # saturated pool demonstrates eviction + re-admission
        qos = 2.0 if args.preemption and rid >= args.requests // 2 else 1.0
        reqs.append(Request(rid=rid, prompt=prompt, max_new=args.max_new,
                            model_id=model_id, qos=qos))

    swap_after = (args.swap_after if args.swap_after is not None
                  else args.requests // 2)
    swap_tenant = tenant_ids[-1]
    swap_params = (resolve_swap_params(args.hot_swap, model, params)
                   if args.hot_swap else None)

    def stats_banner(steps):
        if not args.metrics_interval or steps % args.metrics_interval:
            return
        reg = sched.metrics
        toks = int(reg.total("serve_tokens_total"))
        parts = []
        for t in sched.tenants:
            n = int(reg.total("serve_tokens_total", tenant=t))
            e = reg.total("serve_device_energy_joules_total", tenant=t)
            pj = e / n * 1e12 if n else 0.0
            parts.append(f"{t}:{n}tok/{pj:.0f}pJ")
        retr = int(obs.registry().total("serve_jit_retraces_total"))
        print(f"[obs] step {steps}: {toks} tokens "
              f"({', '.join(parts)}); jit retraces {retr}")

    t0 = time.time()
    done, steps, n_submitted = [], 0, 0
    while len(done) < args.requests and steps < 10_000:
        while (n_submitted < len(reqs)
               and steps >= n_submitted * args.stagger):
            sched.submit(reqs[n_submitted])
            n_submitted += 1
        if (swap_params is not None and not sched.swap_in_flight
                and not sched.swap_history and len(done) >= swap_after):
            hs = sched.begin_hot_swap(swap_params,
                                      chunks_per_step=args.swap_chunks,
                                      tenant=swap_tenant)
            print(f"hot-swap: staging {hs.plan.total_chunks} chunks onto "
                  f"tenant {swap_tenant}'s write planes after {len(done)} "
                  f"requests ({steps} decode steps)")
        done += sched.step()
        steps += 1
        stats_banner(steps)
    # requests can drain before the chunked swap completes — finish the
    # deployment rather than abandoning a half-written shadow plane
    # (idle steps still program chunks and promote at the boundary)
    if sched.swap_in_flight:
        print("hot-swap: requests drained mid-swap; finishing shadow "
              "programming before exit")
        while sched.swap_in_flight and steps < 20_000:
            sched.step()
            steps += 1
            stats_banner(steps)
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens in "
          f"{steps} decode steps, {dt:.2f}s "
          f"({total_tokens / max(dt, 1e-9):.1f} tok/s)")
    if args.stream_pages:
        rep = sched.attn_lane_report()
        d = rep["dispatch"]
        print(f"attn lanes: streamed >= {rep['stream_min_pages']}p of "
              f"{rep['pages_per_seq']}p table, "
              f"block={rep['block_pages']}p; traced closures "
              f"scratch={d['paged_scratch']} "
              f"streamed={d['paged_streamed']} "
              f"fallback={d['paged_fallback']}")
    if (args.prefix_share or args.preemption) and sched.metrics.enabled:
        reg = sched.metrics
        if args.prefix_share:
            print(f"prefix sharing: "
                  f"{int(reg.total('serve_kv_pages_shared_total'))} pages "
                  f"aliased, "
                  f"{int(reg.total('serve_kv_shared_tokens_total'))} "
                  f"prompt tokens skipped, "
                  f"{int(reg.total('serve_kv_cow_total'))} copy-on-write "
                  f"page copies")
        if args.preemption:
            n_evict = int(reg.total("serve_preemptions_total"))
            print(f"preemption: {n_evict} evictions, "
                  f"{sum(r.preemptions for r in done)} re-admissions, "
                  f"zero dropped requests")
    if tenants:
        qos = sched.qos_report()
        for t in sched.tenants:
            reqs = [r for r in done if r.model_id == t]
            q = qos[t]
            print(f"  tenant {t}: {len(reqs)} requests, "
                  f"{sum(len(r.out) for r in reqs)} tokens; qos "
                  f"weight={q['weight']:g} slots={q['slots']} "
                  f"share={q['token_share'] * 100:.1f}% "
                  f"(fingerprint={model.executor.fingerprint(tenant=t)})")
    for r in done[:3]:
        print(f"  req {r.rid} [{r.model_id}]: {r.out[:8]}...")
    for rep in sched.swap_history:
        ex = model.executor
        t = rep.get("tenant", "A")
        print(f"hot-swap promoted [{rep['policy']} tenant {t}]: "
              f"version={ex.version(t)} "
              f"fingerprint={ex.fingerprint(tenant=t)} "
              f"wall={rep['wall_swap_s']:.2f}s "
              f"({rep['decode_steps_during_swap']} decode steps served "
              f"during the swap, zero dropped)")
        print(f"  device-time: overlapped window "
              f"{rep['device_swap_window_overlapped_s'] * 1e6:.1f}us vs "
              f"stop-the-world "
              f"{rep['device_swap_window_stop_world_s'] * 1e6:.1f}us; "
              f"throughput-during-swap ratio "
              f"{rep['throughput_ratio_overlap_vs_stop_world']:.2f}x; "
              f"steady-state overlap "
              f"{rep['overlap_frac_steady_state'] * 100:.1f}% at "
              f"{rep['in_bits']}-bit reads (paper: ~29% at 10-bit)")
    if model.executor is not None and sched.metrics.enabled:
        # live traffic-weighted device figures (Table-I accounting per
        # emitted token; see sched.mode_report()["traffic"])
        for t in sched.tenants:
            n = int(sched.metrics.total("serve_tokens_total", tenant=t))
            if not n:
                continue
            for mode in ("expansion", "deepnet"):
                e = sched.metrics.total(
                    "serve_device_energy_joules_total",
                    tenant=t, mode=mode)
                s = sched.metrics.total(
                    "serve_device_read_seconds_total",
                    tenant=t, mode=mode)
                if e:
                    print(f"  device [{t}/{mode}]: {s * 1e6:.1f}us read, "
                          f"{e / n * 1e12:.0f} pJ/token over {n} tokens")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(sched.tracer.to_jsonl())
            f.write(sched.metrics.to_jsonl())
            f.write(obs.tracer().to_jsonl())
            f.write(obs.registry().to_jsonl())
        n_spans = len(sched.tracer) + len(obs.tracer())
        print(f"telemetry: wrote {n_spans} spans + metric samples to "
              f"{args.metrics_out}")
        print("# --- Prometheus snapshot (scheduler + global) ---")
        print(sched.metrics.to_prometheus(), end="")
        print(obs.registry().to_prometheus(), end="")
    return done


if __name__ == "__main__":
    main()
