"""Fault-tolerance runtime pieces for the train/serve drivers.

* PreemptionHandler — SIGTERM/SIGINT -> request a final checkpoint at the
  next step boundary (standard preemptible-VM protocol).
* Heartbeat — per-host liveness file; a coordinator (or the replacement
  host itself) detects staleness and triggers restart-from-latest.
* restore_or_init — the single entry point that makes restarts idempotent.

Straggler/elasticity strategy at fleet scale (documented here, exercised at
container scale by tests/test_ft.py):
  1. SPMD steps are synchronous, so a straggler stalls the step; mitigation
     is replace-and-restart: deterministic data (data/pipeline.py contract)
     + elastic checkpoints (checkpoint/manager.py stores logical arrays)
     mean a replacement host — or a *different pod count* — resumes
     losslessly from step N.
  2. The launcher keeps hot-spare capacity: the mesh is rebuilt from
     whatever slice is healthy (make_production_mesh is a function of the
     device set), and restore() re-shards onto it.
  3. Checkpoint cadence bounds lost work; async writes keep the step loop
     hot (the snapshot is the only synchronous part).
"""
from __future__ import annotations

import os
import signal
import time
from typing import Any

from repro.checkpoint.manager import CheckpointManager


class PreemptionHandler:
    def __init__(self):
        self.requested = False
        self._orig = {}
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                self._orig[sig] = signal.signal(sig, self._on_signal)
            except ValueError:
                pass  # non-main thread (tests)

    def _on_signal(self, signum, frame):
        self.requested = True

    def restore(self):
        for sig, h in self._orig.items():
            signal.signal(sig, h)


class Heartbeat:
    """Liveness file per host; stale mtime == presumed-dead host."""

    def __init__(self, directory: str, host_id: int,
                 interval_s: float = 10.0):
        self.path = os.path.join(directory, f"host_{host_id}.hb")
        os.makedirs(directory, exist_ok=True)
        self.interval_s = interval_s
        self._last = 0.0

    def beat(self) -> None:
        now = time.time()
        if now - self._last >= self.interval_s:
            with open(self.path, "w") as f:
                f.write(str(now))
            self._last = now

    @staticmethod
    def stale_hosts(directory: str, timeout_s: float = 60.0) -> list:
        out = []
        now = time.time()
        for fn in os.listdir(directory):
            if fn.endswith(".hb"):
                if now - os.path.getmtime(os.path.join(directory, fn)) \
                        > timeout_s:
                    out.append(fn)
        return out


def restore_or_init(mgr: CheckpointManager, init_fn, target_struct=None,
                    shardings: Any = None):
    """Resume from the latest valid checkpoint, else initialize fresh.

    Returns (state, start_step).  Idempotent: a host that crashes and
    re-enters gets exactly the same state (checkpoints are atomic; data is
    seekable by step)."""
    step = mgr.latest_step()
    if step is None:
        state = init_fn()
        return state, 0
    target = target_struct if target_struct is not None else init_fn()
    state = mgr.restore(target, step=step, shardings=shardings)
    return state, step
