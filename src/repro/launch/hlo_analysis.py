"""HLO-text analysis: collective-traffic accounting for the roofline.

``compiled.cost_analysis()`` provides FLOPs/bytes but no collective
breakdown, so we parse the partitioned HLO: every value definition line
carries its (per-device) shape; for each collective op we sum its operand
bytes.  Shapes in post-SPMD HLO are per-device, so the totals here are
per-device collective bytes — exactly the numerator of the roofline's
collective term when divided by link bandwidth (equivalently: global bytes
/ (chips * link_bw); see EXPERIMENTS.md §Roofline).

Loop caveat: XLA cost analysis and this parser both count a while-loop
(lax.scan) body ONCE.  dryrun.py corrects by compiling depth-1 and depth-2
variants and extrapolating the per-layer delta (DESIGN.md §5).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*\(?([a-z0-9]+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_OP_RE = re.compile(
    r"=\s*\(?[a-z0-9]+\[[\d,]*\][^)]*?\)?\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(([^)]*)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-type {bytes, count} from partitioned HLO text."""
    sizes: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            name, dtype, dims = m.groups()
            if dtype in _DTYPE_BYTES:
                sizes[name] = _shape_bytes(dtype, dims)

    out = defaultdict(lambda: {"bytes": 0.0, "count": 0})
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m is None:
            # cheaper pre-filter for non-matching lines
            continue
        kind, operands = m.groups()
        if "-done(" in line:
            continue  # async completion carries no new payload
        total = 0
        for tok in operands.split(","):
            tok = tok.strip().lstrip("%")
            tok = tok.split(" ")[0]
            if tok in sizes:
                total += sizes[tok]
        out[kind]["bytes"] += total
        out[kind]["count"] += 1
    return dict(out)


def total_collective_bytes(hlo_text: str) -> float:
    return sum(v["bytes"] for v in collective_bytes(hlo_text).values())


def op_histogram(hlo_text: str, ops=("fusion", "while", "custom-call",
                                     "dot", "convolution")) -> Dict[str, int]:
    hist = {}
    for op in ops + _COLLECTIVES:
        hist[op] = len(re.findall(rf"\s{re.escape(op)}(?:\(|\.|\s)",
                                  hlo_text))
    return hist


def extrapolate(full: float, l1: float, l2: float, n_layers: int,
                depth1: int = 1, depth2: int = 2) -> float:
    """Correct loop-body single-counting: cost(L) ~ cost(l1) + (L - d1) * d,
    with d = (cost(l2) - cost(l1)) / (d2 - d1) measured from two shallow
    compiles.  ``full`` (the scanned compile) is returned unchanged when it
    already exceeds the extrapolation (no loop was present)."""
    delta = (l2 - l1) / max(depth2 - depth1, 1)
    est = l1 + (n_layers - depth1) * delta
    return max(full, est)
