"""Resistive-network (IR-drop) model of crossbar read-out.

Full nodal analysis of the row/column wire ladder network:

* ``solve_planar``      — conventional 2-D n x m crossbar,
* ``solve_crossstack``  — two stacked planes (r x m each) sharing the middle
                          column electrode (expansion mode, paper Fig. 1a/e).

Geometry and conventions
------------------------
Row wires are driven by ideal sources at the j = 0 end and have resistance
``r_wire`` per cell segment.  Column wires run along the row index and are
sensed by an ideal transimpedance stage (virtual ground) past the last row
node.  Every device sits between its row node and its column node, in series
with the access transistor ON resistance (paper: ~1 kOhm, see timing.py).

In CrossStack expansion mode both planes inject into the *shared* column, so
for a fixed number of inputs n the column wire passes only n/2 nodes — this
is the structural origin of the paper's 22 % IR-drop reduction, which
``benchmarks/bench_ir_drop.py`` reproduces from this solver.

Solvers: dense direct (exact, small arrays) and damped-Jacobi stencil
iteration (large arrays; also the oracle for the ``ir_solve`` Pallas kernel).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.timing import PAPER


def _series(g_dev: jax.Array, r_access: float) -> jax.Array:
    """Device conductance in series with the access transistor."""
    return 1.0 / (1.0 / jnp.maximum(g_dev, 1e-12) + r_access)


# ---------------------------------------------------------------------------
# Dense direct solve
# ---------------------------------------------------------------------------

def _assemble_planar(g: jax.Array, v_in: jax.Array, g_w: float):
    """Build the (2nm x 2nm) nodal matrix for a planar crossbar.

    Unknown ordering: row nodes (n*m) then column nodes (n*m), row-major.
    """
    n, m = g.shape
    nn = n * m

    def ridx(i, j):
        return i * m + j

    def cidx(i, j):
        return nn + i * m + j

    N = 2 * nn
    A = jnp.zeros((N, N))
    b = jnp.zeros((N,))

    ii, jj = jnp.meshgrid(jnp.arange(n), jnp.arange(m), indexing="ij")
    ii, jj = ii.ravel(), jj.ravel()
    gg = g.ravel()

    # device branches: row node <-> column node
    r_, c_ = ridx(ii, jj), cidx(ii, jj)
    A = A.at[r_, r_].add(gg)
    A = A.at[c_, c_].add(gg)
    A = A.at[r_, c_].add(-gg)
    A = A.at[c_, r_].add(-gg)

    # row wire segments: (i, j) <-> (i, j+1), plus source at j = 0
    mask = jj < m - 1
    r0, r1 = ridx(ii, jj), ridx(ii, jnp.minimum(jj + 1, m - 1))
    gmask = jnp.where(mask, g_w, 0.0)
    A = A.at[r0, r0].add(gmask)
    A = A.at[r1, r1].add(gmask)
    A = A.at[r0, r1].add(-gmask)
    A = A.at[r1, r0].add(-gmask)

    # source: node (i, 0) tied to V_in[i] through one wire segment
    src = jj == 0
    gsrc = jnp.where(src, g_w, 0.0)
    A = A.at[r0, r0].add(gsrc)
    b = b.at[r0].add(jnp.where(src, g_w * v_in[ii], 0.0))

    # column wire segments: (i, j) <-> (i+1, j), sense ground past i = n-1
    maskc = ii < n - 1
    c0, c1 = cidx(ii, jj), cidx(jnp.minimum(ii + 1, n - 1), jj)
    gmc = jnp.where(maskc, g_w, 0.0)
    A = A.at[c0, c0].add(gmc)
    A = A.at[c1, c1].add(gmc)
    A = A.at[c0, c1].add(-gmc)
    A = A.at[c1, c0].add(-gmc)

    sense = ii == n - 1
    gsn = jnp.where(sense, g_w, 0.0)
    A = A.at[c0, c0].add(gsn)  # tied to 0 V, no b contribution
    return A, b


@partial(jax.jit, static_argnames=("r_access",))
def solve_planar(g_dev: jax.Array, v_in: jax.Array,
                 r_wire: float = PAPER.r_wire,
                 r_access: float | None = None):
    """Exact nodal solve of an n x m planar crossbar.

    Returns (i_out, v_row, v_col): per-column sense currents (m,) and the
    node voltage fields (n, m).
    """
    if r_access is None:
        r_access = PAPER.r_on_transistor
    n, m = g_dev.shape
    g = _series(g_dev, r_access)
    g_w = 1.0 / r_wire
    A, b = _assemble_planar(g, v_in, g_w)
    v = jnp.linalg.solve(A, b)
    v_row = v[: n * m].reshape(n, m)
    v_col = v[n * m:].reshape(n, m)
    i_out = g_w * v_col[n - 1, :]  # current into the virtual ground
    return i_out, v_row, v_col


@partial(jax.jit, static_argnames=("r_access",))
def solve_crossstack(g_top: jax.Array, g_bot: jax.Array,
                     v_in_top: jax.Array, v_in_bot: jax.Array,
                     r_wire: float = PAPER.r_wire,
                     r_access: float | None = None):
    """Exact nodal solve of a CrossStack pair (expansion mode).

    Two r x m planes share the column nodes: device (p, i, j) connects row
    node (p, i, j) to shared column node (i, j).  Unknowns: 2*r*m row nodes
    (top then bottom) + r*m column nodes.

    Returns (i_out, v_rows, v_col) with v_rows shaped (2, r, m).
    """
    if r_access is None:
        r_access = PAPER.r_on_transistor
    r, m = g_top.shape
    gt = _series(g_top, r_access)
    gb = _series(g_bot, r_access)
    g_w = 1.0 / r_wire
    nn = r * m

    def ridx(p, i, j):
        return p * nn + i * m + j

    def cidx(i, j):
        return 2 * nn + i * m + j

    N = 3 * nn
    A = jnp.zeros((N, N))
    b = jnp.zeros((N,))

    ii, jj = jnp.meshgrid(jnp.arange(r), jnp.arange(m), indexing="ij")
    ii, jj = ii.ravel(), jj.ravel()

    for p, (gp, vp) in enumerate(((gt, v_in_top), (gb, v_in_bot))):
        gg = gp.ravel()
        r_, c_ = ridx(p, ii, jj), cidx(ii, jj)
        A = A.at[r_, r_].add(gg)
        A = A.at[c_, c_].add(gg)
        A = A.at[r_, c_].add(-gg)
        A = A.at[c_, r_].add(-gg)

        mask = jj < m - 1
        r0 = ridx(p, ii, jj)
        r1 = ridx(p, ii, jnp.minimum(jj + 1, m - 1))
        gmask = jnp.where(mask, g_w, 0.0)
        A = A.at[r0, r0].add(gmask)
        A = A.at[r1, r1].add(gmask)
        A = A.at[r0, r1].add(-gmask)
        A = A.at[r1, r0].add(-gmask)

        src = jj == 0
        A = A.at[r0, r0].add(jnp.where(src, g_w, 0.0))
        b = b.at[r0].add(jnp.where(src, g_w * vp[ii], 0.0))

    maskc = ii < r - 1
    c0, c1 = cidx(ii, jj), cidx(jnp.minimum(ii + 1, r - 1), jj)
    gmc = jnp.where(maskc, g_w, 0.0)
    A = A.at[c0, c0].add(gmc)
    A = A.at[c1, c1].add(gmc)
    A = A.at[c0, c1].add(-gmc)
    A = A.at[c1, c0].add(-gmc)

    sense = ii == r - 1
    A = A.at[c0, c0].add(jnp.where(sense, g_w, 0.0))

    v = jnp.linalg.solve(A, b)
    v_rows = v[: 2 * nn].reshape(2, r, m)
    v_col = v[2 * nn:].reshape(r, m)
    i_out = g_w * v_col[r - 1, :]
    return i_out, v_rows, v_col


# ---------------------------------------------------------------------------
# Iterative (Jacobi) solve for large arrays — stencil form
# ---------------------------------------------------------------------------

def jacobi_planar(g_dev: jax.Array, v_in: jax.Array,
                  r_wire: float = PAPER.r_wire,
                  r_access: float | None = None,
                  n_iter: int = 2000, omega: float = 1.0):
    """Damped-Jacobi solve of the same planar network, O(n*m) per sweep.

    This stencil form is the oracle for ``kernels/ir_solve`` and scales to
    large fidelity studies (128 x 128+) where the dense solve is infeasible.
    """
    if r_access is None:
        r_access = PAPER.r_on_transistor
    n, m = g_dev.shape
    g = _series(g_dev, r_access)
    g_w = 1.0 / r_wire

    def sweep(state, _):
        v_row, v_col = state
        # row nodes: west neighbour (or source), east neighbour, device
        west = jnp.concatenate([v_in[:, None], v_row[:, :-1]], axis=1)
        east_g = jnp.concatenate(
            [jnp.full((n, m - 1), g_w), jnp.zeros((n, 1))], axis=1)
        east_v = jnp.concatenate([v_row[:, 1:], jnp.zeros((n, 1))], axis=1)
        num_r = g_w * west + east_g * east_v + g * v_col
        den_r = g_w + east_g + g
        v_row_new = v_row + omega * (num_r / den_r - v_row)

        # column nodes: north neighbour, south neighbour (or ground), device
        north_g = jnp.concatenate(
            [jnp.zeros((1, m)), jnp.full((n - 1, m), g_w)], axis=0)
        north_v = jnp.concatenate([jnp.zeros((1, m)), v_col[:-1, :]], axis=0)
        south_v = jnp.concatenate([v_col[1:, :], jnp.zeros((1, m))], axis=0)
        num_c = north_g * north_v + g_w * south_v + g * v_row_new
        den_c = north_g + g_w + g
        v_col_new = v_col + omega * (num_c / den_c - v_col)
        return (v_row_new, v_col_new), ()

    v0 = (jnp.broadcast_to(v_in[:, None], (n, m)).astype(jnp.float32),
          jnp.zeros((n, m), jnp.float32))
    (v_row, v_col), _ = jax.lax.scan(sweep, v0, None, length=n_iter)
    i_out = g_w * v_col[n - 1, :]
    return i_out, v_row, v_col


# ---------------------------------------------------------------------------
# Mode scoring (per-layer expansion vs deep-net IR deviation)
# ---------------------------------------------------------------------------

def capped_geometry(r: int, m: int, max_nodes: int = 1024
                    ) -> tuple[int, int]:
    """Shrink a tile geometry until the dense nodal solves stay tractable.

    The expansion solve has ``3*r*m`` unknowns and the planar comparison
    ``4*r*m`` (2r rows); dense LU beyond a few thousand nodes is not worth
    paying inside a policy decision.  The aspect ratio is preserved and
    both axes keep at least 2 nodes, so the *relative* expansion-vs-planar
    deviation — the quantity the policy ranks on — is scored on a
    faithful proxy of the tile.  Geometries already under the cap are
    returned unchanged (``max_nodes >= 3*r*m``), i.e. small paper-scale
    tiles are scored exactly.
    """
    while 3 * r * m > max_nodes and (r > 2 or m > 2):
        if r >= m and r > 2:
            r = -(-r // 2)
        else:
            m = -(-m // 2)
    return r, m


def mode_ir_report(r: int, m: int, r_wire: float = PAPER.r_wire,
                   params=PAPER, max_nodes: int = 1024) -> dict:
    """Worst-case IR deviation of one conversion group, per read mode.

    One expansion-mode conversion sums ``2r`` inputs split across the two
    stacked planes of an ``r x m`` tile (shared column passes r nodes);
    the deep-net layout of the *same* doubled-input read is a planar
    ``2r x m`` array whose column passes all 2r nodes — the paper's
    Fig. 3b comparison at the tile's own geometry.  Both are solved
    exactly at the worst-case operating point (every cell SET, every row
    driven at V_read, maximum column current) and scored by the mean
    per-column relative current loss — the metric under the paper's 22 %
    claim, reproduced by ``benchmarks/paper_benches.bench_ir_drop``.

    Returns ``dev_deepnet``, ``dev_expansion`` (fractional losses),
    ``ir_drop_reduction`` (1 - expansion/deepnet), and the (possibly
    capped, see :func:`capped_geometry`) geometry that was scored.
    """
    r_s, m_s = capped_geometry(int(r), int(m), max_nodes)
    g_half = jnp.full((r_s, m_s), params.g_set)
    g_full = jnp.full((2 * r_s, m_s), params.g_set)
    v_half = jnp.full((r_s,), params.v_read)
    v_full = jnp.full((2 * r_s,), params.v_read)
    i_ideal = ideal_currents(
        _series(g_full, params.r_on_transistor), v_full)
    i_pl, _, _ = solve_planar(g_full, v_full, r_wire)
    i_cs, _, _ = solve_crossstack(g_half, g_half, v_half, v_half, r_wire)
    dev_pl = float(ir_drop_loss(i_pl, i_ideal).mean())
    dev_cs = float(ir_drop_loss(i_cs, i_ideal).mean())
    return {
        "tile_rows": r_s,
        "tile_cols": m_s,
        "dev_deepnet": dev_pl,
        "dev_expansion": dev_cs,
        "ir_drop_reduction": 1.0 - dev_cs / dev_pl if dev_pl else 0.0,
    }


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------

def ideal_currents(g_dev: jax.Array, v_in: jax.Array) -> jax.Array:
    """Zero-wire-resistance column currents: i = v^T G (Eq. 1)."""
    return v_in @ g_dev


def ir_drop_loss(i_actual: jax.Array, i_ideal: jax.Array) -> jax.Array:
    """Per-column relative current loss due to line resistance."""
    return 1.0 - i_actual / i_ideal


def attenuation_map(g_dev: jax.Array, v_in: jax.Array,
                    r_wire: float = PAPER.r_wire) -> jax.Array:
    """First-order per-column attenuation used by the engine's fast
    IR-compensation path: i_actual ~ attenuation * i_ideal for operating
    points near the calibration inputs."""
    i_act, _, _ = solve_planar(g_dev, v_in, r_wire)
    return i_act / ideal_currents(g_dev, v_in)
