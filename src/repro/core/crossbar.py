"""Single-plane crossbar array model (analog MAC, Eq. 1 of the paper).

A plane is an (n_rows, n_cols) conductance array.  ``mac`` implements the
ideal i = V^T G read-out; the noisy / non-ideal variants layer in device
variability (Table I tolerances), access-transistor series resistance,
deep-net-mode leakage from the co-located write plane, and a first-order
IR-drop attenuation calibrated from the exact nodal solver.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.timing import PAPER, CrossStackParams
from repro.core import ir_drop
from repro.core.device import transistor_leakage


@dataclasses.dataclass(frozen=True)
class PlaneConfig:
    n_rows: int
    n_cols: int
    params: CrossStackParams = PAPER
    include_access_r: bool = True


def effective_conductance(g: jax.Array, cfg: PlaneConfig) -> jax.Array:
    if not cfg.include_access_r:
        return g
    return 1.0 / (1.0 / jnp.maximum(g, 1e-12) + cfg.params.r_on_transistor)


def mac(v_in: jax.Array, g: jax.Array, cfg: PlaneConfig) -> jax.Array:
    """Ideal analog MAC: per-column currents i = v^T g_eff (KCL)."""
    return v_in @ effective_conductance(g, cfg)


def mac_noisy(key: jax.Array, v_in: jax.Array, g: jax.Array,
              cfg: PlaneConfig, rel_sigma: float | None = None) -> jax.Array:
    """MAC with lognormal-ish multiplicative conductance variability.

    Default sigma interpolates the Table-I corners: 7 % near G_set,
    10 % near G_reset.
    """
    p = cfg.params
    if rel_sigma is None:
        frac = (g - p.g_reset) / (p.g_set - p.g_reset)
        rel_sigma = p.r_reset_tol + (p.r_set_tol - p.r_reset_tol) * frac
    noise = 1.0 + rel_sigma * jax.random.normal(key, g.shape)
    return v_in @ effective_conductance(g * noise, cfg)


def write_plane_leakage(v_write_rows: jax.Array, cfg: PlaneConfig) -> jax.Array:
    """Column current leaked by a plane that is being *programmed* while the
    other plane reads (deep-net mode, paper Fig. 3c).

    Per cell, the OFF N1 transistor leaks ~2.5 pA at the worst-case bias;
    leakage scales with the write drive on each row and accumulates down
    each column (paper: 25 pA for a 10-cell column = 6.3e-2 % of the
    worst-case read current).
    """
    i_cell = transistor_leakage(v_write_rows, jnp.zeros_like(v_write_rows),
                                cfg.params)
    return jnp.broadcast_to(jnp.sum(i_cell)[None], (cfg.n_cols,))


def mac_with_ir(v_in: jax.Array, g: jax.Array, cfg: PlaneConfig,
                exact: bool = False) -> jax.Array:
    """MAC including line-resistance losses.

    exact=True: full nodal solve (small planes).  exact=False: first-order
    per-column attenuation map from the solver at the nominal operating
    point (fast path used inside the engine; validated against the exact
    solve in tests).
    """
    if exact:
        i_out, _, _ = ir_drop.solve_planar(g, v_in, cfg.params.r_wire)
        return i_out
    atten = ir_drop.attenuation_map(g, jnp.full((cfg.n_rows,),
                                                cfg.params.v_read),
                                    cfg.params.r_wire)
    return (v_in @ effective_conductance(g, cfg)) * atten


def worst_case_power(cfg: PlaneConfig) -> float:
    """All cells SET, full read drive — compare against Table I P_critical."""
    p = cfg.params
    i_cell = p.v_read / (p.r_set + p.r_on_transistor)
    return float(i_cell * p.v_read * cfg.n_rows * cfg.n_cols)
