"""Table-I device/circuit constants of the CrossStack prototype and the
per-mode latency/energy accounting used by the deep-net pipeline model.

All values are taken verbatim from Table I of the paper (SK Hynix 180 nm
process, Al/TiO2/TiO2-x/Al bilayer devices).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CrossStackParams:
    """Device + circuit corner set (paper Table I)."""

    r_set: float = 10e3            # R_s: static SET resistance [ohm]
    r_set_tol: float = 0.07        # +/- 7 % (Gaussian sigma used for MC)
    r_reset: float = 100e3         # R_r: static RESET resistance [ohm]
    r_reset_tol: float = 0.10      # +/- 10 %
    v_dd: float = 1.8              # supply [V]
    v_read: float = 0.5            # max read voltage [V]
    v_write: float = 1.2           # write voltage [V]
    t_read: float = 10e-9          # current read-out time [s]
    t_write: float = 250e-9        # programming time [s]
    n_devices: int = 200           # 10 x 10 x 2 prototype
    v_th: float = 0.4              # NMOS threshold [V]
    p_critical: float = 2.9e-3     # worst-case power [W]
    r_wire: float = 3.2            # wire resistance per cell [ohm]
    cell_pitch: float = 20e-6      # 20 um x 20 um cell
    w_over_l: float = 2.5          # 450 nm / 180 nm transistor sizing

    # Derived / calibrated analog front-end constants (see DESIGN.md §6).
    # N1/N2 ON resistance from the square-law triode model at
    # Vgs = Vdd, overdrive = 1.4 V, uCox ~ 300 uA/V^2 (180 nm nominal):
    # R_on = 1 / (uCox * W/L * (Vgs - Vth)) ~ 950 ohm.  This reproduces the
    # paper's measured 39.6 nA (1 % below the ideal 40 nA) single-cell read.
    u_cox: float = 300e-6          # [A/V^2]
    # Subthreshold leakage calibration: I0 such that the worst-case deep-net
    # leakage through OFF N1 is ~2.5 pA/cell at Vds ~ V_write (paper Fig 3c).
    i_leak_0: float = 2.5e-12      # [A] per cell at the worst-case bias
    subthreshold_swing: float = 0.090  # 90 mV/dec, typical 180 nm

    @property
    def g_set(self) -> float:
        return 1.0 / self.r_set

    @property
    def g_reset(self) -> float:
        return 1.0 / self.r_reset

    @property
    def r_on_transistor(self) -> float:
        """Triode ON resistance of the access transistor (N1 or N2)."""
        return 1.0 / (self.u_cox * self.w_over_l * (self.v_dd - self.v_th))


PAPER = CrossStackParams()


def read_time(n_input_bits: int, p: CrossStackParams = PAPER) -> float:
    """Total read time of a bit-serial b-bit MAC: one t_read pulse per bit."""
    return n_input_bits * p.t_read


def serial_layer_time(n_input_bits: int, p: CrossStackParams = PAPER) -> float:
    """Conventional 2-D crossbar: program, then read (steps 1-3 of §V)."""
    return p.t_write + read_time(n_input_bits, p)


def deepnet_layer_time(n_input_bits: int, p: CrossStackParams = PAPER) -> float:
    """Deep-net mode steady-state: read of layer l overlaps the write of
    layer l+1, so each pipeline stage costs max(t_write, b * t_read)."""
    return max(p.t_write, read_time(n_input_bits, p))


def deepnet_speedup(n_input_bits: int, n_layers: int = 10 ** 6,
                    p: CrossStackParams = PAPER) -> float:
    """Fractional speed improvement of deep-net mode over the serial schedule.

    Serial:   T = L * (t_write + b*t_read)
    Deep-net: T = t_write + L * max(t_write, b*t_read)   (fill + steady state)

    For b = 10 bits, t_read = 10 ns, t_write = 250 ns and large L this is
    1 - 250/350 = 28.6 % ~ "29 %" (paper §IV-B / §V).
    """
    t_serial = n_layers * serial_layer_time(n_input_bits, p)
    t_deep = p.t_write + n_layers * deepnet_layer_time(n_input_bits, p)
    return 1.0 - t_deep / t_serial


def mac_energy(n_rows: int, n_cols: int, duty: float = 1.0,
               p: CrossStackParams = PAPER) -> float:
    """Upper-bound read energy of one analog MAC over an n_rows x n_cols tile.

    Worst case: every device at G_set with the full read voltage across it.
    """
    i_cell = p.v_read * p.g_set
    power = i_cell * p.v_read * n_rows * n_cols * duty
    return power * p.t_read
