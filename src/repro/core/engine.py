"""CrossStackEngine — tiles arbitrary matmuls onto stacked crossbar pairs.

This is the bridge between the device-level digital twin and the model zoo:
any linear layer ``y = x @ W`` can be *programmed* onto a grid of CrossStack
tiles and executed with bit-exact crossbar arithmetic:

  * K (input/row) dimension   -> tiles of ``tile_rows`` rows per plane.
      - expansion mode: adjacent row-tiles are stacked onto the two planes
        and their currents sum in ANALOG on the shared column before the
        ADC (one conversion per 2*tile_rows rows — the paper's doubled-n).
      - deep-net mode: one plane is read per beat (ADC per tile_rows rows);
        the other plane is concurrently programmed (see pipeline.py).
  * N (output/col) dimension  -> tiles of ``tile_cols`` columns.
  * weights -> differential single-bit (or multi-bit) cell planes (quant.py).
  * inputs  -> two's-complement bit-serial pulse trains.
  * each (tile, slice, pulse) read passes through a saturating ADC before
    the digital shift-add recombine — quantization error is faithful.

Two execution paths share one ``ProgrammedLinear`` representation:
  * digital twin (integer-exact; also what kernels/crossbar_mac computes),
  * analog (conductance domain: device variability, access-transistor R,
    first-order IR attenuation) for fidelity studies on small layers.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Mapping
from typing import Optional

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import quant
from repro.core.device import DeviceConfig
from repro.core.quant import QuantConfig
from repro.core.timing import PAPER, CrossStackParams
from repro.core import ir_drop


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    tile_rows: int = 128
    tile_cols: int = 128
    quant: QuantConfig = QuantConfig()
    mode: str = "expansion"            # "expansion" | "deepnet"
    params: CrossStackParams = PAPER
    use_kernel: bool = False           # route MAC through the Pallas kernel
    interpret: bool = True             # Pallas interpret mode (CPU container)
    swap_leakage: bool = False         # perturb reads with write-plane
    # leakage while a hot-swap is in flight (fidelity studies; breaks
    # bit-exactness of mid-swap reads by at most the ADC residual)
    device: DeviceConfig = DeviceConfig()  # vertical stack geometry

    @property
    def rows_per_adc(self) -> int:
        """Rows summed in analog before one ADC conversion."""
        return 2 * self.tile_rows if self.mode == "expansion" else self.tile_rows

    @property
    def stack_planes(self) -> int:
        """Planes stacked per cell site (the bank height N)."""
        return self.device.stack_planes


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ProgrammedLinear:
    """Crossbar-resident weights: differential cell-code planes + scales."""
    pos: jax.Array      # (S, T, R, N_pad) int8 cell codes, T row-tiles
    neg: jax.Array      # (S, T, R, N_pad) int8
    w_scale: jax.Array  # (1, N_pad) or scalar
    k: int              # logical input dim
    n: int              # logical output dim

    def tree_flatten(self):
        return (self.pos, self.neg, self.w_scale), (self.k, self.n)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def n_devices(self) -> int:
        return 2 * int(jnp.size(self.pos))  # pos + neg planes


def _pad_to(x: jax.Array, size: int, axis: int) -> jax.Array:
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def program(w: jax.Array, cfg: EngineConfig) -> ProgrammedLinear:
    """Quantize and map a float (K, N) weight matrix onto crossbar tiles."""
    k, n = w.shape
    q = cfg.quant
    w_int, w_scale = quant.quantize_weights(w, q)
    r = cfg.tile_rows
    t = -(-k // r)
    w_int = _pad_to(w_int, t * r, axis=0)
    n_pad = -(-n // cfg.tile_cols) * cfg.tile_cols
    w_int = _pad_to(w_int, n_pad, axis=1)
    if q.per_channel:
        w_scale = _pad_to(w_scale, n_pad, axis=1)
    pos, neg = quant.to_slices(w_int, q)               # (S, T*R, N_pad)
    s = q.n_slices
    pos = pos.reshape(s, t, r, n_pad).astype(jnp.int8)
    neg = neg.reshape(s, t, r, n_pad).astype(jnp.int8)
    return ProgrammedLinear(pos, neg, w_scale, k, n)


# ---------------------------------------------------------------------------
# Digital-twin execution (integer-exact; oracle for kernels/crossbar_mac)
# ---------------------------------------------------------------------------

def _adc_codes(acc: jax.Array, cfg: EngineConfig) -> jax.Array:
    """Saturating ADC in code units.

    acc holds per-column analog sums in [0, rows_per_adc * (base-1)].
    The ADC maps this to 2**adc_bits uniform levels with clamp; we return
    the dequantized value on the same scale so recombination is a pure
    shift-add.
    """
    q = cfg.quant
    base = 2 ** q.bits_per_cell
    full_scale = cfg.rows_per_adc * (base - 1)
    levels = 2.0 ** q.adc_bits - 1.0
    lsb = full_scale / levels
    code = jnp.clip(jnp.round(acc / lsb), 0.0, levels)
    return code * lsb


# host-side dispatch accounting (bumped per call, i.e. per trace under
# jit): every matmul dispatch lands in the global telemetry registry as
# crossstack_dispatch_total{path, geometry}.  Benches and the overlap
# property test snapshot these around a decode closure's trace to prove
# the hot path lowered the Pallas kernel and not the reference scan.
_DISPATCH = "crossstack_dispatch_total"


def _count_dispatch(path: str, pw: "ProgrammedLinear") -> None:
    obs.registry().counter(
        _DISPATCH,
        help="engine.matmul dispatches per execution path, bumped per "
             "call (= per trace under jit), labeled by KxN geometry",
    ).inc(path=path, geometry=f"{pw.k}x{pw.n}")


class _PathCallsView(Mapping):
    """Deprecated read-only alias for the registry's dispatch counters.

    Kept so pre-registry callers (``eng.path_calls["kernel"]``,
    ``dict(eng.path_calls)``, equality against plain dicts) keep
    working; new code should query
    ``obs.registry().total("crossstack_dispatch_total", path=...)``,
    which also exposes the per-geometry split this view sums away.
    """

    _PATHS = ("kernel", "reference")

    def __getitem__(self, key: str) -> int:
        if key not in self._PATHS:
            raise KeyError(key)
        return int(obs.registry().total(_DISPATCH, path=key))

    def __iter__(self):
        return iter(self._PATHS)

    def __len__(self) -> int:
        return len(self._PATHS)

    def __eq__(self, other) -> bool:
        if isinstance(other, (Mapping, dict)):
            return dict(self) == dict(other)
        return NotImplemented

    __hash__ = None

    def __repr__(self) -> str:
        return f"path_calls({dict(self)})"


path_calls = _PathCallsView()


def matmul(x: jax.Array, pw: ProgrammedLinear, cfg: EngineConfig,
           leak_codes=0.0) -> jax.Array:
    """Bit-exact crossbar execution of ``x @ W`` for x of shape (..., K).

    ``leak_codes`` is the common-mode write-plane leakage in pre-ADC code
    units (deep-net overlap; see ``planes.write_leak_codes``) — a python
    float or a *traced* scalar.  The Pallas kernel fuses it into its ADC
    stage, so ``use_kernel`` traffic stays on the kernel path during an
    overlap read (precisely when throughput matters most); as a traced
    operand it changes value between decode steps without re-lowering.
    """
    if cfg.use_kernel:
        from repro.kernels.crossbar_mac import ops as cb_ops
        _count_dispatch("kernel", pw)
        return cb_ops.crossbar_matmul(x, pw, cfg, leak_codes=leak_codes)
    return matmul_reference(x, pw, cfg, leak_codes=leak_codes)


def matmul_reference(x: jax.Array, pw: ProgrammedLinear, cfg: EngineConfig,
                     leak_codes=0.0) -> jax.Array:
    """Scan-based reference: one (pulse, slice) step at a time, ADC fused.

    The einsum formulation (kept as ``_matmul_reference_einsum``) holds the
    full pre-ADC accumulator of shape (in_bits, S, B, T, N) live at once;
    scanning over the in_bits * S (pulse, slice) pairs and applying the ADC
    inside each step bounds peak activation memory at O(B * T * N) — the
    hardware reads one pulse against one cell plane per beat anyway, so the
    scan is also the faithful schedule.

    ``leak_codes`` adds the write-plane subthreshold leakage of an
    in-flight deep-net shadow write to BOTH differential columns before
    each ADC conversion (modes.deepnet_read at executor scale): the term
    is common-mode and survives only through ADC quantization.
    """
    _count_dispatch("reference", pw)
    q = cfg.quant
    lead = x.shape[:-1]
    xb = x.reshape(-1, x.shape[-1])                     # (B, K)
    x_int, x_scale = quant.quantize_inputs(xb, q)
    s, t, r, n_pad = pw.pos.shape
    x_int = _pad_to(x_int, t * r, axis=-1).reshape(-1, t, r)
    bits = quant.to_bit_serial(x_int, q)                # (b, B, T, R)
    bitw = quant.bit_weights(q)                         # (b,)
    slcw = quant.slice_weights(q)                       # (S,)

    pos = pw.pos.astype(jnp.float32)
    neg = pw.neg.astype(jnp.float32)
    b_in = bits.shape[0]
    bsz = x_int.shape[0]

    def step(y_acc, idx):
        a, sl = idx // s, idx % s
        xa = jax.lax.dynamic_index_in_dim(bits, a, 0, keepdims=False)
        p_s = jax.lax.dynamic_index_in_dim(pos, sl, 0, keepdims=False)
        n_s = jax.lax.dynamic_index_in_dim(neg, sl, 0, keepdims=False)
        # analog column sums of ONE pulse against ONE cell plane: (B, T, N)
        acc_p = jnp.einsum("btr,trn->btn", xa, p_s)
        acc_n = jnp.einsum("btr,trn->btn", xa, n_s)
        if cfg.mode == "expansion" and t % 2 == 0 and t >= 2:
            # adjacent row-tiles stacked on the two planes: analog sum first
            acc_p = acc_p.reshape(bsz, t // 2, 2, n_pad).sum(axis=2)
            acc_n = acc_n.reshape(bsz, t // 2, 2, n_pad).sum(axis=2)
        d = (_adc_codes(acc_p + leak_codes, cfg)
             - _adc_codes(acc_n + leak_codes, cfg))
        return y_acc + bitw[a] * slcw[sl] * d.sum(axis=1), None

    y_int, _ = jax.lax.scan(step, jnp.zeros((bsz, n_pad), jnp.float32),
                            jnp.arange(b_in * s))
    y = y_int * x_scale * pw.w_scale[..., :n_pad]
    return y[:, : pw.n].reshape(*lead, pw.n)


def _matmul_reference_einsum(x: jax.Array, pw: ProgrammedLinear,
                             cfg: EngineConfig) -> jax.Array:
    """Original all-at-once einsum formulation.

    O(in_bits * S * B * T * N) peak memory; retained as the oracle the
    scan-based reference must match bit for bit (tests/test_executor.py).
    """
    q = cfg.quant
    lead = x.shape[:-1]
    xb = x.reshape(-1, x.shape[-1])                     # (B, K)
    x_int, x_scale = quant.quantize_inputs(xb, q)
    s, t, r, n_pad = pw.pos.shape
    x_int = _pad_to(x_int, t * r, axis=-1).reshape(-1, t, r)
    bits = quant.to_bit_serial(x_int, q)                # (b, B, T, R)
    bitw = quant.bit_weights(q)                         # (b,)
    slcw = quant.slice_weights(q)                       # (S,)

    pos = pw.pos.astype(jnp.float32)
    neg = pw.neg.astype(jnp.float32)

    # per (pulse b, slice s, row-tile t): analog column sums
    acc_p = jnp.einsum("abtr,strn->asbtn", bits, pos)
    acc_n = jnp.einsum("abtr,strn->asbtn", bits, neg)

    if cfg.mode == "expansion" and t % 2 == 0 and t >= 2:
        # adjacent row-tiles stacked on the two planes: analog sum first
        acc_p = acc_p.reshape(*acc_p.shape[:3], t // 2, 2, n_pad).sum(axis=4)
        acc_n = acc_n.reshape(*acc_n.shape[:3], t // 2, 2, n_pad).sum(axis=4)

    acc_p = _adc_codes(acc_p, cfg)
    acc_n = _adc_codes(acc_n, cfg)

    y_int = jnp.einsum("asbtn,a,s->bn", acc_p - acc_n, bitw, slcw)
    y = y_int * x_scale * pw.w_scale[..., :n_pad]
    return y[:, : pw.n].reshape(*lead, pw.n)


def linear(x: jax.Array, w: jax.Array, cfg: EngineConfig) -> jax.Array:
    """Program-and-run convenience op (QAT / fidelity studies).

    Differentiable end to end via the STE quantizers.
    """
    return matmul(x, program(w, cfg), cfg)


# ---------------------------------------------------------------------------
# Analog execution (conductance domain, non-idealities)
# ---------------------------------------------------------------------------

def matmul_analog(key: Optional[jax.Array], x: jax.Array,
                  pw: ProgrammedLinear, cfg: EngineConfig,
                  noise: bool = True, ir_comp: bool = False) -> jax.Array:
    """Conductance-domain execution with Table-I non-idealities.

    Each (slice, row-tile) is a physical plane pair; cell codes map to
    conductances in [g_reset, g_set]; inputs map to read voltages; column
    currents pass a current-domain ADC.  Meant for small fidelity studies
    (the digital twin is the production path).
    """
    p = cfg.params
    q = cfg.quant
    lead = x.shape[:-1]
    xb = x.reshape(-1, x.shape[-1])
    x_int, x_scale = quant.quantize_inputs(xb, q)
    s, t, r, n_pad = pw.pos.shape
    x_int = _pad_to(x_int, t * r, axis=-1).reshape(-1, t, r)
    bits = quant.to_bit_serial(x_int, q)                 # (b, B, T, R)
    v_pulses = bits * p.v_read                           # 0 / V_read drives

    g_pos = quant.slices_to_conductance(pw.pos, q, p.g_reset, p.g_set)
    g_neg = quant.slices_to_conductance(pw.neg, q, p.g_reset, p.g_set)
    if noise:
        if key is None:
            raise ValueError("matmul_analog(noise=True) needs a PRNG key")
        kp, kn = jax.random.split(key)
        frac_p = (g_pos - p.g_reset) / (p.g_set - p.g_reset)
        frac_n = (g_neg - p.g_reset) / (p.g_set - p.g_reset)
        sig_p = p.r_reset_tol + (p.r_set_tol - p.r_reset_tol) * frac_p
        sig_n = p.r_reset_tol + (p.r_set_tol - p.r_reset_tol) * frac_n
        g_pos = g_pos * (1.0 + sig_p * jax.random.normal(kp, g_pos.shape))
        g_neg = g_neg * (1.0 + sig_n * jax.random.normal(kn, g_neg.shape))

    # access transistor in series
    g_pos = 1.0 / (1.0 / g_pos + p.r_on_transistor)
    g_neg = 1.0 / (1.0 / g_neg + p.r_on_transistor)

    i_p = jnp.einsum("abtr,strn->asbtn", v_pulses, g_pos)
    i_n = jnp.einsum("abtr,strn->asbtn", v_pulses, g_neg)

    if ir_comp:
        # first-order column attenuation for a nominal all-SET tile
        g_nom = jnp.full((r, n_pad), p.g_set)
        atten = ir_drop.attenuation_map(
            g_nom, jnp.full((r,), p.v_read), p.r_wire)
        i_p = i_p * atten
        i_n = i_n * atten

    if cfg.mode == "expansion" and t % 2 == 0 and t >= 2:
        i_p = i_p.reshape(*i_p.shape[:3], t // 2, 2, n_pad).sum(axis=4)
        i_n = i_n.reshape(*i_n.shape[:3], t // 2, 2, n_pad).sum(axis=4)

    # current-domain ADC: full scale = every summed cell at G_set, V_read
    g_fs = 1.0 / (p.r_set + p.r_on_transistor)
    i_fs = cfg.rows_per_adc * p.v_read * g_fs
    levels = 2.0 ** q.adc_bits - 1.0
    lsb = i_fs / levels
    i_p = jnp.clip(jnp.round(i_p / lsb), 0.0, levels)
    i_n = jnp.clip(jnp.round(i_n / lsb), 0.0, levels)

    # Convert ADC codes back to cell-code units for the shift-add.  The
    # differential subtraction cancels the common g_reset pedestal (both
    # column groups have the same cell count), so one cell-code step
    # corresponds to the *spacing* conductance, not the absolute one.
    base = 2 ** q.bits_per_cell
    g_step = (1.0 / (1.0 / p.g_set + p.r_on_transistor)
              - 1.0 / (1.0 / p.g_reset + p.r_on_transistor)) / (base - 1)
    adc_codes_per_cell_code = (p.v_read * g_step) / lsb
    y_codes = (i_p - i_n) / adc_codes_per_cell_code
    bitw = quant.bit_weights(q)
    slcw = quant.slice_weights(q)
    y_int = jnp.einsum("asbtn,a,s->bn", y_codes, bitw, slcw)
    y = y_int * x_scale * pw.w_scale[..., :n_pad]
    return y[:, : pw.n].reshape(*lead, pw.n)
