"""Deep-net mode: the in-situ read/write pipeline (paper §III-B, §V).

A conventional crossbar runs a deep net as
    write W_l -> read layer l -> write W_{l+1} -> read layer l+1 -> ...
serialized, because the single array cannot be read and programmed at once.

CrossStack's deep-net mode keeps the two stacked planes isolated
(complementary RE), so while plane A produces layer l's read-out, plane B is
*simultaneously* programmed with W_{l+1}.  The (shorter) read time is
subsumed within the programming time — 29 % faster per 10-bit convolution
(t_read = 10 ns/pulse, t_write = 250 ns; 1 - 250/350 = 28.6 %).

This module provides
  * an event-level schedule builder (validated for overlap-correctness),
  * the closed-form steady-state speedup,
  * a functional executor that runs an MLP through the ping-pong plane
    state machine of modes.py (bit-exact same result as the sequential
    net — the pipeline reorders *time*, not *math*), and
  * the TPU adaptation hook: the same schedule algebra applied to
    HBM->VMEM weight streaming (read == MXU compute of layer l,
    write == DMA of layer l+1 weights), used by kernels/deepnet_stream.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, NamedTuple, Sequence

import jax

from repro.core import engine as eng
from repro.core.timing import PAPER, CrossStackParams, read_time


class Phase(NamedTuple):
    layer: int
    kind: str      # "write" | "read"
    start: float
    end: float
    plane: int     # 0 | 1


@dataclasses.dataclass(frozen=True)
class Schedule:
    phases: List[Phase]
    total: float

    def validate(self) -> None:
        """Structural invariants of a legal deep-net schedule."""
        by_layer = {}
        for ph in self.phases:
            by_layer.setdefault(ph.layer, {})[ph.kind] = ph
        for layer, d in by_layer.items():
            w, r = d["write"], d["read"]
            assert r.start >= w.end - 1e-15, (
                f"layer {layer}: read before its write completed")
            assert w.plane == r.plane, (
                f"layer {layer}: read/write plane mismatch")
            if layer > 0:
                prev_r = by_layer[layer - 1]["read"]
                assert r.start >= prev_r.end - 1e-15, (
                    f"layer {layer}: read before its input was produced")
        # no plane is read and written at the same instant
        for a in self.phases:
            for b in self.phases:
                if (a.plane == b.plane and a.kind == "read"
                        and b.kind == "write" and a.layer != b.layer):
                    assert a.end <= b.start + 1e-15 or b.end <= a.start + 1e-15, (
                        f"plane {a.plane}: overlapping read (L{a.layer}) and "
                        f"write (L{b.layer})")


def serial_schedule(n_layers: int, in_bits: int,
                    p: CrossStackParams = PAPER) -> Schedule:
    """Conventional 2-D array: write then read, strictly alternating."""
    t, phases = 0.0, []
    t_r = read_time(in_bits, p)
    for l in range(n_layers):
        phases.append(Phase(l, "write", t, t + p.t_write, 0))
        t += p.t_write
        phases.append(Phase(l, "read", t, t + t_r, 0))
        t += t_r
    return Schedule(phases, t)


def deepnet_schedule(n_layers: int, in_bits: int,
                     p: CrossStackParams = PAPER) -> Schedule:
    """Pipelined schedule: write of layer l+1 overlaps read of layer l.

    Layer l lives on plane l % 2.  The write of layer l+1 may start as soon
    as its plane is free (i.e. the read of layer l-1 finished); the read of
    layer l starts when both its own write and the previous read are done.
    """
    t_r = read_time(in_bits, p)
    phases: List[Phase] = []
    write_end = [0.0] * n_layers
    read_end = [0.0] * n_layers
    for l in range(n_layers):
        plane = l % 2
        # plane free once the read two layers back has finished
        plane_free = read_end[l - 2] if l >= 2 else 0.0
        w_start = max(plane_free,
                      write_end[l - 1] if l >= 1 else 0.0)  # one write port
        w_end = w_start + p.t_write
        write_end[l] = w_end
        r_start = max(w_end, read_end[l - 1] if l >= 1 else 0.0)
        r_end = r_start + t_r
        read_end[l] = r_end
        phases.append(Phase(l, "write", w_start, w_end, plane))
        phases.append(Phase(l, "read", r_start, r_end, plane))
    return Schedule(phases, read_end[-1])


def speedup(n_layers: int, in_bits: int,
            p: CrossStackParams = PAPER) -> float:
    """Fractional latency reduction of deep-net over serial execution."""
    s = serial_schedule(n_layers, in_bits, p)
    d = deepnet_schedule(n_layers, in_bits, p)
    d.validate()
    return 1.0 - d.total / s.total


# ---------------------------------------------------------------------------
# Functional executor: MLP through the plane ping-pong
# ---------------------------------------------------------------------------

def deepnet_mlp(x: jax.Array, weights: Sequence[jax.Array],
                cfg: eng.EngineConfig,
                act: Callable[[jax.Array], jax.Array] = jax.nn.relu
                ) -> jax.Array:
    """Run an MLP through the deep-net state machine.

    Layer l's programmed tiles live on plane l % 2 while plane (l+1) % 2 is
    being loaded with layer l+1 — functionally identical to the sequential
    net (asserted in tests); the gain is temporal and is accounted by
    ``deepnet_schedule``.  Engine mode must be "deepnet" so each ADC
    conversion spans a single plane.
    """
    assert cfg.mode == "deepnet", "deepnet_mlp requires deep-net engine mode"
    h = x
    n = len(weights)
    for l, w in enumerate(weights):
        pw = eng.program(w, cfg)   # "write" of plane l % 2
        h = eng.matmul(h, pw, cfg)  # "read" concurrent with write l+1
        if l < n - 1:
            h = act(h)
    return h


def latency_report(n_layers: int, in_bits: int,
                   p: CrossStackParams = PAPER) -> dict:
    s = serial_schedule(n_layers, in_bits, p)
    d = deepnet_schedule(n_layers, in_bits, p)
    d.validate()
    return {
        "n_layers": n_layers,
        "in_bits": in_bits,
        "t_serial_us": s.total * 1e6,
        "t_deepnet_us": d.total * 1e6,
        "speedup_frac": 1.0 - d.total / s.total,
        "steady_state_frac": 1.0 - max(p.t_write, read_time(in_bits, p))
        / (p.t_write + read_time(in_bits, p)),
    }


# ---------------------------------------------------------------------------
# TPU adaptation: the same schedule algebra for weight streaming
# ---------------------------------------------------------------------------

def streaming_speedup(t_compute: float, t_dma: float, n_tiles: int) -> float:
    """Deep-net schedule applied to HBM->VMEM weight streaming.

    read  == MXU compute of tile l      (t_compute)
    write == DMA of tile l+1's weights  (t_dma)

    Serial: n * (t_dma + t_compute); pipelined: t_dma + n * max(...).
    This is the napkin model behind kernels/deepnet_stream and the §Perf
    collective-overlap analysis.
    """
    serial = n_tiles * (t_dma + t_compute)
    piped = t_dma + n_tiles * max(t_dma, t_compute)
    return 1.0 - piped / serial
