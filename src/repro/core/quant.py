"""Weight -> conductance and activation -> voltage quantization.

The paper's array uses conservative single-bit cells ("Restricting each
memristor to one of two conductance values ... one would require log2(n)
memristors for n bits of precision", §V) with differential columns for sign,
and bit-serial inputs (a 10-bit convolution = 10 read pulses of t_read each,
§IV-B).  This module implements exactly that digital-twin arithmetic:

* weights  -> symmetric int, split into differential (+/-) single-bit planes,
* inputs   -> two's-complement bit-serial pulse trains,
* read-out -> per-column ADC with saturation, then signed shift-add recombine.

Multi-bit cells (up to the paper's 3.5-bit variability limit) are supported
via ``bits_per_cell``.  All quantizers carry straight-through gradients so
the engine is usable inside QAT training loops.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    w_bits: int = 4          # magnitude bits per differential side
    in_bits: int = 8         # input bits (two's complement, bit-serial)
    adc_bits: int = 8        # ADC resolution per column read
    bits_per_cell: int = 1   # conductance levels per device = 2**bits_per_cell
    per_channel: bool = True  # per-output-column weight scales

    @property
    def n_slices(self) -> int:
        """Cell planes per differential side: ceil(w_bits / bits_per_cell)."""
        return -(-self.w_bits // self.bits_per_cell)


# -- straight-through rounding ----------------------------------------------

@jax.custom_vjp
def ste_round(x):
    return jnp.round(x)


def _ste_fwd(x):
    return jnp.round(x), None


def _ste_bwd(_, g):
    return (g,)


ste_round.defvjp(_ste_fwd, _ste_bwd)


# -- weights -----------------------------------------------------------------

def weight_scales(w: jax.Array, cfg: QuantConfig) -> jax.Array:
    """Symmetric quantization scale(s); per output column if per_channel."""
    qmax = 2.0 ** cfg.w_bits - 1.0
    if cfg.per_channel:
        amax = jnp.max(jnp.abs(w), axis=0, keepdims=True)
    else:
        amax = jnp.max(jnp.abs(w))
    return jnp.maximum(amax, 1e-8) / qmax


def quantize_weights(w: jax.Array, cfg: QuantConfig
                     ) -> Tuple[jax.Array, jax.Array]:
    """float (K, N) -> signed int in [-qmax, qmax] plus scale(s)."""
    scale = weight_scales(w, cfg)
    qmax = 2.0 ** cfg.w_bits - 1.0
    w_int = jnp.clip(ste_round(w / scale), -qmax, qmax)
    return w_int, scale


def to_slices(w_int: jax.Array, cfg: QuantConfig) -> Tuple[jax.Array, jax.Array]:
    """Split signed ints into differential single-/multi-bit cell planes.

    Returns (pos_slices, neg_slices), each (n_slices, K, N) holding cell
    values in [0, 2**bits_per_cell - 1] — i.e. programmed conductance codes.
    Slice s carries digit s in base 2**bits_per_cell, LSB first.
    """
    wp = jnp.maximum(w_int, 0.0).astype(jnp.int32)
    wn = jnp.maximum(-w_int, 0.0).astype(jnp.int32)
    base = 2 ** cfg.bits_per_cell

    def digits(x):
        out = []
        for s in range(cfg.n_slices):
            out.append((x // (base ** s)) % base)
        return jnp.stack(out, axis=0)

    return digits(wp), digits(wn)


def slices_to_conductance(slices: jax.Array, cfg: QuantConfig,
                          g_reset: float, g_set: float) -> jax.Array:
    """Map cell codes [0, levels-1] to device conductances [g_reset, g_set].

    Linear conductance spacing (standard multi-level-cell programming
    target; single-bit cells hit exactly {g_reset, g_set})."""
    levels = 2 ** cfg.bits_per_cell
    frac = slices.astype(jnp.float32) / (levels - 1)
    return g_reset + frac * (g_set - g_reset)


# -- inputs -------------------------------------------------------------------

def quantize_inputs(x: jax.Array, cfg: QuantConfig
                    ) -> Tuple[jax.Array, jax.Array]:
    """float (..., K) -> two's-complement ints in [-2^(b-1), 2^(b-1)-1]."""
    qmax = 2.0 ** (cfg.in_bits - 1) - 1.0
    amax = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-8)
    scale = amax / qmax
    x_int = jnp.clip(ste_round(x / scale), -qmax - 1, qmax)
    return x_int, scale


def to_bit_serial(x_int: jax.Array, cfg: QuantConfig) -> jax.Array:
    """Signed int -> (in_bits, ..., K) binary pulse train (two's complement,
    LSB first).  Each pulse is a 0/V_read row drive; the MSB recombines with
    weight -2^(b-1) (signed shift-add), which is how the digital twin
    handles negative activations without a second read phase."""
    b = cfg.in_bits
    u = (x_int.astype(jnp.int32) + (1 << b)) % (1 << b)  # two's complement
    bits = [(u >> s) & 1 for s in range(b)]
    return jnp.stack(bits, axis=0).astype(jnp.float32)


def bit_weights(cfg: QuantConfig) -> jax.Array:
    """Signed positional weights of the bit-serial pulses, LSB first."""
    w = [2.0 ** s for s in range(cfg.in_bits - 1)]
    w.append(-(2.0 ** (cfg.in_bits - 1)))  # MSB of two's complement
    return jnp.asarray(w, jnp.float32)


def slice_weights(cfg: QuantConfig) -> jax.Array:
    """Positional weights of the cell planes, LSB first."""
    base = 2 ** cfg.bits_per_cell
    return jnp.asarray([float(base ** s) for s in range(cfg.n_slices)],
                       jnp.float32)


# -- ADC ----------------------------------------------------------------------

def adc(i_col: jax.Array, cfg: QuantConfig, i_full_scale: float) -> jax.Array:
    """Uniform ADC with saturation: current -> integer code, STE gradient.

    i_full_scale is the column full-scale current (tile_rows * max cell
    current); codes occupy [0, 2^adc_bits - 1].
    """
    levels = 2.0 ** cfg.adc_bits - 1.0
    x = jnp.clip(i_col / i_full_scale, 0.0, 1.0) * levels
    return ste_round(x) / levels * i_full_scale
