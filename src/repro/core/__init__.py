"""CrossStack core: device physics, crossbar operating modes, and the tiled
crossbar execution engine (the paper's primary contribution as a composable
JAX module)."""

from repro.core.timing import PAPER, CrossStackParams, deepnet_speedup
from repro.core.quant import QuantConfig
from repro.core.engine import (
    EngineConfig,
    ProgrammedLinear,
    program,
    matmul,
    linear,
)

__all__ = [
    "PAPER",
    "CrossStackParams",
    "deepnet_speedup",
    "QuantConfig",
    "EngineConfig",
    "ProgrammedLinear",
    "program",
    "matmul",
    "linear",
]
