"""Array-level semantics of the two CrossStack operating modes (paper §III).

The read-enable (RE) signal per cell decides where device current flows:

* RE high -> N1 on, N2 off: device couples to the shared column (read).
* RE low  -> N1 off, N2 on: device path to ground (write), isolated from the
  column except for N1 subthreshold leakage.

Expansion mode: both planes RE-high -> one logical crossbar with 2n rows on
an n-node shared column (Eq. 1 with doubled n).

Deep-net mode: complementary RE -> the read plane produces the MAC while the
write plane is programmed with the *next* layer's weights; its only coupling
into the read-out is the leakage term.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.timing import PAPER, CrossStackParams
from repro.core import crossbar, ir_drop
from repro.core.crossbar import PlaneConfig


class StackState(NamedTuple):
    """A stacked pair of conductance planes plus which one is read-active.

    The N = 2 special case of :class:`BankState`; kept as the named shape
    the paper's figures (and the expansion-mode ops below) speak in.
    """
    g_top: jax.Array       # (r, m)
    g_bot: jax.Array       # (r, m)
    read_top: jax.Array    # bool scalar — deep-net ping-pong selector


class BankState(NamedTuple):
    """An N-high stack of conductance planes plus the read-active index.

    Generalizes :class:`StackState` (g_top, g_bot, read_top) to the
    plane-bank geometry of ``DeviceConfig.stack_planes``: one plane
    serves reads while any of the other N-1 planes may be programmed.
    ``read_idx`` may be a traced scalar, so a jitted serving loop can
    rotate the ring without re-lowering.
    """
    g: jax.Array           # (N, r, m) conductance planes
    read_idx: jax.Array    # int32 scalar — which plane is read-active


@dataclasses.dataclass(frozen=True)
class StackConfig:
    rows_per_plane: int
    n_cols: int
    params: CrossStackParams = PAPER

    @property
    def plane(self) -> PlaneConfig:
        return PlaneConfig(self.rows_per_plane, self.n_cols, self.params)


# -- expansion mode -----------------------------------------------------------

def expansion_mac(state: StackState, v_top: jax.Array, v_bot: jax.Array,
                  cfg: StackConfig) -> jax.Array:
    """i = [v_top; v_bot]^T [G_top; G_bot] — Eq. 1 with n doubled.

    Both planes' RE are identical (high): currents from above and below the
    shared electrode sum by KCL.
    """
    return (crossbar.mac(v_top, state.g_top, cfg.plane)
            + crossbar.mac(v_bot, state.g_bot, cfg.plane))


def expansion_mac_ir(state: StackState, v_top: jax.Array, v_bot: jax.Array,
                     cfg: StackConfig) -> jax.Array:
    """Expansion-mode MAC through the exact shared-column nodal solve."""
    i_out, _, _ = ir_drop.solve_crossstack(
        state.g_top, state.g_bot, v_top, v_bot, cfg.params.r_wire)
    return i_out


def expansion_program(state: StackState, g_top_new: jax.Array,
                      g_bot_new: jax.Array) -> StackState:
    """RE low on both planes: column isolated, both planes written."""
    return StackState(g_top_new, g_bot_new, state.read_top)


# -- deep-net mode -------------------------------------------------------------

def deepnet_read(state: StackState, v_in: jax.Array, cfg: StackConfig,
                 v_write_other: jax.Array | None = None,
                 include_leakage: bool = True) -> jax.Array:
    """Read the active plane while the other is being programmed.

    The write plane contributes only N1 subthreshold leakage into the shared
    column (paper Fig. 3c: ~2.5 pA/cell, negligible vs the read current).
    """
    g_read = jnp.where(state.read_top, state.g_top, state.g_bot)
    i = crossbar.mac(v_in, g_read, cfg.plane)
    if include_leakage:
        if v_write_other is None:
            v_write_other = jnp.full((cfg.rows_per_plane,),
                                     cfg.params.v_write)
        i = i + crossbar.write_plane_leakage(v_write_other, cfg.plane)
    return i


def deepnet_write_inactive(state: StackState, g_new: jax.Array) -> StackState:
    """Program the *inactive* plane with the next layer's weights."""
    g_top = jnp.where(state.read_top, state.g_top, g_new)
    g_bot = jnp.where(state.read_top, g_new, state.g_bot)
    return StackState(g_top, g_bot, state.read_top)


def deepnet_swap(state: StackState) -> StackState:
    """Flip roles once the concurrent read and write both complete."""
    return StackState(state.g_top, state.g_bot,
                      jnp.logical_not(state.read_top))


def deepnet_layer(state: StackState, v_in: jax.Array, g_next: jax.Array,
                  cfg: StackConfig) -> tuple[jax.Array, StackState]:
    """One full deep-net pipeline beat: read active plane, write next-layer
    weights into the inactive plane, swap.  Returns (currents, new state)."""
    i = deepnet_read(state, v_in, cfg)
    state = deepnet_write_inactive(state, g_next)
    return i, deepnet_swap(state)


# -- N-plane banks (DeviceConfig.stack_planes > 2) ----------------------------

def bank_from_pair(state: StackState) -> BankState:
    """Lift a 2-plane StackState into the bank representation (plane 0 =
    top, plane 1 = bottom; read_idx 0 <=> read_top)."""
    g = jnp.stack([state.g_top, state.g_bot], axis=0)
    idx = jnp.where(state.read_top, 0, 1).astype(jnp.int32)
    return BankState(g, idx)


def bank_write_idx(state: BankState) -> jax.Array:
    """The ring's next write target: the plane after the read-active one
    (for N = 2 this is exactly the classic inactive/shadow plane)."""
    return (state.read_idx + 1) % state.g.shape[0]


def bank_read(state: BankState, v_in: jax.Array, cfg: StackConfig,
              n_writing: int = 1, v_write_other: jax.Array | None = None,
              include_leakage: bool = True) -> jax.Array:
    """Read the active plane of an N-high bank while ``n_writing`` other
    planes are being programmed.

    Each concurrently writing plane contributes one N1 subthreshold
    leakage term into the shared column (paper Fig. 3c); planes that are
    merely resident (RE floating low, no write drive) contribute nothing.
    ``bank_read(bank_from_pair(s), ...)`` is bit-exact with
    :func:`deepnet_read` on ``s``.
    """
    g_read = jnp.take(state.g, state.read_idx, axis=0)
    i = crossbar.mac(v_in, g_read, cfg.plane)
    if include_leakage and n_writing > 0:
        if v_write_other is None:
            v_write_other = jnp.full((cfg.rows_per_plane,),
                                     cfg.params.v_write)
        i = i + n_writing * crossbar.write_plane_leakage(
            v_write_other, cfg.plane)
    return i


def bank_write_plane(state: BankState, idx: jax.Array,
                     g_new: jax.Array) -> BankState:
    """Program plane ``idx`` of the bank (RE low on that plane only).
    ``idx`` may be traced; writing the read-active plane is the caller's
    bug — executor-scale code refuses it (reads pause for in-place
    swaps), the array-scale op does not police it."""
    n = state.g.shape[0]
    mask = (jnp.arange(n) == idx)[:, None, None]
    return BankState(jnp.where(mask, g_new[None], state.g), state.read_idx)


def bank_set_read(state: BankState, idx: jax.Array) -> BankState:
    """Point the read-enable at plane ``idx`` (the generalized RE flip:
    promotion retargets the read to whichever plane was just staged)."""
    return BankState(state.g, jnp.asarray(idx, jnp.int32))


def bank_advance(state: BankState) -> BankState:
    """Rotate the ring one position (N = 2: exactly ``deepnet_swap``)."""
    return bank_set_read(state, bank_write_idx(state))


def bank_fused_pair(state: BankState, idx_top: jax.Array | int = 0,
                    idx_bot: jax.Array | int = 1
                    ) -> tuple[jax.Array, jax.Array]:
    """The two planes of an expansion-fused pair inside an N-high bank.

    Expansion mode fuses exactly two planes (they share one middle
    electrode); in a taller bank the *other* N-2 planes stay independent
    — resident for other tenants, staging, or dark.  Returns the
    (g_top, g_bot) conductance pair; indices may be traced.
    """
    g_top = jnp.take(state.g, jnp.asarray(idx_top), axis=0)
    g_bot = jnp.take(state.g, jnp.asarray(idx_bot), axis=0)
    return g_top, g_bot


def bank_expansion_mac(state: BankState, v_top: jax.Array,
                       v_bot: jax.Array, cfg: StackConfig,
                       idx_top: jax.Array | int = 0,
                       idx_bot: jax.Array | int = 1) -> jax.Array:
    """Expansion-mode MAC on a fused plane pair of an N-high bank.

    Both fused planes' RE are high, so their currents sum by KCL on the
    shared column — :func:`expansion_mac` lifted to the bank geometry.
    ``bank_expansion_mac(bank_from_pair(s), ...)`` is bit-exact with
    ``expansion_mac(s, ...)`` at N = 2 (pinned in tests).  Unlike
    :func:`bank_read`, no leakage term applies: a fused pair never hosts
    an in-flight write (its executor-scale bank refuses overlap writes).
    """
    g_top, g_bot = bank_fused_pair(state, idx_top, idx_bot)
    return (crossbar.mac(v_top, g_top, cfg.plane)
            + crossbar.mac(v_bot, g_bot, cfg.plane))


def bank_expansion_mac_ir(state: BankState, v_top: jax.Array,
                          v_bot: jax.Array, cfg: StackConfig,
                          idx_top: jax.Array | int = 0,
                          idx_bot: jax.Array | int = 1) -> jax.Array:
    """Fused-pair MAC through the exact shared-column nodal solve."""
    g_top, g_bot = bank_fused_pair(state, idx_top, idx_bot)
    i_out, _, _ = ir_drop.solve_crossstack(
        g_top, g_bot, v_top, v_bot, cfg.params.r_wire)
    return i_out


def bank_layer(state: BankState, v_in: jax.Array, g_next: jax.Array,
               cfg: StackConfig) -> tuple[jax.Array, BankState]:
    """One deep-net beat on an N-high bank: read the active plane, write
    the next-layer weights into the ring's next plane, advance."""
    i = bank_read(state, v_in, cfg)
    state = bank_write_plane(state, bank_write_idx(state), g_next)
    return i, bank_advance(state)
