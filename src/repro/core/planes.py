"""Ping-pong tile planes: ``modes.StackState`` lifted to executor scale.

The paper's deep-net mode pairs every crossbar plane with a stacked twin
behind complementary RE signals: one plane serves reads while the other is
programmed, and an RE flip promotes the freshly written plane without ever
interrupting the read stream (paper §III-B).  ``modes.py`` models that at
the array level (two conductance matrices + a read selector); this module
is the same state machine at the scale ``CrossbarExecutor`` operates on —
whole ``ProgrammedLinear`` tile grids instead of single (r, m) planes:

  * :class:`PlanePair` — a read-active plane and a write-shadow plane per
    named weight, plus the content fingerprints of both planes.
  * :class:`ChunkedProgram` — incremental programming of one weight onto a
    shadow plane, one row-tile chunk at a time.  Each chunk is one write
    pulse of ``t_write`` in the device-time model (``core/timing.py``), so
    a serving loop can interleave chunks between decode steps exactly the
    way the paper hides writes under reads.
  * :class:`SwapPlan` — the ordered chunk work-list for a whole params
    tree, consumed by ``CrossbarExecutor.write_chunks`` and promoted
    atomically by ``CrossbarExecutor.promote``.
  * :func:`write_leak_codes` — the only coupling of an in-flight write
    into the read-out: N1 subthreshold leakage (paper Fig. 3c), expressed
    in pre-ADC code units so ``engine.matmul_reference`` can add it as a
    common-mode term.

Chunked programming is bit-exact with ``engine.program``: the assembled
shadow plane is the same ``ProgrammedLinear`` the one-shot path builds
(asserted in tests/test_hotswap.py), so a promoted swap serves exactly the
arithmetic a cold deploy of the new weights would.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.engine import EngineConfig, ProgrammedLinear, _pad_to


def fingerprint_weight(w2d: jax.Array) -> str:
    """Content digest of a (K, N) float32 weight — the identity of what a
    plane was programmed from (stale-params checks, promotion audit)."""
    arr = np.asarray(jax.device_get(jnp.asarray(w2d, jnp.float32)))
    h = hashlib.blake2b(digest_size=8)
    h.update(str(arr.shape).encode())
    h.update(arr.tobytes())
    return h.hexdigest()


def fingerprint_tiles(pw: ProgrammedLinear) -> str:
    """Content digest of PROGRAMMED tile state (cell codes + scales) —
    what write-verify compares, independent of where the codes came from
    (chunked assembly vs one-shot ``engine.program``)."""
    h = hashlib.blake2b(digest_size=8)
    h.update(str((pw.k, pw.n, pw.pos.shape)).encode())
    for arr in (pw.pos, pw.neg, pw.w_scale):
        h.update(np.asarray(jax.device_get(arr)).tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class PlanePair:
    """A stacked pair of tile-grid planes plus which one is read-active.

    Mirrors ``modes.StackState`` (g_top, g_bot, read_top) with whole
    ``ProgrammedLinear`` grids in place of conductance matrices.  The
    twin slot plays one of two roles:

      * **write-shadow** (``twin_tenant is None``) — empty until a
        hot-swap stages new weights into it; an RE flip then promotes it
        (single-tenant deep-net serving, PR 2).
      * **second tenant** (``twin_tenant = "B"``) — a *resident* second
        checkpoint served concurrently from the same stack: tenant "A"
        reads one plane, tenant "B" the other, and the pair multiplexes
        two models onto one physical device count (the paper's
        user-re-purposable stack, §III, applied to multi-model serving).

    Tenant "A" always addresses the ``read_a``-selected slot (so classic
    shadow flips keep working); any other tenant owns the complement.
    """
    name: str
    plane_a: Optional[ProgrammedLinear] = None
    plane_b: Optional[ProgrammedLinear] = None
    read_a: bool = True
    fp_a: Optional[str] = None
    fp_b: Optional[str] = None
    twin_tenant: Optional[str] = None

    @property
    def active(self) -> ProgrammedLinear:
        pw = self.plane_a if self.read_a else self.plane_b
        if pw is None:
            raise RuntimeError(f"{self.name}: read-active plane unprogrammed")
        return pw

    @property
    def shadow(self) -> Optional[ProgrammedLinear]:
        return self.plane_b if self.read_a else self.plane_a

    @property
    def fingerprint(self) -> str:
        fp = self.fp_a if self.read_a else self.fp_b
        if fp is None:
            raise RuntimeError(f"{self.name}: read-active plane unprogrammed")
        return fp

    @property
    def shadow_fingerprint(self) -> Optional[str]:
        return self.fp_b if self.read_a else self.fp_a

    # -- tenant addressing ---------------------------------------------------

    @property
    def twin_resident(self) -> bool:
        return self.twin_tenant is not None

    def _tenant_reads_a(self, tenant: str) -> bool:
        """Which physical slot the named tenant reads."""
        if tenant == "A":
            return self.read_a
        if self.twin_tenant != tenant:
            raise RuntimeError(
                f"{self.name}: tenant {tenant!r} is not resident on the "
                f"twin plane (twin holds {self.twin_tenant!r})")
        return not self.read_a

    def has_tenant(self, tenant: str) -> bool:
        if tenant == "A":
            return (self.plane_a if self.read_a else self.plane_b) is not None
        return self.twin_tenant == tenant

    def active_for(self, tenant: str = "A") -> ProgrammedLinear:
        pw = (self.plane_a if self._tenant_reads_a(tenant)
              else self.plane_b)
        if pw is None:
            raise RuntimeError(
                f"{self.name}: tenant {tenant!r} plane unprogrammed")
        return pw

    def fingerprint_for(self, tenant: str = "A") -> str:
        fp = self.fp_a if self._tenant_reads_a(tenant) else self.fp_b
        if fp is None:
            raise RuntimeError(
                f"{self.name}: tenant {tenant!r} plane unprogrammed")
        return fp

    def assign(self, tenant: str, pw: ProgrammedLinear, fp: str) -> None:
        """Program ``pw`` as the named tenant's resident plane.

        Tenant "A" writes the read-active slot; any other tenant claims
        (or rewrites) the twin slot, evicting the write-shadow role.
        """
        if tenant == "A":
            reads_a = self.read_a
        else:
            if self.twin_tenant not in (None, tenant):
                raise RuntimeError(
                    f"{self.name}: twin plane already holds tenant "
                    f"{self.twin_tenant!r}")
            self.twin_tenant = tenant
            reads_a = not self.read_a
        if reads_a:
            self.plane_a, self.fp_a = pw, fp
        else:
            self.plane_b, self.fp_b = pw, fp

    def clear_twin(self, tenant: str) -> None:
        """Evict the twin tenant; its slot reverts to an empty shadow."""
        if self.twin_tenant != tenant:
            raise RuntimeError(
                f"{self.name}: twin plane holds {self.twin_tenant!r}, "
                f"not {tenant!r}")
        self.twin_tenant = None
        self.drop_shadow()

    @property
    def any_plane(self) -> ProgrammedLinear:
        """Either programmed plane — the shape/tile-geometry reference."""
        pw = self.plane_a if self.plane_a is not None else self.plane_b
        if pw is None:
            raise RuntimeError(f"{self.name}: no plane programmed")
        return pw

    @property
    def n_devices(self) -> int:
        """Memristors holding the weights being SERVED (the read-active
        plane) — comparable across deploys and with the pre-plane-pair
        counts.  The stacked twin doubles the physical device count
        (:attr:`n_devices_physical`) whether or not it is programmed;
        both planes share one tile geometry, so either is the count."""
        return self.any_plane.n_devices

    @property
    def n_devices_physical(self) -> int:
        return 2 * self.any_plane.n_devices

    def stage(self, pw: ProgrammedLinear, fp: str) -> None:
        """Write ``pw`` into the shadow plane (RE low: column-isolated)."""
        if self.twin_resident:
            raise RuntimeError(
                f"{self.name}: no free shadow plane — the twin holds "
                f"tenant {self.twin_tenant!r}; swap or evict that tenant")
        if self.read_a:
            self.plane_b, self.fp_b = pw, fp
        else:
            self.plane_a, self.fp_a = pw, fp

    def flip(self) -> None:
        """Promote the shadow plane (the RE swap of ``modes.deepnet_swap``)."""
        if self.twin_resident:
            raise RuntimeError(
                f"{self.name}: cannot flip — the twin plane holds tenant "
                f"{self.twin_tenant!r}, not a staged shadow")
        if self.shadow is None:
            raise RuntimeError(f"{self.name}: no staged shadow plane to "
                               f"promote")
        self.read_a = not self.read_a

    def drop_shadow(self) -> None:
        if self.read_a:
            self.plane_b, self.fp_b = None, None
        else:
            self.plane_a, self.fp_a = None, None


class ChunkedProgram:
    """Incremental programming of one (K, N) weight onto a shadow plane.

    One chunk = one row-tile (``cfg.tile_rows`` wordlines) quantized and
    written across all cell-bit slices — one ``t_write`` pulse in the
    device-time model (slices and column tiles are independent stacks and
    program in parallel; row-tiles share the write driver and serialize).
    The per-chunk arithmetic replicates ``engine.program`` exactly, so
    ``finish()`` assembles a bit-identical ``ProgrammedLinear``.
    """

    def __init__(self, name: str, w2d: jax.Array, cfg: EngineConfig):
        self.name, self.cfg = name, cfg
        q = cfg.quant
        w2d = jnp.asarray(w2d, jnp.float32)
        self.k, self.n = w2d.shape
        self.fp = fingerprint_weight(w2d)
        self._w2d = w2d          # retained for write-verify (see verify())
        # scales come from the UNPADDED matrix (engine.program order)
        self._scale = quant.weight_scales(w2d, q)
        r = cfg.tile_rows
        self.t = -(-self.k // r)
        self.n_pad = -(-self.n // cfg.tile_cols) * cfg.tile_cols
        self._w_pad = _pad_to(w2d, self.t * r, axis=0)  # rows only; column
        # padding happens on the quantized slices (zero cells), matching
        # engine.program's zero-pad of w_int
        self._pos: List[jax.Array] = []
        self._neg: List[jax.Array] = []

    @property
    def total_chunks(self) -> int:
        return self.t

    @property
    def chunks_done(self) -> int:
        return len(self._pos)

    @property
    def done(self) -> bool:
        return self.chunks_done >= self.total_chunks

    def write_chunk(self) -> None:
        """Quantize and program the next row-tile of the shadow plane."""
        if self.done:
            raise RuntimeError(f"{self.name}: all chunks already written")
        q = self.cfg.quant
        r = self.cfg.tile_rows
        i = self.chunks_done
        rows = self._w_pad[i * r:(i + 1) * r]
        qmax = 2.0 ** q.w_bits - 1.0
        w_int = jnp.clip(quant.ste_round(rows / self._scale), -qmax, qmax)
        pos, neg = quant.to_slices(w_int, q)           # (S, r, n)
        pos = _pad_to(pos, self.n_pad, axis=2)         # zero cells, same as
        neg = _pad_to(neg, self.n_pad, axis=2)         # engine.program
        self._pos.append(pos.astype(jnp.int8))
        self._neg.append(neg.astype(jnp.int8))

    def finish(self) -> ProgrammedLinear:
        """Assemble the fully written shadow plane (bit-exact with
        ``engine.program`` on the same weight)."""
        if not self.done:
            raise RuntimeError(
                f"{self.name}: {self.total_chunks - self.chunks_done} "
                f"chunks still unwritten")
        pos = jnp.stack(self._pos, axis=1)             # (S, T, R, n_pad)
        neg = jnp.stack(self._neg, axis=1)
        w_scale = self._scale
        if self.cfg.quant.per_channel:
            w_scale = _pad_to(w_scale, self.n_pad, axis=1)
        return ProgrammedLinear(pos, neg, w_scale, self.k, self.n)

    def verify(self, staged: ProgrammedLinear) -> None:
        """Write-verify: the chunk-assembled plane must match an
        independent one-shot programming of the same weight (RRAM
        program-and-verify, at tile-grid scale).  This is the check that
        catches assembly bugs — chunk ordering, padding, scale handling —
        before a plane can ever be promoted into the read path.
        """
        from repro.core import engine
        ref = fingerprint_tiles(engine.program(self._w2d, self.cfg))
        got = fingerprint_tiles(staged)
        if got != ref:
            raise RuntimeError(
                f"{self.name}: write-verify failed — assembled shadow "
                f"tiles {got} != one-shot programming {ref}")


@dataclasses.dataclass
class SwapPlan:
    """Ordered chunk work-list for hot-swapping a whole params tree.

    One write port: chunks serialize across all tiles, so total device
    time is ``total_chunks * t_write`` — the quantity the overlapped
    schedule hides under the read stream.

    ``tenant`` names the plane set being deployed.  The default "A" is
    the classic shadow swap (stage the free twin, flip at promotion);
    ``in_place`` marks a tenant-targeted swap that rewrites that
    tenant's own resident slot — its reads pause for the swap window
    while the *other* tenant keeps serving (read-under-write re-purposed
    for multi-tenancy).  Fully written-and-verified planes are buffered
    in ``staged`` and land on the pairs only at promotion, so no read —
    either tenant's — can ever observe a partially deployed checkpoint.
    """
    programs: List[ChunkedProgram]
    leaves: Tuple[Any, ...]        # incoming tree leaves (identity check)
    params: Any                    # the incoming tree itself
    cursor: int = 0
    chunks_done: int = 0
    tenant: str = "A"
    in_place: bool = False
    staged: Dict[str, Tuple[ProgrammedLinear, str]] = dataclasses.field(
        default_factory=dict)

    @property
    def total_chunks(self) -> int:
        return sum(cp.total_chunks for cp in self.programs)

    @property
    def remaining(self) -> int:
        return self.total_chunks - self.chunks_done

    @property
    def done(self) -> bool:
        return self.remaining == 0

    @property
    def expected_fingerprints(self) -> Dict[str, str]:
        return {cp.name: cp.fp for cp in self.programs}

    def write_chunk(self) -> Optional[ChunkedProgram]:
        """Advance one chunk; returns the program if this chunk finished
        its weight (the caller stages it onto the shadow plane)."""
        if self.done:
            return None
        cp = self.programs[self.cursor]
        cp.write_chunk()
        self.chunks_done += 1
        if cp.done:
            self.cursor += 1
            return cp
        return None

    def device_write_time(self) -> float:
        """Total modeled programming time [s]: one t_write per chunk."""
        return self.total_chunks * self.programs[0].cfg.params.t_write


def write_leak_codes(cfg: EngineConfig) -> float:
    """Worst-case common-mode leakage of an in-flight shadow write, in
    pre-ADC code units.

    While a shadow plane is programmed, its OFF N1 transistors leak
    ~``i_leak_0`` per cell into the shared column (paper Fig. 3c); a full
    column of ``tile_rows`` writing cells injects ``tile_rows * i_leak_0``.
    One cell-code unit of column current is ``v_read`` across the
    conductance spacing, so the ratio is the leak in the accumulator units
    ``engine._adc_codes`` digitizes.  Differential columns cancel the term
    except through ADC quantization — which is exactly the paper's
    "negligible" claim, and what tests assert.
    """
    p = cfg.params
    base = 2 ** cfg.quant.bits_per_cell
    i_unit = p.v_read * (p.g_set - p.g_reset) / (base - 1)
    return cfg.tile_rows * p.i_leak_0 / i_unit


def write_leak_scalar(cfg: EngineConfig) -> jax.Array:
    """:func:`write_leak_codes` as a device scalar — the form a serving
    loop feeds its jitted decode closure each step: the closure takes it
    as a *traced* argument, so flipping between 0.0 (steady state) and
    the leak value (an active swap window) never re-traces, and the
    Pallas kernel fuses it pre-ADC without re-lowering."""
    return jnp.float32(write_leak_codes(cfg))
