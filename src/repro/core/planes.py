"""Plane banks: ``modes.BankState`` lifted to executor scale.

The paper's deep-net mode stacks crossbar planes behind per-plane RE
signals: one plane serves reads while another is programmed, and an RE
retarget promotes the freshly written plane without ever interrupting
the read stream (paper §III-B).  ``modes.py`` models that at the array
level (an N-high conductance stack + a read selector); this module is
the same state machine at the scale ``CrossbarExecutor`` operates on —
whole ``ProgrammedLinear`` tile grids instead of single (r, m) planes:

  * :class:`PlaneBank` — an ordered bank of ``stack_planes`` role-tagged
    plane slots per named weight.  Each slot is ``free``, ``staging``
    (reserved as the write target of an in-flight swap), or ``resident``
    for a named tenant; the bank is the unit the executor's residency
    registry is built from.  With ``stack_planes = 2`` and one tenant
    the bank is exactly the paper's ping-pong pair.
  * :class:`ChunkedProgram` — incremental programming of one weight onto a
    staging plane, one row-tile chunk at a time.  Each chunk is one write
    pulse of ``t_write`` in the device-time model (``core/timing.py``), so
    a serving loop can interleave chunks between decode steps exactly the
    way the paper hides writes under reads.
  * :class:`SwapPlan` — the ordered chunk work-list for a whole params
    tree, consumed by ``CrossbarExecutor.write_chunks`` and promoted
    atomically by ``CrossbarExecutor.promote``.
  * :func:`write_leak_codes` — the only coupling of an in-flight write
    into the read-out: N1 subthreshold leakage (paper Fig. 3c), expressed
    in pre-ADC code units so ``engine.matmul_reference`` can add it as a
    common-mode term.

Chunked programming is bit-exact with ``engine.program``: the assembled
staging plane is the same ``ProgrammedLinear`` the one-shot path builds
(asserted in tests/test_hotswap.py), so a promoted swap serves exactly the
arithmetic a cold deploy of the new weights would.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.engine import EngineConfig, ProgrammedLinear, _pad_to


def fingerprint_weight(w2d: jax.Array) -> str:
    """Content digest of a (K, N) float32 weight — the identity of what a
    plane was programmed from (stale-params checks, promotion audit)."""
    arr = np.asarray(jax.device_get(jnp.asarray(w2d, jnp.float32)))
    h = hashlib.blake2b(digest_size=8)
    h.update(str(arr.shape).encode())
    h.update(arr.tobytes())
    return h.hexdigest()


def fingerprint_tiles(pw: ProgrammedLinear) -> str:
    """Content digest of PROGRAMMED tile state (cell codes + scales) —
    what write-verify compares, independent of where the codes came from
    (chunked assembly vs one-shot ``engine.program``)."""
    h = hashlib.blake2b(digest_size=8)
    h.update(str((pw.k, pw.n, pw.pos.shape)).encode())
    for arr in (pw.pos, pw.neg, pw.w_scale):
        h.update(np.asarray(jax.device_get(arr)).tobytes())
    return h.hexdigest()


#: slot lifecycle roles: free -> staging -> resident(tenant) -> free,
#: plus the fused companion of an expansion-programmed resident slot
ROLE_FREE = "free"
ROLE_STAGING = "staging"
ROLE_RESIDENT = "resident"
ROLE_FUSED = "fused"


@dataclasses.dataclass
class PlaneSlot:
    """One physical plane of a bank plus its role in the residency
    lifecycle.  A ``resident`` slot always carries a programmed plane and
    fingerprint; a ``staging`` slot is reserved (empty until promotion
    lands the write-verified plane on it); a ``free`` slot is dark
    silicon awaiting a deploy or a swap."""
    plane: Optional[ProgrammedLinear] = None
    fp: Optional[str] = None
    role: str = ROLE_FREE
    tenant: Optional[str] = None


@dataclasses.dataclass
class PlaneBank:
    """An ordered bank of N role-tagged tile-grid plane slots.

    Mirrors ``modes.BankState`` (an N-high conductance stack + a read
    selector) with whole ``ProgrammedLinear`` grids in place of
    conductance matrices — except that at executor scale there is one
    read selector *per tenant*: every resident tenant owns exactly one
    slot, and reads address the tenant, not a physical index.  The bank
    replaces the old ``PlanePair``'s twin-slot role-juggling (one slot
    overloaded as write-shadow *or* second tenant) with explicit roles:

      * ``resident(T)`` — serves tenant T's reads (RE high for T's
        traffic).
      * ``staging`` — reserved write target of an in-flight
        :class:`SwapPlan`; lands a plane only at promotion.
      * ``free`` — unprogrammed, claimable by a new tenant or a swap.
      * ``fused(T)`` — the companion plane of an *expansion-programmed*
        resident slot: the two planes share one middle electrode and
        hold the alternating row-tile halves of one doubled-input
        weight (``modes.expansion_mac`` at ``ProgrammedLinear`` scale).
        Both planes' RE are permanently high for T's reads, so a fused
        pair can never host a concurrent write — overlap swaps are
        refused at the executor.

    ``stack_planes = 2`` with one tenant reproduces the classic
    ping-pong pair (resident + free/staging); with two tenants it is the
    PR-3 multiplex pair; taller stacks host up to N residents, or N-1
    residents plus a staging slot for zero-pause swaps.
    """
    name: str
    n_planes: int = 2
    slots: List[PlaneSlot] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        if self.n_planes < 2:
            raise ValueError(f"{self.name}: a bank needs >= 2 planes")
        if not self.slots:
            self.slots = [PlaneSlot() for _ in range(self.n_planes)]

    # -- queries -------------------------------------------------------------

    def slot_of(self, tenant: str) -> Optional[PlaneSlot]:
        for s in self.slots:
            if s.role == ROLE_RESIDENT and s.tenant == tenant:
                return s
        return None

    @property
    def residents(self) -> List[str]:
        return [s.tenant for s in self.slots if s.role == ROLE_RESIDENT]

    def has_tenant(self, tenant: str) -> bool:
        return self.slot_of(tenant) is not None

    def fused_companion(self, tenant: str) -> Optional[PlaneSlot]:
        for s in self.slots:
            if s.role == ROLE_FUSED and s.tenant == tenant:
                return s
        return None

    def is_fused(self, tenant: str) -> bool:
        """True when the tenant's weight is expansion-programmed across a
        fused plane pair (read mode "expansion")."""
        return self.fused_companion(tenant) is not None

    def mode_for(self, tenant: str) -> str:
        """The read mode the tenant's residency implies."""
        self._resident_slot(tenant)
        return "expansion" if self.is_fused(tenant) else "deepnet"

    def n_slots_of(self, tenant: str) -> int:
        """Plane slots the tenant occupies: 2 for a fused pair, else 1."""
        return 2 if self.is_fused(tenant) else 1

    def _resident_slot(self, tenant: str) -> PlaneSlot:
        s = self.slot_of(tenant)
        if s is None:
            raise RuntimeError(
                f"{self.name}: tenant {tenant!r} is not resident in this "
                f"bank (residents: {sorted(self.residents)})")
        return s

    def active_for(self, tenant: str = "A") -> ProgrammedLinear:
        s = self._resident_slot(tenant)
        if s.plane is None:
            raise RuntimeError(
                f"{self.name}: tenant {tenant!r} plane unprogrammed")
        return s.plane

    def fingerprint_for(self, tenant: str = "A") -> str:
        s = self._resident_slot(tenant)
        if s.fp is None:
            raise RuntimeError(
                f"{self.name}: tenant {tenant!r} plane unprogrammed")
        return s.fp

    def _first(self, role: str) -> Optional[PlaneSlot]:
        for s in self.slots:
            if s.role == role:
                return s
        return None

    @property
    def n_free(self) -> int:
        return sum(1 for s in self.slots if s.role == ROLE_FREE)

    @property
    def staging(self) -> Optional[PlaneSlot]:
        return self._first(ROLE_STAGING)

    # -- lifecycle: free -> staging -> resident -> free ----------------------

    def assign(self, tenant: str, pw: ProgrammedLinear, fp: str) -> None:
        """Program ``pw`` as the named tenant's resident plane: rewrite
        the tenant's own slot if resident (content only — an existing
        fused pair keeps its companion, so in-place reprograms preserve
        the read mode), else claim a free slot in deep-net layout."""
        s = self.slot_of(tenant) or self._first(ROLE_FREE)
        if s is None:
            raise RuntimeError(
                f"{self.name}: bank is full — {self.n_planes} planes hold "
                f"{sorted(self.residents)}"
                + (" plus a staging slot" if self.staging else "")
                + f"; evict a tenant before deploying {tenant!r}")
        s.plane, s.fp = pw, fp
        s.role, s.tenant = ROLE_RESIDENT, tenant

    def assign_fused(self, tenant: str, pw: ProgrammedLinear,
                     fp: str) -> None:
        """Program ``pw`` as the tenant's expansion-fused plane pair.

        The resident slot carries the programmed tiles (all row-tile
        halves — adjacent pairs map onto the two physical planes); its
        companion slot is claimed as the pair's second plane with RE
        permanently high, so it can never be a write target.  A tenant
        already fused here is rewritten in place; a deep-net resident
        cannot silently become fused — mode changes reprogram physical
        planes, so the caller must evict first.
        """
        s = self.slot_of(tenant)
        if s is not None:
            if not self.is_fused(tenant):
                raise RuntimeError(
                    f"{self.name}: tenant {tenant!r} is resident in "
                    f"deep-net layout; a mode change reprograms physical "
                    f"planes — evict the tenant (or swap) first")
            s.plane, s.fp = pw, fp
            return
        free = [sl for sl in self.slots if sl.role == ROLE_FREE]
        if len(free) < 2:
            raise RuntimeError(
                f"{self.name}: an expansion-fused weight needs TWO free "
                f"planes (both RE high), found {len(free)} of "
                f"{self.n_planes} — residents {sorted(self.residents)}"
                + (" plus a staging slot" if self.staging else "")
                + f"; evict a tenant or program {tenant!r} in deep-net "
                f"layout")
        prim, comp = free[0], free[1]
        prim.plane, prim.fp = pw, fp
        prim.role, prim.tenant = ROLE_RESIDENT, tenant
        comp.role, comp.tenant = ROLE_FUSED, tenant

    def reserve_staging(self) -> PlaneSlot:
        """Mark a free slot as the write target of an in-flight swap (RE
        low: column-isolated while chunks program)."""
        if self.staging is not None:
            raise RuntimeError(f"{self.name}: a staging slot is already "
                               f"reserved (swap in flight)")
        s = self._first(ROLE_FREE)
        if s is None:
            raise RuntimeError(
                f"{self.name}: no free plane to stage into — "
                f"{self.n_planes} planes hold {sorted(self.residents)}")
        s.role = ROLE_STAGING
        return s

    def land_staged(self, tenant: str, pw: ProgrammedLinear,
                    fp: str) -> None:
        """Promote a write-verified plane onto the staging slot and
        retarget the tenant's read-enable to it (the generalized RE
        flip); the tenant's previous slot — if any — reverts to free."""
        s = self.staging
        if s is None:
            raise RuntimeError(f"{self.name}: no staging slot reserved")
        old = self.slot_of(tenant)
        s.plane, s.fp = pw, fp
        s.role, s.tenant = ROLE_RESIDENT, tenant
        if old is not None:
            old.plane, old.fp = None, None
            old.role, old.tenant = ROLE_FREE, None

    def release_staging(self) -> None:
        """Abort: the reserved staging slot reverts to free (written
        chunks were buffered in the SwapPlan, never on the bank)."""
        s = self.staging
        if s is not None:
            s.plane, s.fp = None, None
            s.role, s.tenant = ROLE_FREE, None

    def evict(self, tenant: str) -> None:
        """Evict a resident tenant; its slot — and, for an
        expansion-fused pair, the companion plane — reverts to free."""
        s = self._resident_slot(tenant)
        comp = self.fused_companion(tenant)
        s.plane, s.fp = None, None
        s.role, s.tenant = ROLE_FREE, None
        if comp is not None:
            comp.plane, comp.fp = None, None
            comp.role, comp.tenant = ROLE_FREE, None

    # -- geometry ------------------------------------------------------------

    @property
    def any_plane(self) -> ProgrammedLinear:
        """Any programmed plane — the shape/tile-geometry reference."""
        for s in self.slots:
            if s.plane is not None:
                return s.plane
        raise RuntimeError(f"{self.name}: no plane programmed")

    @property
    def n_devices(self) -> int:
        """Memristors holding ONE plane's weights — comparable across
        deploys and with the pre-bank counts.  Every slot shares one tile
        geometry, so any programmed plane is the count."""
        return self.any_plane.n_devices

    @property
    def n_devices_physical(self) -> int:
        """Total memristors in the stack: all ``n_planes`` planes,
        programmed or dark."""
        return self.n_planes * self.any_plane.n_devices


class ChunkedProgram:
    """Incremental programming of one (K, N) weight onto a shadow plane.

    One chunk = one row-tile (``cfg.tile_rows`` wordlines) quantized and
    written across all cell-bit slices — one ``t_write`` pulse in the
    device-time model (slices and column tiles are independent stacks and
    program in parallel; row-tiles share the write driver and serialize).
    The per-chunk arithmetic replicates ``engine.program`` exactly, so
    ``finish()`` assembles a bit-identical ``ProgrammedLinear``.
    """

    def __init__(self, name: str, w2d: jax.Array, cfg: EngineConfig):
        self.name, self.cfg = name, cfg
        q = cfg.quant
        w2d = jnp.asarray(w2d, jnp.float32)
        self.k, self.n = w2d.shape
        self.fp = fingerprint_weight(w2d)
        self._w2d = w2d          # retained for write-verify (see verify())
        # scales come from the UNPADDED matrix (engine.program order)
        self._scale = quant.weight_scales(w2d, q)
        r = cfg.tile_rows
        self.t = -(-self.k // r)
        self.n_pad = -(-self.n // cfg.tile_cols) * cfg.tile_cols
        self._w_pad = _pad_to(w2d, self.t * r, axis=0)  # rows only; column
        # padding happens on the quantized slices (zero cells), matching
        # engine.program's zero-pad of w_int
        self._pos: List[jax.Array] = []
        self._neg: List[jax.Array] = []

    @property
    def total_chunks(self) -> int:
        return self.t

    @property
    def chunks_done(self) -> int:
        return len(self._pos)

    @property
    def done(self) -> bool:
        return self.chunks_done >= self.total_chunks

    def write_chunk(self) -> None:
        """Quantize and program the next row-tile of the shadow plane."""
        if self.done:
            raise RuntimeError(f"{self.name}: all chunks already written")
        q = self.cfg.quant
        r = self.cfg.tile_rows
        i = self.chunks_done
        rows = self._w_pad[i * r:(i + 1) * r]
        qmax = 2.0 ** q.w_bits - 1.0
        w_int = jnp.clip(quant.ste_round(rows / self._scale), -qmax, qmax)
        pos, neg = quant.to_slices(w_int, q)           # (S, r, n)
        pos = _pad_to(pos, self.n_pad, axis=2)         # zero cells, same as
        neg = _pad_to(neg, self.n_pad, axis=2)         # engine.program
        self._pos.append(pos.astype(jnp.int8))
        self._neg.append(neg.astype(jnp.int8))

    def finish(self) -> ProgrammedLinear:
        """Assemble the fully written shadow plane (bit-exact with
        ``engine.program`` on the same weight)."""
        if not self.done:
            raise RuntimeError(
                f"{self.name}: {self.total_chunks - self.chunks_done} "
                f"chunks still unwritten")
        pos = jnp.stack(self._pos, axis=1)             # (S, T, R, n_pad)
        neg = jnp.stack(self._neg, axis=1)
        w_scale = self._scale
        if self.cfg.quant.per_channel:
            w_scale = _pad_to(w_scale, self.n_pad, axis=1)
        return ProgrammedLinear(pos, neg, w_scale, self.k, self.n)

    def verify(self, staged: ProgrammedLinear) -> None:
        """Write-verify: the chunk-assembled plane must match an
        independent one-shot programming of the same weight (RRAM
        program-and-verify, at tile-grid scale).  This is the check that
        catches assembly bugs — chunk ordering, padding, scale handling —
        before a plane can ever be promoted into the read path.
        """
        from repro.core import engine
        ref = fingerprint_tiles(engine.program(self._w2d, self.cfg))
        got = fingerprint_tiles(staged)
        if got != ref:
            raise RuntimeError(
                f"{self.name}: write-verify failed — assembled shadow "
                f"tiles {got} != one-shot programming {ref}")


@dataclasses.dataclass
class SwapPlan:
    """Ordered chunk work-list for hot-swapping a whole params tree.

    One write port: chunks serialize across all tiles, so total device
    time is ``total_chunks * t_write`` — the quantity the overlapped
    schedule hides under the read stream.

    ``tenant`` names the plane set being deployed.  A **staged** swap
    (``in_place = False``) writes each bank's reserved staging slot and
    retargets the tenant's read-enable at promotion — the tenant keeps
    serving its old plane through the whole window.  ``in_place`` marks
    the fallback when the bank has no free slot: the swap rewrites the
    tenant's own resident slot, so that tenant's reads pause for the
    window while every *other* resident tenant keeps serving
    (read-under-write re-purposed for multi-tenancy).  Fully
    written-and-verified planes are buffered in ``staged`` and land on
    the banks only at promotion, so no read — any tenant's — can ever
    observe a partially deployed checkpoint.
    """
    programs: List[ChunkedProgram]
    leaves: Tuple[Any, ...]        # incoming tree leaves (identity check)
    params: Any                    # the incoming tree itself
    cursor: int = 0
    chunks_done: int = 0
    tenant: str = "A"
    in_place: bool = False
    staged: Dict[str, Tuple[ProgrammedLinear, str]] = dataclasses.field(
        default_factory=dict)

    @property
    def total_chunks(self) -> int:
        return sum(cp.total_chunks for cp in self.programs)

    @property
    def remaining(self) -> int:
        return self.total_chunks - self.chunks_done

    @property
    def done(self) -> bool:
        return self.remaining == 0

    @property
    def expected_fingerprints(self) -> Dict[str, str]:
        return {cp.name: cp.fp for cp in self.programs}

    def write_chunk(self) -> Optional[ChunkedProgram]:
        """Advance one chunk; returns the program if this chunk finished
        its weight (the caller stages it onto the shadow plane)."""
        if self.done:
            return None
        cp = self.programs[self.cursor]
        cp.write_chunk()
        self.chunks_done += 1
        if cp.done:
            self.cursor += 1
            return cp
        return None

    def device_write_time(self) -> float:
        """Total modeled programming time [s]: one t_write per chunk."""
        return self.total_chunks * self.programs[0].cfg.params.t_write


def write_leak_codes(cfg: EngineConfig) -> float:
    """Worst-case common-mode leakage of an in-flight shadow write, in
    pre-ADC code units.

    While a shadow plane is programmed, its OFF N1 transistors leak
    ~``i_leak_0`` per cell into the shared column (paper Fig. 3c); a full
    column of ``tile_rows`` writing cells injects ``tile_rows * i_leak_0``.
    One cell-code unit of column current is ``v_read`` across the
    conductance spacing, so the ratio is the leak in the accumulator units
    ``engine._adc_codes`` digitizes.  Differential columns cancel the term
    except through ADC quantization — which is exactly the paper's
    "negligible" claim, and what tests assert.
    """
    p = cfg.params
    base = 2 ** cfg.quant.bits_per_cell
    i_unit = p.v_read * (p.g_set - p.g_reset) / (base - 1)
    return cfg.tile_rows * p.i_leak_0 / i_unit


def write_leak_scalar(cfg: EngineConfig) -> jax.Array:
    """:func:`write_leak_codes` as a device scalar — the form a serving
    loop feeds its jitted decode closure each step: the closure takes it
    as a *traced* argument, so flipping between 0.0 (steady state) and
    the leak value (an active swap window) never re-traces, and the
    Pallas kernel fuses it pre-ADC without re-lowering."""
    return jnp.float32(write_leak_codes(cfg))
