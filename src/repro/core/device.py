"""TiO2/TiO2-x bilayer memristor device model.

Implements the standard linear-ion-drift memristor with a Biolek window,
calibrated to the paper's Table-I corners (R_on = R_s = 10 kOhm,
R_off = R_r = 100 kOhm).  Used for:

* the pinched-hysteresis-loop reproduction (paper Fig. 3a, 50 Hz drive),
* SET/RESET programming dynamics (t_write = 250 ns at V_write = 1.2 V),
* stochastic conductance sampling for Monte-Carlo fidelity studies.

Everything is pure JAX (lax.scan transients, vmappable over device arrays).
"""
from __future__ import annotations

import dataclasses
import string
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.timing import PAPER, CrossStackParams


@dataclasses.dataclass(frozen=True)
class DeviceConfig:
    """Vertical geometry of one crossbar cell site.

    The paper's 10x10x2 array stacks exactly two TiO2/TiO2-x planes per
    cell; ``stack_planes`` generalizes that height so the same serving
    stack can model taller monolithic stacks (N resident checkpoints, or
    N-1 residents plus a free staging plane for zero-pause hot-swaps).
    The default of 2 keeps every seed geometry and paper figure
    unchanged: a 2-plane stack is exactly the classic ping-pong pair.
    """
    stack_planes: int = 2

    def __post_init__(self):
        if self.stack_planes < 2:
            raise ValueError(
                f"stack_planes must be >= 2 (a read plane plus at least "
                f"one write/twin plane); got {self.stack_planes}")

    @property
    def tenant_names(self) -> Tuple[str, ...]:
        """One addressable tenant name per plane slot: "A", "B", "C", ...
        (the bank can host at most ``stack_planes`` resident checkpoints,
        one per plane)."""
        letters = string.ascii_uppercase
        return tuple(letters[i] if i < len(letters) else f"T{i}"
                     for i in range(self.stack_planes))


@dataclasses.dataclass(frozen=True)
class MemristorModel:
    """Linear ion drift + Biolek window, bilayer TiO2/TiO2-x stack."""

    r_on: float = PAPER.r_set
    r_off: float = PAPER.r_reset
    # Mobility constant chosen so a 1.2 V write pulse of 250 ns fully
    # switches the device (paper: t_write = 250 ns @ V_write = 1.2 V).
    # dw/dt = k * i(t) * f(w);  full SET traversal requires
    # integral(k * i) dt = 1 over 250 ns with i ~ V_w / R_avg.
    k_drift: float = None  # filled in __post_init__
    p_window: int = 2      # Biolek window exponent
    v_th_pos: float = 0.0  # drift threshold (TiO2 devices are threshold-free)
    v_th_neg: float = 0.0

    def __post_init__(self):
        if self.k_drift is None:
            r_avg = 0.5 * (self.r_on + self.r_off)
            # traverse w: 0 -> 1 in t_write at i = v_write / r_avg
            k = r_avg / (PAPER.v_write * PAPER.t_write)
            object.__setattr__(self, "k_drift", k)

    # -- static I/V ---------------------------------------------------------
    def resistance(self, w: jax.Array) -> jax.Array:
        """Memristance at internal state w in [0, 1] (1 = fully SET)."""
        return self.r_on * w + self.r_off * (1.0 - w)

    def conductance(self, w: jax.Array) -> jax.Array:
        return 1.0 / self.resistance(w)

    def current(self, v: jax.Array, w: jax.Array) -> jax.Array:
        return v * self.conductance(w)

    # -- dynamics -----------------------------------------------------------
    def _window(self, w: jax.Array, i: jax.Array) -> jax.Array:
        """Biolek window f = 1 - (w - stp(-i))^(2p): suppresses drift only at
        the boundary being *approached* (w=1 for SET, w=0 for RESET)."""
        stp_neg_i = jnp.where(i >= 0, 0.0, 1.0)
        x = w - stp_neg_i
        return 1.0 - x ** (2 * self.p_window)

    def dw_dt(self, v: jax.Array, w: jax.Array) -> jax.Array:
        i = self.current(v, w)
        # sign convention: positive v (SET polarity) grows w
        mag = jnp.where(
            v >= 0,
            jnp.where(v > self.v_th_pos, i, 0.0),
            jnp.where(v < -self.v_th_neg, i, 0.0),
        )
        return self.k_drift * mag * self._window(w, i)

    @partial(jax.jit, static_argnums=(0,))
    def transient(self, v_t: jax.Array, w0: jax.Array, dt: float):
        """Integrate the device response to a voltage waveform.

        Args:
          v_t: (T,) applied voltage samples.
          w0:  scalar or array initial state.
          dt:  timestep [s].

        Returns:
          (i_t, w_t): current and state trajectories, each (T,) + w0.shape.
        """

        def step(w, v):
            i = self.current(v, w)
            w_new = jnp.clip(w + self.dw_dt(v, w) * dt, 0.0, 1.0)
            return w_new, (i, w_new)

        _, (i_t, w_t) = jax.lax.scan(step, jnp.asarray(w0, jnp.float32), v_t)
        return i_t, w_t

    @partial(jax.jit, static_argnums=(0, 3))
    def program(self, w0: jax.Array, v_pulse: jax.Array, n_steps: int = 64):
        """Apply one write pulse of duration t_write; returns the new state.

        v_pulse > 0 SETs (w -> 1), v_pulse < 0 RESETs (w -> 0).
        """
        dt = PAPER.t_write / n_steps

        def step(w, _):
            w_new = jnp.clip(w + self.dw_dt(v_pulse, w) * dt, 0.0, 1.0)
            return w_new, ()

        w, _ = jax.lax.scan(step, jnp.asarray(w0, jnp.float32), None,
                            length=n_steps)
        return w

    def program_verify(self, w0: jax.Array, g_target: jax.Array,
                       n_pulses: int = 16, n_steps: int = 16):
        """Iterative program-and-verify to hit a target conductance.

        Mirrors multi-level cell tuning: apply short write pulses whose
        polarity is chosen from the sign of the conductance error, reading
        (verifying) between pulses.  Returns the final state.
        """
        dt = PAPER.t_write / (n_pulses * n_steps)

        def pulse(w, _):
            err = g_target - self.conductance(w)
            v = jnp.where(err > 0, PAPER.v_write, -PAPER.v_write)

            def micro(wc, _):
                return jnp.clip(wc + self.dw_dt(v, wc) * dt, 0.0, 1.0), ()

            w_new, _ = jax.lax.scan(micro, w, None, length=n_steps)
            return w_new, self.conductance(w_new)

        w, g_trace = jax.lax.scan(pulse, jnp.asarray(w0, jnp.float32), None,
                                  length=n_pulses)
        return w, g_trace


def hysteresis_loop(model: MemristorModel | None = None,
                    freq_hz: float = 50.0, v_amp: float = 1.2,
                    n_cycles: int = 2, samples_per_cycle: int = 4096,
                    w0: float = 0.05):
    """Drive the device with a sinusoid and return (v, i) — the pinched
    hysteresis loop of paper Fig. 3a.  At 50 Hz the loop must (a) pass
    through the origin and (b) enclose nonzero area (frequency-dependent
    lobes), the two defining signatures of a memristor.

    NOTE on timescale: the physical device switches in ~250 ns; at 50 Hz the
    drive is quasi-static, so we scale the drift constant to the drive
    period (standard practice when reproducing low-frequency loops with a
    fast-switching model — the loop SHAPE, not the absolute speed, is the
    fingerprint being reproduced).
    """
    model = model or MemristorModel()
    period = 1.0 / freq_hz
    t = jnp.linspace(0.0, n_cycles * period, n_cycles * samples_per_cycle)
    v = v_amp * jnp.sin(2 * jnp.pi * freq_hz * t)
    dt = float(t[1] - t[0])
    # rescale drift so ~one full traversal happens per half cycle
    slow = MemristorModel(r_on=model.r_on, r_off=model.r_off,
                          k_drift=model.k_drift * (PAPER.t_write * freq_hz * 4),
                          p_window=model.p_window)
    i, w = slow.transient(v, jnp.float32(w0), dt)
    return v, i, w


def sample_conductances(key: jax.Array, w_bits: jax.Array,
                        p: CrossStackParams = PAPER) -> jax.Array:
    """Sample stochastic conductances for an array of binary weight bits.

    bit == 1 -> G_set = 1/(10 kOhm * (1 + N(0, 7%)))
    bit == 0 -> G_reset = 1/(100 kOhm * (1 + N(0, 10%)))

    Matches the paper's Monte-Carlo methodology (Gaussian, 200 trials).
    """
    k1, k2 = jax.random.split(key)
    r_s = p.r_set * (1.0 + p.r_set_tol * jax.random.normal(k1, w_bits.shape))
    r_r = p.r_reset * (1.0 + p.r_reset_tol * jax.random.normal(k2, w_bits.shape))
    r = jnp.where(w_bits > 0, r_s, r_r)
    return 1.0 / r


def transistor_leakage(v_ds: jax.Array, v_gs: jax.Array,
                       p: CrossStackParams = PAPER) -> jax.Array:
    """Subthreshold leakage of the OFF access transistor (N1 during a
    deep-net write).  Calibrated so the paper's worst-case bias
    (v_gs = 0, v_ds ~ V_write) leaks ~2.5 pA/cell (Fig. 3c).
    """
    vt_therm = 0.02585
    n = p.subthreshold_swing / (vt_therm * jnp.log(10.0))
    i0 = p.i_leak_0 / (10.0 ** ((0.0 - p.v_th) / p.subthreshold_swing)
                       * (1.0 - jnp.exp(-p.v_write / vt_therm)))
    return (i0 * 10.0 ** ((v_gs - p.v_th) / p.subthreshold_swing)
            * (1.0 - jnp.exp(-jnp.maximum(v_ds, 0.0) / vt_therm)))
