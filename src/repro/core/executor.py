"""Weight-resident crossbar execution: program at load, read at inference.

The paper's deep-net operating point keeps weights *resident* in the
TiO2/TiO2-x stack — programming happens once when a network is deployed,
and every subsequent inference is a read-only bit-serial MAC against the
already-programmed conductances.  ``engine.linear`` (program-and-run) is
the right op for QAT and fidelity sweeps, but re-quantizing and re-slicing
every weight matrix on every forward call is exactly what a memristor
engine exists to avoid.

``CrossbarExecutor`` is the deployment-side half:

  * :meth:`program_params` walks a model's params pytree once, classifies
    every eligible linear weight (attention projections, dense MLP mats,
    the LM head), and programs each onto cached :class:`ProgrammedLinear`
    tile grids — layer-stacked leaves are unstacked so each layer owns its
    physical tiles.  Re-walking the same tree is a cache hit, never a
    re-program (``stats`` records both).
  * :func:`crossbar_linear` is the drop-in the model zoo routes through:
    inside an :meth:`activate` region it executes ``x @ W`` on the resident
    tiles via ``engine.matmul``; outside (or for weights the executor does
    not hold) it falls back to the caller's digital formulation.

Weight addressing is by *name*: ``models/transformer.py`` pushes name
scopes (``blocks.3.attn``) around each sub-module so the same pure layer
functions resolve their crossbar tiles under jit, where array identity is
meaningless (params are tracers).  The crossbar backend therefore runs the
unrolled layer loop (``scan_layers=False`` path) — layer indices must be
Python ints to name tiles.
"""
from __future__ import annotations

import contextlib
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.engine import EngineConfig, ProgrammedLinear

# weight-leaf classification: final path key -> contracted input axes,
# in the context of its parent module key
_ATTN_KEYS = {"wq": 1, "wk": 1, "wv": 1, "wo": 2}
_MLP_KEYS = {"wi": 1, "wg": 1, "wo": 1}
# top-level param stacks whose leading axis is the layer index
_STACKED_ROOTS = ("blocks", "enc_blocks")


def _path_parts(path) -> List[str]:
    out = []
    for k in path:
        out.append(str(getattr(k, "key", getattr(k, "idx",
                                                 getattr(k, "name", k)))))
    return out


def _classify(parts: List[str]) -> Optional[int]:
    """Return contracted-input-axis count for an eligible leaf, else None."""
    if parts == ["head"]:
        return 1
    if len(parts) >= 2:
        mod, leaf = parts[-2], parts[-1]
        if mod == "xattn" and leaf in ("wk", "wv"):
            # cross-attention K/V come from the encoder output via the
            # digital _cross_kv path (model.py); programming them would
            # waste tiles that are never read
            return None
        if mod in ("attn", "xattn") and leaf in _ATTN_KEYS:
            return _ATTN_KEYS[leaf]
        if mod == "mlp" and leaf in _MLP_KEYS:
            return _MLP_KEYS[leaf]
    return None


class CrossbarExecutor:
    """Programs a model's linear weights onto crossbar tiles exactly once
    and serves all subsequent ``x @ W`` reads from the resident tiles."""

    def __init__(self, cfg: EngineConfig = EngineConfig(mode="deepnet")):
        self.cfg = cfg
        self._cache: Dict[str, ProgrammedLinear] = {}
        self._n_in: Dict[str, int] = {}
        # the leaf arrays the tiles were programmed from: resident
        # conductances are physical state, so serving a DIFFERENT tree
        # through them must be an error, not silent reuse.  Strong refs —
        # identity comparison stays sound (no id() reuse after GC).
        self._programmed_leaves: Optional[Tuple[Any, ...]] = None
        self.stats = {"programmed": 0, "cache_hits": 0, "program_walks": 0}

    # -- programming (the write path; once per deployment) -----------------

    def program_params(self, params: Any) -> int:
        """Program every eligible linear weight in ``params``; idempotent.

        Returns the number of weights *newly* programmed this walk; weights
        already resident count as ``stats['cache_hits']`` instead.
        """
        leaves = jax.tree_util.tree_flatten_with_path(params)[0]
        if any(isinstance(w, jax.core.Tracer) for _, w in leaves):
            raise TypeError(
                "CrossbarExecutor.program_params needs concrete arrays; "
                "program at load time, before entering jit")
        tree = tuple(w for _, w in leaves)
        if self._programmed_leaves is None:
            self._programmed_leaves = tree
        elif not self._same_tree(tree):
            raise RuntimeError(
                "crossbar tiles are already programmed from a different "
                "params tree; resident weights are physical state — build "
                "a fresh model/executor to deploy new params")
        self.stats["program_walks"] += 1
        new = 0
        for path, w in leaves:
            parts = _path_parts(path)
            n_in = _classify(parts)
            if n_in is None:
                continue
            if parts[0] in _STACKED_ROOTS:
                for layer in range(w.shape[0]):
                    name = ".".join([parts[0], str(layer)] + parts[1:])
                    new += self._program_one(name, w[layer], n_in)
            else:
                new += self._program_one(name := ".".join(parts), w, n_in)
        return new

    def _program_one(self, name: str, w: jax.Array, n_in: int) -> int:
        if name in self._cache:
            self.stats["cache_hits"] += 1
            return 0
        k = math.prod(w.shape[:n_in])
        w2d = jnp.asarray(w, jnp.float32).reshape(k, -1)
        self._cache[name] = engine.program(w2d, self.cfg)
        self._n_in[name] = n_in
        self.stats["programmed"] += 1
        return 1

    def _same_tree(self, leaves: Tuple[Any, ...]) -> bool:
        prog = self._programmed_leaves
        return (prog is not None and len(prog) == len(leaves)
                and all(a is b for a, b in zip(prog, leaves)))

    def ensure_programmed(self, params: Any) -> None:
        """Program on the first eager call; afterwards verify the caller is
        serving the SAME params tree the tiles were programmed from.

        Under jit the leaves are tracers and identity CANNOT be verified —
        a caller who programs tree A eagerly and then jit-calls with tree B
        gets tree A's tiles.  The supported flow (BatchScheduler / the
        model's eager entry points) always passes through an eager call,
        where the check is sound.
        """
        leaves = jax.tree_util.tree_leaves(params)
        if any(isinstance(w, jax.core.Tracer) for w in leaves):
            if not self._cache:
                raise RuntimeError(
                    "crossbar weights are not programmed and params are "
                    "tracers; call model.executor.program_params(params) "
                    "eagerly before jitting the serving step")
            return  # tracers: identity unverifiable here (see docstring)
        if self._same_tree(tuple(leaves)):
            return
        # unseen tree: program it (first call), or raise (different tree /
        # a tree extending a manually-programmed subset) via program_params
        self.program_params(params)

    # -- read path ----------------------------------------------------------

    def has(self, name: str) -> bool:
        return name in self._cache

    def linear(self, x: jax.Array, w: jax.Array, name: str) -> jax.Array:
        """Resident-tile execution of ``x @ W`` for the named weight.

        ``w`` is only consulted for its (static) shape — the arithmetic
        reads the programmed tiles, which is the point.
        """
        pw = self._cache[name]
        n_in = self._n_in[name]
        lead = x.shape[:-n_in]
        k = math.prod(x.shape[-n_in:])
        if k != pw.k:
            raise ValueError(f"{name}: input dim {k} != programmed {pw.k}")
        y = engine.matmul(x.reshape(*lead, k).astype(jnp.float32), pw,
                          self.cfg)
        return y.reshape(*lead, *w.shape[n_in:]).astype(x.dtype)

    # -- bookkeeping ---------------------------------------------------------

    @property
    def n_resident(self) -> int:
        return len(self._cache)

    @property
    def n_devices(self) -> int:
        """Total programmed memristors across all resident tile grids."""
        return sum(pw.n_devices for pw in self._cache.values())

    @contextlib.contextmanager
    def activate(self):
        global _ACTIVE
        prev, _ACTIVE = _ACTIVE, self
        try:
            yield self
        finally:
            _ACTIVE = prev


# -- routing: active executor + name scopes (trace-time Python state) -------

_ACTIVE: Optional[CrossbarExecutor] = None
_SCOPE: List[str] = []


def active() -> Optional[CrossbarExecutor]:
    return _ACTIVE


@contextlib.contextmanager
def scope(name: Any):
    """Push a name-scope segment (layer index, module name) for routing."""
    _SCOPE.append(str(name))
    try:
        yield
    finally:
        _SCOPE.pop()


def scoped(name: str) -> str:
    return ".".join(_SCOPE + [name]) if _SCOPE else name


def crossbar_linear(x: jax.Array, w: jax.Array, name: str,
                    digital=None) -> jax.Array:
    """Drop-in linear: resident-crossbar read when an executor is active
    and holds the scoped weight, else the caller's digital formulation.

    ``digital`` is a thunk so the digital path keeps its exact dtype /
    sharding-constraint behavior (bf16 einsums, TP matmul variants) with
    zero cost on the crossbar path.
    """
    ex = _ACTIVE
    if ex is not None:
        full = scoped(name)
        if ex.has(full):
            return ex.linear(x, w, full)
    if digital is None:
        # no axes-guessing fallback: only the executor knows how many input
        # axes a named weight contracts (attention wo contracts two)
        raise ValueError(
            f"no resident tiles for {scoped(name)!r} and no digital "
            f"fallback provided")
    return digital()
