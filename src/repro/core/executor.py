"""Weight-resident crossbar execution: program at load, read at inference.

The paper's deep-net operating point keeps weights *resident* in the
TiO2/TiO2-x stack — programming happens once when a network is deployed,
and every subsequent inference is a read-only bit-serial MAC against the
already-programmed conductances.  ``engine.linear`` (program-and-run) is
the right op for QAT and fidelity sweeps, but re-quantizing and re-slicing
every weight matrix on every forward call is exactly what a memristor
engine exists to avoid.

``CrossbarExecutor`` is the deployment-side half:

  * :meth:`program_params` walks a model's params pytree once, classifies
    every eligible linear weight (attention projections, dense MLP mats,
    the LM head), and programs each onto cached :class:`ProgrammedLinear`
    tile grids — layer-stacked leaves are unstacked so each layer owns its
    physical tiles.  Re-walking the same tree is a cache hit, never a
    re-program (``stats`` records both).
  * :func:`crossbar_linear` is the drop-in the model zoo routes through:
    inside an :meth:`activate` region it executes ``x @ W`` on the resident
    tiles via ``engine.matmul``; outside (or for weights the executor does
    not hold) it falls back to the caller's digital formulation.

Weight addressing is by *name*: ``models/transformer.py`` pushes name
scopes (``blocks.3.attn``) around each sub-module so the same pure layer
functions resolve their crossbar tiles under jit, where array identity is
meaningless (params are tracers).  The crossbar backend therefore runs the
unrolled layer loop (``scan_layers=False`` path) — layer indices must be
Python ints to name tiles.

Plane-bank residency (PR 5, generalizing PRs 2-3): every resident weight
is a :class:`~repro.core.planes.PlaneBank` — an ordered bank of
``DeviceConfig.stack_planes`` role-tagged plane slots (``free`` /
``staging`` / ``resident(tenant)``).  The executor keeps a single
residency registry over the banks: ``program_params(params, tenant=...)``
deploys any of up to N resident checkpoints (one per plane),
:meth:`residency` reports ``{tenant: fingerprint/version}``, and
``linear(..., tenant=...)`` (or the ambient :meth:`read_tenant` scope a
serving loop jits under) selects the tenant's plane per bank — N models
served from ONE physical stack, the paper's user-reconfigurable stack as
a serving-tier analogue of PUMA's many-workload fabric.

:meth:`begin_swap` targets any tenant with one lifecycle: when a free
plane exists, a **staged** swap reserves it per bank, programs the new
checkpoint in write-latency-costed chunks (:meth:`write_chunks`, meant
to interleave with decode steps), and :meth:`promote` retargets the
tenant's read-enable atomically after verifying per-tile fingerprints —
the tenant serves its old plane through the whole window (zero-downtime
hot-swap, the paper's read-under-write overlap at the serving tier).
When the bank is full, the swap falls back to an **in-place** rewrite of
the tenant's own slot: that tenant's reads pause for the window while
every other resident tenant keeps serving.  With ``stack_planes = 2``
these two configurations are exactly the PR-2 shadow swap and the PR-3
two-tenant multiplex — one code path, not two special cases.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import math
import time
from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import engine, ir_drop, planes, timing
from repro.core.engine import EngineConfig
from repro.core.planes import ChunkedProgram, PlaneBank, SwapPlan

#: per-weight read modes a policy may assign ("auto" resolves to one)
READ_MODES = ("expansion", "deepnet")

#: a mode policy: None (= cfg.mode for every weight), a uniform mode,
#: "auto" (IR-drop-aware per-layer selection), or a mapping from weight
#: name / dotted name fragment to a mode (values may themselves be
#: "auto"; the special key "default" covers unmatched weights)
ModePolicy = Union[None, str, Dict[str, str]]

# weight-leaf classification: final path key -> contracted input axes,
# in the context of its parent module key
_ATTN_KEYS = {"wq": 1, "wk": 1, "wv": 1, "wo": 2}
_MLP_KEYS = {"wi": 1, "wg": 1, "wo": 1}
# top-level param stacks whose leading axis is the layer index
_STACKED_ROOTS = ("blocks", "enc_blocks")


def _path_parts(path) -> List[str]:
    out = []
    for k in path:
        out.append(str(getattr(k, "key", getattr(k, "idx",
                                                 getattr(k, "name", k)))))
    return out


def _classify(parts: List[str]) -> Optional[int]:
    """Return contracted-input-axis count for an eligible leaf, else None."""
    if parts == ["head"]:
        return 1
    if len(parts) >= 2:
        mod, leaf = parts[-2], parts[-1]
        if mod == "xattn" and leaf in ("wk", "wv"):
            # cross-attention K/V come from the encoder output via the
            # digital _cross_kv path (model.py); programming them would
            # waste tiles that are never read
            return None
        if mod in ("attn", "xattn") and leaf in _ATTN_KEYS:
            return _ATTN_KEYS[leaf]
        if mod == "mlp" and leaf in _MLP_KEYS:
            return _MLP_KEYS[leaf]
    return None


class CrossbarExecutor:
    """Programs a model's linear weights onto crossbar tiles exactly once
    and serves all subsequent ``x @ W`` reads from the resident tiles."""

    def __init__(self, cfg: EngineConfig = EngineConfig(mode="deepnet")):
        self.cfg = cfg
        self._cache: Dict[str, PlaneBank] = {}
        self._n_in: Dict[str, int] = {}
        # per tenant, the leaf arrays its planes were programmed from:
        # resident conductances are physical state, so serving a DIFFERENT
        # tree through them must be an error, not silent reuse.  Strong
        # refs — identity comparison stays sound (no id() reuse after GC).
        self._programmed_leaves: Dict[str, Tuple[Any, ...]] = {}
        self._swap: Optional[SwapPlan] = None
        self._versions: Dict[str, int] = {}
        # ambient tenant for linear()/fingerprint()/ensure_programmed()
        # when no explicit tenant is passed — trace-time Python state, set
        # by read_tenant() around a serving closure's trace
        self._read_tenant: str = "A"
        # ambient leak override: a serving closure traces under
        # leak_scope(<traced scalar>) so the write-plane leakage is an
        # ARGUMENT of the compiled step (0.0 outside a swap window, the
        # live value inside it) instead of a trace-time constant
        self._leak_override: Optional[Any] = None
        # cached device scalars for current_leak_codes(): cfg is frozen,
        # so both values are constants — one host->device put each, not
        # one per decode step
        self._leak_zero: Optional[jax.Array] = None
        self._leak_live: Optional[jax.Array] = None
        # per-weight read mode (PR 6): mode-variant EngineConfigs are
        # cached so every weight programmed in the same mode shares one
        # frozen cfg (stable jit cache keys — zero re-traces when reads
        # mix modes), plus the resolved policy + reasons for mode_report
        self._mode_cfgs: Dict[str, EngineConfig] = {cfg.mode: cfg}
        self._mode_reasons: Dict[Tuple[str, str], str] = {}
        self._ir_scores: Dict[Tuple[int, int], Dict[str, Any]] = {}
        self.stats = {"programmed": 0, "cache_hits": 0, "program_walks": 0,
                      "swaps": 0, "swap_chunks": 0}
        # wall-clock start of the in-flight swap window, for the
        # executor_swap span recorded at promote()/abort_swap()
        self._swap_t0: Optional[float] = None

    def _event(self, stat: str, metric: str, help: str, n: int = 1,
               **labels: Any) -> None:
        """Bump a legacy ``stats`` entry and its registry counter."""
        self.stats[stat] += n
        obs.registry().counter(metric, help=help).inc(n, **labels)

    # -- tenant addressing ----------------------------------------------------

    @property
    def stack_planes(self) -> int:
        """Bank height N: planes stacked per cell site (and the bound on
        the resident tenant population)."""
        return self.cfg.stack_planes

    @property
    def tenant_names(self):
        """The addressable tenant population, one name per plane slot."""
        return self.cfg.device.tenant_names

    @property
    def anchor(self) -> str:
        """The registry's anchor tenant (first name, "A"): required by
        the serving tier, never evictable, and never paused by an
        in-place rewrite — its deploys must go through staged swaps."""
        return self.tenant_names[0]

    def _check_tenant(self, tenant: str) -> str:
        if tenant not in self.tenant_names:
            raise ValueError(
                f"unknown tenant {tenant!r}: a {self.stack_planes}-plane "
                f"stack serves at most tenants {self.tenant_names}")
        return tenant

    def _resolve_tenant(self, tenant: Optional[str]) -> str:
        return self._check_tenant(tenant or self._read_tenant)

    @contextlib.contextmanager
    def read_tenant(self, tenant: str):
        """Ambient-tenant scope: reads (and eager programming checks)
        inside the block address ``tenant``'s plane set.  Wrap a serving
        closure's trace in this so the jitted step reads that tenant's
        tiles as its trace constants."""
        self._check_tenant(tenant)
        prev, self._read_tenant = self._read_tenant, tenant
        try:
            yield self
        finally:
            self._read_tenant = prev

    @property
    def tenants(self) -> List[str]:
        """Resident tenants (those with a programmed plane set)."""
        return sorted(self._programmed_leaves)

    def residency(self) -> Dict[str, Dict[str, Any]]:
        """The unified residency registry: for every resident tenant, the
        checkpoint-content fingerprint its planes were programmed from,
        its monotone deploy version, and its per-mode weight counts
        (``modes``: how many banks serve it from an expansion-fused pair
        vs a deep-net slot) — the one structure dashboards, schedulers
        and swap tooling read instead of poking bank slots."""
        out: Dict[str, Dict[str, Any]] = {}
        for t in self.tenants:
            n_exp = sum(1 for b in self._cache.values()
                        if b.has_tenant(t) and b.is_fused(t))
            n_deep = sum(1 for b in self._cache.values()
                         if b.has_tenant(t)) - n_exp
            out[t] = {"fingerprint": self.fingerprint(tenant=t),
                      "version": self.version(t),
                      "modes": {"expansion": n_exp, "deepnet": n_deep}}
        return out

    # -- write-plane leakage (deep-net overlap reads) ------------------------

    @contextlib.contextmanager
    def leak_scope(self, leak_codes):
        """Ambient leak override: reads inside the block carry
        ``leak_codes`` as their common-mode pre-ADC term, whatever the
        swap state.  Trace a serving closure under this with the
        closure's own *traced* scalar argument — the compiled step then
        accepts the live value per call (0.0 steady-state, the write
        plane's leakage during an overlap window) with zero re-traces."""
        prev, self._leak_override = self._leak_override, leak_codes
        try:
            yield self
        finally:
            self._leak_override = prev

    def current_leak_codes(self) -> jax.Array:
        """The leak value a read issued NOW should carry, as a device
        scalar: the write plane's subthreshold leakage while a swap is in
        flight with ``cfg.swap_leakage`` set, else 0.0.  Serving loops
        feed this to closures traced under :meth:`leak_scope` each step
        (both scalars are cached — no per-step transfer)."""
        if self._swap is not None and self.cfg.swap_leakage:
            if self._leak_live is None:
                self._leak_live = planes.write_leak_scalar(self.cfg)
            return self._leak_live
        if self._leak_zero is None:
            self._leak_zero = jnp.float32(0.0)
        return self._leak_zero

    # -- per-weight read-mode policy (PR 6) ----------------------------------

    def _read_cfg(self, mode: str) -> EngineConfig:
        """The engine config a read in ``mode`` uses: ``self.cfg`` when
        the mode matches, else a cached ``dataclasses.replace`` variant.
        Programming is mode-independent (one ``ProgrammedLinear`` serves
        both read paths), so flipping mode is purely a read-time choice
        of ADC grouping (``rows_per_adc``)."""
        cfg = self._mode_cfgs.get(mode)
        if cfg is None:
            cfg = self._mode_cfgs[mode] = dataclasses.replace(
                self.cfg, mode=mode)
        return cfg

    def _row_tiles(self, k: int) -> int:
        return -(-k // self.cfg.tile_rows)

    def _auto_mode(self, name: str, k: int) -> Tuple[str, str]:
        """IR-drop-aware per-layer selection (ROADMAP item 2).

        Expansion mode cuts worst-case IR deviation (paper: 22%) but
        fuses both planes read-only — no write shadow, so no overlapped
        reprogramming.  The policy therefore spends the fused pairs on
        accuracy-critical layers (attention projections and the LM head,
        where logit fidelity is most sensitive) and keeps the swap-heavy
        MLP mats — the bulk of reprogram chunks — in deep-net layout.
        A layer only qualifies when its row-tiles pair up evenly
        (adjacent row-tiles map onto the two planes; an odd count would
        hit the per-plane ADC fallback and forfeit the IR benefit).
        """
        t = self._row_tiles(k)
        parts = name.split(".")
        critical = name == "head" or "attn" in parts or "xattn" in parts
        if not critical:
            return "deepnet", "auto: swap-heavy (mlp) — keep write shadow"
        if t < 2 or t % 2:
            return ("deepnet",
                    f"auto: {t} row-tile(s) cannot pair across planes")
        return "expansion", "auto: accuracy-critical (attention/head)"

    def _validate_policy(self, policy: ModePolicy) -> None:
        """Reject malformed policies BEFORE any residency state mutates
        — a refused ``program_params`` call must leave the executor
        exactly as it found it."""
        if policy is None:
            return
        valid = READ_MODES + ("auto",)
        if isinstance(policy, str):
            if policy not in valid:
                raise ValueError(
                    f"unknown mode policy {policy!r}: want one of "
                    f"{valid} or a name->mode mapping")
            return
        for pat, mode in policy.items():
            if mode not in valid:
                raise ValueError(
                    f"mode policy entry {pat!r} maps to {mode!r}; want "
                    f"one of {valid}")

    def _resolve_mode(self, policy: ModePolicy, name: str,
                      k: int) -> Tuple[str, str]:
        """(mode, reason) for one weight under ``policy``.

        Mapping keys match the full dotted name, any contiguous dotted
        fragment of it (``"attn"``, ``"attn.wq"``, ``"blocks.0"``; the
        most specific — most segments — wins), or ``"default"`` for the
        rest; values may be ``"auto"``.  Unmatched weights without a
        ``"default"`` entry fall back to deep-net, the swap-capable
        layout.
        """
        if policy is None:
            return self.cfg.mode, "engine default (cfg.mode)"
        if isinstance(policy, str):
            if policy == "auto":
                return self._auto_mode(name, k)
            if policy not in READ_MODES:
                raise ValueError(
                    f"unknown mode policy {policy!r}: want one of "
                    f"{READ_MODES + ('auto',)} or a name->mode mapping")
            return policy, f"uniform policy {policy!r}"
        if name in policy:
            mode, why = policy[name], f"policy[{name!r}]"
        else:
            hay = f".{name}."
            best = None
            for pat in policy:
                if pat != "default" and f".{pat}." in hay:
                    if (best is None
                            or pat.count(".") > best.count(".")
                            or (pat.count(".") == best.count(".")
                                and len(pat) > len(best))):
                        best = pat
            if best is not None:
                mode, why = policy[best], f"policy[{best!r}]"
            else:
                mode, why = policy.get("default", "deepnet"), "policy default"
        if mode == "auto":
            return self._auto_mode(name, k)
        if mode not in READ_MODES:
            raise ValueError(
                f"{name}: mode policy maps to {mode!r}; want one of "
                f"{READ_MODES + ('auto',)}")
        return mode, why

    def mode_for(self, name: str, tenant: Optional[str] = None) -> str:
        """The read mode the named weight is programmed in for a tenant
        (ground truth is bank residency, not the requested policy)."""
        return self._cache[name].mode_for(self._resolve_tenant(tenant))

    def _tile_scores(self, k: int, n: int,
                     max_nodes: int = 1024) -> Dict[str, Any]:
        """Worst-case IR-deviation scores at a weight's tile geometry
        (nodal solves, cached per effective tile)."""
        key = (min(k, self.cfg.tile_rows), min(n, self.cfg.tile_cols))
        score = self._ir_scores.get(key)
        if score is None:
            score = self._ir_scores[key] = ir_drop.mode_ir_report(
                key[0], key[1], r_wire=self.cfg.params.r_wire,
                params=self.cfg.params, max_nodes=max_nodes)
        return score

    def mode_report(self, tenant: Optional[str] = None) -> Dict[str, Any]:
        """Per-weight mode choices with their IR-drop economics.

        For every resident weight of the tenant: the programmed mode,
        why the policy chose it, and the worst-case IR deviation of a
        tile at its geometry under each layout (``ir_drop.mode_ir_report``
        — exact nodal solves at the all-SET/full-drive operating point,
        planar 2n-row tile vs the CrossStack fused pair).  The aggregate
        block carries the mean reduction over expansion-programmed
        layers — the paper's headline 22% figure, asserted >= 20% by
        benchmarks/expansion_bench.py on the paper geometry.
        """
        tenant = self._resolve_tenant(tenant)
        layers: Dict[str, Any] = {}
        for name in sorted(self._cache):
            bank = self._cache[name]
            if not bank.has_tenant(tenant):
                continue
            pw = bank.active_for(tenant)
            score = self._tile_scores(pw.k, pw.n)
            layers[name] = {
                "mode": bank.mode_for(tenant),
                "fused": bank.is_fused(tenant),
                "row_tiles": int(pw.pos.shape[1]),
                "k": pw.k, "n": pw.n,
                "reason": self._mode_reasons.get((tenant, name), ""),
                "dev_deepnet": score["dev_deepnet"],
                "dev_expansion": score["dev_expansion"],
                "ir_drop_reduction": score["ir_drop_reduction"],
            }
        exp = [e for e in layers.values() if e["mode"] == "expansion"]
        agg = {
            "tenant": tenant,
            "n_expansion": len(exp),
            "n_deepnet": len(layers) - len(exp),
            "tile_rows": self.cfg.tile_rows,
            "tile_cols": self.cfg.tile_cols,
            "stack_planes": self.stack_planes,
            # mean worst-case IR-drop reduction the fused pairs buy, over
            # the layers actually programmed in expansion layout
            "ir_drop_reduction_expansion": (
                sum(e["ir_drop_reduction"] for e in exp) / len(exp)
                if exp else 0.0),
        }
        return {"layers": layers, "aggregate": agg}

    def device_token_cost(self, tenant: Optional[str] = None,
                          ) -> Dict[str, Dict[str, float]]:
        """Modeled device cost of ONE full-model read (one token) for a
        tenant, split by read mode — the constants the serving tier's
        per-token device-time/energy counters accumulate.

        Per resident weight (Table-I accounting, ``core/timing.py``):

        * read time: one bit-serial MAC, ``read_time(in_bits)`` — row
          tiles of one slice read concurrently in the device, so depth
          does not multiply time (mode only changes ADC grouping, not
          pulse count);
        * energy: ``in_bits * S * T`` (pulse, slice, row-tile) analog
          column reads, each a worst-case ``mac_energy(R, N_pad)``,
          doubled for the differential pos/neg planes.

        Returns ``{mode: {"grids", "read_s", "energy_j"}}`` with only
        the modes the tenant actually has weights programmed in.
        """
        tenant = self._resolve_tenant(tenant)
        q, p = self.cfg.quant, self.cfg.params
        out: Dict[str, Dict[str, float]] = {}
        for name in sorted(self._cache):
            bank = self._cache[name]
            if not bank.has_tenant(tenant):
                continue
            pw = bank.active_for(tenant)
            s, t, r, n_pad = (int(d) for d in pw.pos.shape)
            mode = bank.mode_for(tenant)
            entry = out.setdefault(
                mode, {"grids": 0.0, "read_s": 0.0, "energy_j": 0.0})
            entry["grids"] += 1
            entry["read_s"] += timing.read_time(q.in_bits, p)
            entry["energy_j"] += (q.in_bits * s * t * 2
                                  * timing.mac_energy(r, n_pad, p=p))
        return out

    # -- programming (the write path; once per deployment) -----------------

    @staticmethod
    def _eligible(leaves) -> List[Tuple[str, Any, int]]:
        """(name, weight, n_in) for every eligible linear leaf, with
        layer-stacked roots unstacked so each layer owns its tiles."""
        out = []
        for path, w in leaves:
            parts = _path_parts(path)
            n_in = _classify(parts)
            if n_in is None:
                continue
            if parts[0] in _STACKED_ROOTS:
                for layer in range(w.shape[0]):
                    name = ".".join([parts[0], str(layer)] + parts[1:])
                    out.append((name, w[layer], n_in))
            else:
                out.append((".".join(parts), w, n_in))
        return out

    def program_params(self, params: Any, tenant: Optional[str] = None,
                       mode_policy: ModePolicy = None) -> int:
        """Program every eligible linear weight in ``params`` onto the
        named tenant's plane set; idempotent per tenant.

        A new tenant claims one free plane slot in every bank — TWO in
        banks where ``mode_policy`` programs the weight in expansion
        layout (the fused pair: both planes RE-high, holding the
        row-tile halves of one doubled-input weight).  ``mode_policy``
        is ``None`` (every weight reads in ``cfg.mode``), a uniform
        ``"expansion"``/``"deepnet"``, ``"auto"`` (IR-drop-aware
        per-layer selection; see :meth:`mode_report`), or a name->mode
        mapping.  Re-walking the same tree is a cache hit — but
        requesting a *different* mode for an already-resident weight is
        an error: modes are physical plane layout, not a read flag.
        Returns the number of weights newly programmed this walk.
        """
        tenant = self._resolve_tenant(tenant)
        self._validate_policy(mode_policy)
        if tenant not in self._programmed_leaves:
            self._require_free_plane(tenant)
        leaves = jax.tree_util.tree_flatten_with_path(params)[0]
        if any(isinstance(w, jax.core.Tracer) for _, w in leaves):
            raise TypeError(
                "CrossbarExecutor.program_params needs concrete arrays; "
                "program at load time, before entering jit")
        tree = tuple(w for _, w in leaves)
        if tenant not in self._programmed_leaves:
            self._programmed_leaves[tenant] = tree
        elif not self._same_tree(tree, tenant):
            raise RuntimeError(
                f"tenant {tenant!r} planes are already programmed from a "
                f"different params tree; resident weights are physical "
                f"state — use swap(params, tenant={tenant!r}) / "
                f"begin_swap(params, tenant={tenant!r}) for a "
                f"zero-downtime reprogram")
        self._event("program_walks", "crossstack_program_walks_total",
                    "program_params pytree walks", tenant=tenant)
        new = 0
        for name, w, n_in in self._eligible(leaves):
            if mode_policy is None:
                # no preference: resident weights keep their layout,
                # new ones program in the engine's cfg.mode
                mode, reason = None, "engine default (cfg.mode)"
            else:
                k = math.prod(w.shape[:n_in])
                mode, reason = self._resolve_mode(mode_policy, name, k)
            new += self._program_one(name, w, n_in, tenant, mode, reason)
        if new:
            self._versions[tenant] = self._versions.get(tenant, 0) + 1
        return new

    def _require_free_plane(self, tenant: str) -> None:
        """A first-time tenant needs one free slot per bank.  Resident
        tenants (expansion-fused ones hold TWO slots in their banks), an
        in-flight staged swap's reserved slot, and fused companions all
        occupy planes; admitting a tenant past the bound would either
        overflow the stack or steal the very plane an open swap will
        land on at promote() (making that promotion fail half-applied).
        Bank slot roles are the ground truth once banks exist; before
        any bank does, the tenant count is."""
        staging = self._swap is not None and not self._swap.in_place
        if self._cache:
            if min(b.n_free for b in self._cache.values()) > 0:
                return
        else:
            occupied = len(self._programmed_leaves) + (1 if staging else 0)
            if occupied < self.stack_planes:
                return
        if staging:
            raise RuntimeError(
                f"cannot deploy new tenant {tenant!r} while a hot-swap is "
                f"in flight (the staging plane is the swap's write "
                f"target); promote() or abort_swap() first")
        raise RuntimeError(
            f"stack is full: {self.stack_planes} planes hold resident "
            f"tenants {self.tenants}; evict_tenant() before deploying "
            f"{tenant!r}")

    def _program_one(self, name: str, w: jax.Array, n_in: int,
                     tenant: str, mode: Optional[str], reason: str) -> int:
        bank = self._cache.get(name)
        if bank is not None and bank.has_tenant(tenant):
            have = bank.mode_for(tenant)
            if mode is not None and have != mode:
                raise RuntimeError(
                    f"{name}: tenant {tenant!r} is already resident in "
                    f"{have} layout but the policy asks for {mode}; mode "
                    f"is physical plane layout — evict_tenant() and "
                    f"re-program to change it")
            self._event("cache_hits", "crossstack_program_cache_hits_total",
                        "re-walks that found the weight already resident",
                        tenant=tenant)
            return 0
        k = math.prod(w.shape[:n_in])
        w2d = jnp.asarray(w, jnp.float32).reshape(k, -1)
        if bank is None:
            bank = self._cache[name] = PlaneBank(
                name, n_planes=self.stack_planes)
            self._n_in[name] = n_in
        else:
            ref = bank.any_plane
            if (w2d.shape[0], w2d.shape[1]) != (ref.k, ref.n):
                raise ValueError(
                    f"{name}: tenant {tenant!r} weight shape "
                    f"{w2d.shape} != the bank's tile geometry "
                    f"{(ref.k, ref.n)}; tenants share physical stacks")
        # programming is mode-independent: the same ProgrammedLinear
        # serves both read paths; mode decides slot layout (fused pair
        # vs single plane) and the read-time ADC grouping
        if mode is None:
            mode = self.cfg.mode
        pw = engine.program(w2d, self.cfg)
        fp = planes.fingerprint_weight(w2d)
        if mode == "expansion":
            bank.assign_fused(tenant, pw, fp)
        else:
            bank.assign(tenant, pw, fp)
        self._mode_reasons[(tenant, name)] = reason
        self._event("programmed", "crossstack_programmed_weights_total",
                    "weights programmed onto resident planes",
                    tenant=tenant, mode=mode)
        return 1

    def _same_tree(self, leaves: Tuple[Any, ...], tenant: str) -> bool:
        prog = self._programmed_leaves.get(tenant)
        return (prog is not None and len(prog) == len(leaves)
                and all(a is b for a, b in zip(prog, leaves)))

    def ensure_programmed(self, params: Any,
                          tenant: Optional[str] = None,
                          mode_policy: ModePolicy = None) -> None:
        """Program on the first eager call; afterwards verify the caller is
        serving the SAME params tree the tenant's tiles were programmed
        from.

        Under jit the leaves are tracers and identity CANNOT be verified —
        a caller who programs tree A eagerly and then jit-calls with tree B
        gets tree A's tiles.  The supported flow (BatchScheduler / the
        model's eager entry points) always passes through an eager call,
        where the check is sound.  The tenant defaults to the ambient
        :meth:`read_tenant` scope, so a lane closure jitted under
        ``read_tenant("B")`` checks (and first-programs) tenant B.
        """
        tenant = self._resolve_tenant(tenant)
        leaves = jax.tree_util.tree_leaves(params)
        if any(isinstance(w, jax.core.Tracer) for w in leaves):
            if tenant not in self._programmed_leaves:
                raise RuntimeError(
                    f"tenant {tenant!r} crossbar weights are not "
                    f"programmed and params are tracers; call "
                    f"model.executor.program_params(params, "
                    f"tenant={tenant!r}) eagerly before jitting the "
                    f"serving step")
            return  # tracers: identity unverifiable here (see docstring)
        if self._same_tree(tuple(leaves), tenant):
            return
        # unseen tree: program it (first call), or raise (different tree /
        # a tree extending a manually-programmed subset) via program_params
        self.program_params(params, tenant, mode_policy=mode_policy)

    # -- read path ----------------------------------------------------------

    def has(self, name: str) -> bool:
        return name in self._cache

    def linear(self, x: jax.Array, w: jax.Array, name: str,
               tenant: Optional[str] = None) -> jax.Array:
        """Resident-tile execution of ``x @ W`` for the named weight.

        ``w`` is only consulted for its (static) shape — the arithmetic
        reads the named tenant's plane of the bank (default: the ambient
        :meth:`read_tenant` scope, i.e. tenant "A" unless a serving lane
        set otherwise).  While a hot-swap is in flight and
        ``cfg.swap_leakage`` is set, reads carry the write plane's
        subthreshold leakage; a closure traced under :meth:`leak_scope`
        instead takes the leak as its own traced argument, so a compiled
        serving step applies the LIVE value per call (and the Pallas
        kernel fuses it pre-ADC — overlap reads stay on the kernel
        path).  Reads of a tenant whose own planes are mid-write (an
        in-place tenant swap) are refused — those wordlines are driving
        write pulses, not read pulses.

        Per-weight mode dispatch (PR 6): the read path follows the
        bank's *residency layout* — an expansion-fused pair reads with
        doubled-input ADC grouping through a cached mode-variant cfg, a
        deep-net slot reads as before.  Mode is trace-time Python state
        fixed at program time, so mixed-mode models compile each
        weight's read exactly once; and a fused pair never hosts an
        in-flight write, so its reads carry NO leak term — the leak
        operand keeps flowing to the deep-net weights only, preserving
        the zero-re-trace property at swap-window boundaries.
        """
        tenant = self._resolve_tenant(tenant)
        if (self._swap is not None and self._swap.in_place
                and self._swap.tenant == tenant):
            raise RuntimeError(
                f"tenant {tenant!r} planes are mid-write (in-place swap "
                f"in flight); reads resume after promote()")
        bank = self._cache[name]
        pw = bank.active_for(tenant)
        mode = bank.mode_for(tenant)
        cfg = self._read_cfg(mode)
        n_in = self._n_in[name]
        lead = x.shape[:-n_in]
        k = math.prod(x.shape[-n_in:])
        if k != pw.k:
            raise ValueError(f"{name}: input dim {k} != programmed {pw.k}")
        if mode == "expansion" and bank.is_fused(tenant):
            # both planes RE-high: the fused pair's shared column never
            # sees a write shadow, so no leakage term — a trace-time
            # constant, not a traced operand (mode is fixed per weight)
            leak = 0.0
        elif self._leak_override is not None:
            leak = self._leak_override
        else:
            leak = (planes.write_leak_codes(cfg)
                    if self._swap is not None and cfg.swap_leakage
                    else 0.0)
        y = engine.matmul(x.reshape(*lead, k).astype(jnp.float32), pw,
                          cfg, leak_codes=leak)
        return y.reshape(*lead, *w.shape[n_in:]).astype(x.dtype)

    # -- fingerprints / versioning -------------------------------------------

    def fingerprint(self, name: Optional[str] = None,
                    tenant: Optional[str] = None) -> str:
        """Digest of the source weights the named tenant's plane(s) were
        programmed (and write-verified) from — checkpoint-content
        addressing, not a raw cell-code hash (``planes.fingerprint_tiles``
        is the tile-state digest write-verify uses).

        With ``name``: the per-tile fingerprint of that weight's plane.
        Without: a combined digest over all resident tiles (sorted by
        name) — two executors serving identical weights agree, and any
        mixed-plane state mid-promotion would produce a digest matching
        neither checkpoint (asserted by the overlap property test).
        Tenant defaults to the ambient :meth:`read_tenant` scope.
        """
        tenant = self._resolve_tenant(tenant)
        if name is not None:
            return self._cache[name].fingerprint_for(tenant)
        h = hashlib.blake2b(digest_size=8)
        for n in sorted(self._cache):
            h.update(n.encode())
            h.update(self._cache[n].fingerprint_for(tenant).encode())
        return h.hexdigest()

    def fingerprints(self, tenant: Optional[str] = None) -> Dict[str, str]:
        """Per-tile fingerprints of the named tenant's plane set."""
        tenant = self._resolve_tenant(tenant)
        return {n: p.fingerprint_for(tenant)
                for n, p in sorted(self._cache.items())}

    def version(self, tenant: str = "A") -> int:
        """Per-tenant monotone deploy counter: 0 = unprogrammed; +1 per
        initial program walk that wrote tiles; +1 per promoted swap."""
        return self._versions.get(self._check_tenant(tenant), 0)

    @property
    def programmed_version(self) -> int:
        """Tenant A's deploy counter (the pre-multiplex quantity, kept so
        existing dashboards stay comparable); see :meth:`version`."""
        return self.version("A")

    # -- deep-net hot-swap (write the shadow planes, then flip) --------------

    @property
    def swap_in_flight(self) -> bool:
        return self._swap is not None

    def begin_swap(self, params: Any, tenant: str = "A") -> SwapPlan:
        """Stage ``params`` for chunked programming of a plane set.

        One lifecycle for every tenant.  When the banks have a free
        plane, the swap is **staged**: a staging slot is reserved per
        bank, the new checkpoint programs into it chunk by chunk, and
        promotion retargets the tenant's read-enable atomically — the
        tenant (resident or a first-time live deploy) never stops
        serving.  When the banks are full, a resident non-anchor tenant
        falls back to an **in-place** rewrite of its own slot: its reads
        pause until :meth:`promote` while every other tenant keeps
        serving (the paper's read-under-write overlap re-purposed for
        multi-tenancy).  The anchor tenant's reads never pause, so its
        swaps require a free plane.

        The incoming tree must carry exactly the resident tile set with
        matching shapes (a new checkpoint, fine-tuned delta, or
        recalibrated conductances — not a different architecture).
        Returns the chunk work-list; drive it with :meth:`write_chunks`
        and finish with :meth:`promote`.

        Expansion-fused weights refuse overlap writes: a fused pair
        holds both of its planes RE-high for the tenant's reads, so
        there is no write shadow to stage into — the paper's IR-drop
        win trades away read-under-write.  A tenant with ANY fused
        weight therefore always swaps **in place** (its reads pause for
        the window; deep-net tenants sharing the stack keep serving),
        and the anchor tenant — whose reads may never pause — cannot
        swap at all while fused.
        """
        self._check_tenant(tenant)
        if not self._cache:
            raise RuntimeError("nothing programmed; call program_params "
                               "before begin_swap")
        if self._swap is not None:
            raise RuntimeError("a hot-swap is already in flight; promote() "
                               "or abort_swap() first")
        resident = tenant in self._programmed_leaves
        fused = resident and any(
            bank.is_fused(tenant) for bank in self._cache.values()
            if bank.has_tenant(tenant))
        if fused and tenant == self.anchor:
            raise RuntimeError(
                f"tenant {tenant!r} holds expansion-fused planes (both "
                f"RE high — no write shadow) and anchors the stack, so "
                f"its reads cannot pause for an in-place rewrite; "
                f"expansion-mode anchor deploys are cold deploys "
                f"(evict/reprogram), or program the anchor in deep-net "
                f"layout to hot-swap it")
        n_free = min(bank.n_free for bank in self._cache.values())
        if fused:
            # overlap refused: rewrite the fused tenant's own pair with
            # reads paused, whatever free planes exist
            n_free = 0
        if n_free == 0 and not fused:
            others = sorted(t for t in self._programmed_leaves
                            if t != tenant)
            if not resident:
                raise RuntimeError(
                    f"cannot live-deploy tenant {tenant!r}: stack is full "
                    f"({self.stack_planes} planes hold tenant(s) "
                    f"{others}); evict_tenant() first")
            if tenant == self.anchor:
                raise RuntimeError(
                    f"tenant {tenant!r} has no free write plane: the "
                    f"{self.stack_planes}-plane stack also holds "
                    f"tenant(s) {others}, and the anchor tenant cannot "
                    f"pause for an in-place rewrite; swap or evict one "
                    f"of {others} first")
        in_place = resident and n_free == 0
        leaves = jax.tree_util.tree_flatten_with_path(params)[0]
        if any(isinstance(w, jax.core.Tracer) for _, w in leaves):
            raise TypeError("begin_swap needs concrete arrays (eager, "
                            "outside jit)")
        programs = []
        for name, w, n_in in self._eligible(leaves):
            if name not in self._cache:
                raise ValueError(
                    f"swap tree carries {name!r} which has no resident "
                    f"tiles; hot-swap reprograms existing planes only")
            pw = self._cache[name].any_plane
            k = math.prod(w.shape[:n_in])
            w2d = jnp.asarray(w, jnp.float32).reshape(k, -1)
            if (k, w2d.shape[1]) != (pw.k, pw.n):
                raise ValueError(
                    f"{name}: swap shape {(k, w2d.shape[1])} != resident "
                    f"{(pw.k, pw.n)}")
            programs.append(ChunkedProgram(name, w2d, self.cfg))
        missing = set(self._cache) - {cp.name for cp in programs}
        if missing:
            raise ValueError(
                f"swap tree is missing resident tiles: {sorted(missing)}")
        if not in_place:
            # reserve the write target up front (all validation passed):
            # the staging role keeps a concurrent new-tenant deploy from
            # claiming the very plane this swap lands on at promote()
            for bank in self._cache.values():
                bank.reserve_staging()
        self._swap = SwapPlan(programs, tuple(w for _, w in leaves), params,
                              tenant=tenant, in_place=in_place)
        self._swap_t0 = time.perf_counter()
        return self._swap

    def write_chunks(self, n: int = 1) -> int:
        """Program up to ``n`` write-latency-costed chunks of the staged
        swap (each is one t_write pulse in the device-time model); returns
        the number of chunks still unwritten."""
        if self._swap is None:
            raise RuntimeError("no hot-swap in flight")
        for _ in range(n):
            if self._swap.done:
                break
            finished = self._swap.write_chunk()
            self._event("swap_chunks", "crossstack_swap_chunks_total",
                        "write-latency-costed chunks programmed into "
                        "swap targets", tenant=self._swap.tenant)
            if finished is not None:
                staged = finished.finish()
                # write-verify against an independent one-shot programming
                # (paced here, inside the overlap window — not at the flip)
                finished.verify(staged)
                self._swap.staged[finished.name] = (staged, finished.fp)
        return self._swap.remaining

    def promote(self) -> Any:
        """Atomically land the freshly written plane set.

        Every staged plane was already write-verified against an
        independent one-shot programming when its last chunk landed
        (``ChunkedProgram.verify``); this gate checks completeness and
        ownership — every tile must have been staged by THIS plan, not a
        stale or foreign one — before any bank changes, so a read can
        never observe a mixed-plane state.  A staged plan lands every
        bank's staging slot and retargets the tenant's read-enable (its
        previous slot reverts to free); an in-place plan rewrites the
        tenant's own slot (and un-pauses its reads).  Returns the
        promoted params tree (the caller serves embeddings/norms from
        it).
        """
        plan = self._swap
        if plan is None:
            raise RuntimeError("no hot-swap in flight")
        if not plan.done:
            raise RuntimeError(
                f"swap not complete: {plan.remaining} chunks unwritten")
        for name, fp in plan.expected_fingerprints.items():
            got = plan.staged.get(name)
            if got is None or got[1] != fp:
                raise RuntimeError(
                    f"{name}: staged plane fingerprint "
                    f"{got[1] if got else None} != checkpoint {fp}; "
                    f"refusing to promote")
        for cp in plan.programs:
            bank = self._cache[cp.name]
            pw, fp = plan.staged[cp.name]
            if plan.in_place:
                bank.assign(plan.tenant, pw, fp)
            else:
                bank.land_staged(plan.tenant, pw, fp)
        self._programmed_leaves[plan.tenant] = plan.leaves
        self._versions[plan.tenant] = self._versions.get(plan.tenant, 0) + 1
        lifecycle = "in_place" if plan.in_place else "staged"
        self._event("swaps", "crossstack_swaps_total",
                    "promoted plane-set swaps, by lifecycle",
                    tenant=plan.tenant, lifecycle=lifecycle)
        if self._swap_t0 is not None:
            obs.tracer().record(
                "executor_swap", self._swap_t0, time.perf_counter(),
                tenant=plan.tenant, lifecycle=lifecycle,
                chunks=plan.total_chunks,
                device_write_s=plan.device_write_time())
        self._swap = None
        self._swap_t0 = None
        return plan.params

    def abort_swap(self) -> None:
        """Drop an in-flight swap; every tenant's resident planes keep
        serving (written-and-verified planes are buffered in the plan and
        never touch a bank before promote, so abort is pure discard —
        a staged plan's reserved slots simply revert to free)."""
        if self._swap is not None:
            obs.registry().counter(
                "crossstack_swap_aborts_total",
                help="in-flight swaps discarded before promote").inc(
                    tenant=self._swap.tenant)
            if not self._swap.in_place:
                for bank in self._cache.values():
                    bank.release_staging()
        self._swap = None
        self._swap_t0 = None

    def swap(self, params: Any, chunk_burst: int = 64,
             tenant: str = "A") -> Dict[str, Any]:
        """Blocking convenience swap: stage, write every chunk, promote.

        The overlapped serving path (serve/hotswap.py) interleaves
        ``write_chunks`` with decode steps instead; this is the
        stop-the-world comparison point and the API for offline reloads.
        """
        plan = self.begin_swap(params, tenant=tenant)
        while not plan.done:
            self.write_chunks(chunk_burst)
        self.promote()
        return {"n_tiles": len(plan.programs),
                "n_chunks": plan.total_chunks,
                "tenant": tenant,
                "swap_mode": "in_place" if plan.in_place else "staged",
                "device_write_s": plan.device_write_time(),
                "programmed_version": self.version(tenant)}

    def evict_tenant(self, tenant: str) -> None:
        """Evict a resident tenant; its slot in every bank reverts to
        free (the anchor tenant cannot be evicted — reprogram it via
        swap instead).

        Refused outright while ANY :class:`SwapPlan` is in flight: every
        plan targets the same weight set the banks hold, and changing
        the residency registry mid-swap is exactly the race the old
        ``clear_twin`` API allowed (it silently discarded an in-flight
        staged shadow).  ``promote()`` or ``abort_swap()`` first.
        """
        self._check_tenant(tenant)
        if tenant == self.anchor:
            raise ValueError(
                f"tenant {tenant!r} anchors the plane banks; "
                f"swap(params) to replace its weights")
        if self._swap is not None:
            raise RuntimeError(
                f"cannot evict tenant {tenant!r}: a swap plan is in "
                f"flight over this stack's weights; promote() or "
                f"abort_swap() first")
        if tenant not in self._programmed_leaves:
            return
        for bank in self._cache.values():
            if bank.has_tenant(tenant):
                bank.evict(tenant)
        del self._programmed_leaves[tenant]

    # -- bookkeeping ---------------------------------------------------------

    @property
    def n_resident(self) -> int:
        return len(self._cache)

    @property
    def n_devices(self) -> int:
        """Programmed memristors serving reads (read-active planes) —
        the same quantity reported before plane banking, so bench
        trajectories stay comparable."""
        return sum(bank.n_devices for bank in self._cache.values())

    @property
    def n_devices_physical(self) -> int:
        """Total memristors in the stacks, all plane slots included."""
        return sum(bank.n_devices_physical
                   for bank in self._cache.values())

    @contextlib.contextmanager
    def activate(self):
        global _ACTIVE
        prev, _ACTIVE = _ACTIVE, self
        try:
            yield self
        finally:
            _ACTIVE = prev


# -- routing: active executor + name scopes (trace-time Python state) -------

_ACTIVE: Optional[CrossbarExecutor] = None
_SCOPE: List[str] = []


def active() -> Optional[CrossbarExecutor]:
    return _ACTIVE


@contextlib.contextmanager
def scope(name: Any):
    """Push a name-scope segment (layer index, module name) for routing."""
    _SCOPE.append(str(name))
    try:
        yield
    finally:
        _SCOPE.pop()


def scoped(name: str) -> str:
    return ".".join(_SCOPE + [name]) if _SCOPE else name


def crossbar_linear(x: jax.Array, w: jax.Array, name: str,
                    digital=None) -> jax.Array:
    """Drop-in linear: resident-crossbar read when an executor is active
    and holds the scoped weight, else the caller's digital formulation.

    ``digital`` is a thunk so the digital path keeps its exact dtype /
    sharding-constraint behavior (bf16 einsums, TP matmul variants) with
    zero cost on the crossbar path.
    """
    ex = _ACTIVE
    if ex is not None:
        full = scoped(name)
        if ex.has(full):
            return ex.linear(x, w, full)
    if digital is None:
        # no axes-guessing fallback: only the executor knows how many input
        # axes a named weight contracts (attention wo contracts two)
        raise ValueError(
            f"no resident tiles for {scoped(name)!r} and no digital "
            f"fallback provided")
    return digital()
