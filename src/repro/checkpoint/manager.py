"""Fault-tolerant checkpointing: async, atomic, keep-K, elastic re-shard.

Layout (one directory per step):
    <dir>/step_000123.tmp/...      while writing
    <dir>/step_000123/manifest.json + leaf_*.npy   after atomic rename

Fault-tolerance properties:
  * atomic visibility — a checkpoint directory either exists completely
    (rename is atomic on POSIX) or not at all; a killed writer leaves only
    a .tmp that restore() ignores and the next save() garbage-collects;
  * async — save() snapshots to host RAM synchronously (cheap) and writes
    in a daemon thread, so the train loop is stalled only for the snapshot;
  * keep-K — bounded disk usage under periodic saving;
  * elastic re-shard — leaves are stored as *logical* (unsharded) arrays
    keyed by tree path, so restore() can place them onto any mesh/sharding
    (different pod count, different TP degree) via device_put with the
    target sharding.  On a multi-host fleet each host would write its
    owned shard index instead (same manifest format; noted in DESIGN.md);
  * preemption — PreemptionHandler turns SIGTERM into a final save point
    (see launch/ft.py).

No orbax dependency — this container is intentionally self-sufficient.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{9})$")


def _paths(tree) -> list:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return flat


def _key_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- write ---------------------------------------------------------------
    def save(self, step: int, state: Any, blocking: bool = False) -> None:
        """Snapshot state to host memory now; write to disk asynchronously."""
        host = [(_key_str(p), np.asarray(leaf))
                for p, leaf in _paths(state)]
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, host), daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host) -> None:
        name = f"step_{step:09d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": []}
        for i, (key, arr) in enumerate(host):
            fn = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"].append(
                {"key": key, "file": fn, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)
        for d in os.listdir(self.dir):
            if d.endswith(".tmp"):
                # stale partial write from a killed process
                full = os.path.join(self.dir, d)
                if os.path.isdir(full):
                    shutil.rmtree(full, ignore_errors=True)

    # -- read ----------------------------------------------------------------
    def all_steps(self) -> list:
        out = []
        for d in os.listdir(self.dir):
            m = _STEP_RE.match(d)
            if m and os.path.exists(os.path.join(self.dir, d,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``target``.

        shardings: optional matching pytree of Shardings — the elastic
        path: leaves are placed directly onto the (possibly different)
        target mesh.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        root = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(root, "manifest.json")) as f:
            manifest = json.load(f)
        by_key = {l["key"]: l for l in manifest["leaves"]}

        flat_t = _paths(target)
        flat_s = (_paths(shardings) if shardings is not None
                  else [(p, None) for p, _ in flat_t])
        out = []
        for (path, leaf), (_, shard) in zip(flat_t, flat_s):
            key = _key_str(path)
            if key not in by_key:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = np.load(os.path.join(root, by_key[key]["file"]))
            if shard is not None:
                out.append(jax.device_put(arr, shard))
            else:
                out.append(jax.numpy.asarray(arr))
        treedef = jax.tree_util.tree_structure(target)
        return jax.tree_util.tree_unflatten(treedef, out)
