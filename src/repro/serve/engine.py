"""Batched serving runtime: prefill/decode step builders + a paged
continuous-batching scheduler.

serve_step contract (what the dry-run lowers for decode cells): one new
token for every sequence in the batch against a seq_len-deep KV cache,
cache donated, greedy or temperature sampling on-device.

The scheduler serves every tenant through exactly ONE compiled closure:
a fixed (slots, chunk)-window step with per-row valid counts.  Newly
admitted prompts join the running batch as prefill *chunks* — rows mid
prompt consume ``chunk`` tokens per step, decoding rows consume one —
so admission never stalls an in-flight decode step and there is no
per-prompt-length jit cache to explode (the old padded-bucket prefill
machinery is gone).  KV storage is a block-paged pool per lane
(serve/kv_pool.py): fixed-size pages, per-slot page tables, free-list
allocation at admission and reclaim at completion.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models.model import Model
from repro.serve.hotswap import HotSwapper, overlap_report
from repro.serve.kv_pool import PagedKVPool, default_pool_pages


def make_prefill_step(model: Model):
    def prefill_step(params, batch, cache):
        logits, cache = model.prefill(params, batch, cache)
        next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(
            jnp.int32)
        return next_tok, cache

    return prefill_step


def make_decode_step(model: Model, temperature: float = 0.0):
    def decode_step(params, tokens, cache, key=None):
        logits, cache = model.decode_step(params, tokens, cache)
        lg = logits[:, -1].astype(jnp.float32)
        if temperature > 0.0 and key is not None:
            nxt = jax.random.categorical(key, lg / temperature, axis=-1)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        return nxt[:, None].astype(jnp.int32), cache

    return decode_step


def greedy_generate(model: Model, params, batch, max_new: int,
                    max_len: Optional[int] = None):
    """Jit-friendly generation loop used by examples/serve_batch.py."""
    b = batch["tokens"].shape[0]
    s = batch["tokens"].shape[1]
    max_len = max_len or (s + max_new)
    if model.cfg.family == "encdec":
        cache = model.init_cache(b, max_len, src_len=s)
    else:
        cache = model.init_cache(b, max_len)
    prefill = make_prefill_step(model)
    decode = make_decode_step(model)
    tok, cache = prefill(params, batch, cache)
    toks = [tok]

    def body(carry, _):
        tok, cache = carry
        tok, cache = decode(params, tok, cache)
        return (tok, cache), tok

    (_, _), rest = jax.lax.scan(body, (tok, cache), None,
                                length=max_new - 1)
    return jnp.concatenate([tok[:, None], rest.swapaxes(0, 1)],
                           axis=1)[:, :, 0]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jax.Array          # (S,) int32
    max_new: int
    model_id: str = "A"        # tenant whose checkpoint serves this request
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # preemption priority: under pool saturation the scheduler may evict
    # a resident request of strictly lower qos to admit a waiting one
    qos: float = 1.0
    # chunked-prefill progress: prompt tokens already fed to the window
    # closure (scheduler-owned; the first token emits once fed == len)
    fed: int = 0
    # the admission feed (scheduler-owned): prompt tokens, plus — after
    # a preemption — the tokens already emitted, so re-admission replays
    # the whole recomputable state through chunked prefill.  This object
    # IS the host-side spill stub: prompt + out fully determine the
    # greedy continuation, no device state needs saving.
    feed: Optional[np.ndarray] = None
    # times this request was evicted (pages reclaimed) and re-queued
    preemptions: int = 0
    # token positions whose K/V arrived via aliased prefix pages instead
    # of prefill compute (cumulative across re-admissions)
    shared_tokens: int = 0
    # pages the pool allocated at admission (None on the dense path)
    bucket: Optional[int] = None
    # lifecycle timestamps (scheduler tracer clock), filled in by the
    # scheduler when telemetry is on; the span set recorded at completion
    # telescopes exactly: queue_wait [t_submit, t_admit] + prefill
    # [t_admit, t_first] + decode [t_first, t_done] = request wall time
    t_submit: Optional[float] = None
    t_admit: Optional[float] = None
    t_first: Optional[float] = None
    t_done: Optional[float] = None


@dataclasses.dataclass
class _Lane:
    """One tenant's serving state: a fixed slot batch against one plane
    set, with its own jitted window closure (the tiles it traced are that
    tenant's planes — trace constants, like params sharding)."""
    tenant: str
    params: Any
    slots: List[Optional[Request]]
    cache: Any
    queue: List[Request]
    decode: Callable
    # paged-KV page allocator (None on the dense fallback path)
    pool: Optional[PagedKVPool] = None
    # compiled batch width (fixed at construction: the closure's shape)
    width: int = 0
    # QoS: this lane's *effective* slot quota (admission cap <= width,
    # re-split by set_weights) and its admission weight
    n_slots: int = 0
    weight: float = 1.0
    # served-token accounting (admission + decode tokens), the quantity
    # QoS weights shift; see BatchScheduler.qos_report
    tokens_served: int = 0
    # True while this tenant's own planes are mid-write (in-place swap):
    # its reads pause — admissions hold, in-flight slots freeze — and
    # resume on the promoted weights at the swap boundary
    paused: bool = False
    # modeled per-token device read cost by mode, cached from
    # CrossbarExecutor.device_token_cost at lane build/promotion — the
    # constants the per-token device-time/energy counters accumulate
    device_cost: Optional[Dict[str, Dict[str, float]]] = None


def _split_slots(n_slots: int, weights: Dict[str, float]) -> Dict[str, int]:
    """QoS-weighted budget split across tenant lanes (slots OR pages).

    The budget is ``n_slots`` per tenant (so equal weights reproduce
    the historical even split exactly); quotas are proportional to weight
    with largest-remainder rounding, and a starvation guard pins every
    tenant at >= 1 unit — a resident tenant with queued work always
    decodes, however small its weight.
    """
    total = n_slots * len(weights)
    wsum = float(sum(weights.values()))
    raw = {t: total * float(w) / wsum for t, w in weights.items()}
    alloc = {t: max(1, int(raw[t])) for t in weights}
    leftover = total - sum(alloc.values())
    # hand leftover slots to the largest fractional remainders (name
    # breaks ties, so the split is deterministic)
    order = sorted(weights, key=lambda t: (-(raw[t] - int(raw[t])), t))
    i = 0
    while leftover > 0:
        alloc[order[i % len(order)]] += 1
        leftover -= 1
        i += 1
    while leftover < 0:
        # the >=1 guard oversubscribed the budget: reclaim from the
        # largest allocation that can spare a slot
        t = max(sorted(alloc), key=lambda k: alloc[k])
        if alloc[t] <= 1:
            break   # everyone is at the guard floor; keep the floor
        alloc[t] -= 1
        leftover += 1
    return alloc


class BatchScheduler:
    """Paged continuous-batching scheduler (ragged, multi-tenant).

    Each tenant lane runs ONE jitted window closure of fixed shape
    ``(width, chunk)``: per step, every occupied slot contributes either
    its next ``chunk`` prompt tokens (admission prefill, emitting its
    first token on the final chunk) or one generated token (decode) —
    the per-row valid count ``m`` pins each row's cache fill marker, and
    pad positions are causally masked so the streams are bit-exact with
    an unpadded per-request reference.  Because the compiled shape never
    depends on prompt length, a mixed-length stream costs exactly one
    trace per tenant (the ``serve_jit_retraces_total`` counter pins this
    at runtime) and admissions never stall an in-flight decode step —
    admission is pure host bookkeeping (slot + page-table assignment).

    KV storage defaults to a block-paged pool per lane (``kv="paged"``):
    pages of ``page_size`` tokens, per-slot page tables, refcounted
    free-list allocation at admission and reclaim at completion
    (serve/kv_pool.py).  ``kv="dense"`` keeps the per-slot dense cache —
    same closure, same streams (the bit-exactness oracle the paged
    bench gates against).  Two opt-in paged policies:

    * ``prefix_share=True`` — requests whose feed shares a head with an
      already-prefilled row alias its prefix pages (per-page refcounts,
      copy-on-write when a shared page would take the new row's own
      tokens) and skip the shared prefill positions entirely, so N
      common-head requests peak well below N private copies while
      staying bit-exact with the dense oracle.
    * ``preemption=True`` — under pool/budget saturation a waiting
      request of strictly higher ``Request.qos`` evicts the
      lowest-QoS resident (pages reclaimed, recomputable state spilled
      host-side) instead of FIFO-waiting; the victim re-admits later
      through the ordinary chunked-prefill path and continues its
      stream bit-exactly, with zero drops and zero retraces.

    Passing ``tenants={"A": params_a, "B": params_b, ...}`` multiplexes
    up to ``stack_planes`` checkpoints from the plane bank of ONE
    crossbar executor: each tenant gets its own slot partition, cache,
    pool, and jitted closure (traced under ``executor.read_tenant(t)``
    so the closure's trace constants are that tenant's planes), and
    every ``step`` interleaves all token streams.  Requests route by
    ``Request.model_id``.

    A tenant value may also be a ``(params, weight)`` pair: QoS weights
    drive the slot AND page budgets (``_split_slots``: proportional
    quota with a >=1 starvation guard) and the admission order across
    lanes (heavier lanes admit first each step).  Bare params mean
    weight 1.0, which reproduces the historical even split exactly.
    ``set_weights`` re-splits both budgets live at a step boundary.
    """

    def __init__(self, model: Model, params, n_slots: int, max_len: int,
                 tenants: Optional[Dict[str, Any]] = None,
                 mode_policy=None, telemetry: bool = True,
                 kv: str = "paged", page_size: int = 8,
                 kv_pages: Optional[int] = None, chunk: int = 4,
                 prefix_share: bool = False, preemption: bool = False):
        if kv not in ("paged", "dense"):
            raise ValueError(f"kv must be 'paged' or 'dense', got {kv!r}")
        if (prefix_share or preemption) and kv != "paged":
            raise ValueError(
                "prefix_share/preemption operate on the page pool; "
                "they require kv='paged'")
        if kv == "paged" and model.init_paged_cache is None:
            raise ValueError(
                f"model family {model.cfg.family!r} has no paged cache; "
                f"pass kv='dense' (the scheduler targets decoder LMs)")
        if kv == "paged" and max_len % page_size:
            raise ValueError(f"page_size {page_size} must divide max_len "
                             f"{max_len}")
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        self.model = model
        self.n_slots, self.max_len = n_slots, max_len
        self.kv, self.page_size, self.chunk = kv, page_size, int(chunk)
        self.kv_pages = kv_pages
        self.prefix_share = bool(prefix_share)
        self.preemption = bool(preemption)
        self.pages_per_seq = (max_len // page_size if kv == "paged"
                              else 0)
        self.mode_policy = mode_policy
        # per-scheduler telemetry: request lifecycle, token latency, QoS
        # shares, modeled device time/energy.  Scoped per instance so
        # concurrent schedulers never cross-contaminate and
        # telemetry=False is a clean metrics-off baseline (the CI
        # overhead gate).  Process-wide signals (engine dispatch, jit
        # trace/retrace counters) live in obs.registry() instead.
        self.telemetry = telemetry
        self.metrics = obs.MetricsRegistry(enabled=telemetry)
        self.tracer = obs.Tracer(enabled=telemetry)
        tenant_params: Dict[str, Any] = {}
        self._weights: Dict[str, float] = {}
        for t, spec in (dict(tenants) if tenants else {"A": params}).items():
            if (isinstance(spec, (tuple, list)) and len(spec) == 2
                    and isinstance(spec[1], (int, float))):
                p, w = spec
            else:
                p, w = spec, 1.0
            if w <= 0:
                raise ValueError(
                    f"tenant {t!r} QoS weight must be > 0, got {w}")
            tenant_params[t] = p
            self._weights[t] = float(w)
        if "A" not in tenant_params:
            raise ValueError("tenant 'A' is required (it anchors the "
                             "plane banks)")
        executor = getattr(model, "executor", None)
        if len(tenant_params) > 1 and executor is None:
            raise RuntimeError(
                "multi-tenant multiplexing serves each checkpoint from "
                "one plane of a stacked bank; it requires the "
                "crossbar backend (ModelConfig(backend='crossbar'))")
        if mode_policy is not None and executor is None:
            raise RuntimeError(
                "mode_policy selects per-weight crossbar read modes; it "
                "requires the crossbar backend "
                "(ModelConfig(backend='crossbar'))")
        if executor is not None:
            # crossbar backend: program each tenant's weights onto its
            # plane set ONCE at scheduler construction — the jitted
            # window closures below trace against already-programmed
            # tiles (program-at-load, read-at-inference).  mode_policy
            # decides each weight's plane layout here, at program time;
            # the closures then dispatch per weight with no extra traces
            # (expansion-fused reads are leak-free constants, deep-net
            # reads keep the traced leak operand)
            for t in sorted(tenant_params):
                with executor.read_tenant(t):
                    executor.ensure_programmed(tenant_params[t],
                                               mode_policy=mode_policy)
        self._slot_quota = _split_slots(n_slots, self._weights)
        self._page_quota: Dict[str, int] = {}
        if kv == "paged":
            if kv_pages is None:
                self._page_quota = {
                    t: self._slot_quota[t] * self.pages_per_seq
                    for t in self._weights}
            else:
                self._page_quota = _split_slots(kv_pages, self._weights)
        self._lanes: Dict[str, _Lane] = {
            t: self._make_lane(t, p) for t, p in sorted(tenant_params.items())}
        self._swap: Optional[HotSwapper] = None
        self._swap_t0: Optional[float] = None
        self.swap_history: List[Dict[str, Any]] = []
        for t, lane in self._lanes.items():
            self._set_qos_gauges(t, lane)

    # -- telemetry helpers ---------------------------------------------------

    def _set_qos_gauges(self, tenant: str, lane: _Lane) -> None:
        self.metrics.gauge(
            "serve_qos_weight",
            help="configured QoS weight per tenant lane").set(
                lane.weight, tenant=tenant)
        self.metrics.gauge(
            "serve_qos_slot_quota",
            help="decode slots the QoS-weighted split granted").set(
                lane.n_slots, tenant=tenant)
        if lane.pool is not None:
            self.metrics.gauge(
                "serve_qos_page_budget",
                help="KV pages the QoS-weighted split granted").set(
                    lane.pool.budget, tenant=tenant)

    def _account_tokens(self, lane: _Lane, n: int, kind: str) -> None:
        """Count ``n`` emitted tokens on a lane: the QoS served-token
        figure, plus modeled device-read time and energy split by read
        mode (Table-I constants via ``device_token_cost``)."""
        if n <= 0:
            return
        lane.tokens_served += n
        if not self.metrics.enabled:
            return
        self.metrics.counter(
            "serve_tokens_total",
            help="tokens emitted, by tenant and kind "
                 "(admission|decode)").inc(n, tenant=lane.tenant, kind=kind)
        if lane.device_cost:
            for mode, c in lane.device_cost.items():
                self.metrics.counter(
                    "serve_device_read_seconds_total",
                    help="modeled device read time spent producing "
                         "tokens, by read mode (t_read accounting)").inc(
                    n * c["read_s"], tenant=lane.tenant, mode=mode)
                self.metrics.counter(
                    "serve_device_energy_joules_total",
                    help="modeled worst-case analog read energy spent "
                         "producing tokens, by read mode").inc(
                    n * c["energy_j"], tenant=lane.tenant, mode=mode)

    def _finish_request(self, lane: _Lane, req: Request) -> None:
        """Completion bookkeeping: counter + the request's span set."""
        req.done = True
        self.metrics.counter(
            "serve_requests_completed_total",
            help="requests that emitted their full max_new budget").inc(
                tenant=lane.tenant)
        tr = self.tracer
        if not tr.enabled or req.t_submit is None:
            return
        tr.record("queue_wait", req.t_submit, req.t_admit,
                  rid=req.rid, tenant=lane.tenant)
        tr.record("prefill", req.t_admit, req.t_first,
                  rid=req.rid, tenant=lane.tenant, bucket=req.bucket)
        tr.record("decode", req.t_first, req.t_done,
                  rid=req.rid, tenant=lane.tenant, n_tokens=len(req.out))
        tr.record("request", req.t_submit, req.t_done,
                  rid=req.rid, tenant=lane.tenant, bucket=req.bucket,
                  n_tokens=len(req.out),
                  ttft_s=req.t_first - req.t_submit)

    # -- lanes ---------------------------------------------------------------

    def _make_lane(self, tenant: str, params) -> _Lane:
        n = self._slot_quota.get(tenant, self.n_slots)
        ex = self.model.executor
        pool = None
        if self.kv == "paged":
            n_pages = self._page_quota.get(
                tenant, default_pool_pages(n, self.max_len, self.page_size))
            pool = PagedKVPool(n_pages, self.page_size, self.max_len, n)
            cache = self.model.init_paged_cache(n, self.max_len, n_pages,
                                                self.page_size)
        else:
            cache = self.model.init_cache(n, self.max_len)
        return _Lane(tenant=tenant, params=params,
                     slots=[None] * n, cache=cache,
                     queue=[], decode=self._make_decode(tenant),
                     pool=pool, width=n, n_slots=n,
                     weight=self._weights.get(tenant, 1.0),
                     device_cost=(ex.device_token_cost(tenant)
                                  if ex is not None else None))

    def _lane_order(self) -> List[str]:
        """QoS admission/decode order: heavier lanes first, name breaks
        ties (so the equal-weight order is the historical sorted one)."""
        return sorted(self._lanes,
                      key=lambda t: (-self._lanes[t].weight, t))

    def _make_decode(self, tenant: str) -> Callable:
        """The ONE jitted closure per tenant:
        ``(params, tokens, cache, m, leak) -> (token, cache)``.

        ``tokens`` is the fixed (width, chunk) window; ``m`` the per-row
        valid counts (chunk tokens for a row mid-prompt, 1 for a
        decoding row, 0 for an empty slot).  The cache fill marker is
        pinned to ``old_len + m`` — pad positions past a row's count are
        never attendable (causal + length masks hit them with exact
        -1e30s), so the emitted token at row position ``m - 1`` is
        bit-exact with an unpadded reference.  Because the compiled
        shape is prompt-length independent, this closure traces exactly
        once per tenant for ANY prompt mix.

        ``leak`` is the write-plane leakage of an in-flight hot-swap as
        a *traced* scalar: the same compiled step serves leak = 0.0 in
        steady state and the live value during an overlap window — no
        re-trace at window boundaries, and (with ``cfg.use_kernel``) the
        Pallas kernel applies it pre-ADC, so overlap decode never falls
        back to the reference scan."""
        model = self.model
        ex = model.executor
        n_traces = [0]

        def _note_trace():
            # host-side code in a jitted body runs at trace time only:
            # each call here is exactly one (re)trace of THIS closure.
            # Any trace beyond the first is a re-trace — the runtime
            # counter behind the "zero re-traces at swap-window
            # boundaries" invariant (closure rebuilds at promotion get
            # a fresh counter, so their first trace is expected).
            n_traces[0] += 1
            obs.note_jit_trace("decode", tenant, retrace=n_traces[0] > 1)

        def _window(params, tokens, cache, m):
            old = cache["layers"]["len"]                     # (L, B)
            logits, cache = model.decode_step(params, tokens, cache)
            layers = dict(cache["layers"])
            layers["len"] = (old + m[None, :]).astype(old.dtype)
            sel = jnp.take_along_axis(
                logits, jnp.maximum(m - 1, 0)[:, None, None], axis=1)[:, 0]
            tok = jnp.argmax(sel.astype(jnp.float32),
                             axis=-1).astype(jnp.int32)
            return tok, dict(cache, layers=layers)

        if ex is None:
            def digital_step(params, tokens, cache, m):
                _note_trace()
                return _window(params, tokens, cache, m)

            digital = jax.jit(digital_step, donate_argnums=(2,))
            return lambda params, tokens, cache, m, leak: digital(
                params, tokens, cache, m)

        def tenant_step(params, tokens, cache, m, leak):
            _note_trace()
            with ex.read_tenant(tenant), ex.leak_scope(leak):
                return _window(params, tokens, cache, m)

        return jax.jit(tenant_step, donate_argnums=(2,))

    @property
    def params(self):
        """Tenant A's serving params (single-tenant compatibility)."""
        return self._lanes["A"].params

    @property
    def tenants(self) -> List[str]:
        return sorted(self._lanes)

    @property
    def queue(self) -> List[Request]:
        """Tenant A's queue (single-tenant compatibility)."""
        return self._lanes["A"].queue

    def submit(self, req: Request):
        lane = self._lanes.get(req.model_id)
        if lane is None:
            raise ValueError(
                f"request {req.rid} routes to unknown tenant "
                f"{req.model_id!r}; serving {self.tenants}")
        req.t_submit = self.tracer.now()
        self.metrics.counter(
            "serve_requests_submitted_total",
            help="requests accepted into a tenant queue").inc(
                tenant=lane.tenant)
        lane.queue.append(req)

    # -- dynamic QoS ---------------------------------------------------------

    def set_weights(self, weights: Dict[str, float]) -> None:
        """Re-weight QoS live: recompute the slot quotas and page
        budgets at a step boundary and update the ``serve_qos_*``
        gauges.  ``weights`` may cover any subset of resident tenants;
        the rest keep their current weight.

        Quota growth is capped at each lane's compiled width (resizing
        the batch would force a re-trace and drop in-flight cache
        state); shrinking takes effect as admissions — occupied slots
        above the new quota drain naturally as requests complete.
        Page-budget shrinks likewise only gate NEW admissions.
        """
        for t, w in weights.items():
            if t not in self._lanes:
                raise KeyError(f"no lane for tenant {t!r}: this "
                               f"scheduler serves {self.tenants}")
            if w <= 0:
                raise ValueError(
                    f"tenant {t!r} QoS weight must be > 0, got {w}")
        self._weights.update({t: float(w) for t, w in weights.items()})
        quota = _split_slots(self.n_slots, self._weights)
        pquota = (_split_slots(self.kv_pages, self._weights)
                  if (self.kv == "paged" and self.kv_pages is not None)
                  else None)
        for t, lane in self._lanes.items():
            lane.weight = self._weights[t]
            lane.n_slots = min(quota.get(t, lane.width), lane.width)
            self._slot_quota[t] = lane.n_slots
            if pquota is not None and lane.pool is not None:
                lane.pool.set_budget(pquota[t])
            self._set_qos_gauges(t, lane)

    # -- deep-net-mode hot-swap (serve reads while shadow planes program) ----

    def begin_hot_swap(self, new_params, chunks_per_step: int = 8,
                       tenant: str = "A") -> HotSwapper:
        """Start programming ``new_params`` onto a write plane set.

        Chunks are written between decode steps (inside :meth:`step`);
        when every chunk lands, the planes land atomically at a step
        boundary and subsequent tokens come from the new weights — no
        request is dropped and no decode step reads mixed planes.

        ``tenant`` may name any tenant of the plane bank; the lifecycle
        is chosen by bank state (see ``CrossbarExecutor.begin_swap``).
        With a free plane the swap is *staged*: the tenant — resident or
        a first-time live deploy — keeps serving through the whole
        window and no lane pauses.  With a full bank a non-anchor
        tenant is rewritten *in place*: its lane pauses for the write
        window (its planes are the write target) while every other
        tenant's traffic flows uninterrupted — the same
        read-under-write overlap, re-purposed for multi-tenancy.  A
        paused lane's in-flight requests freeze in place and resume on
        the promoted weights, exactly like single-tenant requests that
        span a flip.
        """
        if self.model.executor is None:
            raise RuntimeError("hot-swap requires the crossbar backend "
                               "(ModelConfig(backend='crossbar'))")
        if self._swap is not None:
            raise RuntimeError("a hot-swap is already in flight")
        self._swap = HotSwapper(self.model.executor, new_params,
                                chunks_per_step=chunks_per_step,
                                tenant=tenant)
        self._swap_t0 = self.tracer.now()
        lane = self._lanes.get(tenant)
        if lane is not None and self._swap.plan.in_place:
            lane.paused = True
        return self._swap

    @property
    def swap_in_flight(self) -> bool:
        return self._swap is not None

    def _apply_promotion(self, tenant: str, new_params) -> None:
        """Land promoted params on a lane: resident planes are trace
        constants of the jitted closure, so the tenant's window closure
        rebuilds (one re-trace, zero dropped requests).  A tenant
        deployed live via ``begin_hot_swap(..., tenant=...)`` gets a
        fresh lane here and starts admitting."""
        lane = self._lanes.get(tenant)
        if lane is None:
            if tenant not in self._weights:
                # a live-deployed tenant joins the QoS split at weight
                # 1.0: its quota comes from the same proportional rule
                # the construction-time split used (existing lanes keep
                # their quotas — resizing them would drop in-flight
                # cache state), so a weight-1.0 newcomer decodes like
                # any other weight-1.0 lane, not at the full base width
                self._weights[tenant] = 1.0
                total = self.n_slots * len(self._weights)
                wsum = sum(self._weights.values())
                self._slot_quota[tenant] = max(1, round(total / wsum))
                if self.kv == "paged":
                    if self.kv_pages is None:
                        self._page_quota[tenant] = (
                            self._slot_quota[tenant] * self.pages_per_seq)
                    else:
                        ptotal = self.kv_pages * len(self._weights)
                        self._page_quota[tenant] = max(
                            self.pages_per_seq, round(ptotal / wsum))
            self._lanes[tenant] = self._make_lane(tenant, new_params)
        else:
            lane.params = new_params
            lane.decode = self._make_decode(tenant)
            lane.paused = False
            ex = self.model.executor
            if ex is not None:
                lane.device_cost = ex.device_token_cost(tenant)
        self._set_qos_gauges(tenant, self._lanes[tenant])

    def _note_swap_window(self, tenant: str, lifecycle: str, policy: str,
                          rep: Dict[str, Any]) -> None:
        """Record a completed swap window: one counter bump plus a span
        tagged with its lifecycle (``staged``/``in_place``) and policy
        (``overlapped``/``stop_the_world``)."""
        self.metrics.counter(
            "serve_swap_windows_total",
            help="completed swap windows, by lifecycle and policy").inc(
                tenant=tenant, lifecycle=lifecycle, policy=policy)
        if self._swap_t0 is not None:
            self.tracer.record(
                "swap_window", self._swap_t0, self.tracer.now(),
                tenant=tenant, lifecycle=lifecycle, policy=policy,
                chunks=rep.get("n_chunks"),
                decode_steps_during=rep.get("decode_steps_during_swap"))

    def stop_the_world_swap(self, new_params,
                            tenant: str = "A") -> Dict[str, Any]:
        """Blocking reprogram (the conventional-2-D-array policy): serving
        stalls while every chunk is written, the planes land, and the
        decode step re-traces.  The comparison baseline for the overlapped
        path — same end state, but no tokens flow during the swap.  Like
        the overlapped path, every deploy lands in ``swap_history`` so
        benches and operators see it."""
        if self.model.executor is None:
            raise RuntimeError("hot-swap requires the crossbar backend "
                               "(ModelConfig(backend='crossbar'))")
        if self._swap is not None:
            raise RuntimeError("a hot-swap is already in flight")
        ex = self.model.executor
        t0 = time.perf_counter()
        stats = ex.swap(new_params, tenant=tenant)
        wall = time.perf_counter() - t0
        self._apply_promotion(tenant, new_params)
        rep = overlap_report(ex.cfg, n_grids=ex.n_resident,
                             n_chunks=stats["n_chunks"],
                             batch_size=self.n_slots,
                             decode_steps_during=0, wall_swap_s=wall)
        rep["policy"] = "stop_the_world"
        rep["tenant"] = tenant
        rep["swap_mode"] = stats.get("swap_mode", "staged")
        self.swap_history.append(rep)
        self._swap_t0 = t0
        self._note_swap_window(tenant, rep["swap_mode"],
                               "stop_the_world", rep)
        self._swap_t0 = None
        return stats

    def _advance_swap(self):
        """Program a burst of chunks; promote at the step boundary once
        the staged planes are fully written."""
        sw = self._swap
        if sw is None:
            return
        sw.step()
        if sw.done:
            new_params = sw.promote()
            self._apply_promotion(sw.tenant, new_params)
            rep = sw.report(batch_size=self.n_slots)
            self.swap_history.append(rep)
            self._note_swap_window(sw.tenant, rep["swap_mode"],
                                   "overlapped", rep)
            self._swap = None
            self._swap_t0 = None

    # -- admission (host bookkeeping only: slots + pages) --------------------

    def _leak_now(self) -> jax.Array:
        """The leak scalar this step's closures should carry (see
        ``CrossbarExecutor.current_leak_codes``): 0.0 outside a swap
        window, the write plane's leakage inside one."""
        ex = self.model.executor
        return (ex.current_leak_codes() if ex is not None
                else jnp.float32(0.0))

    def _admit(self, lane: _Lane) -> None:
        """Move queued requests into free slots.

        Pure host bookkeeping — a slot index, a page-table row, a fill
        marker — so admission can NEVER stall an in-flight decode step;
        the admitted prompt streams into the running batch as prefill
        chunks on subsequent :meth:`step` calls.  When the page pool
        (or the QoS page budget) cannot cover a request's whole
        lifetime (``min(prompt + max_new - 1, max_len)`` tokens,
        claimed up front so an admitted request can never deadlock
        mid-decode), the request waits in FIFO order — queued, never
        dropped — unless ``preemption`` is on and a strictly
        lower-QoS resident can be evicted (see :meth:`_preempt_for`).

        With ``prefix_share`` on, the allocation consults the pool's
        prefix index: pages whose token chain matches the head of this
        request's feed are aliased (refcounted) instead of freshly
        claimed, the fill marker and prefill cursor start past the
        shared positions (their K/V is already written), and any
        partially-covered aliased page is privatized copy-on-write —
        the device page copy happens here, before the row's first
        write.  The admitted streams stay bit-exact with the dense
        oracle because a chain hit pins page contents byte-for-byte.
        """
        while lane.queue:
            req = lane.queue[0]
            plen = int(req.prompt.shape[0])
            if plen - 1 >= self.max_len:
                # the last real token's K/V lands at position plen - 1:
                # the prompt must fit strictly inside the cache depth or
                # the write (and every token after it) silently falls
                # off the end
                raise ValueError(f"prompt length {plen} exceeds the "
                                 f"scheduler's max_len {self.max_len}")
            free = [i for i, s in enumerate(lane.slots) if s is None]
            active = sum(s is not None for s in lane.slots)
            if active >= lane.n_slots or not free:
                if not (self.preemption and self._preempt_for(lane, req)):
                    return
                continue
            row = free[0]
            # the feed replays prompt + (after a preemption) the tokens
            # already emitted — greedy decode is deterministic, so the
            # re-prefilled row continues its stream bit-exactly
            feed = np.asarray(req.prompt, np.int32)
            if req.out:
                feed = np.concatenate(
                    [feed, np.asarray(req.out, np.int32)])
            shared = 0
            cow_pairs: List[Any] = []
            if lane.pool is not None:
                need = min(plen + req.max_new - 1, self.max_len)
                if self.prefix_share:
                    if not lane.pool.can_alloc_shared(need, feed):
                        if not (self.preemption
                                and self._preempt_for(lane, req)):
                            return        # backpressure: wait, FIFO
                        continue
                    pages, shared, cow_pairs = lane.pool.alloc_shared(
                        row, need, feed)
                else:
                    if not lane.pool.can_alloc(need):
                        if not (self.preemption
                                and self._preempt_for(lane, req)):
                            return        # backpressure: wait, FIFO
                        continue
                    pages = lane.pool.alloc(row, need)
                req.bucket = len(pages)
                for src, dst in cow_pairs:
                    lane.cache = self.model.copy_paged_page(
                        lane.cache, src, dst)
            layers = dict(lane.cache["layers"])
            if lane.pool is not None:
                tab = jnp.asarray(lane.pool.table_row(row))
                layers["pt"] = layers["pt"].at[:, row].set(tab[None])
            # shared positions are pre-written: the fill marker starts
            # past them and prefill skips straight to the divergence
            layers["len"] = layers["len"].at[:, row].set(shared)
            lane.cache = dict(lane.cache, layers=layers)
            lane.queue.pop(0)
            req.feed = feed
            req.fed = shared
            req.shared_tokens += shared
            if shared and self.metrics.enabled:
                self.metrics.counter(
                    "serve_kv_pages_shared_total",
                    help="KV pages aliased from the prefix index at "
                         "admission instead of freshly written").inc(
                    lane.pool.row_shared_pages(row), tenant=lane.tenant)
                self.metrics.counter(
                    "serve_kv_shared_tokens_total",
                    help="prefill token positions skipped because their "
                         "K/V arrived via shared pages").inc(
                    shared, tenant=lane.tenant)
            if cow_pairs and self.metrics.enabled:
                self.metrics.counter(
                    "serve_kv_cow_total",
                    help="shared pages privatized copy-on-write at "
                         "admission").inc(len(cow_pairs),
                                          tenant=lane.tenant)
            if req.t_admit is None:
                # first admission only: re-admissions after a preemption
                # keep the original timestamps so the span set still
                # telescopes over the request's real wall time
                req.t_admit = self.tracer.now()
                if self.metrics.enabled and req.t_submit is not None:
                    self.metrics.histogram(
                        "serve_queue_wait_seconds",
                        help="submit-to-admission wait").observe(
                        req.t_admit - req.t_submit, tenant=lane.tenant)
            lane.slots[row] = req

    def _preempt_for(self, lane: _Lane, head: Request) -> bool:
        """Evict one resident request so ``head`` can admit.

        Victims must sit at *strictly* lower ``qos`` than the waiting
        request — strictness keeps equal-priority traffic pure FIFO and
        guarantees preemption chains terminate (each eviction is paid
        for by a strictly higher-QoS admission, so no two requests can
        evict each other forever).  Among candidates the lowest QoS
        goes first; ties evict the least-progressed request (cheapest
        to recompute), then the highest row for determinism.

        Eviction reclaims the victim's pages (refcount-aware: pages it
        shares with other rows survive for them) and spills its
        recomputable state to the host-side stub it already carries —
        the ``Request`` itself, whose prompt + emitted tokens fully
        determine the greedy continuation.  The victim re-queues right
        behind ``head`` and later re-admits through the ordinary
        chunked-prefill path, continuing its stream bit-exactly (and
        through the SAME compiled closure: eviction is host
        bookkeeping, so the retrace count stays zero across the
        preempt/re-admit boundary).
        """
        cands = [(i, r) for i, r in enumerate(lane.slots)
                 if r is not None and r.qos < head.qos]
        if not cands:
            return False
        row, victim = min(
            cands,
            key=lambda ir: (ir[1].qos, ir[1].fed + len(ir[1].out),
                            -ir[0]))
        victim.preemptions += 1
        victim.fed = 0
        victim.feed = None
        self._release_slot(lane, row)
        lane.queue.insert(min(1, len(lane.queue)), victim)
        self.metrics.counter(
            "serve_preemptions_total",
            help="resident requests evicted (pages reclaimed, state "
                 "spilled to host) to admit higher-QoS work").inc(
            tenant=lane.tenant)
        return True

    def _release_slot(self, lane: _Lane, row: int) -> None:
        """Return a completed slot: reclaim its pages and null its
        table row so stale writes land on the null page, never on a
        page the free list may hand to the next admission."""
        lane.slots[row] = None
        layers = dict(lane.cache["layers"])
        if lane.pool is not None:
            lane.pool.free_row(row)
            layers["pt"] = layers["pt"].at[:, row].set(0)
        layers["len"] = layers["len"].at[:, row].set(0)
        lane.cache = dict(lane.cache, layers=layers)

    def step(self) -> List[Request]:
        """One window step for every tenant's active slots; returns
        finished requests (across tenants).

        An in-flight hot-swap advances first — plane chunks program
        strictly between decode steps, and promotion happens here at the
        boundary, so every decode call reads one consistent plane set.
        A lane whose planes are the write target stays paused for the
        window; the other tenant's lane decodes through it.

        Each occupied row contributes its next prompt chunk (mid
        prefill; emits its first token when the prompt drains) or its
        last generated token (decode, ``m = 1``).  Empty rows ride along
        at ``m = 0``.  One fixed-shape call serves them all.
        """
        self._advance_swap()
        finished: List[Request] = []
        decoded = False
        leak = self._leak_now()
        c = self.chunk
        for t in self._lane_order():
            lane = self._lanes[t]
            if lane.paused:
                continue
            self._admit(lane)
            if all(s is None for s in lane.slots):
                continue
            toks = np.zeros((lane.width, c), np.int32)
            m = np.zeros((lane.width,), np.int32)
            emit: List[Optional[str]] = [None] * lane.width
            reg_rows: List[int] = []
            for i, req in enumerate(lane.slots):
                if req is None:
                    continue
                feed = req.feed
                flen = int(feed.shape[0])
                if req.fed < flen:
                    piece = feed[req.fed:req.fed + c]
                    toks[i, :piece.shape[0]] = piece
                    m[i] = piece.shape[0]
                    req.fed += int(piece.shape[0])
                    if req.fed >= flen:
                        # final chunk: the argmax is the request's first
                        # token — or, on a post-preemption re-admission
                        # (out non-empty), the continuation of a stream
                        # that already started
                        emit[i] = "admission" if not req.out else "decode"
                        reg_rows.append(i)
                else:
                    toks[i, 0] = req.out[-1]
                    m[i] = 1
                    emit[i] = "decode"
            t0 = self.tracer.now()
            tok, lane.cache = lane.decode(
                lane.params, jnp.asarray(toks), lane.cache,
                jnp.asarray(m), leak)
            decoded = True
            tok_host = np.asarray(tok)
            if self.prefix_share and lane.pool is not None:
                # prefill just completed for these rows: every page
                # wholly covered by the feed is final on device now —
                # index it so later common-head admissions alias it
                for i in reg_rows:
                    if lane.slots[i] is not None:
                        lane.pool.register_prefix(
                            i, lane.slots[i].feed.tolist())
            n_admit = n_dec = 0
            for i, req in enumerate(lane.slots):
                if req is None or emit[i] is None:
                    continue
                req.out.append(int(tok_host[i]))
                if emit[i] == "admission":
                    req.t_first = self.tracer.now()
                    n_admit += 1
                    if self.metrics.enabled and req.t_submit is not None:
                        self.metrics.histogram(
                            "serve_ttft_seconds",
                            help="submit to first emitted token").observe(
                            req.t_first - req.t_submit, tenant=lane.tenant)
                else:
                    n_dec += 1
                if len(req.out) >= req.max_new:
                    req.t_done = self.tracer.now()
                    self._finish_request(lane, req)
                    finished.append(req)
                    self._release_slot(lane, i)
            self._account_tokens(lane, n_admit, "admission")
            self._account_tokens(lane, n_dec, "decode")
            if self.metrics.enabled and (n_admit + n_dec):
                # every emitted token materialized in this one batched
                # step, so the per-token latency IS the step wall time —
                # observed once per emitted token so histogram mass
                # weights by tokens, not steps
                dt = self.tracer.now() - t0
                h = self.metrics.histogram(
                    "serve_token_latency_seconds",
                    help="wall time of the decode step that produced "
                         "each token")
                for _ in range(n_admit + n_dec):
                    h.observe(dt, tenant=lane.tenant)
        if decoded and self._swap is not None:
            self._swap.note_decode_step()
        return finished

    def kv_report(self) -> Dict[str, Dict[str, Any]]:
        """Per-tenant page-pool accounting (paged lanes only): sizes,
        live usage, QoS budget, and the conservation invariant
        ``pages_in_use + pages_free == n_pages`` — the paged bench's
        exit gate reads this."""
        return {t: lane.pool.report()
                for t, lane in sorted(self._lanes.items())
                if lane.pool is not None}

    def attn_lane_report(self) -> Dict[str, Any]:
        """Which paged-attention lane the compiled closures dispatch,
        plus the model's streaming configuration.  The dispatch counts
        are the process-global trace-time counters
        (``crossstack_dispatch_total{path=paged_*}``): one bump per
        traced closure, so a serving run whose every closure streamed
        shows ``paged_fallback == 0`` and ``paged_streamed >= 1`` — the
        long-context bench's no-silent-fallback exit gate reads this.
        """
        from repro.kernels.paged_attention import paged_path_calls
        cfg = self.model.cfg
        return {"paged_kernel": bool(getattr(cfg, "paged_kernel", False)),
                "stream_min_pages": int(
                    getattr(cfg, "paged_stream_pages", 0)),
                "block_pages": int(getattr(cfg, "paged_block_pages", 16)),
                "pages_per_seq": self.pages_per_seq,
                "dispatch": dict(paged_path_calls)}

    def mode_report(self, tenant: Optional[str] = None) -> Dict[str, Any]:
        """Per-weight read-mode choices and their IR-drop economics for
        a tenant's plane set (``CrossbarExecutor.mode_report``) — the
        operator-facing view of what ``mode_policy`` decided — plus a
        ``traffic`` block turning the static per-mode claims into live
        traffic-weighted figures: tokens served and the modeled device
        read time / energy / pJ-per-token accumulated per read mode.

        ``tenant`` defaults to the scheduler's anchor tenant (the
        executor's first plane, what ``params`` serves); asking for a
        tenant this scheduler has no lane for is a ``KeyError`` naming
        the resident tenants.
        """
        ex = self.model.executor
        if ex is None:
            raise RuntimeError(
                "mode_report requires the crossbar backend "
                "(ModelConfig(backend='crossbar'))")
        if tenant is None:
            tenant = ex.anchor
        lane = self._lanes.get(tenant)
        if lane is None:
            raise KeyError(
                f"no lane for tenant {tenant!r}: this scheduler serves "
                f"tenants {self.tenants}")
        rep = ex.mode_report(tenant=tenant)
        tokens = lane.tokens_served
        modes: Dict[str, Any] = {}
        for mode, cost in sorted((lane.device_cost or {}).items()):
            if self.metrics.enabled:
                read_s = self.metrics.total(
                    "serve_device_read_seconds_total",
                    tenant=tenant, mode=mode)
                energy = self.metrics.total(
                    "serve_device_energy_joules_total",
                    tenant=tenant, mode=mode)
            else:
                # metrics off: the per-token cost is constant, so the
                # accumulated figure is exactly cost * tokens
                read_s = cost["read_s"] * tokens
                energy = cost["energy_j"] * tokens
            modes[mode] = {
                "device_read_s": read_s,
                "energy_j": energy,
                "pj_per_token": (energy / tokens * 1e12
                                 if tokens else 0.0),
            }
        rep["traffic"] = {"tokens_served": tokens, "modes": modes}
        return rep

    def qos_report(self) -> Dict[str, Dict[str, Any]]:
        """Per-tenant QoS accounting in ``swap_history`` style: the
        configured weight, the slot quota the weighted split granted,
        the page budget/usage (paged lanes), and the served-token
        count/share so far (admission + decode tokens) — the figure the
        weights are supposed to shift.

        A view over the scheduler registry when telemetry is on
        (``serve_qos_*`` gauges + ``serve_tokens_total``); the lane
        fields remain authoritative with telemetry off.
        """
        if self.metrics.enabled:
            served = {t: int(self.metrics.total("serve_tokens_total",
                                                tenant=t))
                      for t in self._lanes}
        else:
            served = {t: lane.tokens_served
                      for t, lane in self._lanes.items()}
        total = sum(served.values())
        out = {}
        for t, lane in sorted(self._lanes.items()):
            entry: Dict[str, Any] = {
                "weight": lane.weight,
                "slots": lane.n_slots,
                "tokens_served": served[t],
                "token_share": (served[t] / total if total else 0.0)}
            if lane.pool is not None:
                entry["page_budget"] = lane.pool.budget
                entry["pages_in_use"] = lane.pool.pages_in_use
                entry["pages_owned"] = lane.pool.pages_owned
                entry["pages_shared"] = lane.pool.pages_shared
            out[t] = entry
        return out
