"""Batched serving runtime: prefill/decode step builders + a simple
continuous-batching scheduler for the examples.

serve_step contract (what the dry-run lowers for decode cells): one new
token for every sequence in the batch against a seq_len-deep KV cache,
cache donated, greedy or temperature sampling on-device.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro import obs
from repro.models.model import Model
from repro.serve.hotswap import HotSwapper, overlap_report


def make_prefill_step(model: Model):
    def prefill_step(params, batch, cache):
        logits, cache = model.prefill(params, batch, cache)
        next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(
            jnp.int32)
        return next_tok, cache

    return prefill_step


def make_decode_step(model: Model, temperature: float = 0.0):
    def decode_step(params, tokens, cache, key=None):
        logits, cache = model.decode_step(params, tokens, cache)
        lg = logits[:, -1].astype(jnp.float32)
        if temperature > 0.0 and key is not None:
            nxt = jax.random.categorical(key, lg / temperature, axis=-1)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        return nxt[:, None].astype(jnp.int32), cache

    return decode_step


def greedy_generate(model: Model, params, batch, max_new: int,
                    max_len: Optional[int] = None):
    """Jit-friendly generation loop used by examples/serve_batch.py."""
    b = batch["tokens"].shape[0]
    s = batch["tokens"].shape[1]
    max_len = max_len or (s + max_new)
    if model.cfg.family == "encdec":
        cache = model.init_cache(b, max_len, src_len=s)
    else:
        cache = model.init_cache(b, max_len)
    prefill = make_prefill_step(model)
    decode = make_decode_step(model)
    tok, cache = prefill(params, batch, cache)
    toks = [tok]

    def body(carry, _):
        tok, cache = carry
        tok, cache = decode(params, tok, cache)
        return (tok, cache), tok

    (_, _), rest = jax.lax.scan(body, (tok, cache), None,
                                length=max_new - 1)
    return jnp.concatenate([tok[:, None], rest.swapaxes(0, 1)],
                           axis=1)[:, :, 0]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jax.Array          # (S,) int32
    max_new: int
    model_id: str = "A"        # tenant whose checkpoint serves this request
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # lifecycle timestamps (scheduler tracer clock), filled in by the
    # scheduler when telemetry is on; the span set recorded at completion
    # telescopes exactly: queue_wait [t_submit, t_admit] + prefill
    # [t_admit, t_first] + decode [t_first, t_done] = request wall time
    bucket: Optional[int] = None
    t_submit: Optional[float] = None
    t_admit: Optional[float] = None
    t_first: Optional[float] = None
    t_done: Optional[float] = None


def _prompt_bucket(m: int, max_len: int) -> int:
    """Padded prefill length for an ``m``-token prompt slice: the next
    power of two (>= 8), capped at the cache depth — the jit cache key,
    so admissions re-trace per *bucket*, not per prompt length."""
    bucket = 8 if m <= 8 else 1 << (m - 1).bit_length()
    return min(bucket, max_len)


@dataclasses.dataclass
class _Lane:
    """One tenant's serving state: a fixed slot batch against one plane
    set, with its own jitted decode closure (the tiles it traced are that
    tenant's planes — trace constants, like params sharding)."""
    tenant: str
    params: Any
    slots: List[Optional[Request]]
    cache: Any
    tokens: jax.Array
    queue: List[Request]
    decode: Callable
    # QoS: this lane's share of the slot budget and its admission weight
    n_slots: int = 0
    weight: float = 1.0
    # served-token accounting (admission + decode tokens), the quantity
    # QoS weights shift; see BatchScheduler.qos_report
    tokens_served: int = 0
    # True while this tenant's own planes are mid-write (in-place swap):
    # its reads pause — admissions hold, in-flight slots freeze — and
    # resume on the promoted weights at the swap boundary
    paused: bool = False
    # modeled per-token device read cost by mode, cached from
    # CrossbarExecutor.device_token_cost at lane build/promotion — the
    # constants the per-token device-time/energy counters accumulate
    device_cost: Optional[Dict[str, Dict[str, float]]] = None


def _split_slots(n_slots: int, weights: Dict[str, float]) -> Dict[str, int]:
    """QoS-weighted slot allocation across tenant lanes.

    The slot budget is ``n_slots`` per tenant (so equal weights reproduce
    the historical even split exactly); quotas are proportional to weight
    with largest-remainder rounding, and a starvation guard pins every
    tenant at >= 1 slot — a resident tenant with queued work always
    decodes, however small its weight.
    """
    total = n_slots * len(weights)
    wsum = float(sum(weights.values()))
    raw = {t: total * float(w) / wsum for t, w in weights.items()}
    alloc = {t: max(1, int(raw[t])) for t in weights}
    leftover = total - sum(alloc.values())
    # hand leftover slots to the largest fractional remainders (name
    # breaks ties, so the split is deterministic)
    order = sorted(weights, key=lambda t: (-(raw[t] - int(raw[t])), t))
    i = 0
    while leftover > 0:
        alloc[order[i % len(order)]] += 1
        leftover -= 1
        i += 1
    while leftover < 0:
        # the >=1 guard oversubscribed the budget: reclaim from the
        # largest allocation that can spare a slot
        t = max(sorted(alloc), key=lambda k: alloc[k])
        if alloc[t] <= 1:
            break   # everyone is at the guard floor; keep the floor
        alloc[t] -= 1
        leftover += 1
    return alloc


class BatchScheduler:
    """Minimal continuous-batching scheduler (slot-based, multi-tenant).

    Maintains a fixed decode batch per tenant (the QoS-weighted slot
    quota); free slots are refilled from that tenant's queue by batched
    admission prefills, which keeps the decode step shape static — the
    property the dry-run cells exercise.  Same-bucket queued prompts
    coalesce into ONE batched prefill call per admission group; the
    calls are jitted and cached per padded prompt-length bucket, so
    steady-state admission is a cache hit, not a re-trace.

    Passing ``tenants={"A": params_a, "B": params_b, ...}`` multiplexes
    up to ``stack_planes`` checkpoints from the plane bank of ONE
    crossbar executor: each tenant gets its own slot partition, cache,
    and jitted decode closure (traced under ``executor.read_tenant(t)``
    so the closure's trace constants are that tenant's planes), and
    every ``step`` interleaves all token streams.  Requests route by
    ``Request.model_id``.

    A tenant value may also be a ``(params, weight)`` pair: QoS weights
    drive the slot split (``_split_slots``: proportional quota with a
    >=1 starvation guard) and the admission order across lanes
    (heavier lanes admit first each step).  Bare params mean weight 1.0,
    which reproduces the historical even split exactly.
    """

    def __init__(self, model: Model, params, n_slots: int, max_len: int,
                 tenants: Optional[Dict[str, Any]] = None,
                 mode_policy=None, telemetry: bool = True):
        self.model = model
        self.n_slots, self.max_len = n_slots, max_len
        self.mode_policy = mode_policy
        # per-scheduler telemetry: request lifecycle, token latency, QoS
        # shares, modeled device time/energy.  Scoped per instance so
        # concurrent schedulers never cross-contaminate and
        # telemetry=False is a clean metrics-off baseline (the CI
        # overhead gate).  Process-wide signals (engine dispatch, jit
        # trace/retrace counters) live in obs.registry() instead.
        self.telemetry = telemetry
        self.metrics = obs.MetricsRegistry(enabled=telemetry)
        self.tracer = obs.Tracer(enabled=telemetry)
        tenant_params: Dict[str, Any] = {}
        self._weights: Dict[str, float] = {}
        for t, spec in (dict(tenants) if tenants else {"A": params}).items():
            if (isinstance(spec, (tuple, list)) and len(spec) == 2
                    and isinstance(spec[1], (int, float))):
                p, w = spec
            else:
                p, w = spec, 1.0
            if w <= 0:
                raise ValueError(
                    f"tenant {t!r} QoS weight must be > 0, got {w}")
            tenant_params[t] = p
            self._weights[t] = float(w)
        if "A" not in tenant_params:
            raise ValueError("tenant 'A' is required (it anchors the "
                             "plane banks)")
        executor = getattr(model, "executor", None)
        if len(tenant_params) > 1 and executor is None:
            raise RuntimeError(
                "multi-tenant multiplexing serves each checkpoint from "
                "one plane of a stacked bank; it requires the "
                "crossbar backend (ModelConfig(backend='crossbar'))")
        if mode_policy is not None and executor is None:
            raise RuntimeError(
                "mode_policy selects per-weight crossbar read modes; it "
                "requires the crossbar backend "
                "(ModelConfig(backend='crossbar'))")
        if executor is not None:
            # crossbar backend: program each tenant's weights onto its
            # plane set ONCE at scheduler construction — the jitted decode
            # closures below trace against already-programmed tiles
            # (program-at-load, read-at-inference).  mode_policy decides
            # each weight's plane layout here, at program time; the
            # decode closures then dispatch per weight with no extra
            # traces (expansion-fused reads are leak-free constants,
            # deep-net reads keep the traced leak operand)
            for t in sorted(tenant_params):
                with executor.read_tenant(t):
                    executor.ensure_programmed(tenant_params[t],
                                               mode_policy=mode_policy)
        self._slot_quota = _split_slots(n_slots, self._weights)
        self._lanes: Dict[str, _Lane] = {
            t: self._make_lane(t, p) for t, p in sorted(tenant_params.items())}
        # jitted admission prefill per tenant; jax's jit cache keys on the
        # padded token shape, i.e. one trace per prompt-length bucket
        self._prefill_fns: Dict[str, Callable] = {}
        self._prefill_traces = 0     # bumped at trace time (tests pin it)
        # (tenant, bucket) pairs already traced by the CURRENT prefill
        # closures: a trace of a seen pair is a re-trace (the registry's
        # serve_jit_retraces_total).  Cleared per tenant at promotion,
        # where the closure legitimately rebuilds.
        self._prefill_seen: set = set()
        self._swap: Optional[HotSwapper] = None
        self._swap_t0: Optional[float] = None
        self.swap_history: List[Dict[str, Any]] = []
        for t, lane in self._lanes.items():
            self._set_qos_gauges(t, lane)

    # -- telemetry helpers ---------------------------------------------------

    def _set_qos_gauges(self, tenant: str, lane: _Lane) -> None:
        self.metrics.gauge(
            "serve_qos_weight",
            help="configured QoS weight per tenant lane").set(
                lane.weight, tenant=tenant)
        self.metrics.gauge(
            "serve_qos_slot_quota",
            help="decode slots the QoS-weighted split granted").set(
                lane.n_slots, tenant=tenant)

    def _account_tokens(self, lane: _Lane, n: int, kind: str) -> None:
        """Count ``n`` emitted tokens on a lane: the QoS served-token
        figure, plus modeled device-read time and energy split by read
        mode (Table-I constants via ``device_token_cost``)."""
        if n <= 0:
            return
        lane.tokens_served += n
        if not self.metrics.enabled:
            return
        self.metrics.counter(
            "serve_tokens_total",
            help="tokens emitted, by tenant and kind "
                 "(admission|decode)").inc(n, tenant=lane.tenant, kind=kind)
        if lane.device_cost:
            for mode, c in lane.device_cost.items():
                self.metrics.counter(
                    "serve_device_read_seconds_total",
                    help="modeled device read time spent producing "
                         "tokens, by read mode (t_read accounting)").inc(
                    n * c["read_s"], tenant=lane.tenant, mode=mode)
                self.metrics.counter(
                    "serve_device_energy_joules_total",
                    help="modeled worst-case analog read energy spent "
                         "producing tokens, by read mode").inc(
                    n * c["energy_j"], tenant=lane.tenant, mode=mode)

    def _finish_request(self, lane: _Lane, req: Request) -> None:
        """Completion bookkeeping: counter + the request's span set."""
        req.done = True
        self.metrics.counter(
            "serve_requests_completed_total",
            help="requests that emitted their full max_new budget").inc(
                tenant=lane.tenant)
        tr = self.tracer
        if not tr.enabled or req.t_submit is None:
            return
        tr.record("queue_wait", req.t_submit, req.t_admit,
                  rid=req.rid, tenant=lane.tenant)
        tr.record("prefill", req.t_admit, req.t_first,
                  rid=req.rid, tenant=lane.tenant, bucket=req.bucket)
        tr.record("decode", req.t_first, req.t_done,
                  rid=req.rid, tenant=lane.tenant, n_tokens=len(req.out))
        tr.record("request", req.t_submit, req.t_done,
                  rid=req.rid, tenant=lane.tenant, bucket=req.bucket,
                  n_tokens=len(req.out),
                  ttft_s=req.t_first - req.t_submit)

    # -- lanes ---------------------------------------------------------------

    def _make_lane(self, tenant: str, params) -> _Lane:
        n = self._slot_quota.get(tenant, self.n_slots)
        ex = self.model.executor
        return _Lane(tenant=tenant, params=params,
                     slots=[None] * n,
                     cache=self.model.init_cache(n, self.max_len),
                     tokens=jnp.zeros((n, 1), jnp.int32),
                     queue=[], decode=self._make_decode(tenant),
                     n_slots=n, weight=self._weights.get(tenant, 1.0),
                     device_cost=(ex.device_token_cost(tenant)
                                  if ex is not None else None))

    def _lane_order(self) -> List[str]:
        """QoS admission/decode order: heavier lanes first, name breaks
        ties (so the equal-weight order is the historical sorted one)."""
        return sorted(self._lanes,
                      key=lambda t: (-self._lanes[t].weight, t))

    def _make_decode(self, tenant: str) -> Callable:
        """Jitted decode closure ``(params, tokens, cache, leak) -> ...``.

        ``leak`` is the write-plane leakage of an in-flight hot-swap as a
        *traced* scalar: the same compiled step serves leak = 0.0 in
        steady state and the live value during an overlap window — no
        re-trace at window boundaries, and (with ``cfg.use_kernel``) the
        Pallas kernel applies it pre-ADC, so overlap decode never falls
        back to the reference scan."""
        base = make_decode_step(self.model)
        ex = self.model.executor
        n_traces = [0]

        def _note_trace():
            # host-side code in a jitted body runs at trace time only:
            # each call here is exactly one (re)trace of THIS closure.
            # Any trace beyond the first is a re-trace — the runtime
            # counter behind the "zero re-traces at swap-window
            # boundaries" invariant (closure rebuilds at promotion get
            # a fresh counter, so their first trace is expected).
            n_traces[0] += 1
            obs.note_jit_trace("decode", tenant, retrace=n_traces[0] > 1)

        if ex is None:
            def digital_step(params, tokens, cache):
                _note_trace()
                return base(params, tokens, cache)

            digital = jax.jit(digital_step, donate_argnums=(2,))
            return lambda params, tokens, cache, leak: digital(
                params, tokens, cache)

        def tenant_step(params, tokens, cache, leak):
            _note_trace()
            with ex.read_tenant(tenant), ex.leak_scope(leak):
                return base(params, tokens, cache)

        return jax.jit(tenant_step, donate_argnums=(2,))

    @property
    def params(self):
        """Tenant A's serving params (single-tenant compatibility)."""
        return self._lanes["A"].params

    @property
    def tenants(self) -> List[str]:
        return sorted(self._lanes)

    @property
    def queue(self) -> List[Request]:
        """Tenant A's queue (single-tenant compatibility)."""
        return self._lanes["A"].queue

    def submit(self, req: Request):
        lane = self._lanes.get(req.model_id)
        if lane is None:
            raise ValueError(
                f"request {req.rid} routes to unknown tenant "
                f"{req.model_id!r}; serving {self.tenants}")
        req.t_submit = self.tracer.now()
        self.metrics.counter(
            "serve_requests_submitted_total",
            help="requests accepted into a tenant queue").inc(
                tenant=lane.tenant)
        lane.queue.append(req)

    # -- deep-net-mode hot-swap (serve reads while shadow planes program) ----

    def begin_hot_swap(self, new_params, chunks_per_step: int = 8,
                       tenant: str = "A") -> HotSwapper:
        """Start programming ``new_params`` onto a write plane set.

        Chunks are written between decode steps (inside :meth:`step`);
        when every chunk lands, the planes land atomically at a step
        boundary and subsequent tokens come from the new weights — no
        request is dropped and no decode step reads mixed planes.

        ``tenant`` may name any tenant of the plane bank; the lifecycle
        is chosen by bank state (see ``CrossbarExecutor.begin_swap``).
        With a free plane the swap is *staged*: the tenant — resident or
        a first-time live deploy — keeps serving through the whole
        window and no lane pauses.  With a full bank a non-anchor
        tenant is rewritten *in place*: its lane pauses for the write
        window (its planes are the write target) while every other
        tenant's traffic flows uninterrupted — the same
        read-under-write overlap, re-purposed for multi-tenancy.  A
        paused lane's in-flight requests freeze in place and resume on
        the promoted weights, exactly like single-tenant requests that
        span a flip.
        """
        if self.model.executor is None:
            raise RuntimeError("hot-swap requires the crossbar backend "
                               "(ModelConfig(backend='crossbar'))")
        if self._swap is not None:
            raise RuntimeError("a hot-swap is already in flight")
        self._swap = HotSwapper(self.model.executor, new_params,
                                chunks_per_step=chunks_per_step,
                                tenant=tenant)
        self._swap_t0 = self.tracer.now()
        lane = self._lanes.get(tenant)
        if lane is not None and self._swap.plan.in_place:
            lane.paused = True
        return self._swap

    @property
    def swap_in_flight(self) -> bool:
        return self._swap is not None

    def _apply_promotion(self, tenant: str, new_params) -> None:
        """Land promoted params on a lane: resident planes are trace
        constants of the jitted closures, so the tenant's decode closure
        rebuilds (one re-trace, zero dropped requests) and its cached
        admission prefills are dropped for the same reason.  A tenant
        deployed live via ``begin_hot_swap(..., tenant=...)`` gets a
        fresh lane here and starts admitting."""
        # only the swapped tenant's cached prefills go stale: its planes
        # (trace constants) just changed.  Leakage is NOT baked into any
        # closure — it flows as a traced argument (leak_scope) — so the
        # other tenant's buckets stay warm across the window.
        self._prefill_fns.pop(tenant, None)
        # the dropped closures' bucket traces no longer count as "seen":
        # the rebuilt prefill's first trace per bucket is expected, not
        # a re-trace (same reasoning as the fresh decode trace counter)
        self._prefill_seen = {k for k in self._prefill_seen
                              if k[0] != tenant}
        lane = self._lanes.get(tenant)
        if lane is None:
            if tenant not in self._weights:
                # a live-deployed tenant joins the QoS split at weight
                # 1.0: its quota comes from the same proportional rule
                # the construction-time split used (existing lanes keep
                # their quotas — resizing them would drop in-flight
                # cache state), so a weight-1.0 newcomer decodes like
                # any other weight-1.0 lane, not at the full base width
                self._weights[tenant] = 1.0
                total = self.n_slots * len(self._weights)
                wsum = sum(self._weights.values())
                self._slot_quota[tenant] = max(1, round(total / wsum))
            self._lanes[tenant] = self._make_lane(tenant, new_params)
        else:
            lane.params = new_params
            lane.decode = self._make_decode(tenant)
            lane.paused = False
            ex = self.model.executor
            if ex is not None:
                lane.device_cost = ex.device_token_cost(tenant)
        self._set_qos_gauges(tenant, self._lanes[tenant])

    def _note_swap_window(self, tenant: str, lifecycle: str, policy: str,
                          rep: Dict[str, Any]) -> None:
        """Record a completed swap window: one counter bump plus a span
        tagged with its lifecycle (``staged``/``in_place``) and policy
        (``overlapped``/``stop_the_world``)."""
        self.metrics.counter(
            "serve_swap_windows_total",
            help="completed swap windows, by lifecycle and policy").inc(
                tenant=tenant, lifecycle=lifecycle, policy=policy)
        if self._swap_t0 is not None:
            self.tracer.record(
                "swap_window", self._swap_t0, self.tracer.now(),
                tenant=tenant, lifecycle=lifecycle, policy=policy,
                chunks=rep.get("n_chunks"),
                decode_steps_during=rep.get("decode_steps_during_swap"))

    def stop_the_world_swap(self, new_params,
                            tenant: str = "A") -> Dict[str, Any]:
        """Blocking reprogram (the conventional-2-D-array policy): serving
        stalls while every chunk is written, the planes land, and the
        decode step re-traces.  The comparison baseline for the overlapped
        path — same end state, but no tokens flow during the swap.  Like
        the overlapped path, every deploy lands in ``swap_history`` so
        benches and operators see it."""
        if self.model.executor is None:
            raise RuntimeError("hot-swap requires the crossbar backend "
                               "(ModelConfig(backend='crossbar'))")
        if self._swap is not None:
            raise RuntimeError("a hot-swap is already in flight")
        ex = self.model.executor
        t0 = time.perf_counter()
        stats = ex.swap(new_params, tenant=tenant)
        wall = time.perf_counter() - t0
        self._apply_promotion(tenant, new_params)
        rep = overlap_report(ex.cfg, n_grids=ex.n_resident,
                             n_chunks=stats["n_chunks"],
                             batch_size=self.n_slots,
                             decode_steps_during=0, wall_swap_s=wall)
        rep["policy"] = "stop_the_world"
        rep["tenant"] = tenant
        rep["swap_mode"] = stats.get("swap_mode", "staged")
        self.swap_history.append(rep)
        self._swap_t0 = t0
        self._note_swap_window(tenant, rep["swap_mode"],
                               "stop_the_world", rep)
        self._swap_t0 = None
        return stats

    def _advance_swap(self):
        """Program a burst of chunks; promote at the step boundary once
        the staged planes are fully written."""
        sw = self._swap
        if sw is None:
            return
        sw.step()
        if sw.done:
            new_params = sw.promote()
            self._apply_promotion(sw.tenant, new_params)
            rep = sw.report(batch_size=self.n_slots)
            self.swap_history.append(rep)
            self._note_swap_window(sw.tenant, rep["swap_mode"],
                                   "overlapped", rep)
            self._swap = None
            self._swap_t0 = None

    # -- admission (jitted, bucketed prefill) --------------------------------

    def _build_prefill(self, tenant: str) -> Callable:
        """Jitted coalesced admission prefill (batched, one call per
        same-bucket admission group).

        Every admission batch is the lane's full slot width (unused rows
        are zero-padded and discarded), so jax's jit cache keys only on
        the padded bucket length — one trace per bucket, whatever the
        group size.  Each row's first ``m_i = len_i - 1`` prompt tokens
        prefill at the bucket length; the cache fill marker is then
        pinned *per row* to ``m_i`` — pad positions beyond it are
        length-masked, never attended — and one decode step on the
        per-row last real tokens yields every admission token in one
        call.  Bit-exact with per-slot batch-of-1 admissions (and with
        an unpadded prefill of each full prompt): every op on the path
        is row-independent — per-row input-quantization scales, per-row
        cache positions and causal offsets.
        """
        model, max_len = self.model, self.max_len
        ex = model.executor

        def pf(params, tokens_pad, last_tok, m):
            self._prefill_traces += 1       # trace-time only (host state)
            key = (tenant, int(tokens_pad.shape[1]))
            obs.note_jit_trace("prefill", tenant,
                               retrace=key in self._prefill_seen)
            self._prefill_seen.add(key)
            cache = model.init_cache(tokens_pad.shape[0], max_len)
            _, cache = model.prefill(params, {"tokens": tokens_pad}, cache)
            layers = dict(cache["layers"])
            layers["len"] = jnp.broadcast_to(
                m[None, :], layers["len"].shape).astype(layers["len"].dtype)
            logits, cache = model.decode_step(params, last_tok,
                                              dict(cache, layers=layers))
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            return tok, cache

        if ex is None:
            digital = jax.jit(pf)
            return lambda params, tokens_pad, last_tok, m, leak: digital(
                params, tokens_pad, last_tok, m)

        def pf_tenant(params, tokens_pad, last_tok, m, leak):
            # like decode: leak is a traced argument, so an admission
            # inside the swap window carries the live leakage through the
            # SAME compiled bucket that serves steady-state admissions
            with ex.read_tenant(tenant), ex.leak_scope(leak):
                return pf(params, tokens_pad, last_tok, m)

        return jax.jit(pf_tenant)

    def _leak_now(self) -> jax.Array:
        """The leak scalar this step's closures should carry (see
        ``CrossbarExecutor.current_leak_codes``): 0.0 outside a swap
        window, the write plane's leakage inside one."""
        ex = self.model.executor
        return (ex.current_leak_codes() if ex is not None
                else jnp.float32(0.0))

    def _next_bucket_group(self, lane: _Lane,
                           n_free: int) -> List[Request]:
        """Pop the longest FIFO prefix of the lane's queue whose members
        share one padded prefill bucket, capped at the free slot count —
        the unit one coalesced admission call serves."""
        head = lane.queue[0]
        m0 = int(head.prompt.shape[0]) - 1
        if m0 >= self.max_len:
            # the last real token's K/V lands at position m: the prompt
            # must fit strictly inside the cache depth or the write (and
            # every token after it) silently falls off the end
            raise ValueError(f"prompt length {m0 + 1} exceeds the "
                             f"scheduler's max_len {self.max_len}")
        bucket = _prompt_bucket(m0, self.max_len)
        group = [lane.queue.pop(0)]
        while lane.queue and len(group) < n_free:
            m = int(lane.queue[0].prompt.shape[0]) - 1
            if (m >= self.max_len
                    or _prompt_bucket(m, self.max_len) != bucket):
                break
            group.append(lane.queue.pop(0))
        return group

    def _prefill_group(self, lane: _Lane, group: List[Request]):
        """One batched prefill call for a same-bucket admission group
        (batch = the lane's slot width; rows past the group are dummies)."""
        fn = self._prefill_fns.get(lane.tenant)
        if fn is None:
            fn = self._prefill_fns[lane.tenant] = self._build_prefill(
                lane.tenant)
        bucket = _prompt_bucket(int(group[0].prompt.shape[0]) - 1,
                                self.max_len)
        t_admit = self.tracer.now()
        for req in group:
            req.t_admit = t_admit
            req.bucket = bucket
        b = lane.n_slots
        tokens_pad = jnp.zeros((b, bucket), jnp.int32)
        last = jnp.zeros((b, 1), jnp.int32)
        ms = [0] * b
        for j, req in enumerate(group):
            m = int(req.prompt.shape[0]) - 1
            if m:
                tokens_pad = tokens_pad.at[j, :m].set(req.prompt[:m])
            last = last.at[j, 0].set(req.prompt[-1])
            ms[j] = m
        return fn(lane.params, tokens_pad, last,
                  jnp.asarray(ms, jnp.int32), self._leak_now())

    def _admit(self, lane: _Lane, finished: List[Request]) -> None:
        while lane.queue:
            free = [i for i, s in enumerate(lane.slots) if s is None]
            if not free:
                return
            group = self._next_bucket_group(lane, len(free))
            toks, cache_b = self._prefill_group(lane, group)
            for j, req in enumerate(group):
                req.out.append(int(toks[j]))
                req.t_first = self.tracer.now()
                self._account_tokens(lane, 1, "admission")
                if self.metrics.enabled and req.t_submit is not None:
                    self.metrics.histogram(
                        "serve_queue_wait_seconds",
                        help="submit-to-admission wait").observe(
                        req.t_admit - req.t_submit, tenant=lane.tenant)
                    self.metrics.histogram(
                        "serve_ttft_seconds",
                        help="submit to first emitted token").observe(
                        req.t_first - req.t_submit, tenant=lane.tenant)
                if len(req.out) >= req.max_new:
                    # the admission token already met the budget: finish
                    # here and keep the slot free for the next request —
                    # no decode step burned, no extra token emitted
                    req.t_done = req.t_first
                    self._finish_request(lane, req)
                    finished.append(req)
                    continue
                slot = free.pop(0)
                # transformer-family caches are (L, B, ...): batch axis 1.
                # (The scheduler targets decoder LMs; stateful families
                # use greedy_generate / custom loops.)
                lane.cache = jax.tree.map(
                    lambda full, newc, j=j, slot=slot:
                    jax.lax.dynamic_update_slice_in_dim(
                        full,
                        jax.lax.dynamic_slice_in_dim(
                            newc, j, 1, axis=1).astype(full.dtype),
                        slot, axis=1),
                    lane.cache, cache_b)
                lane.tokens = lane.tokens.at[slot, 0].set(toks[j])
                lane.slots[slot] = req

    def step(self) -> List[Request]:
        """One decode step for every tenant's active slots; returns
        finished requests (across tenants).

        An in-flight hot-swap advances first — plane chunks program
        strictly between decode steps, and promotion happens here at the
        boundary, so every decode call reads one consistent plane set.
        A lane whose planes are the write target stays paused for the
        window; the other tenant's lane decodes through it."""
        self._advance_swap()
        finished: List[Request] = []
        decoded = False
        leak = self._leak_now()
        for t in self._lane_order():
            lane = self._lanes[t]
            if lane.paused:
                continue
            self._admit(lane, finished)
            if all(s is None for s in lane.slots):
                continue
            t0 = self.tracer.now()
            lane.tokens, lane.cache = lane.decode(
                lane.params, lane.tokens, lane.cache, leak)
            decoded = True
            n_emitted = 0
            for i, req in enumerate(lane.slots):
                if req is None:
                    continue
                req.out.append(int(lane.tokens[i, 0]))
                n_emitted += 1
                if len(req.out) >= req.max_new:
                    req.t_done = self.tracer.now()
                    self._finish_request(lane, req)
                    finished.append(req)
                    lane.slots[i] = None
            self._account_tokens(lane, n_emitted, "decode")
            if self.metrics.enabled and n_emitted:
                # every slot's token materialized in this one batched
                # step, so the per-token latency IS the step wall time —
                # observed once per emitted token so histogram mass
                # weights by tokens, not steps
                dt = self.tracer.now() - t0
                h = self.metrics.histogram(
                    "serve_token_latency_seconds",
                    help="wall time of the decode step that produced "
                         "each token")
                for _ in range(n_emitted):
                    h.observe(dt, tenant=lane.tenant)
        if decoded and self._swap is not None:
            self._swap.note_decode_step()
        return finished

    def mode_report(self, tenant: Optional[str] = None) -> Dict[str, Any]:
        """Per-weight read-mode choices and their IR-drop economics for
        a tenant's plane set (``CrossbarExecutor.mode_report``) — the
        operator-facing view of what ``mode_policy`` decided — plus a
        ``traffic`` block turning the static per-mode claims into live
        traffic-weighted figures: tokens served and the modeled device
        read time / energy / pJ-per-token accumulated per read mode.

        ``tenant`` defaults to the scheduler's anchor tenant (the
        executor's first plane, what ``params`` serves); asking for a
        tenant this scheduler has no lane for is a ``KeyError`` naming
        the resident tenants.
        """
        ex = self.model.executor
        if ex is None:
            raise RuntimeError(
                "mode_report requires the crossbar backend "
                "(ModelConfig(backend='crossbar'))")
        if tenant is None:
            tenant = ex.anchor
        lane = self._lanes.get(tenant)
        if lane is None:
            raise KeyError(
                f"no lane for tenant {tenant!r}: this scheduler serves "
                f"tenants {self.tenants}")
        rep = ex.mode_report(tenant=tenant)
        tokens = lane.tokens_served
        modes: Dict[str, Any] = {}
        for mode, cost in sorted((lane.device_cost or {}).items()):
            if self.metrics.enabled:
                read_s = self.metrics.total(
                    "serve_device_read_seconds_total",
                    tenant=tenant, mode=mode)
                energy = self.metrics.total(
                    "serve_device_energy_joules_total",
                    tenant=tenant, mode=mode)
            else:
                # metrics off: the per-token cost is constant, so the
                # accumulated figure is exactly cost * tokens
                read_s = cost["read_s"] * tokens
                energy = cost["energy_j"] * tokens
            modes[mode] = {
                "device_read_s": read_s,
                "energy_j": energy,
                "pj_per_token": (energy / tokens * 1e12
                                 if tokens else 0.0),
            }
        rep["traffic"] = {"tokens_served": tokens, "modes": modes}
        return rep

    def qos_report(self) -> Dict[str, Dict[str, Any]]:
        """Per-tenant QoS accounting in ``swap_history`` style: the
        configured weight, the slot quota the weighted split granted,
        and the served-token count/share so far (admission + decode
        tokens) — the figure the weights are supposed to shift.

        A view over the scheduler registry when telemetry is on
        (``serve_qos_*`` gauges + ``serve_tokens_total``); the lane
        fields remain authoritative with telemetry off.
        """
        if self.metrics.enabled:
            served = {t: int(self.metrics.total("serve_tokens_total",
                                                tenant=t))
                      for t in self._lanes}
        else:
            served = {t: lane.tokens_served
                      for t, lane in self._lanes.items()}
        total = sum(served.values())
        return {t: {"weight": lane.weight,
                    "slots": lane.n_slots,
                    "tokens_served": served[t],
                    "token_share": (served[t] / total if total else 0.0)}
                for t, lane in sorted(self._lanes.items())}
