"""Batched serving runtime: prefill/decode step builders + a simple
continuous-batching scheduler for the examples.

serve_step contract (what the dry-run lowers for decode cells): one new
token for every sequence in the batch against a seq_len-deep KV cache,
cache donated, greedy or temperature sampling on-device.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.serve.hotswap import HotSwapper


def make_prefill_step(model: Model):
    def prefill_step(params, batch, cache):
        logits, cache = model.prefill(params, batch, cache)
        next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(
            jnp.int32)
        return next_tok, cache

    return prefill_step


def make_decode_step(model: Model, temperature: float = 0.0):
    def decode_step(params, tokens, cache, key=None):
        logits, cache = model.decode_step(params, tokens, cache)
        lg = logits[:, -1].astype(jnp.float32)
        if temperature > 0.0 and key is not None:
            nxt = jax.random.categorical(key, lg / temperature, axis=-1)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        return nxt[:, None].astype(jnp.int32), cache

    return decode_step


def greedy_generate(model: Model, params, batch, max_new: int,
                    max_len: Optional[int] = None):
    """Jit-friendly generation loop used by examples/serve_batch.py."""
    b = batch["tokens"].shape[0]
    s = batch["tokens"].shape[1]
    max_len = max_len or (s + max_new)
    if model.cfg.family == "encdec":
        cache = model.init_cache(b, max_len, src_len=s)
    else:
        cache = model.init_cache(b, max_len)
    prefill = make_prefill_step(model)
    decode = make_decode_step(model)
    tok, cache = prefill(params, batch, cache)
    toks = [tok]

    def body(carry, _):
        tok, cache = carry
        tok, cache = decode(params, tok, cache)
        return (tok, cache), tok

    (_, _), rest = jax.lax.scan(body, (tok, cache), None,
                                length=max_new - 1)
    return jnp.concatenate([tok[:, None], rest.swapaxes(0, 1)],
                           axis=1)[:, :, 0]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jax.Array          # (S,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchScheduler:
    """Minimal continuous-batching scheduler (slot-based).

    Maintains a fixed decode batch of ``n_slots``; free slots are refilled
    from the queue by running a fresh prefill for that slot (production
    systems fuse prefill into the batch; here prefill is per-admission,
    which keeps the decode step shape static — the property the dry-run
    cells exercise)."""

    def __init__(self, model: Model, params, n_slots: int, max_len: int):
        self.model, self.params = model, params
        self.n_slots, self.max_len = n_slots, max_len
        self.queue: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.cache = model.init_cache(n_slots, max_len)
        self.tokens = jnp.zeros((n_slots, 1), jnp.int32)
        executor = getattr(model, "executor", None)
        if executor is not None:
            # crossbar backend: program weights onto the resident tiles
            # ONCE at scheduler construction — the jitted decode step below
            # traces against already-programmed tiles (program-at-load,
            # read-at-inference)
            executor.ensure_programmed(params)
        self._decode = jax.jit(make_decode_step(model), donate_argnums=(2,))
        self._swap: Optional[HotSwapper] = None
        self.swap_history: List[Dict[str, Any]] = []

    def submit(self, req: Request):
        self.queue.append(req)

    # -- deep-net-mode hot-swap (serve reads while shadow planes program) ----

    def begin_hot_swap(self, new_params, chunks_per_step: int = 8
                       ) -> HotSwapper:
        """Start programming ``new_params`` onto the write-shadow planes.

        Chunks are written between decode steps (inside :meth:`step`);
        when every chunk lands, the planes flip atomically at a step
        boundary and subsequent tokens come from the new weights — no
        request is dropped and no decode step reads mixed planes.
        """
        if self.model.executor is None:
            raise RuntimeError("hot-swap requires the crossbar backend "
                               "(ModelConfig(backend='crossbar'))")
        if self._swap is not None:
            raise RuntimeError("a hot-swap is already in flight")
        self._swap = HotSwapper(self.model.executor, new_params,
                                chunks_per_step=chunks_per_step)
        return self._swap

    @property
    def swap_in_flight(self) -> bool:
        return self._swap is not None

    def stop_the_world_swap(self, new_params) -> Dict[str, Any]:
        """Blocking reprogram (the conventional-2-D-array policy): serving
        stalls while every chunk is written, the planes flip, and the
        decode step re-traces.  The comparison baseline for the overlapped
        path — same end state, but no tokens flow during the swap."""
        if self.model.executor is None:
            raise RuntimeError("hot-swap requires the crossbar backend "
                               "(ModelConfig(backend='crossbar'))")
        if self._swap is not None:
            raise RuntimeError("a hot-swap is already in flight")
        stats = self.model.executor.swap(new_params)
        self.params = new_params
        self._decode = jax.jit(make_decode_step(self.model),
                               donate_argnums=(2,))
        return stats

    def _advance_swap(self):
        """Program a burst of chunks; promote at the step boundary once
        the shadow planes are fully written."""
        sw = self._swap
        if sw is None:
            return
        sw.step()
        if sw.done:
            self.params = sw.promote()
            # resident planes are compile-time constants of the jitted
            # decode step (program-at-load); the flip invalidates that
            # closure, so rebuild it — one re-trace, zero dropped requests
            self._decode = jax.jit(make_decode_step(self.model),
                                   donate_argnums=(2,))
            self.swap_history.append(sw.report(batch_size=self.n_slots))
            self._swap = None

    def _admit(self):
        for slot, cur in enumerate(self.slots):
            if cur is None and self.queue:
                req = self.queue.pop(0)
                # per-slot prefill (batch of 1), then splice into the cache
                c1 = self.model.init_cache(1, self.max_len)
                lg, c1 = self.model.prefill(
                    self.params, {"tokens": req.prompt[None]}, c1)
                tok = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)
                req.out.append(int(tok[0]))
                # transformer-family caches are (L, B, ...): batch axis 1.
                # (The scheduler targets decoder LMs; stateful families use
                # greedy_generate / custom loops.)
                self.cache = jax.tree.map(
                    lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                        full, one.astype(full.dtype), slot, axis=1),
                    self.cache, c1)
                self.tokens = self.tokens.at[slot, 0].set(tok[0])
                self.slots[slot] = req

    def step(self) -> List[Request]:
        """One decode step for all active slots; returns finished requests.

        An in-flight hot-swap advances first — shadow-plane chunks program
        strictly between decode steps, and promotion happens here at the
        boundary, so every decode call reads one consistent plane set."""
        self._advance_swap()
        self._admit()
        if all(s is None for s in self.slots):
            return []
        self.tokens, self.cache = self._decode(
            self.params, self.tokens, self.cache)
        if self._swap is not None:
            self._swap.note_decode_step()
        finished = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            req.out.append(int(self.tokens[i, 0]))
            if len(req.out) >= req.max_new:
                req.done = True
                finished.append(req)
                self.slots[i] = None
        return finished
