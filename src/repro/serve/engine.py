"""Batched serving runtime: prefill/decode step builders + a simple
continuous-batching scheduler for the examples.

serve_step contract (what the dry-run lowers for decode cells): one new
token for every sequence in the batch against a seq_len-deep KV cache,
cache donated, greedy or temperature sampling on-device.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.serve.hotswap import HotSwapper, overlap_report


def make_prefill_step(model: Model):
    def prefill_step(params, batch, cache):
        logits, cache = model.prefill(params, batch, cache)
        next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(
            jnp.int32)
        return next_tok, cache

    return prefill_step


def make_decode_step(model: Model, temperature: float = 0.0):
    def decode_step(params, tokens, cache, key=None):
        logits, cache = model.decode_step(params, tokens, cache)
        lg = logits[:, -1].astype(jnp.float32)
        if temperature > 0.0 and key is not None:
            nxt = jax.random.categorical(key, lg / temperature, axis=-1)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        return nxt[:, None].astype(jnp.int32), cache

    return decode_step


def greedy_generate(model: Model, params, batch, max_new: int,
                    max_len: Optional[int] = None):
    """Jit-friendly generation loop used by examples/serve_batch.py."""
    b = batch["tokens"].shape[0]
    s = batch["tokens"].shape[1]
    max_len = max_len or (s + max_new)
    if model.cfg.family == "encdec":
        cache = model.init_cache(b, max_len, src_len=s)
    else:
        cache = model.init_cache(b, max_len)
    prefill = make_prefill_step(model)
    decode = make_decode_step(model)
    tok, cache = prefill(params, batch, cache)
    toks = [tok]

    def body(carry, _):
        tok, cache = carry
        tok, cache = decode(params, tok, cache)
        return (tok, cache), tok

    (_, _), rest = jax.lax.scan(body, (tok, cache), None,
                                length=max_new - 1)
    return jnp.concatenate([tok[:, None], rest.swapaxes(0, 1)],
                           axis=1)[:, :, 0]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jax.Array          # (S,) int32
    max_new: int
    model_id: str = "A"        # tenant whose checkpoint serves this request
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _prompt_bucket(m: int, max_len: int) -> int:
    """Padded prefill length for an ``m``-token prompt slice: the next
    power of two (>= 8), capped at the cache depth — the jit cache key,
    so admissions re-trace per *bucket*, not per prompt length."""
    bucket = 8 if m <= 8 else 1 << (m - 1).bit_length()
    return min(bucket, max_len)


@dataclasses.dataclass
class _Lane:
    """One tenant's serving state: a fixed slot batch against one plane
    set, with its own jitted decode closure (the tiles it traced are that
    tenant's planes — trace constants, like params sharding)."""
    tenant: str
    params: Any
    slots: List[Optional[Request]]
    cache: Any
    tokens: jax.Array
    queue: List[Request]
    decode: Callable
    # True while this tenant's own planes are mid-write (in-place swap):
    # its reads pause — admissions hold, in-flight slots freeze — and
    # resume on the promoted weights at the swap boundary
    paused: bool = False


class BatchScheduler:
    """Minimal continuous-batching scheduler (slot-based, multi-tenant).

    Maintains a fixed decode batch of ``n_slots`` per tenant; free slots
    are refilled from that tenant's queue by running a prefill for the
    slot (production systems fuse prefill into the batch; here prefill is
    per-admission, which keeps the decode step shape static — the
    property the dry-run cells exercise).  Admission prefills are jitted
    and cached per padded prompt-length bucket, so steady-state admission
    is a cache hit, not a re-trace.

    Passing ``tenants={"A": params_a, "B": params_b}`` multiplexes two
    checkpoints from the two tile planes of ONE crossbar executor: each
    tenant gets its own slot partition, cache, and jitted decode closure
    (traced under ``executor.read_tenant(t)`` so the closure's trace
    constants are that tenant's planes), and every ``step`` interleaves
    both token streams.  Requests route by ``Request.model_id``.
    """

    def __init__(self, model: Model, params, n_slots: int, max_len: int,
                 tenants: Optional[Dict[str, Any]] = None):
        self.model = model
        self.n_slots, self.max_len = n_slots, max_len
        tenant_params = dict(tenants) if tenants else {"A": params}
        if "A" not in tenant_params:
            raise ValueError("tenant 'A' is required (it anchors the "
                             "plane pairs)")
        executor = getattr(model, "executor", None)
        if len(tenant_params) > 1 and executor is None:
            raise RuntimeError(
                "multi-tenant multiplexing serves each checkpoint from "
                "one tile plane of a stacked pair; it requires the "
                "crossbar backend (ModelConfig(backend='crossbar'))")
        if executor is not None:
            # crossbar backend: program each tenant's weights onto its
            # plane set ONCE at scheduler construction — the jitted decode
            # closures below trace against already-programmed tiles
            # (program-at-load, read-at-inference)
            for t in sorted(tenant_params):
                with executor.read_tenant(t):
                    executor.ensure_programmed(tenant_params[t])
        self._lanes: Dict[str, _Lane] = {
            t: self._make_lane(t, p) for t, p in sorted(tenant_params.items())}
        # jitted admission prefill per tenant; jax's jit cache keys on the
        # padded token shape, i.e. one trace per prompt-length bucket
        self._prefill_fns: Dict[str, Callable] = {}
        self._prefill_traces = 0     # bumped at trace time (tests pin it)
        self._swap: Optional[HotSwapper] = None
        self.swap_history: List[Dict[str, Any]] = []

    # -- lanes ---------------------------------------------------------------

    def _make_lane(self, tenant: str, params) -> _Lane:
        return _Lane(tenant=tenant, params=params,
                     slots=[None] * self.n_slots,
                     cache=self.model.init_cache(self.n_slots, self.max_len),
                     tokens=jnp.zeros((self.n_slots, 1), jnp.int32),
                     queue=[], decode=self._make_decode(tenant))

    def _make_decode(self, tenant: str) -> Callable:
        """Jitted decode closure ``(params, tokens, cache, leak) -> ...``.

        ``leak`` is the write-plane leakage of an in-flight hot-swap as a
        *traced* scalar: the same compiled step serves leak = 0.0 in
        steady state and the live value during an overlap window — no
        re-trace at window boundaries, and (with ``cfg.use_kernel``) the
        Pallas kernel applies it pre-ADC, so overlap decode never falls
        back to the reference scan."""
        base = make_decode_step(self.model)
        ex = self.model.executor
        if ex is None:
            digital = jax.jit(base, donate_argnums=(2,))
            return lambda params, tokens, cache, leak: digital(
                params, tokens, cache)

        def tenant_step(params, tokens, cache, leak):
            with ex.read_tenant(tenant), ex.leak_scope(leak):
                return base(params, tokens, cache)

        return jax.jit(tenant_step, donate_argnums=(2,))

    @property
    def params(self):
        """Tenant A's serving params (single-tenant compatibility)."""
        return self._lanes["A"].params

    @property
    def tenants(self) -> List[str]:
        return sorted(self._lanes)

    @property
    def queue(self) -> List[Request]:
        """Tenant A's queue (single-tenant compatibility)."""
        return self._lanes["A"].queue

    def submit(self, req: Request):
        lane = self._lanes.get(req.model_id)
        if lane is None:
            raise ValueError(
                f"request {req.rid} routes to unknown tenant "
                f"{req.model_id!r}; serving {self.tenants}")
        lane.queue.append(req)

    # -- deep-net-mode hot-swap (serve reads while shadow planes program) ----

    def begin_hot_swap(self, new_params, chunks_per_step: int = 8,
                       tenant: str = "A") -> HotSwapper:
        """Start programming ``new_params`` onto a write plane set.

        Chunks are written between decode steps (inside :meth:`step`);
        when every chunk lands, the planes land atomically at a step
        boundary and subsequent tokens come from the new weights — no
        request is dropped and no decode step reads mixed planes.

        ``tenant="A"`` (default) writes the free shadow planes while A
        keeps decoding.  ``tenant="B"`` targets the twin plane set: B's
        lane pauses for the write window (its planes are the write
        target) while tenant A's traffic flows uninterrupted — the same
        read-under-write overlap, re-purposed for multi-tenancy.  A
        paused lane's in-flight requests freeze in place and resume on
        the promoted weights, exactly like single-tenant requests that
        span a flip.
        """
        if self.model.executor is None:
            raise RuntimeError("hot-swap requires the crossbar backend "
                               "(ModelConfig(backend='crossbar'))")
        if self._swap is not None:
            raise RuntimeError("a hot-swap is already in flight")
        self._swap = HotSwapper(self.model.executor, new_params,
                                chunks_per_step=chunks_per_step,
                                tenant=tenant)
        lane = self._lanes.get(tenant)
        if lane is not None and self._swap.plan.in_place:
            lane.paused = True
        return self._swap

    @property
    def swap_in_flight(self) -> bool:
        return self._swap is not None

    def _apply_promotion(self, tenant: str, new_params) -> None:
        """Land promoted params on a lane: resident planes are trace
        constants of the jitted closures, so the tenant's decode closure
        rebuilds (one re-trace, zero dropped requests) and its cached
        admission prefills are dropped for the same reason.  A tenant
        deployed live via ``begin_hot_swap(..., tenant="B")`` gets a
        fresh lane here and starts admitting."""
        # only the swapped tenant's cached prefills go stale: its planes
        # (trace constants) just changed.  Leakage is NOT baked into any
        # closure — it flows as a traced argument (leak_scope) — so the
        # other tenant's buckets stay warm across the window.
        self._prefill_fns.pop(tenant, None)
        lane = self._lanes.get(tenant)
        if lane is None:
            self._lanes[tenant] = self._make_lane(tenant, new_params)
        else:
            lane.params = new_params
            lane.decode = self._make_decode(tenant)
            lane.paused = False

    def stop_the_world_swap(self, new_params,
                            tenant: str = "A") -> Dict[str, Any]:
        """Blocking reprogram (the conventional-2-D-array policy): serving
        stalls while every chunk is written, the planes land, and the
        decode step re-traces.  The comparison baseline for the overlapped
        path — same end state, but no tokens flow during the swap.  Like
        the overlapped path, every deploy lands in ``swap_history`` so
        benches and operators see it."""
        if self.model.executor is None:
            raise RuntimeError("hot-swap requires the crossbar backend "
                               "(ModelConfig(backend='crossbar'))")
        if self._swap is not None:
            raise RuntimeError("a hot-swap is already in flight")
        ex = self.model.executor
        t0 = time.perf_counter()
        stats = ex.swap(new_params, tenant=tenant)
        wall = time.perf_counter() - t0
        self._apply_promotion(tenant, new_params)
        rep = overlap_report(ex.cfg, n_grids=ex.n_resident,
                             n_chunks=stats["n_chunks"],
                             batch_size=self.n_slots,
                             decode_steps_during=0, wall_swap_s=wall)
        rep["policy"] = "stop_the_world"
        rep["tenant"] = tenant
        self.swap_history.append(rep)
        return stats

    def _advance_swap(self):
        """Program a burst of chunks; promote at the step boundary once
        the staged planes are fully written."""
        sw = self._swap
        if sw is None:
            return
        sw.step()
        if sw.done:
            new_params = sw.promote()
            self._apply_promotion(sw.tenant, new_params)
            self.swap_history.append(sw.report(batch_size=self.n_slots))
            self._swap = None

    # -- admission (jitted, bucketed prefill) --------------------------------

    def _build_prefill(self, tenant: str) -> Callable:
        """Jitted per-slot admission prefill.

        The prompt's first ``m = len-1`` tokens prefill at a padded
        bucket length (jax's jit cache keys on that shape, so admissions
        re-trace per bucket, not per prompt length); the cache fill
        marker is then pinned to ``m`` — pad positions beyond it are
        length-masked, never attended — and one decode step on the last
        real token yields the admission token, bit-exact with an unpadded
        prefill of the full prompt.
        """
        model, max_len = self.model, self.max_len
        ex = model.executor

        def pf(params, tokens_pad, last_tok, m):
            self._prefill_traces += 1       # trace-time only (host state)
            cache = model.init_cache(1, max_len)
            _, cache = model.prefill(params, {"tokens": tokens_pad}, cache)
            layers = dict(cache["layers"])
            layers["len"] = jnp.full_like(layers["len"], m)
            logits, cache = model.decode_step(params, last_tok,
                                              dict(cache, layers=layers))
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            return tok, cache

        if ex is None:
            digital = jax.jit(pf)
            return lambda params, tokens_pad, last_tok, m, leak: digital(
                params, tokens_pad, last_tok, m)

        def pf_tenant(params, tokens_pad, last_tok, m, leak):
            # like decode: leak is a traced argument, so an admission
            # inside the swap window carries the live leakage through the
            # SAME compiled bucket that serves steady-state admissions
            with ex.read_tenant(tenant), ex.leak_scope(leak):
                return pf(params, tokens_pad, last_tok, m)

        return jax.jit(pf_tenant)

    def _leak_now(self) -> jax.Array:
        """The leak scalar this step's closures should carry (see
        ``CrossbarExecutor.current_leak_codes``): 0.0 outside a swap
        window, the write plane's leakage inside one."""
        ex = self.model.executor
        return (ex.current_leak_codes() if ex is not None
                else jnp.float32(0.0))

    def _prefill(self, lane: _Lane, prompt: jax.Array):
        fn = self._prefill_fns.get(lane.tenant)
        if fn is None:
            fn = self._prefill_fns[lane.tenant] = self._build_prefill(
                lane.tenant)
        m = int(prompt.shape[0]) - 1
        if m >= self.max_len:
            # the last real token's K/V lands at position m: the prompt
            # must fit strictly inside the cache depth or the write (and
            # every token after it) silently falls off the end
            raise ValueError(f"prompt length {m + 1} exceeds the "
                             f"scheduler's max_len {self.max_len}")
        bucket = _prompt_bucket(m, self.max_len)
        pad = jnp.zeros((1, bucket), jnp.int32)
        if m:
            pad = pad.at[0, :m].set(prompt[:m])
        return fn(lane.params, pad, prompt[None, -1:].astype(jnp.int32),
                  jnp.int32(m), self._leak_now())

    def _admit(self, lane: _Lane, finished: List[Request]) -> None:
        for slot in range(self.n_slots):
            while lane.slots[slot] is None and lane.queue:
                req = lane.queue.pop(0)
                # per-slot prefill (batch of 1), then splice into the cache
                tok, c1 = self._prefill(lane, req.prompt)
                req.out.append(int(tok[0]))
                if len(req.out) >= req.max_new:
                    # the admission token already met the budget: finish
                    # here and keep the slot free for the next request —
                    # no decode step burned, no extra token emitted
                    req.done = True
                    finished.append(req)
                    continue
                # transformer-family caches are (L, B, ...): batch axis 1.
                # (The scheduler targets decoder LMs; stateful families use
                # greedy_generate / custom loops.)
                lane.cache = jax.tree.map(
                    lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                        full, one.astype(full.dtype), slot, axis=1),
                    lane.cache, c1)
                lane.tokens = lane.tokens.at[slot, 0].set(tok[0])
                lane.slots[slot] = req

    def step(self) -> List[Request]:
        """One decode step for every tenant's active slots; returns
        finished requests (across tenants).

        An in-flight hot-swap advances first — plane chunks program
        strictly between decode steps, and promotion happens here at the
        boundary, so every decode call reads one consistent plane set.
        A lane whose planes are the write target stays paused for the
        window; the other tenant's lane decodes through it."""
        self._advance_swap()
        finished: List[Request] = []
        decoded = False
        leak = self._leak_now()
        for t in sorted(self._lanes):
            lane = self._lanes[t]
            if lane.paused:
                continue
            self._admit(lane, finished)
            if all(s is None for s in lane.slots):
                continue
            lane.tokens, lane.cache = lane.decode(
                lane.params, lane.tokens, lane.cache, leak)
            decoded = True
            for i, req in enumerate(lane.slots):
                if req is None:
                    continue
                req.out.append(int(lane.tokens[i, 0]))
                if len(req.out) >= req.max_new:
                    req.done = True
                    finished.append(req)
                    lane.slots[i] = None
        if decoded and self._swap is not None:
            self._swap.note_decode_step()
        return finished
