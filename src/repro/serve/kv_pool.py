"""Block-paged KV pool: the host-side page allocator behind paged serving.

One pool backs one tenant lane.  Physical pages live in the lane's cache
arrays as ``(n_pages + 1, page_size, kv_heads, head_dim)`` — index 0 is a
reserved **null page** that is never allocated: unwritten page-table
entries point at it, so pad/out-of-range scatter writes land there
harmlessly and gathers through an unallocated entry read zeros that the
length mask excludes exactly.

Allocation is whole-lifetime: a request's full page need
(``ceil(min(prompt_len + max_new - 1, max_len) / page_size)``) is claimed
from the free list at admission and reclaimed in one shot at completion.
That keeps the conservation invariant trivial and exact at every step:

    pages_in_use + pages_free == n_pages

``budget`` is the QoS view of the same pool: a logical cap (<= the
physical ``n_pages``) that ``BatchScheduler.set_weights`` re-splits at
step boundaries.  Shrinking the budget below current usage only blocks
new admissions; resident pages drain as requests complete.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

NULL_PAGE = 0


class PagedKVPool:
    """Free-list page allocator with per-row (per-slot) page tables."""

    def __init__(self, n_pages: int, page_size: int, max_len: int,
                 n_rows: int):
        if page_size <= 0:
            raise ValueError(f"page_size must be > 0, got {page_size}")
        if max_len % page_size:
            raise ValueError(
                f"page_size {page_size} must divide max_len {max_len}: the "
                f"gathered logical view must be exactly max_len wide for "
                f"bit-exactness with the dense cache path")
        if n_pages < 1:
            raise ValueError(f"pool needs >= 1 page, got {n_pages}")
        self.page_size = page_size
        self.max_len = max_len
        self.n_rows = n_rows
        self.n_pages = n_pages
        self.pages_per_seq = max_len // page_size
        self._budget = n_pages
        # physical ids n_pages..1 so pop() hands out low ids first;
        # id 0 is the null page and never enters the free list
        self._free: List[int] = list(range(n_pages, 0, -1))
        self._rows: List[List[int]] = [[] for _ in range(n_rows)]

    # -- sizing ---------------------------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        """Pages covering ``n_tokens`` cache positions (>= 1)."""
        return max(1, -(-min(n_tokens, self.max_len) // self.page_size))

    @property
    def pages_in_use(self) -> int:
        return sum(len(r) for r in self._rows)

    @property
    def pages_free(self) -> int:
        return len(self._free)

    @property
    def budget(self) -> int:
        return self._budget

    def set_budget(self, n: int) -> None:
        """Re-cap the QoS budget (clamped to [1, n_pages])."""
        self._budget = max(1, min(int(n), self.n_pages))

    def conservation_ok(self) -> bool:
        """The exit-gate invariant: every page is either owned or free."""
        return self.pages_in_use + self.pages_free == self.n_pages

    # -- alloc / free ---------------------------------------------------------

    def can_alloc(self, n_tokens: int) -> bool:
        need = self.pages_for(n_tokens)
        return (need <= self.pages_free
                and self.pages_in_use + need <= self._budget)

    def alloc(self, row: int, n_tokens: int) -> List[int]:
        """Claim all pages for a sequence of ``n_tokens`` onto ``row``.

        Returns the physical page ids (logical order).  Raises if the row
        already owns pages or the pool/budget cannot satisfy the request —
        callers gate on :meth:`can_alloc` (admission backpressure; the
        scheduler queues rather than drops).
        """
        if self._rows[row]:
            raise RuntimeError(f"row {row} already owns pages "
                               f"{self._rows[row]}")
        if not self.can_alloc(n_tokens):
            raise RuntimeError(
                f"pool cannot allocate {self.pages_for(n_tokens)} pages "
                f"(free={self.pages_free}, in_use={self.pages_in_use}, "
                f"budget={self._budget})")
        pages = [self._free.pop() for _ in range(self.pages_for(n_tokens))]
        self._rows[row] = pages
        return pages

    def free_row(self, row: int) -> List[int]:
        """Reclaim a completed row's pages back onto the free list."""
        pages = self._rows[row]
        self._rows[row] = []
        self._free.extend(reversed(pages))
        return pages

    # -- table views ----------------------------------------------------------

    def table_row(self, row: int) -> np.ndarray:
        """(pages_per_seq,) int32 physical ids; NULL_PAGE past the end."""
        out = np.full((self.pages_per_seq,), NULL_PAGE, np.int32)
        pages = self._rows[row]
        out[:len(pages)] = pages
        return out

    def table(self) -> np.ndarray:
        """(n_rows, pages_per_seq) int32 page table for the whole lane."""
        return np.stack([self.table_row(r) for r in range(self.n_rows)])

    def report(self) -> dict:
        return {"n_pages": self.n_pages, "page_size": self.page_size,
                "pages_per_seq": self.pages_per_seq,
                "pages_in_use": self.pages_in_use,
                "pages_free": self.pages_free, "budget": self._budget,
                "conservation_ok": self.conservation_ok()}


def default_pool_pages(n_rows: int, max_len: int, page_size: int,
                       kv_pages: Optional[int] = None) -> int:
    """Pool sizing: ``kv_pages`` when the operator set one, else enough
    for every row to hold a full-depth sequence (never blocks)."""
    if kv_pages is not None:
        return kv_pages
    return n_rows * (max_len // page_size)
