"""Block-paged KV pool: refcounted, prefix-shared, copy-on-write pages.

One pool backs one tenant lane.  Physical pages live in the lane's cache
arrays as ``(n_pages + 1, page_size, kv_heads, head_dim)`` — index 0 is a
reserved **null page** that is never allocated: unwritten page-table
entries point at it, so pad/out-of-range scatter writes land there
harmlessly and gathers through an unallocated entry read zeros that the
length mask excludes exactly.

Allocation is whole-lifetime: a request's full page need
(``pages_for(min(prompt_len + max_new - 1, max_len))``) is claimed at
admission and reclaimed in one shot at completion.  Pages are
**refcounted**: several rows' tables may alias one physical page (prefix
sharing), and a page returns to the free list only when its last
reference drops.  The conservation invariant is refcount-aware and holds
exactly at every step:

    pages_in_use + pages_free == n_pages          (distinct pages)
    sum(refcounts)            == total page-table entries

Prefix sharing: when a row's prompt finishes prefill, the pool indexes
its fully-written whole pages under a **rolling chain key**
``(parent_phys, page_tokens)`` — the physical id of the page's
predecessor in the chain (-1 at the root) plus the ``page_size`` tokens
the page itself covers.  The parent id was itself indexed under *its*
whole chain, so by induction a hit still pins the page's K/V content
byte-for-byte to the full cumulative token prefix (cache content is a
deterministic function of the token prefix) — but each key hashes only
``page_size`` tokens, making prompt indexing O(plen) total where the
old cumulative-tuple keys (page j keyed on ``T[:(j+1)*page_size]``)
cost O(plen²).  ``index_ops`` counts token positions hashed;
tests/test_kv_pool.py pins the linear scaling.  A later admission with
a matching head aliases indexed pages instead of recomputing them.
Because chain keys embed a *recyclable* physical id, every key that
names a page as its parent is also registered for cleanup under that
parent: when the parent's last reference drops, those keys leave the
index with it, so a recycled id can never satisfy a stale
``(parent, page_tokens)`` lookup and alias K/V computed under a
different prefix (tests/test_kv_pool.py pins the regression).
Sharing always stops at least one token short of the prompt end (the
final token must flow through the model to produce the first output
logits), and a sub-page extension match (the next page's tokens agree
for ``r < page_size`` positions) may alias one partial page.

Copy-on-write: a row that would write its *own* tokens into a shared
page (the partial-page cases above) privatizes it first — the pool
claims a fresh page, drops one reference on the shared original, and
hands the caller a ``(src, dst)`` device-copy obligation.  After COW the
two rows' tables never alias that logical position again.

``budget`` is the QoS view of the same pool: a logical cap (<= the
physical ``n_pages``) that ``BatchScheduler.set_weights`` re-splits at
step boundaries.  Shrinking the budget below current usage only blocks
new admissions; resident pages drain as requests complete.  The budget
gates *admission plans* (``can_alloc`` / ``can_alloc_shared``) —
mid-life COW is accounted in the plan that admitted the row, never
re-gated.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

NULL_PAGE = 0


class PagedKVPool:
    """Refcounted free-list page allocator with per-row (per-slot) page
    tables and a whole-page prefix index for cross-request sharing."""

    def __init__(self, n_pages: int, page_size: int, max_len: int,
                 n_rows: int):
        if page_size <= 0:
            raise ValueError(f"page_size must be > 0, got {page_size}")
        if max_len % page_size:
            raise ValueError(
                f"page_size {page_size} must divide max_len {max_len}: the "
                f"gathered logical view must be exactly max_len wide for "
                f"bit-exactness with the dense cache path")
        if n_pages < 1:
            raise ValueError(f"pool needs >= 1 page, got {n_pages}")
        self.page_size = page_size
        self.max_len = max_len
        self.n_rows = n_rows
        self.n_pages = n_pages
        self.pages_per_seq = max_len // page_size
        self._budget = n_pages
        # physical ids n_pages..1 so pop() hands out low ids first;
        # id 0 is the null page and never enters the free list
        self._free: List[int] = list(range(n_pages, 0, -1))
        self._rows: List[List[int]] = [[] for _ in range(n_rows)]
        # refcounts for every allocated physical page (absent == free)
        self._ref: Dict[int, int] = {}
        # prefix index, rolling chain keys: (parent_phys | -1, the page's
        # own page_size tokens) -> physical page.  The parent id stands
        # in for the whole chain before the page (it was indexed under
        # ITS chain), so a hit pins content exactly while hashing O(ps)
        # tokens per key instead of the whole cumulative prefix; _ext
        # maps parent_phys | -1 -> (phys, page tokens) of the first page
        # registered after it, for sub-page extension matches
        self._prefix: Dict[Tuple[int, Tuple[int, ...]], int] = {}
        self._ext: Dict[int, Tuple[int, Tuple[int, ...]]] = {}
        # reverse map: phys page -> index records to purge when the page
        # leaves the pool (refcount 0), BEFORE the free list can recycle
        # the id under different contents.  Two record flavors per page:
        # keys whose VALUE is the page, and keys that EMBED the page's
        # id as the chain parent.  The second flavor is load-bearing: a
        # registration that hits an existing key chains off the CANONICAL
        # page (which the registering row may not reference at all), so
        # refcount(parent) >= refcount(child) does NOT hold in general —
        # the parent can free first, and any surviving (parent, tokens)
        # key would silently alias wrong-content K/V once the id is
        # recycled.  _drop_index therefore removes both flavors.
        self._page_keys: Dict[int, List[Tuple[str, object]]] = {}
        # token positions hashed while building index keys (register +
        # plan) — the admission-cost counter the O(plen) test pins
        self.index_ops = 0

    # -- sizing ---------------------------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        """Pages covering ``n_tokens`` cache positions.

        ``pages_for(0) == 0``: a row holding no tokens claims no pages
        (admission sizes rows by ``min(prompt_len + max_new - 1,
        max_len)``, which is >= 1 for any real request, so the old
        floor of 1 was dead weight — and wrong for the share planner,
        which sizes partial spans).  Sizing clamps at ``max_len``
        because the cache is ``max_len`` deep: the scheduler never
        admits a prompt with ``prompt_len - 1 >= max_len`` and caps the
        lifetime claim at ``max_len`` tokens, so a row can never need
        more than ``pages_per_seq`` pages.
        """
        if n_tokens <= 0:
            return 0
        return -(-min(n_tokens, self.max_len) // self.page_size)

    @property
    def pages_in_use(self) -> int:
        """Distinct physical pages allocated.  Refcount-aware: a page
        aliased by k rows counts once, not k times."""
        return self.n_pages - len(self._free)

    @property
    def pages_free(self) -> int:
        return len(self._free)

    @property
    def pages_owned(self) -> int:
        """Allocated pages with exactly one referencing row."""
        return sum(1 for c in self._ref.values() if c == 1)

    @property
    def pages_shared(self) -> int:
        """Allocated pages aliased by two or more rows."""
        return sum(1 for c in self._ref.values() if c >= 2)

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    def row_pages(self, row: int) -> List[int]:
        return list(self._rows[row])

    def row_shared_pages(self, row: int) -> int:
        """How many of ``row``'s pages are currently aliased."""
        return sum(1 for p in self._rows[row] if self._ref.get(p, 0) >= 2)

    @property
    def budget(self) -> int:
        return self._budget

    def set_budget(self, n: int) -> None:
        """Re-cap the QoS budget (clamped to [1, n_pages]).

        Shrinking below ``pages_in_use`` — including when some of that
        usage is refcounted shared pages — only gates NEW admissions:
        resident rows keep every page (shared or owned) until they
        complete, and the pool drains under the new cap naturally.
        """
        self._budget = max(1, min(int(n), self.n_pages))

    def conservation_ok(self) -> bool:
        """The exit-gate invariant, refcount-aware:

        * distinct allocated + free == n_pages,
        * every allocated page has a refcount (and only those),
        * sum of refcounts == total page-table entries across rows,
        * the null page is never allocated and never in the free list.
        """
        entries = sum(len(r) for r in self._rows)
        return (self.pages_in_use + self.pages_free == self.n_pages
                and len(self._ref) == self.pages_in_use
                and sum(self._ref.values()) == entries
                and NULL_PAGE not in self._ref
                and NULL_PAGE not in self._free
                and not set(self._free) & set(self._ref))

    # -- alloc / free ---------------------------------------------------------

    def _pop_free(self) -> int:
        page = self._free.pop()
        self._ref[page] = 1
        return page

    def can_alloc(self, n_tokens: int) -> bool:
        need = self.pages_for(n_tokens)
        return (need <= self.pages_free
                and self.pages_in_use + need <= self._budget)

    def alloc(self, row: int, n_tokens: int) -> List[int]:
        """Claim all pages for a sequence of ``n_tokens`` onto ``row``
        (private — no sharing; see :meth:`alloc_shared`).

        Returns the physical page ids (logical order).  Raises if the row
        already owns pages or the pool/budget cannot satisfy the request —
        callers gate on :meth:`can_alloc` (admission backpressure; the
        scheduler queues rather than drops).
        """
        if self._rows[row]:
            raise RuntimeError(f"row {row} already owns pages "
                               f"{self._rows[row]}")
        if not self.can_alloc(n_tokens):
            raise RuntimeError(
                f"pool cannot allocate {self.pages_for(n_tokens)} pages "
                f"(free={self.pages_free}, in_use={self.pages_in_use}, "
                f"budget={self._budget})")
        pages = [self._pop_free() for _ in range(self.pages_for(n_tokens))]
        self._rows[row] = pages
        return pages

    def free_row(self, row: int) -> List[int]:
        """Drop one reference on each of a row's pages; pages whose last
        reference drops return to the free list (and leave the prefix
        index — a recycled id must never be reachable under stale
        token keys)."""
        pages = self._rows[row]
        self._rows[row] = []
        for page in reversed(pages):
            left = self._ref[page] - 1
            if left:
                self._ref[page] = left
            else:
                del self._ref[page]
                self._drop_index(page)
                self._free.append(page)
        return pages

    # -- prefix sharing -------------------------------------------------------

    def register_prefix(self, row: int, tokens: Sequence[int]) -> int:
        """Index ``row``'s fully-written whole pages for future sharing.

        Call when the row's prefill completes: every page wholly covered
        by ``tokens`` is final (decode writes land past the prompt), so
        its contents are exactly the K/V of its token chain.  First
        registration of a chain wins; duplicates are no-ops.  Returns
        the number of pages newly indexed.
        """
        toks = tuple(int(t) for t in tokens)
        pages = self._rows[row]
        ps = self.page_size
        added = 0
        parent = -1                        # chain root (no predecessor)
        for j in range(min(len(toks) // ps, len(pages))):
            page_toks = toks[j * ps:(j + 1) * ps]
            self.index_ops += ps
            key = (parent, page_toks)
            hit = self._prefix.get(key)
            if hit is not None:
                # chain already indexed: keep walking down the CANONICAL
                # phys chain so later keys parent off the indexed pages,
                # not this row's duplicate copies
                parent = hit
                continue
            phys = pages[j]
            self._prefix[key] = phys
            self._page_keys.setdefault(phys, []).append(("p", key))
            if parent != -1:
                # the key embeds the parent's phys id — make it reachable
                # from the parent too, so _drop_index(parent) purges it
                # even when this row holds no reference on the parent
                # (the stale-key recycling hazard; see _page_keys above)
                self._page_keys.setdefault(parent, []).append(("p", key))
            added += 1
            if parent not in self._ext:
                self._ext[parent] = (phys, page_toks)
                self._page_keys[phys].append(("e", parent))
                if parent != -1:
                    self._page_keys[parent].append(("e", parent))
            parent = phys
        return added

    def _drop_index(self, phys: int) -> None:
        """Purge every index entry that could resolve through ``phys``
        once its id recycles: keys whose value is the page, AND keys /
        ``_ext`` slots that embed its id as the chain parent.  Records
        left behind in a *child's* list after its parent-key was purged
        here are harmless: the guards below no-op on a missing key, and
        a key re-created under a recycled parent id never matches the
        stale record's value test."""
        for kind, key in self._page_keys.pop(phys, ()):
            if kind == "p":
                if key[0] == phys or self._prefix.get(key) == phys:
                    self._prefix.pop(key, None)
            else:
                entry = self._ext.get(key)
                if key == phys or (entry is not None and entry[0] == phys):
                    self._ext.pop(key, None)

    @property
    def prefix_entries(self) -> int:
        return len(self._prefix)

    def plan_shared(self, n_tokens: int,
                    tokens: Sequence[int]) -> Dict[str, object]:
        """Admission plan for ``tokens`` with a whole-lifetime claim of
        ``n_tokens`` positions: how many pages alias the prefix index,
        how many tokens of prefill that skips, whether the last aliased
        page needs copy-on-write, and whether the fresh-page remainder
        fits the pool and budget.
        """
        toks = tuple(int(t) for t in tokens)
        ps = self.page_size
        total = self.pages_for(n_tokens)
        chain: List[int] = []
        parent = -1
        while (len(chain) + 1) * ps <= len(toks):
            page_toks = toks[len(chain) * ps:(len(chain) + 1) * ps]
            self.index_ops += ps
            phys = self._prefix.get((parent, page_toks))
            if phys is None:
                break
            chain.append(phys)
            parent = phys
        m = len(chain)
        # sub-page extension: the indexed page after the matched chain
        # may share a head of its tokens with ours — alias it and COW
        ext_phys: Optional[int] = None
        r = 0
        rest = toks[m * ps:]
        ext = self._ext.get(parent) if rest else None
        if ext is not None:
            phys, content = ext
            while r < min(len(rest), ps) and rest[r] == content[r]:
                r += 1
            if r:
                ext_phys = phys
        # never share the whole prompt: the final token must be fed so
        # the window closure emits the first output token
        s_tok = min(m * ps + r, len(toks) - 1) if toks else 0
        n_alias = min(self.pages_for(s_tok), total)
        aliased = (chain + ([ext_phys] if ext_phys is not None else []))
        aliased = aliased[:n_alias]
        # a partially-covered aliased page takes this row's own tokens
        # at positions >= s_tok: privatize it (one fresh page) first
        cow = 1 if (s_tok % ps and n_alias) else 0
        fresh = total - n_alias + cow
        return {"total": total, "aliased": aliased, "shared_tokens": s_tok,
                "cow": cow, "fresh": fresh,
                "fits": (fresh <= self.pages_free
                         and self.pages_in_use + fresh <= self._budget)}

    def can_alloc_shared(self, n_tokens: int,
                         tokens: Sequence[int]) -> bool:
        return bool(self.plan_shared(n_tokens, tokens)["fits"])

    def alloc_shared(self, row: int, n_tokens: int,
                     tokens: Sequence[int]
                     ) -> Tuple[List[int], int, List[Tuple[int, int]]]:
        """Claim ``row``'s pages, aliasing indexed prefix pages where
        the token chain matches.

        Returns ``(pages, shared_tokens, cow_pairs)``: the row's full
        page list, how many leading token positions arrive pre-written
        through the aliased pages (the scheduler starts the fill marker
        and the chunked-prefill cursor there), and the ``(src, dst)``
        device page copies the caller MUST apply before the row's first
        write — each pair is a copy-on-write privatization already
        reflected in the page table.
        """
        if self._rows[row]:
            raise RuntimeError(f"row {row} already owns pages "
                               f"{self._rows[row]}")
        plan = self.plan_shared(n_tokens, tokens)
        if not plan["fits"]:
            raise RuntimeError(
                f"pool cannot admit shared plan {plan} "
                f"(free={self.pages_free}, in_use={self.pages_in_use}, "
                f"budget={self._budget})")
        pages: List[int] = []
        for p in plan["aliased"]:
            self._ref[p] += 1
            pages.append(p)
        for _ in range(plan["total"] - len(pages)):
            pages.append(self._pop_free())
        self._rows[row] = pages
        cow_pairs: List[Tuple[int, int]] = []
        if plan["cow"]:
            pair = self.cow(row, len(plan["aliased"]) - 1)
            if pair is not None:
                cow_pairs.append(pair)
        return pages, int(plan["shared_tokens"]), cow_pairs

    def cow(self, row: int, logical: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write: make ``row``'s page at ``logical`` private.

        No-op (returns ``None``) when the page is already singly
        referenced.  Otherwise claims a fresh page, retargets the row's
        table at it, drops one reference on the shared original, and
        returns ``(src, dst)`` — the caller owns copying the device
        page contents before the row's next write lands.  After this,
        the row's entry no longer aliases any other row's.
        """
        phys = self._rows[row][logical]
        if self._ref.get(phys, 0) <= 1:
            return None
        if not self._free:
            raise RuntimeError(
                "copy-on-write needs a free page but the pool is "
                "exhausted; admission plans must reserve COW pages "
                "up front (plan_shared does)")
        new = self._pop_free()
        self._ref[phys] -= 1
        self._rows[row][logical] = new
        return phys, new

    # -- table views ----------------------------------------------------------

    def table_row(self, row: int) -> np.ndarray:
        """(pages_per_seq,) int32 physical ids; NULL_PAGE past the end."""
        out = np.full((self.pages_per_seq,), NULL_PAGE, np.int32)
        pages = self._rows[row]
        out[:len(pages)] = pages
        return out

    def table(self) -> np.ndarray:
        """(n_rows, pages_per_seq) int32 page table for the whole lane."""
        return np.stack([self.table_row(r) for r in range(self.n_rows)])

    def report(self) -> dict:
        return {"n_pages": self.n_pages, "page_size": self.page_size,
                "pages_per_seq": self.pages_per_seq,
                "pages_in_use": self.pages_in_use,
                "pages_free": self.pages_free,
                "pages_owned": self.pages_owned,
                "pages_shared": self.pages_shared,
                "prefix_entries": self.prefix_entries,
                "index_ops": self.index_ops,
                "budget": self._budget,
                "conservation_ok": self.conservation_ok()}


def default_pool_pages(n_rows: int, max_len: int, page_size: int,
                       kv_pages: Optional[int] = None) -> int:
    """Pool sizing: ``kv_pages`` when the operator set one, else enough
    for every row to hold a full-depth sequence (never blocks)."""
    if kv_pages is not None:
        return kv_pages
    return n_rows * (max_len // page_size)
