"""Zero-downtime weight hot-swap: deep-net mode at the serving tier.

The paper hides a 250 ns plane write under the 10 ns/pulse read stream by
programming one plane of a stacked pair while its twin serves reads
(§III-B, §V).  ``HotSwapper`` is that schedule applied to a serving
deployment: while the read-active planes keep producing decode tokens, a
new checkpoint is programmed onto the write-shadow planes in
write-latency-costed chunks, and an atomic flip promotes it with zero
dropped requests — versus the conventional stop-the-world reprogram,
which serializes write -> read exactly like the 2-D baseline the paper
benchmarks against.

``overlap_report`` prices both policies in device time with the Table-I
constants (core/timing.py) and the same schedule algebra the
deepnet_stream kernel uses (core/pipeline.py):

  * read:  one decode step reads every resident tile grid once —
    ``n_grids * in_bits * t_read`` (bit-serial, grids serialized).
  * write: chunks share one write port — ``n_chunks * t_write`` total,
    fully overlapped with reads because the shadow planes are
    column-isolated (complementary RE).

Overlapped serving therefore sustains native decode throughput through
the whole swap window, while stop-the-world delivers its first post-swap
token only after the full reprogram.  At the paper's operating point
(10-bit reads) the per-beat overlap recovers the ~29 % figure of §V.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional

import jax

from repro.core import pipeline, timing
from repro.core.planes import SwapPlan


def finetune_delta(params: Any, scale: float = 0.02, seed: int = 17) -> Any:
    """``params`` plus a small per-leaf Gaussian delta — the stand-in
    "fine-tuned checkpoint" used by the hot-swap CLI (``--hot-swap
    ft:<scale>``), benches, examples and tests.  On a fleet the second
    checkpoint comes from checkpoint/manager.py instead."""
    leaves, tdef = jax.tree_util.tree_flatten(params)
    return jax.tree_util.tree_unflatten(tdef, [
        w + scale * jax.random.normal(
            jax.random.fold_in(jax.random.PRNGKey(seed), i), w.shape
        ).astype(w.dtype)
        for i, w in enumerate(leaves)])


def overlap_report(cfg, n_grids: int, n_chunks: int,
                   batch_size: int = 1,
                   decode_steps_during: Optional[int] = None,
                   wall_swap_s: Optional[float] = None) -> Dict[str, Any]:
    """Device-time accounting of one hot-swap: overlapped vs stop-the-world.

    ``cfg`` is the executor's EngineConfig (quant.in_bits sets the read
    pulse count; cfg.params the Table-I corner).  Throughput-during-swap
    is tokens per modeled second inside the swap window: overlapped reads
    free-run (the window is write-paced), stop-the-world delivers its
    first batch only after the blocking reprogram plus one decode step.
    """
    p = cfg.params
    b = cfg.quant.in_bits
    t_read_grid = timing.read_time(b, p)          # one tile-grid read
    t_step = n_grids * t_read_grid                # one decode step, serialized
    t_write = n_chunks * p.t_write                # one write port
    thr_overlap = batch_size / t_step
    thr_stop_world = batch_size / (t_write + t_step)
    ratio = thr_overlap / thr_stop_world          # = 1 + t_write / t_step
    # per-beat overlap: the paper's read-subsumed-in-write figure (§V);
    # steady state reproduces 1 - 250/350 = 28.6 % ~ "29 %" at 10-bit reads
    steady = timing.deepnet_speedup(b, p=p)
    this_swap = pipeline.streaming_speedup(
        t_compute=t_read_grid, t_dma=p.t_write, n_tiles=max(n_chunks, 1))
    rep = {
        "n_grids": n_grids,
        "n_chunks": n_chunks,
        "in_bits": b,
        "device_decode_step_s": t_step,
        "device_write_total_s": t_write,
        "device_swap_window_overlapped_s": t_write,
        "device_swap_window_stop_world_s": t_write + t_step,
        "decode_steps_hidden_in_window": t_write / t_step,
        "tok_per_device_s_overlapped_during_swap": thr_overlap,
        "tok_per_device_s_stop_world_during_swap": thr_stop_world,
        "throughput_ratio_overlap_vs_stop_world": ratio,
        "sustains_2x_during_swap": bool(ratio >= 2.0),
        "overlap_frac_steady_state": steady,
        "overlap_frac_this_swap": this_swap,
        "paper_overlap_frac": 0.29,
        "within_2pts_of_paper": bool(abs(steady - 0.29) <= 0.02),
    }
    if decode_steps_during is not None:
        rep["decode_steps_during_swap"] = decode_steps_during
    if wall_swap_s is not None:
        rep["wall_swap_s"] = wall_swap_s
    return rep


class HotSwapper:
    """Drives one chunked swap of ``executor`` onto ``new_params``.

    Call :meth:`step` between decode steps (the BatchScheduler does this
    automatically); once :attr:`done`, :meth:`promote` lands every plane
    atomically and returns the new params tree for the caller to serve
    embeddings/norms from.  ``tenant`` may name any tenant of the plane
    bank: with a free plane the swap is *staged* (the tenant — resident
    or a first-time live deploy — keeps serving through the window);
    with a full bank a non-anchor tenant is rewritten *in place* (its
    reads pause) under the other tenants' read traffic — the
    multi-tenant use of the same read-under-write window.
    """

    def __init__(self, executor, new_params: Any, chunks_per_step: int = 8,
                 tenant: str = "A"):
        if chunks_per_step < 1:
            raise ValueError("chunks_per_step must be >= 1")
        self.executor = executor
        self.new_params = new_params
        self.chunks_per_step = chunks_per_step
        self.tenant = tenant
        self.plan: SwapPlan = executor.begin_swap(new_params, tenant=tenant)
        self.decode_steps_during = 0
        self.promoted = False
        self._wall_begin = time.perf_counter()
        self._wall_done: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.plan.done

    @property
    def remaining(self) -> int:
        return self.plan.remaining

    @property
    def leak_codes(self) -> jax.Array:
        """This window's write-plane leakage as a device scalar (0.0 when
        the config doesn't model it, or once promoted) — what the
        scheduler feeds the lane closures each step so overlap reads
        carry the live value without re-tracing (delegates to
        ``CrossbarExecutor.current_leak_codes``; see ``leak_scope``)."""
        return self.executor.current_leak_codes()

    def step(self, n: Optional[int] = None) -> int:
        """Program up to ``n`` (default ``chunks_per_step``) chunks onto
        the shadow planes; returns chunks still unwritten."""
        if self.promoted:
            return 0
        if self.plan.done:
            return 0
        return self.executor.write_chunks(n or self.chunks_per_step)

    def note_decode_step(self) -> None:
        self.decode_steps_during += 1

    def promote(self) -> Any:
        """Atomic flip (executor verifies per-tile fingerprints first)."""
        params = self.executor.promote()
        self.promoted = True
        self._wall_done = time.perf_counter()
        return params

    @property
    def wall_swap_s(self) -> Optional[float]:
        if self._wall_done is None:
            return None
        return self._wall_done - self._wall_begin

    def report(self, batch_size: int = 1) -> Dict[str, Any]:
        rep = overlap_report(
            self.executor.cfg, n_grids=self.executor.n_resident,
            n_chunks=self.plan.total_chunks, batch_size=batch_size,
            decode_steps_during=self.decode_steps_during,
            wall_swap_s=self.wall_swap_s)
        rep["policy"] = "overlapped"
        rep["tenant"] = self.tenant
        # bank-level context: which lifecycle this window used and how
        # tall the stack is (staged = the tenant served throughout;
        # in_place = its reads paused while the others flowed)
        rep["swap_mode"] = "in_place" if self.plan.in_place else "staged"
        rep["stack_planes"] = self.executor.stack_planes
        return rep
