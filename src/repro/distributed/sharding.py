"""Logical-axis sharding: one place where DP/FSDP/TP/EP/SP policy lives.

Models annotate parameters and activations with *logical* axis names
("embed", "mlp", "heads", "vocab", "experts", "batch", "seq", ...).  A
``ShardingRules`` table maps logical names to physical mesh axes; the same
model code then runs on a laptop (no mesh — everything replicated), a
single 16x16 pod, or the 2x16x16 multi-pod mesh.

Conventions for the production meshes (see launch/mesh.py):
  * "data" axis  : batch data-parallelism AND ZeRO-3/FSDP weight sharding.
  * "model" axis : tensor parallelism (heads / mlp / vocab / experts).
  * "pod" axis   : outer data-parallel axis spanning pods (gradient
                   all-reduce crosses the slower pod interconnect once per
                   step; FSDP gathering stays inside a pod by default).

GQA note: when tp > kv_heads the configs raise ``kv_repeat`` so the
replicated KV heads shard cleanly (Megatron-style KV replication) — see
models/layers.py.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Mapping, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Logical = Tuple[Optional[str], ...]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> physical mesh axis (or None = replicate)."""
    rules: Mapping[str, Optional[object]]

    def spec(self, names: Sequence[Optional[str]]) -> P:
        axes = []
        for n in names:
            if n is None:
                axes.append(None)
            else:
                axes.append(self.rules.get(n))
        return P(*axes)

    def sharding(self, mesh: Mesh, names: Sequence[Optional[str]]
                 ) -> NamedSharding:
        return NamedSharding(mesh, self.spec(names))


# Baseline rule tables ------------------------------------------------------

def fsdp_rules(multi_pod: bool = False, fsdp: bool = True,
               seq_shard: bool = False) -> ShardingRules:
    """Default production table.

    * batch over (pod, data) — pure DP.
    * weight "fsdp" dims over data (ZeRO-3) when ``fsdp``.
    * heads/mlp/vocab/experts over model — TP.
    * kv_seq over data for sequence-parallel long-context decode (SP).
    """
    dp = ("pod", "data") if multi_pod else ("data",)
    return ShardingRules(rules={
        "batch": dp,
        "act_embed": None,
        "act_mlp": "model",
        "act_heads": "model",
        "act_kv_heads": "model",
        "seq": None,
        "seq_act": None,   # SP gather points (always gathered)
        "kv_seq": ("data" if seq_shard else None),
        "vocab": "model",
        "embed": ("data" if fsdp else None),
        "mlp": "model",
        "heads": "model",
        "kv_heads": "model",
        "experts": None,
        "expert_mlp": "model",
        "layers": None,
        "conv": None,
        "ssm_state": None,
        "ssm_heads": "model",
    })


def single_device_rules() -> ShardingRules:
    return ShardingRules(rules={})


# Context registry -----------------------------------------------------------
# Models call logical_constraint()/param_sharding() without threading a rules
# object through every layer; the launcher installs the active table here.

class _Ctx(threading.local):
    rules: Optional[ShardingRules] = None
    mesh: Optional[Mesh] = None


_CTX = _Ctx()


class use_rules:
    """Context manager installing a rules table (and optionally a mesh)."""

    def __init__(self, rules: Optional[ShardingRules],
                 mesh: Optional[Mesh] = None):
        self.rules, self.mesh = rules, mesh

    def __enter__(self):
        self._old = (_CTX.rules, _CTX.mesh)
        _CTX.rules, _CTX.mesh = self.rules, self.mesh
        return self

    def __exit__(self, *exc):
        _CTX.rules, _CTX.mesh = self._old
        return False


def current_rules() -> Optional[ShardingRules]:
    return _CTX.rules


def logical_constraint(x: jax.Array, names: Sequence[Optional[str]]
                       ) -> jax.Array:
    """Annotate an activation with logical axes (no-op without rules)."""
    r = _CTX.rules
    if r is None:
        return x
    spec = r.spec(names)
    if all(a is None for a in spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def tp_bf16_matmul(h: jax.Array, w: jax.Array,
                   dp_logical: str = "batch") -> Optional[jax.Array]:
    """Megatron-style low-precision tensor-parallel projection:
    ``y = h @ w`` where the contraction dim is TP-sharded, with the
    partial sums CONVERTED TO bf16 BEFORE the all-reduce (halves TP
    traffic at a small, well-studied precision cost).

    This cannot be expressed in plain pjit: converting partials before the
    reduction changes semantics (sum(convert(p_i)) != convert(sum(p_i))),
    so XLA legally refuses to move the convert below the all-reduce — an
    explicit shard_map + psum carries the intent.  Returns None when no
    rules/mesh are installed or the contraction is not model-sharded
    (caller falls back to the plain einsum).

    h: (..., F) activations, F sharded over "model"; w: (F, D) weights.
    """
    rules, mesh = _CTX.rules, _CTX.mesh
    if rules is None or mesh is None:
        return None
    if not rules.rules.get("_tp_bf16_reduce"):
        return None
    tp_axis = rules.rules.get("mlp")
    if tp_axis != "model" or "model" not in mesh.axis_names:
        return None
    dp = rules.rules.get(dp_logical)
    lead = (dp,) + (None,) * (h.ndim - 2)

    # gather the FSDP dim of w first (shard_map blocks need it local)
    w = jax.lax.with_sharding_constraint(w, P("model", None))

    def block(h_blk, w_blk):
        part = jnp.einsum("...f,fd->...d", h_blk, w_blk,
                          preferred_element_type=jnp.float32)
        return jax.lax.psum(part.astype(h_blk.dtype), "model")

    from jax.experimental.shard_map import shard_map
    import jax.numpy as jnp_  # noqa: F401
    fn = shard_map(block, mesh=mesh,
                   in_specs=(P(*lead, "model"), P("model", None)),
                   out_specs=P(*lead, None))
    return fn(h, w)


import jax.numpy as jnp  # noqa: E402  (used by tp_bf16_matmul)


def spec_tree(param_specs, rules: ShardingRules):
    """Map a pytree of logical-name tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda names: rules.spec(names), param_specs,
        is_leaf=lambda x: type(x) is tuple)


def sharding_tree(param_specs, rules: ShardingRules, mesh: Mesh):
    return jax.tree.map(
        lambda names: rules.sharding(mesh, names), param_specs,
        is_leaf=lambda x: type(x) is tuple)
