"""Dependency-free metrics registry: counters, gauges, fixed-bucket
histograms, snapshot-able to Prometheus text format and JSONL.

Design constraints, in order:

  * **stdlib only** — this package sits UNDER ``core/`` (the engine's
    dispatch counters route here), so it can never import jax, numpy,
    or anything from ``repro.*``.
  * **cheap when disabled** — ``MetricsRegistry(enabled=False)`` turns
    every ``inc``/``set``/``observe`` into a dict lookup and a boolean
    test, which is what the CI telemetry smoke's <=5 % decode-overhead
    gate compares against.
  * **labels are first-class** — every sample carries a label set
    (``tenant``, ``mode``, ``path``, ...); :meth:`MetricsRegistry.total`
    sums across a label *subset* so views like ``engine.path_calls``
    (per-geometry labels, summed per path) stay O(samples).

Export formats:

  * :meth:`MetricsRegistry.to_prometheus` — the Prometheus text
    exposition format (``# HELP``/``# TYPE`` headers, escaped label
    values, ``_bucket``/``_sum``/``_count`` histogram series).
    :func:`parse_prometheus` is the matching line-format parser; the CI
    telemetry smoke round-trips every snapshot through it.
  * :meth:`MetricsRegistry.to_jsonl` — one JSON object per sample,
    tagged ``{"kind": "metric", ...}`` so metric lines and span lines
    (``trace.py``) can share one file.
"""
from __future__ import annotations

import json
import re
import threading
from typing import Any, Dict, List, Sequence, Tuple

#: label-set key: sorted (name, value) pairs, values coerced to str
LabelKey = Tuple[Tuple[str, str], ...]

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

#: default latency buckets [s]: sub-ms host paths up through multi-second
#: interpret-mode decode steps (fixed at histogram creation — the bucket
#: layout is part of the metric's identity, like a Prometheus scrape)
DEFAULT_LATENCY_BUCKETS: Tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def _label_key(labels: Dict[str, Any]) -> LabelKey:
    for k in labels:
        if not _LABEL_RE.match(k):
            raise ValueError(f"invalid label name {k!r}")
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _escape_label(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")


def _escape_help(v: str) -> str:
    return v.replace("\\", r"\\").replace("\n", r"\n")


def _fmt(v: float) -> str:
    """Prometheus sample value: shortest round-trippable float."""
    if v == float("inf"):
        return "+Inf"
    if v == float("-inf"):
        return "-Inf"
    return repr(float(v))


class _Metric:
    """Common machinery: per-label-set sample storage under one lock."""

    kind = "untyped"

    def __init__(self, name: str, help: str, registry: "MetricsRegistry"):
        self.name, self.help = name, help
        self._registry = registry
        self._lock = registry._lock
        self._samples: Dict[LabelKey, Any] = {}

    @property
    def enabled(self) -> bool:
        return self._registry.enabled

    def _labels_dict(self, key: LabelKey) -> Dict[str, str]:
        return dict(key)

    def clear(self) -> None:
        with self._lock:
            self._samples.clear()


class Counter(_Metric):
    """Monotone counter (per label set)."""

    kind = "counter"

    def inc(self, value: float = 1.0, **labels: Any) -> None:
        if not self.enabled:
            return
        if value < 0:
            raise ValueError(
                f"{self.name}: counters are monotone, got inc({value})")
        key = _label_key(labels)
        with self._lock:
            self._samples[key] = self._samples.get(key, 0.0) + value

    def get(self, **labels: Any) -> float:
        return float(self._samples.get(_label_key(labels), 0.0))


class Gauge(_Metric):
    """Set-table instantaneous value (per label set)."""

    kind = "gauge"

    def set(self, value: float, **labels: Any) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._samples[_label_key(labels)] = float(value)

    def inc(self, value: float = 1.0, **labels: Any) -> None:
        if not self.enabled:
            return
        key = _label_key(labels)
        with self._lock:
            self._samples[key] = self._samples.get(key, 0.0) + value

    def get(self, **labels: Any) -> float:
        return float(self._samples.get(_label_key(labels), 0.0))


class Histogram(_Metric):
    """Fixed-bucket histogram: cumulative ``le`` buckets + sum + count.

    Bucket semantics match Prometheus exactly: an observation lands in
    every bucket whose upper bound is >= the value (``value <= le``),
    and the implicit ``+Inf`` bucket equals the total count.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str, registry: "MetricsRegistry",
                 buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS):
        super().__init__(name, help, registry)
        bs = tuple(float(b) for b in buckets)
        if not bs or any(b2 <= b1 for b1, b2 in zip(bs, bs[1:])):
            raise ValueError(
                f"{name}: buckets must be non-empty and strictly "
                f"increasing, got {bs}")
        if any(b != b or b in (float("inf"), float("-inf")) for b in bs):
            raise ValueError(f"{name}: buckets must be finite (the +Inf "
                             f"bucket is implicit), got {bs}")
        self.buckets = bs

    def observe(self, value: float, **labels: Any) -> None:
        if not self.enabled:
            return
        key = _label_key(labels)
        with self._lock:
            s = self._samples.get(key)
            if s is None:
                s = self._samples[key] = {
                    "counts": [0] * len(self.buckets), "sum": 0.0,
                    "count": 0}
            v = float(value)
            for i, b in enumerate(self.buckets):
                if v <= b:
                    s["counts"][i] += 1
            s["sum"] += v
            s["count"] += 1

    def bucket_counts(self, **labels: Any) -> Dict[str, int]:
        """Cumulative per-bucket counts, keyed by the ``le`` bound (str),
        including the implicit ``+Inf`` bucket."""
        s = self._samples.get(_label_key(labels))
        if s is None:
            return {**{_fmt(b): 0 for b in self.buckets}, "+Inf": 0}
        out = {_fmt(b): c for b, c in zip(self.buckets, s["counts"])}
        out["+Inf"] = s["count"]
        return out

    def get_sum(self, **labels: Any) -> float:
        s = self._samples.get(_label_key(labels))
        return float(s["sum"]) if s else 0.0

    def get_count(self, **labels: Any) -> int:
        s = self._samples.get(_label_key(labels))
        return int(s["count"]) if s else 0


class MetricsRegistry:
    """Create-or-get metric families + snapshot/export surface."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.RLock()
        self._metrics: Dict[str, _Metric] = {}

    # -- family creation (create-or-get; kind conflicts raise) --------------

    def _get(self, name: str, kind: str, factory) -> Any:
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = factory()
            elif m.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested {kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, "counter",
                         lambda: Counter(name, help, self))

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, "gauge", lambda: Gauge(name, help, self))

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS,
                  ) -> Histogram:
        h = self._get(name, "histogram",
                      lambda: Histogram(name, help, self, buckets))
        if tuple(float(b) for b in buckets) != h.buckets:
            raise ValueError(
                f"metric {name!r} already registered with buckets "
                f"{h.buckets}; bucket layout is fixed at creation")
        return h

    # -- queries -------------------------------------------------------------

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def get(self, name: str, **labels: Any) -> float:
        """Exact-label-set value of a counter/gauge (0.0 when absent)."""
        m = self._metrics.get(name)
        if m is None:
            return 0.0
        if isinstance(m, Histogram):
            raise TypeError(f"{name}: use bucket_counts/get_sum/get_count "
                            f"on the histogram object")
        return m.get(**labels)

    def total(self, name: str, **label_filter: Any) -> float:
        """Sum a counter/gauge across every sample whose labels are a
        superset of ``label_filter`` — e.g. ``total("dispatch_total",
        path="kernel")`` sums over all geometries."""
        m = self._metrics.get(name)
        if m is None:
            return 0.0
        want = set(_label_key(label_filter))
        with self._lock:
            if isinstance(m, Histogram):
                return float(sum(
                    s["sum"] for key, s in m._samples.items()
                    if want <= set(key)))
            return float(sum(v for key, v in m._samples.items()
                             if want <= set(key)))

    # -- snapshot / export ---------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        """Plain-dict snapshot: ``{name: {type, help, samples: [...]}}``.

        Counter/gauge samples are ``{"labels": {...}, "value": v}``;
        histogram samples carry ``buckets``/``sum``/``count``.
        """
        out: Dict[str, Any] = {}
        with self._lock:
            for name in sorted(self._metrics):
                m = self._metrics[name]
                samples: List[Dict[str, Any]] = []
                for key in sorted(m._samples):
                    labels = dict(key)
                    if isinstance(m, Histogram):
                        samples.append({
                            "labels": labels,
                            "buckets": m.bucket_counts(**labels),
                            "sum": m.get_sum(**labels),
                            "count": m.get_count(**labels)})
                    else:
                        samples.append({"labels": labels,
                                        "value": float(m._samples[key])})
                out[name] = {"type": m.kind, "help": m.help,
                             "samples": samples}
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format of the whole registry."""
        lines: List[str] = []
        for name, fam in self.snapshot().items():
            if fam["help"]:
                lines.append(f"# HELP {name} {_escape_help(fam['help'])}")
            lines.append(f"# TYPE {name} {fam['type']}")
            for s in fam["samples"]:
                if fam["type"] == "histogram":
                    for le, c in s["buckets"].items():
                        lines.append(_sample_line(
                            f"{name}_bucket",
                            {**s["labels"], "le": le}, c))
                    lines.append(_sample_line(f"{name}_sum", s["labels"],
                                              s["sum"]))
                    lines.append(_sample_line(f"{name}_count", s["labels"],
                                              s["count"]))
                else:
                    lines.append(_sample_line(name, s["labels"],
                                              s["value"]))
        return "\n".join(lines) + ("\n" if lines else "")

    def to_jsonl(self) -> str:
        """One JSON object per sample, tagged ``"kind": "metric"``."""
        lines = []
        for name, fam in self.snapshot().items():
            for s in fam["samples"]:
                doc = {"kind": "metric", "metric": name,
                       "type": fam["type"], **s}
                lines.append(json.dumps(doc, sort_keys=True))
        return "\n".join(lines) + ("\n" if lines else "")

    def reset(self) -> None:
        """Drop every sample (metric definitions persist)."""
        with self._lock:
            for m in self._metrics.values():
                m._samples.clear()


def _sample_line(name: str, labels: Dict[str, Any], value: Any) -> str:
    if labels:
        body = ",".join(f'{k}="{_escape_label(str(v))}"'
                        for k, v in sorted(labels.items()))
        return f"{name}{{{body}}} {_fmt(float(value))}"
    return f"{name} {_fmt(float(value))}"


# -- Prometheus line-format parser -------------------------------------------

def _parse_labels(body: str, line: str) -> Dict[str, str]:
    labels: Dict[str, str] = {}
    i, n = 0, len(body)
    while i < n:
        j = body.index("=", i)
        key = body[i:j].strip()
        if not _LABEL_RE.match(key):
            raise ValueError(f"bad label name {key!r} in line {line!r}")
        if j + 1 >= n or body[j + 1] != '"':
            raise ValueError(f"unquoted label value in line {line!r}")
        i, chars = j + 2, []
        while i < n and body[i] != '"':
            c = body[i]
            if c == "\\" and i + 1 < n:
                nxt = body[i + 1]
                c = {"n": "\n", "\\": "\\", '"': '"'}.get(nxt)
                if c is None:
                    raise ValueError(
                        f"bad escape \\{nxt} in line {line!r}")
                i += 1
            chars.append(c)
            i += 1
        if i >= n:
            raise ValueError(f"unterminated label value in line {line!r}")
        labels[key] = "".join(chars)
        i += 1                       # past the closing quote
        if i < n and body[i] == ",":
            i += 1
        i += len(body[i:]) - len(body[i:].lstrip())
    return labels


def parse_prometheus(text: str) -> List[Dict[str, Any]]:
    """Parse Prometheus text format into sample dicts.

    Returns ``[{"name": str, "labels": {str: str}, "value": float}]`` in
    input order; comment/blank lines are skipped.  Raises ``ValueError``
    on any malformed line — the CI telemetry smoke gates on this parser
    accepting every snapshot the registry emits.
    """
    samples: List[Dict[str, Any]] = []
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if "{" in line:
            name, rest = line.split("{", 1)
            body, _, tail = rest.rpartition("}")
            if not _:
                raise ValueError(f"unbalanced braces in line {line!r}")
            labels = _parse_labels(body, line)
            value_str = tail.strip()
        else:
            parts = line.split()
            if len(parts) < 2:
                raise ValueError(f"missing value in line {line!r}")
            name, value_str, labels = parts[0], parts[1], {}
        name = name.strip()
        if not _NAME_RE.match(name):
            raise ValueError(f"bad metric name {name!r} in line {line!r}")
        # a timestamp may trail the value; take the first token
        value_tok = value_str.split()[0] if value_str.split() else ""
        try:
            value = float(value_tok.replace("+Inf", "inf")
                          .replace("-Inf", "-inf"))
        except ValueError:
            raise ValueError(
                f"bad sample value {value_tok!r} in line {line!r}")
        samples.append({"name": name, "labels": labels, "value": value})
    return samples


__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "DEFAULT_LATENCY_BUCKETS", "parse_prometheus",
]
