"""Request-lifecycle tracing: named spans with wall-clock bounds.

A :class:`Span` is a closed interval ``[start, end]`` on the tracer's
clock (``time.perf_counter`` by default) plus free-form attributes
(``rid``, ``tenant``, ``bucket``, ``lifecycle``, ...).  The scheduler
records one span *set* per completed request — ``queue_wait``,
``prefill``, ``decode``, and the enclosing ``request`` — chosen so the
parts telescope exactly to the whole:

    queue_wait: [t_submit, t_admit]
    prefill:    [t_admit,  t_first]   (ends at first emitted token; its
                                       duration is TTFT minus queue wait)
    decode:     [t_first,  t_done]
    request:    [t_submit, t_done]

Swap windows and promotions are recorded as ``swap_window`` spans tagged
with ``lifecycle`` (``staged``/``in_place``) and ``policy``.

stdlib only — same constraint as ``registry.py``.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Any, Callable, Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class Span:
    name: str
    start: float
    end: float
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.end - self.start

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": "span", "span": self.name, "start": self.start,
                "end": self.end, "duration_s": self.duration,
                **{f"attr_{k}": v for k, v in sorted(self.attrs.items())}}


class Tracer:
    """Append-only span buffer with a monotonic clock.

    ``enabled=False`` keeps :meth:`now` functional (callers may use it
    unconditionally) but makes :meth:`record` a no-op, so a metrics-off
    scheduler pays only the clock reads.
    """

    def __init__(self, enabled: bool = True,
                 clock: Callable[[], float] = time.perf_counter):
        self.enabled = enabled
        self._clock = clock
        self._lock = threading.Lock()
        self._spans: List[Span] = []

    def now(self) -> float:
        return self._clock()

    def record(self, name: str, start: float, end: float,
               **attrs: Any) -> Optional[Span]:
        if not self.enabled:
            return None
        span = Span(name, float(start), float(end), dict(attrs))
        with self._lock:
            self._spans.append(span)
        return span

    def spans(self, name: Optional[str] = None,
              **attr_filter: Any) -> List[Span]:
        """Recorded spans, optionally filtered by name and exact
        attribute values (e.g. ``spans("request", tenant="B")``)."""
        with self._lock:
            out = list(self._spans)
        if name is not None:
            out = [s for s in out if s.name == name]
        for k, v in attr_filter.items():
            out = [s for s in out if s.attrs.get(k) == v]
        return out

    def __len__(self) -> int:
        return len(self._spans)

    def to_jsonl(self) -> str:
        """One JSON object per span, tagged ``"kind": "span"``."""
        with self._lock:
            lines = [json.dumps(s.to_dict(), sort_keys=True)
                     for s in self._spans]
        return "\n".join(lines) + ("\n" if lines else "")

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()


__all__ = ["Span", "Tracer"]
