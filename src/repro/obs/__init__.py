"""Telemetry for the CrossStack serving stack (dependency-free).

Two tiers of ownership:

* **Global registry/tracer** (:func:`registry`, :func:`tracer`) —
  process-wide signals that exist below any one scheduler: engine
  dispatch counts (``crossstack_dispatch_total``), executor
  program/swap events, and jit trace/retrace counters
  (``serve_jit_traces_total`` / ``serve_jit_retraces_total``, bumped
  from inside jitted closure bodies, i.e. at trace time only).
* **Per-scheduler registry/tracer** (``BatchScheduler.metrics`` /
  ``.tracer``) — request lifecycle, token latency, QoS shares, and
  modeled device-time/energy, scoped so concurrent schedulers in one
  process (every bench builds several) never cross-contaminate and a
  ``telemetry=False`` scheduler is a clean metrics-off baseline.

See ``docs/OBSERVABILITY.md`` for the metric/span catalog.
"""
from repro.obs.registry import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    DEFAULT_LATENCY_BUCKETS,
    parse_prometheus,
)
from repro.obs.trace import Span, Tracer

_REGISTRY = MetricsRegistry()
_TRACER = Tracer()


def registry() -> MetricsRegistry:
    """The process-global registry (engine/executor/jit-trace events)."""
    return _REGISTRY


def tracer() -> Tracer:
    """The process-global tracer (executor-level swap spans)."""
    return _TRACER


def note_jit_trace(closure: str, tenant: str, retrace: bool) -> None:
    """Record one jit trace of a serving closure in the global registry.

    Called from *inside* jitted function bodies — host-side code there
    runs at trace time only, so each call is exactly one (re)trace.
    A ``retrace`` is any trace beyond the first for a given built
    closure; the serving invariant is that the retrace counter stays 0
    across `begin_hot_swap` windows (leak codes are traced operands,
    never trace constants).
    """
    reg = _REGISTRY
    reg.counter(
        "serve_jit_traces_total",
        help="jit traces of serving closures (decode/prefill), "
             "counted at trace time").inc(closure=closure, tenant=tenant)
    if retrace:
        reg.counter(
            "serve_jit_retraces_total",
            help="jit re-traces beyond the first per built closure; "
                 "must stay 0 across hot-swap windows",
        ).inc(closure=closure, tenant=tenant)


def reset() -> None:
    """Zero the global registry samples and drop global spans (for
    tests/benches that need a clean process-wide slate)."""
    _REGISTRY.reset()
    _TRACER.clear()


__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "Span", "Tracer",
    "DEFAULT_LATENCY_BUCKETS", "parse_prometheus",
    "registry", "tracer", "note_jit_trace", "reset",
]
