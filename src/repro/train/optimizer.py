"""AdamW in pure JAX with ZeRO-compatible state layout and optional int8
gradient all-reduce with error feedback.

Optimizer state mirrors the parameter pytree (so the same logical-axis
sharding rules apply — m/v shards exactly like its parameter; that IS
ZeRO when parameters are FSDP-sharded).  No optax dependency.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init(params, state_dtype=jnp.float32) -> OptState:
    """state_dtype=bf16 halves optimizer HBM — required to fit 340B-class
    models on a single 256-chip pod (16 GB/chip); moments are upcast to f32
    inside the update, so only storage precision drops."""
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, state_dtype), params)
    return OptState(m=zeros,
                    v=jax.tree.map(jnp.zeros_like, zeros),
                    step=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, params, grads, state: OptState
           ) -> Tuple[Any, OptState, dict]:
    """One AdamW step (f32 master params).  Returns (params, state, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        sdtype = m.dtype
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mh = m32 / b1c
        vh = v32 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(sdtype), v32.astype(sdtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(new_m, new_v, step), {
        "lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------
# int8 gradient all-reduce with error feedback (opt-in, shard_map over DP)
# ---------------------------------------------------------------------------

def quantize_grad_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_grad(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jax.Array, axis_name: str, err: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback int8 all-reduce: quantize (g + carried error), psum
    the int8 payload (4x less DP traffic than f32), dequantize, and carry
    the quantization residual to the next step.

    Call inside shard_map over the DP axis.  The returned error tensor must
    be threaded through train state.
    """
    g_corr = g.astype(jnp.float32) + err
    q, scale = quantize_grad_int8(g_corr)
    deq_local = dequantize_grad(q, scale)
    new_err = g_corr - deq_local
    summed = jax.lax.psum(deq_local, axis_name)
    n = jax.lax.psum(jnp.ones(()), axis_name)
    return summed / n, new_err
