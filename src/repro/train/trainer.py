"""Train-step builder: mixed precision, microbatch accumulation, remat,
donation, and sharding-annotated state.

``make_train_step(model, opt_cfg, microbatches=1)`` returns a pure
function  (state, batch) -> (state, metrics)  suitable for jax.jit with
in/out shardings from ``state_specs`` and donated state.

TrainState = {params (f32 master), opt (AdamW m/v/step)}.  The forward
pass consumes params cast to the model's activation dtype (bf16), so under
FSDP the all-gather moves bf16 — half the bytes of the f32 master — and
the cast is fused into the gather by XLA.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models.model import Model
from repro.train import optimizer as opt


class TrainState(NamedTuple):
    params: Any
    opt: opt.OptState


def init_state(model: Model, key, opt_state_dtype=jnp.float32) -> TrainState:
    params = model.init(key)
    params = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return TrainState(params=params,
                      opt=opt.init(params, opt_state_dtype))


def state_specs(model: Model):
    ps = model.param_specs()
    return TrainState(params=ps,
                      opt=opt.OptState(m=ps, v=ps, step=()))


def _split_microbatch(batch, n: int, i: int):
    def sl(x):
        per = x.shape[0] // n
        return jax.lax.dynamic_slice_in_dim(x, i * per, per, axis=0)

    return jax.tree.map(sl, batch)


def make_train_step(model: Model, opt_cfg: opt.AdamWConfig,
                    microbatches: int = 1, compute_dtype=jnp.bfloat16,
                    grad_accum_dtype=jnp.float32,
                    unroll_accum: bool = False):
    """Returns train_step(state, batch) -> (state, metrics).

    grad_accum_dtype=bf16 halves the microbatch gradient accumulator —
    needed (with bf16 optimizer moments) to fit 340B-class training on a
    single 256-chip pod; each microbatch's grads are produced in f32 and
    rounded once on accumulation.

    unroll_accum=True replaces the fori_loop with a Python loop so XLA
    cost analysis sees every microbatch (dry-run probes only — the rolled
    loop is the production form).
    """

    def cast(p):
        c = jax.tree.map(
            lambda x: x.astype(compute_dtype)
            if x.dtype == jnp.float32 and x.ndim >= 2 else x, p)
        # Pin the casted copy to the SAME sharding as the f32 master:
        # without this, XLA SPMD is free to all-gather the f32 master and
        # convert afterwards, doubling FSDP gather traffic (observed in
        # the nemotron-340b HLO); with it, the convert happens shard-local
        # and the per-layer gathers move bf16.
        rules = shd.current_rules()
        if rules is not None:
            specs = model.param_specs()
            c = jax.tree.map(
                lambda x, names: jax.lax.with_sharding_constraint(
                    x, rules.spec(names)), c, specs)
        return c

    def loss_of(params_c, batch):
        loss, metrics = model.loss_fn(params_c, batch)
        return loss, metrics

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        params_c = cast(state.params)

        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params_c, batch)
        else:
            def one(i, carry):
                gacc, lacc = carry
                mb = _split_microbatch(batch, microbatches, i)
                (l, _), g = jax.value_and_grad(loss_of, has_aux=True)(
                    params_c, mb)
                gacc = jax.tree.map(
                    lambda a, b: (a.astype(jnp.float32)
                                  + b.astype(jnp.float32)
                                  ).astype(grad_accum_dtype), gacc, g)
                return gacc, lacc + l

            gz = jax.tree.map(
                lambda p: jnp.zeros(p.shape, grad_accum_dtype), params_c)
            if unroll_accum:
                carry = (gz, jnp.zeros((), jnp.float32))
                for i in range(microbatches):
                    carry = one(i, carry)
                grads, loss = carry
            else:
                grads, loss = jax.lax.fori_loop(
                    0, microbatches, one, (gz, jnp.zeros((), jnp.float32)))
            grads = jax.tree.map(
                lambda g: g.astype(jnp.float32) / microbatches, grads)
            loss = loss / microbatches
            metrics = {"loss": loss}

        new_params, new_opt, stats = opt.update(
            opt_cfg, state.params, grads, state.opt)
        metrics = dict(metrics, **stats)
        return TrainState(new_params, new_opt), metrics

    return train_step


def make_eval_step(model: Model):
    def eval_step(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return metrics

    return eval_step
