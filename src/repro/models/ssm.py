"""Mamba2 (SSD) blocks and the Zamba2 hybrid layout.

Mamba2: in_proj -> (z, x, B, C, dt); short causal depthwise conv on
(x, B, C); per-head scalar decay a_t = exp(-exp(A_log) * dt_t); SSD
recurrence via the shared chunked GLA (decay broadcast over the state dim);
skip D*x; gated SiLU(z); out_proj.

Zamba2: a stack of Mamba2 blocks with ONE shared full-attention transformer
block applied every ``attn_every`` layers (weights shared across
applications, per-application KV caches), following arXiv:2411.15242 (the
concatenated-embedding LoRA adapters of the released model are simplified
away — see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_constraint as lc
from repro.models import layers as L
from repro.models.lin_attn import chunked_gla, gla_decode_step


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 16
    chunk_unroll: bool = True

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def _n(key, shape, scale):
    return jax.random.normal(key, shape) * scale


def mamba2_init(key, cfg: Mamba2Config):
    d, di, n = cfg.d_model, cfg.d_inner, cfg.d_state
    h = cfg.n_heads
    ks = jax.random.split(key, 6)
    conv_ch = di + 2 * n
    p = {
        "w_in": _n(ks[0], (d, 2 * di + 2 * n + h), d ** -0.5),
        "conv_w": _n(ks[1], (cfg.conv_width, conv_ch), 0.5),
        "conv_b": jnp.zeros((conv_ch,)),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)),
        "dt_bias": jnp.log(jnp.expm1(jnp.exp(jnp.linspace(
            jnp.log(1e-3), jnp.log(1e-1), h)))),
        "d_skip": jnp.ones((h,)),
        "norm": jnp.ones((di,)),
        "w_out": _n(ks[2], (di, d), di ** -0.5),
    }
    s = {
        "w_in": ("embed", "ssm_inner"),
        "conv_w": (None, "ssm_inner"),
        "conv_b": ("ssm_inner",),
        "a_log": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "d_skip": ("ssm_heads",),
        "norm": ("ssm_inner",),
        "w_out": ("ssm_inner", "embed"),
    }
    return p, s


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv, width W, via W shifted adds (exact, unrollable).

    x: (B, S, C); w: (W, C); conv_state: (B, W-1, C) carry for decode.
    Returns (y, new_conv_state)."""
    bsz, s, c = x.shape
    wd = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((bsz, wd - 1, c), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)             # (B, S+W-1, C)
    y = b.astype(x.dtype)[None, None]
    y = y + sum(xp[:, i:i + s] * w[i].astype(x.dtype)[None, None]
                for i in range(wd))
    return jax.nn.silu(y), xp[:, -(wd - 1):]


def mamba2(p, cfg: Mamba2Config, x, state: Dict[str, Any],
           decode: bool = False):
    """x: (B, S, d); state: {"conv": (B, W-1, d_inner+2N), "ssm": (B,H,N,hd)}.

    Returns (y, new_state)."""
    b, s, d = x.shape
    di, n, h, hd = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.head_dim

    proj = x @ p["w_in"].astype(x.dtype)
    proj = lc(proj, ("batch", "seq", "act_ssm"))
    z, xbc, dt_raw = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                   state["conv"])
    xin, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None])        # (B,S,H)
    log_a = -jnp.exp(p["a_log"])[None, None] * dt            # (B,S,H) <= 0

    # map to GLA form: q=C, k=B (shared across heads), v = dt * x per head
    xin = xin.reshape(b, s, h, hd)
    v = (xin.astype(jnp.float32) * dt[..., None])
    q = jnp.broadcast_to(cmat[:, :, None, :].astype(jnp.float32),
                         (b, s, h, n))
    k = jnp.broadcast_to(bmat[:, :, None, :].astype(jnp.float32),
                         (b, s, h, n))
    log_w = jnp.broadcast_to(log_a[..., None], (b, s, h, n))

    if decode:
        y, ssm = gla_decode_step(q[:, 0], k[:, 0], v[:, 0], log_w[:, 0],
                                 state["ssm"])
        y = y[:, None]
    else:
        y, ssm = chunked_gla(q, k, v, log_w, None,
                             chunk=min(cfg.chunk, s),
                             unroll=cfg.chunk_unroll, state0=state["ssm"])
    y = y + xin.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, di).astype(x.dtype)
    y = L.rmsnorm(y * jax.nn.silu(z), p["norm"])
    out = y @ p["w_out"].astype(x.dtype)
    return (lc(out, ("batch", "seq", "act_embed")),
            {"conv": conv_state, "ssm": ssm})


def mamba2_state(cfg: Mamba2Config, batch: int, dtype=jnp.bfloat16):
    """conv carry in activation dtype; SSM state in f32 (accumulating)."""
    return {"conv": jnp.zeros((batch, cfg.conv_width - 1,
                               cfg.d_inner + 2 * cfg.d_state), dtype),
            "ssm": jnp.zeros((batch, cfg.n_heads, cfg.d_state,
                              cfg.head_dim), jnp.float32)}


def mamba2_state_specs(cfg: Mamba2Config):
    return {"conv": ("batch", None, "act_ssm"),
            "ssm": ("batch", "ssm_heads", None, None)}


def mamba2_block_init(key, cfg: Mamba2Config):
    p, s = mamba2_init(key, cfg)
    return ({"ln": jnp.ones((cfg.d_model,)), "mixer": p},
            {"ln": (None,), "mixer": s})


def mamba2_block_specs(cfg: Mamba2Config):
    """Spec-only twin of mamba2_block_init (no array materialization)."""
    mixer = {"w_in": ("embed", "ssm_inner"), "conv_w": (None, "ssm_inner"),
             "conv_b": ("ssm_inner",), "a_log": ("ssm_heads",),
             "dt_bias": ("ssm_heads",), "d_skip": ("ssm_heads",),
             "norm": ("ssm_inner",), "w_out": ("ssm_inner", "embed")}
    return {"ln": (None,), "mixer": mixer}


def mamba2_block(p, cfg: Mamba2Config, x, state, decode=False):
    h, new_state = mamba2(p["mixer"], cfg, L.rmsnorm(x, p["ln"]), state,
                          decode=decode)
    return x + h, new_state
