"""Unified model builder: one ModelConfig + build_model() for all ten
assigned architectures (dense / MoE / VLM / enc-dec / RWKV6 / Zamba2).

``build_model(cfg)`` returns a ``Model`` with a functional API:
  init(key) -> params                      param_specs -> logical axes
  loss_fn(params, batch) -> (loss, metrics)             [train shapes]
  prefill(params, batch, cache) -> (logits, cache)      [prefill shapes]
  decode_step(params, tokens, cache) -> (logits, cache) [decode shapes]
  init_cache(batch, max_len) / cache_specs()
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import executor as xbar
from repro.core.engine import EngineConfig
from repro.core.executor import CrossbarExecutor
from repro.distributed.sharding import logical_constraint as lc
from repro.models import layers as L
from repro.models import rwkv as R
from repro.models import ssm as S
from repro.models import transformer as T
from repro.models.layers import AttnConfig, MoEConfig


def _pad_vocab(v: int, mult: int = 256) -> int:
    return -(-v // mult) * mult


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | vlm | encdec | rwkv6 | zamba2
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv: int = 0
    d_ff: int = 0
    vocab: int = 32000
    head_dim: int = 128
    act: str = "swiglu"
    qk_norm: bool = False
    norm: str = "rms"
    rope_theta: float = 1e6
    kv_repeat: int = 1             # Megatron KV replication for TP > n_kv
    moe_experts: int = 0
    moe_top_k: int = 2
    moe_capacity: float = 1.25
    ssm_state: int = 64
    ssm_head_dim: int = 64
    attn_every: int = 6            # zamba2 shared-attention period
    q_chunk: int = 0               # chunked attention (0 = off)
    chunk_unroll: bool = True
    lin_chunk: int = 16            # GLA chunk for rwkv/mamba
    remat: str = "none"            # none | full | dots
    scan_layers: bool = True
    dtype: Any = jnp.bfloat16      # activation/compute dtype
    kv_dtype: Any = None           # KV-cache storage dtype (None = dtype);
    # fp8 halves decode's dominant memory term — the CrossStack low-bit-cell
    # argument applied to the cache (§Perf)
    tie_embeddings: bool = False
    paged_kernel: bool = False     # paged decode via the Pallas kernel
    paged_stream_pages: int = 0    # streamed-lane threshold in pages
    # (>= this many table pages -> online-softmax block streaming; 0 =
    # always the bitwise gather-scratch lane); see kernels/paged_attention
    paged_block_pages: int = 16    # pages per streamed block
    backend: str = "digital"       # "digital" | "crossbar" (weight-resident)
    xbar: EngineConfig = EngineConfig(mode="deepnet")  # crossbar-backend cfg

    @property
    def padded_vocab(self) -> int:
        return _pad_vocab(self.vocab)

    @property
    def attn(self) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads, n_kv=self.n_kv,
            head_dim=self.head_dim, qk_norm=self.qk_norm,
            rope_theta=self.rope_theta, kv_repeat=self.kv_repeat,
            mrope=(self.family == "vlm"), q_chunk=self.q_chunk,
            chunk_unroll=self.chunk_unroll,
            paged_kernel=self.paged_kernel,
            paged_stream_pages=self.paged_stream_pages,
            paged_block_pages=self.paged_block_pages)

    @property
    def moe(self) -> Optional[MoEConfig]:
        if self.moe_experts == 0:
            return None
        return MoEConfig(self.moe_experts, self.moe_top_k,
                         self.moe_capacity, self.act)

    @property
    def block_cfg(self) -> T.BlockConfig:
        return T.BlockConfig(attn=self.attn, d_ff=self.d_ff, act=self.act,
                             moe=self.moe, norm=self.norm,
                             cross_attn=(self.family == "encdec"))

    @property
    def rwkv(self) -> R.RWKVConfig:
        return R.RWKVConfig(d_model=self.d_model, n_layers=self.n_layers,
                            head_dim=self.ssm_head_dim, vocab=self.vocab,
                            ffn_mult=self.d_ff / self.d_model,
                            chunk=self.lin_chunk,
                            chunk_unroll=self.chunk_unroll)

    @property
    def mamba(self) -> S.Mamba2Config:
        return S.Mamba2Config(d_model=self.d_model, d_state=self.ssm_state,
                              head_dim=self.ssm_head_dim,
                              chunk=self.lin_chunk,
                              chunk_unroll=self.chunk_unroll)

    # zamba2 layout: n_super super-blocks of (shared attn + attn_every
    # mamba layers) + trailing mamba layers
    @property
    def zamba_layout(self) -> Tuple[int, int]:
        n_super = self.n_layers // self.attn_every
        trailing = self.n_layers - n_super * self.attn_every
        return n_super, trailing


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    init: Any
    param_specs: Any
    loss_fn: Any
    prefill: Any
    decode_step: Any
    init_cache: Any
    cache_specs: Any
    executor: Optional[CrossbarExecutor] = None  # crossbar backend only
    # paged-KV serving (transformer decoder families; None elsewhere)
    init_paged_cache: Any = None
    paged_cache_specs: Any = None
    # copy-on-write page duplication for the prefix-sharing scheduler
    copy_paged_page: Any = None


# ---------------------------------------------------------------------------
# transformer families (dense / moe / vlm / encdec)
# ---------------------------------------------------------------------------

def _build_transformer(cfg: ModelConfig) -> Model:
    bc = cfg.block_cfg
    enc_bc = dataclasses.replace(
        bc, cross_attn=False,
        attn=dataclasses.replace(bc.attn, causal=False))
    pv = cfg.padded_vocab
    executor = (CrossbarExecutor(cfg.xbar) if cfg.backend == "crossbar"
                else None)
    # crossbar tiles are addressed by layer NAME, so the layer loop must be
    # unrolled (Python ints, not a scanned carry index)
    scan_layers = cfg.scan_layers and executor is None

    def init(key):
        ks = jax.random.split(key, 4)
        p: Dict[str, Any] = {}
        p["embed"], _ = T.embed_init(ks[0], pv, cfg.d_model)
        p["blocks"], _ = T.stack_init(ks[1], bc, cfg.n_layers)
        p["ln_f"], _ = L.rmsnorm_init(cfg.d_model)
        if not cfg.tie_embeddings:
            p["head"] = jax.random.normal(
                ks[2], (cfg.d_model, pv)) * cfg.d_model ** -0.5
        if cfg.family == "encdec":
            p["enc_blocks"], _ = T.stack_init(ks[3], enc_bc, cfg.n_layers)
            p["enc_ln_f"], _ = L.rmsnorm_init(cfg.d_model)
        return p

    def param_specs():
        p: Dict[str, Any] = {}
        p["embed"] = {"tok": ("vocab", "embed")}
        p["blocks"] = T.stack_specs(bc)
        p["ln_f"] = (None,)
        if not cfg.tie_embeddings:
            p["head"] = ("embed", "vocab")
        if cfg.family == "encdec":
            p["enc_blocks"] = T.stack_specs(enc_bc)
            p["enc_ln_f"] = (None,)
        return p

    def _positions(batch, sq, offset=None):
        if cfg.family == "vlm":
            return batch["positions_thw"]
        pos = jnp.arange(sq)[None]
        if offset is not None:
            pos = pos + offset[:, None]
        return jnp.broadcast_to(pos, (batch["tokens"].shape[0], sq))

    def _trunk(p, x, positions, caches=None, cross_kv=None, cross_len=None):
        with xbar.scope("blocks"):
            return T.stack_apply(p["blocks"], bc, x, positions,
                                 caches=caches, cross_kv=cross_kv,
                                 cross_len=cross_len, remat=cfg.remat,
                                 scan=scan_layers)

    def _encode(p, batch):
        enc = batch["enc_emb"].astype(cfg.dtype)
        pos = jnp.broadcast_to(jnp.arange(enc.shape[1])[None],
                               enc.shape[:2])
        with xbar.scope("enc_blocks"):
            h, _, _ = T.stack_apply(p["enc_blocks"], enc_bc, enc, pos,
                                    remat=cfg.remat, scan=scan_layers)
        return L.rmsnorm(h, p["enc_ln_f"])

    def _cross_kv(p, enc_out):
        """Per-layer cross-attention K/V from encoder output (stacked)."""

        def one(pl):
            k = jnp.einsum("bsd,dhk->bshk", enc_out,
                           pl["xattn"]["wk"].astype(enc_out.dtype))
            v = jnp.einsum("bsd,dhk->bshk", enc_out,
                           pl["xattn"]["wv"].astype(enc_out.dtype))
            return k, v

        return jax.lax.map(one, p["blocks"])

    def _logits(p, x):
        x = lc(x, ("batch", "seq_act", "act_embed"))  # SP gather point
        if not cfg.tie_embeddings:
            logits = xbar.crossbar_linear(
                x, p["head"], "head",
                digital=lambda: T.unembed(p["embed"], x, head=p["head"]))
            return lc(logits, ("batch", None, "vocab"))
        return T.unembed(p["embed"], x)

    def _embed_inputs(p, batch):
        x = T.embed(p["embed"], batch["tokens"]).astype(cfg.dtype)
        if cfg.family == "vlm" and "vis_emb" in batch:
            x = jnp.concatenate([batch["vis_emb"].astype(cfg.dtype), x],
                                axis=1)
        return x

    def loss_fn(params, batch):
        x = _embed_inputs(params, batch)
        sq = x.shape[1]
        cross_kv = cross_len = None
        if cfg.family == "encdec":
            enc_out = _encode(params, batch)
            cross_kv = _cross_kv(params, enc_out)
        pos = _positions(batch, sq)
        h, _, aux = _trunk(params, x, pos, cross_kv=cross_kv)
        h = L.rmsnorm(h, params["ln_f"])
        if cfg.family == "vlm" and "vis_emb" in batch:
            h = h[:, batch["vis_emb"].shape[1]:]
        logits = _logits(params, h)
        loss = T.xent_loss(logits, batch["labels"],
                           batch.get("loss_mask"), vocab=cfg.vocab)
        if cfg.moe is not None:
            loss = loss + 0.01 * aux / cfg.n_layers
        return loss, {"loss": loss, "aux": aux}

    def init_cache(batch: int, max_len: int, src_len: int = 0):
        one = L.init_cache(bc.attn, batch, max_len,
                           dtype=cfg.kv_dtype or cfg.dtype)
        caches = {k: jnp.zeros((cfg.n_layers,) + v.shape, v.dtype)
                  for k, v in one.items()}
        out = {"layers": caches}
        if cfg.family == "encdec" and src_len:
            kv = jnp.zeros((cfg.n_layers, batch, src_len,
                            bc.attn.kv_eff, bc.attn.head_dim), cfg.dtype)
            # dict, NOT tuple: a tuple-of-tuples would read as one spec
            # leaf in cache_specs and silently replicate 10s of GiB
            out["cross_kv"] = {"k": kv, "v": kv}
        return out

    def cache_specs():
        cs = L.cache_specs(bc.attn)
        out = {"layers": jax.tree.map(
            lambda names: ("layers",) + names, cs,
            is_leaf=lambda x: type(x) is tuple)}
        if cfg.family == "encdec":
            kv_spec = ("layers", "batch", "kv_seq", "act_kv_heads", None)
            out["cross_kv"] = {"k": kv_spec, "v": kv_spec}
        return out

    def init_paged_cache(batch: int, max_len: int, n_pages: int,
                         page_size: int):
        """Paged KV cache: physical page pools + per-row page tables,
        stacked across layers like ``init_cache``.  The table is
        replicated per layer so the one cache pytree flows through
        ``stack_apply`` (scan and unrolled) unchanged."""
        if cfg.family == "encdec":
            raise ValueError("paged KV serving targets decoder-only "
                             "families (no cross-attention cache)")
        one = L.paged_init_cache(bc.attn, batch, max_len, n_pages,
                                 page_size, dtype=cfg.kv_dtype or cfg.dtype)
        caches = {k: jnp.zeros((cfg.n_layers,) + v.shape, v.dtype)
                  for k, v in one.items()}
        return {"layers": caches}

    def paged_cache_specs():
        cs = L.paged_cache_specs(bc.attn)
        return {"layers": jax.tree.map(
            lambda names: ("layers",) + names, cs,
            is_leaf=lambda x: type(x) is tuple)}

    def copy_paged_page(cache, src, dst):
        """Copy-on-write plumbing: duplicate physical KV page ``src``
        into ``dst`` in every layer (page tables untouched — the pool
        retargets them host-side).  One jitted copy serves every page
        pair; see ``layers.paged_copy_page``."""
        return dict(cache, layers=L.paged_copy_page(
            cache["layers"], jnp.int32(src), jnp.int32(dst)))

    def prefill(params, batch, cache):
        """Prefill the KV cache with a full prompt; returns last logits.

        Uses the cache-aware attention path (dynamic_update_slice at
        position 0 + length-masked SDPA) so prefill and decode share one
        code path."""
        x = _embed_inputs(params, batch)
        sq = x.shape[1]
        pos = _positions(batch, sq)
        cross_kv = None
        if cfg.family == "encdec":
            enc_out = _encode(params, batch)
            cross_kv = _cross_kv(params, enc_out)
        h, new_layers, _ = _trunk(params, x, pos, caches=cache["layers"],
                                  cross_kv=cross_kv)
        h = L.rmsnorm(h, params["ln_f"])
        logits = _logits(params, h[:, -1:])
        cache = dict(cache, layers=new_layers)
        if cfg.family == "encdec":
            cache["cross_kv"] = {"k": cross_kv[0].astype(cfg.dtype),
                                 "v": cross_kv[1].astype(cfg.dtype)}
        return logits, cache

    def decode_step(params, tokens, cache):
        x = T.embed(params["embed"], tokens).astype(cfg.dtype)
        offset = cache["layers"]["len"][0]
        sq = tokens.shape[1]
        if cfg.family == "vlm":
            pos1 = offset[:, None] + jnp.arange(sq, dtype=jnp.int32)[None]
            pos = jnp.broadcast_to(pos1[..., None], pos1.shape + (3,))
        else:
            pos = offset[:, None] + jnp.arange(sq, dtype=jnp.int32)[None]
        ckv = cache.get("cross_kv")
        cross_kv = (ckv["k"], ckv["v"]) if ckv is not None else None
        h, new_layers, _ = _trunk(params, x, pos, caches=cache["layers"],
                                  cross_kv=cross_kv)
        h = L.rmsnorm(h, params["ln_f"])
        logits = _logits(params, h)
        return logits, dict(cache, layers=new_layers)

    def _on_crossbar(fn):
        """Inference entry points read the resident tiles.

        Programming happens on the first *eager* call (or explicitly via
        ``model.executor.program_params``); under jit the tiles must
        already be resident.  The training path (``loss_fn``) stays
        digital — program-at-load is a deployment-side contract.
        """
        if executor is None:
            return fn

        def wrapped(params, *args, **kwargs):
            executor.ensure_programmed(params)
            with executor.activate():
                return fn(params, *args, **kwargs)

        return wrapped

    return Model(cfg, init, param_specs, loss_fn, _on_crossbar(prefill),
                 _on_crossbar(decode_step), init_cache, cache_specs,
                 executor=executor, init_paged_cache=init_paged_cache,
                 paged_cache_specs=paged_cache_specs,
                 copy_paged_page=copy_paged_page)


# ---------------------------------------------------------------------------
# RWKV6
# ---------------------------------------------------------------------------

def _build_rwkv(cfg: ModelConfig) -> Model:
    rc = cfg.rwkv
    pv = cfg.padded_vocab

    def init(key):
        ks = jax.random.split(key, 3)
        blocks = [R.block_init(k, rc)[0]
                  for k in jax.random.split(ks[1], cfg.n_layers)]
        return {
            "embed": {"tok": jax.random.normal(ks[0], (pv, cfg.d_model))
                      * 0.02},
            "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
            "ln_f": jnp.ones((cfg.d_model,)),
            "head": jax.random.normal(ks[2], (cfg.d_model, pv))
            * cfg.d_model ** -0.5,
        }

    def param_specs():
        bs = jax.tree.map(lambda n: ("layers",) + n, R.block_specs(rc),
                          is_leaf=lambda x: type(x) is tuple)
        return {"embed": {"tok": ("vocab", "embed")}, "blocks": bs,
                "ln_f": (None,), "head": ("embed", "vocab")}

    def _run(params, x, states, decode):
        if not cfg.scan_layers:   # unrolled (dry-run cost probes)
            one = T._remat(
                lambda p_l, xc, st_l: R.block(p_l, rc, xc, st_l,
                                              decode=decode), cfg.remat)
            outs = []
            for l in range(cfg.n_layers):
                p_l = jax.tree.map(lambda a: a[l], params["blocks"])
                st_l = jax.tree.map(lambda a: a[l], states)
                x, new_st = one(p_l, x, st_l)
                outs.append(new_st)
            return x, jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

        def body(carry, pl):
            xc = carry
            p_l, st_l = pl
            xo, new_st = R.block(p_l, rc, xc, st_l, decode=decode)
            return xo, new_st

        body = T._remat(body, cfg.remat)
        x, new_states = jax.lax.scan(body, x, (params["blocks"], states))
        return x, new_states

    def init_cache(batch: int, max_len: int = 0):
        one = R.init_state(rc, batch)
        return {"layers": jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), one)}

    def cache_specs():
        cs = R.state_specs(rc)
        return {"layers": jax.tree.map(
            lambda n: ("layers",) + n, cs,
            is_leaf=lambda x: type(x) is tuple)}

    def loss_fn(params, batch):
        x = T.embed(params["embed"], batch["tokens"]).astype(cfg.dtype)
        states = init_cache(x.shape[0])["layers"]
        h, _ = _run(params, x, states, decode=False)
        h = L.rmsnorm(h, params["ln_f"])
        logits = T.unembed(params["embed"], h, head=params["head"])
        loss = T.xent_loss(logits, batch["labels"],
                           batch.get("loss_mask"), vocab=cfg.vocab)
        return loss, {"loss": loss}

    def prefill(params, batch, cache):
        x = T.embed(params["embed"], batch["tokens"]).astype(cfg.dtype)
        h, new_states = _run(params, x, cache["layers"], decode=False)
        h = L.rmsnorm(h, params["ln_f"])
        logits = T.unembed(params["embed"], h[:, -1:], head=params["head"])
        return logits, {"layers": new_states}

    def decode_step(params, tokens, cache):
        x = T.embed(params["embed"], tokens).astype(cfg.dtype)
        h, new_states = _run(params, x, cache["layers"], decode=True)
        h = L.rmsnorm(h, params["ln_f"])
        logits = T.unembed(params["embed"], h, head=params["head"])
        return logits, {"layers": new_states}

    return Model(cfg, init, param_specs, loss_fn, prefill, decode_step,
                 init_cache, cache_specs)


# ---------------------------------------------------------------------------
# Zamba2 hybrid
# ---------------------------------------------------------------------------

def _build_zamba(cfg: ModelConfig) -> Model:
    mc = cfg.mamba
    bc = dataclasses.replace(cfg.block_cfg, moe=None)
    n_super, trailing = cfg.zamba_layout
    pv = cfg.padded_vocab

    def init(key):
        ks = jax.random.split(key, 5)
        inner = []
        for k_s in jax.random.split(ks[1], n_super):
            blocks = [S.mamba2_block_init(k, mc)[0]
                      for k in jax.random.split(k_s, cfg.attn_every)]
            inner.append(jax.tree.map(lambda *xs: jnp.stack(xs), *blocks))
        tail = [S.mamba2_block_init(k, mc)[0]
                for k in jax.random.split(ks[2], max(trailing, 1))]
        return {
            "embed": {"tok": jax.random.normal(ks[0], (pv, cfg.d_model))
                      * 0.02},
            "shared_attn": T.block_init(ks[3], bc)[0],  # ONE shared block
            "supers": jax.tree.map(lambda *xs: jnp.stack(xs), *inner),
            "tail": jax.tree.map(lambda *xs: jnp.stack(xs), *tail),
            "ln_f": jnp.ones((cfg.d_model,)),
            "head": jax.random.normal(ks[4], (cfg.d_model, pv))
            * cfg.d_model ** -0.5,
        }

    def param_specs():
        ms = S.mamba2_block_specs(mc)
        as_ = T.block_specs(bc)
        pre2 = jax.tree.map(lambda n: ("layers", "layers") + n, ms,
                            is_leaf=lambda x: type(x) is tuple)
        pre1 = jax.tree.map(lambda n: ("layers",) + n, ms,
                            is_leaf=lambda x: type(x) is tuple)
        return {"embed": {"tok": ("vocab", "embed")},
                "shared_attn": as_, "supers": pre2, "tail": pre1,
                "ln_f": (None,), "head": ("embed", "vocab")}

    def init_cache(batch: int, max_len: int):
        attn_cache = L.init_cache(bc.attn, batch, max_len,
                                  dtype=cfg.kv_dtype or cfg.dtype)
        m_state = S.mamba2_state(mc, batch)
        return {
            "attn": jax.tree.map(
                lambda a: jnp.zeros((n_super,) + a.shape, a.dtype),
                attn_cache),
            "mamba": jax.tree.map(
                lambda a: jnp.zeros((n_super, cfg.attn_every) + a.shape,
                                    a.dtype), m_state),
            "tail": jax.tree.map(
                lambda a: jnp.zeros((max(trailing, 1),) + a.shape, a.dtype),
                m_state),
        }

    def cache_specs():
        acs = L.cache_specs(bc.attn)
        mcs = S.mamba2_state_specs(mc)
        return {
            "attn": jax.tree.map(lambda n: ("layers",) + n, acs,
                                 is_leaf=lambda x: type(x) is tuple),
            "mamba": jax.tree.map(lambda n: ("layers", "layers") + n, mcs,
                                  is_leaf=lambda x: type(x) is tuple),
            "tail": jax.tree.map(lambda n: ("layers",) + n, mcs,
                                 is_leaf=lambda x: type(x) is tuple),
        }

    def _run(params, x, cache, positions, decode):
        """Scan over super-blocks: shared attn (per-app cache) + mamba x6.

        Attention KV caches ride in the scan carry (in-place updates; at
        long_500k they are the dominant buffers); mamba states are small
        and flow as xs/ys."""

        def super_body(carry, per):
            xc, attn_caches, idx = carry
            p_super, m_states = per
            attn_cache = jax.tree.map(
                lambda c: jax.lax.dynamic_index_in_dim(c, idx, 0,
                                                       keepdims=False),
                attn_caches)
            xo, new_attn, _ = T.block(params["shared_attn"], bc, xc,
                                      positions, cache=attn_cache)
            attn_caches = jax.tree.map(
                lambda c, nc: jax.lax.dynamic_update_index_in_dim(
                    c, nc.astype(c.dtype), idx, 0), attn_caches, new_attn)

            def inner_body(c2, per2):
                p_m, st = per2
                y, new_st = S.mamba2_block(p_m, mc, c2, st, decode=decode)
                return y, new_st

            if not cfg.scan_layers:   # unroll inner loop too (cost probes)
                new_sts = []
                for m_i in range(cfg.attn_every):
                    per2 = jax.tree.map(lambda a: a[m_i],
                                        (p_super, m_states))
                    xo, new_st = inner_body(xo, per2)
                    new_sts.append(new_st)
                new_m = jax.tree.map(lambda *xs: jnp.stack(xs), *new_sts)
            else:
                xo, new_m = jax.lax.scan(inner_body, xo,
                                         (p_super, m_states))
            return (xo, attn_caches, idx + 1), new_m

        super_body = T._remat(super_body, cfg.remat)
        if not cfg.scan_layers:   # unrolled (dry-run cost probes)
            carry = (x, cache["attn"], jnp.int32(0))
            new_ms = []
            for s_i in range(n_super):
                per = jax.tree.map(lambda a: a[s_i],
                                   (params["supers"], cache["mamba"]))
                carry, nm = super_body(carry, per)
                new_ms.append(nm)
            x, new_attn, _ = carry
            new_m = jax.tree.map(lambda *xs: jnp.stack(xs), *new_ms)
        else:
            (x, new_attn, _), new_m = jax.lax.scan(
                super_body, (x, cache["attn"], jnp.int32(0)),
                (params["supers"], cache["mamba"]))

        def tail_body(c2, per2):
            p_m, st = per2
            y, new_st = S.mamba2_block(p_m, mc, c2, st, decode=decode)
            return y, new_st

        if trailing > 0:
            x, new_tail = jax.lax.scan(tail_body, x,
                                       (params["tail"], cache["tail"]))
        else:
            new_tail = cache["tail"]
        return x, {"attn": new_attn, "mamba": new_m, "tail": new_tail}

    def loss_fn(params, batch):
        x = T.embed(params["embed"], batch["tokens"]).astype(cfg.dtype)
        b, sq = batch["tokens"].shape
        cache = init_cache(b, sq)
        pos = jnp.broadcast_to(jnp.arange(sq)[None], (b, sq))
        h, _ = _run(params, x, cache, pos, decode=False)
        h = L.rmsnorm(h, params["ln_f"])
        logits = T.unembed(params["embed"], h, head=params["head"])
        loss = T.xent_loss(logits, batch["labels"],
                           batch.get("loss_mask"), vocab=cfg.vocab)
        return loss, {"loss": loss}

    def prefill(params, batch, cache):
        x = T.embed(params["embed"], batch["tokens"]).astype(cfg.dtype)
        b, sq = batch["tokens"].shape
        pos = jnp.broadcast_to(jnp.arange(sq)[None], (b, sq))
        h, new_cache = _run(params, x, cache, pos, decode=False)
        h = L.rmsnorm(h, params["ln_f"])
        logits = T.unembed(params["embed"], h[:, -1:], head=params["head"])
        return logits, new_cache

    def decode_step(params, tokens, cache):
        x = T.embed(params["embed"], tokens).astype(cfg.dtype)
        offset = cache["attn"]["len"][0, 0]
        pos = jnp.broadcast_to(offset[None, None], tokens.shape)
        h, new_cache = _run(params, x, cache, pos, decode=True)
        h = L.rmsnorm(h, params["ln_f"])
        logits = T.unembed(params["embed"], h, head=params["head"])
        return logits, new_cache

    return Model(cfg, init, param_specs, loss_fn, prefill, decode_step,
                 init_cache, cache_specs)


def build_model(cfg: ModelConfig) -> Model:
    if cfg.backend not in ("digital", "crossbar"):
        raise ValueError(f"unknown backend {cfg.backend!r}")
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        return _build_transformer(cfg)
    if cfg.backend == "crossbar":
        raise ValueError(
            f"backend='crossbar' supports transformer families only, "
            f"not {cfg.family!r}")
    if cfg.family == "rwkv6":
        return _build_rwkv(cfg)
    if cfg.family == "zamba2":
        return _build_zamba(cfg)
    raise ValueError(f"unknown family {cfg.family}")
