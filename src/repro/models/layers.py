"""Model-zoo building blocks: norms, RoPE/M-RoPE, GQA attention (qk-norm,
KV cache, chunked/causal), dense MLPs (SwiGLU / squared-ReLU / GELU) and
token-dropping MoE with group-local sort-based dispatch.

Everything is a pure function over explicit param dicts.  Each ``init_*``
returns ``(params, specs)`` where specs mirror params with tuples of
*logical* axis names consumed by distributed/sharding.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.executor import crossbar_linear
from repro.distributed.sharding import logical_constraint as lc

Params = Dict[str, Any]


# -- initializers -------------------------------------------------------------

def _normal(key, shape, scale, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def dense_init(key, d_in: int, d_out: int, spec=("embed", "mlp"),
               scale: Optional[float] = None):
    scale = scale if scale is not None else d_in ** -0.5
    return _normal(key, (d_in, d_out), scale), spec


# -- norms --------------------------------------------------------------------

def rmsnorm_init(d: int, spec=(None,)):
    return jnp.ones((d,), jnp.float32), spec


def rmsnorm(x, w, eps: float = 1e-6):
    # statistics in f32; the normalized tensor is cast back BEFORE the
    # weight multiply so the op feeding any downstream sharding constraint
    # is a bf16 multiply — otherwise XLA hoists SP all-gathers above the
    # final convert and moves the activation in f32 (2x bytes; §Perf H1).
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    normed = (x32 * jax.lax.rsqrt(var + eps)).astype(dtype)
    return normed * w.astype(dtype)


def layernorm_init(d: int):
    return {"w": jnp.ones((d,), jnp.float32),
            "b": jnp.zeros((d,), jnp.float32)}, {"w": (None,), "b": (None,)}


def layernorm(x, p, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps) * p["w"] + p["b"]).astype(dtype)


# -- rotary embeddings ---------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 1e6):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 1e6):
    """x: (B, S, H, D), positions: (B, S) int32."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                                # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * inv      # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions_thw, theta: float = 1e6, sections=None):
    """Qwen2-VL multimodal RoPE: positions_thw (B, S, 3) = (t, h, w) ids.

    The head_dim/2 frequency slots are split into (temporal, height, width)
    sections; each section rotates by its own position stream.  Text tokens
    carry t == h == w, reducing to standard RoPE.  Default split follows
    Qwen2-VL's 1:1.5:1.5 ratio ((16, 24, 24) at head_dim = 128).
    """
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                                # (D/2,)
    n = d // 2
    if sections is None:
        t = n // 4
        h = (n - t) // 2
        sections = (t, h, n - t - h)
    assert sum(sections) == n, (sections, n)
    sec_ids = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                         total_repeat_length=n)               # (D/2,)
    pos = jnp.take_along_axis(
        positions_thw.astype(jnp.float32),
        jnp.broadcast_to(sec_ids[None, None, :],
                         positions_thw.shape[:2] + (n,)).astype(jnp.int32),
        axis=-1)                                              # (B, S, D/2)
    ang = pos * inv
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- attention -----------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    qk_norm: bool = False
    rope_theta: float = 1e6
    kv_repeat: int = 1          # Megatron-style KV replication for TP > n_kv
    causal: bool = True
    mrope: bool = False
    q_chunk: int = 0            # 0 = unchunked; else chunk the query axis
    chunk_unroll: bool = True   # unroll the q-chunk loop (see DESIGN §5)
    paged_kernel: bool = False  # paged decode via the Pallas kernel
    kernel_interpret: bool = True  # Pallas interpret mode (CPU container)
    paged_stream_pages: int = 0  # stream the paged kernel (online-softmax
    # block lane) when the page table is >= this many pages; 0 = always
    # the gather-scratch lane (the bitwise small-window fast path)
    paged_block_pages: int = 16  # pages per streamed block (VMEM knob)

    @property
    def kv_eff(self) -> int:
        return self.n_kv * self.kv_repeat


def attn_init(key, cfg: AttnConfig):
    ks = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.head_dim
    p: Params = {
        "wq": _normal(ks[0], (d, cfg.n_heads, hd), d ** -0.5),
        "wk": _normal(ks[1], (d, cfg.kv_eff, hd), d ** -0.5),
        "wv": _normal(ks[2], (d, cfg.kv_eff, hd), d ** -0.5),
        "wo": _normal(ks[3], (cfg.n_heads, hd, d),
                      (cfg.n_heads * hd) ** -0.5),
    }
    s: Params = {
        "wq": ("embed", "heads", None),
        "wk": ("embed", "kv_heads", None),
        "wv": ("embed", "kv_heads", None),
        "wo": ("heads", None, "embed"),
    }
    if cfg.qk_norm:
        p["q_norm"], s["q_norm"] = rmsnorm_init(hd)
        p["k_norm"], s["k_norm"] = rmsnorm_init(hd)
    return p, s


def _qkv_proj(x, w, name):
    """q/k/v projection, routable onto resident crossbar tiles."""
    return crossbar_linear(
        x, w, name,
        digital=lambda: jnp.einsum("bsd,dhk->bshk", x, w.astype(x.dtype)))


def _project_qkv(p, cfg: AttnConfig, x, positions):
    q = _qkv_proj(x, p["wq"], "wq")
    k = _qkv_proj(x, p["wk"], "wk")
    v = _qkv_proj(x, p["wv"], "wv")
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)
    elif positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = lc(q, ("batch", None, "act_heads", None))
    k = lc(k, ("batch", None, "act_kv_heads", None))
    v = lc(v, ("batch", None, "act_kv_heads", None))
    return q, k, v


def _sdpa(q, k, v, cfg: AttnConfig, q_offset, kv_len=None,
          cross: bool = False):
    """Grouped scaled-dot-product attention on (B, S, H, D) tensors.

    q_offset: absolute position of q[.., 0] for causal masking — a
              scalar, or (B,) when slots sit at different depths
              (continuous batching admits prompts of different lengths).
    kv_len:   (B,) valid KV lengths (decode), or None for full.
    """
    b, sq, hq, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = hq // kv
    if k.dtype != q.dtype:   # low-precision (fp8) cache: upcast fuses
        k = k.astype(q.dtype)  # into the dot, no materialized copy
        v = v.astype(q.dtype)
    qg = q.reshape(b, sq, kv, g, hd)
    scale = hd ** -0.5
    # bf16 operands, f32 ACCUMULATION: never materialize an f32 copy of the
    # (potentially huge) K tensor — MXU-style mixed precision.
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if cfg.causal and not cross:
        qpos = (jnp.asarray(q_offset).reshape(-1, 1)
                + jnp.arange(sq)[None])                   # (B or 1, sq)
        mask = qpos[:, :, None] >= jnp.arange(sk)[None, None, :]
        logits = jnp.where(mask[:, None, None], logits, -1e30)
    if kv_len is not None:
        valid = jnp.arange(sk)[None, :] < kv_len[:, None]
        logits = jnp.where(valid[:, None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w.astype(v.dtype), v)
    return out.reshape(b, sq, hq, hd)


def _chunked_sdpa(q, k, v, cfg: AttnConfig, kv_len=None,
                  cross: bool = False, q_offset=0):
    """Q-axis-chunked SDPA: bounds the scores working set to
    (B, H, q_chunk, S_k) — applies to self, cross, AND cache-prefill
    attention (a 32k x 32k unchunked score tensor is tens of GiB)."""
    b, sq = q.shape[:2]
    if not (cfg.q_chunk and sq > cfg.q_chunk and sq % cfg.q_chunk == 0):
        return _sdpa(q, k, v, cfg, q_offset, kv_len=kv_len, cross=cross)
    nq = sq // cfg.q_chunk
    qs = q.reshape(b, nq, cfg.q_chunk, *q.shape[2:])

    def one(i, qi):
        return _sdpa(qi, k, v, cfg, q_offset + i * cfg.q_chunk,
                     kv_len=kv_len, cross=cross)

    if cfg.chunk_unroll:
        outs = [one(i, qs[:, i]) for i in range(nq)]
        out = jnp.stack(outs, axis=1)
    else:
        def body(_, iq):
            i, qi = iq
            return 0.0, one(i, qi)

        _, outs = jax.lax.scan(body, 0.0,
                               (jnp.arange(nq), qs.swapaxes(0, 1)))
        out = outs.swapaxes(0, 1)
    return out.reshape(b, sq, *q.shape[2:])


def attention(p, cfg: AttnConfig, x, positions, cache=None,
              cross_kv=None, kv_len=None):
    """Returns (y, new_cache).

    cache: None (training / prefill-no-cache) or dict with
      k, v: (B, S_max, kv_eff, hd) and "len": (B,) int32 fill marker.
    cross_kv: (k, v) precomputed for encoder-decoder cross attention.
    """
    b, sq, _ = x.shape
    if cross_kv is not None:
        q = _qkv_proj(x, p["wq"], "wq")
        if cfg.qk_norm:
            q = rmsnorm(q, p["q_norm"])
        k, v = cross_kv
        out = _chunked_sdpa(q, k, v, cfg, kv_len=kv_len, cross=True)
        new_cache = cache
    elif cache is None:
        q, k, v = _project_qkv(p, cfg, x, positions)
        out = _chunked_sdpa(q, k, v, cfg)
        new_cache = None
    elif "pt" in cache:
        # paged decode: route this window's K/V writes through the page
        # table, then attend over the gathered logical view.  ``pt`` maps
        # each row's logical pages to physical pages of the pool arrays
        # (leading axis n_pages + 1); physical page 0 is the reserved
        # null page — unallocated entries point at it, so out-of-range
        # writes land there (the dense path's dropped-OOB-scatter
        # semantics) and gathers through it read only masked positions.
        q, k, v = _project_qkv(p, cfg, x, positions)
        pos = cache["len"]                                # (B,)
        pt = cache["pt"]                                  # (B, P_seq)
        ps = cache["k"].shape[1]
        depth = pt.shape[1] * ps                          # == max_len
        s_idx = pos[:, None] + jnp.arange(sq)[None]       # (B, sq)
        inb = s_idx < depth
        lpage = jnp.minimum(s_idx // ps, pt.shape[1] - 1)
        phys = jnp.where(inb, jnp.take_along_axis(pt, lpage, axis=1), 0)
        slot = jnp.where(inb, s_idx % ps, 0)
        ck = cache["k"].at[phys, slot].set(k.astype(cache["k"].dtype))
        cv = cache["v"].at[phys, slot].set(v.astype(cache["v"].dtype))
        new_len = cache["len"] + sq
        if cfg.paged_kernel:
            from repro.kernels.paged_attention import paged_attention
            out = paged_attention(q, ck, cv, pt, new_len, pos,
                                  causal=cfg.causal,
                                  interpret=cfg.kernel_interpret,
                                  stream_min_pages=cfg.paged_stream_pages,
                                  block_pages=cfg.paged_block_pages)
        else:
            # gather width is exactly max_len (page_size | max_len), so
            # the SDPA below sees the same einsum shapes as the dense
            # branch — the bit-exactness contract with that path
            gk = ck[pt].reshape(b, depth, *ck.shape[2:])
            gv = cv[pt].reshape(b, depth, *cv.shape[2:])
            out = _chunked_sdpa(q, gk, gv, cfg, kv_len=new_len,
                                q_offset=pos)
        new_cache = {"k": ck, "v": cv, "len": new_len, "pt": pt}
    else:
        # decode: append this step's K/V at each row's own fill position —
        # slots admitted with different prompt lengths sit at different
        # depths, so the write index and causal offset are per-row
        q, k, v = _project_qkv(p, cfg, x, positions)
        pos = cache["len"]                                # (B,)
        b_idx = jnp.arange(x.shape[0])[:, None]
        s_idx = pos[:, None] + jnp.arange(sq)[None]
        ck = cache["k"].at[b_idx, s_idx].set(k.astype(cache["k"].dtype))
        cv = cache["v"].at[b_idx, s_idx].set(v.astype(cache["v"].dtype))
        new_len = cache["len"] + sq
        out = _chunked_sdpa(q, ck, cv, cfg, kv_len=new_len, q_offset=pos)
        new_cache = {"k": ck, "v": cv, "len": new_len}
    # explicit bf16 dot output: the TP partial-sum all-reduce then moves
    # bf16, not the f32 accumulator JAX requests by default (§Perf H1)
    y = crossbar_linear(
        out, p["wo"], "wo",
        digital=lambda: jnp.einsum("bshk,hkd->bsd", out,
                                   p["wo"].astype(x.dtype),
                                   preferred_element_type=x.dtype))
    return lc(y, ("batch", "seq", "act_embed")), new_cache


def init_cache(cfg: AttnConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    shape = (batch, max_len, cfg.kv_eff, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "len": jnp.zeros((batch,), jnp.int32)}


def cache_specs(cfg: AttnConfig):
    return {"k": ("batch", "kv_seq", "act_kv_heads", None),
            "v": ("batch", "kv_seq", "act_kv_heads", None),
            "len": ("batch",)}


def paged_init_cache(cfg: AttnConfig, batch: int, max_len: int,
                     n_pages: int, page_size: int, dtype=jnp.bfloat16):
    """Paged KV cache for one layer: a physical page pool plus per-row
    page tables.  Page 0 is the reserved null page (see serve/kv_pool.py);
    ``page_size`` must divide ``max_len`` so a full gather through the
    table is exactly ``max_len`` deep (the dense-path bit-exactness
    contract)."""
    if max_len % page_size:
        raise ValueError(f"page_size {page_size} must divide max_len "
                         f"{max_len}")
    shape = (n_pages + 1, page_size, cfg.kv_eff, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "len": jnp.zeros((batch,), jnp.int32),
            "pt": jnp.zeros((batch, max_len // page_size), jnp.int32)}


def paged_cache_specs(cfg: AttnConfig):
    # the page axis is unsharded: pages are lane-local working state
    return {"k": (None, None, "act_kv_heads", None),
            "v": (None, None, "act_kv_heads", None),
            "len": ("batch",), "pt": ("batch", None)}


@jax.jit
def paged_copy_page(layers, src, dst):
    """Duplicate physical page ``src`` into ``dst`` across every layer's
    K/V pool — the device half of copy-on-write (serve/kv_pool.py
    privatizes a shared page before a row's own tokens overwrite it).
    ``layers`` is the scheduler's stacked per-layer dict: ``k``/``v``
    are ``(n_layers, n_pages + 1, page_size, kv_eff, head_dim)``.
    ``src``/``dst`` are traced scalars, so ONE compiled copy serves
    every page pair (no per-page-id retrace); page tables and fill
    markers pass through untouched — the pool owns them."""
    out = dict(layers)
    for key in ("k", "v"):
        page = jax.lax.dynamic_index_in_dim(layers[key], src, axis=1,
                                            keepdims=True)
        out[key] = jax.lax.dynamic_update_slice_in_dim(
            layers[key], page, dst, axis=1)
    return out


# -- MLPs ----------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, act: str):
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        p = {"wi": _normal(ks[0], (d_model, d_ff), d_model ** -0.5),
             "wg": _normal(ks[1], (d_model, d_ff), d_model ** -0.5),
             "wo": _normal(ks[2], (d_ff, d_model), d_ff ** -0.5)}
        s = {"wi": ("embed", "mlp"), "wg": ("embed", "mlp"),
             "wo": ("mlp", "embed")}
    else:
        p = {"wi": _normal(ks[0], (d_model, d_ff), d_model ** -0.5),
             "wo": _normal(ks[2], (d_ff, d_model), d_ff ** -0.5)}
        s = {"wi": ("embed", "mlp"), "wo": ("mlp", "embed")}
    return p, s


def mlp(p, x, act: str):
    h = crossbar_linear(x, p["wi"], "wi",
                        digital=lambda: x @ p["wi"].astype(x.dtype))
    if act == "swiglu":
        g = crossbar_linear(x, p["wg"], "wg",
                            digital=lambda: x @ p["wg"].astype(x.dtype))
        h = jax.nn.silu(g) * h
    elif act == "relu2":                  # nemotron squared-ReLU
        h = jnp.square(jax.nn.relu(h))
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(act)
    h = lc(h, ("batch", None, "act_mlp"))

    def _wo_digital():
        from repro.distributed.sharding import tp_bf16_matmul
        y = tp_bf16_matmul(h, p["wo"].astype(x.dtype))  # opt-in (§Perf)
        if y is None:
            y = jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype),
                           preferred_element_type=x.dtype)
        return y

    y = crossbar_linear(h, p["wo"], "wo", digital=_wo_digital)
    return lc(y, ("batch", "seq", "act_embed"))


# -- Mixture of Experts ----------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int = 2
    capacity_factor: float = 1.25
    act: str = "swiglu"


def moe_init(key, d_model: int, d_ff: int, cfg: MoEConfig):
    ks = jax.random.split(key, 4)
    e = cfg.n_experts
    p = {"router": _normal(ks[0], (d_model, e), d_model ** -0.5),
         "wi": _normal(ks[1], (e, d_model, d_ff), d_model ** -0.5),
         "wo": _normal(ks[3], (e, d_ff, d_model), d_ff ** -0.5)}
    s = {"router": ("embed", None),
         "wi": ("experts", "embed", "expert_mlp"),
         "wo": ("experts", "expert_mlp", "embed")}
    if cfg.act == "swiglu":
        p["wg"] = _normal(ks[2], (e, d_model, d_ff), d_model ** -0.5)
        s["wg"] = ("experts", "embed", "expert_mlp")
    return p, s


def moe_block(p, x, cfg: MoEConfig):
    """Token-dropping top-k MoE with group-local sort-based dispatch.

    x: (B, S, d).  Groups are the (sharded) batch rows, so the argsort and
    scatter stay shard-local under pjit — no cross-device token exchange in
    the baseline layout (experts are TP-sharded on d_ff; see DESIGN.md for
    the all-to-all EP variant).  Capacity per group/expert:
      C = ceil(S * top_k * capacity_factor / n_experts).
    Tokens over capacity are dropped (standard dropping MoE); the residual
    stream carries them unchanged.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = max(1, int(-(-s * k * cfg.capacity_factor // e)))

    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype))
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_g, top_e = jax.lax.top_k(gates, k)                 # (B, S, k)
    top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)

    # flatten assignments within each group
    ids = top_e.reshape(b, s * k)                          # (B, S*k)
    gts = top_g.reshape(b, s * k)
    order = jnp.argsort(ids, axis=-1)                      # group-local sort
    ids_s = jnp.take_along_axis(ids, order, axis=-1)
    gts_s = jnp.take_along_axis(gts, order, axis=-1)
    tok_s = order // k                                     # source token

    # position within expert via running count over the sorted list
    same = (ids_s[:, :, None] == jnp.arange(e)[None, None, :])
    pos_all = jnp.cumsum(same, axis=1) - 1                 # (B, S*k, E)
    pos = jnp.take_along_axis(pos_all, ids_s[:, :, None],
                              axis=-1)[..., 0]             # (B, S*k)
    keep = pos < c
    dest = ids_s * c + jnp.minimum(pos, c - 1)             # (B, S*k)

    xs = jnp.take_along_axis(x, tok_s[..., None], axis=1)  # (B, S*k, d)
    xs = jnp.where(keep[..., None], xs, 0.0)
    buf = jnp.zeros((b, e * c, d), x.dtype)
    buf = jax.vmap(lambda bf, dst, val: bf.at[dst].add(val))(buf, dest, xs)
    buf = buf.reshape(b, e, c, d)
    buf = lc(buf, ("batch", None, None, "act_embed"))

    h = jnp.einsum("becd,edf->becf", buf, p["wi"].astype(x.dtype))
    if cfg.act == "swiglu":
        g = jnp.einsum("becd,edf->becf", buf, p["wg"].astype(x.dtype))
        h = jax.nn.silu(g) * h
    elif cfg.act == "gelu":
        h = jax.nn.gelu(h)
    elif cfg.act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    h = lc(h, ("batch", None, None, "act_mlp"))
    out = jnp.einsum("becf,efd->becd", h, p["wo"].astype(x.dtype),
                     preferred_element_type=x.dtype)  # bf16 TP all-reduce
    out = out.reshape(b, e * c, d)

    # gather back to sorted slots, weight by gates, unsort via scatter-add
    ys = jnp.take_along_axis(out, dest[..., None], axis=1)
    ys = ys * (gts_s * keep)[..., None].astype(x.dtype)
    y = jnp.zeros((b, s, d), x.dtype)
    y = jax.vmap(lambda acc, t, val: acc.at[t].add(val))(y, tok_s, ys)
    return lc(y, ("batch", "seq", "act_embed")), gates


def moe_aux_loss(gates: jax.Array, top_e: Optional[jax.Array] = None
                 ) -> jax.Array:
    """Switch-style load-balance loss: E * sum_e f_e * p_e."""
    e = gates.shape[-1]
    p_e = gates.mean(axis=tuple(range(gates.ndim - 1)))
    hard = jax.nn.one_hot(jnp.argmax(gates, -1), e)
    f_e = hard.mean(axis=tuple(range(hard.ndim - 1)))
    return e * jnp.sum(f_e * p_e)
