"""Decoder-only (dense + MoE) and encoder-decoder transformer stacks.

Layer-stacked parameters + ``lax.scan`` over layers (compile time and HLO
size independent of depth), with optional rematerialization policies.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import executor as xbar
from repro.distributed.sharding import logical_constraint as lc
from repro.models import layers as L
from repro.models.layers import AttnConfig, MoEConfig

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    attn: AttnConfig
    d_ff: int
    act: str = "swiglu"
    moe: Optional[MoEConfig] = None
    norm: str = "rms"            # "rms" | "ln"
    cross_attn: bool = False     # decoder block of an enc-dec model


def _norm_init(cfg: BlockConfig, d: int):
    if cfg.norm == "rms":
        return L.rmsnorm_init(d)
    return L.layernorm_init(d)


def _norm(cfg: BlockConfig, x, p):
    if cfg.norm == "rms":
        return L.rmsnorm(x, p)
    return L.layernorm(x, p)


def block_init(key, cfg: BlockConfig):
    ks = jax.random.split(key, 5)
    d = cfg.attn.d_model
    p: Params = {}
    s: Params = {}
    p["ln1"], s["ln1"] = _norm_init(cfg, d)
    p["attn"], s["attn"] = L.attn_init(ks[0], cfg.attn)
    p["ln2"], s["ln2"] = _norm_init(cfg, d)
    if cfg.moe is not None:
        p["moe"], s["moe"] = L.moe_init(ks[1], d, cfg.d_ff, cfg.moe)
    else:
        p["mlp"], s["mlp"] = L.mlp_init(ks[1], d, cfg.d_ff, cfg.act)
    if cfg.cross_attn:
        p["ln_x"], s["ln_x"] = _norm_init(cfg, d)
        p["xattn"], s["xattn"] = L.attn_init(ks[2], cfg.attn)
    return p, s


def block_specs(cfg: BlockConfig) -> Params:
    """Logical-axis specs of one block, with NO array materialization
    (param_specs for 300B-scale configs must stay abstract)."""
    norm_spec = (None,) if cfg.norm == "rms" else {"w": (None,), "b": (None,)}
    attn_s: Params = {"wq": ("embed", "heads", None),
                      "wk": ("embed", "kv_heads", None),
                      "wv": ("embed", "kv_heads", None),
                      "wo": ("heads", None, "embed")}
    if cfg.attn.qk_norm:
        attn_s["q_norm"] = (None,)
        attn_s["k_norm"] = (None,)
    s: Params = {"ln1": norm_spec, "attn": attn_s, "ln2": norm_spec}
    if cfg.moe is not None:
        s["moe"] = {"router": ("embed", None),
                    "wi": ("experts", "embed", "expert_mlp"),
                    "wo": ("experts", "expert_mlp", "embed")}
        if cfg.moe.act == "swiglu":
            s["moe"]["wg"] = ("experts", "embed", "expert_mlp")
    else:
        s["mlp"] = {"wi": ("embed", "mlp"), "wo": ("mlp", "embed")}
        if cfg.act == "swiglu":
            s["mlp"]["wg"] = ("embed", "mlp")
    if cfg.cross_attn:
        s["ln_x"] = norm_spec
        s["xattn"] = dict(attn_s)
    return s


def stack_specs(cfg: BlockConfig) -> Params:
    return jax.tree.map(lambda names: ("layers",) + names, block_specs(cfg),
                        is_leaf=lambda x: type(x) is tuple)


def block(p, cfg: BlockConfig, x, positions, cache=None, cross_kv=None,
          cross_len=None):
    """Pre-norm residual block.  Returns (x, new_cache, aux_loss).

    Megatron-SP gather points: when the residual stream is sequence-sharded
    ("seq" -> model), the attention/MLP inputs are constrained to
    "seq_act" (= replicated seq), forcing XLA to all-gather the small
    ACTIVATIONS over the TP axis instead of un-sharding the (much larger)
    weights; the residual add then reduce-scatters back.  With the default
    rules both names map to None and these constraints are no-ops.
    """
    def gather_sp(h):
        return lc(h, ("batch", "seq_act", "act_embed"))

    with xbar.scope("attn"):
        h, new_cache = L.attention(p["attn"], cfg.attn,
                                   gather_sp(_norm(cfg, x, p["ln1"])),
                                   positions, cache=cache)
    x = x + lc(h, ("batch", "seq", "act_embed"))
    if cfg.cross_attn:
        with xbar.scope("xattn"):
            h, _ = L.attention(p["xattn"], cfg.attn,
                               gather_sp(_norm(cfg, x, p["ln_x"])),
                               None, cross_kv=cross_kv, kv_len=cross_len)
        x = x + lc(h, ("batch", "seq", "act_embed"))
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        h, gates = L.moe_block(p["moe"], gather_sp(_norm(cfg, x, p["ln2"])),
                               cfg.moe)
        aux = L.moe_aux_loss(gates)
    else:
        with xbar.scope("mlp"):
            h = L.mlp(p["mlp"], gather_sp(_norm(cfg, x, p["ln2"])), cfg.act)
    return x + lc(h, ("batch", "seq", "act_embed")), new_cache, aux


# -- stacked layers ------------------------------------------------------------

def stack_init(key, cfg: BlockConfig, n_layers: int):
    """Initialize n_layers blocks with stacked (leading 'layers' axis) params."""
    keys = jax.random.split(key, n_layers)
    ps = [block_init(k, cfg)[0] for k in keys]
    _, spec = block_init(keys[0], cfg)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
    spec = jax.tree.map(lambda names: ("layers",) + names, spec,
                        is_leaf=lambda x: type(x) is tuple)
    return stacked, spec


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    raise ValueError(policy)


def stack_apply(stacked_p, cfg: BlockConfig, x, positions, caches=None,
                cross_kv=None, cross_len=None, remat: str = "none",
                scan: bool = True):
    """Scan the block over the stacked layer params.

    caches: stacked per-layer caches (dict of (L, ...) arrays) or None.
    The cache rides in the scan CARRY and is updated in place with
    dynamic_update_index — XLA keeps one buffer alive (donation-friendly);
    threading it through xs/ys would materialize a second full KV cache.
    cross_kv: stacked (k, v) of shape (L, B, S_src, H, hd) or None.
    scan=False unrolls the layer loop (used by the dry-run cost probes:
    XLA cost analysis counts a while body once, so per-layer FLOPs are
    measured on shallow UNROLLED variants — DESIGN.md §5).
    Returns (x, new_caches, total_aux).
    """
    has_cache = caches is not None
    if not scan:
        n_layers = jax.tree.leaves(stacked_p)[0].shape[0]
        aux = jnp.zeros((), jnp.float32)
        new_caches = caches

        def one_layer(p_l, xc, cache_l, xkv_l):
            return block(p_l, cfg, xc, positions, cache=cache_l,
                         cross_kv=xkv_l, cross_len=cross_len)

        one_layer = _remat(one_layer, remat)  # keep remat semantics so the
        # unrolled cost probes see the same recompute the scan incurs
        for l in range(n_layers):
            p_l = jax.tree.map(lambda a: a[l], stacked_p)
            cache_l = (jax.tree.map(lambda c: c[l], new_caches)
                       if has_cache else None)
            xkv_l = (jax.tree.map(lambda a: a[l], cross_kv)
                     if cross_kv is not None else None)
            with xbar.scope(l):   # names this layer's resident tiles
                x, new_cache, a = one_layer(p_l, x, cache_l, xkv_l)
            aux = aux + a
            if has_cache:
                new_caches = jax.tree.map(
                    lambda c, nc, ll=l: c.at[ll].set(nc.astype(c.dtype)),
                    new_caches, new_cache)
        return x, new_caches, aux

    def body(carry, per_layer):
        xc, aux, cch, idx = carry
        p_l, xkv_l = per_layer
        cache_l = (jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, idx, 0,
                                                   keepdims=False), cch)
            if has_cache else None)
        xo, new_cache, a = block(p_l, cfg, xc, positions, cache=cache_l,
                                 cross_kv=xkv_l, cross_len=cross_len)
        if has_cache:
            cch = jax.tree.map(
                lambda c, nc: jax.lax.dynamic_update_index_in_dim(
                    c, nc.astype(c.dtype), idx, 0), cch, new_cache)
        return (xo, aux + a, cch, idx + 1), ()

    body = _remat(body, remat)
    (x, aux, new_caches, _), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32), caches, jnp.int32(0)),
        (stacked_p, cross_kv))
    return x, new_caches, aux


# -- embeddings / head ----------------------------------------------------------

def embed_init(key, vocab: int, d_model: int, tie: bool = False):
    p = {"tok": jax.random.normal(key, (vocab, d_model)) * 0.02}
    s = {"tok": ("vocab", "embed")}
    return p, s


def embed(p, tokens):
    e = jnp.take(p["tok"], tokens, axis=0)
    return lc(e, ("batch", "seq", "act_embed"))


def unembed(p, x, head=None):
    w = head if head is not None else p["tok"].T
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    return lc(logits, ("batch", None, "vocab"))


def xent_loss(logits, labels, mask=None, vocab: Optional[int] = None):
    """Cross entropy with f32 accumulation over a (possibly padded) vocab."""
    lg = logits.astype(jnp.float32)
    if vocab is not None and vocab < lg.shape[-1]:
        pad = lg.shape[-1] - vocab
        neg = jnp.full((pad,), -1e30, jnp.float32)
        lg = lg + jnp.concatenate([jnp.zeros((vocab,)), neg])
    lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
