"""RWKV6 "Finch": attention-free RNN with data-dependent decay.

Per layer: time-mix (the GLA recurrence with low-rank *data-dependent*
decay — the Finch signature) + channel-mix (token-shifted squared-ReLU
FFN).  Simplifications vs the released checkpoints (documented in
DESIGN.md): static token-shift lerp coefficients for r/k/v/g (Finch makes
these data-dependent too via a shared LoRA stack); the decay path keeps the
full dynamic low-rank form since it defines the architecture.

State per layer for decode: (last hidden token-shift states, GLA state).
"""
from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_constraint as lc
from repro.models import layers as L
from repro.models.lin_attn import chunked_gla, gla_decode_step


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    d_model: int
    n_layers: int
    head_dim: int = 64
    decay_lora: int = 64
    ffn_mult: float = 3.5
    vocab: int = 65536
    chunk: int = 16
    chunk_unroll: bool = True

    @property
    def n_heads(self) -> int:
        return self.d_model // self.head_dim

    @property
    def d_ffn(self) -> int:
        return int(self.d_model * self.ffn_mult)


def _n(key, shape, scale):
    return jax.random.normal(key, shape) * scale


def time_mix_init(key, cfg: RWKVConfig):
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 10)
    p = {
        "mu": 0.5 * jnp.ones((5, d)),            # shift-lerp for r,k,v,w,g
        "wr": _n(ks[0], (d, h, hd), d ** -0.5),
        "wk": _n(ks[1], (d, h, hd), d ** -0.5),
        "wv": _n(ks[2], (d, h, hd), d ** -0.5),
        "wg": _n(ks[3], (d, h, hd), d ** -0.5),
        "wo": _n(ks[4], (h, hd, d), (h * hd) ** -0.5),
        "w0": -6.0 + 5.0 * jnp.linspace(0.0, 1.0, h * hd).reshape(h, hd),
        "wd_a": _n(ks[5], (d, cfg.decay_lora), d ** -0.5),
        "wd_b": _n(ks[6], (cfg.decay_lora, h, hd), cfg.decay_lora ** -0.5),
        "u": _n(ks[7], (h, hd), 0.3),
        "ln_x": jnp.ones((h * hd,)),
    }
    s = {
        "mu": (None, None),
        "wr": ("embed", "lin_heads", None),
        "wk": ("embed", "lin_heads", None),
        "wv": ("embed", "lin_heads", "lin_dv"),
        "wg": ("embed", "lin_heads", "lin_dv"),
        "wo": ("lin_heads", "lin_dv", "embed"),
        "w0": ("lin_heads", None),
        "wd_a": ("embed", None),
        "wd_b": (None, "lin_heads", None),
        "u": ("lin_heads", None),
        "ln_x": (None,),
    }
    return p, s


def _shift(x, last):
    """Token shift: x_{t-1} (first position sees ``last``, decode carry)."""
    return jnp.concatenate([last.astype(x.dtype)[:, None], x[:, :-1]],
                           axis=1)


def _decay(p, xw):
    """Data-dependent decay (Finch): log w = -exp(w0 + lora(xw)) <= 0."""
    lora = jnp.einsum("bsd,dr,rhk->bshk", xw, p["wd_a"].astype(xw.dtype),
                      p["wd_b"].astype(xw.dtype))
    return -jnp.exp(p["w0"].astype(jnp.float32)
                    + jnp.tanh(lora).astype(jnp.float32) * 0.5)


def time_mix(p, cfg: RWKVConfig, x, shift_last, gla_state=None,
             decode: bool = False):
    """x: (B, S, d).  Returns (y, (new_shift_last, new_gla_state))."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    xs = _shift(x, shift_last)
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xw, xg = (x + (xs - x) * mu[i] for i in range(5))

    r = jnp.einsum("bsd,dhk->bshk", xr, p["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", xk, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", xv, p["wv"].astype(x.dtype))
    g = jnp.einsum("bsd,dhk->bshk", xg, p["wg"].astype(x.dtype))
    log_w = _decay(p, xw)                                   # (B,S,H,hd) f32

    r = lc(r, ("batch", "seq", "lin_heads", None))
    v = lc(v, ("batch", "seq", "lin_heads", "lin_dv"))

    u = p["u"].astype(jnp.float32)
    if decode:
        y, new_state = gla_decode_step(
            r[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32),
            v[:, 0].astype(jnp.float32), log_w[:, 0], gla_state, u)
        y = y[:, None]
    else:
        y, new_state = chunked_gla(
            r.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), log_w, u,
            chunk=min(cfg.chunk, s), unroll=cfg.chunk_unroll,
            state0=gla_state)
    y = y.reshape(b, s, h * hd)
    y = L.rmsnorm(y, p["ln_x"])                             # group-norm-ish
    y = y.astype(x.dtype) * jax.nn.silu(g.reshape(b, s, h * hd))
    out = jnp.einsum("bshk,hkd->bsd", y.reshape(b, s, h, hd),
                     p["wo"].astype(x.dtype))
    return lc(out, ("batch", "seq", "act_embed")), (x[:, -1], new_state)


def channel_mix_init(key, cfg: RWKVConfig):
    d, f = cfg.d_model, cfg.d_ffn
    ks = jax.random.split(key, 3)
    p = {"mu": 0.5 * jnp.ones((2, d)),
         "wk": _n(ks[0], (d, f), d ** -0.5),
         "wv": _n(ks[1], (f, d), f ** -0.5),
         "wr": _n(ks[2], (d, d), d ** -0.5)}
    s = {"mu": (None, None), "wk": ("embed", "mlp"),
         "wv": ("mlp", "embed"), "wr": ("embed", None)}
    return p, s


def channel_mix(p, x, shift_last):
    xs = _shift(x, shift_last)
    mu = p["mu"].astype(x.dtype)
    xk = x + (xs - x) * mu[0]
    xr = x + (xs - x) * mu[1]
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(x.dtype)))
    k = lc(k, ("batch", "seq", "act_mlp"))
    kv = k @ p["wv"].astype(x.dtype)
    y = jax.nn.sigmoid(xr @ p["wr"].astype(x.dtype)) * kv
    return lc(y, ("batch", "seq", "act_embed")), x[:, -1]


def block_init(key, cfg: RWKVConfig):
    k1, k2 = jax.random.split(key)
    tm, tms = time_mix_init(k1, cfg)
    cm, cms = channel_mix_init(k2, cfg)
    p = {"ln1": jnp.ones((cfg.d_model,)), "ln2": jnp.ones((cfg.d_model,)),
         "tm": tm, "cm": cm}
    s = {"ln1": (None,), "ln2": (None,), "tm": tms, "cm": cms}
    return p, s


def block_specs(cfg: RWKVConfig):
    """Spec-only twin of block_init (no array materialization)."""
    tms = {"mu": (None, None), "wr": ("embed", "lin_heads", None),
           "wk": ("embed", "lin_heads", None),
           "wv": ("embed", "lin_heads", "lin_dv"),
           "wg": ("embed", "lin_heads", "lin_dv"),
           "wo": ("lin_heads", "lin_dv", "embed"),
           "w0": ("lin_heads", None), "wd_a": ("embed", None),
           "wd_b": (None, "lin_heads", None), "u": ("lin_heads", None),
           "ln_x": (None,)}
    cms = {"mu": (None, None), "wk": ("embed", "mlp"),
           "wv": ("mlp", "embed"), "wr": ("embed", None)}
    return {"ln1": (None,), "ln2": (None,), "tm": tms, "cm": cms}


def block(p, cfg: RWKVConfig, x, state, decode: bool = False):
    """state: dict(tm_shift (B,d), cm_shift (B,d), gla (B,H,dk,dv))."""
    h, st = time_mix(p["tm"], cfg, L.rmsnorm(x, p["ln1"]),
                     state["tm_shift"], state["gla"], decode=decode)
    x = x + h
    h, cm_shift = channel_mix(p["cm"], L.rmsnorm(x, p["ln2"]),
                              state["cm_shift"])
    x = x + h
    new_state = {"tm_shift": st[0], "cm_shift": cm_shift, "gla": st[1]}
    return x, new_state


def init_state(cfg: RWKVConfig, batch: int, dtype=jnp.bfloat16):
    """Decode state: token-shift carries (activation dtype) + GLA state
    (always f32 — the recurrence accumulates)."""
    h, hd = cfg.n_heads, cfg.head_dim
    return {"tm_shift": jnp.zeros((batch, cfg.d_model), dtype),
            "cm_shift": jnp.zeros((batch, cfg.d_model), dtype),
            "gla": jnp.zeros((batch, h, hd, hd), jnp.float32)}


def state_specs(cfg: RWKVConfig):
    return {"tm_shift": ("batch", None), "cm_shift": ("batch", None),
            "gla": ("batch", "lin_heads", None, "lin_dv")}
