"""Chunk-parallel linear attention with per-channel data-dependent decay.

Shared machinery for RWKV6 (Finch) and Mamba2 (SSD).  Both are instances of
the diagonal-gated state recurrence

    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (state: H x dk x dv)
    y_t = q_t S_t  (+ u-bonus for RWKV6)

computed here in three numerically safe pieces:

  1. intra-chunk: a depth-L scan (vectorized over all chunks at once) that
     produces per-position outputs and each chunk's local end state.  Every
     factor is a product of decays <= 1 — no exploding exp(-cum) terms, the
     standard failure mode of the (L x L) matrix form when decays are far
     from 1 (RWKV6 tail channels).
  2. inter-chunk: jax.lax.associative_scan over (total_decay, local_state)
     pairs — log-depth, fully counted by XLA cost analysis (no while loop,
     so dry-run FLOP accounting stays honest; see DESIGN.md §5).
  3. injection: y_i += (q_i * cumdecay_i) . S_prev(chunk(i)).

Decode is the plain O(1) recurrence on a carried state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_constraint as lc


def chunked_gla(q, k, v, log_w, u: Optional[jax.Array] = None,
                chunk: int = 16, unroll: bool = True,
                state0: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """q, k, log_w: (B, S, H, dk); v: (B, S, H, dv); u: (H, dk) or None.

    Returns (y: (B, S, H, dv), final_state: (B, H, dk, dv)).
    log_w must be <= 0 (decay in (0, 1]).
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    s_orig = s
    if s % chunk != 0:
        # pad with zero k/v (no state contribution) and unit decay
        # (log_w = 0): the final state is unchanged by padded steps.
        pad = chunk - s % chunk
        widths = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(a, widths) for a in (q, k, v))
        log_w = jnp.pad(log_w, widths)
        s = s + pad
    nc = s // chunk

    cq = q.reshape(b, nc, chunk, h, dk)
    ck = k.reshape(b, nc, chunk, h, dk)
    cv = v.reshape(b, nc, chunk, h, dv)
    clw = log_w.reshape(b, nc, chunk, h, dk)
    w = jnp.exp(clw)

    # ---- 1. intra-chunk: depth-L scan over positions, all chunks at once
    # Read/update order differs between the two users of this kernel:
    #   Mamba2 (u is None):  S_t = w S_{t-1} + kv;  y_t = q S_t
    #   RWKV6  (u given):    y_t = q (S_{t-1} + u*kv);  S_t = w S_{t-1} + kv
    read_before = u is not None

    def step(carry, inp):
        s_loc = carry                                  # (B, nc, H, dk, dv)
        qj, kj, vj, wj = inp                           # (B, nc, H, d*)
        if read_before:
            yj = jnp.einsum("bnhk,bnhkv->bnhv", qj, s_loc)
            s_loc = wj[..., None] * s_loc + kj[..., None] * vj[..., None, :]
        else:
            s_loc = wj[..., None] * s_loc + kj[..., None] * vj[..., None, :]
            yj = jnp.einsum("bnhk,bnhkv->bnhv", qj, s_loc)
        return s_loc, yj

    s0 = jnp.zeros((b, nc, h, dk, dv), q.dtype)
    s0 = lc(s0, ("batch", None, "lin_heads", None, "lin_dv"))
    xs = (cq.swapaxes(0, 2).swapaxes(1, 2),            # (L, B, nc, H, dk)
          ck.swapaxes(0, 2).swapaxes(1, 2),
          cv.swapaxes(0, 2).swapaxes(1, 2),
          w.swapaxes(0, 2).swapaxes(1, 2))
    s_end, y_intra = jax.lax.scan(step, s0, xs,
                                  unroll=chunk if unroll else 1)
    y_intra = y_intra.swapaxes(0, 1).swapaxes(1, 2)    # (B, nc, L, H, dv)

    # u-bonus (RWKV6): current token reads (u * k_t) v_t before decaying in
    if u is not None:
        bonus = jnp.einsum("bnlhk,hk,bnlhk->bnlh", cq, u, ck)
        y_intra = y_intra + bonus[..., None] * cv

    # ---- 2. inter-chunk associative scan over (decay, state)
    total = jnp.exp(clw.sum(axis=2))                   # (B, nc, H, dk)

    def combine(a, c):
        a_d, a_s = a
        c_d, c_s = c
        return a_d * c_d, c_d[..., None] * a_s + c_s

    dec, states = jax.lax.associative_scan(
        combine, (total, s_end), axis=1)
    # state BEFORE each chunk: shift right, chunk 0 sees state0 (or zeros)
    if state0 is None:
        state0 = jnp.zeros((b, h, dk, dv), q.dtype)
    prev = jnp.concatenate(
        [state0[:, None], states[:, :-1]], axis=1)     # (B, nc, H, dk, dv)
    # account for an incoming state0 flowing into later chunks
    if states.shape[1] > 1:
        carry_in = dec[:, :-1, ..., None] * state0[:, None]
        prev = prev.at[:, 1:].add(carry_in)

    # ---- 3. inject inter-chunk history into per-position outputs
    cum = jnp.cumsum(clw, axis=2)                      # (B, nc, L, H, dk)
    if read_before:
        # S_{t-1} saw decays w_1..w_{t-1} only: exclusive cumulative decay
        cum = cum - clw
    q_scaled = cq * jnp.exp(cum)
    y_inter = jnp.einsum("bnlhk,bnhkv->bnlhv", q_scaled, prev)

    y = (y_intra + y_inter).reshape(b, s, h, dv)[:, :s_orig]
    final = dec[:, -1, ..., None] * state0 + states[:, -1]
    return y, final


def gla_decode_step(q, k, v, log_w, state, u: Optional[jax.Array] = None):
    """One-token recurrence.  q/k/log_w: (B, H, dk); v: (B, H, dv);
    state: (B, H, dk, dv).  Returns (y: (B, H, dv), new_state)."""
    w = jnp.exp(log_w)
    kv = k[..., None] * v[..., None, :]
    new_state = w[..., None] * state + kv
    if u is not None:   # RWKV6: read S_{t-1} + bonus, then update
        y = jnp.einsum("bhk,bhkv->bhv", q,
                       state + u[None, ..., None] * kv)
    else:               # Mamba2: update, then read
        y = jnp.einsum("bhk,bhkv->bhv", q, new_state)
    return y, new_state


def naive_gla(q, k, v, log_w, u: Optional[jax.Array] = None,
              state0: Optional[jax.Array] = None):
    """O(S) sequential oracle for tests."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    st = (jnp.zeros((b, h, dk, dv), jnp.float32)
          if state0 is None else state0.astype(jnp.float32))
    ys = []
    for t in range(s):
        w = jnp.exp(log_w[:, t].astype(jnp.float32))
        kv = k[:, t, ..., None].astype(jnp.float32) * \
            v[:, t, :, None, :].astype(jnp.float32)
        if u is not None:
            ys.append(jnp.einsum("bhk,bhkv->bhv", q[:, t].astype(jnp.float32),
                                 st + u[None, ..., None] * kv))
            st = w[..., None] * st + kv
        else:
            st = w[..., None] * st + kv
            ys.append(jnp.einsum("bhk,bhkv->bhv",
                                 q[:, t].astype(jnp.float32), st))
    return jnp.stack(ys, axis=1), st
