"""Pallas TPU kernel: deep-net-mode streaming crossbar matmul.

The paper's deep-net mode programs one plane *while* the other is read, so
weight programming never stalls the data path (§III-B).  On TPU the same
schedule appears at the memory hierarchy: this kernel streams the *float*
weights tile-by-tile from HBM and performs the "program" step (quantize ->
differential cell codes) in VMEM, fused immediately with the "read" step
(bit-serial MAC + ADC).  Pallas' automatic block double-buffering prefetches
tile t+1's weights during tile t's matmuls — the write of the next tile
rides under the read of the current one, exactly the paper's
read-subsumed-in-write budget (pipeline.streaming_speedup gives the napkin
model).  ``block_k`` widens each streamed tile to several row groups
(default four via ops.py): one DMA covers block_k // rows_per_adc
conversions, so the prefetch has a longer read to hide under, while the
per-group ``out_ref += acc`` order keeps the output bitwise identical to
the narrow layout.

Napkin math (why fuse): the unfused path ships 2*S int8 code planes per
weight (pos+neg), i.e. 2*S bytes/weight of HBM traffic; streaming the bf16
master weight ships 2 bytes/weight and programs on the fly.  For the
default S = 4 slices that is a 4x cut of the dominant HBM term, and the
quantize/slice arithmetic (a handful of VPU ops per weight) hides under the
S * in_bits MXU matmuls per tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import tpu_compiler_params


def _adc(acc, adc_bits: int, full_scale: float):
    levels = 2.0 ** adc_bits - 1.0
    lsb = full_scale / levels
    return jnp.clip(jnp.round(acc / lsb), 0.0, levels) * lsb


def _kernel(x_ref, w_ref, scale_ref, out_ref, *, w_bits: int, in_bits: int,
            adc_bits: int, bits_per_cell: int, rows_per_adc: int,
            groups_per_block: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    base = 2 ** bits_per_cell
    n_slices = -(-w_bits // bits_per_cell)
    full_scale = float(rows_per_adc * (base - 1))
    qmax = 2.0 ** w_bits - 1.0
    r = rows_per_adc

    # the streamed tile covers groups_per_block row groups: Pallas
    # prefetches tile t+1 (one HBM->VMEM DMA of block_k rows) while the
    # body walks tile t's groups — the wider the tile, the longer the
    # plane read rides under the next plane's write (fetch)
    w_tile = w_ref[...].astype(jnp.float32)               # (block_k, N)
    x_tile = x_ref[...].astype(jnp.int32)                 # (B, block_k)

    for gi in range(groups_per_block):
        # ---- "program" phase: quantize this row group to cell codes ----
        w = w_tile[gi * r:(gi + 1) * r]                   # (R, N)
        w_int = jnp.clip(jnp.round(w / scale_ref[...]), -qmax, qmax)
        wp = jnp.maximum(w_int, 0.0)
        wn = jnp.maximum(-w_int, 0.0)

        # ---- "read" phase: bit-serial MAC with per-conversion ADC ------
        x = x_tile[:, gi * r:(gi + 1) * r]
        u = (x + (1 << in_bits)) % (1 << in_bits)

        acc = jnp.zeros_like(out_ref)
        for p in range(in_bits):
            bitw = float(2 ** p) if p < in_bits - 1 else -float(2 ** p)
            xb = ((u >> p) & 1).astype(jnp.float32)
            rp, rn = wp, wn
            for s in range(n_slices):
                slcw = float(base ** s)
                pos_s = rp - jnp.floor(rp / base) * base  # digit s
                neg_s = rn - jnp.floor(rn / base) * base
                rp = jnp.floor(rp / base)
                rn = jnp.floor(rn / base)
                ap = jax.lax.dot(xb, pos_s,
                                 preferred_element_type=jnp.float32)
                an = jax.lax.dot(xb, neg_s,
                                 preferred_element_type=jnp.float32)
                d = (_adc(ap, adc_bits, full_scale)
                     - _adc(an, adc_bits, full_scale))
                acc = acc + (bitw * slcw) * d
        # per-GROUP += in row-group order: the accumulation association
        # is identical to the block_k == rows_per_adc layout, so widening
        # the streamed tile never moves a bit of the output
        out_ref[...] += acc


@functools.partial(jax.jit, static_argnames=(
    "w_bits", "in_bits", "adc_bits", "bits_per_cell", "rows_per_adc",
    "block_b", "block_n", "block_k", "interpret"))
def deepnet_stream(x_int, w, w_scale, *, w_bits: int, in_bits: int,
                   adc_bits: int, bits_per_cell: int, rows_per_adc: int,
                   block_b: int = 128, block_n: int = 128,
                   block_k: int = 0, interpret: bool = True):
    """x_int (B, K) int32, w (K, N) float, w_scale (1, N) -> (B, N) f32.

    ``block_k`` (0 = ``rows_per_adc``) is the streamed weight-tile depth:
    a multiple of ``rows_per_adc`` dividing K.  Each grid step along the
    K axis fetches one (block_k, block_n) tile and walks its row groups
    in order — bitwise identical to the one-group-per-step layout, but
    the prefetch window (the "write" that hides under the "read") spans
    ``block_k // rows_per_adc`` conversions instead of one.
    """
    b, k = x_int.shape
    k2, n = w.shape
    bk = block_k or rows_per_adc
    assert k == k2 and bk % rows_per_adc == 0 and k % bk == 0, (
        k, k2, rows_per_adc, bk)
    grid = (b // block_b, n // block_n, k // bk)

    return pl.pallas_call(
        functools.partial(_kernel, w_bits=w_bits, in_bits=in_bits,
                          adc_bits=adc_bits, bits_per_cell=bits_per_cell,
                          rows_per_adc=rows_per_adc,
                          groups_per_block=bk // rows_per_adc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, bk), lambda i, j, t: (i, t)),
            pl.BlockSpec((bk, block_n), lambda i, j, t: (t, j)),
            pl.BlockSpec((1, block_n), lambda i, j, t: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_int, w, w_scale)
