"""Pure-jnp oracle for the deep-net streaming kernel.

"Program" (quantize float weights to differential cell codes) immediately
followed by "read" (the bit-sliced crossbar MAC) — the composition of
quant.quantize_weights/to_slices with crossbar_mac_ref, without ever
materializing the programmed planes.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.crossbar_mac.ref import crossbar_mac_ref


def deepnet_stream_ref(x_int, w, w_scale, *, w_bits: int, in_bits: int,
                       adc_bits: int, bits_per_cell: int, rows_per_adc: int):
    """x_int (B, K) int32, w (K, N) float, w_scale (1, N) -> (B, N) f32.

    Output is in integer code units (input/weight scales applied by caller).
    """
    qmax = 2.0 ** w_bits - 1.0
    w_int = jnp.clip(jnp.round(w / w_scale), -qmax, qmax)
    wp = jnp.maximum(w_int, 0.0).astype(jnp.int32)
    wn = jnp.maximum(-w_int, 0.0).astype(jnp.int32)
    base = 2 ** bits_per_cell
    n_slices = -(-w_bits // bits_per_cell)
    pos = jnp.stack([(wp // (base ** s)) % base for s in range(n_slices)])
    neg = jnp.stack([(wn // (base ** s)) % base for s in range(n_slices)])
    return crossbar_mac_ref(x_int, pos.astype(jnp.int8),
                            neg.astype(jnp.int8), in_bits=in_bits,
                            adc_bits=adc_bits, bits_per_cell=bits_per_cell,
                            rows_per_adc=rows_per_adc)
