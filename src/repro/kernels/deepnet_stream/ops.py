"""Jitted wrapper for the deep-net streaming matmul.

``stream_linear(x, w, cfg)`` is the deployment-shaped entry point: float
activations and float weights in, float activations out, with the program
step fused into the read pass (no programmed planes in HBM).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import quant
from repro.kernels.deepnet_stream.kernel import deepnet_stream


def _pad_axis(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def stream_linear(x, w, cfg):
    """x (..., K) float, w (K, N) float, cfg: EngineConfig -> (..., N)."""
    q = cfg.quant
    lead = x.shape[:-1]
    xb = x.reshape(-1, x.shape[-1])
    x_int, x_scale = quant.quantize_inputs(xb, q)
    w_scale = quant.weight_scales(w, q)
    if not q.per_channel:
        w_scale = jnp.full((1, w.shape[1]), w_scale)

    rows = cfg.rows_per_adc
    k, n = w.shape
    x_int = _pad_axis(x_int.astype(jnp.int32), rows, axis=-1)
    w_p = _pad_axis(w.astype(jnp.float32), rows, axis=0)
    # widen the streamed weight tile to four row groups per fetch (the
    # double-buffered plane-read window: Pallas prefetches the next
    # block_k-deep tile while the current one's conversions run).  The
    # zero rows padding adds contribute exact zeros through quantize ->
    # MAC -> ADC, so the widened layout stays value-identical.
    block_k = 4 * rows if x_int.shape[1] > rows else rows
    x_int = _pad_axis(x_int, block_k, axis=-1)
    w_p = _pad_axis(w_p, block_k, axis=0)

    block_b = min(128, max(8, x_int.shape[0]))
    block_n = min(128, n)
    x_pad = _pad_axis(x_int, block_b, axis=0)
    w_p = _pad_axis(w_p, block_n, axis=1)
    s_pad = _pad_axis(w_scale.astype(jnp.float32), block_n, axis=1)
    # padded scale columns must be nonzero (div-by-zero in the kernel)
    s_pad = jnp.where(s_pad == 0.0, 1.0, s_pad)

    y = deepnet_stream(
        x_pad, w_p, s_pad, w_bits=q.w_bits, in_bits=q.in_bits,
        adc_bits=q.adc_bits, bits_per_cell=q.bits_per_cell,
        rows_per_adc=rows, block_b=block_b, block_n=block_n,
        block_k=block_k, interpret=cfg.interpret)

    y = y[: xb.shape[0], : n] * x_scale * w_scale[..., :n]
    return y.reshape(*lead, n)
