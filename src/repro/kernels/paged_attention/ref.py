"""Reference paged decode attention (pure jnp): the oracles both kernel
lanes are pinned against in interpret-mode CI.

``paged_attention_ref`` is the **scratch-lane** oracle, bit-for-bit:
the dense ``models/layers._sdpa`` decode path verbatim — same einsum
contraction strings, same f32 accumulation, same -1e30 mask constants —
applied to the K/V view gathered through the page table.  Because
``page_size`` divides ``max_len``, the gathered view is exactly
``max_len`` deep, so equal cache contents give bit-identical logits,
softmax weights, and outputs vs the dense cache path.

``paged_attention_streamed_ref`` is the **streamed-lane** oracle: the
same online-softmax block recursion as the streamed kernel body, one
page block at a time in the same order with the same f32 running
max/denominator/accumulator updates.  Its contract with the kernel is
bounded-ulp, not bitwise — XLA reassociates the multiply-adds
differently inside the Pallas interpreter than in a plain jit graph, so
even this same-order replica lands 1–2 ulp off the kernel on ~1/3 of
random cases (measured; see kernel.py's module docstring).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_pages(pages: jax.Array, page_table: jax.Array) -> jax.Array:
    """(P+1, ps, kv, hd) pages + (B, P_seq) table -> (B, depth, kv, hd)
    logical view, depth = P_seq * ps (== max_len)."""
    b, p_seq = page_table.shape
    ps = pages.shape[1]
    return pages[page_table].reshape(b, p_seq * ps, *pages.shape[2:])


def paged_attention_ref(q, k_pages, v_pages, page_table, kv_len, q_offset,
                        *, causal: bool = True):
    """q (B, sq, hq, hd); k/v pages (P+1, ps, kv, hd); page_table
    (B, P_seq) int32; kv_len/q_offset (B,) int32 -> (B, sq, hq, hd)."""
    b, sq, hq, hd = q.shape
    gk = gather_pages(k_pages, page_table)
    gv = gather_pages(v_pages, page_table)
    depth = gk.shape[1]
    if gk.dtype != q.dtype:   # low-precision (fp8) cache: upcast in-dot
        gk = gk.astype(q.dtype)
        gv = gv.astype(q.dtype)
    kv = gk.shape[2]
    g = hq // kv
    qg = q.reshape(b, sq, kv, g, hd)
    scale = hd ** -0.5
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, gk,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = (jnp.asarray(q_offset).reshape(-1, 1)
                + jnp.arange(sq)[None])
        mask = qpos[:, :, None] >= jnp.arange(depth)[None, None, :]
        logits = jnp.where(mask[:, None, None], logits, -1e30)
    valid = jnp.arange(depth)[None, :] < kv_len[:, None]
    logits = jnp.where(valid[:, None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w.astype(gv.dtype), gv)
    return out.reshape(b, sq, hq, hd)


def paged_attention_streamed_ref(q, k_pages, v_pages, page_table, kv_len,
                                 q_offset, *, causal: bool = True,
                                 block_pages: int = 16):
    """Block-order online-softmax oracle for the streamed kernel lane:
    the flash recursion in plain jnp, same block schedule, same update
    order.  ``block_pages`` must match the kernel call being checked
    (it is clamped to a divisor of the table width the same way)."""
    from repro.kernels.paged_attention.kernel import resolve_block_pages

    b, sq, hq, hd = q.shape
    ps = k_pages.shape[1]
    p_seq = page_table.shape[1]
    bp = resolve_block_pages(p_seq, block_pages)
    bt = bp * ps
    kv = k_pages.shape[2]
    g = hq // kv
    qg = q.reshape(b, sq, kv, g, hd)
    scale = hd ** -0.5
    kv_len = jnp.asarray(kv_len).reshape(b)
    q_offset = jnp.asarray(q_offset).reshape(b)
    m = jnp.full((b, kv, g, sq), -1e30, jnp.float32)
    l = jnp.zeros((b, kv, g, sq), jnp.float32)
    acc = jnp.zeros((b, kv, g, sq, hd), jnp.float32)
    for j in range(p_seq // bp):
        ptj = page_table[:, j * bp:(j + 1) * bp].reshape(-1)
        kk = k_pages[ptj].reshape(b, bt, kv, hd)
        vv = v_pages[ptj].reshape(b, bt, kv, hd)
        if kk.dtype != q.dtype:
            kk = kk.astype(q.dtype)
            vv = vv.astype(q.dtype)
        logits = jnp.einsum("bskgh,btkh->bkgst", qg, kk,
                            preferred_element_type=jnp.float32) * scale
        tpos = j * bt + jnp.arange(bt)
        if causal:
            qpos = q_offset[:, None] + jnp.arange(sq)[None]
            mask = qpos[:, :, None] >= tpos[None, None, :]
            logits = jnp.where(mask[:, None, None], logits, -1e30)
        valid = tpos[None, :] < kv_len[:, None]
        logits = jnp.where(valid[:, None, None, None], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkh->bkgsh", p, vv.astype(jnp.float32),
            preferred_element_type=jnp.float32)
        m = m_new
    out = acc / l[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, hd).astype(
        q.dtype)
