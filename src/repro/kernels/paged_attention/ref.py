"""Reference paged decode attention (pure jnp): the oracle the Pallas
kernel is pinned against, bit-for-bit, in interpret-mode CI.

The math is the dense ``models/layers._sdpa`` decode path verbatim —
same einsum contraction strings, same f32 accumulation, same -1e30
mask constants — applied to the K/V view gathered through the page
table.  Because ``page_size`` divides ``max_len``, the gathered view is
exactly ``max_len`` deep, so equal cache contents give bit-identical
logits, softmax weights, and outputs vs the dense cache path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_pages(pages: jax.Array, page_table: jax.Array) -> jax.Array:
    """(P+1, ps, kv, hd) pages + (B, P_seq) table -> (B, depth, kv, hd)
    logical view, depth = P_seq * ps (== max_len)."""
    b, p_seq = page_table.shape
    ps = pages.shape[1]
    return pages[page_table].reshape(b, p_seq * ps, *pages.shape[2:])


def paged_attention_ref(q, k_pages, v_pages, page_table, kv_len, q_offset,
                        *, causal: bool = True):
    """q (B, sq, hq, hd); k/v pages (P+1, ps, kv, hd); page_table
    (B, P_seq) int32; kv_len/q_offset (B,) int32 -> (B, sq, hq, hd)."""
    b, sq, hq, hd = q.shape
    gk = gather_pages(k_pages, page_table)
    gv = gather_pages(v_pages, page_table)
    depth = gk.shape[1]
    if gk.dtype != q.dtype:   # low-precision (fp8) cache: upcast in-dot
        gk = gk.astype(q.dtype)
        gv = gv.astype(q.dtype)
    kv = gk.shape[2]
    g = hq // kv
    qg = q.reshape(b, sq, kv, g, hd)
    scale = hd ** -0.5
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, gk,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = (jnp.asarray(q_offset).reshape(-1, 1)
                + jnp.arange(sq)[None])
        mask = qpos[:, :, None] >= jnp.arange(depth)[None, None, :]
        logits = jnp.where(mask[:, None, None], logits, -1e30)
    valid = jnp.arange(depth)[None, :] < kv_len[:, None]
    logits = jnp.where(valid[:, None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w.astype(gv.dtype), gv)
    return out.reshape(b, sq, hq, hd)
