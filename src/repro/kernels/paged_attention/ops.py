"""Shape-checked entry point + two-lane dispatch for paged attention.

Mirrors crossbar_mac's layering: ops validates/normalizes operands and
dispatches a kernel; the kernels stay pure shape-in/shape-out Pallas
calls.  No padding is needed here — the serving tier guarantees
``page_size | max_len`` (kv_pool.py enforces it), so the gathered depth
is already the dense path's ``max_len``.

Two lanes, dispatched by window size (``lane="auto"``):

* **scratch** (``paged_attention_kernel``) — gather-then-SDPA, bitwise
  vs ref/dense; peak VMEM linear in the window.  The small-window fast
  path and the oracle.
* **streamed** (``paged_attention_streamed``) — block-streamed online
  softmax, double-buffered page-block prefetch, O(block_pages) VMEM;
  bounded-ulp + argmax-stable vs the scratch lane.  Selected when the
  table is at least ``stream_min_pages`` pages wide (0 disables it).

Every dispatch lands in the global telemetry registry as
``crossstack_dispatch_total{path=paged_scratch|paged_streamed|
paged_fallback, geometry}`` — bumped per call, i.e. per trace under jit,
the same accounting ``core/engine.matmul`` uses — so CI can pin which
lane served each decode closure (``paged_path_calls`` is the summed
view).  **No silent reference fallback**: if the streamed lane was
selected but its kernel fails, the dispatcher warns ONCE per geometry,
counts ``path="paged_fallback"``, and routes to the *scratch kernel* —
never the jnp reference scan — mirroring crossbar_mac's
no-silent-fallback contract.  The paged bench exit-gates the fallback
counter at zero.

Scope of that guard: the ``except`` around the dispatch can only see
errors raised while *this frame* runs — i.e. while the streamed call
traces.  When ``paged_attention`` runs inside an outer jit closure
(``layers.attention``), backend lowering and compilation happen after
tracing returns, outside any except.  The dispatcher therefore
**probe-compiles** the streamed kernel once per geometry (an AOT
``.lower(...).compile()`` on abstract avals) before committing to the
lane, so lowering/compile failures surface where the fallback can
reroute.  A pure *runtime* fault on the final backend (e.g. a device
OOM mid-execution) remains out of reach of any dispatcher-level guard
— that residue is the documented limit of the contract.

Page tables may ALIAS: no validation here (or in the kernels) assumes
table entries are unique across rows.  Refcounted prefix sharing
(serve/kv_pool.py) points several rows' tables at the same physical
pages, and the read-only gather makes that indistinguishable from
private copies — see docs/KERNELS.md, "Aliased page tables are
in-contract".
"""
from __future__ import annotations

import warnings
from collections.abc import Mapping

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels.paged_attention import kernel as _kernel_mod
from repro.kernels.paged_attention.kernel import paged_attention_kernel

_DISPATCH = "crossstack_dispatch_total"

# streamed-lane failures already warned, keyed by geometry — warn once
# per geometry, not once per traced closure
_FALLBACK_WARNED = set()

# streamed-kernel geometries whose AOT probe-compile succeeded: the
# expensive .lower().compile() runs once per geometry, then dispatch is
# a set lookup
_PROBE_OK = set()


def _probe_streamed(q, k_pages, v_pages, page_table, kv_len, q_offset,
                    *, causal, interpret, block_pages) -> None:
    """AOT-compile the streamed kernel for this geometry (abstract
    avals — nothing executes) so lowering/compile failures raise HERE,
    inside the dispatcher's try, instead of later when the enclosing
    jit closure compiles outside any except.  Safe to call from within
    an outer trace: ``.lower`` spawns an independent trace."""
    key = tuple((tuple(x.shape), jnp.dtype(x.dtype).name)
                for x in (q, k_pages, page_table)) + (
                    causal, interpret, block_pages)
    if key in _PROBE_OK:
        return
    aval = lambda x: jax.ShapeDtypeStruct(tuple(x.shape),  # noqa: E731
                                          jnp.dtype(x.dtype))
    _kernel_mod.paged_attention_streamed.lower(
        aval(q), aval(k_pages), aval(v_pages), aval(page_table),
        aval(kv_len), aval(q_offset), causal=causal, interpret=interpret,
        block_pages=block_pages).compile()
    _PROBE_OK.add(key)


def _count_dispatch(path: str, p_seq: int, ps: int) -> None:
    obs.registry().counter(
        _DISPATCH,
        help="engine.matmul dispatches per execution path, bumped per "
             "call (= per trace under jit), labeled by KxN geometry",
    ).inc(path=path, geometry=f"{p_seq}x{ps}")


class _PagedPathCallsView(Mapping):
    """Read-only view over the paged-attention dispatch counters, summed
    across geometries (``paged_path_calls["paged_streamed"]``); the
    registry keeps the per-geometry split."""

    _PATHS = ("paged_scratch", "paged_streamed", "paged_fallback")

    def __getitem__(self, key: str) -> int:
        if key not in self._PATHS:
            raise KeyError(key)
        return int(obs.registry().total(_DISPATCH, path=key))

    def __iter__(self):
        return iter(self._PATHS)

    def __len__(self) -> int:
        return len(self._PATHS)

    def __eq__(self, other) -> bool:
        if isinstance(other, (Mapping, dict)):
            return dict(self) == dict(other)
        return NotImplemented

    __hash__ = None

    def __repr__(self) -> str:
        return f"paged_path_calls({dict(self)})"


paged_path_calls = _PagedPathCallsView()


def paged_attention(q, k_pages, v_pages, page_table, kv_len, q_offset,
                    *, causal: bool = True, interpret: bool = True,
                    lane: str = "auto", stream_min_pages: int = 0,
                    block_pages: int = 16):
    """Ragged paged decode attention; see kernel.py for the per-lane
    contracts.

    ``lane``: ``"auto"`` (streamed iff ``stream_min_pages > 0`` and the
    table is at least that many pages wide), ``"scratch"``, or
    ``"streamed"``.  ``block_pages`` sizes the streamed lane's page
    blocks (clamped to a divisor of the table width).
    """
    b, sq, hq, hd = q.shape
    if k_pages.shape != v_pages.shape:
        raise ValueError(f"k/v page pools disagree: {k_pages.shape} vs "
                         f"{v_pages.shape}")
    p1, ps, kv, hd2 = k_pages.shape
    if hd2 != hd:
        raise ValueError(f"head_dim mismatch: q {hd} vs pages {hd2}")
    if hq % kv:
        raise ValueError(f"n_heads {hq} not a multiple of kv heads {kv}")
    if page_table.shape[0] != b:
        raise ValueError(f"page_table rows {page_table.shape[0]} != "
                         f"batch {b}")
    kv_len = jnp.asarray(kv_len)
    q_offset = jnp.asarray(q_offset)
    if kv_len.shape != (b,) or q_offset.shape != (b,):
        raise ValueError(f"kv_len/q_offset want shape ({b},), got "
                         f"{kv_len.shape}/{q_offset.shape}")
    if lane not in ("auto", "scratch", "streamed"):
        raise ValueError(f"unknown lane {lane!r} (want auto | scratch | "
                         f"streamed)")
    p_seq = page_table.shape[1]
    if lane == "auto":
        lane = ("streamed" if stream_min_pages > 0
                and p_seq >= stream_min_pages else "scratch")
    page_table = page_table.astype(jnp.int32)
    if lane == "streamed":
        try:
            # probe-compile first: trace-time errors raise from the call
            # below, but lowering/compile errors would otherwise fire
            # later, inside the enclosing jit's compile, past this except
            # (module docstring, "Scope of that guard")
            _probe_streamed(q, k_pages, v_pages, page_table, kv_len,
                            q_offset, causal=causal, interpret=interpret,
                            block_pages=block_pages)
            out = _kernel_mod.paged_attention_streamed(
                q, k_pages, v_pages, page_table, kv_len, q_offset,
                causal=causal, interpret=interpret,
                block_pages=block_pages)
            _count_dispatch("paged_streamed", p_seq, ps)
            return out
        except Exception as e:  # noqa: BLE001 — trace/lower/compile failure
            # NEVER silently degrade: the fallback target is the scratch
            # KERNEL (still a Pallas lane, still bitwise-contracted), the
            # warning names the cause, and the counter lets the bench
            # exit-gate fallbacks at zero.
            key = (p_seq, ps)
            if key not in _FALLBACK_WARNED:
                _FALLBACK_WARNED.add(key)
                warnings.warn(
                    f"paged_attention: streamed lane failed for geometry "
                    f"{p_seq}x{ps} ({type(e).__name__}: {e}); falling "
                    f"back to the gather-scratch kernel. Long windows "
                    f"will pay O(window) VMEM until this is fixed.",
                    stacklevel=2)
            _count_dispatch("paged_fallback", p_seq, ps)
            return paged_attention_kernel(q, k_pages, v_pages, page_table,
                                          kv_len, q_offset, causal=causal,
                                          interpret=interpret)
    _count_dispatch("paged_scratch", p_seq, ps)
    return paged_attention_kernel(q, k_pages, v_pages, page_table, kv_len,
                                  q_offset, causal=causal,
                                  interpret=interpret)
