"""Shape-checked entry point for the paged-attention kernel.

Mirrors crossbar_mac's layering: ops validates/normalizes operands and
dispatches the kernel; the kernel stays a pure shape-in/shape-out
Pallas call.  No padding is needed here — the serving tier guarantees
``page_size | max_len`` (kv_pool.py enforces it), so the gathered depth
is already the dense path's ``max_len``.

Page tables may ALIAS: no validation here (or in the kernel) assumes
table entries are unique across rows.  Refcounted prefix sharing
(serve/kv_pool.py) points several rows' tables at the same physical
pages, and the read-only gather makes that bitwise-indistinguishable
from private copies — see docs/KERNELS.md, "Aliased page tables are
in-contract".
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_attention_kernel


def paged_attention(q, k_pages, v_pages, page_table, kv_len, q_offset,
                    *, causal: bool = True, interpret: bool = True):
    """Ragged paged decode attention; see kernel.py for the contract."""
    b, sq, hq, hd = q.shape
    if k_pages.shape != v_pages.shape:
        raise ValueError(f"k/v page pools disagree: {k_pages.shape} vs "
                         f"{v_pages.shape}")
    p1, ps, kv, hd2 = k_pages.shape
    if hd2 != hd:
        raise ValueError(f"head_dim mismatch: q {hd} vs pages {hd2}")
    if hq % kv:
        raise ValueError(f"n_heads {hq} not a multiple of kv heads {kv}")
    if page_table.shape[0] != b:
        raise ValueError(f"page_table rows {page_table.shape[0]} != "
                         f"batch {b}")
    kv_len = jnp.asarray(kv_len)
    q_offset = jnp.asarray(q_offset)
    if kv_len.shape != (b,) or q_offset.shape != (b,):
        raise ValueError(f"kv_len/q_offset want shape ({b},), got "
                         f"{kv_len.shape}/{q_offset.shape}")
    return paged_attention_kernel(q, k_pages, v_pages,
                                  page_table.astype(jnp.int32), kv_len,
                                  q_offset, causal=causal,
                                  interpret=interpret)
