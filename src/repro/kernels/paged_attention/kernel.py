"""Pallas kernel: paged ragged decode attention.

Grid = (batch,): each step serves ONE sequence row.  Inside the body:

  * the row's page table (a (1, P_seq) int32 operand) drives a staticly
    unrolled gather — each logical page is a dynamic-index load from the
    physical page pool into a VMEM scratch buffer, materializing the
    row's logical K/V view (depth = P_seq * page_size == max_len),
  * grouped SDPA over that view with the EXACT dense-path math: the same
    einsum contraction strings as ``models/layers._sdpa`` (f32
    accumulation via ``preferred_element_type``), the same -1e30 causal
    and length mask constants, the same softmax — so the kernel is
    bit-exact vs both ``ref.py`` and the dense cache path at equal
    contents (the interpret-mode CI pins this),
  * per-row scalars ``kv_len`` (valid depth) and ``q_offset`` (absolute
    position of the window's first query) arrive as (1, 1) SMEM operands
    — rows sit at different depths under continuous batching, and the
    causal offset must not be a trace constant.

The gather is READ-ONLY, so aliased page tables (two rows sharing
physical prefix pages under the scheduler's refcounted prefix sharing)
are in-contract and bit-exact vs materialized private copies; the
scheduler's copy-on-write keeps *writes* off shared pages before this
kernel ever runs (docs/KERNELS.md).

VMEM budget per step (one row): the gathered K+V views dominate at
2 * max_len * kv_heads * head_dim elements — at the serving tier's
decode shapes (max_len <= a few k, GQA'd kv_heads) this is well under
the 16 MB v5e budget.  TPU porting notes live in docs/KERNELS.md: the
gather loop wants scalar-prefetch (PrefetchScalarGridSpec) so page ids
are known before the DMA, and a production flash-style online-softmax
variant would trade the bitwise-equality contract for O(page) memory.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import smem_scalar_spec, tpu_compiler_params


def _kernel(pt_ref, len_ref, off_ref, q_ref, k_ref, v_ref, out_ref,
            ks_ref, vs_ref, *, page_size: int, pages_per_seq: int,
            causal: bool):
    ps = page_size
    # gather: logical page i of this row lives at physical page pt[i]
    # (0 = the null page — unallocated entries read zeros that the
    # length mask below excludes exactly)
    for i in range(pages_per_seq):
        pg = pt_ref[0, i]
        ks_ref[pl.ds(i * ps, ps)] = k_ref[pl.ds(pg, 1)].reshape(
            ps, *k_ref.shape[2:])
        vs_ref[pl.ds(i * ps, ps)] = v_ref[pl.ds(pg, 1)].reshape(
            ps, *v_ref.shape[2:])
    kk = ks_ref[...]                       # (depth, kv, hd)
    vv = vs_ref[...]
    q = q_ref[0]                           # (sq, hq, hd)
    if kk.dtype != q.dtype:   # low-precision (fp8) cache: upcast in-dot
        kk = kk.astype(q.dtype)
        vv = vv.astype(q.dtype)
    sq, hq, hd = q.shape
    depth, kv = kk.shape[0], kk.shape[1]
    g = hq // kv
    qg = q.reshape(sq, kv, g, hd)
    scale = hd ** -0.5
    # identical contraction to _sdpa's "bskgh,btkh->bkgst" at B = 1
    logits = jnp.einsum("skgh,tkh->kgst", qg, kk,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = off_ref[0, 0] + jnp.arange(sq)
        mask = qpos[:, None] >= jnp.arange(depth)[None, :]
        logits = jnp.where(mask[None, None], logits, -1e30)
    valid = jnp.arange(depth) < len_ref[0, 0]
    logits = jnp.where(valid[None, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("kgst,tkh->skgh", w.astype(vv.dtype), vv)
    out_ref[0] = out.reshape(sq, hq, hd)


@functools.partial(jax.jit, static_argnames=("causal", "interpret"))
def paged_attention_kernel(q, k_pages, v_pages, page_table, kv_len,
                           q_offset, *, causal: bool = True,
                           interpret: bool = True):
    """q (B, sq, hq, hd); k/v pages (P+1, ps, kv, hd); page_table
    (B, P_seq) int32; kv_len/q_offset (B,) int32 -> (B, sq, hq, hd).

    ``kv_len`` and ``q_offset`` are traced operands: rows at different
    cache depths share ONE lowered kernel.  interpret=True on CPU; False
    on real TPU.
    """
    b, sq, hq, hd = q.shape
    p1, ps, kv, _ = k_pages.shape
    p_seq = page_table.shape[1]
    depth = p_seq * ps
    grid = (b,)
    return pl.pallas_call(
        functools.partial(_kernel, page_size=ps, pages_per_seq=p_seq,
                          causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, p_seq), lambda i: (i, 0)),
            smem_scalar_spec(lambda i: (i, 0)),
            smem_scalar_spec(lambda i: (i, 0)),
            pl.BlockSpec((1, sq, hq, hd), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((p1, ps, kv, hd), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((p1, ps, kv, hd), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, sq, hq, hd), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, hq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((depth, kv, hd), k_pages.dtype),
            pltpu.VMEM((depth, kv, hd), v_pages.dtype),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(page_table,
      kv_len.astype(jnp.int32).reshape(b, 1),
      q_offset.astype(jnp.int32).reshape(b, 1),
      q, k_pages, v_pages)
