"""Pallas kernels: paged ragged decode attention, two lanes.

**Scratch lane** (``paged_attention_kernel``, the small-window fast path
and the bitwise oracle) — grid = (batch,): each step serves ONE sequence
row.  Inside the body:

  * the row's page table (a (1, P_seq) int32 operand) drives a staticly
    unrolled gather — each logical page is a dynamic-index load from the
    physical page pool into a VMEM scratch buffer, materializing the
    row's logical K/V view (depth = P_seq * page_size == max_len),
  * grouped SDPA over that view with the EXACT dense-path math: the same
    einsum contraction strings as ``models/layers._sdpa`` (f32
    accumulation via ``preferred_element_type``), the same -1e30 causal
    and length mask constants, the same softmax — so the kernel is
    bit-exact vs both ``ref.py`` and the dense cache path at equal
    contents (the interpret-mode CI pins this),
  * per-row scalars ``kv_len`` (valid depth) and ``q_offset`` (absolute
    position of the window's first query) arrive as (1, 1) SMEM operands
    — rows sit at different depths under continuous batching, and the
    causal offset must not be a trace constant.

The gather is READ-ONLY, so aliased page tables (two rows sharing
physical prefix pages under the scheduler's refcounted prefix sharing)
are in-contract and bit-exact vs materialized private copies; the
scheduler's copy-on-write keeps *writes* off shared pages before this
kernel ever runs (docs/KERNELS.md).

VMEM budget per step (one row): the gathered K+V views dominate at
2 * max_len * kv_heads * head_dim elements — peak scratch grows
LINEARLY with the window (``scratch_lane_vmem_bytes``), which is what
caps this lane at short windows.

**Streamed lane** (``paged_attention_streamed``, the long-context path)
— a block-streamed online-softmax (flash-style) kernel.  Grid =
(n_page_blocks,) with the whole batch folded into each block step; the
page table, fill lengths and query offsets arrive as *scalar-prefetch*
operands (``compat.prefetch_grid_spec`` →
``pltpu.PrefetchScalarGridSpec``; on TPU the table is in SMEM before
the first DMA issues).  Each step gathers ONE page block of K/V into a
two-slot VMEM scratch ring — block j+1 prefetches into the other slot
while block j is attended — and folds it into running max /
denominator / accumulator scratch carried across grid steps.

The O(block_pages) claim is about the *scratch* (the ring + the f32
online-softmax stats, ``streamed_lane_vmem_bytes``): it is constant in
the window length, which is what lets ``block_pages`` cap the working
set the attention math touches per step.  It is NOT yet the kernel's
total VMEM residency on a real TPU lowering: the current ``in_specs``
map the whole K/V pools as single full-array blocks (the body gathers
with ``k_ref[...][page_ids]``), which the CPU interpreter streams
lazily but a Mosaic lowering would make resident —
``streamed_lane_resident_bytes`` accounts that honestly (scratch +
2×pool), and the paged bench records both numbers.  Finishing the TPU
port means replacing the one-shot gather with per-block DMA out of
HBM-resident pools (``pltpu.make_async_copy`` indexed by the
prefetched table, or per-page index maps through the scalar-prefetch
operands); the slot arithmetic, the scratch layout and the numerics
below do not change — see docs/KERNELS.md "Porting notes".

Numerics contract per lane: the scratch lane is bitwise vs ref.py and
the dense ``_sdpa`` (the paged≡dense stream oracle).  The streamed lane
reassociates the softmax reduction (one block at a time), so bitwise
equality with the one-shot order is unattainable *by construction* —
and empirically even a jitted same-order jnp replica of the block
recursion drifts 1–2 ulp vs the in-kernel execution (XLA fuses the
multiply-adds differently inside the Pallas interpreter than in a plain
jit graph).  Its contract is therefore **bounded-ulp + argmax-stable**:
``|streamed − scratch| <= ~1e-6`` relative at fp32 and the argmax over
the head dim never moves (tests/test_paged_streamed.py pins both, plus
its own block-order oracle ``ref.paged_attention_streamed_ref``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import (prefetch_grid_spec, smem_scalar_spec,
                                  tpu_compiler_params)


def _kernel(pt_ref, len_ref, off_ref, q_ref, k_ref, v_ref, out_ref,
            ks_ref, vs_ref, *, page_size: int, pages_per_seq: int,
            causal: bool):
    ps = page_size
    # gather: logical page i of this row lives at physical page pt[i]
    # (0 = the null page — unallocated entries read zeros that the
    # length mask below excludes exactly)
    for i in range(pages_per_seq):
        pg = pt_ref[0, i]
        ks_ref[pl.ds(i * ps, ps)] = k_ref[pl.ds(pg, 1)].reshape(
            ps, *k_ref.shape[2:])
        vs_ref[pl.ds(i * ps, ps)] = v_ref[pl.ds(pg, 1)].reshape(
            ps, *v_ref.shape[2:])
    kk = ks_ref[...]                       # (depth, kv, hd)
    vv = vs_ref[...]
    q = q_ref[0]                           # (sq, hq, hd)
    if kk.dtype != q.dtype:   # low-precision (fp8) cache: upcast in-dot
        kk = kk.astype(q.dtype)
        vv = vv.astype(q.dtype)
    sq, hq, hd = q.shape
    depth, kv = kk.shape[0], kk.shape[1]
    g = hq // kv
    qg = q.reshape(sq, kv, g, hd)
    scale = hd ** -0.5
    # identical contraction to _sdpa's "bskgh,btkh->bkgst" at B = 1
    logits = jnp.einsum("skgh,tkh->kgst", qg, kk,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = off_ref[0, 0] + jnp.arange(sq)
        mask = qpos[:, None] >= jnp.arange(depth)[None, :]
        logits = jnp.where(mask[None, None], logits, -1e30)
    valid = jnp.arange(depth) < len_ref[0, 0]
    logits = jnp.where(valid[None, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("kgst,tkh->skgh", w.astype(vv.dtype), vv)
    out_ref[0] = out.reshape(sq, hq, hd)


@functools.partial(jax.jit, static_argnames=("causal", "interpret"))
def paged_attention_kernel(q, k_pages, v_pages, page_table, kv_len,
                           q_offset, *, causal: bool = True,
                           interpret: bool = True):
    """q (B, sq, hq, hd); k/v pages (P+1, ps, kv, hd); page_table
    (B, P_seq) int32; kv_len/q_offset (B,) int32 -> (B, sq, hq, hd).

    ``kv_len`` and ``q_offset`` are traced operands: rows at different
    cache depths share ONE lowered kernel.  interpret=True on CPU; False
    on real TPU.
    """
    b, sq, hq, hd = q.shape
    p1, ps, kv, _ = k_pages.shape
    p_seq = page_table.shape[1]
    depth = p_seq * ps
    grid = (b,)
    return pl.pallas_call(
        functools.partial(_kernel, page_size=ps, pages_per_seq=p_seq,
                          causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, p_seq), lambda i: (i, 0)),
            smem_scalar_spec(lambda i: (i, 0)),
            smem_scalar_spec(lambda i: (i, 0)),
            pl.BlockSpec((1, sq, hq, hd), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((p1, ps, kv, hd), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((p1, ps, kv, hd), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, sq, hq, hd), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, hq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((depth, kv, hd), k_pages.dtype),
            pltpu.VMEM((depth, kv, hd), v_pages.dtype),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(page_table,
      kv_len.astype(jnp.int32).reshape(b, 1),
      q_offset.astype(jnp.int32).reshape(b, 1),
      q, k_pages, v_pages)


# -- streamed lane: block-streamed online softmax ---------------------------


def _stream_body(pt_ref, len_ref, off_ref, q_ref, k_ref, v_ref, out_ref,
                 ks_ref, vs_ref, m_ref, l_ref, acc_ref, *, page_size: int,
                 block_pages: int, n_blocks: int, causal: bool):
    """One grid step = one page block for the WHOLE batch.

    Scratch refs carried across steps: ``ks/vs`` — the (2, B, block_tok,
    kv, hd) double-buffer ring; ``m/l`` — running max / denominator
    (B, kv, g, sq) in f32; ``acc`` — the unnormalized output accumulator
    (B, kv, g, sq, hd) in f32.  Step j attends the block prefetched at
    step j−1 (slot j % 2) while prefetching block j+1 into the other
    slot; the final step divides ``acc / l`` and writes the output.
    """
    j = pl.program_id(0)
    ps, bp = page_size, block_pages
    bt = bp * ps                                   # tokens per block
    b = q_ref.shape[0]

    def gather(jb, slot):
        # one-shot gather: B*bp page ids -> one XLA gather of the pool
        # (the unrolled per-page dynamic slices of the scratch lane cost
        # O(pages) kernel ops; this is O(1) ops per block)
        ptj = pt_ref[:, pl.ds(jb * bp, bp)].reshape(-1)
        kk = k_ref[...][ptj].reshape(b, bt, *k_ref.shape[2:])
        vv = v_ref[...][ptj].reshape(b, bt, *v_ref.shape[2:])
        ks_ref[pl.ds(slot, 1)] = kk[None]
        vs_ref[pl.ds(slot, 1)] = vv[None]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        gather(0, 0)                               # prime slot 0

    @pl.when(j + 1 < n_blocks)
    def _prefetch():                               # double-buffer: next
        gather(j + 1, (j + 1) % 2)                 # block -> other slot

    cur = j % 2
    kk = ks_ref[pl.ds(cur, 1)][0]                  # (B, bt, kv, hd)
    vv = vs_ref[pl.ds(cur, 1)][0]
    q = q_ref[...]
    if kk.dtype != q.dtype:   # low-precision (fp8) cache: upcast in-dot
        kk = kk.astype(q.dtype)
        vv = vv.astype(q.dtype)
    _, sq, hq, hd = q.shape
    kv = kk.shape[2]
    g = hq // kv
    qg = q.reshape(b, sq, kv, g, hd)
    scale = hd ** -0.5
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, kk,
                        preferred_element_type=jnp.float32) * scale
    tpos = j * bt + jnp.arange(bt)                 # absolute KV positions
    if causal:
        qpos = off_ref[:, 0][:, None] + jnp.arange(sq)[None]
        mask = qpos[:, :, None] >= tpos[None, None, :]
        logits = jnp.where(mask[:, None, None], logits, -1e30)
    valid = tpos[None, :] < len_ref[:, 0][:, None]
    logits = jnp.where(valid[:, None, None, None], logits, -1e30)
    # online softmax: rescale the running sums by exp(m_old - m_new)
    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, logits.max(axis=-1))
    alpha = jnp.exp(m_old - m_new)
    p = jnp.exp(logits - m_new[..., None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + jnp.einsum(
        "bkgst,btkh->bkgsh", p, vv.astype(jnp.float32),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _finalize():
        out = acc_ref[...] / l_ref[...][..., None]
        out_ref[...] = out.transpose(0, 3, 1, 2, 4).reshape(
            b, sq, hq, hd).astype(out_ref.dtype)


def resolve_block_pages(pages_per_seq: int, block_pages: int) -> int:
    """Largest divisor of ``pages_per_seq`` that is <= ``block_pages``
    (the grid needs equal blocks; the serving tier's pages_per_seq is a
    power of two in practice, so this is usually ``block_pages`` itself)."""
    bp = max(1, min(block_pages, pages_per_seq))
    while pages_per_seq % bp:
        bp -= 1
    return bp


@functools.partial(jax.jit, static_argnames=(
    "causal", "interpret", "block_pages", "force_compat_fallback"))
def paged_attention_streamed(q, k_pages, v_pages, page_table, kv_len,
                             q_offset, *, causal: bool = True,
                             interpret: bool = True, block_pages: int = 16,
                             force_compat_fallback: bool = False):
    """Streamed-lane entry point; same signature/contract surface as
    ``paged_attention_kernel`` plus ``block_pages`` (pages per streamed
    block; clamped to a divisor of the table width).

    ``force_compat_fallback`` routes through the plain-GridSpec shim
    path even when ``PrefetchScalarGridSpec`` exists (compat test hook).
    """
    b, sq, hq, hd = q.shape
    p1, ps, kv, _ = k_pages.shape
    p_seq = page_table.shape[1]
    bp = resolve_block_pages(p_seq, block_pages)
    n_blocks = p_seq // bp
    g = hq // kv
    grid_kwargs = prefetch_grid_spec(
        num_scalar_prefetch=3,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((b, sq, hq, hd), lambda j, *_: (0, 0, 0, 0)),
            # the K/V pools ride as single whole-array blocks: fine for
            # the interpreter (lazy gather), but VMEM-resident under a
            # real Mosaic lowering — streamed_lane_resident_bytes counts
            # them; the TPU port swaps these for per-block DMA (module
            # docstring)
            pl.BlockSpec((p1, ps, kv, hd), lambda j, *_: (0, 0, 0, 0)),
            pl.BlockSpec((p1, ps, kv, hd), lambda j, *_: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((b, sq, hq, hd), lambda j, *_: (0, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((2, b, bp * ps, kv, hd), k_pages.dtype),
            pltpu.VMEM((2, b, bp * ps, kv, hd), v_pages.dtype),
            pltpu.VMEM((b, kv, g, sq), jnp.float32),
            pltpu.VMEM((b, kv, g, sq), jnp.float32),
            pltpu.VMEM((b, kv, g, sq, hd), jnp.float32),
        ],
        scalar_shapes=[(b, p_seq), (b, 1), (b, 1)],
        force_fallback=force_compat_fallback,
    )
    return pl.pallas_call(
        functools.partial(_stream_body, page_size=ps, block_pages=bp,
                          n_blocks=n_blocks, causal=causal),
        out_shape=jax.ShapeDtypeStruct((b, sq, hq, hd), q.dtype),
        interpret=interpret,
        **grid_kwargs,
    )(page_table.astype(jnp.int32),
      kv_len.astype(jnp.int32).reshape(b, 1),
      q_offset.astype(jnp.int32).reshape(b, 1),
      q, k_pages, v_pages)


# -- peak-scratch accounting (the bench records these) ----------------------


def scratch_lane_vmem_bytes(pages_per_seq: int, page_size: int, kv: int,
                            hd: int, kv_dtype) -> int:
    """Peak VMEM scratch of the gather-then-SDPA lane: the K+V logical
    views, LINEAR in the window length."""
    itemsize = jnp.dtype(kv_dtype).itemsize
    return 2 * pages_per_seq * page_size * kv * hd * itemsize


def streamed_lane_vmem_bytes(b: int, sq: int, hq: int, kv: int, hd: int,
                             pages_per_seq: int, page_size: int,
                             block_pages: int, kv_dtype) -> int:
    """VMEM *scratch* of the streamed lane: the two-slot K/V block ring
    plus the f32 running max/denominator/accumulator — a function of
    ``block_pages``, NOT of the window length.  This is the working set
    the per-step attention math touches; it is not the lowering's total
    residency (see :func:`streamed_lane_resident_bytes`)."""
    bp = resolve_block_pages(pages_per_seq, block_pages)
    itemsize = jnp.dtype(kv_dtype).itemsize
    g = hq // kv
    ring = 2 * 2 * b * bp * page_size * kv * hd * itemsize
    stats = (2 * b * kv * g * sq + b * kv * g * sq * hd) * 4
    return ring + stats


def streamed_lane_resident_bytes(b: int, sq: int, hq: int, kv: int,
                                 hd: int, pages_per_seq: int,
                                 page_size: int, block_pages: int,
                                 n_pool_pages: int, kv_dtype) -> int:
    """Total VMEM the CURRENT lowering would hold resident on real TPU:
    the scratch above plus the two whole K/V pools that the full-array
    ``in_specs`` pin per grid step (``n_pool_pages`` includes the null
    page).  Honest accounting for the interpret-mode-only gap the TPU
    port closes — once the gather becomes per-block HBM DMA this
    collapses to :func:`streamed_lane_vmem_bytes`."""
    itemsize = jnp.dtype(kv_dtype).itemsize
    pools = 2 * n_pool_pages * page_size * kv * hd * itemsize
    return streamed_lane_vmem_bytes(b, sq, hq, kv, hd, pages_per_seq,
                                    page_size, block_pages,
                                    kv_dtype) + pools
