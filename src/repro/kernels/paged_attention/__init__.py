"""Paged ragged decode attention: two Pallas lanes + pure-jnp oracles.

``paged_attention`` (ops.py) is the dispatching entry point: the
gather-then-SDPA **scratch** lane (bitwise vs ``paged_attention_ref``
and the dense ``_sdpa`` path — the small-window fast path and oracle)
and the block-streamed online-softmax **streamed** lane
(``paged_attention_streamed``: scalar-prefetch page table,
double-buffered page-block prefetch, O(block_pages) VMEM scratch —
``streamed_lane_resident_bytes`` accounts the full residency of the
current whole-pool lowering — bounded-ulp
+ argmax-stable vs the scratch lane, pinned against its own block-order
oracle ``paged_attention_streamed_ref``).  Dispatches land in
``crossstack_dispatch_total{path=paged_*}``; ``paged_path_calls`` is
the summed view (tests/test_paged_attention.py,
tests/test_paged_streamed.py).
"""
from repro.kernels.paged_attention.kernel import (
    paged_attention_streamed,
    resolve_block_pages,
    scratch_lane_vmem_bytes,
    streamed_lane_resident_bytes,
    streamed_lane_vmem_bytes,
)
from repro.kernels.paged_attention.ops import (
    paged_attention,
    paged_path_calls,
)
from repro.kernels.paged_attention.ref import (
    paged_attention_ref,
    paged_attention_streamed_ref,
)

__all__ = [
    "paged_attention", "paged_attention_ref", "paged_attention_streamed",
    "paged_attention_streamed_ref", "paged_path_calls",
    "resolve_block_pages", "scratch_lane_vmem_bytes",
    "streamed_lane_resident_bytes", "streamed_lane_vmem_bytes",
]
