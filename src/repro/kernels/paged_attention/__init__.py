"""Paged ragged decode attention: Pallas kernel + pure-jnp oracle.

``paged_attention`` (ops.py) gathers each row's K/V through its page
table and runs grouped SDPA with per-row lengths and causal offsets —
the kernel behind ``AttnConfig.paged_kernel``.  ``paged_attention_ref``
(ref.py) is the standalone oracle the interpret-mode CI pins the kernel
against, bit-exactly; both are bit-exact vs the dense ``_sdpa`` path at
equal cache contents (tests/test_paged_attention.py).
"""
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref

__all__ = ["paged_attention", "paged_attention_ref"]
