"""JAX version compatibility for Pallas TPU constructs.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in
newer JAX releases (and the old name later removed).  All kernels build
their compiler params through :func:`tpu_compiler_params` so either JAX
works unchanged.  :func:`smem_scalar_spec` papers over the BlockSpec
``memory_space`` keyword (absent in older JAX) for (1, 1) scalar operands.
:func:`prefetch_grid_spec` wraps ``pltpu.PrefetchScalarGridSpec`` (the
scalar-prefetch grid the streamed paged-attention kernel rides) with a
plain-``GridSpec`` fallback so a JAX without the TPU-only spec — or the
CPU interpreter of a future JAX that drops it — still runs the same
kernel body unchanged.
"""
from __future__ import annotations

from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

if hasattr(pltpu, "CompilerParams"):
    TPUCompilerParams = pltpu.CompilerParams
else:
    TPUCompilerParams = pltpu.TPUCompilerParams


def tpu_compiler_params(**kwargs):
    """Construct TPU compiler params under whichever name this JAX has."""
    return TPUCompilerParams(**kwargs)


def smem_scalar_spec(index_map):
    """BlockSpec for a (1, 1) scalar operand, in SMEM where this JAX
    supports naming the memory space (scalars belong in SMEM on TPU; the
    interpreter ignores the space, so CPU behavior is identical)."""
    try:
        return pl.BlockSpec((1, 1), index_map, memory_space=pltpu.SMEM)
    except (TypeError, AttributeError):
        return pl.BlockSpec((1, 1), index_map)


def prefetch_grid_spec(*, num_scalar_prefetch: int, grid, in_specs,
                       out_specs, scratch_shapes, scalar_shapes,
                       force_fallback: bool = False) -> dict:
    """``pl.pallas_call`` kwargs for a scalar-prefetch grid.

    Primary path: ``pltpu.PrefetchScalarGridSpec`` — the first
    ``num_scalar_prefetch`` operands land in SMEM before the grid runs,
    every ``index_map`` receives them after the grid indices, and the
    kernel body sees them as leading refs.  Fallback (a JAX without the
    spec, or ``force_fallback=True`` in tests): a plain grid where the
    scalar operands ride as ordinary full-array inputs with constant
    index maps.  The fallback is only sound for kernels that read the
    scalars *in the body* (not in index maps) and whose index maps
    tolerate the extra trailing args (write them ``lambda j, *_:``) —
    the streamed paged-attention kernel is written to that discipline,
    so both paths run the identical body.

    ``scalar_shapes``: the full shapes of the ``num_scalar_prefetch``
    leading operands, in order (the fallback needs them to build the
    constant-block specs; the primary path ignores them).
    """
    if len(scalar_shapes) != num_scalar_prefetch:
        raise ValueError(f"scalar_shapes has {len(scalar_shapes)} entries "
                         f"for num_scalar_prefetch={num_scalar_prefetch}")
    if not force_fallback and hasattr(pltpu, "PrefetchScalarGridSpec"):
        return dict(grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=num_scalar_prefetch, grid=grid,
            in_specs=list(in_specs), out_specs=out_specs,
            scratch_shapes=list(scratch_shapes)))
    scalar_specs = [
        pl.BlockSpec(tuple(shape), lambda *_, _n=len(shape): (0,) * _n)
        for shape in scalar_shapes]
    return dict(grid=grid, in_specs=scalar_specs + list(in_specs),
                out_specs=out_specs, scratch_shapes=list(scratch_shapes))
