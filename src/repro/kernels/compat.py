"""JAX version compatibility for Pallas TPU constructs.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in
newer JAX releases (and the old name later removed).  All kernels build
their compiler params through :func:`tpu_compiler_params` so either JAX
works unchanged.  :func:`smem_scalar_spec` papers over the BlockSpec
``memory_space`` keyword (absent in older JAX) for (1, 1) scalar operands.
"""
from __future__ import annotations

from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

if hasattr(pltpu, "CompilerParams"):
    TPUCompilerParams = pltpu.CompilerParams
else:
    TPUCompilerParams = pltpu.TPUCompilerParams


def tpu_compiler_params(**kwargs):
    """Construct TPU compiler params under whichever name this JAX has."""
    return TPUCompilerParams(**kwargs)


def smem_scalar_spec(index_map):
    """BlockSpec for a (1, 1) scalar operand, in SMEM where this JAX
    supports naming the memory space (scalars belong in SMEM on TPU; the
    interpreter ignores the space, so CPU behavior is identical)."""
    try:
        return pl.BlockSpec((1, 1), index_map, memory_space=pltpu.SMEM)
    except (TypeError, AttributeError):
        return pl.BlockSpec((1, 1), index_map)
