"""JAX version compatibility for Pallas TPU compiler params.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in
newer JAX releases (and the old name later removed).  All kernels build
their compiler params through :func:`tpu_compiler_params` so either JAX
works unchanged.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

if hasattr(pltpu, "CompilerParams"):
    TPUCompilerParams = pltpu.CompilerParams
else:
    TPUCompilerParams = pltpu.TPUCompilerParams


def tpu_compiler_params(**kwargs):
    """Construct TPU compiler params under whichever name this JAX has."""
    return TPUCompilerParams(**kwargs)
