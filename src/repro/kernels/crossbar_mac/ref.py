"""Pure-jnp oracle for the crossbar MAC kernel.

Computes the bit-exact digital twin of a CrossStack tile grid:

  y[b, n] = sum_t sum_s sum_p bitw[p] * slcw[s]
              * ( ADC( xbits[p, b, t, :] @ pos[s, t, :, n] + leak )
                - ADC( xbits[p, b, t, :] @ neg[s, t, :, n] + leak ) )

with xbits the two's-complement bit-serial planes of the int inputs, ADC
the saturating uniform quantizer over [0, full_scale_rows * (base - 1)],
and ``leak`` the common-mode pre-ADC code offset of an in-flight deep-net
shadow write (paper Fig. 3c; 0.0 in steady state).  The term hits both
differential conversions identically, so it survives only through ADC
quantization — which is what the kernel must reproduce exactly.

Shapes (code units, no scales — scales are applied by the caller):
  x_int : (B, T * R) int32   — quantized inputs, row-tiled
  pos   : (S, T * R, N) int8 — differential cell codes
  neg   : (S, T * R, N) int8
Returns (B, N) float32 in integer code units.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def adc(acc, adc_bits: int, full_scale: float):
    levels = 2.0 ** adc_bits - 1.0
    lsb = full_scale / levels
    return jnp.clip(jnp.round(acc / lsb), 0.0, levels) * lsb


def crossbar_mac_ref(x_int, pos, neg, *, in_bits: int, adc_bits: int,
                     bits_per_cell: int, rows_per_adc: int,
                     full_scale_rows: Optional[int] = None,
                     leak_codes=0.0):
    s, kr, n = pos.shape
    b = x_int.shape[0]
    assert kr % rows_per_adc == 0, (kr, rows_per_adc)
    t = kr // rows_per_adc
    base = 2 ** bits_per_cell
    if full_scale_rows is None:
        full_scale_rows = rows_per_adc
    full_scale = float(full_scale_rows * (base - 1))
    leak = jnp.asarray(leak_codes, jnp.float32)

    u = (x_int.astype(jnp.int32) + (1 << in_bits)) % (1 << in_bits)
    u = u.reshape(b, t, rows_per_adc)
    pos = pos.astype(jnp.float32).reshape(s, t, rows_per_adc, n)
    neg = neg.astype(jnp.float32).reshape(s, t, rows_per_adc, n)

    out = jnp.zeros((b, n), jnp.float32)
    for p in range(in_bits):
        bitw = float(2 ** p) if p < in_bits - 1 else -float(2 ** p)
        xb = ((u >> p) & 1).astype(jnp.float32)          # (B, T, R)
        for si in range(s):
            slcw = float(base ** si)
            ap = jnp.einsum("btr,trn->btn", xb, pos[si])
            an = jnp.einsum("btr,trn->btn", xb, neg[si])
            d = (adc(ap + leak, adc_bits, full_scale)
                 - adc(an + leak, adc_bits, full_scale))
            out = out + bitw * slcw * d.sum(axis=1)
    return out
