"""Pallas TPU kernel: bit-sliced CrossStack crossbar MAC.

One grid step materializes a (block_b x block_n) output tile's contribution
from one analog row group (``rows_per_adc`` rows — 2*tile_rows in expansion
mode, tile_rows in deep-net mode).  Inside the body:

  * the DAC happens in-register: the int32 inputs are expanded to
    two's-complement bit planes with shifts/masks (8x less input traffic
    than shipping pre-expanded pulse trains from HBM),
  * per (input bit, cell slice): one MXU matmul (bits x codes, exact in
    f32), followed by the saturating ADC — the per-conversion nonlinearity
    is fused in VMEM; nothing round-trips to HBM,
  * the signed shift-add recombine accumulates into the output block, which
    is revisited across the row-group grid axis (standard accumulate-over-K
    pattern; the K axis is marked "arbitrary").

Deep-net overlap reads (paper Fig. 3c): while the twin plane of a stacked
pair is being programmed, its OFF access transistors leak a common-mode
current into the shared columns.  That term rides into BOTH differential
conversions as a pre-ADC code offset — so it is a *scalar operand*, not a
compile-time constant: ``leak`` arrives as a (1, 1) f32 ref in SMEM and is
added to each analog accumulator before the ADC, exactly where
``engine._adc_codes(acc + leak_codes)`` applies it in the reference.
Passing it as a traced operand means one compiled kernel serves leak = 0
(steady state) and leak != 0 (an active hot-swap window) without
re-lowering — the serving tier flips the value per decode step.

ADC full scale is set by the *mode's* conversion group
(``full_scale_rows``), which may exceed ``rows_per_adc`` when an odd
row-tile count forces per-plane conversions in expansion mode (see
ops.py): the converter hardware keeps its range; only the analog group
shrinks.

VMEM budget per step (f32 words):
  x: block_b * rows  +  pos/neg: 2 * S * rows * block_n  +  out: block_b * block_n
With the default block_b = block_n = 128, rows = 256, S <= 4 this is
~1.2 MB << 16 MB v5e VMEM, leaving room for the automatic double buffering
that overlaps the next row-group's DMA with the current matmuls (the
deep-net read/write overlap, at the kernel level).

The MXU contraction dim is ``rows`` (a multiple of 128 in production
configs) and the output tile is 128-aligned.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import smem_scalar_spec, tpu_compiler_params


def _adc(acc, adc_bits: int, full_scale: float):
    # NB: divide (not reciprocal-multiply) so rounding at half-LSB points is
    # bit-identical to ref.py and the engine reference path.
    levels = 2.0 ** adc_bits - 1.0
    lsb = full_scale / levels
    return jnp.clip(jnp.round(acc / lsb), 0.0, levels) * lsb


def _kernel(leak_ref, x_ref, pos_ref, neg_ref, out_ref, *, in_bits: int,
            adc_bits: int, bits_per_cell: int, rows_per_adc: int,
            full_scale_rows: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    base = 2 ** bits_per_cell
    full_scale = float(full_scale_rows * (base - 1))
    leak = leak_ref[0, 0]                                 # common-mode code
    x = x_ref[...].astype(jnp.int32)                      # (B, R)
    u = (x + (1 << in_bits)) % (1 << in_bits)             # two's complement

    acc = jnp.zeros_like(out_ref)
    for p in range(in_bits):
        bitw = float(2 ** p) if p < in_bits - 1 else -float(2 ** p)
        xb = ((u >> p) & 1).astype(jnp.float32)           # in-register DAC
        for s in range(pos_ref.shape[0]):
            slcw = float(base ** s)
            ap = jax.lax.dot(xb, pos_ref[s].astype(jnp.float32),
                             preferred_element_type=jnp.float32)
            an = jax.lax.dot(xb, neg_ref[s].astype(jnp.float32),
                             preferred_element_type=jnp.float32)
            d = (_adc(ap + leak, adc_bits, full_scale)
                 - _adc(an + leak, adc_bits, full_scale))
            acc = acc + (bitw * slcw) * d
    out_ref[...] += acc


@functools.partial(jax.jit, static_argnames=(
    "in_bits", "adc_bits", "bits_per_cell", "rows_per_adc",
    "full_scale_rows", "block_b", "block_n", "interpret"))
def crossbar_mac(x_int, pos, neg, leak_codes=0.0, *, in_bits: int,
                 adc_bits: int, bits_per_cell: int, rows_per_adc: int,
                 full_scale_rows: Optional[int] = None,
                 block_b: int = 128, block_n: int = 128,
                 interpret: bool = True):
    """x_int (B, K) int32, pos/neg (S, K, N) int8 -> (B, N) f32 code units.

    ``leak_codes`` is the write-plane common-mode leakage in pre-ADC code
    units — a *traced* scalar (python float or 0-d array): changing its
    value does not re-lower the kernel.  ``full_scale_rows`` sets the ADC
    full scale independently of the contraction group (defaults to
    ``rows_per_adc``; ops.py passes the mode's group when an odd row-tile
    count forces smaller conversions).  K must be a multiple of
    rows_per_adc; B of block_b; N of block_n (ops.py pads).
    interpret=True on CPU; False on real TPU.
    """
    b, k = x_int.shape
    s, k2, n = pos.shape
    assert k == k2 and k % rows_per_adc == 0
    if full_scale_rows is None:
        full_scale_rows = rows_per_adc
    grid = (b // block_b, n // block_n, k // rows_per_adc)
    leak = jnp.asarray(leak_codes, jnp.float32).reshape(1, 1)

    return pl.pallas_call(
        functools.partial(_kernel, in_bits=in_bits, adc_bits=adc_bits,
                          bits_per_cell=bits_per_cell,
                          rows_per_adc=rows_per_adc,
                          full_scale_rows=full_scale_rows),
        grid=grid,
        in_specs=[
            smem_scalar_spec(lambda i, j, t: (0, 0)),
            pl.BlockSpec((block_b, rows_per_adc), lambda i, j, t: (i, t)),
            pl.BlockSpec((s, rows_per_adc, block_n),
                         lambda i, j, t: (0, t, j)),
            pl.BlockSpec((s, rows_per_adc, block_n),
                         lambda i, j, t: (0, t, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(leak, x_int, pos, neg)
