"""Pallas TPU kernel: bit-sliced CrossStack crossbar MAC.

One grid step materializes a (block_b x block_n) output tile's contribution
from one analog row group (``rows_per_adc`` rows — 2*tile_rows in expansion
mode, tile_rows in deep-net mode).  Inside the body:

  * the DAC happens in-register: the int32 inputs are expanded to
    two's-complement bit planes with shifts/masks (8x less input traffic
    than shipping pre-expanded pulse trains from HBM),
  * per (input bit, cell slice): one MXU matmul (bits x codes, exact in
    f32), followed by the saturating ADC — the per-conversion nonlinearity
    is fused in VMEM; nothing round-trips to HBM,
  * the signed shift-add recombine accumulates into the output block, which
    is revisited across the row-group grid axis (standard accumulate-over-K
    pattern; the K axis is marked "arbitrary").

VMEM budget per step (f32 words):
  x: block_b * rows  +  pos/neg: 2 * S * rows * block_n  +  out: block_b * block_n
With the default block_b = block_n = 128, rows = 256, S <= 4 this is
~1.2 MB << 16 MB v5e VMEM, leaving room for the automatic double buffering
that overlaps the next row-group's DMA with the current matmuls (the
deep-net read/write overlap, at the kernel level).

The MXU contraction dim is ``rows`` (a multiple of 128 in production
configs) and the output tile is 128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import tpu_compiler_params


def _adc(acc, adc_bits: int, full_scale: float):
    # NB: divide (not reciprocal-multiply) so rounding at half-LSB points is
    # bit-identical to ref.py and the engine reference path.
    levels = 2.0 ** adc_bits - 1.0
    lsb = full_scale / levels
    return jnp.clip(jnp.round(acc / lsb), 0.0, levels) * lsb


def _kernel(x_ref, pos_ref, neg_ref, out_ref, *, in_bits: int,
            adc_bits: int, bits_per_cell: int, rows_per_adc: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    base = 2 ** bits_per_cell
    full_scale = float(rows_per_adc * (base - 1))
    x = x_ref[...].astype(jnp.int32)                      # (B, R)
    u = (x + (1 << in_bits)) % (1 << in_bits)             # two's complement

    acc = jnp.zeros_like(out_ref)
    for p in range(in_bits):
        bitw = float(2 ** p) if p < in_bits - 1 else -float(2 ** p)
        xb = ((u >> p) & 1).astype(jnp.float32)           # in-register DAC
        for s in range(pos_ref.shape[0]):
            slcw = float(base ** s)
            ap = jax.lax.dot(xb, pos_ref[s].astype(jnp.float32),
                             preferred_element_type=jnp.float32)
            an = jax.lax.dot(xb, neg_ref[s].astype(jnp.float32),
                             preferred_element_type=jnp.float32)
            d = (_adc(ap, adc_bits, full_scale)
                 - _adc(an, adc_bits, full_scale))
            acc = acc + (bitw * slcw) * d
    out_ref[...] += acc


@functools.partial(jax.jit, static_argnames=(
    "in_bits", "adc_bits", "bits_per_cell", "rows_per_adc",
    "block_b", "block_n", "interpret"))
def crossbar_mac(x_int, pos, neg, *, in_bits: int, adc_bits: int,
                 bits_per_cell: int, rows_per_adc: int,
                 block_b: int = 128, block_n: int = 128,
                 interpret: bool = True):
    """x_int (B, K) int32, pos/neg (S, K, N) int8 -> (B, N) f32 code units.

    K must be a multiple of rows_per_adc; B of block_b; N of block_n
    (ops.py pads).  interpret=True on CPU; False on real TPU.
    """
    b, k = x_int.shape
    s, k2, n = pos.shape
    assert k == k2 and k % rows_per_adc == 0
    grid = (b // block_b, n // block_n, k // rows_per_adc)

    return pl.pallas_call(
        functools.partial(_kernel, in_bits=in_bits, adc_bits=adc_bits,
                          bits_per_cell=bits_per_cell,
                          rows_per_adc=rows_per_adc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, rows_per_adc), lambda i, j, t: (i, t)),
            pl.BlockSpec((s, rows_per_adc, block_n),
                         lambda i, j, t: (0, t, j)),
            pl.BlockSpec((s, rows_per_adc, block_n),
                         lambda i, j, t: (0, t, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_int, pos, neg)
