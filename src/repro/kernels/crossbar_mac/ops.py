"""Jitted wrapper: engine-facing entry point for the crossbar MAC kernel.

Handles quantization, padding to kernel-friendly shapes, scale application
and un-padding, so ``engine.matmul(..., use_kernel=True)`` is a drop-in for
the jnp reference path — including deep-net overlap reads, where the write
plane's common-mode leakage arrives as a traced ``leak_codes`` scalar
(changing its value between decode steps never re-lowers the kernel).

Expansion-fused reads (per-weight mode policy, ``executor.mode_report``)
ride this same lane: the executor dispatches them through its cached
expansion-mode cfg, so ``cfg.rows_per_adc`` doubles the pre-ADC grouping
and the fused pair's planes convert as one analog sum — with
``leak_codes`` pinned to the Python constant 0.0 at trace time, since a
fused pair never hosts an in-flight write.  Mixed-mode models therefore
lower one kernel variant per mode, not per swap-window state.
"""
from __future__ import annotations

import warnings

import jax.numpy as jnp

from repro.core import quant
from repro.kernels.crossbar_mac.kernel import crossbar_mac

# grouping-fallback warnings already emitted, keyed by tile geometry —
# warn once per geometry, not once per traced matmul
_FALLBACK_WARNED = set()


def _pad_axis(x, mult, axis):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def crossbar_matmul(x, pw, cfg, leak_codes=0.0):
    """x (..., K) float, pw: ProgrammedLinear, cfg: EngineConfig -> (..., N).

    ``leak_codes`` (python float or traced 0-d array) is the in-flight
    shadow write's common-mode pre-ADC offset, fused into the kernel's ADC
    stage exactly as ``engine.matmul_reference`` applies it.
    """
    q = cfg.quant
    lead = x.shape[:-1]
    xb = x.reshape(-1, x.shape[-1])
    x_int, x_scale = quant.quantize_inputs(xb, q)

    s, t, r, n_pad = pw.pos.shape
    pos = pw.pos.reshape(s, t * r, n_pad)
    neg = pw.neg.reshape(s, t * r, n_pad)
    x_int = _pad_axis(x_int.astype(jnp.int32), t * r, axis=-1)

    rows_per_adc = cfg.rows_per_adc
    full_scale_rows = cfg.rows_per_adc
    if (t * r) % rows_per_adc != 0:
        # odd number of row tiles in expansion mode: the pairwise analog
        # sum has no partner tile, so conversions fall back to per-plane
        # groups.  The ADC itself keeps the mode's full scale
        # (full_scale_rows) — matching the reference path, which digitizes
        # un-paired tiles against the expansion-mode range.
        rows_per_adc = r
        key = (cfg.mode, t, r)
        if key not in _FALLBACK_WARNED:
            _FALLBACK_WARNED.add(key)
            warnings.warn(
                f"crossbar_mac: {t} row tiles of {r} rows cannot pair for "
                f"{cfg.mode}-mode analog summation ({t * r} rows % "
                f"{cfg.rows_per_adc} rows/ADC != 0); falling back to "
                f"per-plane conversions ({r} rows/ADC at the mode's "
                f"{full_scale_rows}-row full scale). ADC grouping differs "
                f"from the even-tile layout — pad K to a multiple of "
                f"{cfg.rows_per_adc} rows to avoid this.",
                stacklevel=3)

    block_b = min(128, max(8, x_int.shape[0]))
    block_n = min(128, n_pad)
    x_pad = _pad_axis(x_int, block_b, axis=0)
    pos = _pad_axis(pos, block_n, axis=-1)
    neg = _pad_axis(neg, block_n, axis=-1)

    y = crossbar_mac(
        x_pad, pos, neg, leak_codes,
        in_bits=q.in_bits, adc_bits=q.adc_bits,
        bits_per_cell=q.bits_per_cell, rows_per_adc=rows_per_adc,
        full_scale_rows=full_scale_rows,
        block_b=block_b, block_n=min(block_n, pos.shape[-1]),
        interpret=cfg.interpret)

    y = y[: xb.shape[0], :n_pad]
    y = y * x_scale * pw.w_scale[..., :n_pad]
    return y[:, : pw.n].reshape(*lead, pw.n)
