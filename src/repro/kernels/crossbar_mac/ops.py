"""Jitted wrapper: engine-facing entry point for the crossbar MAC kernel.

Handles quantization, padding to kernel-friendly shapes, scale application
and un-padding, so ``engine.matmul(..., use_kernel=True)`` is a drop-in for
the jnp reference path.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import quant
from repro.kernels.crossbar_mac.kernel import crossbar_mac


def _pad_axis(x, mult, axis):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def crossbar_matmul(x, pw, cfg):
    """x (..., K) float, pw: ProgrammedLinear, cfg: EngineConfig -> (..., N)."""
    q = cfg.quant
    lead = x.shape[:-1]
    xb = x.reshape(-1, x.shape[-1])
    x_int, x_scale = quant.quantize_inputs(xb, q)

    s, t, r, n_pad = pw.pos.shape
    pos = pw.pos.reshape(s, t * r, n_pad)
    neg = pw.neg.reshape(s, t * r, n_pad)
    x_int = _pad_axis(x_int.astype(jnp.int32), t * r, axis=-1)

    rows_per_adc = cfg.rows_per_adc
    if (t * r) % rows_per_adc != 0:
        # odd number of row tiles in expansion mode: fall back to per-plane
        rows_per_adc = r

    block_b = min(128, max(8, x_int.shape[0]))
    block_n = min(128, n_pad)
    x_pad = _pad_axis(x_int, block_b, axis=0)
    pos = _pad_axis(pos, block_n, axis=-1)
    neg = _pad_axis(neg, block_n, axis=-1)

    y = crossbar_mac(
        x_pad, pos, neg, in_bits=q.in_bits, adc_bits=q.adc_bits,
        bits_per_cell=q.bits_per_cell, rows_per_adc=rows_per_adc,
        block_b=block_b, block_n=min(block_n, pos.shape[-1]),
        interpret=cfg.interpret)

    y = y[: xb.shape[0], :n_pad]
    y = y * x_scale * pw.w_scale[..., :n_pad]
    return y[:, : pw.n].reshape(*lead, pw.n)
