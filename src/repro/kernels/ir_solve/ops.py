"""Jitted wrapper: full IR-drop solve via the fused-sweep kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.timing import PAPER
from repro.kernels.ir_solve.kernel import jacobi_sweeps


def solve(g_dev, v_in, r_wire: float = PAPER.r_wire,
          r_access: float | None = None, n_iter: int = 2000,
          sweeps_per_call: int = 16, omega: float = 1.0,
          interpret: bool = True):
    """Drop-in for core/ir_drop.jacobi_planar built on the Pallas kernel.

    Returns (i_out, v_row, v_col)."""
    if r_access is None:
        r_access = PAPER.r_on_transistor
    n, m = g_dev.shape
    g = 1.0 / (1.0 / jnp.maximum(g_dev, 1e-12) + r_access)
    g = g.astype(jnp.float32)
    g_w = 1.0 / r_wire
    v_row = jnp.broadcast_to(v_in[:, None], (n, m)).astype(jnp.float32)
    v_col = jnp.zeros((n, m), jnp.float32)
    vin_col = v_in[:, None].astype(jnp.float32)
    for _ in range(max(1, n_iter // sweeps_per_call)):
        v_row, v_col = jacobi_sweeps(g, vin_col, v_row, v_col,
                                     g_w=float(g_w), omega=omega,
                                     sweeps=sweeps_per_call,
                                     interpret=interpret)
    i_out = g_w * v_col[n - 1, :]
    return i_out, v_row, v_col
