"""Pure-jnp oracle for the IR-drop stencil kernel: one damped-Jacobi sweep
of the planar crossbar ladder network (see core/ir_drop.jacobi_planar —
this is its inner update, exposed per-sweep for kernel validation)."""
from __future__ import annotations

import jax.numpy as jnp


def jacobi_sweep_ref(v_row, v_col, g, v_in, g_w: float, omega: float):
    """One sweep. v_row/v_col/g: (n, m); v_in: (n,).  Returns updated
    (v_row, v_col)."""
    n, m = g.shape
    west = jnp.concatenate([v_in[:, None], v_row[:, :-1]], axis=1)
    east_g = jnp.concatenate([jnp.full((n, m - 1), g_w),
                              jnp.zeros((n, 1))], axis=1)
    east_v = jnp.concatenate([v_row[:, 1:], jnp.zeros((n, 1))], axis=1)
    num_r = g_w * west + east_g * east_v + g * v_col
    den_r = g_w + east_g + g
    v_row_new = v_row + omega * (num_r / den_r - v_row)

    north_g = jnp.concatenate([jnp.zeros((1, m)),
                               jnp.full((n - 1, m), g_w)], axis=0)
    north_v = jnp.concatenate([jnp.zeros((1, m)), v_col[:-1, :]], axis=0)
    south_v = jnp.concatenate([v_col[1:, :], jnp.zeros((1, m))], axis=0)
    num_c = north_g * north_v + g_w * south_v + g * v_row_new
    den_c = north_g + g_w + g
    v_col_new = v_col + omega * (num_c / den_c - v_col)
    return v_row_new, v_col_new
