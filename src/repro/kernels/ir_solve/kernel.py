"""Pallas TPU kernel: fused Jacobi sweeps for the crossbar IR-drop network.

Large-array fidelity studies (core/ir_drop.jacobi_planar at 256x256+) are
bandwidth-bound: each jnp sweep re-reads v_row/v_col/g from HBM.  This
kernel keeps the whole tile resident in VMEM and runs ``sweeps_per_call``
damped-Jacobi iterations per grid step — a classic stencil-in-fast-memory
pattern (HBM traffic / sweep drops by the fusion factor).

One grid cell owns the full (n, m) problem (crossbar tiles are <= 512x512
by construction — engine tiles are VMEM-sized): v_row, v_col, g and v_in
all live in VMEM; the sweep loop is unrolled at trace time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import tpu_compiler_params


def _kernel(g_ref, vin_ref, vrow_ref, vcol_ref, orow_ref, ocol_ref, *,
            g_w: float, omega: float, sweeps: int):
    g = g_ref[...]
    v_in = vin_ref[...]
    v_row = vrow_ref[...]
    v_col = vcol_ref[...]
    n, m = g.shape
    east_g = jnp.concatenate([jnp.full((n, m - 1), g_w, g.dtype),
                              jnp.zeros((n, 1), g.dtype)], axis=1)
    north_g = jnp.concatenate([jnp.zeros((1, m), g.dtype),
                               jnp.full((n - 1, m), g_w, g.dtype)], axis=0)
    den_r = g_w + east_g + g
    for _ in range(sweeps):
        west = jnp.concatenate([v_in, v_row[:, :-1]], axis=1)
        east_v = jnp.concatenate([v_row[:, 1:],
                                  jnp.zeros((n, 1), g.dtype)], axis=1)
        num_r = g_w * west + east_g * east_v + g * v_col
        v_row = v_row + omega * (num_r / den_r - v_row)

        north_v = jnp.concatenate([jnp.zeros((1, m), g.dtype),
                                   v_col[:-1, :]], axis=0)
        south_v = jnp.concatenate([v_col[1:, :],
                                   jnp.zeros((1, m), g.dtype)], axis=0)
        num_c = north_g * north_v + g_w * south_v + g * v_row
        den_c = north_g + g_w + g
        v_col = v_col + omega * (num_c / den_c - v_col)
    orow_ref[...] = v_row
    ocol_ref[...] = v_col


@functools.partial(jax.jit, static_argnames=("g_w", "omega", "sweeps",
                                             "interpret"))
def jacobi_sweeps(g, v_in, v_row, v_col, *, g_w: float, omega: float = 1.0,
                  sweeps: int = 8, interpret: bool = True):
    """Run ``sweeps`` fused Jacobi iterations.  g/(v_row/v_col): (n, m);
    v_in: (n, 1) column vector of source voltages."""
    n, m = g.shape
    return pl.pallas_call(
        functools.partial(_kernel, g_w=g_w, omega=omega, sweeps=sweeps),
        out_shape=(jax.ShapeDtypeStruct((n, m), g.dtype),
                   jax.ShapeDtypeStruct((n, m), g.dtype)),
        in_specs=[pl.BlockSpec((n, m), lambda: (0, 0)),
                  pl.BlockSpec((n, 1), lambda: (0, 0)),
                  pl.BlockSpec((n, m), lambda: (0, 0)),
                  pl.BlockSpec((n, m), lambda: (0, 0))],
        out_specs=(pl.BlockSpec((n, m), lambda: (0, 0)),
                   pl.BlockSpec((n, m), lambda: (0, 0))),
        compiler_params=tpu_compiler_params(),
        interpret=interpret,
    )(g, v_in, v_row, v_col)
