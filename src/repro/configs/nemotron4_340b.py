"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000 — GQA, squared-ReLU MLP.  [arXiv:2402.16819; unverified]

Largest dense cell: 680 GB of bf16 weights -> FSDP (ZeRO-3) sharding over
the data axes is mandatory; head_dim = 18432 / 96 = 192.
"""
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="nemotron-4-340b", family="dense", n_layers=96, d_model=18432,
    n_heads=96, n_kv=8, head_dim=192, d_ff=73728, vocab=256000,
    act="relu2", rope_theta=1e4, kv_repeat=2, remat="dots",
)

SMOKE = ModelConfig(
    name="nemotron-smoke", family="dense", n_layers=2, d_model=96,
    n_heads=6, n_kv=2, head_dim=16, d_ff=384, vocab=384, act="relu2",
)
