"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2.  [hf:xai-org/grok-1; unverified]

8 experts do not divide the 16-way TP axis -> baseline uses TP-within-
expert (d_ff sharded) + FSDP storage sharding; the EP all-to-all variant is
exercised on phi3.5 (16 experts).  kv_repeat=2 gives 16 effective KV heads
for clean TP-16 decode sharding (Megatron-style KV replication).
"""
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="grok-1-314b", family="moe", n_layers=64, d_model=6144,
    n_heads=48, n_kv=8, head_dim=128, d_ff=32768, vocab=131072,
    moe_experts=8, moe_top_k=2, act="gelu", kv_repeat=2, remat="dots",
)

SMOKE = ModelConfig(
    name="grok-1-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv=2, head_dim=16, d_ff=128, vocab=384,
    moe_experts=4, moe_top_k=2, act="gelu",
)
