"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 blocks + ONE shared full-attention
block applied every 6 layers (weights shared, per-application KV caches).
[arXiv:2411.15242; hf]

Hybrid -> long_500k RUNS: the Mamba2 state is O(1); the periodic attention
caches (6 applications x 500k) are KV-head/TP sharded and, for batch=1,
sequence-sharded over the data axis (SP with XLA's distributed softmax).
"""
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="zamba2-1.2b", family="zamba2", n_layers=38, d_model=2048,
    n_heads=32, n_kv=32, head_dim=64, d_ff=8192, vocab=32000,
    ssm_state=64, ssm_head_dim=64, attn_every=6, act="gelu",
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="zamba2", n_layers=5, d_model=64,
    n_heads=4, n_kv=4, head_dim=16, d_ff=128, vocab=384,
    ssm_state=16, ssm_head_dim=16, attn_every=2, act="gelu", lin_chunk=8,
)
