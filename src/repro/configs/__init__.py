"""Architecture registry: ``--arch <id>`` resolves here.

Each ``configs/<id>.py`` exports FULL (the exact published configuration)
and SMOKE (a reduced same-family config for CPU tests).  Shapes are the
four assigned input-shape cells; ``long_500k`` only applies to
sub-quadratic architectures (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
import importlib

ARCH_IDS = [
    "whisper_base", "rwkv6_3b", "grok1_314b", "phi35_moe", "qwen2_vl_72b",
    "qwen3_4b", "nemotron4_340b", "minitron_4b", "qwen3_8b", "zamba2_1p2b",
]

# public names (hyphenated) -> module names
ALIASES = {
    "whisper-base": "whisper_base",
    "rwkv6-3b": "rwkv6_3b",
    "grok-1-314b": "grok1_314b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "phi3.5-moe": "phi35_moe",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "qwen3-4b": "qwen3_4b",
    "nemotron-4-340b": "nemotron4_340b",
    "minitron-4b": "minitron_4b",
    "qwen3-8b": "qwen3_8b",
    "zamba2-1.2b": "zamba2_1p2b",
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# sub-quadratic archs that run the 500k cell (others skip; DESIGN.md)
LONG_CONTEXT_OK = {"rwkv6_3b", "zamba2_1p2b"}


def _module(arch: str):
    name = ALIASES.get(arch, arch).replace("-", "_")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch: str, smoke: bool = False):
    mod = _module(arch)
    return mod.SMOKE if smoke else mod.FULL


def shape_applicable(arch: str, shape: str) -> bool:
    name = ALIASES.get(arch, arch).replace("-", "_")
    if shape == "long_500k":
        return name in LONG_CONTEXT_OK
    return True


def all_cells():
    """Every applicable (arch, shape) dry-run cell."""
    for arch in ARCH_IDS:
        for shape in SHAPES:
            if shape_applicable(arch, shape):
                yield arch, shape
