"""minitron-4b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000 — width/depth-pruned nemotron.  [arXiv:2407.14679; hf]
"""
from repro.models.model import ModelConfig

# 24 heads do not divide 16-way TP: attention is replicated (FFN TP only,
# see dryrun.rules_for), so KV replication is unnecessary -> kv_repeat=1
# (g = 24/8 = 3).  The decode KV cache shards along SEQUENCE instead.
FULL = ModelConfig(
    name="minitron-4b", family="dense", n_layers=32, d_model=3072,
    n_heads=24, n_kv=8, head_dim=128, d_ff=9216, vocab=256000,
    act="relu2", rope_theta=1e4, kv_repeat=1,
)

SMOKE = ModelConfig(
    name="minitron-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv=2, head_dim=16, d_ff=192, vocab=384, act="relu2",
)
