"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE, dynamic resolution.  [arXiv:2409.12191; hf]

Vision frontend is a STUB: ``input_specs`` supplies precomputed patch
embeddings (B, P, d) merged before the first block, plus (t, h, w)
M-RoPE position ids for the full sequence.  Full attention -> long_500k
skipped.
"""
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="qwen2-vl-72b", family="vlm", n_layers=80, d_model=8192,
    n_heads=64, n_kv=8, head_dim=128, d_ff=29568, vocab=152064,
    act="swiglu", kv_repeat=2, remat="dots",
)

SMOKE = ModelConfig(
    name="qwen2-vl-smoke", family="vlm", n_layers=2, d_model=96,
    n_heads=6, n_kv=2, head_dim=16, d_ff=192, vocab=384,
)
