"""whisper-base [audio]: 6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865.

Encoder-decoder with a convolutional audio frontend, which is a STUB here:
``input_specs`` supplies precomputed mel-frame embeddings (B, S_src, d) —
per the assignment, only the transformer backbone is modeled.
[arXiv:2212.04356; unverified]

Attention is tiny (8 heads of 64) relative to the 16-way TP axis, so the
production rules replicate attention and TP-shard only the FFN (see
launch/dryrun.py rules overrides).  Full attention -> long_500k skipped.
"""
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="whisper-base", family="encdec", n_layers=6, d_model=512,
    n_heads=8, n_kv=8, head_dim=64, d_ff=2048, vocab=51865,
    act="gelu", norm="ln", rope_theta=1e4, qk_norm=False, kv_repeat=1,
)

SMOKE = ModelConfig(
    name="whisper-base-smoke", family="encdec", n_layers=2, d_model=64,
    n_heads=4, n_kv=4, head_dim=16, d_ff=128, vocab=384,
    act="gelu", norm="ln", rope_theta=1e4,
)
