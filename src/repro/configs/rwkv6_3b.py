"""rwkv6-3b [ssm]: 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536.

RWKV6 "Finch" — data-dependent decay linear RNN. [arXiv:2404.05892; hf]
head_size 64 -> 40 heads; decode carries O(1) state, so long_500k RUNS.
40 heads do not divide the 16-way TP axis; the value dimension (64) does —
production rules shard lin_dv over "model" (see DESIGN.md).
"""
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="rwkv6-3b", family="rwkv6", n_layers=32, d_model=2560,
    d_ff=8960, vocab=65536, ssm_head_dim=64, lin_chunk=16,
)

SMOKE = ModelConfig(
    name="rwkv6-3b-smoke", family="rwkv6", n_layers=2, d_model=64,
    d_ff=224, vocab=384, ssm_head_dim=16, lin_chunk=8,
)
