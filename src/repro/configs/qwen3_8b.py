"""qwen3-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936 — qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]
"""
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="qwen3-8b", family="dense", n_layers=36, d_model=4096,
    n_heads=32, n_kv=8, head_dim=128, d_ff=12288, vocab=151936,
    act="swiglu", qk_norm=True, kv_repeat=2,
)

SMOKE = ModelConfig(
    name="qwen3-8b-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv=2, head_dim=16, d_ff=256, vocab=384,
    act="swiglu", qk_norm=True,
)
