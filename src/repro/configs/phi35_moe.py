"""phi3.5-moe-42b-a6.6b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16 experts top-2.  [hf:microsoft/Phi-3.5-MoE-instruct; hf]

16 experts == 16-way TP axis -> this is the EP showcase arch (expert-
parallel all-to-all variant in §Perf).  Baseline: TP-within-expert.
"""
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="phi3.5-moe-42b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv=8, head_dim=128, d_ff=6400, vocab=32064,
    moe_experts=16, moe_top_k=2, act="swiglu", kv_repeat=2, remat="dots",
)

SMOKE = ModelConfig(
    name="phi3.5-moe-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv=2, head_dim=16, d_ff=96, vocab=384,
    moe_experts=4, moe_top_k=2, act="swiglu",
)
