"""Abstract input builders for every (architecture x shape) dry-run cell.

``input_specs(cfg, shape)`` returns (kind, args, logical_specs):
  * kind: "train" | "prefill" | "decode" — which step function to lower,
  * args: pytree of jax.ShapeDtypeStruct (weak-type-correct, no allocation),
  * logical_specs: matching pytree of logical-axis tuples for in_shardings.

Modality frontends are stubs per the assignment: whisper gets precomputed
frame embeddings, qwen2-vl gets precomputed patch embeddings + (t, h, w)
M-RoPE ids.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ShapeSpec
from repro.models.model import Model, ModelConfig

VLM_PATCHES = 256


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def make_batch(cfg: ModelConfig, shape: ShapeSpec
               ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Training/prefill batch structs + logical specs."""
    b, s = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    if cfg.family == "vlm":
        s_text = s - VLM_PATCHES
        batch["tokens"] = _sds((b, s_text), jnp.int32)
        specs["tokens"] = ("batch", "seq")
        batch["vis_emb"] = _sds((b, VLM_PATCHES, cfg.d_model), cfg.dtype)
        specs["vis_emb"] = ("batch", "seq", "act_embed")
        batch["positions_thw"] = _sds((b, s, 3), jnp.int32)
        specs["positions_thw"] = ("batch", "seq", None)
        if shape.kind == "train":
            batch["labels"] = _sds((b, s_text), jnp.int32)
            specs["labels"] = ("batch", "seq")
    else:
        batch["tokens"] = _sds((b, s), jnp.int32)
        specs["tokens"] = ("batch", "seq")
        if shape.kind == "train":
            batch["labels"] = _sds((b, s), jnp.int32)
            specs["labels"] = ("batch", "seq")
        if cfg.family == "encdec":
            batch["enc_emb"] = _sds((b, s, cfg.d_model), cfg.dtype)
            specs["enc_emb"] = ("batch", "seq", "act_embed")
    return batch, specs


def make_cache(model: Model, shape: ShapeSpec):
    """Abstract cache struct + logical specs for prefill/decode cells."""
    cfg = model.cfg
    b, s = shape.global_batch, shape.seq_len
    kwargs = {}
    if cfg.family == "encdec":
        kwargs["src_len"] = s
        cache = jax.eval_shape(
            lambda: model.init_cache(b, s, **kwargs))
    else:
        cache = jax.eval_shape(lambda: model.init_cache(b, s))
    return cache, model.cache_specs()


def input_specs(model: Model, shape: ShapeSpec):
    """(kind, args, logical_specs) for the step function of this cell."""
    cfg = model.cfg
    if shape.kind == "train":
        batch, specs = make_batch(cfg, shape)
        return "train", (batch,), (specs,)
    if shape.kind == "prefill":
        batch, bspecs = make_batch(cfg, shape)
        cache, cspecs = make_cache(model, shape)
        return "prefill", (batch, cache), (bspecs, cspecs)
    # decode: one new token against a seq_len-deep cache
    b = shape.global_batch
    tokens = _sds((b, 1), jnp.int32)
    tspecs = ("batch", None)
    cache, cspecs = make_cache(model, shape)
    return "decode", (tokens, cache), (tspecs, cspecs)
