"""Deterministic, seekable, shardable synthetic token pipeline.

Fault-tolerance contract: ``batch_at(step)`` is a pure function of
(seed, step, topology), so any replacement host can regenerate exactly the
batch its failed predecessor would have produced — no data-loader state to
checkpoint or replay.  Real-corpus loaders should preserve this contract
(index-based sharded reads); the synthetic stream is used by the examples,
tests and the end-to-end train driver.

The synthetic language is a structured Markov-ish stream (not uniform
noise) so models actually reduce loss on it: token t+1 depends on token t
through a fixed random permutation plus noise, with periodic "syntax"
markers — enough statistical structure for a ~100M model to show clean
learning curves in examples/train_lm.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.1       # fraction of tokens replaced by uniform noise
    period: int = 17         # syntax-marker period


class SyntheticLM:
    """next = perm[cur] with prob 1-noise else uniform; marker every period."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.perm = jnp.asarray(rng.permutation(cfg.vocab), jnp.int32)

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        b, s = cfg.global_batch, cfg.seq_len
        start = jax.random.randint(k1, (b, 1), 0, cfg.vocab)

        def gen(tok, k):
            nxt = self.perm[tok]
            return nxt, nxt

        toks = [start[:, 0]]
        cur = start[:, 0]
        # vectorized chain: token_t = perm^t(start); use gather composition
        # (cheap: s sequential gathers on (b,) vectors)
        for _ in range(s - 1):
            cur = self.perm[cur]
            toks.append(cur)
        seq = jnp.stack(toks, axis=1)
        noise_mask = jax.random.bernoulli(k2, cfg.noise, (b, s))
        noise_tok = jax.random.randint(k3, (b, s), 0, cfg.vocab)
        seq = jnp.where(noise_mask, noise_tok, seq)
        marker = (jnp.arange(s) % cfg.period) == 0
        seq = jnp.where(marker[None, :], jnp.int32(0), seq)
        return {"tokens": seq[:, :-1].astype(jnp.int32),
                "labels": seq[:, 1:].astype(jnp.int32)}

    def host_shard_at(self, step: int, host_id: int, n_hosts: int
                      ) -> Dict[str, jax.Array]:
        """Per-host slice of the global batch (deterministic by host id)."""
        full = self.batch_at(step)
        per = self.cfg.global_batch // n_hosts
        lo = host_id * per
        return jax.tree.map(lambda a: a[lo:lo + per], full)
