"""Multi-tenant plane-multiplexing benchmarks: two checkpoints served
from the two tile planes of ONE executor vs two dedicated deployments.

Three measurements, all on the CI smoke transformer:

  * **fidelity** — both tenants' token streams from one multiplexed
    executor must be bit-identical to two dedicated single-tenant
    schedulers (same checkpoints, same prompts).
  * **density** — the multiplexed deployment serves both checkpoints at
    1.0x one deployment's physical memristor count (the stacked twin
    planes that a single-tenant deploy leaves as idle write-shadows);
    two dedicated arrays burn 2.0x.
  * **availability** — a tenant-B hot-swap under tenant-A traffic: B's
    planes reprogram in t_write-costed chunks between A's decode steps
    (read-under-write re-purposed for multi-tenancy).  Zero A-requests
    drop, A's stream is bit-identical to a swap-free run, and the
    device-time throughput during the swap window sustains >= 2x the
    stop-the-world policy.

CLI: ``python benchmarks/multiplex_bench.py --json
BENCH_multiplex_smoke.json`` (the CI bench-lane multiplex smoke; exits
nonzero if an acceptance figure fails).

``--planebank`` runs the 3-tenant plane-bank smoke instead
(``BENCH_planebank.json``): three checkpoints resident in one executor's
3-plane banks (``DeviceConfig(stack_planes=3)``), streams bit-identical
to three dedicated schedulers at 1.0x physical devices (vs 3.0x
dedicated), a tenant-C in-place swap under A+B traffic dropping zero
requests, and 2:1:1 QoS weights shifting served-token shares within
+-10 %.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core.device import DeviceConfig  # noqa: E402
from repro.core.engine import EngineConfig  # noqa: E402
from repro.core.quant import QuantConfig  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.serve.engine import BatchScheduler, Request  # noqa: E402
from repro.serve.hotswap import finetune_delta  # noqa: E402

# the paper's operating point (10-bit reads vs 250 ns writes), matching
# hotswap_bench.py so the two smokes are comparable
_XBAR = EngineConfig(tile_rows=64, tile_cols=128, mode="deepnet",
                     quant=QuantConfig(w_bits=4, in_bits=10, adc_bits=10))

_N_SLOTS, _MAX_LEN = 2, 64


def _crossbar_cfg():
    return dataclasses.replace(get_config("qwen3_4b", smoke=True),
                               backend="crossbar", xbar=_XBAR)


def _prompt(rid, vocab):
    return jax.random.randint(jax.random.PRNGKey(rid), (6,), 0,
                              vocab - 1).astype(jnp.int32)


def _submit(sched, model_id, rids, vocab, max_new):
    for rid in rids:
        sched.submit(Request(rid=rid, prompt=_prompt(rid, vocab),
                             max_new=max_new, model_id=model_id))


def _drain(sched, n_req, max_steps=500):
    done, steps = [], 0
    while len(done) < n_req and steps < max_steps:
        done += sched.step()
        steps += 1
    return {r.rid: r.out for r in done}


def bench_multiplex(quick: bool = False):
    n_req, max_new = (2, 5) if quick else (3, 8)
    cfg = _crossbar_cfg()
    params_a = build_model(cfg).init(jax.random.PRNGKey(0))
    params_b = finetune_delta(params_a, scale=0.04, seed=11)
    rids_a, rids_b = range(n_req), range(100, 100 + n_req)

    # -- dedicated pair: one executor (and its whole stack) per checkpoint --
    t0 = time.perf_counter()
    model_da = build_model(cfg)
    sched_da = BatchScheduler(model_da, params_a, _N_SLOTS, _MAX_LEN)
    _submit(sched_da, "A", rids_a, cfg.vocab, max_new)
    out_da = _drain(sched_da, n_req)
    model_db = build_model(cfg)
    sched_db = BatchScheduler(model_db, params_b, _N_SLOTS, _MAX_LEN)
    _submit(sched_db, "A", rids_b, cfg.vocab, max_new)
    out_db = _drain(sched_db, n_req)
    wall_dedicated = time.perf_counter() - t0
    devices_dedicated = (model_da.executor.n_devices_physical
                         + model_db.executor.n_devices_physical)

    # -- multiplexed: both checkpoints resident in ONE executor's planes ----
    t0 = time.perf_counter()
    model_m = build_model(cfg)
    sched_m = BatchScheduler(model_m, params_a, _N_SLOTS, _MAX_LEN,
                             tenants={"A": params_a, "B": params_b})
    _submit(sched_m, "A", rids_a, cfg.vocab, max_new)
    _submit(sched_m, "B", rids_b, cfg.vocab, max_new)
    out_m = _drain(sched_m, 2 * n_req)
    wall_multiplexed = time.perf_counter() - t0
    devices_mux = model_m.executor.n_devices_physical

    streams_identical = (
        all(out_m[r] == out_da[r] for r in rids_a)
        and all(out_m[r] == out_db[r] for r in rids_b))
    device_ratio = devices_dedicated / devices_mux

    # -- tenant-B hot-swap under tenant-A traffic ---------------------------
    params_b2 = finetune_delta(params_a, scale=0.07, seed=23)
    # swap-free reference for tenant A's stream
    model_r = build_model(cfg)
    sched_r = BatchScheduler(model_r, params_a, _N_SLOTS, _MAX_LEN,
                             tenants={"A": params_a, "B": params_b})
    _submit(sched_r, "A", rids_a, cfg.vocab, 3 * max_new)
    ref_a = _drain(sched_r, n_req)

    model_s = build_model(cfg)
    sched_s = BatchScheduler(model_s, params_a, _N_SLOTS, _MAX_LEN,
                             tenants={"A": params_a, "B": params_b})
    _submit(sched_s, "A", rids_a, cfg.vocab, 3 * max_new)
    for _ in range(2):
        sched_s.step()
    hs = sched_s.begin_hot_swap(params_b2, chunks_per_step=1, tenant="B")
    n_chunks = hs.plan.total_chunks
    # pace the write window across several of A's decode steps
    hs.chunks_per_step = max(1, -(-n_chunks // max(3 * max_new - 4, 1)))
    t0 = time.perf_counter()
    out_swap = _drain(sched_s, n_req)
    wall_swap = time.perf_counter() - t0
    rep = sched_s.swap_history[0]
    a_streams_unperturbed = all(out_swap[r] == ref_a[r] for r in rids_a)
    zero_dropped = (len(out_swap) == n_req
                    and all(len(out_swap[r]) == 3 * max_new
                            for r in rids_a))

    out = {
        "us_per_call": wall_multiplexed * 1e6,
        "n_requests_per_tenant": n_req,
        "max_new": max_new,
        "wall_dedicated_pair_s": wall_dedicated,
        "wall_multiplexed_s": wall_multiplexed,
        "wall_b_swap_under_a_s": wall_swap,
        "streams_bit_identical_to_dedicated": bool(streams_identical),
        "devices_physical_dedicated_pair": devices_dedicated,
        "devices_physical_multiplexed": devices_mux,
        "device_count_ratio_dedicated_over_mux": device_ratio,
        "tenants": model_m.executor.tenants,
        "b_swap_n_chunks": n_chunks,
        "b_swap_tenant": rep["tenant"],
        "b_swap_zero_dropped_a_requests": bool(zero_dropped),
        "b_swap_a_streams_unperturbed": bool(a_streams_unperturbed),
        "b_swap_decode_steps_during": rep["decode_steps_during_swap"],
    }
    # device-time acceptance metrics for the swap window (Table-I model)
    out.update({k: rep[k] for k in (
        "device_decode_step_s", "device_write_total_s",
        "tok_per_device_s_overlapped_during_swap",
        "tok_per_device_s_stop_world_during_swap",
        "throughput_ratio_overlap_vs_stop_world",
        "sustains_2x_during_swap")})
    return out


def accepted(res) -> bool:
    return (res["streams_bit_identical_to_dedicated"]
            and res["device_count_ratio_dedicated_over_mux"] == 2.0
            and res["b_swap_zero_dropped_a_requests"]
            and res["b_swap_a_streams_unperturbed"]
            and res["b_swap_decode_steps_during"] > 0
            and res["sustains_2x_during_swap"])


# -- 3-tenant plane-bank smoke -------------------------------------------------

def bench_planebank(quick: bool = False):
    """Three checkpoints resident in one executor's 3-plane banks vs
    three dedicated deployments, plus a tenant-C in-place swap under A+B
    traffic and a QoS-share measurement at 2:1:1 weights."""
    n_fid, max_fid = (1, 3) if quick else (2, 4)
    n_swp, max_swp = (1, 6) if quick else (2, 8)
    n_qos, max_qos, qos_steps = (12, 3, 5) if quick else (24, 3, 8)
    cfg = dataclasses.replace(
        _crossbar_cfg(),
        xbar=dataclasses.replace(_XBAR, device=DeviceConfig(stack_planes=3)))
    params = {"A": build_model(cfg).init(jax.random.PRNGKey(0))}
    params["B"] = finetune_delta(params["A"], scale=0.04, seed=11)
    params["C"] = finetune_delta(params["A"], scale=0.06, seed=19)
    rids_fid = {t: range(100 * i, 100 * i + n_fid)
                for i, t in enumerate("ABC")}
    rids_swp = {t: range(500 + 100 * i, 500 + 100 * i + n_swp)
                for i, t in enumerate("AB")}

    # -- dedicated trio: one executor (one full 3-plane stack) per ckpt ----
    t0 = time.perf_counter()
    ded_out, devices_dedicated = {}, 0
    for t in "ABC":
        model_d = build_model(cfg)
        sched_d = BatchScheduler(model_d, params[t], _N_SLOTS, _MAX_LEN)
        _submit(sched_d, "A", rids_fid[t], cfg.vocab, max_fid)
        n = n_fid
        if t in rids_swp:       # the swap-phase reference streams ride along
            _submit(sched_d, "A", rids_swp[t], cfg.vocab, max_swp)
            n += n_swp
        ded_out.update(_drain(sched_d, n))
        devices_dedicated += model_d.executor.n_devices_physical
    wall_dedicated = time.perf_counter() - t0

    # -- multiplexed: all three resident in ONE executor's plane banks -----
    t0 = time.perf_counter()
    model_m = build_model(cfg)
    sched_m = BatchScheduler(
        model_m, params["A"], 4, _MAX_LEN,
        tenants={"A": (params["A"], 2.0), "B": (params["B"], 1.0),
                 "C": (params["C"], 1.0)})
    for t in "ABC":
        _submit(sched_m, t, rids_fid[t], cfg.vocab, max_fid)
    out_m = _drain(sched_m, 3 * n_fid)
    wall_multiplexed = time.perf_counter() - t0
    devices_mux = model_m.executor.n_devices_physical
    streams_identical = all(out_m[r] == ded_out[r]
                            for t in "ABC" for r in rids_fid[t])
    device_ratio = devices_dedicated / devices_mux
    slot_quota = {t: q["slots"] for t, q in sched_m.qos_report().items()}

    # -- tenant-C in-place swap under A+B traffic --------------------------
    params_c2 = finetune_delta(params["A"], scale=0.09, seed=23)
    for t in "AB":
        _submit(sched_m, t, rids_swp[t], cfg.vocab, max_swp)
    for _ in range(2):
        sched_m.step()
    hs = sched_m.begin_hot_swap(params_c2, chunks_per_step=1, tenant="C")
    n_chunks = hs.plan.total_chunks
    hs.chunks_per_step = max(1, -(-n_chunks // max(2 * max_swp - 4, 1)))
    t0 = time.perf_counter()
    out_swap = _drain(sched_m, 2 * n_swp)
    while sched_m.swap_in_flight:           # pace out any tail chunks
        sched_m.step()
    wall_swap = time.perf_counter() - t0
    rep = sched_m.swap_history[0]
    ab_unperturbed = all(out_swap[r] == ded_out[r]
                         for t in "AB" for r in rids_swp[t])
    zero_dropped = (len(out_swap) == 2 * n_swp
                    and all(len(out_swap[r]) == max_swp
                            for t in "AB" for r in rids_swp[t]))

    # -- QoS: 2:1:1 weights must shift served-token shares -----------------
    base = {t: q["tokens_served"] for t, q in sched_m.qos_report().items()}
    for i, t in enumerate("ABC"):
        _submit(sched_m, t, range(900 + 100 * i, 900 + 100 * i + n_qos),
                cfg.vocab, max_qos)
    for _ in range(qos_steps):              # all lanes saturated
        sched_m.step()
    served = {t: q["tokens_served"] - base[t]
              for t, q in sched_m.qos_report().items()}
    total = sum(served.values())
    shares = {t: served[t] / total for t in served}
    qos_ok = (abs(shares["A"] - 0.5) <= 0.10
              and abs(shares["B"] - 0.25) <= 0.10
              and abs(shares["C"] - 0.25) <= 0.10)

    return {
        "us_per_call": wall_multiplexed * 1e6,
        "stack_planes": 3,
        "tenants": model_m.executor.tenants,
        "wall_dedicated_trio_s": wall_dedicated,
        "wall_multiplexed_s": wall_multiplexed,
        "wall_c_swap_under_ab_s": wall_swap,
        "streams_bit_identical_to_dedicated": bool(streams_identical),
        "devices_physical_dedicated_trio": devices_dedicated,
        "devices_physical_multiplexed": devices_mux,
        "device_count_ratio_dedicated_over_mux": device_ratio,
        "qos_weights": {"A": 2.0, "B": 1.0, "C": 1.0},
        "qos_slot_quota": slot_quota,
        "qos_served_token_shares": shares,
        "qos_shares_within_10pct": bool(qos_ok),
        "c_swap_mode": rep["swap_mode"],
        "c_swap_n_chunks": n_chunks,
        "c_swap_zero_dropped_ab_requests": bool(zero_dropped),
        "c_swap_ab_streams_unperturbed": bool(ab_unperturbed),
        "c_swap_decode_steps_during": rep["decode_steps_during_swap"],
        "throughput_ratio_overlap_vs_stop_world":
            rep["throughput_ratio_overlap_vs_stop_world"],
        "sustains_2x_during_swap": rep["sustains_2x_during_swap"],
    }


def planebank_accepted(res) -> bool:
    return (res["streams_bit_identical_to_dedicated"]
            and res["device_count_ratio_dedicated_over_mux"] == 3.0
            and res["c_swap_zero_dropped_ab_requests"]
            and res["c_swap_ab_streams_unperturbed"]
            and res["c_swap_mode"] == "in_place"
            and res["qos_shares_within_10pct"])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    ap.add_argument("--planebank", action="store_true",
                    help="run the 3-tenant plane-bank smoke instead of "
                         "the 2-tenant multiplex smoke")
    args = ap.parse_args(argv)
    name = "planebank_3tenant" if args.planebank else \
        "multiplex_plane_sharing"
    json_path = args.json or ("BENCH_planebank.json" if args.planebank
                              else "BENCH_multiplex_smoke.json")
    bench = bench_planebank if args.planebank else bench_multiplex
    res = bench(quick=True)
    print("name,us_per_call,derived")
    derived = {k: v for k, v in res.items() if k != "us_per_call"}
    print(f"{name},{res['us_per_call']:.1f},"
          f"{json.dumps(derived, default=float)}")
    from benchmarks.meta import append_trajectory, write_stamped
    results = {name: res}
    meta = write_stamped(results, json_path,
                         lane="planebank-smoke" if args.planebank
                         else "multiplex-smoke")
    append_trajectory(meta, results)
    print(f"# wrote {json_path} (sha={meta['git_sha'][:12]})")
    if args.planebank:
        ok = planebank_accepted(res)
        sh = res["qos_served_token_shares"]
        print(f"# acceptance: 3 streams bit-identical "
              f"{res['streams_bit_identical_to_dedicated']}, device ratio "
              f"{res['device_count_ratio_dedicated_over_mux']:.1f}x "
              f"dedicated vs 1.0x banked, C-swap "
              f"[{res['c_swap_mode']}] under A+B dropped zero "
              f"({res['c_swap_zero_dropped_ab_requests']}) with A/B "
              f"unperturbed ({res['c_swap_ab_streams_unperturbed']}), "
              f"QoS 2:1:1 shares A={sh['A']:.2f} B={sh['B']:.2f} "
              f"C={sh['C']:.2f} within 10% "
              f"({res['qos_shares_within_10pct']})")
        return 0 if ok else 1
    ok = accepted(res)
    print(f"# acceptance: streams bit-identical "
          f"{res['streams_bit_identical_to_dedicated']}, device ratio "
          f"{res['device_count_ratio_dedicated_over_mux']:.1f}x dedicated "
          f"vs 1.0x multiplexed, B-swap under A traffic dropped zero "
          f"({res['b_swap_zero_dropped_a_requests']}) with A unperturbed "
          f"({res['b_swap_a_streams_unperturbed']}), throughput-during-"
          f"swap {res['throughput_ratio_overlap_vs_stop_world']:.2f}x "
          f"stop-the-world (>=2x: {res['sustains_2x_during_swap']})")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
