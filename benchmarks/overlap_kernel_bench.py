"""Overlap-window decode: Pallas kernel path vs the reference scan.

The deep-net mode's whole point is that reads keep streaming while the
twin plane programs (paper §III-B, Fig. 3c) — so the decode matmuls
issued DURING a hot-swap are the serving system's hot path.  Before this
bench's PR, ``engine.matmul`` abandoned the Pallas kernel whenever the
write-plane leakage was nonzero, i.e. precisely inside the overlap
window; now the leakage is a traced kernel operand and ``use_kernel``
traffic stays on the kernel path throughout.

The measured loop: program the smoke transformer onto crossbar tiles,
serve until steady state, open a chunked hot-swap (the window stays open
while chunks program between steps), and time decode inside the window
under both engine configs:

  * **kernel**    — ``use_kernel=True``: Pallas crossbar MAC with the
    leak fused pre-ADC (interpret mode on CPU; the real win is on TPU).
  * **reference** — the ``lax.scan`` over (pulse, slice) pairs that
    overlap reads used to fall back to.

Acceptance (exit code, enforced by the CI "Overlap-kernel smoke" step):

  1. the kernel-path overlap decode step is faster than the reference
     scan's, and
  2. the kernel policy's serving closures never dispatched the reference
     path (``engine.path_calls`` snapshot) — no silent fallback in the
     overlap window.

CLI: ``python benchmarks/overlap_kernel_bench.py --json
BENCH_overlap_kernel.json``
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core import engine as eng  # noqa: E402
from repro.core.engine import EngineConfig  # noqa: E402
from repro.core.quant import QuantConfig  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.serve.engine import BatchScheduler, Request  # noqa: E402
from repro.serve.hotswap import finetune_delta  # noqa: E402

# the paper's operating point (10-bit reads), leakage modeled: overlap
# decode carries the write plane's common-mode term through the ADC
_XBAR = EngineConfig(tile_rows=64, tile_cols=128, mode="deepnet",
                     quant=QuantConfig(w_bits=4, in_bits=10, adc_bits=10),
                     swap_leakage=True)


def _scheduler(use_kernel: bool, n_slots: int, max_len: int):
    xbar = dataclasses.replace(_XBAR, use_kernel=use_kernel)
    cfg = dataclasses.replace(get_config("qwen3_4b", smoke=True),
                              backend="crossbar", xbar=xbar)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = BatchScheduler(model, params, n_slots=n_slots, max_len=max_len)
    for rid in range(n_slots):
        p = jax.random.randint(jax.random.PRNGKey(rid), (6,), 0,
                               model.cfg.vocab - 1).astype(jnp.int32)
        sched.submit(Request(rid=rid, prompt=p, max_new=max_len))
    return model, params, sched


def _time_overlap_decode(use_kernel: bool, warmup_steps: int,
                         timed_calls: int):
    """Decode-step wall time measured INSIDE an open swap window."""
    model, params, sched = _scheduler(use_kernel, n_slots=2, max_len=64)
    calls_before = dict(eng.path_calls)
    for _ in range(warmup_steps):
        sched.step()

    # open the window; 1 chunk/step keeps it open while we measure
    sched.begin_hot_swap(finetune_delta(params), chunks_per_step=1)
    sched.step()                       # first in-window step (swap active)
    assert sched.swap_in_flight, "swap closed before the overlap window"
    ex = model.executor
    leak = ex.current_leak_codes()
    assert float(leak) > 0.0, "overlap window must carry nonzero leakage"

    # the raw decode closure, mid-window: this is the hot path the
    # tentpole moves onto the kernel (swap bookkeeping excluded so the
    # number isolates kernel-vs-reference arithmetic)
    lane = sched._lanes["A"]
    cache = lane.cache
    toks = jnp.zeros((lane.width, sched.chunk), jnp.int32)
    toks = toks.at[:, 0].set(jnp.asarray(
        [r.out[-1] if r is not None and r.out else 0
         for r in lane.slots], jnp.int32))
    m = jnp.asarray([1 if r is not None else 0 for r in lane.slots],
                    jnp.int32)
    tok, cache = lane.decode(lane.params, toks, cache, m, leak)
    jax.block_until_ready(tok)
    t0 = time.perf_counter()
    for _ in range(timed_calls):
        tok, cache = lane.decode(lane.params, toks, cache, m, leak)
    jax.block_until_ready(tok)
    per_decode = (time.perf_counter() - t0) / timed_calls
    lane.cache = cache

    # end-to-end step() time through the rest of the window (decode +
    # chunk programming + write-verify), then drain
    in_window = 0
    t0 = time.perf_counter()
    while sched.swap_in_flight:
        sched.step()
        in_window += 1
    per_step = (time.perf_counter() - t0) / max(in_window, 1)
    calls = {k: eng.path_calls[k] - calls_before[k]
             for k in eng.path_calls}
    return {
        "per_overlap_decode_s": per_decode,
        "per_window_step_s": per_step,
        "window_steps_measured": in_window,
        "matmul_dispatches": calls,
        "leak_codes": float(leak),
        "swap_history_policies": [r["policy"] for r in sched.swap_history],
    }


def bench_overlap_kernel(quick: bool = False):
    """Returns the kernel-vs-reference overlap figures + acceptance flags."""
    warmup = 2 if quick else 4
    timed = 6 if quick else 16
    kern = _time_overlap_decode(use_kernel=True, warmup_steps=warmup,
                                timed_calls=timed)
    ref = _time_overlap_decode(use_kernel=False, warmup_steps=warmup,
                               timed_calls=timed)

    speedup = (ref["per_overlap_decode_s"]
               / max(kern["per_overlap_decode_s"], 1e-12))
    # the kernel policy's closures must have dispatched ONLY the kernel:
    # any "reference" dispatch means an overlap (or steady) decode fell
    # back to the scan — the regression this bench exists to catch
    no_fallback = (kern["matmul_dispatches"]["reference"] == 0
                   and kern["matmul_dispatches"]["kernel"] > 0)
    return {
        "us_per_call": kern["per_overlap_decode_s"] * 1e6,
        "overlap_decode_kernel_s": kern["per_overlap_decode_s"],
        "overlap_decode_reference_s": ref["per_overlap_decode_s"],
        "overlap_decode_speedup_kernel_vs_reference": speedup,
        "kernel_beats_reference": bool(speedup > 1.0),
        "window_step_kernel_s": kern["per_window_step_s"],
        "window_step_reference_s": ref["per_window_step_s"],
        "kernel_policy_dispatches": kern["matmul_dispatches"],
        "no_silent_reference_fallback": bool(no_fallback),
        "leak_codes_during_window": kern["leak_codes"],
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_overlap_kernel.json")
    args = ap.parse_args(argv)
    res = bench_overlap_kernel(quick=True)
    print("name,us_per_call,derived")
    derived = {k: v for k, v in res.items() if k != "us_per_call"}
    print(f"overlap_kernel,{res['us_per_call']:.1f},"
          f"{json.dumps(derived, default=float)}")
    from benchmarks.meta import append_trajectory, write_stamped
    results = {"overlap_kernel": res}
    meta = write_stamped(results, args.json, lane="overlap-kernel-smoke")
    append_trajectory(meta, results)
    print(f"# wrote {args.json} (sha={meta['git_sha'][:12]})")
    ok = res["kernel_beats_reference"] and res["no_silent_reference_fallback"]
    print(f"# acceptance: overlap decode kernel vs reference "
          f"{res['overlap_decode_speedup_kernel_vs_reference']:.2f}x "
          f"(>1x: {res['kernel_beats_reference']}), no reference fallback "
          f"in kernel policy: {res['no_silent_reference_fallback']} "
          f"(dispatches: {res['kernel_policy_dispatches']})")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
