"""Paged-serving smoke: the paged KV pool + ragged continuous batching
under a real mixed-length workload, gated in the exit code (the CI
"Paged-serving smoke" step).

Phase 1 — ragged correctness (digital backend, one tenant): a stream of
prompt lengths spanning 8..max_len (>= 4 of the old padded prefill
buckets), admitted continuously, served twice — once from the paged
pool, once from the dense per-slot cache.  Gates:

  * zero dropped requests on both paths,
  * **bit-exact token streams** paged vs dense (the end-to-end half of
    the acceptance; the kernel-level half is test_paged_attention.py),
  * exactly ONE compiled decode closure for the tenant and a ZERO
    retrace delta across the whole mixed-length stream
    (``serve_jit_traces_total`` / ``serve_jit_retraces_total``),
  * page conservation at every step and full reclaim at drain
    (``pages_in_use + pages_free == n_pages``).

Phase 2 — decode throughput: steady-state tokens/s, paged vs dense
(fresh schedulers, warmed closures, interleaved best-of windows), gated
at paged >= 0.7x dense — the page-table gather must not cost the slot
path its throughput (the 30 % headroom absorbs CPU-interpret noise; on
TPU the gather is a kernel prefetch).

Phase 3 — multi-tenant + swap (crossbar backend): A/B multiplexed
serving of the same mixed-length stream with a mid-stream tenant-B
hot-swap.  Gates: zero dropped requests, zero retraces across the swap
window, conservation on every lane's pool, and decode steps served
DURING the write window (admissions kept flowing).

Phase 4 — prefix sharing: four requests with a common 24-token head
and distinct tails, staggered so followers admit after the head
request's prompt pages are written.  Three arms (dense oracle, paged
private, paged ``prefix_share=True``); gates: bit-exact streams on
both paged arms, shared peak ``pages_in_use`` STRICTLY below private,
shared pages aliased > 0, conservation every step, full reclaim at
drain, zero retrace delta.

Phase 5 — QoS preemption under pool saturation: an 8-page pool holds
two low-QoS residents when two high-QoS requests arrive; preemption
evicts the residents (pages reclaim, recomputable state spills to the
host stub) and replays them through chunked prefill.  Gates: all four
requests complete with ZERO drops, streams bit-exact vs dense,
>= 1 eviction, zero retrace delta, conservation + full reclaim.

Phase 6 — long-context kernel sweep (the two-lane dispatch): the
streamed online-softmax lane vs the gather-scratch lane at the kernel
level over growing windows (16/32/64 pages of 8), full-depth decode
reads.  Hard gates are the STRUCTURAL properties: streamed VMEM
scratch bytes CONSTANT across all windows (the O(page_block) claim —
the ring + online-softmax stats; ``streamed_resident_bytes`` records
the current whole-pool lowering's residency alongside, informational),
bounded-ulp parity (fp32 maxdiff < 1e-5) with stable argmax, and ZERO
``paged_fallback`` dispatches — the no-silent-fallback counter wired
straight into the exit code.  The streamed/scratch throughput ratio at
the longest window is soft-gated at >= _LONGCTX_RATIO_GATE (0.9):
both lanes are interpret-mode wall clocks on a shared CPU runner, so a
hard 1.0 gate flaked on scheduler jitter unrelated to correctness —
the full ratio is still recorded per window in BENCH_paged.json.

CLI: ``python benchmarks/paged_bench.py --json BENCH_paged.json`` (exits
nonzero if any gate fails).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import obs  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.core.engine import EngineConfig  # noqa: E402
from repro.core.quant import QuantConfig  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.serve.engine import BatchScheduler, Request  # noqa: E402
from repro.serve.hotswap import finetune_delta  # noqa: E402

_XBAR = EngineConfig(tile_rows=64, tile_cols=128, mode="deepnet",
                     quant=QuantConfig(w_bits=4, in_bits=10, adc_bits=10))

_N_SLOTS, _MAX_LEN, _PAGE_SIZE = 3, 64, 8
# spans the old 8/16/32/64 padded buckets
_PLENS = (8, 13, 22, 35, 50, 62)
_THROUGHPUT_GATE = 0.7
# soft margin on the longctx streamed/scratch wall-clock ratio: timing
# noise between two CPU-interpret lanes must not flip CI (the
# structural gates — VMEM constancy, parity, argmax, zero fallbacks —
# stay hard)
_LONGCTX_RATIO_GATE = 0.9


def _digital_cfg():
    return get_config("qwen3_4b", smoke=True)


def _crossbar_cfg():
    return dataclasses.replace(_digital_cfg(), backend="crossbar",
                               xbar=_XBAR)


def _prompt(rid, vocab, plen):
    return jax.random.randint(jax.random.PRNGKey(rid), (plen,), 0,
                              vocab - 1).astype(jnp.int32)


def _serve_reqs(sched, reqs, submit_at):
    """Submit ``reqs[i]`` at decode step ``submit_at[i]`` and drain;
    returns ({rid: tokens}, steps, conservation_held, peak pages in
    use across all lanes).  Staggering matters for the sharing phase:
    a follower can only alias prompt pages the head request has
    already written (and still holds)."""
    done, steps, conserved, peak = {}, 0, True, 0
    while len(done) < len(reqs) and steps < 2000:
        for r, t in zip(reqs, submit_at):
            if t == steps:
                sched.submit(r)
        for r in sched.step():
            done[r.rid] = list(r.out)
        in_use = 0
        for rep in sched.kv_report().values():
            conserved = conserved and rep["conservation_ok"]
            in_use += rep["pages_in_use"]
        peak = max(peak, in_use)
        steps += 1
    return done, steps, conserved, peak


def _serve_stream(sched, vocab, plens, max_new, model_id="A", rid0=0,
                  trickle=2, on_step=None):
    """Admit ``plens`` continuously (one submit every ``trickle`` steps)
    and drain; returns ({rid: tokens}, steps, conservation_held)."""
    pending = [(rid0 + i, p) for i, p in enumerate(plens)]
    done, steps, conserved = {}, 0, True
    while (len(done) < len(plens)) and steps < 1000:
        if pending and steps % trickle == 0:
            rid, plen = pending.pop(0)
            sched.submit(Request(rid=rid, prompt=_prompt(rid, vocab, plen),
                                 max_new=max_new, model_id=model_id))
        for r in sched.step():
            done[r.rid] = list(r.out)
        for rep in sched.kv_report().values():
            conserved = conserved and rep["conservation_ok"]
        if on_step is not None:
            on_step(steps)
        steps += 1
    return done, steps, conserved


def _ragged_phase(max_new):
    """Paged vs dense over the mixed-length stream (digital backend)."""
    cfg = _digital_cfg()
    reg = obs.registry()
    out = {}
    for kv in ("paged", "dense"):
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        traces0 = reg.total("serve_jit_traces_total", closure="decode")
        retr0 = reg.total("serve_jit_retraces_total")
        sched = BatchScheduler(model, params, _N_SLOTS, _MAX_LEN, kv=kv,
                               page_size=_PAGE_SIZE)
        done, steps, conserved = _serve_stream(sched, cfg.vocab, _PLENS,
                                               max_new)
        pool_rep = sched.kv_report().get("A", {})
        out[kv] = {
            "streams": done,
            "completed": len(done),
            "steps": steps,
            "closures_traced": reg.total("serve_jit_traces_total",
                                         closure="decode") - traces0,
            "retrace_delta": reg.total("serve_jit_retraces_total") - retr0,
            "conservation_every_step": conserved,
            "pages_in_use_at_drain": pool_rep.get("pages_in_use", 0),
        }
    return out


def _throughput_phase(steps, repeats):
    """Steady-state decode tokens/s, paged vs dense, interleaved timed
    windows so machine drift hits both arms equally."""
    cfg = _digital_cfg()
    scheds = {}
    for kv in ("dense", "paged"):
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        sched = BatchScheduler(model, params, _N_SLOTS, _MAX_LEN, kv=kv,
                               page_size=_PAGE_SIZE)
        budget = (repeats + 2) * steps + 8
        for rid in range(_N_SLOTS):
            sched.submit(Request(rid=rid,
                                 prompt=_prompt(rid, cfg.vocab, 6),
                                 max_new=budget))
        for _ in range(4):      # admission chunks + decode warm
            sched.step()
        scheds[kv] = sched
    best = {"dense": 0.0, "paged": 0.0}
    for _ in range(repeats):
        for kv, sched in scheds.items():
            lane = sched._lanes["A"]
            tok0 = lane.tokens_served
            t0 = time.perf_counter()
            for _ in range(steps):
                sched.step()
            jax.block_until_ready(lane.cache["layers"]["len"])
            dt = time.perf_counter() - t0
            best[kv] = max(best[kv], (lane.tokens_served - tok0) / dt)
    return best["dense"], best["paged"]


def _swap_phase(max_new):
    """A/B multiplexed mixed-length stream with a mid-stream tenant-B
    hot-swap over the paged pool."""
    cfg = _crossbar_cfg()
    reg = obs.registry()
    model = build_model(cfg)
    params_a = model.init(jax.random.PRNGKey(0))
    params_b = finetune_delta(params_a, scale=0.04, seed=11)
    params_b2 = finetune_delta(params_a, scale=0.07, seed=23)
    sched = BatchScheduler(model, params_a, _N_SLOTS, _MAX_LEN,
                           tenants={"A": params_a, "B": params_b},
                           page_size=_PAGE_SIZE)
    n = len(_PLENS)
    for i, plen in enumerate(_PLENS):
        sched.submit(Request(rid=i, prompt=_prompt(i, cfg.vocab, plen),
                             max_new=max_new, model_id="A"))
        sched.submit(Request(rid=100 + i,
                             prompt=_prompt(100 + i, cfg.vocab, plen),
                             max_new=max_new, model_id="B"))
    for _ in range(3):
        sched.step()
    retr0 = reg.total("serve_jit_retraces_total")
    sched.begin_hot_swap(params_b2, chunks_per_step=4, tenant="B")
    done, steps, conserved = {}, 0, True
    while (len(done) < 2 * n or sched.swap_in_flight) and steps < 1000:
        for r in sched.step():
            done[r.rid] = list(r.out)
        for rep in sched.kv_report().values():
            conserved = conserved and rep["conservation_ok"]
        steps += 1
    swap_rep = sched.swap_history[0] if sched.swap_history else {}
    pools = sched.kv_report()
    return {
        "completed": len(done),
        "expected": 2 * n,
        "steps": steps,
        "retraces_across_swap_window":
            reg.total("serve_jit_retraces_total") - retr0,
        "swap_lifecycle": swap_rep.get("swap_mode"),
        "swap_decode_steps_during":
            swap_rep.get("decode_steps_during_swap", 0),
        "conservation_every_step": conserved,
        "pages_in_use_at_drain": sum(p["pages_in_use"]
                                     for p in pools.values()),
        "pools": pools,
    }


def _prefix_phase():
    """Shared-prefix workload, three arms: dense (the bit-exactness
    oracle), paged-private, and paged with --prefix-share.  Four
    requests carry the same 24-token head (a shared system prompt) and
    distinct 4-token tails, staggered so the head request's prompt
    pages are fully written before any follower admits.  The shared
    arm must serve the identical streams from strictly fewer peak
    pages."""
    cfg = _digital_cfg()
    head = _prompt(7000, cfg.vocab, 24)
    prompts = [jnp.concatenate([head, _prompt(7100 + i, cfg.vocab, 4)])
               for i in range(4)]
    # head admits at 0 and registers after ceil(28/chunk=4)=7 prefill
    # steps; followers trail it and each other
    submit_at = [0, 8, 10, 12]
    max_new = 4
    reg = obs.registry()
    arms = {}
    for arm, kv, share in (("dense", "dense", False),
                           ("private", "paged", False),
                           ("shared", "paged", True)):
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        retr0 = reg.total("serve_jit_retraces_total")
        sched = BatchScheduler(model, params, 4, 32, kv=kv,
                               page_size=_PAGE_SIZE, prefix_share=share)
        reqs = [Request(rid=i, prompt=p, max_new=max_new)
                for i, p in enumerate(prompts)]
        done, steps, conserved, peak = _serve_reqs(sched, reqs, submit_at)
        arms[arm] = {
            "streams": done,
            "completed": len(done),
            "steps": steps,
            "peak_pages_in_use": peak,
            "conservation_every_step": conserved,
            "retrace_delta": reg.total("serve_jit_retraces_total") - retr0,
            "pages_in_use_at_drain": sum(
                rep["pages_in_use"]
                for rep in sched.kv_report().values()),
        }
        if share:
            arms[arm]["pages_shared_total"] = int(
                sched.metrics.total("serve_kv_pages_shared_total"))
            arms[arm]["shared_tokens_total"] = int(
                sched.metrics.total("serve_kv_shared_tokens_total"))
            arms[arm]["cow_total"] = int(
                sched.metrics.total("serve_kv_cow_total"))
    bit_exact = (arms["shared"]["streams"] == arms["dense"]["streams"]
                 and arms["private"]["streams"] == arms["dense"]["streams"])
    for a in arms.values():
        del a["streams"]
    return {
        "n_requests": len(prompts),
        "common_head_tokens": 24,
        "bit_exact_vs_dense": bool(bit_exact),
        "peak_pages_private": arms["private"]["peak_pages_in_use"],
        "peak_pages_shared": arms["shared"]["peak_pages_in_use"],
        "arms": arms,
    }


def _preempt_phase():
    """Pool-saturation preemption: two low-QoS requests fill a tight
    8-page pool; two high-QoS requests arrive behind them.  With
    --preemption the scheduler evicts the low-QoS residents (pages
    reclaim, state spills to the host stub) and replays them through
    chunked prefill after the high-QoS pair drains — every stream
    bit-exact vs the dense oracle, zero drops, zero retraces."""
    cfg = _digital_cfg()
    prompts = [_prompt(8000 + i, cfg.vocab, 20) for i in range(4)]
    qos = (1.0, 1.0, 4.0, 4.0)
    submit_at = [0, 0, 8, 8]
    max_new = 5
    reg = obs.registry()
    arms = {}
    for arm in ("dense", "preempt"):
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        retr0 = reg.total("serve_jit_retraces_total")
        if arm == "dense":
            sched = BatchScheduler(model, params, 4, 32, kv="dense")
        else:
            sched = BatchScheduler(model, params, 3, 32, kv="paged",
                                   page_size=_PAGE_SIZE, kv_pages=8,
                                   preemption=True)
        reqs = [Request(rid=i, prompt=p, max_new=max_new, qos=q)
                for i, (p, q) in enumerate(zip(prompts, qos))]
        done, steps, conserved, peak = _serve_reqs(sched, reqs, submit_at)
        arms[arm] = {
            "streams": done,
            "completed": len(done),
            "steps": steps,
            "conservation_every_step": conserved,
            "retrace_delta": reg.total("serve_jit_retraces_total") - retr0,
            "pages_in_use_at_drain": sum(
                rep["pages_in_use"]
                for rep in sched.kv_report().values()),
        }
        if arm == "preempt":
            arms[arm]["preemptions_total"] = int(
                sched.metrics.total("serve_preemptions_total"))
            arms[arm]["readmissions"] = sum(r.preemptions for r in reqs)
    bit_exact = arms["preempt"]["streams"] == arms["dense"]["streams"]
    for a in arms.values():
        del a["streams"]
    return {
        "n_requests": len(prompts),
        "kv_pages": 8,
        "bit_exact_vs_dense": bool(bit_exact),
        "preemptions": arms["preempt"]["preemptions_total"],
        "arms": arms,
    }


def _longctx_phase(windows=(16, 32, 64), repeats=3):
    """Kernel-level two-lane sweep over growing page-table widths: every
    row reads its full window (the decode worst case), both lanes timed
    back-to-back on identical operands."""
    import numpy as np

    from repro.kernels.paged_attention import (
        paged_attention as paged_op, paged_path_calls,
        scratch_lane_vmem_bytes, streamed_lane_resident_bytes,
        streamed_lane_vmem_bytes)

    b, sq, hq, kv, hd, ps, bp = 4, 1, 8, 2, 64, _PAGE_SIZE, 16
    base = dict(paged_path_calls)
    rows, argmax_stable = [], True
    for p_seq in windows:
        key = jax.random.PRNGKey(p_seq)
        kq, kk, kvk = jax.random.split(key, 3)
        q = jax.random.normal(kq, (b, sq, hq, hd), jnp.float32)
        n_pages = b * p_seq + 1
        kp = jax.random.normal(kk, (n_pages, ps, kv, hd), jnp.float32)
        vp = jax.random.normal(kvk, (n_pages, ps, kv, hd), jnp.float32)
        pt = (jnp.arange(1, b * p_seq + 1, dtype=jnp.int32)
              .reshape(b, p_seq))
        kv_len = jnp.full((b,), p_seq * ps, jnp.int32)
        q_off = kv_len - sq

        def run(lane):
            return paged_op(q, kp, vp, pt, kv_len, q_off, lane=lane,
                            block_pages=bp)

        timed = {}
        outs = {}
        for lane in ("streamed", "scratch"):
            outs[lane] = jax.block_until_ready(run(lane))   # trace + warm
            best = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                jax.block_until_ready(run(lane))
                best = min(best, time.perf_counter() - t0)
            timed[lane] = best * 1e6
        d_str = np.asarray(outs["streamed"], np.float32)
        d_scr = np.asarray(outs["scratch"], np.float32)
        argmax_stable = argmax_stable and bool(
            (d_str.reshape(b, -1).argmax(-1)
             == d_scr.reshape(b, -1).argmax(-1)).all())
        rows.append({
            "pages": p_seq,
            "tokens": p_seq * ps,
            "streamed_us_per_call": timed["streamed"],
            "scratch_us_per_call": timed["scratch"],
            "streamed_over_scratch":
                timed["scratch"] / max(timed["streamed"], 1e-9),
            "parity_maxdiff": float(np.abs(d_str - d_scr).max()),
            "scratch_lane_vmem_bytes":
                scratch_lane_vmem_bytes(p_seq, ps, kv, hd, jnp.float32),
            "streamed_lane_vmem_bytes":
                streamed_lane_vmem_bytes(b, sq, hq, kv, hd, p_seq, ps, bp,
                                         jnp.float32),
            # honest residency of the CURRENT whole-pool lowering (grows
            # with the pool until the TPU port's per-block DMA lands);
            # informational, not gated — the constancy gate is about the
            # scratch working set above
            "streamed_resident_bytes":
                streamed_lane_resident_bytes(b, sq, hq, kv, hd, p_seq, ps,
                                             bp, n_pages, jnp.float32),
        })
    calls = {k: paged_path_calls[k] - base[k] for k in base}
    return {
        "batch": b, "page_size": ps, "block_pages": bp,
        "kv_heads": kv, "head_dim": hd,
        "windows": rows,
        "streamed_vmem_constant":
            len({r["streamed_lane_vmem_bytes"] for r in rows}) == 1,
        "scratch_vmem_growth":
            rows[-1]["scratch_lane_vmem_bytes"]
            / rows[0]["scratch_lane_vmem_bytes"],
        "ratio_at_longest": rows[-1]["streamed_over_scratch"],
        "parity_maxdiff": max(r["parity_maxdiff"] for r in rows),
        "argmax_stable": argmax_stable,
        "dispatch_calls": calls,
        "fallback_delta": calls["paged_fallback"],
    }


def bench_paged(quick: bool = False):
    max_new = 5 if quick else 10
    steps, repeats = (25, 3) if quick else (50, 5)

    ragged = _ragged_phase(max_new)
    bit_exact = ragged["paged"]["streams"] == ragged["dense"]["streams"]
    thr_dense, thr_paged = _throughput_phase(steps, repeats)
    swap = _swap_phase(max_new)
    prefix = _prefix_phase()
    preempt = _preempt_phase()
    longctx = _longctx_phase(windows=(16, 32, 64) if quick
                             else (16, 32, 64, 128),
                             repeats=5 if quick else 9)

    return {
        "us_per_call": 0.0,
        "n_requests": len(_PLENS),
        "prompt_lens": list(_PLENS),
        "former_buckets_spanned": 4,
        "paged_completed": ragged["paged"]["completed"],
        "dense_completed": ragged["dense"]["completed"],
        "paged_vs_dense_bit_exact": bool(bit_exact),
        "paged_closures_traced": ragged["paged"]["closures_traced"],
        "paged_retrace_delta": ragged["paged"]["retrace_delta"],
        "dense_closures_traced": ragged["dense"]["closures_traced"],
        "dense_retrace_delta": ragged["dense"]["retrace_delta"],
        "page_conservation_every_step":
            bool(ragged["paged"]["conservation_every_step"]),
        "pages_in_use_at_drain": ragged["paged"]["pages_in_use_at_drain"],
        "decode_tok_per_s_dense": thr_dense,
        "decode_tok_per_s_paged": thr_paged,
        "paged_over_dense_throughput": thr_paged / max(thr_dense, 1e-12),
        "throughput_gate": _THROUGHPUT_GATE,
        "swap": swap,
        "prefix_share": prefix,
        "preemption": preempt,
        "longctx": longctx,
    }


def accepted(res) -> bool:
    swap = res["swap"]
    pfx = res["prefix_share"]
    pre = res["preemption"]
    lc = res["longctx"]
    return (res["paged_completed"] == res["n_requests"]
            and res["dense_completed"] == res["n_requests"]
            and res["paged_vs_dense_bit_exact"]
            and res["paged_closures_traced"] == 1
            and res["paged_retrace_delta"] == 0
            and res["page_conservation_every_step"]
            and res["pages_in_use_at_drain"] == 0
            and res["paged_over_dense_throughput"]
            >= res["throughput_gate"]
            and swap["completed"] == swap["expected"]
            and swap["retraces_across_swap_window"] == 0
            and swap["swap_decode_steps_during"] > 0
            and swap["conservation_every_step"]
            and swap["pages_in_use_at_drain"] == 0
            # sharing: identical streams from strictly fewer peak pages
            and all(a["completed"] == pfx["n_requests"]
                    for a in pfx["arms"].values())
            and pfx["bit_exact_vs_dense"]
            and pfx["peak_pages_shared"] < pfx["peak_pages_private"]
            and pfx["arms"]["shared"]["pages_shared_total"] > 0
            and all(a["conservation_every_step"]
                    and a["retrace_delta"] == 0
                    and a["pages_in_use_at_drain"] == 0
                    for a in pfx["arms"].values())
            # preemption: saturation resolves with zero drops
            and all(a["completed"] == pre["n_requests"]
                    for a in pre["arms"].values())
            and pre["bit_exact_vs_dense"]
            and pre["preemptions"] >= 1
            and all(a["conservation_every_step"]
                    and a["retrace_delta"] == 0
                    and a["pages_in_use_at_drain"] == 0
                    for a in pre["arms"].values())
            # long-context two-lane sweep: structural gates hard
            # (constant VMEM scratch, bounded-ulp parity, stable argmax,
            # zero silent fallbacks); the wall-clock ratio gets a noise
            # margin — two interpret-mode lanes on a shared CPU runner
            # jitter for reasons unrelated to correctness
            and lc["ratio_at_longest"] >= _LONGCTX_RATIO_GATE
            and lc["streamed_vmem_constant"]
            and lc["parity_maxdiff"] < 1e-5
            and lc["argmax_stable"]
            and lc["fallback_delta"] == 0)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_paged.json")
    args = ap.parse_args(argv)
    res = bench_paged(quick=True)
    print("name,us_per_call,derived")
    derived = {k: v for k, v in res.items() if k != "us_per_call"}
    print(f"paged_serving,{res['us_per_call']:.1f},"
          f"{json.dumps(derived, default=float)}")
    from benchmarks.meta import append_trajectory, write_stamped
    results = {"paged_serving": res}
    meta = write_stamped(results, args.json, lane="paged-smoke")
    append_trajectory(meta, results)
    print(f"# wrote {args.json} (sha={meta['git_sha'][:12]})")
    ok = accepted(res)
    swap = res["swap"]
    print(f"# acceptance: paged==dense bit-exact "
          f"({res['paged_vs_dense_bit_exact']}), closures traced "
          f"{res['paged_closures_traced']} (want 1), retrace delta "
          f"{res['paged_retrace_delta']} (want 0), conservation every "
          f"step ({res['page_conservation_every_step']}), pages leaked "
          f"at drain {res['pages_in_use_at_drain']} (want 0), "
          f"throughput paged/dense "
          f"{res['paged_over_dense_throughput']:.2f}x (gate >= "
          f"{res['throughput_gate']}), swap: "
          f"{swap['completed']}/{swap['expected']} done with "
          f"{swap['retraces_across_swap_window']} retraces and "
          f"{swap['swap_decode_steps_during']} decode steps in-window")
    pfx, pre = res["prefix_share"], res["preemption"]
    print(f"# prefix-share: bit-exact ({pfx['bit_exact_vs_dense']}), "
          f"peak pages {pfx['peak_pages_shared']} shared vs "
          f"{pfx['peak_pages_private']} private (want <), "
          f"{pfx['arms']['shared']['pages_shared_total']} pages aliased, "
          f"{pfx['arms']['shared']['shared_tokens_total']} prompt tokens "
          f"skipped, {pfx['arms']['shared']['cow_total']} COW copies; "
          f"preemption: bit-exact ({pre['bit_exact_vs_dense']}), "
          f"{pre['preemptions']} evictions, "
          f"{pre['arms']['preempt']['completed']}/{pre['n_requests']} "
          f"completed with 0 drops")
    lc = res["longctx"]
    print(f"# long-context: streamed/scratch "
          f"{lc['ratio_at_longest']:.2f}x at "
          f"{lc['windows'][-1]['tokens']} tokens (soft gate >= "
          f"{_LONGCTX_RATIO_GATE}), streamed VMEM scratch constant "
          f"({lc['streamed_vmem_constant']}: "
          f"{lc['windows'][0]['streamed_lane_vmem_bytes']} B; resident "
          f"{lc['windows'][-1]['streamed_resident_bytes']} B under the "
          f"whole-pool lowering) vs scratch "
          f"x{lc['scratch_vmem_growth']:.0f} growth, parity maxdiff "
          f"{lc['parity_maxdiff']:.2e} (gate < 1e-5), argmax stable "
          f"({lc['argmax_stable']}), fallbacks {lc['fallback_delta']} "
          f"(want 0)")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
