"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms (TPU v5e): per (arch x shape) on the single-pod 16x16 mesh,
  compute_s    = HLO_FLOPs_global / (chips * 197e12)
  memory_s     = HLO_bytes_global / (chips * 819e9)
  collective_s = collective_bytes_global / (chips * 50e9)
cost_analysis numbers are per-device in a partitioned module, so the
per-device form (flops/dev / peak) is used directly — identical value.

MODEL_FLOPS: 6*N*D for training (N = params, D = tokens), 2*N_active*D +
exact attention reads for inference; the ratio MODEL_FLOPS / HLO_FLOPs
exposes remat recompute, dropped-MoE overcompute and padding waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

import jax
import jax.numpy as jnp

CHIPS = 256                    # single-pod roofline
PEAK_FLOPS = 197e12            # bf16 / chip
HBM_BW = 819e9                 # bytes/s / chip
LINK_BW = 50e9                 # bytes/s / link

_PARAM_CACHE: Dict[str, Dict[str, float]] = {}


def param_counts(arch: str) -> Dict[str, float]:
    """{'total': N, 'active': N_active} via eval_shape (no allocation)."""
    if arch in _PARAM_CACHE:
        return _PARAM_CACHE[arch]
    from repro.configs import get_config
    from repro.models.model import build_model
    cfg = get_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init,
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    leaves = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = expert = 0
    for path, leaf in leaves:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        keys = "/".join(str(getattr(k, "key", k)) for k in path)
        if "moe/w" in keys:
            expert += n
    active = total - expert
    if cfg.moe_experts:
        active += expert * cfg.moe_top_k / cfg.moe_experts
    out = {"total": float(total), "active": float(active)}
    _PARAM_CACHE[arch] = out
    return out


def model_flops(arch: str, shape: str, kind: str, seq: int, batch: int
                ) -> float:
    """Analytic useful FLOPs per step (global)."""
    from repro.configs import get_config
    cfg = get_config(arch)
    n = param_counts(arch)
    if kind == "train":
        base = 6.0 * n["active"] * batch * seq
    elif kind == "prefill":
        base = 2.0 * n["active"] * batch * seq
    else:  # decode: one token per sequence
        base = 2.0 * n["active"] * batch * 1
    # attention reads (forward; x3 for train fwd+bwd)
    attn = 0.0
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        hq, hd, nl = cfg.n_heads, cfg.head_dim, cfg.n_layers
        if kind == "train":
            attn = 3 * 2.0 * nl * batch * seq * seq * hq * hd
            if cfg.family == "encdec":
                attn *= 2.5  # enc self + dec self + cross, roughly
        elif kind == "prefill":
            attn = 2.0 * nl * batch * seq * seq * hq * hd
        else:
            attn = 4.0 * nl * batch * seq * hq * hd
    elif cfg.family == "zamba2":
        n_super = cfg.n_layers // cfg.attn_every
        hq, hd = cfg.n_heads, cfg.head_dim
        if kind in ("train", "prefill"):
            mult = 3 if kind == "train" else 1
            attn = mult * 2.0 * n_super * batch * seq * seq * hq * hd
        else:
            attn = 4.0 * n_super * batch * seq * hq * hd
        # SSD state math: ~4 flops per (token, head, dk, dv)
        di = 2 * cfg.d_model
        h = di // cfg.ssm_head_dim
        toks = batch * (seq if kind != "decode" else 1)
        attn += (3 if kind == "train" else 1) * 4.0 * cfg.n_layers * toks \
            * h * cfg.ssm_state * cfg.ssm_head_dim
    elif cfg.family == "rwkv6":
        h = cfg.d_model // cfg.ssm_head_dim
        toks = batch * (seq if kind != "decode" else 1)
        attn = (3 if kind == "train" else 1) * 4.0 * cfg.n_layers * toks \
            * h * cfg.ssm_head_dim * cfg.ssm_head_dim
    return base + attn


def analytic_memory_bytes(arch: str, kind: str, seq: int, batch: int,
                          mesh: str = "16x16") -> float:
    """Coarse per-device HBM traffic estimate (bytes/step) — the
    interpretation aid next to the spec's HLO bytes-accessed term, which
    is a no-fusion upper bound further inflated by in-place cache updates
    (each layer's DUS counts the whole stacked buffer as operand).

    decode:  TP weight shard read + KV cache read/write
    prefill: weight shard + cache write + ~12 activation r/w per layer
    train:   3 weight passes x microbatches + optimizer r/w + activations
    """
    from repro.configs import get_config
    from repro.launch.policy import microbatches_for
    cfg = get_config(arch)
    n = param_counts(arch)
    chips = 512 if mesh == "2x16x16" else 256
    dp = chips // 16
    tp_shard = 2.0 * n["total"] / 16          # bf16 weights per TP rank
    act_unit = 2.0 * batch * seq * cfg.d_model / dp  # one (B,S,d) bf16/dev
    nl = cfg.n_layers
    if kind == "decode":
        kv = 2 * 2.0 * batch * seq * 16 * 128 * nl / chips  # rough cache
        return tp_shard + kv + 12 * nl * 2.0 * batch * cfg.d_model / dp
    if kind == "prefill":
        kv = 2 * 2.0 * batch * seq * 16 * 128 * nl / chips
        return tp_shard + kv + 12 * nl * act_unit
    mb = microbatches_for(arch, "train", batch, mesh == "2x16x16")
    opt = 24.0 * n["total"] / chips
    return 3 * mb * tp_shard + opt + 16 * nl * act_unit


def load_cells(dry_dir: str, mesh: str = "16x16") -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(dry_dir,
                                              f"*__{mesh}.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def roofline_row(cell: Dict) -> Dict:
    ct = cell.get("cost_true", None)
    if ct is not None:
        flops_dev = ct["flops"]
        bytes_dev = ct["bytes_accessed"]
        coll_dev = ct["collective_bytes"]
    else:  # fall back to the raw (loop-undercounted) numbers
        flops_dev = cell["cost"]["flops"]
        bytes_dev = cell["cost"]["bytes_accessed"]
        coll_dev = cell["collective_bytes_total"]
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / LINK_BW
    mem_est_s = analytic_memory_bytes(
        cell["arch"], cell["kind"], cell["seq_len"], cell["global_batch"],
        cell["mesh"]) / HBM_BW
    # dominant term: spec formulas, but with the analytic memory estimate
    # replacing the in-place-update-inflated HLO upper bound when the two
    # disagree by >3x (documented in EXPERIMENTS.md §Roofline)
    mem_for_rank = mem_est_s if memory_s > 3 * mem_est_s else memory_s
    terms = {"compute": compute_s, "memory": mem_for_rank,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cell["arch"], cell["shape"], cell["kind"],
                     cell["seq_len"], cell["global_batch"])
    chips = 512 if cell["mesh"] == "2x16x16" else CHIPS
    hlo_global = flops_dev * chips
    ratio = mf / hlo_global if hlo_global else 0.0
    # roofline fraction: useful work over what the dominant resource allows
    step_s = max(terms.values())
    ideal_s = mf / (chips * PEAK_FLOPS)
    frac = ideal_s / step_s if step_s else 0.0
    return {
        "arch": cell["arch"], "shape": cell["shape"], "kind": cell["kind"],
        "compute_s": round(compute_s, 6), "memory_s": round(memory_s, 6),
        "memory_est_s": round(mem_est_s, 6),
        "collective_s": round(collective_s, 6), "bottleneck": bottleneck,
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "model_flops_ratio": round(ratio, 3),
        "roofline_frac": round(frac, 4),
        "peak_gib": round(cell["memory"]["peak_bytes_per_device"] / 2**30,
                          2),
        "fits_16g": cell["memory"]["peak_bytes_per_device"] < 16 * 2**30,
    }


_ADVICE = {
    ("compute", "train"): "cut remat recompute (selective policy) and pad "
    "waste; MFU rises directly with the MODEL_FLOPS ratio",
    ("compute", "prefill"): "fuse attention (Pallas flash) to remove "
    "softmax materialization flops",
    ("compute", "decode"): "decode is tiny per step; batch more sequences "
    "or quantize weights to shrink the other terms",
    ("memory", "train"): "reduce activation traffic: fuse elementwise "
    "chains, bf16 saves, larger microbatches",
    ("memory", "prefill"): "stream KV writes and fuse QKV projections; "
    "bytes/flop falls as S grows",
    ("memory", "decode"): "weight + KV streaming dominates: quantize KV "
    "cache (int8/fp8) and weights; deep-net-style prefetch overlap hides "
    "the rest",
    ("collective", "train"): "overlap grad reduce-scatter with backward "
    "(latency hiding), int8-compress DP gradients, or deepen K per shard "
    "(expansion-mode analogue)",
    ("collective", "prefill"): "re-shard to cut resharding all-to-alls; "
    "keep activations TP-local (SP)",
    ("collective", "decode"): "shrink TP degree for decode or duplicate "
    "hot weights; all-gathers dominate small steps",
}


def advice(row: Dict) -> str:
    return _ADVICE.get((row["bottleneck"], row["kind"]), "")


def summary_rows(dry_dir: str) -> List[Dict]:
    return [roofline_row(c) for c in load_cells(dry_dir)]


def markdown_table(dry_dir: str) -> str:
    rows = summary_rows(dry_dir)
    lines = [
        "| arch | shape | compute_s | memory_s (HLO ub) | memory_s (est) "
        "| collective_s | bottleneck | MODEL/HLO flops | roofline frac "
        "| peak GiB | fits 16G |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4g} "
            f"| {r['memory_s']:.4g} | {r['memory_est_s']:.4g} "
            f"| {r['collective_s']:.4g} "
            f"| **{r['bottleneck']}** | {r['model_flops_ratio']:.3f} "
            f"| {r['roofline_frac']:.3f} | {r['peak_gib']} "
            f"| {'y' if r['fits_16g'] else 'NO'} |")
    return "\n".join(lines)
