"""Telemetry smoke: the observability layer under a real multiplexed
serving run, gated in the exit code (the CI "Telemetry smoke" step).

One A/B multiplexed run with a mid-stream tenant-B hot-swap must
produce, with ``telemetry=True``:

  * a **parseable Prometheus snapshot** — both the scheduler registry
    and the process-global registry round-trip through
    ``obs.parse_prometheus`` with a non-trivial sample count;
  * a **complete span set per completed request** on both tenants —
    ``queue_wait`` + ``prefill`` + ``decode`` spans that telescope
    exactly to the ``request`` span's wall time;
  * a **zero retrace delta** across the swap window
    (``serve_jit_retraces_total`` — the runtime form of the "no
    re-trace at swap-window boundaries" invariant);
  * **device counters consistent with the Table-I model** — the
    per-tenant per-mode ``serve_device_read_seconds_total`` /
    ``serve_device_energy_joules_total`` totals must equal
    ``CrossbarExecutor.device_token_cost`` x tokens served (rel 1e-6).

A second phase measures decode throughput with telemetry on vs off
(fresh schedulers, identical workload, warmed-up closures, best of
several repeats each) and gates the overhead at <= 5 %.

CLI: ``python benchmarks/obs_bench.py --json BENCH_obs.json`` (exits
nonzero if any acceptance figure fails).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import obs  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.core.engine import EngineConfig  # noqa: E402
from repro.core.quant import QuantConfig  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.serve.engine import BatchScheduler, Request  # noqa: E402
from repro.serve.hotswap import finetune_delta  # noqa: E402

# the paper's operating point, matching multiplex_bench.py so the
# telemetry smoke watches the same serving stack the other smokes gate
_XBAR = EngineConfig(tile_rows=64, tile_cols=128, mode="deepnet",
                     quant=QuantConfig(w_bits=4, in_bits=10, adc_bits=10))

_N_SLOTS, _MAX_LEN = 2, 64
_SPAN_SET = ("queue_wait", "prefill", "decode", "request")
_DEVICE_REL_TOL = 1e-6
_OVERHEAD_GATE = 0.05


def _crossbar_cfg():
    return dataclasses.replace(get_config("qwen3_4b", smoke=True),
                               backend="crossbar", xbar=_XBAR)


def _prompt(rid, vocab):
    return jax.random.randint(jax.random.PRNGKey(rid), (6,), 0,
                              vocab - 1).astype(jnp.int32)


def _submit(sched, model_id, rids, vocab, max_new):
    for rid in rids:
        sched.submit(Request(rid=rid, prompt=_prompt(rid, vocab),
                             max_new=max_new, model_id=model_id))


def _drain(sched, n_req, max_steps=500):
    done, steps = [], 0
    while len(done) < n_req and steps < max_steps:
        done += sched.step()
        steps += 1
    return {r.rid: r for r in done}


def _span_gates(sched, done, rids_by_tenant):
    """Every completed request has its full span set and the
    queue_wait + prefill + decode decomposition telescopes to the
    request span's wall time."""
    complete, telescoped = True, True
    for tenant, rids in rids_by_tenant.items():
        for rid in rids:
            if rid not in done:
                complete = False
                continue
            parts = {}
            for name in _SPAN_SET:
                got = sched.tracer.spans(name, rid=rid, tenant=tenant)
                if len(got) != 1:
                    complete = False
                    break
                parts[name] = got[0]
            else:
                decomp = sum(parts[n].duration
                             for n in ("queue_wait", "prefill", "decode"))
                if abs(decomp - parts["request"].duration) > 1e-9:
                    telescoped = False
    return complete, telescoped


def _device_gates(sched, executor):
    """Per-tenant per-mode device counters vs device_token_cost x
    tokens served; returns (ok, worst_rel_err, per-tenant figures)."""
    ok, worst, figures = True, 0.0, {}
    for tenant in sched.tenants:
        tokens = sched.metrics.total("serve_tokens_total", tenant=tenant)
        cost = executor.device_token_cost(tenant)
        figures[tenant] = {"tokens": int(tokens), "modes": {}}
        for mode, c in sorted(cost.items()):
            checks = {
                "read_s": ("serve_device_read_seconds_total",
                           c["read_s"] * tokens),
                "energy_j": ("serve_device_energy_joules_total",
                             c["energy_j"] * tokens),
            }
            fig = {}
            for key, (metric, want) in checks.items():
                got = sched.metrics.total(metric, tenant=tenant,
                                          mode=mode)
                rel = (abs(got - want) / want) if want else abs(got)
                worst = max(worst, rel)
                ok = ok and rel <= _DEVICE_REL_TOL
                fig[key] = got
            fig["pj_per_token"] = (fig["energy_j"] / tokens * 1e12
                                   if tokens else 0.0)
            figures[tenant]["modes"][mode] = fig
    return ok, worst, figures


def _decode_throughput(cfg, params, steps, repeats):
    """Steady-state decode throughput (tokens/s) with telemetry on vs
    off: two fresh single-tenant schedulers, closures pre-warmed so jit
    compile never lands in a timed window, timed windows *interleaved*
    between the arms (so machine drift hits both equally, instead of
    masquerading as overhead), best-of-``repeats`` per arm."""
    scheds = {}
    for arm in ("off", "on"):
        model = build_model(cfg)
        sched = BatchScheduler(model, params, _N_SLOTS, _MAX_LEN,
                               telemetry=(arm == "on"))
        # keep every slot busy for the whole measurement
        budget = (repeats + 2) * steps + 8
        _submit(sched, "A", range(_N_SLOTS), cfg.vocab, budget)
        for _ in range(3):      # admission + decode compile, then warm
            sched.step()
        scheds[arm] = sched
    best = {"off": 0.0, "on": 0.0}
    for _ in range(repeats):
        for arm, sched in scheds.items():
            lane = sched._lanes["A"]
            tok0 = lane.tokens_served
            t0 = time.perf_counter()
            for _ in range(steps):
                sched.step()
            jax.block_until_ready(lane.cache["layers"]["len"])
            dt = time.perf_counter() - t0
            best[arm] = max(best[arm],
                            (lane.tokens_served - tok0) / dt)
    return best["off"], best["on"]


def bench_obs(quick: bool = False):
    n_req, max_new = (2, 5) if quick else (3, 8)
    steps, repeats = (30, 4) if quick else (50, 5)
    cfg = _crossbar_cfg()
    params_a = build_model(cfg).init(jax.random.PRNGKey(0))
    params_b = finetune_delta(params_a, scale=0.04, seed=11)
    params_b2 = finetune_delta(params_a, scale=0.07, seed=23)
    rids = {"A": list(range(n_req)),
            "B": list(range(100, 100 + n_req))}

    # -- phase 1: multiplexed A/B with a mid-stream B hot-swap -------------
    reg = obs.registry()
    retraces_at_start = reg.total("serve_jit_retraces_total")
    t0 = time.perf_counter()
    model = build_model(cfg)
    sched = BatchScheduler(model, params_a, _N_SLOTS, _MAX_LEN,
                           tenants={"A": params_a, "B": params_b},
                           telemetry=True)
    _submit(sched, "A", rids["A"], cfg.vocab, 2 * max_new)
    _submit(sched, "B", rids["B"], cfg.vocab, 2 * max_new)
    for _ in range(2):
        sched.step()
    retraces_pre_swap = reg.total("serve_jit_retraces_total")
    hs = sched.begin_hot_swap(params_b2, chunks_per_step=1, tenant="B")
    # pace the write window across several of the surviving decode steps
    hs.chunks_per_step = max(
        1, -(-hs.plan.total_chunks // max(2 * max_new - 4, 1)))
    done = _drain(sched, 2 * n_req)
    while sched.swap_in_flight:         # pace out any tail chunks
        sched.step()
    wall = time.perf_counter() - t0
    retraces_after = reg.total("serve_jit_retraces_total")

    spans_complete, spans_telescope = _span_gates(sched, done, rids)
    device_ok, device_rel, device_fig = _device_gates(
        sched, model.executor)
    swap_rep = sched.swap_history[0]

    # both exports must round-trip the text exposition parser
    try:
        samples = (len(obs.parse_prometheus(sched.metrics.to_prometheus()))
                   + len(obs.parse_prometheus(reg.to_prometheus())))
        prom_ok = samples > 0
    except ValueError:
        samples, prom_ok = 0, False

    # -- phase 2: decode-throughput overhead, telemetry on vs off ----------
    thr_off, thr_on = _decode_throughput(cfg, params_a, steps, repeats)
    overhead = 1.0 - thr_on / thr_off

    return {
        "us_per_call": wall * 1e6,
        "n_requests_per_tenant": n_req,
        "requests_completed": len(done),
        "swap_lifecycle": swap_rep["swap_mode"],
        "swap_decode_steps_during": swap_rep["decode_steps_during_swap"],
        "prometheus_parseable": bool(prom_ok),
        "prometheus_samples": samples,
        "spans_complete_per_request": bool(spans_complete),
        "spans_telescope_to_request_wall": bool(spans_telescope),
        "jit_retraces_across_swap_window": retraces_after
        - retraces_pre_swap,
        "jit_retraces_whole_run": retraces_after - retraces_at_start,
        "device_counters_match_timing_model": bool(device_ok),
        "device_counter_worst_rel_err": device_rel,
        "device_accounting": device_fig,
        "decode_tok_per_s_telemetry_off": thr_off,
        "decode_tok_per_s_telemetry_on": thr_on,
        "telemetry_overhead_frac": overhead,
        "telemetry_overhead_gate": _OVERHEAD_GATE,
    }


def accepted(res) -> bool:
    return (res["prometheus_parseable"]
            and res["spans_complete_per_request"]
            and res["spans_telescope_to_request_wall"]
            and res["jit_retraces_across_swap_window"] == 0
            and res["device_counters_match_timing_model"]
            and res["swap_decode_steps_during"] > 0
            and res["telemetry_overhead_frac"] <= res[
                "telemetry_overhead_gate"])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_obs.json")
    args = ap.parse_args(argv)
    res = bench_obs(quick=True)
    print("name,us_per_call,derived")
    derived = {k: v for k, v in res.items() if k != "us_per_call"}
    print(f"obs_telemetry,{res['us_per_call']:.1f},"
          f"{json.dumps(derived, default=float)}")
    from benchmarks.meta import append_trajectory, write_stamped
    results = {"obs_telemetry": res}
    meta = write_stamped(results, args.json, lane="obs-smoke")
    append_trajectory(meta, results)
    print(f"# wrote {args.json} (sha={meta['git_sha'][:12]})")
    ok = accepted(res)
    print(f"# acceptance: prometheus parseable "
          f"({res['prometheus_samples']} samples: "
          f"{res['prometheus_parseable']}), span sets complete "
          f"({res['spans_complete_per_request']}) and telescoping "
          f"({res['spans_telescope_to_request_wall']}), retraces across "
          f"swap window {res['jit_retraces_across_swap_window']} "
          f"(whole run {res['jit_retraces_whole_run']}), device "
          f"counters match Table-I model "
          f"({res['device_counters_match_timing_model']}, worst rel "
          f"{res['device_counter_worst_rel_err']:.2e}), telemetry "
          f"overhead {res['telemetry_overhead_frac'] * 100:+.1f}% "
          f"(gate <= {res['telemetry_overhead_gate'] * 100:.0f}%)")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
