"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One function per paper table/figure (see paper_benches.py) plus the
weight-residency benches (executor_bench.py), printed as
``name,us_per_call,derived`` CSV rows and written to a ``BENCH_*.json``
artifact, followed by the roofline summary if dry-run artifacts exist
(benchmarks/roofline.py builds the full table).

``--quick`` runs the smallest configs (the CI benchmark-smoke lane);
``--json PATH`` overrides the artifact path.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)   # so ``python benchmarks/run.py`` also works

from benchmarks import executor_bench as xb  # noqa: E402
from benchmarks import expansion_bench as eb  # noqa: E402
from benchmarks import hotswap_bench as hb  # noqa: E402
from benchmarks import multiplex_bench as mb  # noqa: E402
from benchmarks import obs_bench as ob  # noqa: E402
from benchmarks import overlap_kernel_bench as okb  # noqa: E402
from benchmarks import paged_bench as pgb  # noqa: E402
from benchmarks import paper_benches as pb  # noqa: E402
from benchmarks.meta import append_trajectory, write_stamped  # noqa: E402
from repro import obs  # noqa: E402


BENCHES = [
    ("fig3a_hysteresis", pb.bench_hysteresis),
    ("fig3b_ir_drop_22pct", pb.bench_ir_drop),
    ("fig3cd_leakage_mc", pb.bench_leakage_mc),
    ("fig4_transient_readout", pb.bench_transient_readout),
    ("sec5_deepnet_speedup_29pct", pb.bench_deepnet_speedup),
    ("table1_characteristics", pb.bench_table1),
    ("engine_crossbar_mac", pb.bench_crossbar_mac),
]

# weight-residency benches take a ``quick`` kwarg (CI smoke lane)
RESIDENCY_BENCHES = [
    ("executor_program_once", xb.bench_program_once),
    ("executor_reference_vs_kernel", xb.bench_reference_vs_kernel),
    ("executor_decode_resident", xb.bench_executor_decode),
    ("hotswap_overlap", hb.bench_hotswap),
    ("multiplex_plane_sharing", mb.bench_multiplex),
    ("planebank_3tenant", mb.bench_planebank),
    ("overlap_kernel_decode", okb.bench_overlap_kernel),
    ("expansion_mode_policy", eb.bench_expansion),
    ("obs_telemetry", ob.bench_obs),
    ("paged_serving", pgb.bench_paged),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smallest configs; residency benches only")
    ap.add_argument("--json", default="BENCH_crossstack.json",
                    help="write all results to this JSON artifact")
    args = ap.parse_args(argv)

    results = {}
    # --quick is CI's "Benchmark smoke" step, which is followed by
    # dedicated hotswap_bench.py / multiplex_bench.py runs — skip those
    # here to avoid paying the same serving loops twice per CI run.
    # planebank_3tenant deliberately stays in BOTH lanes: here so the
    # 3-tenant figures ride the main BENCH artifact + trajectory append
    # of every --quick run, and again in the dedicated CI "Plane-bank
    # smoke" step, which is what gates on the acceptance figures (exit
    # code) and uploads BENCH_planebank.json.  ~2 min of duplicated
    # serving loops per CI run, accepted for the standalone gate.
    quick_benches = [(n, f) for n, f in RESIDENCY_BENCHES
                     if n not in ("hotswap_overlap",
                                  "multiplex_plane_sharing",
                                  "overlap_kernel_decode",
                                  "expansion_mode_policy",
                                  "obs_telemetry",
                                  "paged_serving")]
    benches = ([(n, lambda f=f: f(quick=True)) for n, f in quick_benches]
               if args.quick else
               BENCHES + [(n, f) for n, f in RESIDENCY_BENCHES])
    print("name,us_per_call,derived")
    for name, fn in benches:
        res = fn()
        results[name] = dict(res)
        us = res.pop("us_per_call", 0.0)
        derived = json.dumps(res, default=float)
        print(f"{name},{us:.1f},{derived}")

    # final registry snapshot rides the artifact (underscore key: the
    # schema gate skips it when scanning for figures dicts) so every
    # BENCH_*.json records what actually executed — kernel vs reference
    # dispatches, program/swap events, jit trace/retrace counts
    reg = obs.registry()
    results["_registry"] = reg.snapshot()
    telemetry = {
        "dispatch_kernel": int(reg.total("crossstack_dispatch_total",
                                         path="kernel")),
        "dispatch_reference": int(reg.total("crossstack_dispatch_total",
                                            path="reference")),
        "jit_traces": int(reg.total("serve_jit_traces_total")),
        "jit_retraces": int(reg.total("serve_jit_retraces_total")),
    }
    # provenance stamp (git SHA, jax version, timestamp) + trajectory
    # append — BENCH_*.json artifacts are comparable across PRs
    meta = write_stamped(results, args.json,
                         lane="quick" if args.quick else "full")
    append_trajectory(meta, results, telemetry=telemetry)
    print(f"# telemetry: {telemetry}")
    print(f"# wrote {args.json} (sha={meta['git_sha'][:12]} "
          f"jax={meta['jax_version']} at {meta['timestamp_utc']})")

    # roofline summary (reads experiments/dryrun/*.json if present)
    try:
        from benchmarks.roofline import summary_rows
        rows = summary_rows("experiments/dryrun")
        if rows:
            print("\n# roofline (single-pod 16x16; seconds per step)")
            print("arch,shape,compute_s,memory_s,collective_s,bottleneck,"
                  "model_flops_ratio,peak_GiB")
            for r in rows:
                print(",".join(str(r[k]) for k in (
                    "arch", "shape", "compute_s", "memory_s",
                    "collective_s", "bottleneck", "model_flops_ratio",
                    "peak_gib")))
    except Exception as e:  # noqa: BLE001
        print(f"# roofline summary unavailable: {e!r}")


if __name__ == "__main__":
    main()
