"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One function per paper table/figure (see paper_benches.py), printed as
``name,us_per_call,derived`` CSV rows, followed by the roofline summary if
dry-run artifacts exist (benchmarks/roofline.py builds the full table).
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import paper_benches as pb  # noqa: E402


BENCHES = [
    ("fig3a_hysteresis", pb.bench_hysteresis),
    ("fig3b_ir_drop_22pct", pb.bench_ir_drop),
    ("fig3cd_leakage_mc", pb.bench_leakage_mc),
    ("fig4_transient_readout", pb.bench_transient_readout),
    ("sec5_deepnet_speedup_29pct", pb.bench_deepnet_speedup),
    ("table1_characteristics", pb.bench_table1),
    ("engine_crossbar_mac", pb.bench_crossbar_mac),
]


def main() -> None:
    print("name,us_per_call,derived")
    for name, fn in BENCHES:
        res = fn()
        us = res.pop("us_per_call", 0.0)
        derived = json.dumps(res, default=float)
        print(f"{name},{us:.1f},{derived}")

    # roofline summary (reads experiments/dryrun/*.json if present)
    try:
        from benchmarks.roofline import summary_rows
        rows = summary_rows("experiments/dryrun")
        if rows:
            print("\n# roofline (single-pod 16x16; seconds per step)")
            print("arch,shape,compute_s,memory_s,collective_s,bottleneck,"
                  "model_flops_ratio,peak_GiB")
            for r in rows:
                print(",".join(str(r[k]) for k in (
                    "arch", "shape", "compute_s", "memory_s",
                    "collective_s", "bottleneck", "model_flops_ratio",
                    "peak_gib")))
    except Exception as e:  # noqa: BLE001
        print(f"# roofline summary unavailable: {e!r}")


if __name__ == "__main__":
    main()
