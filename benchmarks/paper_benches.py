"""One benchmark per paper table/figure.  Each returns a dict of derived
numbers and asserts nothing (tests/test_core.py holds the assertions);
``benchmarks.run`` prints the canonical CSV.

Paper artifacts covered:
  Fig 3a  pinched hysteresis          -> bench_hysteresis
  Fig 3b  IR-drop, expansion vs planar -> bench_ir_drop (22 % claim C1)
  Fig 3c/d leakage Monte-Carlo        -> bench_leakage_mc (C3, C4)
  Fig 4   transient read-out deviation -> bench_transient_readout (C5)
  Table I corner set                  -> bench_table1
  §IV-B/V deep-net 29 % speedup       -> bench_deepnet_speedup (C2)
  (engine) crossbar MAC fidelity/perf -> bench_crossbar_mac
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as eng
from repro.core import ir_drop as ird
from repro.core import pipeline as pipe
from repro.core.crossbar import PlaneConfig, worst_case_power
from repro.core.device import (hysteresis_loop, sample_conductances,
                               transistor_leakage)
from repro.core.quant import QuantConfig
from repro.core.timing import PAPER, deepnet_speedup


def _timeit(fn, *args, n: int = 5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6  # us


def bench_hysteresis():
    t0 = time.perf_counter()
    v, i, w = hysteresis_loop(n_cycles=2, samples_per_cycle=2048)
    v, i = np.asarray(v), np.asarray(i)
    near0 = np.abs(v) < 0.01
    pinch = float(np.abs(i[near0]).max() / np.abs(i).max())
    half = len(v) // 2
    area = float(abs(np.trapezoid(i[half:], v[half:])))
    return {"us_per_call": (time.perf_counter() - t0) * 1e6,
            "pinch_ratio": pinch, "loop_area_VA": area,
            "w_excursion": float(w.max() - w.min())}


def bench_ir_drop(n: int = 20, m: int = 20):
    """Paper C1: expansion mode reduces line losses ~22 % at fixed inputs."""
    g = jnp.full((n, m), PAPER.g_set)
    v = jnp.full((n,), PAPER.v_write)
    g_ser = 1.0 / (1.0 / g + PAPER.r_on_transistor)
    i_ideal = ird.ideal_currents(g_ser, v)
    t0 = time.perf_counter()
    i_pl, _, _ = ird.solve_planar(g, v)
    gt = jnp.full((n // 2, m), PAPER.g_set)
    vt = jnp.full((n // 2,), PAPER.v_write)
    i_cs, _, _ = ird.solve_crossstack(gt, gt, vt, vt)
    us = (time.perf_counter() - t0) * 1e6
    loss_pl = ird.ir_drop_loss(i_pl, i_ideal)
    loss_cs = ird.ir_drop_loss(i_cs, i_ideal)
    # paper prototype geometry (10x10x2 vs planar 20x10, same 200 devices)
    g10 = jnp.full((20, 10), PAPER.g_set)
    i_pl10, _, _ = ird.solve_planar(g10, jnp.full((20,), PAPER.v_write))
    gt10 = jnp.full((10, 10), PAPER.g_set)
    i_cs10, _, _ = ird.solve_crossstack(
        gt10, gt10, jnp.full((10,), PAPER.v_write),
        jnp.full((10,), PAPER.v_write))
    i_id10 = ird.ideal_currents(
        1.0 / (1.0 / g10 + PAPER.r_on_transistor),
        jnp.full((20,), PAPER.v_write))
    red10 = 1.0 - float(ird.ir_drop_loss(i_cs10, i_id10).mean()
                        / ird.ir_drop_loss(i_pl10, i_id10).mean())
    return {"us_per_call": us,
            "loss_planar_mean": float(loss_pl.mean()),
            "loss_crossstack_mean": float(loss_cs.mean()),
            "reduction_square_20x20": 1.0 - float(loss_cs.mean()
                                                  / loss_pl.mean()),
            "reduction_prototype_10x10x2": red10,
            "paper_claim": 0.22}


def bench_leakage_mc(trials: int = 200):
    """Paper C3/C4: worst-case deep-net leakage + single-cell read current."""
    t0 = time.perf_counter()
    leak_cell = float(transistor_leakage(jnp.float32(PAPER.v_write),
                                         jnp.float32(0.0)))
    # Monte-Carlo over R_s +/- 7 % (Gaussian, 200 trials, paper Fig 3c)
    key = jax.random.PRNGKey(0)
    bits = jnp.ones((trials, 10))          # a 10-cell column, all SET
    g = sample_conductances(key, bits)
    i_col = (PAPER.v_write * g).sum(axis=1)
    i_read_cell = 0.004 / (PAPER.r_reset + PAPER.r_on_transistor)
    return {"us_per_call": (time.perf_counter() - t0) * 1e6,
            "leak_per_cell_pA": leak_cell * 1e12,
            "leak_column10_pA": leak_cell * 10 * 1e12,
            "leak_frac_of_read": leak_cell * 10
            / float(jnp.mean(i_col)),
            "read_cell_nA": i_read_cell * 1e9,
            "read_cell_ideal_nA": 0.004 / PAPER.r_reset * 1e9,
            "mc_col_current_std_frac": float(jnp.std(i_col)
                                             / jnp.mean(i_col)),
            "paper_leak_pA": 2.5, "paper_read_nA": 39.6}


def bench_transient_readout(trials: int = 200):
    """Paper C5: worst-case read deviation -> usable bits/cell."""
    t0 = time.perf_counter()
    key = jax.random.PRNGKey(1)
    bits = jnp.ones((trials, 10))
    g = sample_conductances(key, bits)
    g_eff = 1.0 / (1.0 / g + PAPER.r_on_transistor)
    i_cols = (PAPER.v_read * g_eff).sum(axis=1)
    i_nom = PAPER.v_read * 10 / (PAPER.r_set + PAPER.r_on_transistor)
    dev = jnp.abs(i_cols - i_nom) / i_nom
    worst = float(jnp.max(dev))
    bits_per_cell = float(jnp.log2(1.0 / worst))
    return {"us_per_call": (time.perf_counter() - t0) * 1e6,
            "worst_dev_frac": worst, "bits_per_cell": bits_per_cell,
            "paper_dev": 0.08, "paper_bits": 3.5}


def bench_deepnet_speedup():
    """Paper C2: 29 % faster per-10-bit convolution."""
    t0 = time.perf_counter()
    rep = pipe.latency_report(200, 10)
    s_inf = deepnet_speedup(10)
    sweep = {b: round(pipe.speedup(200, b), 4) for b in (1, 4, 8, 10, 16,
                                                         25, 32)}
    return {"us_per_call": (time.perf_counter() - t0) * 1e6,
            "speedup_10bit": rep["speedup_frac"],
            "steady_state": rep["steady_state_frac"],
            "speedup_vs_bits": sweep, "paper_claim": 0.29,
            "closed_form": s_inf}


def bench_table1():
    plane = PlaneConfig(10, 10)
    return {"us_per_call": 0.0,
            "r_set_kohm": PAPER.r_set / 1e3,
            "r_reset_kohm": PAPER.r_reset / 1e3,
            "t_read_ns": PAPER.t_read * 1e9,
            "t_write_ns": PAPER.t_write * 1e9,
            "r_on_transistor_ohm": PAPER.r_on_transistor,
            "worst_case_power_mW_10x10x2": worst_case_power(plane) * 2e3,
            "paper_p_critical_mW": PAPER.p_critical * 1e3,
            "n_devices": PAPER.n_devices}


def bench_crossbar_mac(b: int = 16, k: int = 256, n: int = 256):
    """Engine fidelity + throughput of the digital-twin MAC paths."""
    key = jax.random.PRNGKey(2)
    w = jax.random.normal(key, (k, n)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(3), (b, k))
    ref = x @ w
    out = {}
    for wb, ib, ab, tag in [(8, 8, 12, "hi"), (4, 8, 10, "mid"),
                            (1, 4, 8, "1bit")]:
        cfg = eng.EngineConfig(tile_rows=64, tile_cols=128, mode="expansion",
                               quant=QuantConfig(w_bits=wb, in_bits=ib,
                                                 adc_bits=ab))
        pw = eng.program(w, cfg)
        f = jax.jit(lambda xx: eng.matmul(xx, pw, cfg))
        us = _timeit(f, x)
        y = f(x)
        out[f"relerr_{tag}"] = float(jnp.abs(y - ref).max()
                                     / jnp.abs(ref).max())
        out[f"us_{tag}"] = us
    out["us_per_call"] = out["us_hi"]
    return out
