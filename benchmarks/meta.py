"""Provenance stamping for BENCH_*.json artifacts.

Every artifact carries a ``_meta`` block (git SHA, jax version, UTC
timestamp, backend) and appends a one-line summary to
``benchmarks/trajectory.json`` so bench numbers are comparable across
PRs — the trajectory starts as an empty ``[]`` and grows one entry per
local/CI run.
"""
from __future__ import annotations

import datetime
import json
import os
import subprocess
from typing import Any, Dict

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TRAJECTORY = os.path.join(_ROOT, "benchmarks", "trajectory.json")


def bench_meta(**extra: Any) -> Dict[str, Any]:
    """git SHA + jax version + UTC timestamp (+ caller extras)."""
    try:
        sha = subprocess.check_output(
            ["git", "rev-parse", "HEAD"], cwd=_ROOT,
            stderr=subprocess.DEVNULL).decode().strip()
    except Exception:  # noqa: BLE001 — not a git checkout / no git binary
        sha = "unknown"
    import jax
    meta = {
        "git_sha": sha,
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
    }
    meta.update(extra)
    return meta


def write_stamped(results: Dict[str, Any], path: str,
                  **meta_extra: Any) -> Dict[str, Any]:
    """Write ``results`` + ``_meta`` to ``path``; returns the meta block."""
    meta = bench_meta(**meta_extra)
    out = dict(results)
    out["_meta"] = meta
    with open(path, "w") as f:
        json.dump(out, f, indent=2, default=float)
    return meta


def append_trajectory(meta: Dict[str, Any],
                      results: Dict[str, Any]) -> None:
    """Append {meta, us_per_call summary} to benchmarks/trajectory.json."""
    try:
        with open(TRAJECTORY) as f:
            traj = json.load(f)
        if not isinstance(traj, list):
            traj = []
    except (OSError, ValueError):
        traj = []
    summary = {name: res.get("us_per_call")
               for name, res in results.items()
               if isinstance(res, dict) and not name.startswith("_")}
    traj.append({"meta": meta, "us_per_call": summary})
    with open(TRAJECTORY, "w") as f:
        json.dump(traj, f, indent=2, default=float)
