"""Provenance stamping + schema gate for BENCH_*.json artifacts.

Every artifact carries a ``_meta`` block (git SHA, jax/jaxlib versions,
UTC timestamp, backend) and at least one figures dict, and appends a
one-line summary to ``benchmarks/trajectory.json`` so bench numbers are
comparable across PRs — the trajectory starts as an empty ``[]`` and
grows one entry per local/CI run.

Run as a module to validate artifacts before they upload (the CI
``bench-validate`` step)::

    python benchmarks/meta.py BENCH_*.json            # schema check
    python benchmarks/meta.py --trajectory [--baseline ref.json]

A malformed artifact or a trajectory that rewrote committed history
exits non-zero and names the violation — fail loudly, never upload.
"""
from __future__ import annotations

import datetime
import json
import os
import subprocess
from typing import Any, Dict, List, Optional

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TRAJECTORY = os.path.join(_ROOT, "benchmarks", "trajectory.json")

#: the shared BENCH_*.json schema: every artifact's ``_meta`` block must
#: carry these keys (non-empty strings; timestamp ISO-8601), and the
#: artifact must hold at least one figures dict beside ``_meta``
REQUIRED_META = ("git_sha", "jax_version", "jaxlib_version", "backend",
                 "timestamp_utc")


def bench_meta(**extra: Any) -> Dict[str, Any]:
    """git SHA + jax/jaxlib versions + UTC timestamp (+ caller extras)."""
    try:
        sha = subprocess.check_output(
            ["git", "rev-parse", "HEAD"], cwd=_ROOT,
            stderr=subprocess.DEVNULL).decode().strip()
    except Exception:  # noqa: BLE001 — not a git checkout / no git binary
        sha = "unknown"
    import jax
    import jaxlib
    meta = {
        "git_sha": sha,
        "jax_version": jax.__version__,
        "jaxlib_version": jaxlib.__version__,
        "backend": jax.default_backend(),
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
    }
    meta.update(extra)
    return meta


def write_stamped(results: Dict[str, Any], path: str,
                  **meta_extra: Any) -> Dict[str, Any]:
    """Write ``results`` + ``_meta`` to ``path``; returns the meta block."""
    meta = bench_meta(**meta_extra)
    out = dict(results)
    out["_meta"] = meta
    with open(path, "w") as f:
        json.dump(out, f, indent=2, default=float)
    return meta


def append_trajectory(meta: Dict[str, Any],
                      results: Dict[str, Any],
                      telemetry: Optional[Dict[str, Any]] = None) -> None:
    """Append {meta, us_per_call summary} to benchmarks/trajectory.json.

    ``telemetry`` (optional) is a flat dict of run-level counters —
    kernel/reference dispatch counts, jit trace/retrace totals — so
    trajectory entries carry the registry's invariants alongside
    timings (see benchmarks/run.py).
    """
    try:
        with open(TRAJECTORY) as f:
            traj = json.load(f)
        if not isinstance(traj, list):
            traj = []
    except (OSError, ValueError):
        traj = []
    summary = {name: res.get("us_per_call")
               for name, res in results.items()
               if isinstance(res, dict) and not name.startswith("_")}
    entry: Dict[str, Any] = {"meta": meta, "us_per_call": summary}
    if telemetry:
        entry["telemetry"] = telemetry
    traj.append(entry)
    with open(TRAJECTORY, "w") as f:
        json.dump(traj, f, indent=2, default=float)


# -- schema gate (the CI bench-validate step) --------------------------------

def validate_artifact(path: str) -> List[str]:
    """Check one BENCH_*.json against the shared schema; returns the
    list of violations (empty = valid)."""
    problems: List[str] = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable/not JSON ({e})"]
    if not isinstance(doc, dict):
        return [f"{path}: top level is {type(doc).__name__}, want object"]
    meta = doc.get("_meta")
    if not isinstance(meta, dict):
        problems.append(f"{path}: missing '_meta' block")
    else:
        for key in REQUIRED_META:
            val = meta.get(key)
            if not isinstance(val, str) or not val:
                problems.append(
                    f"{path}: _meta[{key!r}] missing or not a non-empty "
                    f"string (got {val!r})")
        ts = meta.get("timestamp_utc")
        if isinstance(ts, str) and ts:
            try:
                datetime.datetime.fromisoformat(ts)
            except ValueError:
                problems.append(
                    f"{path}: _meta['timestamp_utc'] {ts!r} is not "
                    f"ISO-8601")
    figures = {k: v for k, v in doc.items()
               if not k.startswith("_") and isinstance(v, dict)}
    if not figures:
        problems.append(
            f"{path}: no figures dict beside '_meta' (want at least one "
            f"non-underscore key holding an object of measurements)")
    return problems


def validate_trajectory(path: str = TRAJECTORY,
                        baseline: Optional[str] = None) -> List[str]:
    """Check ``trajectory.json`` parses as a list of stamped entries and
    that it only APPENDS relative to ``baseline`` (a file holding the
    pre-run trajectory; CI snapshots the committed file before the bench
    steps run) — a rewritten or truncated history is a violation."""
    problems: List[str] = []
    try:
        with open(path) as f:
            traj = json.load(f)
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable/not JSON ({e})"]
    if not isinstance(traj, list):
        return [f"{path}: top level is {type(traj).__name__}, want list"]
    for i, entry in enumerate(traj):
        if not isinstance(entry, dict) or "meta" not in entry:
            problems.append(f"{path}: entry {i} malformed (want an "
                            f"object with a 'meta' block)")
    if baseline is not None:
        try:
            with open(baseline) as f:
                prev = json.load(f)
        except (OSError, ValueError) as e:
            return problems + [f"{baseline}: unreadable baseline ({e})"]
        if not isinstance(prev, list):
            prev = []
        if len(traj) < len(prev) or traj[: len(prev)] != prev:
            problems.append(
                f"{path}: history rewritten — the first {len(prev)} "
                f"entries must equal the pre-run trajectory verbatim "
                f"(runs may only append)")
    return problems


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="validate BENCH_*.json artifacts and "
                    "benchmarks/trajectory.json against the shared schema")
    ap.add_argument("artifacts", nargs="*",
                    help="BENCH_*.json files to validate")
    ap.add_argument("--trajectory", action="store_true",
                    help="also validate benchmarks/trajectory.json")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help="pre-run trajectory snapshot; the current "
                         "trajectory must extend it verbatim")
    args = ap.parse_args(argv)
    problems: List[str] = []
    for path in args.artifacts:
        got = validate_artifact(path)
        problems += got
        print(f"{path}: {'OK' if not got else f'{len(got)} violation(s)'}")
    if args.trajectory or args.baseline:
        got = validate_trajectory(baseline=args.baseline)
        problems += got
        print(f"{TRAJECTORY}: "
              f"{'OK' if not got else f'{len(got)} violation(s)'}")
    for p in problems:
        print(f"FAIL {p}")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())
