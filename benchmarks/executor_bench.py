"""Weight-residency benchmarks: program-once vs re-program-per-call, and
reference (scan) vs Pallas-kernel MAC throughput.

The paper's deployment contract is program-at-load / read-at-inference;
these benches quantify what that residency buys over the naive
``engine.linear`` (which re-quantizes, re-slices and re-"programs" the
weight matrix on every invocation), plus the model-level view: a smoke
transformer decode step on the crossbar backend, weights resident, served
exactly as the BatchScheduler runs it.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

# one timing harness for the whole BENCH artifact — residency numbers stay
# comparable with the paper benches
from benchmarks.paper_benches import _timeit
from repro.configs import get_config
from repro.core import engine as eng
from repro.core.engine import EngineConfig
from repro.core.quant import QuantConfig
from repro.models.model import build_model


def bench_program_once(quick: bool = False):
    """Resident-tile matmul vs program-and-run on every call."""
    b, k, n = (8, 128, 128) if quick else (16, 256, 256)
    reps = 3 if quick else 10
    cfg = EngineConfig(tile_rows=64, tile_cols=128, mode="deepnet",
                       quant=QuantConfig(w_bits=4, in_bits=8, adc_bits=10))
    w = jax.random.normal(jax.random.PRNGKey(0), (k, n)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (b, k))

    pw = eng.program(w, cfg)
    resident = jax.jit(lambda xx: eng.matmul(xx, pw, cfg))
    # w enters as an ARGUMENT so XLA cannot constant-fold the programming
    # step out of the per-call graph
    reprogram = jax.jit(lambda xx, ww: eng.linear(xx, ww, cfg))

    us_once = _timeit(resident, x, n=reps)
    us_reprog = _timeit(reprogram, x, w, n=reps)
    return {"us_per_call": us_once,
            "us_program_once": us_once,
            "us_reprogram_per_call": us_reprog,
            "program_once_speedup": us_reprog / max(us_once, 1e-9),
            "shape_bkn": [b, k, n]}


def bench_reference_vs_kernel(quick: bool = False):
    """Scan-based jnp reference vs the Pallas crossbar_mac kernel.

    On CPU the kernel runs in interpret mode (every grid step is traced
    Python), so the reference wins; on a real TPU ``interpret=False`` flips
    the comparison.  Both numbers land in the JSON either way so the ratio
    is tracked per-commit.
    """
    b, k, n = (8, 64, 64) if quick else (16, 128, 128)
    reps = 2 if quick else 5
    qc = QuantConfig(w_bits=4, in_bits=8, adc_bits=10)
    cfg_ref = EngineConfig(tile_rows=32, tile_cols=64, mode="deepnet",
                           quant=qc)
    cfg_ker = dataclasses.replace(cfg_ref, use_kernel=True)
    w = jax.random.normal(jax.random.PRNGKey(2), (k, n)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(3), (b, k))
    pw = eng.program(w, cfg_ref)

    f_ref = jax.jit(lambda xx: eng.matmul(xx, pw, cfg_ref))
    f_ker = jax.jit(lambda xx: eng.matmul(xx, pw, cfg_ker))
    us_ref = _timeit(f_ref, x, n=reps)
    us_ker = _timeit(f_ker, x, n=reps)
    err = float(jnp.abs(f_ref(x) - f_ker(x)).max())
    return {"us_per_call": us_ref,
            "us_reference_scan": us_ref,
            "us_kernel_interpret": us_ker,
            "kernel_vs_reference": us_ref / max(us_ker, 1e-9),
            "max_abs_diff": err,
            "shape_bkn": [b, k, n]}


def bench_executor_decode(quick: bool = False):
    """Model-level residency: smoke-transformer decode step, crossbar vs
    digital backend, plus one-time programming cost."""
    cfg_d = get_config("qwen3_4b", smoke=True)
    xb = EngineConfig(tile_rows=64, tile_cols=128, mode="deepnet",
                      quant=QuantConfig(w_bits=4, in_bits=8, adc_bits=10))
    cfg_c = dataclasses.replace(cfg_d, backend="crossbar", xbar=xb)
    md = build_model(cfg_d)
    mc = build_model(cfg_c)
    params = md.init(jax.random.PRNGKey(0))

    t0 = time.perf_counter()
    n_programmed = mc.executor.program_params(params)
    us_program = (time.perf_counter() - t0) * 1e6

    toks = jnp.zeros((2, 1), jnp.int32)
    cache_d = md.init_cache(2, 16)
    cache_c = mc.init_cache(2, 16)
    reps = 2 if quick else 5
    dec_d = jax.jit(lambda p, t, c: md.decode_step(p, t, c)[0])
    dec_c = jax.jit(lambda p, t, c: mc.decode_step(p, t, c)[0])
    us_digital = _timeit(dec_d, params, toks, cache_d, n=reps)
    us_crossbar = _timeit(dec_c, params, toks, cache_c, n=reps)
    return {"us_per_call": us_crossbar,
            "us_decode_crossbar": us_crossbar,
            "us_decode_digital": us_digital,
            "us_program_all_weights_once": us_program,
            "n_weights_programmed": n_programmed,
            "n_devices": mc.executor.n_devices,
            "program_cost_amortized_after_calls":
                us_program / max(us_crossbar, 1e-9)}
