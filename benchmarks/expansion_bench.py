"""Expansion-mode serving bench: per-weight mode policy acceptance.

Two acceptance gates (CI "Expansion smoke", exit-code enforced):

  * **policy** — ``mode_report()`` on the paper's 10x10x2 prototype
    geometry: the ``"auto"`` policy must program the accuracy-critical
    layers (attention projections + LM head) as expansion-fused pairs
    and keep the swap-heavy MLP mats in deep-net layout, with a mean
    worst-case IR-drop reduction >= 20% on the expansion layers vs the
    all-deep-net layout of the same doubled-input reads (paper: 22%;
    exact nodal solves, ``ir_drop.mode_ir_report``).
  * **streams** — mixed-mode serving is bit-exact across execution
    paths: the same auto-policy scheduler decodes identical token
    streams through the Pallas kernel lane (``use_kernel=True``) and
    the digital-twin reference scan, with the kernel path actually
    lowered for the decode closures (``engine.path_calls``).

CLI: ``python benchmarks/expansion_bench.py --json BENCH_expansion.json``
(exits nonzero if an acceptance figure fails; the artifact passes the
``benchmarks/meta.py`` schema gate).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core import engine as eng  # noqa: E402
from repro.core.engine import EngineConfig  # noqa: E402
from repro.core.executor import CrossbarExecutor  # noqa: E402
from repro.core.quant import QuantConfig  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.serve.engine import BatchScheduler, Request  # noqa: E402

# the paper's prototype tile: 10 rows x 10 cols per plane, 2 planes
_PAPER_CFG = EngineConfig(
    tile_rows=10, tile_cols=10, mode="deepnet",
    quant=QuantConfig(w_bits=4, in_bits=8, adc_bits=10))

# serving-tier smoke: d_model=64 over 32-row tiles -> 2 row-tiles per
# attention weight, the even pairing expansion mode fuses
_XBAR = EngineConfig(tile_rows=32, tile_cols=32, mode="deepnet",
                     quant=QuantConfig(w_bits=4, in_bits=8, adc_bits=10))


def _paper_params(d: int = 20, d_ff: int = 60, n_layers: int = 2):
    """A transformer-shaped params tree at the paper's tile geometry:
    every attention projection spans 2 row-tiles of 10 wordlines (the
    two stacked planes of one fused pair)."""
    ks = iter(jax.random.split(jax.random.PRNGKey(0), 16))

    def w(*shape):
        return jax.random.normal(next(ks), shape) * 0.3

    return {
        "blocks": {
            "attn": {"wq": w(n_layers, d, d), "wk": w(n_layers, d, d),
                     "wv": w(n_layers, d, d),
                     "wo": w(n_layers, 4, d // 4, d)},
            "mlp": {"wi": w(n_layers, d, d_ff), "wg": w(n_layers, d, d_ff),
                    "wo": w(n_layers, d_ff, d)},
        },
        "head": w(d, 2 * d),
    }


def bench_expansion(quick: bool = False):
    t0 = time.perf_counter()

    # -- gate 1: auto policy on the paper's 10x10x2 geometry ----------------
    ex = CrossbarExecutor(_PAPER_CFG)
    params = _paper_params()
    ex.program_params(params, mode_policy="auto")
    rep = ex.mode_report()
    agg = rep["aggregate"]
    # the all-deep-net comparison point: same tree, uniform policy
    ex_deep = CrossbarExecutor(_PAPER_CFG)
    ex_deep.program_params(params, mode_policy="deepnet")
    agg_deep = ex_deep.mode_report()["aggregate"]
    expansion_layers = {n: e for n, e in rep["layers"].items()
                        if e["mode"] == "expansion"}
    mlp_all_deepnet = all(e["mode"] == "deepnet"
                          for n, e in rep["layers"].items() if ".mlp." in n)
    attn_head_fused = all(e["fused"] for e in expansion_layers.values())

    # -- gate 2: mixed-mode streams bit-exact, kernel lane vs reference -----
    n_req, max_new = (2, 3) if quick else (3, 5)
    cfg = dataclasses.replace(get_config("qwen3_4b", smoke=True),
                              backend="crossbar", xbar=_XBAR)

    def _serve(use_kernel: bool):
        c = dataclasses.replace(
            cfg, xbar=dataclasses.replace(_XBAR, use_kernel=use_kernel))
        model = build_model(c)
        params_m = model.init(jax.random.PRNGKey(0))
        sched = BatchScheduler(model, params_m, n_slots=2, max_len=32,
                               mode_policy="auto")
        for rid in range(n_req):
            prompt = jax.random.randint(
                jax.random.PRNGKey(rid), (6,), 0,
                c.vocab - 1).astype(jnp.int32)
            sched.submit(Request(rid=rid, prompt=prompt, max_new=max_new))
        done, steps = [], 0
        while len(done) < n_req and steps < 200:
            done += sched.step()
            steps += 1
        res = model.executor.residency()["A"]["modes"]
        return {r.rid: r.out for r in done}, res

    calls0 = dict(eng.path_calls)
    out_ref, modes_ref = _serve(use_kernel=False)
    ref_calls = eng.path_calls["reference"] - calls0["reference"]
    calls1 = dict(eng.path_calls)
    out_kern, modes_kern = _serve(use_kernel=True)
    kern_calls = eng.path_calls["kernel"] - calls1["kernel"]
    streams_bit_exact = (len(out_ref) == n_req
                         and out_ref == out_kern)

    wall = time.perf_counter() - t0
    return {
        "us_per_call": wall * 1e6,
        # gate 1 figures (paper geometry)
        "tile_geometry": f"{_PAPER_CFG.tile_rows}x{_PAPER_CFG.tile_cols}x2",
        "n_expansion_layers": agg["n_expansion"],
        "n_deepnet_layers": agg["n_deepnet"],
        "mlp_all_deepnet": bool(mlp_all_deepnet),
        "attn_head_fused": bool(attn_head_fused),
        "ir_drop_reduction_expansion": agg["ir_drop_reduction_expansion"],
        "ir_drop_reduction_paper": 0.22,
        "all_deepnet_policy_n_expansion": agg_deep["n_expansion"],
        "mode_report_layers": {
            n: {"mode": e["mode"],
                "dev_deepnet": e["dev_deepnet"],
                "dev_expansion": e["dev_expansion"],
                "ir_drop_reduction": e["ir_drop_reduction"]}
            for n, e in sorted(rep["layers"].items())},
        # gate 2 figures (serving streams)
        "n_requests": n_req,
        "max_new": max_new,
        "serving_modes": modes_kern,
        "streams_bit_exact_kernel_vs_reference": bool(streams_bit_exact),
        "reference_path_traces": ref_calls,
        "kernel_path_traces": kern_calls,
        "serving_modes_agree": modes_ref == modes_kern,
    }


def accepted(res) -> bool:
    return (res["ir_drop_reduction_expansion"] >= 0.20
            and res["n_expansion_layers"] > 0
            and res["n_deepnet_layers"] > 0
            and res["mlp_all_deepnet"]
            and res["attn_head_fused"]
            and res["all_deepnet_policy_n_expansion"] == 0
            and res["streams_bit_exact_kernel_vs_reference"]
            and res["serving_modes"]["expansion"] > 0
            and res["serving_modes_agree"]
            and res["kernel_path_traces"] > 0)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_expansion.json")
    args = ap.parse_args(argv)
    res = bench_expansion(quick=True)
    print("name,us_per_call,derived")
    derived = {k: v for k, v in res.items() if k != "us_per_call"}
    print(f"expansion_mode_policy,{res['us_per_call']:.1f},"
          f"{json.dumps(derived, default=float)}")
    from benchmarks.meta import append_trajectory, write_stamped
    results = {"expansion_mode_policy": res}
    meta = write_stamped(results, args.json, lane="expansion-smoke")
    append_trajectory(meta, results)
    print(f"# wrote {args.json} (sha={meta['git_sha'][:12]})")
    ok = accepted(res)
    print(f"# acceptance: auto policy fused "
          f"{res['n_expansion_layers']} attention/head grids and kept "
          f"{res['n_deepnet_layers']} MLP grids deep-net on the "
          f"{res['tile_geometry']} paper geometry; mean worst-case "
          f"IR-drop reduction "
          f"{res['ir_drop_reduction_expansion'] * 100:.1f}% "
          f"(>= 20%: {res['ir_drop_reduction_expansion'] >= 0.20}; "
          f"paper: 22%); mixed-mode streams kernel-vs-reference "
          f"bit-exact {res['streams_bit_exact_kernel_vs_reference']} "
          f"({res['serving_modes']['expansion']} expansion / "
          f"{res['serving_modes']['deepnet']} deep-net grids served, "
          f"{res['kernel_path_traces']} kernel lowerings)")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
