"""Generate the data-driven sections of EXPERIMENTS.md from the dry-run
artifacts: §Dry-run (both meshes) and §Roofline (single-pod).

Usage: PYTHONPATH=src python -m benchmarks.report [--dry-dir experiments/dryrun]
Writes experiments/report_sections.md; EXPERIMENTS.md includes its content
(regenerated whenever the sweep re-runs).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import roofline as rl  # noqa: E402


def dryrun_table(dry_dir: str, mesh: str) -> str:
    rows = []
    for path in sorted(glob.glob(os.path.join(dry_dir, f"*__{mesh}.json"))):
        with open(path) as f:
            c = json.load(f)
        peak = c["memory"]["peak_bytes_per_device"] / 2**30
        coll = c["collective_bytes_total"] / 2**20
        by_kind = {k: f"{v['count']}x/{v['bytes']/2**20:.0f}M"
                   for k, v in sorted(c["collectives"].items())}
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['kind']} | "
            f"{peak:.2f} | {c['cost']['flops']:.3e} | {coll:.0f} | "
            f"{'; '.join(f'{k}:{v}' for k, v in by_kind.items())} | "
            f"{c['compile_s']:.0f}s |")
    head = (f"\n### Mesh {mesh}\n\n"
            "| arch | shape | step | peak GiB/dev | HLO flops/dev (raw) | "
            "coll MiB/dev | collective schedule | compile |\n"
            "|---|---|---|---|---|---|---|---|\n")
    return head + "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/report_sections.md")
    args = ap.parse_args()

    parts = ["## §Dry-run (auto-generated from experiments/dryrun/*.json)",
             "",
             "Every cell below **lowered and compiled** for its mesh "
             "(`.lower().compile()` success = the multi-pod distribution "
             "config is coherent).  Peak bytes are per device "
             "(`compiled.memory_analysis()`); raw HLO flops count a scan "
             "body once (see §Roofline for loop-corrected numbers).",
             dryrun_table(args.dry_dir, "16x16"),
             "",
             dryrun_table(args.dry_dir, "2x16x16"),
             "",
             "## §Roofline (single-pod 16x16; loop-corrected)",
             "",
             "Terms in seconds/step: compute = FLOPs/(chips*197e12), "
             "memory = bytes/(chips*819e9), collective = bytes/(chips*50e9)"
             " — v5e constants.  MODEL/HLO = analytic useful flops over "
             "compiled flops (remat/dispatch/padding waste shows up here). "
             "roofline frac = ideal compute time over the dominant term.",
             "",
             rl.markdown_table(args.dry_dir),
             ""]
    # per-cell advice lines
    parts.append("### Dominant-term notes (one per cell)\n")
    for r in rl.summary_rows(args.dry_dir):
        parts.append(f"* **{r['arch']} x {r['shape']}** — {r['bottleneck']}"
                     f"-bound: {rl.advice(r)}.")
    out = "\n".join(parts)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        f.write(out)
    print(f"wrote {args.out} ({len(out)} chars)")


if __name__ == "__main__":
    main()
