"""Hot-swap benchmarks: overlapped (deep-net) vs stop-the-world reprogram.

The measured loop is exactly the CI smoke shape: program a smoke
transformer onto crossbar tiles, serve 8 decode steps, deploy a second
checkpoint, serve 8 more.  Two policies run that loop:

  * **overlapped** — shadow-plane chunks interleave between decode steps
    (BatchScheduler.begin_hot_swap); decoding never stops and the flip is
    atomic at a step boundary.
  * **stop-the-world** — serving halts while ``CrossbarExecutor.swap``
    reprograms everything, then resumes (the serialized
    write -> read -> write pattern of a conventional 2-D array).

Wall-clock numbers quantify the host simulator; the acceptance metrics
are device-time from Table I (``serve.hotswap.overlap_report``): decode
throughput during the swap window (overlapped must sustain >= 2x
stop-the-world) and the steady-state read-under-write overlap (~29 %,
paper §V).

CLI: ``python benchmarks/hotswap_bench.py --json BENCH_hotswap_smoke.json``
(the CI bench-lane hot-swap smoke).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core.engine import EngineConfig  # noqa: E402
from repro.core.quant import QuantConfig  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.serve.engine import BatchScheduler, Request  # noqa: E402
from repro.serve.hotswap import finetune_delta  # noqa: E402

# the paper's operating point: 10-bit bit-serial reads (10 ns/pulse)
# against 250 ns writes -> the 29 % overlap figure of §V
_XBAR = EngineConfig(tile_rows=64, tile_cols=128, mode="deepnet",
                     quant=QuantConfig(w_bits=4, in_bits=10, adc_bits=10))


def _crossbar_cfg():
    return dataclasses.replace(get_config("qwen3_4b", smoke=True),
                               backend="crossbar", xbar=_XBAR)


def _fresh_scheduler(params, n_slots=2, max_len=64):
    model = build_model(_crossbar_cfg())
    sched = BatchScheduler(model, params, n_slots=n_slots, max_len=max_len)
    for rid in range(n_slots):
        p = jax.random.randint(jax.random.PRNGKey(rid), (6,), 0,
                               model.cfg.vocab - 1).astype(jnp.int32)
        sched.submit(Request(rid=rid, prompt=p, max_new=64))
    return model, sched


def _run_steps(sched, n):
    t0 = time.perf_counter()
    for _ in range(n):
        sched.step()
    return time.perf_counter() - t0


def bench_hotswap(quick: bool = False):
    """program -> serve N decode steps -> swap -> serve N more, both
    policies; returns wall + device-time metrics.  ``quick`` (the CI
    smoke lane) uses the 8+8-step window; the full lane widens it."""
    steps_pre = steps_post = 8 if quick else 12
    cfg = _crossbar_cfg()
    params_a = build_model(cfg).init(jax.random.PRNGKey(0))
    params_b = finetune_delta(params_a)

    # -- overlapped: chunks between decode steps, atomic flip ----------------
    model_o, sched_o = _fresh_scheduler(params_a)
    wall_pre = _run_steps(sched_o, steps_pre)
    hs = sched_o.begin_hot_swap(params_b, chunks_per_step=1)
    n_chunks = hs.plan.total_chunks
    # pace the swap to promote inside the post window
    hs.chunks_per_step = max(1, -(-n_chunks // max(steps_post - 2, 1)))
    loop_steps = 0
    while sched_o.swap_in_flight:
        sched_o.step()
        loop_steps += 1
    _run_steps(sched_o, max(steps_post - loop_steps, 0))
    rep = sched_o.swap_history[0]
    # the scheduler's own count is authoritative: the loop's final step()
    # promotes BEFORE its decode, so that decode is post-flip
    steps_during = rep["decode_steps_during_swap"]
    wall_swap_overlap = rep["wall_swap_s"]

    # -- stop-the-world: serving stalls for the blocking reprogram + the
    # decode re-trace (planes are trace constants), then resumes ----------
    model_s, sched_s = _fresh_scheduler(params_a)
    _run_steps(sched_s, steps_pre)
    t0 = time.perf_counter()
    sched_s.stop_the_world_swap(params_b)
    wall_swap_stw = time.perf_counter() - t0
    wall_first_tok_stw = wall_swap_stw + _run_steps(sched_s, 1)
    _run_steps(sched_s, steps_post - 1)
    assert (model_s.executor.fingerprint()
            == model_o.executor.fingerprint()), \
        "both policies must land on the same resident planes"

    # wall-clock throughput during the swap window: overlapped serves
    # n_slots tokens per step through the window; stop-the-world delivers
    # its first post-swap batch only after the blocking reprogram
    toks_overlap = steps_during * sched_o.n_slots
    wall_thr_overlap = toks_overlap / max(wall_swap_overlap, 1e-9)
    wall_thr_stw = sched_s.n_slots / max(wall_first_tok_stw, 1e-9)

    out = {
        "us_per_call": wall_swap_overlap * 1e6,
        "n_chunks": n_chunks,
        "steps_pre": steps_pre,
        "steps_post": steps_post,
        "decode_steps_during_swap": steps_during,
        "wall_swap_overlapped_s": wall_swap_overlap,
        "wall_swap_stop_world_s": wall_swap_stw,
        "wall_tok_s_during_swap_overlapped": wall_thr_overlap,
        "wall_tok_s_during_swap_stop_world": wall_thr_stw,
        "programmed_version": model_o.executor.programmed_version,
    }
    # device-time acceptance metrics (Table-I model; deterministic)
    out.update({k: rep[k] for k in (
        "device_decode_step_s", "device_write_total_s",
        "device_swap_window_overlapped_s", "device_swap_window_stop_world_s",
        "tok_per_device_s_overlapped_during_swap",
        "tok_per_device_s_stop_world_during_swap",
        "throughput_ratio_overlap_vs_stop_world", "sustains_2x_during_swap",
        "overlap_frac_steady_state", "overlap_frac_this_swap",
        "paper_overlap_frac", "within_2pts_of_paper")})
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_hotswap_smoke.json")
    args = ap.parse_args(argv)
    res = bench_hotswap(quick=True)
    print("name,us_per_call,derived")
    derived = {k: v for k, v in res.items() if k != "us_per_call"}
    print(f"hotswap_overlap,{res['us_per_call']:.1f},"
          f"{json.dumps(derived, default=float)}")
    from benchmarks.meta import append_trajectory, write_stamped
    results = {"hotswap_overlap": res}
    meta = write_stamped(results, args.json, lane="hotswap-smoke")
    append_trajectory(meta, results)
    print(f"# wrote {args.json} (sha={meta['git_sha'][:12]})")
    ok = (res["sustains_2x_during_swap"] and res["within_2pts_of_paper"])
    print(f"# acceptance: throughput ratio "
          f"{res['throughput_ratio_overlap_vs_stop_world']:.2f}x (>=2x: "
          f"{res['sustains_2x_during_swap']}), steady overlap "
          f"{res['overlap_frac_steady_state'] * 100:.1f}% vs paper 29% "
          f"(within 2pts: {res['within_2pts_of_paper']})")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
