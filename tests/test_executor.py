"""Weight-resident crossbar execution layer: program-once semantics, the
digital/crossbar backend switch, the scan-based reference path, and the
scheduler serving through resident tiles."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import engine as eng
from repro.core.engine import EngineConfig, ProgrammedLinear
from repro.core.executor import CrossbarExecutor, crossbar_linear, scope
from repro.core.quant import QuantConfig
from repro.models.model import build_model
from repro.serve.engine import BatchScheduler, Request


HIFI = EngineConfig(tile_rows=128, tile_cols=128, mode="deepnet",
                    quant=QuantConfig(w_bits=8, in_bits=10, adc_bits=14))


def _crossbar_cfg(smoke_cfg):
    return dataclasses.replace(smoke_cfg, backend="crossbar", xbar=HIFI,
                               dtype=jnp.float32)


# -- scan-based reference path -----------------------------------------------

@pytest.mark.parametrize("mode", ["expansion", "deepnet"])
@pytest.mark.parametrize("k,n,tile_rows,bpc", [
    (96, 80, 32, 1), (128, 33, 32, 1), (64, 48, 16, 2)])
def test_scan_reference_bit_identical_to_einsum(mode, k, n, tile_rows, bpc):
    qc = QuantConfig(w_bits=4, in_bits=8, adc_bits=10, bits_per_cell=bpc)
    cfg = EngineConfig(tile_rows=tile_rows, tile_cols=32, mode=mode,
                       quant=qc)
    w = jax.random.normal(jax.random.PRNGKey(k + n), (k, n)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(n), (7, k))
    pw = eng.program(w, cfg)
    y_scan = eng.matmul_reference(x, pw, cfg)
    y_einsum = eng._matmul_reference_einsum(x, pw, cfg)
    assert jnp.array_equal(y_scan, y_einsum)


# -- ProgrammedLinear pytree round-trip ---------------------------------------

def test_programmed_linear_pytree_round_trip():
    cfg = EngineConfig(tile_rows=32, tile_cols=32)
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 48)) * 0.3
    pw = eng.program(w, cfg)
    leaves, treedef = jax.tree_util.tree_flatten(pw)
    pw2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(pw2, ProgrammedLinear)
    assert (pw2.k, pw2.n) == (pw.k, pw.n)
    assert jnp.array_equal(pw2.pos, pw.pos)
    assert jnp.array_equal(pw2.neg, pw.neg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    assert jnp.array_equal(eng.matmul(x, pw, cfg), eng.matmul(x, pw2, cfg))
    # and through jit, where the registered pytree is what gets traced
    y_jit = jax.jit(lambda p: eng.matmul(x, p, cfg))(pw)
    assert jnp.allclose(y_jit, eng.matmul(x, pw, cfg), atol=1e-6)


# -- program-once semantics ----------------------------------------------------

def test_executor_programs_each_weight_exactly_once():
    cfg = get_config("qwen3_4b", smoke=True)
    model = build_model(_crossbar_cfg(cfg))
    params = model.init(jax.random.PRNGKey(0))
    ex = model.executor
    n_first = ex.program_params(params)
    # 7 linears per block (wq wk wv wo wi wg wo) x n_layers + head
    assert n_first == 7 * cfg.n_layers + 1
    assert ex.stats["programmed"] == n_first
    assert ex.stats["cache_hits"] == 0
    # second walk: all cache hits, nothing re-programmed
    n_second = ex.program_params(params)
    assert n_second == 0
    assert ex.stats["programmed"] == n_first
    assert ex.stats["cache_hits"] == n_first
    # inference afterwards leaves the program counters untouched
    cache = model.init_cache(1, 16)
    toks = jnp.zeros((1, 4), jnp.int32)
    model.prefill(params, {"tokens": toks}, cache)
    assert ex.stats["programmed"] == n_first


def test_executor_rejects_serving_a_different_params_tree():
    """Resident tiles are physical state: a second checkpoint must not be
    silently served through tiles programmed from the first."""
    cfg = _crossbar_cfg(get_config("qwen3_4b", smoke=True))
    model = build_model(cfg)
    params_v1 = model.init(jax.random.PRNGKey(0))
    params_v2 = model.init(jax.random.PRNGKey(1))
    model.executor.program_params(params_v1)
    toks = jnp.zeros((1, 4), jnp.int32)
    with pytest.raises(RuntimeError, match="different params tree"):
        model.prefill(params_v2, {"tokens": toks}, model.init_cache(1, 16))
    # the programmed tree still serves fine
    model.prefill(params_v1, {"tokens": toks}, model.init_cache(1, 16))


def test_executor_rejects_tracers_before_programming():
    model = build_model(_crossbar_cfg(get_config("qwen3_4b", smoke=True)))
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.zeros((1, 1), jnp.int32)
    cache = model.init_cache(1, 8)
    with pytest.raises(RuntimeError, match="program"):
        jax.jit(model.decode_step)(params, toks, cache)
    # after eager programming the same jit traces fine
    model.executor.program_params(params)
    logits, _ = jax.jit(model.decode_step)(params, toks, cache)
    assert logits.shape[-1] == model.cfg.padded_vocab


# -- backend switch: crossbar forward vs digital -------------------------------

def test_crossbar_forward_matches_digital_within_quant_tolerance():
    base = dataclasses.replace(get_config("qwen3_4b", smoke=True),
                               dtype=jnp.float32)
    md = build_model(base)
    mc = build_model(_crossbar_cfg(base))
    params = md.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              base.vocab - 1).astype(jnp.int32)
    ld, _ = md.prefill(params, {"tokens": toks}, md.init_cache(2, 16))
    lx, _ = mc.prefill(params, {"tokens": toks}, mc.init_cache(2, 16))
    assert lx.shape == ld.shape
    rel = float(jnp.abs(lx - ld).max() / jnp.abs(ld).max())
    assert rel < 0.05, f"crossbar deviates {rel:.3f} from digital"


def test_crossbar_linear_routes_only_inside_active_scope():
    ex = CrossbarExecutor(EngineConfig(
        tile_rows=32, tile_cols=32,
        quant=QuantConfig(w_bits=8, in_bits=10, adc_bits=14)))
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 32)) * 0.3
    ex.program_params({"head": w})
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    digital = x @ w
    # no active executor -> the digital thunk runs
    assert jnp.array_equal(crossbar_linear(x, w, "head",
                                           digital=lambda: x @ w), digital)
    with ex.activate():
        y = crossbar_linear(x, w, "head", digital=lambda: x @ w)
        # resident-tile read: quantized, so close-but-not-equal to digital
        assert not jnp.array_equal(y, digital)
        assert jnp.allclose(y, digital, rtol=0.2, atol=0.2)
        # unknown names fall back to digital even while active
        with scope("blocks"):
            z = crossbar_linear(x, w, "nonexistent",
                                digital=lambda: x @ w)
        assert jnp.array_equal(z, digital)


def test_crossbar_backend_rejected_for_non_transformer_families():
    cfg = dataclasses.replace(get_config("rwkv6_3b", smoke=True),
                              backend="crossbar")
    with pytest.raises(ValueError, match="transformer"):
        build_model(cfg)


# -- end to end: BatchScheduler over resident tiles ----------------------------

def test_scheduler_serves_through_crossbar_path():
    cfg = _crossbar_cfg(get_config("qwen3_4b", smoke=True))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = BatchScheduler(model, params, n_slots=2, max_len=32)
    ex = model.executor
    n_programmed = ex.stats["programmed"]
    assert n_programmed == 7 * cfg.n_layers + 1  # programmed at init
    for rid in range(3):
        p = jax.random.randint(jax.random.PRNGKey(rid), (6,), 0,
                               cfg.vocab - 1).astype(jnp.int32)
        sched.submit(Request(rid=rid, prompt=p, max_new=4))
    done, steps = [], 0
    while len(done) < 3 and steps < 100:
        done += sched.step()
        steps += 1
    assert len(done) == 3
    assert all(len(r.out) >= 4 for r in done)
    # serving re-programmed NOTHING: weights stayed resident throughout
    assert ex.stats["programmed"] == n_programmed
