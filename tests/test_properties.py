"""Hypothesis property-based tests on the system's invariants."""
import jax
import jax.numpy as jnp
import pytest

# hypothesis ships in the [test] extra (pip install -e .[test]); skip the
# whole module instead of erroring collection when it's absent
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import engine as eng
from repro.core import pipeline as pipe
from repro.core import quant
from repro.core.quant import QuantConfig
from repro.core.timing import CrossStackParams
from repro.models.lin_attn import chunked_gla, naive_gla

SET = settings(max_examples=20, deadline=None)


class TestQuantProperties:
    @SET
    @given(st.integers(2, 8), st.integers(1, 2), st.integers(0, 2**31 - 1))
    def test_slices_reconstruct_weights(self, w_bits, bpc, seed):
        """Differential bit slices must reconstruct the signed ints."""
        cfg = QuantConfig(w_bits=w_bits, bits_per_cell=bpc)
        key = jax.random.PRNGKey(seed)
        qmax = 2 ** w_bits - 1
        w_int = jax.random.randint(key, (9, 7), -qmax, qmax + 1)
        pos, neg = quant.to_slices(w_int.astype(jnp.float32), cfg)
        base = 2 ** bpc
        weights = jnp.asarray([base ** s for s in range(cfg.n_slices)])
        recon = (jnp.einsum("skn,s->kn", pos.astype(jnp.int32), weights)
                 - jnp.einsum("skn,s->kn", neg.astype(jnp.int32), weights))
        assert jnp.array_equal(recon, w_int)

    @SET
    @given(st.integers(2, 8), st.integers(0, 2**31 - 1))
    def test_bit_serial_reconstructs_inputs(self, in_bits, seed):
        cfg = QuantConfig(in_bits=in_bits)
        key = jax.random.PRNGKey(seed)
        lo, hi = -(2 ** (in_bits - 1)), 2 ** (in_bits - 1)
        x_int = jax.random.randint(key, (5, 11), lo, hi)
        bits = quant.to_bit_serial(x_int, cfg)
        bw = quant.bit_weights(cfg)
        recon = jnp.einsum("bij,b->ij", bits, bw)
        assert jnp.array_equal(recon.astype(jnp.int32), x_int)

    @SET
    @given(st.integers(2, 8), st.integers(0, 2**31 - 1))
    def test_weight_quantization_error_bound(self, w_bits, seed):
        cfg = QuantConfig(w_bits=w_bits)
        key = jax.random.PRNGKey(seed)
        w = jax.random.normal(key, (16, 8))
        w_int, scale = quant.quantize_weights(w, cfg)
        err = jnp.abs(w_int * scale - w)
        assert float(err.max()) <= float(scale.max()) * 0.5 + 1e-6

    @SET
    @given(st.integers(4, 12), st.floats(0.1, 100.0),
           st.integers(0, 2**31 - 1))
    def test_adc_is_bounded_and_monotone(self, adc_bits, fs, seed):
        cfg = QuantConfig(adc_bits=adc_bits)
        x = jnp.sort(jax.random.uniform(jax.random.PRNGKey(seed), (64,),
                                        minval=-fs, maxval=2 * fs))
        y = quant.adc(x, cfg, fs)
        assert float(y.min()) >= 0.0 and float(y.max()) <= fs + 1e-5
        assert bool(jnp.all(jnp.diff(y) >= -1e-6))  # monotone


class TestEngineProperties:
    @SET
    @given(st.integers(0, 2**31 - 1), st.sampled_from([16, 32]),
           st.sampled_from(["expansion", "deepnet"]))
    def test_error_shrinks_with_bits(self, seed, tile, mode):
        key = jax.random.PRNGKey(seed)
        w = jax.random.normal(key, (64, 48)) * 0.4
        x = jax.random.normal(jax.random.PRNGKey(seed + 1), (4, 64))
        ref = x @ w
        errs = []
        for bits in (2, 4, 8):
            cfg = eng.EngineConfig(tile_rows=tile, tile_cols=48, mode=mode,
                                   quant=QuantConfig(w_bits=bits, in_bits=8,
                                                     adc_bits=12))
            y = eng.linear(x, w, cfg)
            errs.append(float(jnp.abs(y - ref).max()))
        assert errs[2] <= errs[1] <= errs[0] * 1.5 + 1e-3

    @SET
    @given(st.integers(0, 2**31 - 1))
    def test_expansion_equals_deepnet_at_high_adc(self, seed):
        """With a saturating-free ADC the two modes compute the same MAC
        (they differ only in which rows share one analog conversion)."""
        key = jax.random.PRNGKey(seed)
        w = jax.random.normal(key, (64, 32)) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(seed + 9), (3, 64))
        outs = []
        for mode in ("expansion", "deepnet"):
            cfg = eng.EngineConfig(tile_rows=32, tile_cols=32, mode=mode,
                                   quant=QuantConfig(w_bits=6, in_bits=8,
                                                     adc_bits=16))
            outs.append(eng.linear(x, w, cfg))
        assert jnp.allclose(outs[0], outs[1], rtol=1e-3, atol=1e-3)


class TestScheduleProperties:
    @SET
    @given(st.integers(1, 40), st.integers(1, 64),
           st.floats(1.0, 500.0), st.floats(1.0, 500.0))
    def test_schedule_always_valid_and_no_slower(self, layers, bits,
                                                 t_read_ns, t_write_ns):
        p = CrossStackParams(t_read=t_read_ns * 1e-9,
                             t_write=t_write_ns * 1e-9)
        d = pipe.deepnet_schedule(layers, bits, p)
        d.validate()
        s = pipe.serial_schedule(layers, bits, p)
        assert d.total <= s.total + 1e-12
        # steady-state bound: never better than hiding the shorter phase
        bound = 1.0 - max(p.t_write, bits * p.t_read) / (
            p.t_write + bits * p.t_read)
        assert 1.0 - d.total / s.total <= bound + 1e-9


class TestGLAProperties:
    @SET
    @given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8, 16]),
           st.booleans())
    def test_chunked_matches_naive(self, seed, chunk, use_u):
        key = jax.random.PRNGKey(seed)
        ks = jax.random.split(key, 5)
        B, S, H, dk, dv = 2, 24, 2, 4, 8
        q = jax.random.normal(ks[0], (B, S, H, dk)) * 0.5
        k = jax.random.normal(ks[1], (B, S, H, dk)) * 0.5
        v = jax.random.normal(ks[2], (B, S, H, dv)) * 0.5
        log_w = -jnp.exp(jax.random.normal(ks[3], (B, S, H, dk)))
        u = (jax.random.normal(ks[4], (H, dk)) * 0.3) if use_u else None
        y_ref, st_ref = naive_gla(q, k, v, log_w, u)
        y, st_out = chunked_gla(q, k, v, log_w, u, chunk=chunk)
        assert jnp.allclose(y, y_ref, atol=1e-4), "outputs diverge"
        assert jnp.allclose(st_out, st_ref, atol=1e-4), "state diverges"

    @SET
    @given(st.integers(0, 2**31 - 1))
    def test_state_passing_composes(self, seed):
        """gla(x[:S1]) then gla(x[S1:], state0) == gla(x) — the contract
        that makes chunked prefill + decode correct."""
        key = jax.random.PRNGKey(seed)
        ks = jax.random.split(key, 4)
        B, S, H, dk, dv = 1, 16, 2, 4, 4
        q = jax.random.normal(ks[0], (B, S, H, dk)) * 0.5
        k = jax.random.normal(ks[1], (B, S, H, dk)) * 0.5
        v = jax.random.normal(ks[2], (B, S, H, dv)) * 0.5
        log_w = -jnp.exp(jax.random.normal(ks[3], (B, S, H, dk)))
        y_full, st_full = chunked_gla(q, k, v, log_w, None, chunk=4)
        y1, st1 = chunked_gla(q[:, :8], k[:, :8], v[:, :8], log_w[:, :8],
                              None, chunk=4)
        y2, st2 = chunked_gla(q[:, 8:], k[:, 8:], v[:, 8:], log_w[:, 8:],
                              None, chunk=4, state0=st1)
        assert jnp.allclose(jnp.concatenate([y1, y2], 1), y_full, atol=1e-4)
        assert jnp.allclose(st2, st_full, atol=1e-4)
