"""Paged continuous-batching scheduler: end-to-end acceptance tests.

The invariants this file pins are the PR's exit criteria:

  * paged decode is bit-exact with the dense-cache path at the
    scheduler level (same token streams, same request mix),
  * a ragged stream of random-length prompts admitted continuously is
    token-bit-exact vs the unpadded per-request greedy reference,
  * admissions NEVER stall an in-flight decode step,
  * one compiled closure per tenant with a zero retrace delta across a
    prompt-length mix spanning >= 4 of the old padded buckets,
  * page-pool backpressure queues (never drops) and conserves pages,
  * low-precision KV caches (bf16 / fp8) stay within decode parity of
    the fp32 cache on BOTH the dense and paged paths.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.configs import get_config
from repro.models.model import build_model
from repro.serve.engine import BatchScheduler, Request, greedy_generate

# hypothesis drives the ragged-admission property when available; the
# parametrized fallback below keeps the property pinned without it
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _model(**overrides):
    cfg = get_config("qwen3_4b", smoke=True)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def _prompts(cfg, plens, seed0=100):
    out = []
    for i, plen in enumerate(plens):
        k = jax.random.PRNGKey(seed0 + i)
        out.append(jax.random.randint(k, (plen,), 0,
                                      cfg.vocab - 1).astype(jnp.int32))
    return out


def _serve(sched, prompts, max_new, trickle=0):
    """Run a prompt list through a scheduler; ``trickle`` submits one
    request every N steps instead of all up front (continuous
    admission).  Returns {rid: out}."""
    pending = list(enumerate(prompts))
    if not trickle:
        for rid, p in pending:
            sched.submit(Request(rid=rid, prompt=p, max_new=max_new))
        pending = []
    done, steps = {}, 0
    while (len(done) < len(prompts)) and steps < 500:
        if pending and steps % max(trickle, 1) == 0:
            rid, p = pending.pop(0)
            sched.submit(Request(rid=rid, prompt=p, max_new=max_new))
        for r in sched.step():
            done[r.rid] = r.out
        steps += 1
    assert len(done) == len(prompts), f"stalled at {len(done)}"
    return done


# -- paged vs dense bit-exactness ---------------------------------------------

def test_paged_decode_bit_exact_with_dense_path_end_to_end():
    """The scheduler-level half of the acceptance gate (the kernel-level
    half lives in test_paged_attention.py): identical token streams
    from the paged pool and the dense per-slot cache."""
    cfg, m, params = _model()
    plens = (1, 3, 5, 9, 14, 23, 2, 30)
    prompts = _prompts(cfg, plens)
    streams = {}
    for kv in ("paged", "dense"):
        sched = BatchScheduler(m, params, n_slots=3, max_len=32, kv=kv,
                               page_size=8)
        streams[kv] = _serve(sched, prompts, max_new=5)
    assert streams["paged"] == streams["dense"]


def test_paged_kernel_serving_matches_gather_path():
    """cfg.paged_kernel=True routes lane attention through the Pallas
    kernel (interpret mode on CPU) — streams must not change."""
    cfg, m, params = _model()
    prompts = _prompts(cfg, (4, 11, 7))
    base = _serve(BatchScheduler(m, params, n_slots=3, max_len=32),
                  prompts, max_new=4)
    cfg_k, mk, params_k = _model(paged_kernel=True)
    kern = _serve(BatchScheduler(mk, params_k, n_slots=3, max_len=32),
                  prompts, max_new=4)
    assert base == kern


# -- ragged continuous admission vs unpadded reference ------------------------

def _assert_ragged_stream_exact(plens, max_new, trickle):
    obs.reset()
    cfg, m, params = _model()
    prompts = _prompts(cfg, plens, seed0=300)
    refs = {i: [int(t) for t in greedy_generate(
        m, params, {"tokens": p[None]}, max_new=max_new, max_len=64)[0]]
        for i, p in enumerate(prompts)}
    sched = BatchScheduler(m, params, n_slots=3, max_len=64)
    done = _serve(sched, prompts, max_new, trickle=trickle)
    assert done == refs
    reg = obs.registry()
    assert reg.total("serve_jit_traces_total",
                     closure="decode", tenant="A") == 1
    assert reg.total("serve_jit_retraces_total") == 0


# the fallback sweep: fixed draws from the same distribution the
# hypothesis path samples (prompt lengths spanning >= 4 of the old
# padded buckets: 8, 16, 32, 64)
@pytest.mark.parametrize("plens,max_new,trickle", [
    ((5, 13, 27, 50, 2), 4, 0),
    ((1, 8, 9, 33, 17, 60), 3, 2),
    ((62, 3, 31, 15, 7), 2, 1),
])
def test_ragged_admission_bit_exact_vs_unpadded_reference(
        plens, max_new, trickle):
    """Random-length prompts admitted continuously produce streams
    token-bit-exact vs the unpadded greedy reference, through ONE
    compiled closure with a zero retrace delta after warmup."""
    _assert_ragged_stream_exact(plens, max_new, trickle)


if HAVE_HYPOTHESIS:
    @pytest.mark.slow
    @settings(max_examples=8, deadline=None)
    @given(plens=st.lists(st.integers(min_value=1, max_value=63),
                          min_size=1, max_size=6),
           max_new=st.integers(min_value=1, max_value=4),
           trickle=st.integers(min_value=0, max_value=3))
    def test_ragged_admission_property(plens, max_new, trickle):
        _assert_ragged_stream_exact(tuple(plens), max_new, trickle)


# -- admissions never stall decode --------------------------------------------

def test_admission_never_stalls_in_flight_decode():
    """While a long prompt chunk-prefills, an already-decoding request
    must emit exactly one token on EVERY step — no admission pause, no
    skipped decode step (the old bucket prefill ran a separate batched
    call that stalled the decode batch)."""
    cfg, m, params = _model()
    short, long_ = _prompts(cfg, (4, 60), seed0=400)
    sched = BatchScheduler(m, params, n_slots=2, max_len=64, chunk=4)
    sched.submit(Request(rid=0, prompt=short, max_new=30))
    sched.step()                          # rid 0 emits its first token
    req0 = sched._lanes["A"].slots[0]
    assert req0 is not None and len(req0.out) == 1
    sched.submit(Request(rid=1, prompt=long_, max_new=2))
    # rid 1 needs ceil(60/4) = 15 steps of chunked prefill; rid 0 must
    # gain exactly one token on every single one of them
    for step in range(15):
        before = len(req0.out)
        sched.step()
        assert len(req0.out) == before + 1, f"decode stalled at {step}"
    # and rid 1's stream is still the unpadded reference
    ref = [int(t) for t in greedy_generate(
        m, params, {"tokens": long_[None]}, max_new=2, max_len=64)[0]]
    done = {}
    steps = 0
    while 1 not in done and steps < 50:
        for r in sched.step():
            done[r.rid] = r.out
        steps += 1
    assert done[1] == ref


# -- page-pool backpressure ---------------------------------------------------

def test_page_backpressure_queues_without_dropping():
    """A pool too small for the whole queue admits what fits, holds the
    rest in FIFO, and completes everything — zero drops, conservation
    intact at every step."""
    cfg, m, params = _model()
    prompts = _prompts(cfg, (20, 20, 20, 20), seed0=500)
    # 4 slots but only enough pages for ~2 resident full-lifetime seqs
    sched = BatchScheduler(m, params, n_slots=4, max_len=32, page_size=8,
                           kv_pages=8)
    for rid, p in enumerate(prompts):
        sched.submit(Request(rid=rid, prompt=p, max_new=10))
    pool = sched._lanes["A"].pool
    done, steps = [], 0
    max_resident = 0
    while len(done) < 4 and steps < 300:
        done += sched.step()
        assert pool.conservation_ok()
        max_resident = max(max_resident,
                           sum(s is not None
                               for s in sched._lanes["A"].slots))
        steps += 1
    assert len(done) == 4                  # nothing dropped
    assert max_resident == 2               # the budget really gated
    assert pool.pages_in_use == 0          # all reclaimed
    # streams unaffected by having waited
    refs = {i: [int(t) for t in greedy_generate(
        m, params, {"tokens": p[None]}, max_new=10, max_len=32)[0]]
        for i, p in enumerate(prompts)}
    assert {r.rid: r.out for r in done} == refs


def test_prompt_longer_than_max_len_still_rejected():
    cfg, m, params = _model()
    sched = BatchScheduler(m, params, n_slots=2, max_len=16, page_size=8)
    p = _prompts(cfg, (17,))[0]
    sched.submit(Request(rid=0, prompt=p, max_new=2))
    with pytest.raises(ValueError, match="exceeds"):
        sched.step()


# -- KV cache dtypes (dense AND paged) ----------------------------------------

_KV_DTYPES = [jnp.bfloat16]
if hasattr(jnp, "float8_e4m3fn"):
    _KV_DTYPES.append(jnp.float8_e4m3fn)


@pytest.mark.parametrize("kv", ["dense", "paged"])
@pytest.mark.parametrize("kv_dtype", _KV_DTYPES,
                         ids=lambda d: jnp.dtype(d).name)
def test_low_precision_kv_cache_decode_parity(kv, kv_dtype):
    """bf16/fp8 cache storage (the in-dot upcast branch in
    models/layers._sdpa) must track the fp32 cache's streams closely on
    both storage layouts: same argmax token on >= 90 % of steps, and
    IDENTICAL streams between dense and paged at equal dtype (the
    storage layout itself adds no error)."""
    prompts_lens = (6, 13, 25)
    max_new = 8
    cfg32, m32, params = _model(kv_dtype=jnp.float32)
    prompts = _prompts(cfg32, prompts_lens, seed0=600)
    ref = _serve(BatchScheduler(m32, params, n_slots=3, max_len=32, kv=kv),
                 prompts, max_new)
    cfg_lo, m_lo, _ = _model(kv_dtype=kv_dtype)
    low = _serve(BatchScheduler(m_lo, params, n_slots=3, max_len=32, kv=kv),
                 prompts, max_new)
    agree = np.mean([int(a == b)
                     for rid in ref
                     for a, b in zip(ref[rid], low[rid])])
    assert agree >= 0.9, f"{jnp.dtype(kv_dtype).name} cache diverged: " \
                         f"{agree:.2f} token agreement vs fp32 cache"


@pytest.mark.parametrize("kv_dtype", _KV_DTYPES + [jnp.float32],
                         ids=lambda d: jnp.dtype(d).name)
def test_cache_dtype_streams_identical_across_storage_layouts(kv_dtype):
    """At EQUAL cache dtype the paged pool and the dense cache hold the
    same numbers, so the streams must be bit-identical — including the
    fp8 upcast branch, which was previously untested."""
    cfg, m, params = _model(kv_dtype=kv_dtype)
    prompts = _prompts(cfg, (6, 13, 25), seed0=600)
    dense = _serve(BatchScheduler(m, params, n_slots=3, max_len=32,
                                  kv="dense"), prompts, max_new=8)
    paged = _serve(BatchScheduler(m, params, n_slots=3, max_len=32,
                                  kv="paged"), prompts, max_new=8)
    assert dense == paged


# -- constructor validation ---------------------------------------------------

def test_constructor_validation():
    cfg, m, params = _model()
    with pytest.raises(ValueError, match="kv must be"):
        BatchScheduler(m, params, n_slots=2, max_len=32, kv="sparse")
    with pytest.raises(ValueError, match="divide"):
        BatchScheduler(m, params, n_slots=2, max_len=30, page_size=8)
    with pytest.raises(ValueError, match="chunk"):
        BatchScheduler(m, params, n_slots=2, max_len=32, chunk=0)
