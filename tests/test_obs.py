"""Observability layer: metrics registry + tracer unit behaviour, and
the serving/executor integration invariants the telemetry smoke gates
on — span decomposition, retrace counters across hot-swap windows, and
device-energy accounting parity with ``core/timing.py``."""
import json

import jax
import jax.numpy as jnp
import pytest

from repro import obs
from repro.core import engine, timing
from repro.core.engine import EngineConfig
from repro.core.executor import CrossbarExecutor
from repro.core.quant import QuantConfig
from repro.models.model import ModelConfig, build_model
from repro.obs import MetricsRegistry, Tracer, parse_prometheus
from repro.serve.engine import BatchScheduler, Request
from repro.serve.hotswap import finetune_delta

TINY = ModelConfig(
    name="tiny", family="dense", n_layers=2, d_model=32, n_heads=2,
    n_kv=2, head_dim=16, d_ff=64, vocab=128, backend="crossbar",
    dtype=jnp.float32,
    xbar=EngineConfig(tile_rows=32, tile_cols=32, mode="deepnet",
                      quant=QuantConfig(w_bits=4, in_bits=6, adc_bits=12)))

DIGITAL = ModelConfig(
    name="tiny-digital", family="dense", n_layers=2, d_model=32,
    n_heads=2, n_kv=2, head_dim=16, d_ff=64, vocab=128,
    dtype=jnp.float32)


def _submit(sched, model_id, n_req, max_new=4, seed0=0):
    for i in range(n_req):
        p = jax.random.randint(jax.random.PRNGKey(seed0 + i), (5,), 0,
                               TINY.vocab - 1).astype(jnp.int32)
        sched.submit(Request(rid=seed0 + i, prompt=p, max_new=max_new,
                             model_id=model_id))


def _drain(sched, n_req, max_steps=200):
    done, steps = [], 0
    while len(done) < n_req and steps < max_steps:
        done += sched.step()
        steps += 1
    return done


# -- registry unit behaviour --------------------------------------------------

def test_histogram_bucket_edges():
    """Prometheus bucket semantics: an observation lands in every bucket
    with value <= le, and +Inf equals the total count."""
    reg = MetricsRegistry()
    h = reg.histogram("lat", buckets=(1.0, 2.0, 5.0))
    for v in (0.5, 1.0, 2.0, 5.0001):
        h.observe(v)
    assert h.bucket_counts() == {"1.0": 2, "2.0": 3, "5.0": 3, "+Inf": 4}
    assert h.get_count() == 4
    assert h.get_sum() == pytest.approx(8.5001)
    # layout is part of the metric identity
    with pytest.raises(ValueError, match="buckets"):
        reg.histogram("lat", buckets=(1.0, 2.0))
    with pytest.raises(ValueError, match="increasing"):
        reg.histogram("bad", buckets=(2.0, 1.0))


def test_counter_labels_and_total_filtering():
    reg = MetricsRegistry()
    c = reg.counter("reads")
    c.inc(2.0, tenant="A", mode="deepnet")
    c.inc(3.0, tenant="A", mode="expansion")
    c.inc(5.0, tenant="B", mode="deepnet")
    assert reg.get("reads", tenant="A", mode="deepnet") == 2.0
    assert reg.total("reads", tenant="A") == 5.0
    assert reg.total("reads", mode="deepnet") == 7.0
    assert reg.total("reads") == 10.0
    assert reg.total("no_such_metric") == 0.0
    with pytest.raises(ValueError, match="monotone"):
        c.inc(-1.0)
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("reads")


def test_disabled_registry_and_tracer_are_noops():
    reg = MetricsRegistry(enabled=False)
    reg.counter("c").inc(5.0)
    reg.gauge("g").set(1.0)
    reg.histogram("h").observe(0.1)
    assert reg.total("c") == 0.0
    assert reg.get("g") == 0.0
    assert reg.histogram("h").get_count() == 0
    tr = Tracer(enabled=False)
    assert tr.record("x", 0.0, 1.0) is None
    assert len(tr.spans()) == 0
    assert isinstance(tr.now(), float)   # clock stays usable


def test_prometheus_round_trip():
    reg = MetricsRegistry()
    reg.counter("hits", help="hit count").inc(3.0, path='k"er\\nel')
    reg.gauge("depth").set(2.5)
    h = reg.histogram("lat", buckets=(0.1, 1.0))
    h.observe(0.05, tenant="A")
    h.observe(0.5, tenant="A")
    samples = parse_prometheus(reg.to_prometheus())
    by = {(s["name"], tuple(sorted(s["labels"].items()))): s["value"]
          for s in samples}
    assert by[("hits", (("path", 'k"er\\nel'),))] == 3.0
    assert by[("depth", ())] == 2.5
    assert by[("lat_bucket", (("le", "0.1"), ("tenant", "A")))] == 1.0
    assert by[("lat_bucket", (("le", "+Inf"), ("tenant", "A")))] == 2.0
    assert by[("lat_count", (("tenant", "A"),))] == 2.0
    assert by[("lat_sum", (("tenant", "A"),))] == pytest.approx(0.55)
    with pytest.raises(ValueError):
        parse_prometheus("this is { not a metric line")


def test_jsonl_export_round_trip():
    reg = MetricsRegistry()
    reg.counter("c").inc(2.0, tenant="A")
    reg.histogram("h", buckets=(1.0,)).observe(0.5)
    tr = Tracer()
    tr.record("request", 1.0, 3.5, rid=7, tenant="A")
    docs = [json.loads(line) for line in
            (reg.to_jsonl() + tr.to_jsonl()).splitlines()]
    kinds = {d["kind"] for d in docs}
    assert kinds == {"metric", "span"}
    span = next(d for d in docs if d["kind"] == "span")
    assert span["span"] == "request"
    assert span["duration_s"] == pytest.approx(2.5)
    assert span["attr_rid"] == 7
    metric = next(d for d in docs if d.get("metric") == "c")
    assert metric["value"] == 2.0
    assert metric["labels"] == {"tenant": "A"}


def test_tracer_span_filters():
    tr = Tracer()
    tr.record("decode", 0.0, 1.0, rid=1, tenant="A")
    tr.record("decode", 0.0, 2.0, rid=2, tenant="B")
    tr.record("request", 0.0, 3.0, rid=1, tenant="A")
    assert len(tr.spans("decode")) == 2
    assert len(tr.spans(tenant="A")) == 2
    assert tr.spans("decode", rid=2)[0].duration == pytest.approx(2.0)
    tr.clear()
    assert len(tr) == 0


# -- scheduler integration ----------------------------------------------------

def test_request_span_decomposition_sums_to_wall_time():
    """queue_wait + prefill + decode telescope exactly to the request
    span, and the TTFT attribute is submit-to-first-token."""
    model = build_model(DIGITAL)
    sched = BatchScheduler(model, model.init(jax.random.PRNGKey(0)),
                           n_slots=2, max_len=24)
    _submit(sched, "A", 3, max_new=4)
    done = _drain(sched, 3)
    assert len(done) == 3
    for r in done:
        parts = {name: sched.tracer.spans(name, rid=r.rid)
                 for name in ("queue_wait", "prefill", "decode",
                              "request")}
        assert all(len(v) == 1 for v in parts.values())
        req = parts["request"][0]
        decomp = sum(parts[n][0].duration
                     for n in ("queue_wait", "prefill", "decode"))
        assert decomp == pytest.approx(req.duration, abs=1e-9)
        assert req.attrs["ttft_s"] == pytest.approx(
            parts["queue_wait"][0].duration
            + parts["prefill"][0].duration, abs=1e-9)
        assert req.attrs["n_tokens"] == len(r.out)
    # the registry agrees with the tracer
    m = sched.metrics
    assert m.total("serve_requests_submitted_total") == 3
    assert m.total("serve_requests_completed_total") == 3
    assert m.histogram("serve_ttft_seconds").get_count(tenant="A") == 3


def test_telemetry_off_scheduler_still_serves():
    model = build_model(DIGITAL)
    sched = BatchScheduler(model, model.init(jax.random.PRNGKey(0)),
                           n_slots=2, max_len=24, telemetry=False)
    _submit(sched, "A", 2, max_new=3)
    done = _drain(sched, 2)
    assert len(done) == 2
    assert len(sched.tracer.spans()) == 0
    assert sched.metrics.total("serve_tokens_total") == 0.0
    # lane accounting stays authoritative with metrics off
    assert sched.qos_report()["A"]["tokens_served"] >= 6


def test_retrace_counter_zero_across_hot_swap_window():
    """The runtime form of the no-retrace invariant: a tenant-B swap
    under traffic must not bump serve_jit_retraces_total."""
    model = build_model(TINY)
    params_a = model.init(jax.random.PRNGKey(0))
    params_b = finetune_delta(params_a, scale=0.05, seed=7)
    sched = BatchScheduler(model, params_a, n_slots=2, max_len=24,
                           tenants={"A": params_a, "B": params_b})
    _submit(sched, "A", 2, max_new=8, seed0=0)
    _submit(sched, "B", 1, max_new=3, seed0=200)
    done = []
    for _ in range(2):
        done += sched.step()
    reg = obs.registry()
    before = reg.total("serve_jit_retraces_total")
    sched.begin_hot_swap(finetune_delta(params_a, scale=0.08, seed=23),
                         chunks_per_step=6, tenant="B")
    steps = 0
    while (sched.swap_in_flight or len(done) < 3) and steps < 200:
        done += sched.step()
        steps += 1
    assert len(done) == 3
    assert reg.total("serve_jit_retraces_total") == before
    # the window itself was recorded
    assert sched.metrics.total("serve_swap_windows_total",
                               tenant="B", policy="overlapped") == 1
    assert len(sched.tracer.spans("swap_window", tenant="B")) == 1


def test_retrace_counter_increments_on_forced_retrace():
    """Calling a decode closure at a new batch shape IS a re-trace, and
    the counter sees it — the signal the invariant gates on."""
    model = build_model(DIGITAL)
    params = model.init(jax.random.PRNGKey(0))
    sched = BatchScheduler(model, params, n_slots=2, max_len=24)
    _submit(sched, "A", 2, max_new=3)
    _drain(sched, 2)
    reg = obs.registry()
    before = reg.total("serve_jit_retraces_total", closure="decode")
    lane = sched._lanes["A"]
    # batch-of-1 call against the width-traced window closure: new
    # shape, same built closure -> jit re-traces it
    lane.decode(lane.params, jnp.zeros((1, sched.chunk), jnp.int32),
                model.init_cache(1, 24), jnp.ones((1,), jnp.int32),
                jnp.float32(0.0))
    after = reg.total("serve_jit_retraces_total", closure="decode")
    assert after == before + 1


def test_device_energy_accounting_matches_timing_model():
    """device_token_cost is the Table-I model of core/timing.py, and the
    serving counters accumulate exactly cost x tokens served."""
    cfg = TINY.xbar
    q, p = cfg.quant, cfg.params
    w = jax.random.normal(jax.random.PRNGKey(1), (48, 40)) * 0.3
    ex = CrossbarExecutor(cfg)
    ex.program_params({"head": w})
    cost = ex.device_token_cost()
    assert list(cost) == ["deepnet"]
    s, t, r, n_pad = (int(d) for d in
                      ex._cache["head"].active_for("A").pos.shape)
    assert cost["deepnet"]["read_s"] == pytest.approx(
        timing.read_time(q.in_bits, p))
    assert cost["deepnet"]["energy_j"] == pytest.approx(
        q.in_bits * s * t * 2 * timing.mac_energy(r, n_pad, p=p))

    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    sched = BatchScheduler(model, params, n_slots=2, max_len=24)
    _submit(sched, "A", 2, max_new=4)
    _drain(sched, 2)
    tokens = sched._lanes["A"].tokens_served
    assert tokens > 0
    c = model.executor.device_token_cost("A")["deepnet"]
    assert sched.metrics.total("serve_device_energy_joules_total",
                               tenant="A", mode="deepnet"
                               ) == pytest.approx(c["energy_j"] * tokens,
                                                  rel=1e-9)
    assert sched.metrics.total("serve_device_read_seconds_total",
                               tenant="A", mode="deepnet"
                               ) == pytest.approx(c["read_s"] * tokens,
                                                  rel=1e-9)
    # mode_report's traffic block is the same registry view
    traffic = sched.mode_report()["traffic"]
    assert traffic["tokens_served"] == tokens
    assert traffic["modes"]["deepnet"]["pj_per_token"] == pytest.approx(
        c["energy_j"] * 1e12, rel=1e-9)


def test_mode_report_defaults_to_anchor_and_names_tenants_on_miss():
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    sched = BatchScheduler(model, params, n_slots=2, max_len=24)
    # no arg = the executor's anchor tenant (what sched.params serves)
    assert sched.mode_report() == sched.mode_report("A")
    with pytest.raises(KeyError, match=r"no lane for tenant 'Z'.*\['A'\]"):
        sched.mode_report("Z")


def test_path_calls_registry_view_is_dict_compatible():
    view = engine.path_calls
    assert set(dict(view)) == {"kernel", "reference"}
    assert view == dict(view)              # both comparison directions
    assert dict(view) == view
    with pytest.raises(KeyError):
        view["no_such_path"]
    before = view["reference"]
    cfg = EngineConfig(tile_rows=32, tile_cols=32, mode="deepnet",
                       quant=QuantConfig(w_bits=4, in_bits=6,
                                         adc_bits=12))
    w = jax.random.normal(jax.random.PRNGKey(2), (40, 24)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 40))
    engine.matmul_reference(x, engine.program(w, cfg), cfg)
    assert view["reference"] == before + 1
    # per-geometry labels ride the registry sample
    assert obs.registry().get("crossstack_dispatch_total",
                              path="reference", geometry="40x24") >= 1
