"""ir_solve Pallas kernel vs the jnp oracle and the exact nodal solver."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import ir_drop as ird
from repro.core.timing import PAPER
from repro.kernels.ir_solve.kernel import jacobi_sweeps
from repro.kernels.ir_solve.ops import solve
from repro.kernels.ir_solve.ref import jacobi_sweep_ref


# smallest point unmarked so the PR fast lane keeps an ir_solve assertion
@pytest.mark.parametrize("n,m,sweeps", [
    (8, 8, 1),
    pytest.param(8, 8, 4, marks=pytest.mark.slow),
    pytest.param(12, 6, 8, marks=pytest.mark.slow)])
def test_kernel_matches_ref_sweeps(n, m, sweeps):
    key = jax.random.PRNGKey(n * m)
    g = jax.random.uniform(key, (n, m), minval=PAPER.g_reset,
                           maxval=PAPER.g_set).astype(jnp.float32)
    v_in = jnp.full((n,), PAPER.v_read, jnp.float32)
    g_w = 1.0 / PAPER.r_wire
    vr = jnp.broadcast_to(v_in[:, None], (n, m)).astype(jnp.float32)
    vc = jnp.zeros((n, m), jnp.float32)
    kr, kc = jacobi_sweeps(g, v_in[:, None], vr, vc, g_w=float(g_w),
                           sweeps=sweeps, interpret=True)
    rr, rc = vr, vc
    for _ in range(sweeps):
        rr, rc = jacobi_sweep_ref(rr, rc, g, v_in, g_w, 1.0)
    assert jnp.allclose(kr, rr, rtol=1e-5, atol=1e-7)
    assert jnp.allclose(kc, rc, rtol=1e-5, atol=1e-7)


@pytest.mark.slow  # 3000 interpret-mode Jacobi iterations (CI full lane)
def test_solve_matches_direct_nodal():
    g = jnp.full((12, 8), PAPER.g_set)
    v = jnp.full((12,), PAPER.v_write)
    i_k, _, _ = solve(g, v, n_iter=3000, sweeps_per_call=50)
    i_d, _, _ = ird.solve_planar(g, v)
    assert float(jnp.max(jnp.abs(i_k - i_d) / i_d)) < 2e-3
