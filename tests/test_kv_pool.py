"""Paged KV pool unit behaviour: free-list allocation, reclaim, the
null-page convention, QoS budgets, and the conservation invariant the
paged bench's exit gate enforces."""
import pytest

from repro.serve.kv_pool import NULL_PAGE, PagedKVPool, default_pool_pages


def _pool(n_pages=8, page_size=4, max_len=16, n_rows=3):
    return PagedKVPool(n_pages, page_size, max_len, n_rows)


def test_page_size_must_divide_max_len():
    """Bit-exactness requires the gathered paged view to be EXACTLY the
    dense path's max_len wide — a ragged last page would change the
    attention einsum width."""
    with pytest.raises(ValueError, match="divide"):
        PagedKVPool(8, page_size=5, max_len=16, n_rows=2)


def test_null_page_is_reserved_and_never_allocated():
    pool = _pool(n_pages=12)
    seen = set()
    for row in range(3):
        seen.update(pool.alloc(row, 16))
    assert NULL_PAGE not in seen
    assert len(seen) == 12        # 3 rows x 4 pages, all distinct


def test_pages_for_rounds_up_and_clamps_to_max_len():
    pool = _pool(page_size=4, max_len=16)
    assert pool.pages_for(1) == 1
    assert pool.pages_for(4) == 1
    assert pool.pages_for(5) == 2
    assert pool.pages_for(16) == 4
    assert pool.pages_for(99) == 4     # lifetime never exceeds max_len
    assert pool.pages_for(0) == 1      # a resident row owns >= 1 page


def test_alloc_reclaim_conserves_pages():
    pool = _pool(n_pages=8)
    assert pool.pages_free == 8 and pool.conservation_ok()
    a = pool.alloc(0, 9)               # 3 pages
    assert len(a) == 3
    assert pool.pages_in_use == 3 and pool.pages_free == 5
    assert pool.conservation_ok()
    b = pool.alloc(1, 16)              # 4 pages
    assert pool.pages_in_use == 7
    pool.free_row(0)
    assert pool.pages_in_use == 4 and pool.pages_free == 4
    assert pool.conservation_ok()
    # freed pages are reusable and stay distinct from row 1's
    c = pool.alloc(2, 16)
    assert not (set(c) & set(b))
    assert pool.conservation_ok()


def test_double_alloc_on_occupied_row_raises():
    pool = _pool()
    pool.alloc(0, 4)
    with pytest.raises(RuntimeError, match="already owns"):
        pool.alloc(0, 4)


def test_alloc_beyond_free_pages_raises_and_can_alloc_predicts_it():
    pool = _pool(n_pages=4, page_size=4, max_len=16, n_rows=3)
    pool.alloc(0, 16)                   # all 4 pages
    assert not pool.can_alloc(1)
    with pytest.raises(RuntimeError):
        pool.alloc(1, 1)
    pool.free_row(0)
    assert pool.can_alloc(16)


def test_budget_gates_new_allocations_only():
    """A QoS page budget below current usage must not evict live pages —
    it only refuses NEW admissions until usage drains under it."""
    pool = _pool(n_pages=8)
    pool.alloc(0, 16)                   # 4 pages in use
    pool.set_budget(2)
    assert pool.budget == 2
    assert pool.pages_in_use == 4       # live pages untouched
    assert not pool.can_alloc(1)        # in_use already >= budget
    pool.free_row(0)
    assert pool.can_alloc(8)            # 2 pages fit the budget again
    assert not pool.can_alloc(9)        # 3 pages would exceed it


def test_budget_clamps_to_pool_bounds():
    pool = _pool(n_pages=8)
    pool.set_budget(0)
    assert pool.budget == 1             # starvation guard
    pool.set_budget(99)
    assert pool.budget == 8             # physical pool is the ceiling


def test_table_row_pads_with_null_page():
    pool = _pool(n_pages=8, page_size=4, max_len=16)
    pages = pool.alloc(1, 6)            # 2 of 4 table entries
    row = pool.table_row(1)
    assert row.shape == (4,)
    assert list(row[:2]) == pages
    assert all(p == NULL_PAGE for p in row[2:])
    # unallocated rows are all null
    assert all(p == NULL_PAGE for p in pool.table_row(0))
    tab = pool.table()
    assert tab.shape == (3, 4)
    assert list(tab[1]) == list(row)


def test_report_fields():
    pool = _pool(n_pages=8, page_size=4, max_len=16)
    pool.alloc(0, 5)
    rep = pool.report()
    assert rep["n_pages"] == 8 and rep["page_size"] == 4
    assert rep["pages_in_use"] == 2 and rep["pages_free"] == 6
    assert rep["conservation_ok"] is True


def test_default_pool_pages():
    assert default_pool_pages(4, 32, 8) == 16          # 4 rows x 4 pages
    assert default_pool_pages(4, 32, 8, kv_pages=10) == 10
