"""Paged KV pool unit behaviour: free-list allocation, reclaim, the
null-page convention, QoS budgets, and the conservation invariant the
paged bench's exit gate enforces."""
import pytest

from repro.serve.kv_pool import NULL_PAGE, PagedKVPool, default_pool_pages


def _pool(n_pages=8, page_size=4, max_len=16, n_rows=3):
    return PagedKVPool(n_pages, page_size, max_len, n_rows)


def test_page_size_must_divide_max_len():
    """Bit-exactness requires the gathered paged view to be EXACTLY the
    dense path's max_len wide — a ragged last page would change the
    attention einsum width."""
    with pytest.raises(ValueError, match="divide"):
        PagedKVPool(8, page_size=5, max_len=16, n_rows=2)


def test_null_page_is_reserved_and_never_allocated():
    pool = _pool(n_pages=12)
    seen = set()
    for row in range(3):
        seen.update(pool.alloc(row, 16))
    assert NULL_PAGE not in seen
    assert len(seen) == 12        # 3 rows x 4 pages, all distinct


def test_pages_for_rounds_up_and_clamps_to_max_len():
    pool = _pool(page_size=4, max_len=16)
    assert pool.pages_for(1) == 1
    assert pool.pages_for(4) == 1
    assert pool.pages_for(5) == 2
    assert pool.pages_for(16) == 4
    assert pool.pages_for(99) == 4     # lifetime never exceeds max_len
    # zero tokens claim zero pages: admission sizes rows by
    # min(prompt_len + max_new - 1, max_len) >= 1, so the old floor of
    # 1 was never load-bearing — and the share planner needs exact
    # sizing for partial spans (pages_for(shared_tokens))
    assert pool.pages_for(0) == 0
    assert pool.pages_for(-3) == 0


def test_alloc_reclaim_conserves_pages():
    pool = _pool(n_pages=8)
    assert pool.pages_free == 8 and pool.conservation_ok()
    a = pool.alloc(0, 9)               # 3 pages
    assert len(a) == 3
    assert pool.pages_in_use == 3 and pool.pages_free == 5
    assert pool.conservation_ok()
    b = pool.alloc(1, 16)              # 4 pages
    assert pool.pages_in_use == 7
    pool.free_row(0)
    assert pool.pages_in_use == 4 and pool.pages_free == 4
    assert pool.conservation_ok()
    # freed pages are reusable and stay distinct from row 1's
    c = pool.alloc(2, 16)
    assert not (set(c) & set(b))
    assert pool.conservation_ok()


def test_double_alloc_on_occupied_row_raises():
    pool = _pool()
    pool.alloc(0, 4)
    with pytest.raises(RuntimeError, match="already owns"):
        pool.alloc(0, 4)


def test_alloc_beyond_free_pages_raises_and_can_alloc_predicts_it():
    pool = _pool(n_pages=4, page_size=4, max_len=16, n_rows=3)
    pool.alloc(0, 16)                   # all 4 pages
    assert not pool.can_alloc(1)
    with pytest.raises(RuntimeError):
        pool.alloc(1, 1)
    pool.free_row(0)
    assert pool.can_alloc(16)


def test_budget_gates_new_allocations_only():
    """A QoS page budget below current usage must not evict live pages —
    it only refuses NEW admissions until usage drains under it."""
    pool = _pool(n_pages=8)
    pool.alloc(0, 16)                   # 4 pages in use
    pool.set_budget(2)
    assert pool.budget == 2
    assert pool.pages_in_use == 4       # live pages untouched
    assert not pool.can_alloc(1)        # in_use already >= budget
    pool.free_row(0)
    assert pool.can_alloc(8)            # 2 pages fit the budget again
    assert not pool.can_alloc(9)        # 3 pages would exceed it


def test_budget_clamps_to_pool_bounds():
    pool = _pool(n_pages=8)
    pool.set_budget(0)
    assert pool.budget == 1             # starvation guard
    pool.set_budget(99)
    assert pool.budget == 8             # physical pool is the ceiling


def test_table_row_pads_with_null_page():
    pool = _pool(n_pages=8, page_size=4, max_len=16)
    pages = pool.alloc(1, 6)            # 2 of 4 table entries
    row = pool.table_row(1)
    assert row.shape == (4,)
    assert list(row[:2]) == pages
    assert all(p == NULL_PAGE for p in row[2:])
    # unallocated rows are all null
    assert all(p == NULL_PAGE for p in pool.table_row(0))
    tab = pool.table()
    assert tab.shape == (3, 4)
    assert list(tab[1]) == list(row)


def test_report_fields():
    pool = _pool(n_pages=8, page_size=4, max_len=16)
    pool.alloc(0, 5)
    rep = pool.report()
    assert rep["n_pages"] == 8 and rep["page_size"] == 4
    assert rep["pages_in_use"] == 2 and rep["pages_free"] == 6
    assert rep["conservation_ok"] is True


def test_default_pool_pages():
    assert default_pool_pages(4, 32, 8) == 16          # 4 rows x 4 pages
    assert default_pool_pages(4, 32, 8, kv_pages=10) == 10


# -- prefix sharing / refcounts / copy-on-write -------------------------------


def _admit_and_register(pool, row, tokens, max_new=4):
    """Mirror the scheduler's lifecycle: whole-lifetime alloc, prefill,
    then index the fully-written prompt pages."""
    need = min(len(tokens) + max_new - 1, pool.max_len)
    pages = pool.alloc(row, need)
    pool.register_prefix(row, tokens)
    return pages


def test_shared_alloc_aliases_prefix_pages_refcounted():
    pool = _pool(n_pages=8, page_size=4, max_len=16)
    toks = list(range(10, 20))                     # 10 tokens: 2 full pages
    pages0 = _admit_and_register(pool, 0, toks)    # 13 tok -> 4 pages
    used0 = pool.pages_in_use
    # same head, divergent tail: the 2 fully-covered pages alias
    toks1 = toks[:8] + [99, 98]
    pages1, shared, cow = pool.alloc_shared(1, 13, toks1)
    assert shared == 8 and not cow
    assert pages1[:2] == pages0[:2]                # aliased, same phys
    assert pool.refcount(pages0[0]) == 2 and pool.refcount(pages0[1]) == 2
    # distinct pages_in_use grew only by the fresh tail, not 4
    assert pool.pages_in_use == used0 + 2
    assert pool.pages_shared == 2
    assert pool.row_shared_pages(1) == 2
    assert pool.conservation_ok()
    # freeing the owner keeps the shared pages alive for row 1
    pool.free_row(0)
    assert pool.refcount(pages0[0]) == 1
    assert pool.pages_shared == 0 and pool.conservation_ok()
    pool.free_row(1)
    assert pool.pages_in_use == 0 and pool.conservation_ok()


def test_shared_alloc_never_shares_the_whole_prompt():
    """The final prompt token must flow through the model to emit the
    first output token, so sharing caps at plen - 1 — a duplicate
    prompt aliases every page but COWs the last one."""
    pool = _pool(n_pages=8, page_size=4, max_len=16)
    toks = list(range(30, 38))                     # exactly 2 pages
    pages0 = _admit_and_register(pool, 0, toks)
    pages1, shared, cow = pool.alloc_shared(1, 11, toks)
    assert shared == 7                             # plen - 1, not 8
    assert len(cow) == 1
    src, dst = cow[0]
    assert src == pages0[1] and dst == pages1[1] and src != dst
    # after COW the tables no longer alias at that logical position
    assert pool.refcount(src) == 1 and pool.refcount(dst) == 1
    assert pool.conservation_ok()


def test_sub_page_extension_match_cows_the_partial_page():
    pool = _pool(n_pages=10, page_size=4, max_len=16)
    toks = list(range(40, 50))                     # 10 tokens
    pages0 = _admit_and_register(pool, 0, toks)
    # matches page 0 fully and 2 of page 1's 4 tokens
    toks1 = toks[:6] + [77, 76, 75, 74]
    pages1, shared, cow = pool.alloc_shared(1, 13, toks1)
    assert shared == 6                             # 4 whole + 2 partial
    assert pages1[0] == pages0[0]                  # whole page aliased
    assert len(cow) == 1 and cow[0][0] == pages0[1]
    assert pages1[1] == cow[0][1] != pages0[1]     # partial page private
    assert pool.conservation_ok()


def test_no_match_degrades_to_private_alloc():
    pool = _pool(n_pages=8, page_size=4, max_len=16)
    _admit_and_register(pool, 0, list(range(10, 18)))
    pages, shared, cow = pool.alloc_shared(1, 8, [1, 2, 3, 4, 5])
    assert shared == 0 and not cow and len(pages) == 2
    assert pool.pages_shared == 0 and pool.conservation_ok()


def test_freed_pages_leave_the_prefix_index():
    """A page whose last reference drops must become unreachable via its
    token keys — the free list will recycle the id under new contents."""
    pool = _pool(n_pages=8, page_size=4, max_len=16)
    toks = list(range(50, 58))
    _admit_and_register(pool, 0, toks)
    assert pool.prefix_entries == 2
    pool.free_row(0)
    assert pool.prefix_entries == 0
    plan = pool.plan_shared(8, toks)
    assert plan["shared_tokens"] == 0 and not plan["aliased"]


def test_recycled_parent_id_cannot_alias_stale_chain_keys():
    """REVIEW regression: a registration that hits an existing key
    chains its diverging tail off the CANONICAL page's phys id even
    though the registering row holds no reference on that page.  When
    the canonical owner frees first, every surviving key embedding the
    freed id must leave the index with it — otherwise the recycled id
    satisfies the stale (parent, tokens) lookup and a later plan aliases
    a page whose K/V was computed under a DIFFERENT prefix."""
    pool = _pool(n_pages=8, page_size=4, max_len=16, n_rows=4)
    h0, h1 = list(range(10, 14)), list(range(14, 18))
    y = [50, 51, 52, 53]
    pages0 = pool.alloc(0, 8)
    pool.register_prefix(0, h0 + h1)
    # row 1 arrives with its own PRIVATE copy of the H0 prefix (admitted
    # before row 0's pages were indexed), tail Y diverging: registration
    # hits (root, H0), walks onto row 0's canonical page, and indexes
    # row 1's Y page under that phys id
    pool.alloc(1, 8)
    pool.register_prefix(1, h0 + y)
    # canonical owner leaves; row 1 (still resident) never referenced
    # row 0's pages, so their ids return to the free list
    pool.free_row(0)
    # a new prompt G recycles row 0's first page id under new contents
    g = [90, 91, 92, 93]
    pages2 = pool.alloc(2, 8)
    assert pages2[0] == pages0[0]          # the id really was recycled
    pool.register_prefix(2, g)
    # planning G+Y must alias ONLY the live G page — row 1's Y page was
    # conditioned on H0, not G, and must be unreachable via the chain
    plan = pool.plan_shared(12, g + y + [7])
    assert plan["shared_tokens"] == 4
    assert plan["aliased"] == [pages2[0]]
    assert pool.conservation_ok()


def test_budget_gates_shared_plans_on_fresh_pages_only():
    """Aliased pages cost no new allocation: a shared plan fits as long
    as its FRESH remainder fits the budget, so sharing admits where a
    private copy would not."""
    pool = _pool(n_pages=8, page_size=4, max_len=16)
    toks = list(range(60, 70))                     # 2 full pages indexed
    _admit_and_register(pool, 0, toks)             # 4 pages in use
    pool.set_budget(6)
    toks1 = toks[:8] + [99, 98]
    assert not pool.can_alloc(13)                  # private: 4 fresh > 2
    assert pool.can_alloc_shared(13, toks1)        # shared: 2 fresh fit
    pages, shared, _ = pool.alloc_shared(1, 13, toks1)
    assert shared == 8 and pool.pages_in_use == 6
    assert pool.conservation_ok()


def test_cow_requires_free_page():
    pool = _pool(n_pages=4, page_size=4, max_len=16, n_rows=3)
    toks = list(range(4))
    pool.alloc(0, 8)                               # 2 pages
    pool.register_prefix(0, toks)
    pages, shared, _ = pool.alloc_shared(1, 8, toks[:3] + [9, 9, 9])
    # pool is now full (4 distinct); force-share row 1's aliased page
    assert pool.pages_free == 0
    pool._ref[pages[0]] += 1
    pool._rows[2] = [pages[0]]
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.cow(2, 0)
    pool._rows[2] = []
    pool._ref[pages[0]] -= 1


def test_report_owned_vs_shared():
    pool = _pool(n_pages=8, page_size=4, max_len=16)
    toks = list(range(20, 28))
    _admit_and_register(pool, 0, toks)             # 2 full + 1 tail page
    pool.alloc_shared(1, 11, toks[:8] + [5, 6])
    rep = pool.report()
    assert rep["pages_shared"] == 2
    assert rep["pages_owned"] == rep["pages_in_use"] - 2
    assert rep["prefix_entries"] == 2
    assert rep["conservation_ok"] is True


# -- admission cost: the rolling chain key is O(plen), not O(plen^2) ----------


def test_prefix_index_cost_linear_in_prompt_length():
    """The rolling chain key hashes exactly page_size tokens per page
    (the parent phys id stands in for everything before it), so
    registering a prompt costs plen hashed positions — the old
    cumulative-prefix keys cost ps*(1+2+..+n) ~ plen^2/(2*ps).  Pinned
    by the index_ops counter: doubling the prompt EXACTLY doubles the
    count, and a full-chain plan walk is linear too."""
    pool = PagedKVPool(64, page_size=4, max_len=128, n_rows=4)
    short = list(range(100, 132))                # 32 tokens = 8 pages
    long_ = list(range(500, 564))                # 64 tokens, disjoint
    pool.alloc(0, len(short))
    pool.register_prefix(0, short)
    ops_short = pool.index_ops
    pool.alloc(1, len(long_))
    pool.register_prefix(1, long_)
    ops_long = pool.index_ops - ops_short
    assert ops_short == len(short)               # quadratic would be 144
    assert ops_long == 2 * ops_short
    # planning against the index walks one ps-token key per matched
    # page plus the one that misses: linear with a one-page epsilon
    before = pool.index_ops
    plan = pool.plan_shared(64, long_[:48] + [7] * 16)
    assert plan["shared_tokens"] == 48
    assert pool.index_ops - before <= 48 + 2 * pool.page_size
