"""Paper-claim and core-physics tests (device, IR drop, modes, pipeline)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ir_drop as ird
from repro.core import pipeline as pipe
from repro.core.device import (MemristorModel, hysteresis_loop,
                               transistor_leakage)
from repro.core.timing import PAPER, deepnet_speedup


class TestDevice:
    def test_pinched_hysteresis(self):
        """Paper Fig. 3a: loop passes through the origin, nonzero area."""
        v, i, w = hysteresis_loop(n_cycles=2, samples_per_cycle=1024)
        v, i = np.asarray(v), np.asarray(i)
        near0 = np.abs(v) < 0.01
        assert np.abs(i[near0]).max() < 0.05 * np.abs(i).max()
        half = len(v) // 2
        assert abs(np.trapezoid(i[half:], v[half:])) > 0.0

    def test_resistance_corners(self):
        m = MemristorModel()
        assert float(m.resistance(jnp.float32(1.0))) == pytest.approx(PAPER.r_set)
        assert float(m.resistance(jnp.float32(0.0))) == pytest.approx(PAPER.r_reset)

    def test_write_pulse_switches_device(self):
        """t_write = 250 ns at V_write must move the state substantially."""
        m = MemristorModel()
        assert float(m.program(jnp.float32(0.02), PAPER.v_write)) > 0.7
        assert float(m.program(jnp.float32(0.98), -PAPER.v_write)) < 0.3

    def test_program_verify_converges(self):
        m = MemristorModel()
        g_target = 1.0 / 30e3
        w, _ = m.program_verify(jnp.float32(0.1), jnp.float32(g_target),
                                n_pulses=48, n_steps=16)
        r = 1.0 / float(m.conductance(w))
        assert r == pytest.approx(30e3, rel=0.25)

    def test_single_cell_read_current_paper_c4(self):
        """Paper: 39.6 nA measured vs 40 nA ideal (1 % off) at 4 mV."""
        i = 0.004 / (PAPER.r_reset + PAPER.r_on_transistor)
        assert i * 1e9 == pytest.approx(39.6, rel=0.01)

    def test_worst_case_leakage_paper_c3(self):
        """Paper Fig. 3c: ~2.5 pA/cell at V_ds = V_write, gate low."""
        leak = float(transistor_leakage(jnp.float32(PAPER.v_write),
                                        jnp.float32(0.0)))
        assert leak == pytest.approx(2.5e-12, rel=0.05)
        # 10-cell column: 25 pA = 6.3e-2 % of the worst-case read current
        col = 10 * leak
        i_read_worst = PAPER.v_read / (PAPER.r_set + PAPER.r_on_transistor)
        # paper normalizes against the 40 uA-scale column read; a single
        # worst-case cell read is ~45 uA/10 cells -> use column read
        frac = col / (10 * 0.004 / (PAPER.r_reset + PAPER.r_on_transistor))
        assert frac < 1e-3  # "negligible"


class TestIRDrop:
    def test_jacobi_matches_direct(self):
        g = jnp.full((12, 8), PAPER.g_set)
        v = jnp.full((12,), PAPER.v_write)
        i_d, _, _ = ird.solve_planar(g, v)
        i_j, _, _ = ird.jacobi_planar(g, v, n_iter=3000)
        assert jnp.max(jnp.abs(i_j - i_d) / i_d) < 1e-3

    def test_currents_droop_with_distance(self):
        """Fig. 3b: columns farther from the drivers read lower current."""
        g = jnp.full((16, 16), PAPER.g_set)
        v = jnp.full((16,), PAPER.v_write)
        i_out, _, _ = ird.solve_planar(g, v)
        assert bool(jnp.all(jnp.diff(i_out) < 0))

    def test_expansion_reduces_ir_drop_22pct_paper_c1(self):
        """Paper claim C1: ~22 % lower line loss at fixed input count."""
        n, m = 20, 20
        g = jnp.full((n, m), PAPER.g_set)
        v = jnp.full((n,), PAPER.v_write)
        g_ser = 1.0 / (1.0 / g + PAPER.r_on_transistor)
        i_ideal = ird.ideal_currents(g_ser, v)
        i_pl, _, _ = ird.solve_planar(g, v)
        gt = jnp.full((n // 2, m), PAPER.g_set)
        vt = jnp.full((n // 2,), PAPER.v_write)
        i_cs, _, _ = ird.solve_crossstack(gt, gt, vt, vt)
        loss_pl = ird.ir_drop_loss(i_pl, i_ideal).mean()
        loss_cs = ird.ir_drop_loss(i_cs, i_ideal).mean()
        reduction = 1.0 - float(loss_cs / loss_pl)
        assert reduction == pytest.approx(0.22, abs=0.03)

    def test_crossstack_equals_planar_at_zero_wire_r(self):
        """With no wire resistance the two geometries are identical MACs."""
        key = jax.random.PRNGKey(0)
        g = jax.random.uniform(key, (8, 6), minval=PAPER.g_reset,
                               maxval=PAPER.g_set)
        v = jnp.full((8,), PAPER.v_read)
        i_pl, _, _ = ird.solve_planar(g, v, 1e-9)
        i_cs, _, _ = ird.solve_crossstack(g[:4], g[4:], v[:4], v[4:], 1e-9)
        assert jnp.allclose(i_pl, i_cs, rtol=1e-4)


class TestDeepNetPipeline:
    def test_speedup_29pct_paper_c2(self):
        """Paper claim C2: 29 % faster per 10-bit convolution."""
        assert deepnet_speedup(10) == pytest.approx(0.29, abs=0.01)
        assert pipe.speedup(200, 10) == pytest.approx(0.29, abs=0.01)

    def test_schedule_validity(self):
        for n_layers in [1, 2, 3, 7, 32]:
            for bits in [1, 4, 10, 16, 32]:
                pipe.deepnet_schedule(n_layers, bits).validate()

    def test_deepnet_never_slower(self):
        for n_layers in [1, 2, 5, 50]:
            for bits in [1, 8, 10, 40]:
                s = pipe.serial_schedule(n_layers, bits)
                d = pipe.deepnet_schedule(n_layers, bits)
                assert d.total <= s.total + 1e-12

    def test_read_dominated_regime(self):
        """When b*t_read > t_write the pipeline hides the write instead."""
        bits = 100  # 1000 ns read >> 250 ns write
        s = pipe.speedup(1000, bits)
        expected = 1.0 - max(PAPER.t_write, bits * PAPER.t_read) / (
            PAPER.t_write + bits * PAPER.t_read)
        assert s == pytest.approx(expected, abs=0.01)

    def test_streaming_speedup_model(self):
        assert pipe.streaming_speedup(1.0, 1.0, 1000) == pytest.approx(0.5, abs=0.01)
        assert pipe.streaming_speedup(3.0, 1.0, 1000) == pytest.approx(0.25, abs=0.01)
