"""Streamed-lane paged attention CI: the bounded-ulp / argmax-stable
contract of the block-streamed online-softmax kernel, the two-lane
dispatch (counters, no-silent-fallback), the compat shim, and the
O(page_block) VMEM claim.

The scratch lane keeps its BITWISE tripod in test_paged_attention.py;
this file pins everything the streamed lane adds:

  * parity grid streamed-vs-scratch-vs-dense over kv dtypes
    {fp32, bf16, fp8} x window lengths straddling page-block boundaries
    (1, page_size, page_size*B +/- 1, >= 8 blocks), within the
    documented tolerance AND argmax-stable,
  * streamed kernel == its same-schedule jnp flash oracle (tight),
  * aliased/COW page tables are bitwise-invisible to the streamed lane,
  * the compat fallback grid runs the identical kernel body bitwise,
  * dispatch counters: auto-lane thresholding, and a streamed-lane
    failure warns ONCE, counts paged_fallback, and lands on the scratch
    KERNEL (never the jnp reference scan),
  * streamed-lane VMEM scratch is constant in the window length while
    the scratch lane's grows linearly,
  * scheduler property: a long-prompt admission on the streamed lane
    causes ZERO retraces (runtime serve_jit_retraces_total check) and
    zero fallbacks, with token streams matching the scratch lane.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.kernels.paged_attention import (
    paged_attention, paged_attention_streamed, paged_attention_streamed_ref,
    paged_path_calls, resolve_block_pages, scratch_lane_vmem_bytes,
    streamed_lane_resident_bytes, streamed_lane_vmem_bytes)
from repro.kernels.paged_attention import ops as paged_ops
from repro.kernels.paged_attention.kernel import paged_attention_kernel
from repro.kernels.paged_attention.ref import gather_pages
from repro.models.layers import AttnConfig, _chunked_sdpa

jax.config.update("jax_enable_x64", False)

_slow = pytest.mark.slow

# the documented streamed-lane contract: both lanes accumulate in f32,
# they differ only in reduction association (online vs one-shot
# softmax), so fp32 outputs agree to a few ulp and low-precision
# outputs to ~1 output-dtype ulp
_TOL = {
    jnp.float32: dict(atol=1e-6, rtol=1e-6),
    jnp.bfloat16: dict(atol=2e-2, rtol=2e-2),
}


def _assert_close(a, b, dtype):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), **_TOL[dtype])


def _assert_argmax_stable(a, b):
    """The serving-level half of the contract: whatever downstream
    reduction picks a winner, both lanes pick the same one."""
    af = np.asarray(a, np.float32).reshape(a.shape[0], -1)
    bf = np.asarray(b, np.float32).reshape(b.shape[0], -1)
    assert (af.argmax(-1) == bf.argmax(-1)).all()


def _window_case(key, lens, *, sq=1, hq=4, kv=2, hd=8, ps=4, p_seq=16,
                 dtype=jnp.float32, kv_dtype=None):
    """One row per requested window length; each row owns a private
    contiguous page run, trailing table entries null (page 0)."""
    b = len(lens)
    kq, kk, kvk = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, sq, hq, hd)).astype(dtype)
    n_pages = b * p_seq + 1
    kp = jax.random.normal(kk, (n_pages, ps, kv, hd)).astype(kv_dtype
                                                             or dtype)
    vp = jax.random.normal(kvk, (n_pages, ps, kv, hd)).astype(kv_dtype
                                                              or dtype)
    pt = jnp.zeros((b, p_seq), jnp.int32)
    for r, depth in enumerate(lens):
        assert sq <= depth <= ps * p_seq
        npg = -(-depth // ps)
        pt = pt.at[r, :npg].set(jnp.arange(1 + r * p_seq,
                                           1 + r * p_seq + npg))
    kv_len = jnp.asarray(lens, jnp.int32)
    return q, kp, vp, pt, kv_len, kv_len - sq


# window lengths straddling the page-block boundary at block_pages=2,
# page_size=4 (block = 8 tokens): 1, page_size, block -/+ 1, block, and
# the full 16-page window = 8 blocks
_BP = 2
_PS = 4
_WINDOWS = (1, _PS, _PS * _BP - 1, _PS * _BP, _PS * _BP + 1, _PS * 16)


@pytest.mark.parametrize("dtype,sq", [
    (jnp.float32, 1),
    pytest.param(jnp.bfloat16, 1, marks=_slow),
    pytest.param(jnp.float32, 4, marks=_slow),   # causal, multi-query rows
])
def test_streamed_parity_grid_vs_scratch_and_dense(dtype, sq):
    """The parity grid: every boundary-straddling window in one ragged
    batch, streamed within tolerance of BOTH the scratch lane and the
    dense-path SDPA, argmax-stable, and tight against its own
    same-schedule flash oracle."""
    windows = tuple(max(w, sq) for w in _WINDOWS)   # need sq <= window
    q, kp, vp, pt, kv_len, q_off = _window_case(
        jax.random.PRNGKey(3), windows, sq=sq, ps=_PS, dtype=dtype)
    streamed = paged_attention(q, kp, vp, pt, kv_len, q_off,
                               lane="streamed", block_pages=_BP)
    scratch = paged_attention(q, kp, vp, pt, kv_len, q_off, lane="scratch")
    oracle = paged_attention_streamed_ref(q, kp, vp, pt, kv_len, q_off,
                                          block_pages=_BP)
    assert streamed.dtype == dtype
    _assert_close(streamed, scratch, dtype)
    _assert_close(streamed, oracle, dtype)
    _assert_argmax_stable(streamed, scratch)
    if dtype is jnp.float32 and sq == 1:
        # One dense-arm compile is enough: scratch == dense is pinned
        # bitwise in test_paged_attention, so streamed ~= scratch covers
        # the dense path transitively for the slow params.
        cfg = AttnConfig(d_model=q.shape[2] * q.shape[3],
                         n_heads=q.shape[2], n_kv=kp.shape[2],
                         head_dim=q.shape[3])
        dense = _chunked_sdpa(q, gather_pages(kp, pt), gather_pages(vp, pt),
                              cfg, kv_len=kv_len, q_offset=q_off)
        _assert_close(streamed, dense, dtype)
        _assert_argmax_stable(streamed, dense)


def test_streamed_parity_fp8_kv_cache():
    """fp8 K/V pages upcast inside the dot on both lanes; the streamed
    output stays within one bf16 ulp of the scratch lane."""
    if not hasattr(jnp, "float8_e4m3fn"):
        pytest.skip("no fp8 dtype in this jax build")
    q, kp, vp, pt, kv_len, q_off = _window_case(
        jax.random.PRNGKey(5), _WINDOWS, ps=_PS, dtype=jnp.bfloat16,
        kv_dtype=jnp.float8_e4m3fn)
    streamed = paged_attention(q, kp, vp, pt, kv_len, q_off,
                               lane="streamed", block_pages=_BP)
    scratch = paged_attention(q, kp, vp, pt, kv_len, q_off, lane="scratch")
    assert streamed.dtype == q.dtype
    _assert_close(streamed, scratch, jnp.bfloat16)
    _assert_argmax_stable(streamed, scratch)


def test_streamed_aliased_page_tables_bitwise_vs_materialized():
    """Prefix sharing is read-only aliasing: the streamed gather cannot
    tell a shared physical page from a private copy, so aliased vs
    materialized tables agree BITWISE (same lane, same schedule)."""
    key = jax.random.PRNGKey(21)
    b, sq, hq, kv, hd, ps = 2, 1, 4, 2, 8, 4
    kq, kk, kv_ = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, sq, hq, hd)).astype(jnp.float32)
    kp = jax.random.normal(kk, (8, ps, kv, hd)).astype(jnp.float32)
    vp = jax.random.normal(kv_, (8, ps, kv, hd)).astype(jnp.float32)
    # both rows share pages 1,2 for their prefix, own tails 3/4
    pt_alias = jnp.asarray([[1, 2, 3, 0], [1, 2, 4, 0]], jnp.int32)
    kp_mat = kp.at[5].set(kp[1]).at[6].set(kp[2])
    vp_mat = vp.at[5].set(vp[1]).at[6].set(vp[2])
    pt_mat = jnp.asarray([[1, 2, 3, 0], [5, 6, 4, 0]], jnp.int32)
    kv_len = jnp.asarray([ps * 3, ps * 3 - 2], jnp.int32)
    q_off = kv_len - sq
    aliased = paged_attention_streamed(q, kp, vp, pt_alias, kv_len, q_off,
                                       block_pages=2)
    materialized = paged_attention_streamed(q, kp_mat, vp_mat, pt_mat,
                                            kv_len, q_off, block_pages=2)
    assert jnp.array_equal(aliased, materialized)


def test_compat_fallback_grid_is_bitwise_identical():
    """The prefetch_grid_spec fallback (plain grid, scalars as constant
    full-array operands) runs the IDENTICAL kernel body: outputs match
    the PrefetchScalarGridSpec path bitwise."""
    q, kp, vp, pt, kv_len, q_off = _window_case(
        jax.random.PRNGKey(7), (1, 9, 32), ps=_PS, p_seq=8,
        dtype=jnp.float32)
    primary = paged_attention_streamed(q, kp, vp, pt, kv_len, q_off,
                                       block_pages=2)
    fallback = paged_attention_streamed(q, kp, vp, pt, kv_len, q_off,
                                        block_pages=2,
                                        force_compat_fallback=True)
    assert jnp.array_equal(primary, fallback)


def test_compat_prefetch_spec_validates_scalar_shapes():
    from repro.kernels.compat import prefetch_grid_spec
    with pytest.raises(ValueError, match="scalar_shapes"):
        prefetch_grid_spec(num_scalar_prefetch=2, grid=(1,), in_specs=[],
                           out_specs=None, scratch_shapes=[],
                           scalar_shapes=[(1, 1)])


def test_resolve_block_pages_clamps_to_divisor():
    assert resolve_block_pages(16, 16) == 16
    assert resolve_block_pages(16, 5) == 4
    assert resolve_block_pages(9, 4) == 3
    assert resolve_block_pages(7, 16) == 7    # prime width: whole table
    assert resolve_block_pages(12, 8) == 6
    assert resolve_block_pages(1, 16) == 1


# -- dispatch: counters, auto lane, no silent fallback ------------------------

def _tiny_case(seed=1):
    return _window_case(jax.random.PRNGKey(seed), (3, 14), ps=_PS,
                        p_seq=4, dtype=jnp.float32)


def test_auto_lane_thresholds_on_table_width():
    """lane="auto" picks streamed iff stream_min_pages is enabled and
    the table is at least that wide; every call lands in the dispatch
    counters with zero fallbacks."""
    obs.reset()
    q, kp, vp, pt, kv_len, q_off = _tiny_case()
    base = dict(paged_path_calls)
    paged_attention(q, kp, vp, pt, kv_len, q_off)            # default
    paged_attention(q, kp, vp, pt, kv_len, q_off,
                    stream_min_pages=8)                      # 4 < 8
    assert paged_path_calls["paged_scratch"] == base["paged_scratch"] + 2
    assert paged_path_calls["paged_streamed"] == base["paged_streamed"]
    paged_attention(q, kp, vp, pt, kv_len, q_off,
                    stream_min_pages=4, block_pages=2)       # 4 >= 4
    paged_attention(q, kp, vp, pt, kv_len, q_off, lane="streamed",
                    block_pages=2)
    assert paged_path_calls["paged_streamed"] == base["paged_streamed"] + 2
    assert paged_path_calls["paged_fallback"] == base["paged_fallback"]
    with pytest.raises(ValueError, match="lane"):
        paged_attention(q, kp, vp, pt, kv_len, q_off, lane="warp")


def test_streamed_failure_warns_once_and_falls_back_to_scratch_kernel(
        monkeypatch):
    """The no-silent-fallback contract: a streamed-lane failure warns
    ONCE per geometry, bumps paged_fallback, and routes to the scratch
    KERNEL — the output is bitwise the scratch lane's, never a
    reference-scan approximation."""
    obs.reset()
    q, kp, vp, pt, kv_len, q_off = _tiny_case(seed=2)

    def boom(*a, **k):
        raise RuntimeError("induced streamed-lane lowering failure")

    monkeypatch.setattr(paged_ops._kernel_mod, "paged_attention_streamed",
                        boom)
    monkeypatch.setattr(paged_ops, "_FALLBACK_WARNED", set())
    base = dict(paged_path_calls)
    with pytest.warns(UserWarning, match="streamed lane failed"):
        out1 = paged_attention(q, kp, vp, pt, kv_len, q_off,
                               lane="streamed")
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # second call must NOT warn
        out2 = paged_attention(q, kp, vp, pt, kv_len, q_off,
                               lane="streamed")
    scratch = paged_attention_kernel(q, kp, vp, pt, kv_len, q_off)
    assert jnp.array_equal(out1, scratch)
    assert jnp.array_equal(out2, scratch)
    assert paged_path_calls["paged_fallback"] == base["paged_fallback"] + 2
    assert paged_path_calls["paged_streamed"] == base["paged_streamed"]


# -- the O(page_block) VMEM claim ---------------------------------------------

def test_streamed_vmem_constant_while_scratch_grows_linearly():
    """The tentpole's point: the scratch lane's gather buffer is linear
    in the window; the streamed lane's ring + online-softmax stats do
    not depend on it at all.  The honest companion number: the CURRENT
    lowering maps the whole K/V pools as input blocks, so its total
    residency is scratch + 2x pool — accounted (and pinned) separately
    so the O(block) claim never silently overstates what a real-TPU
    lowering would hold."""
    geom = dict(page_size=8, kv=2, hd=64, kv_dtype=jnp.bfloat16)
    windows = (16, 32, 64, 128, 256)
    scratch = [scratch_lane_vmem_bytes(p, geom["page_size"], geom["kv"],
                                       geom["hd"], geom["kv_dtype"])
               for p in windows]
    streamed = [streamed_lane_vmem_bytes(4, 1, 8, geom["kv"], geom["hd"],
                                         p, geom["page_size"],
                                         16, geom["kv_dtype"])
                for p in windows]
    assert len(set(streamed)) == 1                # constant in the window
    for a, b, pa, pb in zip(scratch, scratch[1:], windows, windows[1:]):
        assert b * pa == a * pb                   # exactly linear
    assert streamed[0] < scratch[-1]              # and it actually pays off
    # resident = scratch + both pools, exactly; grows with the pool (one
    # full-depth row per window here), NOT constant — the accounting
    # must not launder pool residency into the O(block) claim
    itemsize = jnp.dtype(geom["kv_dtype"]).itemsize
    resident = []
    for p in windows:
        n_pool = 4 * p + 1
        r = streamed_lane_resident_bytes(4, 1, 8, geom["kv"], geom["hd"],
                                         p, geom["page_size"], 16,
                                         n_pool, geom["kv_dtype"])
        pools = 2 * n_pool * geom["page_size"] * geom["kv"] * geom["hd"] \
            * itemsize
        assert r == streamed[0] + pools
        resident.append(r)
    assert len(set(resident)) == len(windows)


# -- scheduler property: long-prompt admission, zero retraces -----------------

def test_streamed_lane_long_prompt_admission_zero_retraces():
    """Admitting a long prompt (chunked prefill) plus decode traffic on
    the streamed lane traces ONE decode closure, retraces NOTHING, never
    falls back — and emits the same token streams as the scratch lane."""
    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.serve.engine import BatchScheduler, Request

    def serve(**overrides):
        cfg = dataclasses.replace(get_config("qwen3_4b", smoke=True),
                                  paged_kernel=True, **overrides)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        sched = BatchScheduler(m, params, n_slots=2, max_len=64,
                               page_size=8, chunk=8)
        prompts = [jax.random.randint(jax.random.PRNGKey(100 + i), (plen,),
                                      0, cfg.vocab - 1).astype(jnp.int32)
                   for i, plen in enumerate((58, 5))]
        for rid, p in enumerate(prompts):
            sched.submit(Request(rid=rid, prompt=p, max_new=4))
        done, steps = {}, 0
        while len(done) < 2 and steps < 60:
            for r in sched.step():
                done[r.rid] = r.out
            steps += 1
        assert len(done) == 2
        return done

    obs.reset()
    base = dict(paged_path_calls)
    streamed = serve(paged_stream_pages=4, paged_block_pages=2)
    reg = obs.registry()
    assert reg.total("serve_jit_retraces_total") == 0
    assert reg.total("serve_jit_traces_total", closure="decode",
                     tenant="A") == 1
    assert paged_path_calls["paged_streamed"] > base["paged_streamed"]
    assert paged_path_calls["paged_fallback"] == base["paged_fallback"]
    scratch = serve()                        # default config: scratch lane
    assert streamed == scratch               # argmax-stable end to end
