"""N-plane banks: role-tagged slot lifecycle, the unified residency
registry, N-tenant serving bit-exactness, staged-vs-in-place swap modes,
the eviction-during-swap race regression, QoS-weighted slot allocation,
and coalesced same-bucket admission prefill."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core import engine as eng, modes
from repro.core.device import DeviceConfig
from repro.core.engine import EngineConfig
from repro.core.executor import CrossbarExecutor
from repro.core.modes import BankState, StackState
from repro.core.planes import PlaneBank
from repro.core.quant import QuantConfig
from repro.models.model import ModelConfig, build_model
from repro.serve.engine import BatchScheduler, Request, _split_slots
from repro.serve.hotswap import finetune_delta

CFG3 = EngineConfig(tile_rows=32, tile_cols=32, mode="deepnet",
                    quant=QuantConfig(w_bits=4, in_bits=8, adc_bits=10),
                    device=DeviceConfig(stack_planes=3))
CFG2 = dataclasses.replace(CFG3, device=DeviceConfig(stack_planes=2))

TINY3 = ModelConfig(
    name="tiny", family="dense", n_layers=2, d_model=32, n_heads=2,
    n_kv=2, head_dim=16, d_ff=64, vocab=128, backend="crossbar",
    dtype=jnp.float32,
    xbar=EngineConfig(tile_rows=32, tile_cols=32, mode="deepnet",
                      quant=QuantConfig(w_bits=4, in_bits=6, adc_bits=12),
                      device=DeviceConfig(stack_planes=3)))


def _w(key, k, n):
    return jax.random.normal(jax.random.PRNGKey(key), (k, n)) * 0.3


def _cold(w, cfg=CFG3):
    ex = CrossbarExecutor(cfg)
    ex.program_params({"head": w})
    return ex


# -- DeviceConfig / geometry ---------------------------------------------------

def test_device_config_validates_and_names_tenants():
    assert DeviceConfig().stack_planes == 2
    assert DeviceConfig(stack_planes=3).tenant_names == ("A", "B", "C")
    assert DeviceConfig(stack_planes=2).tenant_names == ("A", "B")
    with pytest.raises(ValueError, match="stack_planes"):
        DeviceConfig(stack_planes=1)
    assert EngineConfig().stack_planes == 2
    assert CFG3.stack_planes == 3


def test_physical_device_count_scales_with_stack_height():
    w = _w(0, 64, 32)
    ex2, ex3 = _cold(w, CFG2), _cold(w, CFG3)
    assert ex2.n_devices == ex3.n_devices            # serving count: 1 plane
    assert ex2.n_devices_physical == 2 * ex2.n_devices
    assert ex3.n_devices_physical == 3 * ex3.n_devices


# -- PlaneBank slot lifecycle --------------------------------------------------

def _pw(key=0, k=64, n=32):
    return eng.program(_w(key, k, n), CFG3)


def test_bank_roles_free_staging_resident():
    bank = PlaneBank("tile", n_planes=3)
    assert bank.n_free == 3 and bank.residents == []
    bank.assign("A", _pw(1), "fp_a")
    bank.assign("B", _pw(2), "fp_b")
    assert bank.n_free == 1 and sorted(bank.residents) == ["A", "B"]
    assert bank.fingerprint_for("A") == "fp_a"
    slot = bank.reserve_staging()
    assert slot.role == "staging" and bank.n_free == 0
    # no second staging slot, and no free slot left for a new resident
    with pytest.raises(RuntimeError, match="already"):
        bank.reserve_staging()
    with pytest.raises(RuntimeError, match="full"):
        bank.assign("C", _pw(3), "fp_c")
    # land the staged plane on tenant A: read retargets, old slot frees
    bank.land_staged("A", _pw(4), "fp_a2")
    assert bank.fingerprint_for("A") == "fp_a2"
    assert bank.n_free == 1 and bank.staging is None
    # release path (abort): staging reverts to free
    bank.reserve_staging()
    bank.release_staging()
    assert bank.n_free == 1
    bank.evict("B")
    assert bank.n_free == 2
    with pytest.raises(RuntimeError, match="not resident"):
        bank.fingerprint_for("B")


# -- executor: N-tenant residency registry ------------------------------------

def test_three_tenants_read_their_own_planes_bit_exact():
    ws = {t: _w(i + 10, 64, 48) for i, t in enumerate("ABC")}
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 64))
    ex = CrossbarExecutor(CFG3)
    for t in "ABC":
        ex.program_params({"head": ws[t]}, tenant=t)
    assert ex.tenants == ["A", "B", "C"]
    for t in "ABC":
        cold = _cold(ws[t])
        assert jnp.array_equal(ex.linear(x, ws[t], "head", tenant=t),
                               cold.linear(x, ws[t], "head"))
    # one 3-plane stack vs three dedicated 3-plane stacks: 1/3 the devices
    assert 3 * ex.n_devices_physical == sum(
        _cold(ws[t]).n_devices_physical for t in "ABC")
    # a 4th tenant exceeds the plane population
    with pytest.raises(ValueError, match="unknown tenant"):
        ex.program_params({"head": ws["A"]}, tenant="D")


def test_residency_registry_reports_fingerprint_and_version():
    w_a, w_b = _w(20, 64, 32), _w(21, 64, 32)
    ex = CrossbarExecutor(CFG3)
    ex.program_params({"head": w_a})
    ex.program_params({"head": w_b}, tenant="B")
    reg = ex.residency()
    assert sorted(reg) == ["A", "B"]
    assert reg["A"] == {"fingerprint": ex.fingerprint(tenant="A"),
                        "version": 1,
                        "modes": {"expansion": 0, "deepnet": 1}}
    assert reg["B"]["fingerprint"] == _cold(w_b).fingerprint()
    ex.swap({"head": w_b + 0.1}, tenant="B")
    assert ex.residency()["B"]["version"] == 2
    assert ex.residency()["A"]["version"] == 1


def test_staged_swap_with_free_plane_never_pauses_the_tenant():
    """With a free plane in the bank, even a non-anchor tenant's swap is
    staged: its reads serve the OLD plane through the whole window and
    retarget at promote — no mid-write pause (the N=2 in-place pause was
    a full-bank fallback, not a law)."""
    w_a, w_b, w_b2 = _w(30, 96, 48), _w(31, 96, 48), _w(32, 96, 48)
    x = jax.random.normal(jax.random.PRNGKey(33), (3, 96))
    ex = CrossbarExecutor(CFG3)               # 3 planes: A, B, one free
    ex.program_params({"head": w_a})
    ex.program_params({"head": w_b}, tenant="B")
    y_b = ex.linear(x, w_b, "head", tenant="B")
    plan = ex.begin_swap({"head": w_b2}, tenant="B")
    assert not plan.in_place                  # free plane -> staged
    ex.write_chunks(1)
    # mid-window: B still serves its old plane, bit-exact — no pause
    assert jnp.array_equal(ex.linear(x, w_b, "head", tenant="B"), y_b)
    while not plan.done:
        ex.write_chunks(8)
    assert jnp.array_equal(ex.linear(x, w_b, "head", tenant="B"), y_b)
    ex.promote()
    assert jnp.array_equal(ex.linear(x, w_b2, "head", tenant="B"),
                           _cold(w_b2).linear(x, w_b2, "head"))
    assert ex.fingerprint(tenant="A") == _cold(w_a).fingerprint()


def test_full_bank_swap_falls_back_to_in_place_and_pauses_tenant():
    ws = {t: _w(i + 40, 64, 32) for i, t in enumerate("ABC")}
    x = jax.random.normal(jax.random.PRNGKey(43), (2, 64))
    ex = CrossbarExecutor(CFG3)
    for t in "ABC":
        ex.program_params({"head": ws[t]}, tenant=t)
    plan = ex.begin_swap({"head": ws["C"] + 0.1}, tenant="C")
    assert plan.in_place                      # bank full -> in-place
    ex.write_chunks(1)
    with pytest.raises(RuntimeError, match="mid-write"):
        ex.linear(x, ws["C"], "head", tenant="C")
    # A and B flow through the window untouched
    for t in "AB":
        assert jnp.array_equal(ex.linear(x, ws[t], "head", tenant=t),
                               _cold(ws[t]).linear(x, ws[t], "head"))
    ex.abort_swap()
    # the anchor tenant never pauses: with a full bank its swap is refused
    with pytest.raises(RuntimeError, match="no free write plane"):
        ex.begin_swap({"head": ws["A"] + 0.1}, tenant="A")


def test_eviction_during_swap_raises_instead_of_discarding_shadow():
    """Regression for the PlanePair.clear_twin race: evicting a resident
    while a SwapPlan is in flight over the same weights must raise (the
    old API silently discarded the in-flight staged shadow); abort_swap
    first, then eviction proceeds."""
    w_a, w_b = _w(50, 64, 32), _w(51, 64, 32)
    ex = CrossbarExecutor(CFG3)
    ex.program_params({"head": w_a})
    ex.program_params({"head": w_b}, tenant="B")
    plan = ex.begin_swap({"head": w_a + 0.1}, tenant="A")   # staged
    ex.write_chunks(1)
    with pytest.raises(RuntimeError, match="abort_swap"):
        ex.evict_tenant("B")
    assert ex.swap_in_flight and not plan.done
    ex.abort_swap()
    ex.evict_tenant("B")
    assert ex.tenants == ["A"]
    # the aborted staging slots were released: a fresh swap still works
    ex.swap({"head": w_a + 0.1})
    assert ex.version("A") == 2


def test_new_tenant_can_deploy_during_swap_when_a_plane_is_free():
    """At N >= 3 a staged swap reserves ONE plane; a first-time tenant
    may still claim another free plane mid-window (the N=2 refusal was
    capacity, not policy)."""
    w_a, w_b = _w(60, 64, 32), _w(61, 64, 32)
    ex = CrossbarExecutor(CFG3)
    ex.program_params({"head": w_a})
    plan = ex.begin_swap({"head": w_a + 0.1})  # staged: 1 resident+1 staging
    ex.program_params({"head": w_b}, tenant="B")   # 3rd plane is free
    assert ex.tenants == ["A", "B"]
    # now the stack is saturated: a third new tenant must be refused
    with pytest.raises(RuntimeError, match="while a hot-swap is in"):
        ex.program_params({"head": _w(62, 64, 32)}, tenant="C")
    while not plan.done:
        ex.write_chunks(8)
    ex.promote()
    assert ex.version("A") == 2
    ex.program_params({"head": _w(62, 64, 32)}, tenant="C")
    assert ex.tenants == ["A", "B", "C"]


# -- modes: N-high BankState ---------------------------------------------------

def _stack_cfg():
    return modes.StackConfig(rows_per_plane=8, n_cols=6)


def test_bank_state_n2_matches_stack_state_ops():
    cfg = _stack_cfg()
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(0), 4)
    g_top = jax.random.uniform(k1, (8, 6), minval=1e-5, maxval=1e-4)
    g_bot = jax.random.uniform(k2, (8, 6), minval=1e-5, maxval=1e-4)
    g_new = jax.random.uniform(k3, (8, 6), minval=1e-5, maxval=1e-4)
    v = jax.random.uniform(k4, (8,), maxval=1.0)
    pair = StackState(g_top, g_bot, jnp.bool_(True))
    bank = modes.bank_from_pair(pair)
    # read parity (leakage included)
    assert jnp.array_equal(modes.bank_read(bank, v, cfg),
                           modes.deepnet_read(pair, v, cfg))
    # one full pipeline beat: write-inactive + swap == write-ring + advance
    i_pair, pair2 = modes.deepnet_layer(pair, v, g_new, cfg)
    i_bank, bank2 = modes.bank_layer(bank, v, g_new, cfg)
    assert jnp.array_equal(i_pair, i_bank)
    assert jnp.array_equal(bank2.g[0], pair2.g_top)
    assert jnp.array_equal(bank2.g[1], pair2.g_bot)
    assert int(bank2.read_idx) == (0 if bool(pair2.read_top) else 1)


def test_bank_state_n3_ring_rotates_and_isolates_planes():
    cfg = _stack_cfg()
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    g = jnp.stack([jax.random.uniform(k, (8, 6), minval=1e-5, maxval=1e-4)
                   for k in ks[:3]])
    v = jax.random.uniform(ks[3], (8,), maxval=1.0)
    bank = BankState(g, jnp.int32(0))
    # reads address exactly the active plane
    for idx in range(3):
        b = modes.bank_set_read(bank, idx)
        one = StackState(g[idx], g[idx], jnp.bool_(True))
        assert jnp.array_equal(
            modes.bank_read(b, v, cfg, include_leakage=False),
            modes.deepnet_read(one, v, cfg, include_leakage=False))
    # writing the ring's next plane never perturbs the other two
    g_new = jax.random.uniform(ks[4], (8, 6), minval=1e-5, maxval=1e-4)
    b2 = modes.bank_write_plane(bank, modes.bank_write_idx(bank), g_new)
    assert jnp.array_equal(b2.g[1], g_new)
    assert jnp.array_equal(b2.g[0], g[0])
    assert jnp.array_equal(b2.g[2], g[2])
    # the ring advances mod N
    b3 = modes.bank_advance(modes.bank_advance(modes.bank_advance(bank)))
    assert int(b3.read_idx) == 0
    # two concurrently writing planes leak twice the single-plane term
    lk1 = modes.bank_read(bank, v, cfg, n_writing=1)
    lk2 = modes.bank_read(bank, v, cfg, n_writing=2)
    lk0 = modes.bank_read(bank, v, cfg, include_leakage=False)
    assert jnp.allclose(lk2 - lk0, 2.0 * (lk1 - lk0), rtol=1e-6)


# -- QoS slot allocation -------------------------------------------------------

def test_split_slots_even_weights_keep_historical_split():
    assert _split_slots(2, {"A": 1.0}) == {"A": 2}
    assert _split_slots(2, {"A": 1.0, "B": 1.0}) == {"A": 2, "B": 2}
    assert _split_slots(3, {"A": 1.0, "B": 1.0, "C": 1.0}) == {
        "A": 3, "B": 3, "C": 3}


def test_split_slots_weighted_with_starvation_guard():
    # 2:1:1 at 4 base slots -> exact 6/3/3 of the 12-slot budget
    assert _split_slots(4, {"A": 2.0, "B": 1.0, "C": 1.0}) == {
        "A": 6, "B": 3, "C": 3}
    # extreme skew: the tiny-weight tenant still gets >= 1 slot
    alloc = _split_slots(2, {"A": 100.0, "B": 0.001})
    assert alloc["B"] >= 1 and sum(alloc.values()) == 4
    # budget conserved under awkward ratios
    alloc = _split_slots(2, {"A": 2.0, "B": 1.0, "C": 1.0})
    assert sum(alloc.values()) == 6 and alloc["A"] == 3
    assert min(alloc.values()) >= 1


# -- scheduler: N-tenant serving ----------------------------------------------

def _params_trio():
    model = build_model(TINY3)
    pa = model.init(jax.random.PRNGKey(0))
    pb = finetune_delta(pa, scale=0.05, seed=7)
    pc = finetune_delta(pa, scale=0.08, seed=13)
    return model, {"A": pa, "B": pb, "C": pc}


def _submit(sched, model_id, n_req, max_new=4, seed0=0):
    for i in range(n_req):
        p = jax.random.randint(jax.random.PRNGKey(seed0 + i), (5,), 0,
                               TINY3.vocab - 1).astype(jnp.int32)
        sched.submit(Request(rid=seed0 + i, prompt=p, max_new=max_new,
                             model_id=model_id))


def _drain(sched, n_req, max_steps=300):
    done, steps = [], 0
    while len(done) < n_req and steps < max_steps:
        done += sched.step()
        steps += 1
    return done


def test_three_tenant_bank_matches_three_dedicated_schedulers():
    """The acceptance property: all three tenants' token streams from
    ONE 3-plane-bank scheduler are bit-identical to three dedicated
    single-tenant schedulers — at a third of the physical devices."""
    model_m, trio = _params_trio()
    sched = BatchScheduler(model_m, trio["A"], n_slots=2, max_len=24,
                           tenants=dict(trio))
    assert sched.tenants == ["A", "B", "C"]
    for i, t in enumerate("ABC"):
        _submit(sched, t, 2, seed0=100 * i)
    done = _drain(sched, 6)
    assert len(done) == 6
    mux = {r.rid: r.out for r in done}

    for i, t in enumerate("ABC"):
        model_d = build_model(TINY3)
        ded = BatchScheduler(model_d, trio[t], n_slots=2, max_len=24)
        _submit(ded, "A", 2, seed0=100 * i)
        for r in _drain(ded, 2):
            assert r.out == mux[r.rid], (t, r.rid)
        assert (model_d.executor.n_devices_physical
                == model_m.executor.n_devices_physical)


def test_tenant_c_swap_under_a_b_traffic_drops_nothing():
    """begin_swap on tenant C with A+B traffic in flight: zero A/B
    requests drop, their streams are bit-identical to a swap-free run,
    and C's identity is never a partially written plane (exactly old-C
    before the boundary, exactly new-C after)."""
    model, trio = _params_trio()
    pc2 = finetune_delta(trio["A"], scale=0.11, seed=31)

    model_r, trio_r = _params_trio()
    ref = BatchScheduler(model_r, trio_r["A"], n_slots=2, max_len=24,
                         tenants=dict(trio_r))
    _submit(ref, "A", 2, max_new=8, seed0=0)
    _submit(ref, "B", 2, max_new=8, seed0=100)
    ref_out = {r.rid: r.out for r in _drain(ref, 4)}

    sched = BatchScheduler(model, trio["A"], n_slots=2, max_len=24,
                           tenants=dict(trio))
    _submit(sched, "A", 2, max_new=8, seed0=0)
    _submit(sched, "B", 2, max_new=8, seed0=100)
    _submit(sched, "C", 1, max_new=3, seed0=200)
    done = []
    for _ in range(2):
        done += sched.step()
    ex = model.executor
    fp_c_old = ex.fingerprint(tenant="C")
    cold_c2 = CrossbarExecutor(TINY3.xbar)
    cold_c2.program_params(pc2)
    fp_c_new = cold_c2.fingerprint()

    sched.begin_hot_swap(pc2, chunks_per_step=6, tenant="C")
    assert sched._lanes["C"].paused           # full bank -> in-place
    fps_c, steps = [], 0
    while (sched.swap_in_flight or len(done) < 5) and steps < 300:
        done += sched.step()
        fps_c.append(ex.fingerprint(tenant="C"))
        steps += 1
    assert len(done) == 5                     # zero dropped, any tenant
    for r in done:
        if r.model_id in ("A", "B"):
            assert r.out == ref_out[r.rid]    # A/B streams unperturbed
            assert len(r.out) == 8
    # never a partially written plane: old-C then new-C, nothing else
    assert set(fps_c) <= {fp_c_old, fp_c_new}
    flip = fps_c.index(fp_c_new)
    assert fps_c == [fp_c_old] * flip + [fp_c_new] * (len(fps_c) - flip)
    assert not sched._lanes["C"].paused
    (rep,) = sched.swap_history
    assert rep["tenant"] == "C" and rep["swap_mode"] == "in_place"
    assert rep["stack_planes"] == 3
    assert rep["decode_steps_during_swap"] > 0


def test_qos_weights_shift_served_token_shares():
    """2:1:1 weights at 4 base slots -> 6/3/3 slot quotas; with all
    lanes saturated the served-token shares land on 50/25/25 within
    +-10 % (the acceptance figure)."""
    model, trio = _params_trio()
    sched = BatchScheduler(
        model, trio["A"], n_slots=4, max_len=24,
        tenants={"A": (trio["A"], 2.0), "B": (trio["B"], 1.0),
                 "C": (trio["C"], 1.0)})
    q = sched.qos_report()
    assert {t: q[t]["slots"] for t in q} == {"A": 6, "B": 3, "C": 3}
    for i, t in enumerate("ABC"):
        _submit(sched, t, 30, max_new=4, seed0=100 * i)
    for _ in range(10):                       # lanes stay saturated
        sched.step()
    q = sched.qos_report()
    total = sum(q[t]["tokens_served"] for t in q)
    assert total > 0
    for t, want in (("A", 0.5), ("B", 0.25), ("C", 0.25)):
        assert abs(q[t]["token_share"] - want) <= 0.10 * 1.0, (t, q)
    # heavier lane really served ~2x either light lane
    assert q["A"]["tokens_served"] > 1.5 * q["B"]["tokens_served"]


def test_live_deployed_tenant_joins_qos_split_at_weight_one():
    """A tenant live-deployed after construction must enter the QoS
    split like any weight-1.0 lane (same proportional quota rule), not
    at the full base slot width."""
    model, trio = _params_trio()
    sched = BatchScheduler(model, trio["A"], n_slots=6, max_len=24,
                           tenants={"A": (trio["A"], 2.0),
                                    "B": (trio["B"], 1.0)})
    q = sched.qos_report()
    assert q["A"]["slots"] == 8 and q["B"]["slots"] == 4
    hs = sched.begin_hot_swap(trio["C"], chunks_per_step=50, tenant="C")
    assert not hs.plan.in_place          # free plane: staged live deploy
    steps = 0
    while sched.swap_in_flight and steps < 50:
        sched.step()
        steps += 1
    q = sched.qos_report()
    assert sorted(q) == ["A", "B", "C"]
    assert q["C"]["weight"] == 1.0
    assert q["C"]["slots"] == q["B"]["slots"]   # equal weight, equal quota


def test_qos_weight_validation():
    model, trio = _params_trio()
    with pytest.raises(ValueError, match="weight"):
        BatchScheduler(model, trio["A"], n_slots=2, max_len=24,
                       tenants={"A": (trio["A"], 0.0)})


def test_set_weights_reweights_qos_live_at_a_step_boundary():
    """Dynamic QoS: set_weights mid-stream re-splits the slot quotas
    AND page budgets, updates the serve_qos_* gauges, and the served-
    token shares shift from that boundary on — with the compiled lane
    width as the growth ceiling (no re-trace, no dropped cache)."""
    model, trio = _params_trio()
    sched = BatchScheduler(
        model, trio["A"], n_slots=4, max_len=24, kv_pages=12,
        tenants={"A": (trio["A"], 1.0), "B": (trio["B"], 1.0)})
    q = sched.qos_report()
    assert q["A"]["slots"] == q["B"]["slots"] == 4
    assert q["A"]["page_budget"] == q["B"]["page_budget"] == 12
    for i, t in enumerate("AB"):
        _submit(sched, t, 30, max_new=4, seed0=100 * i)
    for _ in range(4):
        sched.step()
    before = sched.qos_report()
    sched.set_weights({"A": 3.0, "B": 1.0})
    q = sched.qos_report()
    # 3:1 at 4 base slots -> raw 6/2, but growth clamps to the compiled
    # width (4): A stays at its lane width, B shrinks to 2
    assert q["A"]["slots"] == 4 and q["B"]["slots"] == 2
    assert q["A"]["page_budget"] == 12        # clamped to pool size
    assert q["B"]["page_budget"] == 6         # 1/4 of 2 * 12
    # gauges followed
    assert sched.metrics.total("serve_qos_slot_quota", tenant="B") == 2
    assert sched.metrics.total("serve_qos_page_budget", tenant="B") == 6
    assert sched.metrics.total("serve_qos_weight", tenant="A") == 3.0
    for _ in range(16):
        sched.step()
    q = sched.qos_report()
    dA = q["A"]["tokens_served"] - before["A"]["tokens_served"]
    dB = q["B"]["tokens_served"] - before["B"]["tokens_served"]
    assert dA > 1.5 * dB       # the re-weight really shifted service
    # validation still guards the inputs
    with pytest.raises(KeyError, match="no lane"):
        sched.set_weights({"Z": 1.0})
    with pytest.raises(ValueError, match="weight"):
        sched.set_weights({"A": 0.0})


# -- ragged window admission --------------------------------------------------

def test_batched_admission_is_bit_exact_with_serial_admission():
    """Several prompts prefilling together inside one window batch must
    produce streams bit-identical to one-at-a-time admissions
    (n_slots=1 forces serial batch-of-1 occupancy)."""
    model_c, trio = _params_trio()
    sched_c = BatchScheduler(model_c, trio["A"], n_slots=3, max_len=24)
    _submit(sched_c, "A", 3, max_new=5, seed0=0)
    done_c = {r.rid: r.out for r in _drain(sched_c, 3)}

    model_s, trio_s = _params_trio()
    sched_s = BatchScheduler(model_s, trio_s["A"], n_slots=1, max_len=24)
    _submit(sched_s, "A", 3, max_new=5, seed0=0)
    done_s = {r.rid: r.out for r in _drain(sched_s, 3)}
    assert done_c == done_s


def test_mixed_length_admission_stays_bit_exact_on_crossbar():
    """A FIFO run mixing prompt lengths (spanning the old 8- and
    16-wide buckets) streams through the one window closure bit-exact
    with the unbatched greedy reference."""
    from repro.serve.engine import greedy_generate
    model, trio = _params_trio()
    sched = BatchScheduler(model, trio["A"], n_slots=4, max_len=32)
    refs = {}
    for rid, plen in enumerate((5, 7, 12, 4)):
        p = jax.random.randint(jax.random.PRNGKey(70 + rid), (plen,), 0,
                               TINY3.vocab - 1).astype(jnp.int32)
        refs[rid] = [int(t) for t in greedy_generate(
            model, trio["A"], {"tokens": p[None]}, max_new=4,
            max_len=32)[0]]
        sched.submit(Request(rid=rid, prompt=p, max_new=4))
    done = {r.rid: r.out for r in _drain(sched, 4)}
    assert done == refs
