"""Deep-net-mode serving subsystem: ping-pong plane pairs, chunked
shadow-plane programming, fingerprint/versioning API, atomic promotion,
and the BatchScheduler hot-swap integration."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import engine as eng
from repro.core import planes
from repro.core.engine import EngineConfig
from repro.core.executor import CrossbarExecutor
from repro.core.planes import ChunkedProgram
from repro.core.quant import QuantConfig
from repro.models.model import build_model
from repro.serve.engine import BatchScheduler, Request
from repro.serve.hotswap import HotSwapper, finetune_delta, overlap_report

CFG = EngineConfig(tile_rows=32, tile_cols=32, mode="deepnet",
                   quant=QuantConfig(w_bits=4, in_bits=8, adc_bits=10))
HIFI = EngineConfig(tile_rows=128, tile_cols=128, mode="deepnet",
                    quant=QuantConfig(w_bits=8, in_bits=10, adc_bits=14))


def _w(key, k, n):
    return jax.random.normal(jax.random.PRNGKey(key), (k, n)) * 0.3


def _ft(params):
    return finetune_delta(params)


# -- chunked shadow-plane programming -----------------------------------------

@pytest.mark.parametrize("k,n,per_channel", [
    (96, 80, True), (64, 33, True), (33, 17, False)])
def test_chunked_program_bit_exact_vs_engine_program(k, n, per_channel):
    """A chunk-by-chunk shadow write must assemble the exact
    ProgrammedLinear the one-shot path builds."""
    cfg = dataclasses.replace(
        CFG, quant=dataclasses.replace(CFG.quant, per_channel=per_channel))
    w = _w(k + n, k, n)
    cp = ChunkedProgram("tile", w, cfg)
    assert cp.total_chunks == -(-k // cfg.tile_rows)
    with pytest.raises(RuntimeError, match="unwritten"):
        cp.finish()
    while not cp.done:
        cp.write_chunk()
    got, want = cp.finish(), eng.program(w, cfg)
    assert jnp.array_equal(got.pos, want.pos)
    assert jnp.array_equal(got.neg, want.neg)
    assert jnp.array_equal(jnp.asarray(got.w_scale),
                           jnp.asarray(want.w_scale))
    assert (got.k, got.n) == (want.k, want.n)


def test_write_verify_catches_corrupt_assembly():
    """A mis-assembled shadow plane (here: chunk order scrambled) must
    fail write-verify against the independent one-shot programming."""
    w = _w(11, 96, 48)
    cp = ChunkedProgram("tile", w, CFG)
    while not cp.done:
        cp.write_chunk()
    cp.verify(cp.finish())                    # clean assembly passes
    cp._pos[0], cp._pos[1] = cp._pos[1], cp._pos[0]
    with pytest.raises(RuntimeError, match="write-verify failed"):
        cp.verify(cp.finish())


# -- fingerprint / version public API -----------------------------------------

def test_fingerprint_and_programmed_version_api():
    w = _w(0, 64, 48)
    ex = CrossbarExecutor(CFG)
    assert ex.programmed_version == 0
    ex.program_params({"head": w})
    assert ex.programmed_version == 1
    # content-addressed: a second executor over the same weights agrees
    ex2 = CrossbarExecutor(CFG)
    ex2.program_params({"head": jnp.array(w)})
    assert ex.fingerprint("head") == ex2.fingerprint("head")
    assert ex.fingerprint() == ex2.fingerprint()
    assert ex.fingerprints() == {"head": ex.fingerprint("head")}
    # ...and different weights disagree
    ex3 = CrossbarExecutor(CFG)
    ex3.program_params({"head": w + 0.5})
    assert ex.fingerprint() != ex3.fingerprint()
    # re-walk (cache hit) does not bump the version
    ex.program_params({"head": w})
    assert ex.programmed_version == 1


def test_swap_serves_new_weights_bit_exact_and_bumps_version():
    w_a, w_b = _w(1, 80, 48), _w(2, 80, 48)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 80))
    ex = CrossbarExecutor(CFG)
    ex.program_params({"head": w_a})
    y_a = ex.linear(x, w_a, "head")
    stats = ex.swap({"head": w_b})
    assert stats["n_chunks"] == 3 and stats["programmed_version"] == 2
    assert ex.programmed_version == 2 and ex.stats["swaps"] == 1
    cold = CrossbarExecutor(CFG)
    cold.program_params({"head": w_b})
    assert jnp.array_equal(ex.linear(x, w_b, "head"),
                           cold.linear(x, w_b, "head"))
    assert ex.fingerprint() == cold.fingerprint()
    # swap back: the same stacked pair ping-pongs in the other direction
    ex.swap({"head": w_a})
    assert jnp.array_equal(ex.linear(x, w_a, "head"), y_a)
    assert ex.programmed_version == 3


def test_swap_validation_and_atomicity():
    w = _w(4, 64, 32)
    ex = CrossbarExecutor(CFG)
    with pytest.raises(RuntimeError, match="program_params"):
        ex.begin_swap({"head": w})
    ex.program_params({"head": w})
    with pytest.raises(ValueError, match="shape"):
        ex.begin_swap({"head": _w(5, 32, 32)})
    with pytest.raises(ValueError, match="no resident tiles"):
        ex.begin_swap({"head": w, "blocks": {"0": {"mlp": {"wi": w}}}})
    plan = ex.begin_swap({"head": w + 0.1})
    with pytest.raises(RuntimeError, match="already in flight"):
        ex.begin_swap({"head": w + 0.2})
    # promotion is all-or-nothing: refuses while chunks are unwritten
    ex.write_chunks(1)
    assert not plan.done
    with pytest.raises(RuntimeError, match="unwritten"):
        ex.promote()
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 64))
    # mid-swap reads still serve the OLD read-active plane
    cold = CrossbarExecutor(CFG)
    cold.program_params({"head": w})
    assert jnp.array_equal(ex.linear(x, w, "head"),
                           cold.linear(x, w, "head"))
    # abort drops the staged shadow and the pair keeps serving
    ex.abort_swap()
    assert not ex.swap_in_flight
    assert jnp.array_equal(ex.linear(x, w, "head"),
                           cold.linear(x, w, "head"))
    ex.swap({"head": w + 0.1})   # a fresh swap still works after abort
    assert ex.programmed_version == 2


# -- write-plane leakage during overlap ----------------------------------------

def test_write_leakage_is_common_mode_and_below_adc_resolution():
    """Paper Fig. 3c: the only coupling of an in-flight write into the
    read-out is N1 subthreshold leakage — orders below one ADC code."""
    cfg = dataclasses.replace(CFG, swap_leakage=True)
    leak = planes.write_leak_codes(cfg)
    assert 0.0 < leak < 1e-3   # far below one pre-ADC code unit
    w = _w(7, 64, 48)
    x = jax.random.normal(jax.random.PRNGKey(8), (4, 64))
    ex = CrossbarExecutor(cfg)
    ex.program_params({"head": w})
    y_clean = ex.linear(x, w, "head")
    ex.begin_swap({"head": w + 0.1})   # overlap window opens
    y_overlap = ex.linear(x, w, "head")
    ex.abort_swap()
    # common-mode through differential columns + below ADC resolution:
    # the perturbation must round away entirely
    assert jnp.array_equal(y_overlap, y_clean)
    # the engine hook itself is live: a code-scale leak does perturb
    pw = eng.program(w, cfg)
    y_big = eng.matmul_reference(x, pw, cfg, leak_codes=3.7)
    assert not jnp.array_equal(y_big, eng.matmul_reference(x, pw, cfg))


# -- device-time model ---------------------------------------------------------

def test_overlap_report_matches_paper_figures():
    """10-bit reads vs 250 ns writes: steady-state overlap = 1 - 250/350
    = 28.6 % ~ paper's 29 %; overlapped serving >= 2x stop-the-world."""
    cfg = HIFI   # in_bits = 10: the paper's operating point
    rep = overlap_report(cfg, n_grids=15, n_chunks=17, batch_size=2)
    assert abs(rep["overlap_frac_steady_state"] - 0.29) <= 0.02
    assert rep["within_2pts_of_paper"]
    assert rep["throughput_ratio_overlap_vs_stop_world"] >= 2.0
    assert rep["sustains_2x_during_swap"]
    # window algebra: overlapped hides the whole write under reads
    assert rep["device_swap_window_overlapped_s"] == pytest.approx(
        17 * cfg.params.t_write)
    assert rep["device_swap_window_stop_world_s"] == pytest.approx(
        17 * cfg.params.t_write + rep["device_decode_step_s"])


# -- scheduler integration -----------------------------------------------------

def _crossbar_cfg():
    return dataclasses.replace(get_config("qwen3_4b", smoke=True),
                               backend="crossbar", xbar=HIFI,
                               dtype=jnp.float32)


@pytest.mark.slow
def test_scheduler_hot_swap_zero_dropped_requests():
    cfg = _crossbar_cfg()
    model = build_model(cfg)
    params_a = model.init(jax.random.PRNGKey(0))
    params_b = _ft(params_a)
    sched = BatchScheduler(model, params_a, n_slots=2, max_len=48)
    for rid in range(4):
        p = jax.random.randint(jax.random.PRNGKey(rid), (6,), 0,
                               cfg.vocab - 1).astype(jnp.int32)
        sched.submit(Request(rid=rid, prompt=p, max_new=12))
    done, steps = [], 0
    while steps < 4:
        done += sched.step()
        steps += 1
    hs = sched.begin_hot_swap(params_b, chunks_per_step=4)
    assert sched.swap_in_flight
    with pytest.raises(RuntimeError, match="already in flight"):
        sched.begin_hot_swap(params_b)
    while (len(done) < 4 or sched.swap_in_flight) and steps < 200:
        done += sched.step()
        steps += 1
    # zero dropped: every request completed across the swap boundary
    assert len(done) == 4
    assert all(len(r.out) >= 12 for r in done)
    # the flip landed: executor serves the new checkpoint's content
    assert model.executor.programmed_version == 2
    cold = CrossbarExecutor(HIFI)
    cold.program_params(params_b)
    assert model.executor.fingerprint() == cold.fingerprint()
    # report recorded with the acceptance figures
    (rep,) = sched.swap_history
    assert rep["sustains_2x_during_swap"]
    assert rep["within_2pts_of_paper"]
    assert hs.promoted and hs.wall_swap_s > 0


def test_stop_the_world_swap_records_swap_history():
    """Regression: the blocking path must land in ``swap_history`` like
    the overlapped path does, so hotswap_bench.py and operators see
    every deploy regardless of policy."""
    import jax.numpy as jnp
    from repro.models.model import ModelConfig
    tiny = ModelConfig(
        name="tiny", family="dense", n_layers=2, d_model=32, n_heads=2,
        n_kv=2, head_dim=16, d_ff=64, vocab=128, backend="crossbar",
        dtype=jnp.float32,
        xbar=dataclasses.replace(CFG))
    model = build_model(tiny)
    params_a = model.init(jax.random.PRNGKey(0))
    params_b = _ft(params_a)
    sched = BatchScheduler(model, params_a, n_slots=2, max_len=24)
    p = jax.random.randint(jax.random.PRNGKey(1), (4,), 0,
                           tiny.vocab - 1).astype(jnp.int32)
    sched.submit(Request(rid=0, prompt=p, max_new=6))
    sched.step()
    stats = sched.stop_the_world_swap(params_b)
    assert stats["programmed_version"] == 2
    (rep,) = sched.swap_history
    assert rep["policy"] == "stop_the_world" and rep["tenant"] == "A"
    assert rep["decode_steps_during_swap"] == 0    # serving stalled
    assert rep["wall_swap_s"] > 0
    assert rep["n_chunks"] == stats["n_chunks"]
    # serving resumes on the new planes and the request still completes
    done, steps = [], 0
    while not done and steps < 20:
        done += sched.step()
        steps += 1
    assert done and len(done[0].out) == 6
    cold = CrossbarExecutor(tiny.xbar)
    cold.program_params(params_b)
    assert model.executor.fingerprint() == cold.fingerprint()


def test_scheduler_rejects_hot_swap_on_digital_backend():
    cfg = get_config("qwen3_4b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = BatchScheduler(model, params, n_slots=2, max_len=32)
    with pytest.raises(RuntimeError, match="crossbar"):
        sched.begin_hot_swap(params)


def test_hotswapper_drives_executor_without_scheduler():
    w = _w(9, 96, 64)
    ex = CrossbarExecutor(CFG)
    ex.program_params({"head": w})
    hs = HotSwapper(ex, {"head": w + 0.05}, chunks_per_step=2)
    assert hs.remaining == 3
    assert hs.step() == 1        # two chunks written
    assert not hs.done
    assert hs.step() == 0        # last chunk
    assert hs.done
    hs.promote()
    assert ex.programmed_version == 2
    assert hs.step() == 0        # idempotent after promotion
