"""Property test: executor-scale deep-net overlap correctness.

A hot-swap mid-generation must be bit-exact with (a) the pre-swap
weights for every token produced before the flip and (b) the post-swap
weights for every token after it, with no decode step ever reading a
mixed set of planes — the serving-tier analogue of pipeline.py's
"the pipeline reorders *time*, not *math*" invariant.
"""
import jax
import jax.numpy as jnp
import pytest

# randomized sweep under hypothesis when available (the [test] extra);
# otherwise a fixed parametrized sweep of the same property
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.engine import EngineConfig  # noqa: E402
from repro.core.executor import CrossbarExecutor  # noqa: E402
from repro.core.quant import QuantConfig  # noqa: E402
from repro.models.model import ModelConfig, build_model  # noqa: E402
from repro.serve.hotswap import HotSwapper  # noqa: E402

TINY = ModelConfig(
    name="tiny", family="dense", n_layers=2, d_model=32, n_heads=2,
    n_kv=2, head_dim=16, d_ff=64, vocab=128, backend="crossbar",
    dtype=jnp.float32,
    xbar=EngineConfig(tile_rows=32, tile_cols=32, mode="deepnet",
                      quant=QuantConfig(w_bits=4, in_bits=6, adc_bits=12)))

N_STEPS = 8


def _params_pair(delta_seed):
    model = build_model(TINY)
    params_a = model.init(jax.random.PRNGKey(0))
    leaves, tdef = jax.tree_util.tree_flatten(params_a)
    params_b = jax.tree_util.tree_unflatten(tdef, [
        w + 0.05 * jax.random.normal(
            jax.random.fold_in(jax.random.PRNGKey(delta_seed), i), w.shape)
        for i, w in enumerate(leaves)])
    return model, params_a, params_b


def _prefill(model, params, prompt):
    cache = model.init_cache(1, 32)
    logits, cache = model.prefill(params, {"tokens": prompt[None]}, cache)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    return tok, cache


def _decode_run(model, params, tok, cache, n):
    toks = []
    for _ in range(n):
        logits, cache = model.decode_step(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        toks.append(int(tok[0, 0]))
    return toks, tok, cache


def _check_swap_mid_generation(swap_begin, chunks_per_step, delta_seed):
    model, params_a, params_b = _params_pair(delta_seed)
    prompt = jax.random.randint(jax.random.PRNGKey(delta_seed % 97),
                                (5,), 0, TINY.vocab - 1).astype(jnp.int32)

    # reference fingerprints of each checkpoint's full plane set
    ref_a = CrossbarExecutor(TINY.xbar)
    ref_a.program_params(params_a)
    fp_a = ref_a.fingerprint()
    ref_b = CrossbarExecutor(TINY.xbar)
    ref_b.program_params(params_b)
    fp_b = ref_b.fingerprint()
    assert fp_a != fp_b

    # -- hot-swapped generation -------------------------------------------
    ex = model.executor
    ex.program_params(params_a)
    tok, cache = _prefill(model, params_a, prompt)
    tok0 = tok
    cur = params_a
    hs = None
    flip_at = None           # index of the first post-flip decode step
    toks, fps = [], []
    snap = (tok, cache)      # state entering the next decode step
    for i in range(N_STEPS):
        if i == swap_begin:
            hs = HotSwapper(ex, params_b, chunks_per_step=chunks_per_step)
        if hs is not None and not hs.promoted:
            hs.step()        # shadow chunks program BETWEEN decode steps
            if hs.done:
                cur = hs.promote()
                flip_at = i
                snap_flip = snap
        fps.append(ex.fingerprint())
        logits, cache = model.decode_step(cur, tok, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        toks.append(int(tok[0, 0]))
        snap = (tok, cache)

    # no mixed-plane reads: every decode step saw exactly one checkpoint's
    # plane set, and the flip point separates them cleanly
    assert set(fps) <= {fp_a, fp_b}
    if flip_at is None:
        assert fps == [fp_a] * N_STEPS
    else:
        assert fps == [fp_a] * flip_at + [fp_b] * (N_STEPS - flip_at)

    # (a) pre-flip tokens are bit-exact with a pure params_a generation
    model_a = build_model(TINY)
    model_a.executor.program_params(params_a)
    tok_a, cache_a = _prefill(model_a, params_a, prompt)
    assert jnp.array_equal(tok_a, tok0)
    toks_ref_a, _, _ = _decode_run(model_a, params_a, tok_a, cache_a,
                                   N_STEPS)
    pre = N_STEPS if flip_at is None else flip_at
    assert toks[:pre] == toks_ref_a[:pre]

    # (b) post-flip tokens are bit-exact with params_b continuing from the
    # exact pre-flip state (cold executor programmed with params_b)
    if flip_at is not None:
        model_b = build_model(TINY)
        model_b.executor.program_params(params_b)
        tok_f, cache_f = snap_flip
        toks_ref_b, _, _ = _decode_run(model_b, params_b, tok_f, cache_f,
                                       N_STEPS - flip_at)
        assert toks[flip_at:] == toks_ref_b


if HAVE_HYPOTHESIS:
    @pytest.mark.slow
    @settings(max_examples=5, deadline=None)
    @given(st.integers(0, 4), st.integers(5, 20),
           st.integers(1, 2 ** 31 - 1))
    def test_swap_mid_generation_is_bit_exact_with_no_mixed_plane_reads(
            swap_begin, chunks_per_step, delta_seed):
        _check_swap_mid_generation(swap_begin, chunks_per_step, delta_seed)
else:
    @pytest.mark.slow
    @pytest.mark.parametrize("swap_begin,chunks_per_step,delta_seed", [
        (0, 20, 1),        # instant flip before any pre-swap decode
        (2, 5, 12345),     # multi-step overlap window
        (4, 6, 999),       # late begin, promotion near the tail
    ])
    def test_swap_mid_generation_is_bit_exact_with_no_mixed_plane_reads(
            swap_begin, chunks_per_step, delta_seed):
        _check_swap_mid_generation(swap_begin, chunks_per_step, delta_seed)
