"""Property test: executor-scale deep-net overlap correctness.

A hot-swap mid-generation must be bit-exact with (a) the pre-swap
weights for every token produced before the flip and (b) the post-swap
weights for every token after it, with no decode step ever reading a
mixed set of planes — the serving-tier analogue of pipeline.py's
"the pipeline reorders *time*, not *math*" invariant.

A second property guards the overlap HOT PATH itself: with
``use_kernel=True`` the decode closure must lower the Pallas kernel (not
the reference scan), and the write window must reuse that same compiled
closure — the leak arrives as a traced argument, never as a re-trace.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

# randomized sweep under hypothesis when available (the [test] extra);
# otherwise a fixed parametrized sweep of the same property
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.engine import EngineConfig  # noqa: E402
from repro.core.executor import CrossbarExecutor  # noqa: E402
from repro.core.quant import QuantConfig  # noqa: E402
from repro.models.model import ModelConfig, build_model  # noqa: E402
from repro.serve.hotswap import HotSwapper  # noqa: E402

TINY = ModelConfig(
    name="tiny", family="dense", n_layers=2, d_model=32, n_heads=2,
    n_kv=2, head_dim=16, d_ff=64, vocab=128, backend="crossbar",
    dtype=jnp.float32,
    xbar=EngineConfig(tile_rows=32, tile_cols=32, mode="deepnet",
                      quant=QuantConfig(w_bits=4, in_bits=6, adc_bits=12)))

N_STEPS = 8


def _params_pair(delta_seed):
    model = build_model(TINY)
    params_a = model.init(jax.random.PRNGKey(0))
    leaves, tdef = jax.tree_util.tree_flatten(params_a)
    params_b = jax.tree_util.tree_unflatten(tdef, [
        w + 0.05 * jax.random.normal(
            jax.random.fold_in(jax.random.PRNGKey(delta_seed), i), w.shape)
        for i, w in enumerate(leaves)])
    return model, params_a, params_b


def _prefill(model, params, prompt):
    cache = model.init_cache(1, 32)
    logits, cache = model.prefill(params, {"tokens": prompt[None]}, cache)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    return tok, cache


def _decode_run(model, params, tok, cache, n):
    toks = []
    for _ in range(n):
        logits, cache = model.decode_step(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        toks.append(int(tok[0, 0]))
    return toks, tok, cache


def _check_swap_mid_generation(swap_begin, chunks_per_step, delta_seed):
    model, params_a, params_b = _params_pair(delta_seed)
    prompt = jax.random.randint(jax.random.PRNGKey(delta_seed % 97),
                                (5,), 0, TINY.vocab - 1).astype(jnp.int32)

    # reference fingerprints of each checkpoint's full plane set
    ref_a = CrossbarExecutor(TINY.xbar)
    ref_a.program_params(params_a)
    fp_a = ref_a.fingerprint()
    ref_b = CrossbarExecutor(TINY.xbar)
    ref_b.program_params(params_b)
    fp_b = ref_b.fingerprint()
    assert fp_a != fp_b

    # -- hot-swapped generation -------------------------------------------
    ex = model.executor
    ex.program_params(params_a)
    tok, cache = _prefill(model, params_a, prompt)
    tok0 = tok
    cur = params_a
    hs = None
    flip_at = None           # index of the first post-flip decode step
    toks, fps = [], []
    snap = (tok, cache)      # state entering the next decode step
    for i in range(N_STEPS):
        if i == swap_begin:
            hs = HotSwapper(ex, params_b, chunks_per_step=chunks_per_step)
        if hs is not None and not hs.promoted:
            hs.step()        # shadow chunks program BETWEEN decode steps
            if hs.done:
                cur = hs.promote()
                flip_at = i
                snap_flip = snap
        fps.append(ex.fingerprint())
        logits, cache = model.decode_step(cur, tok, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        toks.append(int(tok[0, 0]))
        snap = (tok, cache)

    # no mixed-plane reads: every decode step saw exactly one checkpoint's
    # plane set, and the flip point separates them cleanly
    assert set(fps) <= {fp_a, fp_b}
    if flip_at is None:
        assert fps == [fp_a] * N_STEPS
    else:
        assert fps == [fp_a] * flip_at + [fp_b] * (N_STEPS - flip_at)

    # (a) pre-flip tokens are bit-exact with a pure params_a generation
    model_a = build_model(TINY)
    model_a.executor.program_params(params_a)
    tok_a, cache_a = _prefill(model_a, params_a, prompt)
    assert jnp.array_equal(tok_a, tok0)
    toks_ref_a, _, _ = _decode_run(model_a, params_a, tok_a, cache_a,
                                   N_STEPS)
    pre = N_STEPS if flip_at is None else flip_at
    assert toks[:pre] == toks_ref_a[:pre]

    # (b) post-flip tokens are bit-exact with params_b continuing from the
    # exact pre-flip state (cold executor programmed with params_b)
    if flip_at is not None:
        model_b = build_model(TINY)
        model_b.executor.program_params(params_b)
        tok_f, cache_f = snap_flip
        toks_ref_b, _, _ = _decode_run(model_b, params_b, tok_f, cache_f,
                                       N_STEPS - flip_at)
        assert toks[flip_at:] == toks_ref_b


def _check_tenant_isolation_across_b_swap(swap_begin, chunks_per_step,
                                          delta_seed):
    """Multi-tenant analogue of the swap property: tenant A decodes
    throughout while tenant B's planes reprogram in chunks between A's
    steps.  A's fingerprint and token stream must be bit-exact with a
    dedicated A-only executor at EVERY step; B's identity must read as
    exactly old-B before the promotion boundary and exactly new-B after
    (never a mixture), with B's reads refused only inside the write
    window."""
    model, params_a, params_b = _params_pair(delta_seed)
    params_b2 = jax.tree_util.tree_map(
        lambda w: w + 0.03, params_b)
    prompt = jax.random.randint(jax.random.PRNGKey(delta_seed % 89),
                                (5,), 0, TINY.vocab - 1).astype(jnp.int32)

    ref_b = CrossbarExecutor(TINY.xbar)
    ref_b.program_params(params_b)
    fp_b = ref_b.fingerprint()
    ref_b2 = CrossbarExecutor(TINY.xbar)
    ref_b2.program_params(params_b2)
    fp_b2 = ref_b2.fingerprint()

    ex = model.executor
    ex.program_params(params_a)
    ex.program_params(params_b, tenant="B")
    fp_a = ex.fingerprint(tenant="A")

    # dedicated A-only reference generation
    model_a = build_model(TINY)
    model_a.executor.program_params(params_a)
    tok_r, cache_r = _prefill(model_a, params_a, prompt)
    toks_ref, _, _ = _decode_run(model_a, params_a, tok_r, cache_r, N_STEPS)

    tok, cache = _prefill(model, params_a, prompt)
    hs = None
    flip_at = None
    toks, fps_a, fps_b = [], [], []
    for i in range(N_STEPS):
        if i == swap_begin:
            hs = HotSwapper(ex, params_b2, chunks_per_step=chunks_per_step,
                            tenant="B")
        if hs is not None and not hs.promoted:
            hs.step()            # B's chunks program BETWEEN A's steps
            if hs.done:
                hs.promote()
                flip_at = i
        fps_a.append(ex.fingerprint(tenant="A"))
        if ex.swap_in_flight:
            # B's planes are mid-write: reads refused, identity unchanged
            with pytest.raises(RuntimeError, match="mid-write"):
                ex.linear(jnp.zeros((1, 32)), params_a["head"], "head",
                          tenant="B")
            fps_b.append(ex.fingerprint(tenant="B"))
        else:
            fps_b.append(ex.fingerprint(tenant="B"))
        logits, cache = model.decode_step(params_a, tok, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        toks.append(int(tok[0, 0]))

    # tenant A: bit-exact, fingerprint constant, untouched by B's deploy
    assert toks == toks_ref
    assert fps_a == [fp_a] * N_STEPS
    # tenant B: exactly old-B before the flip, exactly new-B after
    assert set(fps_b) <= {fp_b, fp_b2}
    if flip_at is None:
        assert fps_b == [fp_b] * N_STEPS
    else:
        assert fps_b == [fp_b] * flip_at + [fp_b2] * (N_STEPS - flip_at)


def test_kernel_path_serves_overlap_decode_without_retrace():
    """The serving closure lowers the Pallas kernel, and an active swap
    window (leak != 0) is served by the SAME compiled closure: no
    re-trace at the window boundary, and never the reference scan."""
    from repro.core import engine as eng
    from repro.serve.engine import BatchScheduler, Request

    kcfg = dataclasses.replace(TINY.xbar, use_kernel=True,
                               swap_leakage=True)
    cfg = dataclasses.replace(TINY, xbar=kcfg)
    model = build_model(cfg)
    params_a = model.init(jax.random.PRNGKey(0))
    leaves, tdef = jax.tree_util.tree_flatten(params_a)
    params_b = jax.tree_util.tree_unflatten(tdef, [
        w + 0.05 * jax.random.normal(
            jax.random.fold_in(jax.random.PRNGKey(2), i), w.shape)
        for i, w in enumerate(leaves)])

    sched = BatchScheduler(model, params_a, n_slots=1, max_len=32)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (5,), 0,
                                TINY.vocab - 1).astype(jnp.int32)
    sched.submit(Request(rid=0, prompt=prompt, max_new=24))

    # steady state: admission + decode trace once, all through the kernel
    before = dict(eng.path_calls)
    sched.step()
    assert eng.path_calls["kernel"] > before["kernel"]
    assert eng.path_calls["reference"] == before["reference"]

    # open the write window; the executor now reports a nonzero leak
    sched.begin_hot_swap(params_b, chunks_per_step=1)
    ex = model.executor
    assert float(ex.current_leak_codes()) > 0.0

    # overlap decode: the already-compiled kernel closure serves it —
    # zero new matmul dispatches of either kind (a re-trace would bump
    # "kernel"; a fallback would bump "reference")
    during = dict(eng.path_calls)
    sched.step()
    assert sched.swap_in_flight     # 1 chunk/step: window is still open
    assert eng.path_calls == during

    # drain the swap; post-promotion decode re-traces (new plane
    # constants) but still only ever lowers the kernel path
    while sched.swap_in_flight:
        sched.step()
    sched.step()
    assert eng.path_calls["reference"] == before["reference"]


if HAVE_HYPOTHESIS:
    @pytest.mark.slow
    @settings(max_examples=5, deadline=None)
    @given(st.integers(0, 4), st.integers(5, 20),
           st.integers(1, 2 ** 31 - 1))
    def test_swap_mid_generation_is_bit_exact_with_no_mixed_plane_reads(
            swap_begin, chunks_per_step, delta_seed):
        _check_swap_mid_generation(swap_begin, chunks_per_step, delta_seed)

    @pytest.mark.slow
    @settings(max_examples=5, deadline=None)
    @given(st.integers(0, 4), st.integers(5, 20),
           st.integers(1, 2 ** 31 - 1))
    def test_tenant_a_is_isolated_from_tenant_b_swap(
            swap_begin, chunks_per_step, delta_seed):
        _check_tenant_isolation_across_b_swap(swap_begin, chunks_per_step,
                                              delta_seed)
else:
    @pytest.mark.slow
    @pytest.mark.parametrize("swap_begin,chunks_per_step,delta_seed", [
        (0, 20, 1),        # instant flip before any pre-swap decode
        (2, 5, 12345),     # multi-step overlap window
        (4, 6, 999),       # late begin, promotion near the tail
    ])
    def test_swap_mid_generation_is_bit_exact_with_no_mixed_plane_reads(
            swap_begin, chunks_per_step, delta_seed):
        _check_swap_mid_generation(swap_begin, chunks_per_step, delta_seed)

    @pytest.mark.slow
    @pytest.mark.parametrize("swap_begin,chunks_per_step,delta_seed", [
        (0, 20, 1),        # instant B-flip before any decode
        (2, 5, 12345),     # multi-step write window under A's traffic
        (4, 6, 999),       # late begin, promotion near the tail
    ])
    def test_tenant_a_is_isolated_from_tenant_b_swap(
            swap_begin, chunks_per_step, delta_seed):
        _check_tenant_isolation_across_b_swap(swap_begin, chunks_per_step,
                                              delta_seed)
