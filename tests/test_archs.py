"""Per-architecture smoke tests: reduced same-family configs, one
forward/train step on CPU, asserting output shapes and no NaNs, plus
decode/prefill consistency (the FULL configs are exercised via dry-run
only)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, shape_applicable
from repro.models.model import build_model

B, S = 2, 16
KEY = jax.random.PRNGKey(0)


def _batch(cfg, toks=None, s=S):
    t = toks if toks is not None else jnp.ones((B, s), jnp.int32)
    batch = {"tokens": t, "labels": jnp.ones_like(t)}
    if cfg.family == "encdec":
        # encoder input is independent of decoder length — keep it fixed so
        # prefill(S)+decode(1) and prefill(S+1) see the same source
        batch["enc_emb"] = jax.random.normal(
            jax.random.PRNGKey(1), (B, S, cfg.d_model)).astype(cfg.dtype)
    if cfg.family == "vlm":
        p = 4
        batch = {
            "tokens": t[:, p:], "labels": t[:, p:],
            "vis_emb": jnp.ones((B, p, cfg.d_model), cfg.dtype) * 0.1,
            "positions_thw": jnp.tile(
                jnp.arange(t.shape[1])[None, :, None], (B, 1, 3)
            ).astype(jnp.int32),
        }
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_shapes(arch):
    cfg = get_config(arch, smoke=True)
    m = build_model(cfg)
    params = m.init(KEY)
    loss, metrics = m.loss_fn(params, _batch(cfg))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    # one gradient step must also be finite (train smoke)
    grads = jax.grad(lambda p: m.loss_fn(p, _batch(cfg))[0])(params)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch, smoke=True)
    m = build_model(cfg)
    params = m.init(KEY)
    batch = _batch(cfg)
    if cfg.family == "encdec":
        cache = m.init_cache(B, S, src_len=S)
    else:
        cache = m.init_cache(B, S)
    logits, cache = m.prefill(params, batch, cache)
    assert logits.shape[-1] == cfg.padded_vocab
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    logits2, cache2 = m.decode_step(params, tok, cache)
    assert logits2.shape[:2] == (B, 1)
    assert not bool(jnp.isnan(logits2.astype(jnp.float32)).any()), arch
    # cache structure/dtype stability (required for jitted decode loops)
    jax.tree.map(lambda a, b: None if (a.dtype == b.dtype
                                       and a.shape == b.shape)
                 else pytest.fail(f"cache instability in {arch}"),
                 cache, cache2)


@pytest.mark.parametrize("arch", ["qwen3_4b", "rwkv6_3b", "zamba2_1p2b",
                                  "whisper_base", "minitron_4b"])
def test_decode_matches_prefill(arch):
    """Last-token logits of full prefill == prefill(S) + decode(1)."""
    cfg = get_config(arch, smoke=True)
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, moe_capacity=8.0)  # disable drops
    m = build_model(cfg)
    params = m.init(KEY)
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab - 1)

    def cache(n):
        if cfg.family == "encdec":
            return m.init_cache(B, n, src_len=S)
        return m.init_cache(B, n)

    lg1, c1 = m.prefill(params, _batch(cfg, toks[:, :S]), cache(S + 1))
    lg2, _ = m.decode_step(params, toks[:, S:S + 1], c1)
    lg_full, _ = m.prefill(params, _batch(cfg, toks, s=S + 1), cache(S + 1))
    assert jnp.allclose(lg2.astype(jnp.float32),
                        lg_full.astype(jnp.float32), atol=2e-2), arch


def test_moe_consistency_without_drops():
    cfg = dataclasses.replace(get_config("grok1_314b", smoke=True),
                              moe_capacity=8.0)
    m = build_model(cfg)
    params = m.init(KEY)
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab - 1)
    lg1, c1 = m.prefill(params, {"tokens": toks[:, :S]}, m.init_cache(B, S + 1))
    lg2, _ = m.decode_step(params, toks[:, S:S + 1], c1)
    lg_full, _ = m.prefill(params, {"tokens": toks}, m.init_cache(B, S + 1))
    assert jnp.allclose(lg2.astype(jnp.float32),
                        lg_full.astype(jnp.float32), atol=2e-2)


def test_all_cells_enumeration():
    """32 runnable cells: 10 archs x 3 shapes + 2 archs x long_500k."""
    from repro.configs import all_cells
    cells = list(all_cells())
    assert len(cells) == 32
    assert sum(1 for _, s in cells if s == "long_500k") == 2
    for arch in ARCH_IDS:
        assert shape_applicable(arch, "train_4k")
