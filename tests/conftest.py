"""Shared test configuration: jax cache hygiene + hypothesis profiles.

A full-suite run compiles thousands of XLA executables into one
long-lived process; on single-core CPU runners the accumulated
LLVM-JIT state eventually crashes ``backend_compile`` outright
(SIGSEGV deep in XLA, deterministic at whichever compile crosses the
wall — observed at ~85% of the suite on the pre-PR-10 tree too, so it
is an environment ceiling, not a regression signal).  Dropping every
cached executable at module boundaries keeps the live JIT footprint
bounded at the cost of recompiling shared helpers per module, which
the interpret-mode suite tolerates.  Per-test trace/retrace
assertions are unaffected: every test builds its closures and
counters fresh, and the clear runs only between modules.

The CI property lane (``test-property`` in .github/workflows/ci.yml)
runs the slow-marked hypothesis suites under the deterministic ``ci``
profile: derandomized (a red lane reproduces locally with
``HYPOTHESIS_PROFILE=ci``), an explicit example budget, and no deadline
(interpret-mode jit warmup dwarfs any per-example deadline).  The
default ``dev`` profile keeps random exploration but also drops the
deadline for the same reason.  Import-gated: environments without
hypothesis still run every seeded fallback test.
"""
import os

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _bound_jax_jit_footprint():
    """Release every cached XLA executable once a test module finishes."""
    yield
    jax.clear_caches()


try:
    from hypothesis import HealthCheck, settings
except ImportError:      # pragma: no cover - env without hypothesis
    pass
else:
    settings.register_profile(
        "ci", derandomize=True, deadline=None, max_examples=25,
        print_blob=True,
        suppress_health_check=[HealthCheck.too_slow])
    settings.register_profile("dev", deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
