"""Shared test configuration: pinned hypothesis profiles.

The CI property lane (``test-property`` in .github/workflows/ci.yml)
runs the slow-marked hypothesis suites under the deterministic ``ci``
profile: derandomized (a red lane reproduces locally with
``HYPOTHESIS_PROFILE=ci``), an explicit example budget, and no deadline
(interpret-mode jit warmup dwarfs any per-example deadline).  The
default ``dev`` profile keeps random exploration but also drops the
deadline for the same reason.  Import-gated: environments without
hypothesis still run every seeded fallback test.
"""
import os

try:
    from hypothesis import HealthCheck, settings
except ImportError:      # pragma: no cover - env without hypothesis
    pass
else:
    settings.register_profile(
        "ci", derandomize=True, deadline=None, max_examples=25,
        print_blob=True,
        suppress_health_check=[HealthCheck.too_slow])
    settings.register_profile("dev", deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
