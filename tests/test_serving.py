"""Serving runtime tests: generation loop + continuous-batching scheduler."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models.model import build_model
from repro.serve.engine import (BatchScheduler, Request, greedy_generate,
                                make_decode_step, make_prefill_step)


def _model():
    cfg = get_config("qwen3_4b", smoke=True)
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def test_greedy_generate_shapes_and_determinism():
    cfg, m, params = _model()
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab - 1).astype(jnp.int32)
    out1 = greedy_generate(m, params, {"tokens": prompts}, max_new=6)
    out2 = greedy_generate(m, params, {"tokens": prompts}, max_new=6)
    assert out1.shape == (2, 6)
    assert jnp.array_equal(out1, out2)
    assert int(out1.max()) < cfg.padded_vocab


def test_prefill_then_decode_continues_greedy_path():
    cfg, m, params = _model()
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                                 cfg.vocab - 1).astype(jnp.int32)
    cache = m.init_cache(2, 32)
    prefill = make_prefill_step(m)
    decode = make_decode_step(m)
    tok, cache = prefill(params, {"tokens": prompts}, cache)
    toks = [tok]
    for _ in range(3):
        tok, cache = decode(params, tok, cache)
        toks.append(tok)
    gen = jnp.concatenate(toks, axis=1)
    ref = greedy_generate(m, params, {"tokens": prompts}, max_new=4,
                          max_len=32)
    assert jnp.array_equal(gen, ref)


def test_scheduler_completes_all_requests():
    cfg, m, params = _model()
    sched = BatchScheduler(m, params, n_slots=2, max_len=32)
    for rid in range(4):
        p = jax.random.randint(jax.random.PRNGKey(rid), (6,), 0,
                               cfg.vocab - 1).astype(jnp.int32)
        sched.submit(Request(rid=rid, prompt=p, max_new=5))
    done, steps = [], 0
    while len(done) < 4 and steps < 200:
        done += sched.step()
        steps += 1
    assert len(done) == 4
    assert all(len(r.out) >= 5 for r in done)


def test_scheduler_matches_unbatched_decode():
    """A request served through slot admission must produce the same
    tokens as a dedicated batch-of-1 generation."""
    cfg, m, params = _model()
    p = jax.random.randint(jax.random.PRNGKey(9), (6,), 0,
                           cfg.vocab - 1).astype(jnp.int32)
    ref = greedy_generate(m, params, {"tokens": p[None]}, max_new=5,
                          max_len=32)[0]
    sched = BatchScheduler(m, params, n_slots=2, max_len=32)
    sched.submit(Request(rid=0, prompt=p, max_new=5))
    done = []
    while not done:
        done += sched.step()
    assert done[0].out[:5] == [int(t) for t in ref[:5]]


def test_admission_rejects_prompt_longer_than_max_len():
    """The last real prompt token's K/V lands at position len-1; a prompt
    of max_len+1 tokens would scatter it past the cache depth and JAX
    would silently drop the write — must be refused at admission."""
    cfg, m, params = _model()
    sched = BatchScheduler(m, params, n_slots=2, max_len=16)
    p = jax.random.randint(jax.random.PRNGKey(5), (17,), 0,
                           cfg.vocab - 1).astype(jnp.int32)
    sched.submit(Request(rid=0, prompt=p, max_new=2))
    with pytest.raises(ValueError, match="exceeds"):
        sched.step()


def test_max_new_token_counts_are_exact():
    """Regression: a request must emit EXACTLY max_new tokens.  The old
    scheduler appended the admission (prefill) token without checking
    completion, so max_new=1 emitted 2 tokens and burned a decode step."""
    cfg, m, params = _model()
    p = jax.random.randint(jax.random.PRNGKey(3), (6,), 0,
                           cfg.vocab - 1).astype(jnp.int32)
    for max_new in (1, 2, 3):
        sched = BatchScheduler(m, params, n_slots=2, max_len=32)
        sched.submit(Request(rid=0, prompt=p, max_new=max_new))
        done, steps = [], 0
        while not done and steps < 20:
            done += sched.step()
            steps += 1
        assert len(done) == 1
        assert len(done[0].out) == max_new          # pinned, not >=
        assert done[0].done
        # max_new=1 finishes at admission: no decode step burned
        if max_new == 1:
            assert steps == 1


def test_max_new_1_requests_drain_through_free_slots_in_one_step():
    """Admission-finished requests never occupy a slot, so a queue of
    max_new=1 requests drains through 2 slots in a single step."""
    cfg, m, params = _model()
    sched = BatchScheduler(m, params, n_slots=2, max_len=32)
    for rid in range(3):
        p = jax.random.randint(jax.random.PRNGKey(rid), (4,), 0,
                               cfg.vocab - 1).astype(jnp.int32)
        sched.submit(Request(rid=rid, prompt=p, max_new=1))
    done = sched.step()
    assert sorted(r.rid for r in done) == [0, 1, 2]
    assert all(len(r.out) == 1 for r in done)


def test_admission_prefill_jits_once_per_length_bucket():
    """Perf regression: admissions must reuse a jitted prefill per padded
    prompt-length bucket instead of re-tracing model.prefill for every
    new prompt length."""
    cfg, m, params = _model()
    sched = BatchScheduler(m, params, n_slots=2, max_len=32)
    refs = {}
    for rid, plen in enumerate((1, 3, 5, 8, 9)):
        p = jax.random.randint(jax.random.PRNGKey(40 + rid), (plen,), 0,
                               cfg.vocab - 1).astype(jnp.int32)
        refs[rid] = greedy_generate(m, params, {"tokens": p[None]},
                                    max_new=3, max_len=32)[0]
        sched.submit(Request(rid=rid, prompt=p, max_new=3))
    done, steps = [], 0
    while len(done) < 5 and steps < 50:
        done += sched.step()
        steps += 1
    # prompt lengths 1..9 prefill m = 0..8 tokens -> every admission
    # lands in the single 8-wide bucket: ONE trace serves all five
    assert sched._prefill_traces == 1
    # ...and the padded path is bit-exact with the unpadded reference
    for r in done:
        assert r.out == [int(t) for t in refs[r.rid]]
    # a longer prompt opens a second bucket (16), one more trace
    p = jax.random.randint(jax.random.PRNGKey(60), (12,), 0,
                           cfg.vocab - 1).astype(jnp.int32)
    ref = greedy_generate(m, params, {"tokens": p[None]}, max_new=2,
                          max_len=32)[0]
    sched.submit(Request(rid=99, prompt=p, max_new=2))
    done = []
    while not done:
        done += sched.step()
    assert sched._prefill_traces == 2
    assert done[0].out == [int(t) for t in ref]
