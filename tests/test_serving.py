"""Serving runtime tests: generation loop + continuous-batching scheduler."""
import jax
import jax.numpy as jnp
import pytest

from repro import obs
from repro.configs import get_config
from repro.models.model import build_model
from repro.serve.engine import (BatchScheduler, Request, greedy_generate,
                                make_decode_step, make_prefill_step)


def _model():
    cfg = get_config("qwen3_4b", smoke=True)
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def test_greedy_generate_shapes_and_determinism():
    cfg, m, params = _model()
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab - 1).astype(jnp.int32)
    out1 = greedy_generate(m, params, {"tokens": prompts}, max_new=6)
    out2 = greedy_generate(m, params, {"tokens": prompts}, max_new=6)
    assert out1.shape == (2, 6)
    assert jnp.array_equal(out1, out2)
    assert int(out1.max()) < cfg.padded_vocab


def test_prefill_then_decode_continues_greedy_path():
    cfg, m, params = _model()
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                                 cfg.vocab - 1).astype(jnp.int32)
    cache = m.init_cache(2, 32)
    prefill = make_prefill_step(m)
    decode = make_decode_step(m)
    tok, cache = prefill(params, {"tokens": prompts}, cache)
    toks = [tok]
    for _ in range(3):
        tok, cache = decode(params, tok, cache)
        toks.append(tok)
    gen = jnp.concatenate(toks, axis=1)
    ref = greedy_generate(m, params, {"tokens": prompts}, max_new=4,
                          max_len=32)
    assert jnp.array_equal(gen, ref)


def test_scheduler_completes_all_requests():
    cfg, m, params = _model()
    sched = BatchScheduler(m, params, n_slots=2, max_len=32)
    for rid in range(4):
        p = jax.random.randint(jax.random.PRNGKey(rid), (6,), 0,
                               cfg.vocab - 1).astype(jnp.int32)
        sched.submit(Request(rid=rid, prompt=p, max_new=5))
    done, steps = [], 0
    while len(done) < 4 and steps < 200:
        done += sched.step()
        steps += 1
    assert len(done) == 4
    assert all(len(r.out) >= 5 for r in done)


def test_scheduler_matches_unbatched_decode():
    """A request served through slot admission must produce the same
    tokens as a dedicated batch-of-1 generation."""
    cfg, m, params = _model()
    p = jax.random.randint(jax.random.PRNGKey(9), (6,), 0,
                           cfg.vocab - 1).astype(jnp.int32)
    ref = greedy_generate(m, params, {"tokens": p[None]}, max_new=5,
                          max_len=32)[0]
    sched = BatchScheduler(m, params, n_slots=2, max_len=32)
    sched.submit(Request(rid=0, prompt=p, max_new=5))
    done = []
    while not done:
        done += sched.step()
    assert done[0].out[:5] == [int(t) for t in ref[:5]]


def test_admission_rejects_prompt_longer_than_max_len():
    """The last real prompt token's K/V lands at position len-1; a prompt
    of max_len+1 tokens would scatter it past the cache depth and JAX
    would silently drop the write — must be refused at admission."""
    cfg, m, params = _model()
    sched = BatchScheduler(m, params, n_slots=2, max_len=16)
    p = jax.random.randint(jax.random.PRNGKey(5), (17,), 0,
                           cfg.vocab - 1).astype(jnp.int32)
    sched.submit(Request(rid=0, prompt=p, max_new=2))
    with pytest.raises(ValueError, match="exceeds"):
        sched.step()


def test_max_new_token_counts_are_exact():
    """Regression: a request must emit EXACTLY max_new tokens — the
    first on the step that feeds its final prompt chunk, one per decode
    step after — so a request costs ceil(plen / chunk) + max_new - 1
    steps, never an extra decode step past its budget."""
    cfg, m, params = _model()
    p = jax.random.randint(jax.random.PRNGKey(3), (6,), 0,
                           cfg.vocab - 1).astype(jnp.int32)
    for max_new in (1, 2, 3):
        sched = BatchScheduler(m, params, n_slots=2, max_len=32, chunk=4)
        sched.submit(Request(rid=0, prompt=p, max_new=max_new))
        done, steps = [], 0
        while not done and steps < 20:
            done += sched.step()
            steps += 1
        assert len(done) == 1
        assert len(done[0].out) == max_new          # pinned, not >=
        assert done[0].done
        # plen=6 at chunk=4 prefills in 2 steps (the 2nd emits token 1)
        assert steps == 2 + (max_new - 1)


def test_completion_frees_slots_for_next_step_admission():
    """A request finishing on step N releases its slot (and pages)
    within that step, so a queue of short requests drains through 2
    slots at full occupancy: 3 one-chunk max_new=1 requests need
    exactly 2 steps, never a stall step between waves."""
    cfg, m, params = _model()
    sched = BatchScheduler(m, params, n_slots=2, max_len=32, chunk=4)
    for rid in range(3):
        p = jax.random.randint(jax.random.PRNGKey(rid), (4,), 0,
                               cfg.vocab - 1).astype(jnp.int32)
        sched.submit(Request(rid=rid, prompt=p, max_new=1))
    first = sched.step()
    assert sorted(r.rid for r in first) == [0, 1]
    second = sched.step()
    assert [r.rid for r in second] == [2]
    assert all(len(r.out) == 1 for r in first + second)


def test_mixed_prompt_lengths_share_one_closure_bit_exactly():
    """Tentpole invariant: ANY prompt-length mix is served by ONE
    compiled window closure — zero re-traces — and the chunked-prefill
    path is bit-exact with the unpadded per-request reference."""
    obs.reset()
    cfg, m, params = _model()
    sched = BatchScheduler(m, params, n_slots=2, max_len=32)
    refs = {}
    for rid, plen in enumerate((1, 3, 5, 8, 9)):
        p = jax.random.randint(jax.random.PRNGKey(40 + rid), (plen,), 0,
                               cfg.vocab - 1).astype(jnp.int32)
        refs[rid] = greedy_generate(m, params, {"tokens": p[None]},
                                    max_new=3, max_len=32)[0]
        sched.submit(Request(rid=rid, prompt=p, max_new=3))
    done, steps = [], 0
    while len(done) < 5 and steps < 50:
        done += sched.step()
        steps += 1
    reg = obs.registry()
    assert reg.total("serve_jit_traces_total",
                     closure="decode", tenant="A") == 1
    assert reg.total("serve_jit_retraces_total") == 0
    for r in done:
        assert r.out == [int(t) for t in refs[r.rid]]
    # a longer prompt (the old 16-wide bucket) reuses the SAME closure:
    # still one trace, still bit-exact
    p = jax.random.randint(jax.random.PRNGKey(60), (12,), 0,
                           cfg.vocab - 1).astype(jnp.int32)
    ref = greedy_generate(m, params, {"tokens": p[None]}, max_new=2,
                          max_len=32)[0]
    sched.submit(Request(rid=99, prompt=p, max_new=2))
    done = []
    while not done:
        done += sched.step()
    assert reg.total("serve_jit_traces_total",
                     closure="decode", tenant="A") == 1
    assert reg.total("serve_jit_retraces_total") == 0
    assert done[0].out == [int(t) for t in ref]
