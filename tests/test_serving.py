"""Serving runtime tests: generation loop + continuous-batching scheduler."""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.model import build_model
from repro.serve.engine import (BatchScheduler, Request, greedy_generate,
                                make_decode_step, make_prefill_step)


def _model():
    cfg = get_config("qwen3_4b", smoke=True)
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def test_greedy_generate_shapes_and_determinism():
    cfg, m, params = _model()
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab - 1).astype(jnp.int32)
    out1 = greedy_generate(m, params, {"tokens": prompts}, max_new=6)
    out2 = greedy_generate(m, params, {"tokens": prompts}, max_new=6)
    assert out1.shape == (2, 6)
    assert jnp.array_equal(out1, out2)
    assert int(out1.max()) < cfg.padded_vocab


def test_prefill_then_decode_continues_greedy_path():
    cfg, m, params = _model()
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                                 cfg.vocab - 1).astype(jnp.int32)
    cache = m.init_cache(2, 32)
    prefill = make_prefill_step(m)
    decode = make_decode_step(m)
    tok, cache = prefill(params, {"tokens": prompts}, cache)
    toks = [tok]
    for _ in range(3):
        tok, cache = decode(params, tok, cache)
        toks.append(tok)
    gen = jnp.concatenate(toks, axis=1)
    ref = greedy_generate(m, params, {"tokens": prompts}, max_new=4,
                          max_len=32)
    assert jnp.array_equal(gen, ref)


def test_scheduler_completes_all_requests():
    cfg, m, params = _model()
    sched = BatchScheduler(m, params, n_slots=2, max_len=32)
    for rid in range(4):
        p = jax.random.randint(jax.random.PRNGKey(rid), (6,), 0,
                               cfg.vocab - 1).astype(jnp.int32)
        sched.submit(Request(rid=rid, prompt=p, max_new=5))
    done, steps = [], 0
    while len(done) < 4 and steps < 200:
        done += sched.step()
        steps += 1
    assert len(done) == 4
    assert all(len(r.out) >= 5 for r in done)


def test_scheduler_matches_unbatched_decode():
    """A request served through slot admission must produce the same
    tokens as a dedicated batch-of-1 generation."""
    cfg, m, params = _model()
    p = jax.random.randint(jax.random.PRNGKey(9), (6,), 0,
                           cfg.vocab - 1).astype(jnp.int32)
    ref = greedy_generate(m, params, {"tokens": p[None]}, max_new=5,
                          max_len=32)[0]
    sched = BatchScheduler(m, params, n_slots=2, max_len=32)
    sched.submit(Request(rid=0, prompt=p, max_new=5))
    done = []
    while not done:
        done += sched.step()
    assert done[0].out[:5] == [int(t) for t in ref[:5]]
