"""Substrate tests: data determinism, optimizer, checkpointing/FT,
serving scheduler, sharding rules."""
import os

import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed import sharding as shd
from repro.launch import ft
from repro.train import optimizer as opt


class TestData:
    def test_seekable_determinism(self):
        """batch_at(step) is a pure function — the FT contract."""
        d1 = SyntheticLM(DataConfig(vocab=512, seq_len=33, global_batch=4))
        d2 = SyntheticLM(DataConfig(vocab=512, seq_len=33, global_batch=4))
        for step in (0, 7, 1000):
            a, b = d1.batch_at(step), d2.batch_at(step)
            assert jnp.array_equal(a["tokens"], b["tokens"])
            assert jnp.array_equal(a["labels"], b["labels"])

    def test_steps_differ(self):
        d = SyntheticLM(DataConfig(vocab=512, seq_len=33, global_batch=4))
        assert not jnp.array_equal(d.batch_at(0)["tokens"],
                                   d.batch_at(1)["tokens"])

    def test_host_shard_partitions_global_batch(self):
        d = SyntheticLM(DataConfig(vocab=512, seq_len=17, global_batch=8))
        full = d.batch_at(3)["tokens"]
        parts = [d.host_shard_at(3, h, 4)["tokens"] for h in range(4)]
        assert jnp.array_equal(jnp.concatenate(parts), full)

    def test_labels_are_shifted_tokens(self):
        d = SyntheticLM(DataConfig(vocab=512, seq_len=33, global_batch=2))
        b = d.batch_at(0)
        assert b["tokens"].shape == (2, 32)
        assert b["labels"].shape == (2, 32)


class TestOptimizer:
    def test_adamw_converges_quadratic(self):
        params = {"w": jnp.array([3.0, -2.0])}
        state = opt.init(params)
        cfg = opt.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                              total_steps=200)
        for _ in range(150):
            grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, state, _ = opt.update(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_grad_clip(self):
        params = {"w": jnp.zeros(3)}
        state = opt.init(params)
        cfg = opt.AdamWConfig(lr=1e-3, grad_clip=1.0)
        _, _, stats = opt.update(cfg, params,
                                 {"w": jnp.full(3, 1e6)}, state)
        assert stats["grad_norm"] > 1e5  # reported raw

    def test_bf16_state_roundtrip(self):
        params = {"w": jnp.ones(4)}
        state = opt.init(params, jnp.bfloat16)
        assert state.m["w"].dtype == jnp.bfloat16
        p2, s2, _ = opt.update(opt.AdamWConfig(), params,
                               {"w": jnp.ones(4)}, state)
        assert s2.m["w"].dtype == jnp.bfloat16
        assert p2["w"].dtype == params["w"].dtype

    def test_int8_grad_quantization_error_feedback(self):
        g = jnp.array([1.0, 0.5, -0.25, 1e-4])
        q, scale = opt.quantize_grad_int8(g)
        deq = opt.dequantize_grad(q, scale)
        assert float(jnp.abs(deq - g).max()) <= float(scale) / 2 + 1e-9
        # error feedback: accumulated residual keeps the mean unbiased
        err = jnp.zeros_like(g)
        total = jnp.zeros_like(g)
        for _ in range(64):
            corr = g + err
            q, s = opt.quantize_grad_int8(corr)
            deq = opt.dequantize_grad(q, s)
            err = corr - deq
            total = total + deq
        assert jnp.allclose(total / 64, g, atol=float(s))

    def test_lr_schedule(self):
        cfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                              min_lr_frac=0.1)
        assert float(opt.lr_at(cfg, jnp.int32(0))) == 0.0
        assert float(opt.lr_at(cfg, jnp.int32(10))) == pytest.approx(1.0)
        assert float(opt.lr_at(cfg, jnp.int32(100))) == pytest.approx(0.1)


class TestCheckpoint:
    def _state(self, x=1.0):
        return {"params": {"w": jnp.full((4, 4), x)},
                "opt": {"m": jnp.zeros((4, 4)), "step": jnp.int32(7)}}

    def test_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        state = self._state(3.0)
        mgr.save(10, state, blocking=True)
        restored = mgr.restore(self._state(0.0))
        assert jnp.array_equal(restored["params"]["w"],
                               state["params"]["w"])
        assert int(restored["opt"]["step"]) == 7

    def test_keep_k_and_latest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, self._state(float(s)), blocking=True)
        assert mgr.all_steps() == [3, 4]
        assert mgr.latest_step() == 4
        r = mgr.restore(self._state(0.0))
        assert float(r["params"]["w"][0, 0]) == 4.0

    def test_partial_write_ignored(self, tmp_path):
        """A .tmp dir from a killed writer must be invisible + GC'd."""
        mgr = CheckpointManager(str(tmp_path), keep=2)
        os.makedirs(tmp_path / "step_000000099.tmp")
        assert mgr.latest_step() is None
        mgr.save(1, self._state(), blocking=True)
        assert mgr.latest_step() == 1
        assert not (tmp_path / "step_000000099.tmp").exists()

    def test_restore_or_init(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        state, step = ft.restore_or_init(mgr, lambda: self._state(5.0))
        assert step == 0 and float(state["params"]["w"][0, 0]) == 5.0
        mgr.save(42, self._state(9.0), blocking=True)
        state, step = ft.restore_or_init(mgr, lambda: self._state(5.0))
        assert step == 42 and float(state["params"]["w"][0, 0]) == 9.0

    def test_elastic_reshard_via_device_put(self, tmp_path):
        """Restore onto an explicit (single-device) sharding — the elastic
        path used when the mesh changes between runs."""
        mgr = CheckpointManager(str(tmp_path), keep=1)
        mgr.save(1, self._state(2.0), blocking=True)
        shard = jax.sharding.SingleDeviceSharding(jax.devices()[0])
        shardings = jax.tree.map(lambda _: shard, self._state())
        r = mgr.restore(self._state(0.0), shardings=shardings)
        assert float(r["params"]["w"][0, 0]) == 2.0


class TestShardingRules:
    def test_spec_mapping(self):
        r = shd.fsdp_rules()
        assert r.spec(("embed", "mlp")) == jax.sharding.PartitionSpec(
            "data", "model")
        assert r.spec((None, "heads")) == jax.sharding.PartitionSpec(
            None, "model")

    def test_multi_pod_batch_axes(self):
        r = shd.fsdp_rules(multi_pod=True)
        assert r.spec(("batch",)) == jax.sharding.PartitionSpec(
            ("pod", "data"))

    def test_constraint_noop_without_rules(self):
        x = jnp.ones((2, 2))
        assert shd.logical_constraint(x, ("batch", None)) is x

    def test_spec_tree_skips_namedtuples(self):
        from repro.train.trainer import TrainState
        from repro.train.optimizer import OptState
        tree = TrainState(params={"w": ("embed", "mlp")},
                          opt=OptState(m={"w": ("embed", "mlp")},
                                       v={"w": ("embed", "mlp")}, step=()))
        specs = shd.spec_tree(tree, shd.fsdp_rules())
        assert specs.params["w"] == jax.sharding.PartitionSpec(
            "data", "model")


class TestTrainStep:
    def test_microbatched_equals_full_batch_loss(self):
        from repro.configs import get_config
        from repro.models.model import build_model
        from repro.train import trainer
        cfg = get_config("qwen3_4b", smoke=True)
        model = build_model(cfg)
        ocfg = opt.AdamWConfig(lr=0.0, weight_decay=0.0)  # lr=0: compare loss
        s1 = trainer.init_state(model, jax.random.PRNGKey(0))
        s2 = trainer.init_state(model, jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (4, 16), 0, 300),
                 "labels": jax.random.randint(jax.random.PRNGKey(2),
                                              (4, 16), 0, 300)}
        _, m1 = trainer.make_train_step(model, ocfg, microbatches=1)(
            s1, batch)
        _, m2 = trainer.make_train_step(model, ocfg, microbatches=2)(
            s2, batch)
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]),
                                                  rel=2e-2)
