"""Stateful property test for the refcounted copy-on-write page pool.

A single operation model drives random alloc / alloc_shared /
register_prefix / COW / budget-shrink / preempt(free) sequences against
``PagedKVPool`` and checks, after EVERY operation:

* conservation, refcount-aware: distinct ``pages_in_use + pages_free
  == n_pages`` AND ``sum(refcounts) == total page-table entries``;
* the null page is never allocated, never refcounted, never freed;
* no aliasing after COW: a copy-on-write page has refcount 1 and its
  source keeps exactly its remaining referents;
* a row's table never repeats a physical page;
* budget stays clamped to ``[1, n_pages]`` and only ever gates NEW
  admissions (live rows keep their pages across shrinks).

With hypothesis installed (the ``[test]`` extra — the CI property lane)
a ``RuleBasedStateMachine`` explores operation interleavings under the
pinned profile from ``tests/conftest.py``; without it, a seeded
random-walk fallback replays the same operation mix so the invariants
still run everywhere (pattern from test_hotswap_property.py).
"""
import random

import pytest

from repro.serve.kv_pool import NULL_PAGE, PagedKVPool

try:
    from hypothesis import settings
    from hypothesis import strategies as st
    from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                     invariant, rule)
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - env without hypothesis
    HAVE_HYPOTHESIS = False

N_PAGES, PAGE_SIZE, MAX_LEN, N_ROWS = 12, 4, 16, 5
VOCAB = 3       # tiny alphabet => prompt heads collide => sharing fires
MAX_NEW = 4


class PoolDriver:
    """The shared operation model both drivers exercise."""

    def __init__(self):
        self.pool = PagedKVPool(N_PAGES, PAGE_SIZE, MAX_LEN, N_ROWS)
        # row -> the token feed it was admitted with (None == empty row)
        self.feeds = [None] * N_ROWS

    # -- operations -----------------------------------------------------------

    def op_alloc(self, row, tokens, shared):
        """Admit ``tokens`` onto ``row`` (scheduler lifecycle: lifetime
        claim, then index the prompt pages), privately or via the
        sharing planner.  A plan that doesn't fit is a legal no-op —
        that's the scheduler's FIFO backpressure."""
        if self.feeds[row] is not None or not tokens:
            return
        need = min(len(tokens) + MAX_NEW - 1, MAX_LEN)
        pool = self.pool
        if shared:
            if not pool.can_alloc_shared(need, tokens):
                return
            pages, s_tok, cow_pairs = pool.alloc_shared(row, need, tokens)
            assert s_tok < len(tokens)      # never the whole prompt
            for src, dst in cow_pairs:
                assert src != dst
                assert pool.refcount(dst) == 1     # no aliasing post-COW
        else:
            if not pool.can_alloc(need):
                return
            pages = pool.alloc(row, need)
            for p in pages:
                assert pool.refcount(p) == 1
        self.feeds[row] = list(tokens)
        pool.register_prefix(row, tokens)

    def op_preempt(self, row):
        """Evict a resident row — pages reclaim refcount-aware — then
        re-admit the same feed through the sharing path (the scheduler's
        preempt/re-admit cycle)."""
        if self.feeds[row] is None:
            return
        tokens = self.feeds[row]
        self.pool.free_row(row)
        self.feeds[row] = None
        self.check()
        self.op_alloc(row, tokens, shared=True)

    def op_free(self, row):
        if self.feeds[row] is None:
            return
        self.pool.free_row(row)
        self.feeds[row] = None

    def op_cow(self, row, logical):
        if self.feeds[row] is None:
            return
        pages = self.pool.row_pages(row)
        if not pages:
            return
        logical %= len(pages)
        if self.pool.refcount(pages[logical]) <= 1:
            return                       # already private: cow is a no-op
        if self.pool.pages_free == 0:
            return                       # exhausted: cow would raise
        src_ref = self.pool.refcount(pages[logical])
        pair = self.pool.cow(row, logical)
        assert pair is not None
        src, dst = pair
        assert src == pages[logical] and dst != src
        assert self.pool.refcount(dst) == 1
        assert self.pool.refcount(src) == src_ref - 1
        assert self.pool.row_pages(row)[logical] == dst

    def op_set_budget(self, n):
        before = {r: self.pool.row_pages(r) for r in range(N_ROWS)}
        self.pool.set_budget(n)
        assert 1 <= self.pool.budget <= N_PAGES
        # a shrink never evicts: every live row keeps its exact pages
        for r, pages in before.items():
            assert self.pool.row_pages(r) == pages

    # -- invariants -----------------------------------------------------------

    def check(self):
        pool = self.pool
        assert pool.conservation_ok()
        entries = sum(len(pool.row_pages(r)) for r in range(N_ROWS))
        refs = sum(pool.refcount(p) for p in range(1, N_PAGES + 1))
        assert refs == entries
        assert pool.pages_owned + pool.pages_shared == pool.pages_in_use
        assert pool.refcount(NULL_PAGE) == 0
        for r in range(N_ROWS):
            pages = pool.row_pages(r)
            assert NULL_PAGE not in pages
            assert len(set(pages)) == len(pages)   # no self-aliasing
            # a resident row always holds pages; an empty row holds none
            assert (self.feeds[r] is None) == (len(pages) == 0)
        assert 1 <= pool.budget <= N_PAGES


if HAVE_HYPOTHESIS:

    TOKENS = st.lists(st.integers(0, VOCAB - 1), min_size=1,
                      max_size=MAX_LEN - 1)

    class PoolMachine(RuleBasedStateMachine):
        @initialize()
        def setup(self):
            self.d = PoolDriver()

        @rule(row=st.integers(0, N_ROWS - 1), tokens=TOKENS,
              shared=st.booleans())
        def alloc(self, row, tokens, shared):
            self.d.op_alloc(row, tokens, shared)

        @rule(row=st.integers(0, N_ROWS - 1))
        def free(self, row):
            self.d.op_free(row)

        @rule(row=st.integers(0, N_ROWS - 1))
        def preempt(self, row):
            self.d.op_preempt(row)

        @rule(row=st.integers(0, N_ROWS - 1), logical=st.integers(0, 7))
        def cow(self, row, logical):
            self.d.op_cow(row, logical)

        @rule(n=st.integers(-2, N_PAGES + 2))
        def shrink_budget(self, n):
            self.d.op_set_budget(n)

        @invariant()
        def conserved(self):
            if hasattr(self, "d"):
                self.d.check()

    PoolMachine.TestCase.settings = settings(
        settings.default, max_examples=40, stateful_step_count=50,
        deadline=None)
    TestPoolMachine = PoolMachine.TestCase
    TestPoolMachine.pytestmark = [pytest.mark.slow]


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_pool_random_walk_fallback(seed):
    """Seeded fallback: the same operation mix as the state machine,
    driven by a PRNG — runs in the fast lane and in environments
    without hypothesis."""
    rng = random.Random(seed)
    d = PoolDriver()
    for _ in range(400):
        op = rng.randrange(6)
        row = rng.randrange(N_ROWS)
        if op in (0, 1):
            tokens = [rng.randrange(VOCAB)
                      for _ in range(rng.randrange(1, MAX_LEN))]
            d.op_alloc(row, tokens, shared=bool(op))
        elif op == 2:
            d.op_free(row)
        elif op == 3:
            d.op_preempt(row)
        elif op == 4:
            d.op_cow(row, rng.randrange(8))
        else:
            d.op_set_budget(rng.randrange(-2, N_PAGES + 3))
        d.check()
    # drain: every page returns, the index empties with its pages
    for r in range(N_ROWS):
        d.op_free(r)
    d.check()
    assert d.pool.pages_in_use == 0
    assert d.pool.pages_free == N_PAGES
    assert d.pool.prefix_entries == 0
