"""Per-kernel allclose tests vs the pure-jnp oracles, swept over shapes,
dtypes and quantization configs (interpret mode on CPU)."""
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.core import engine as eng
from repro.core import quant
from repro.core.quant import QuantConfig
from repro.kernels.crossbar_mac import ops as cb_ops
from repro.kernels.crossbar_mac.kernel import crossbar_mac
from repro.kernels.crossbar_mac.ref import crossbar_mac_ref
from repro.kernels.deepnet_stream.kernel import deepnet_stream
from repro.kernels.deepnet_stream.ops import stream_linear
from repro.kernels.deepnet_stream.ref import deepnet_stream_ref

jax.config.update("jax_enable_x64", False)


def _codes(key, shape, base):
    return jax.random.randint(key, shape, 0, base).astype(jnp.int8)


_slow = pytest.mark.slow  # interpret-mode sweeps: CI full lane only; the
# smallest point of each sweep stays unmarked so the PR fast lane keeps a
# kernel-correctness assertion

SWEEP = [
    # (B, K, N, S, in_bits, adc_bits, bits_per_cell, rows_per_adc)
    (4, 32, 16, 1, 4, 6, 1, 16),   # smallest: runs in the fast lane
    pytest.param(8, 64, 32, 3, 8, 8, 1, 32, marks=_slow),
    pytest.param(8, 128, 32, 4, 8, 12, 1, 64, marks=_slow),
    pytest.param(2, 48, 8, 2, 6, 10, 2, 16, marks=_slow),  # multi-bit cells
    pytest.param(16, 64, 64, 2, 8, 8, 2, 32, marks=_slow),
]


@pytest.mark.parametrize("b,k,n,s,ib,ab,bpc,rpa", SWEEP)
def test_crossbar_mac_matches_ref(b, k, n, s, ib, ab, bpc, rpa):
    key = jax.random.PRNGKey(b * 1000 + k)
    k1, k2, k3 = jax.random.split(key, 3)
    lo, hi = -(2 ** (ib - 1)), 2 ** (ib - 1)
    x_int = jax.random.randint(k1, (b, k), lo, hi).astype(jnp.int32)
    base = 2 ** bpc
    pos = _codes(k2, (s, k, n), base)
    neg = _codes(k3, (s, k, n), base)
    kw = dict(in_bits=ib, adc_bits=ab, bits_per_cell=bpc, rows_per_adc=rpa)
    ref = crossbar_mac_ref(x_int, pos, neg, **kw)
    out = crossbar_mac(x_int, pos, neg, block_b=min(b, 8), block_n=min(n, 32),
                       interpret=True, **kw)
    # tolerance: one ADC LSB accumulated per row-group and slice
    lsb = rpa * (base - 1) / (2.0 ** ab - 1.0)
    tol = lsb * (k // rpa) * s * 4 + 1e-3
    assert jnp.max(jnp.abs(out - ref)) <= tol


@pytest.mark.parametrize("dtype", [
    jnp.float32, pytest.param(jnp.bfloat16, marks=_slow)])
@pytest.mark.parametrize("b,k,n", [
    (8, 64, 32), pytest.param(4, 96, 16, marks=_slow)])
def test_deepnet_stream_matches_ref(b, k, n, dtype):
    key = jax.random.PRNGKey(k + n)
    k1, k2 = jax.random.split(key)
    x_int = jax.random.randint(k1, (b, k), -128, 128).astype(jnp.int32)
    w = (jax.random.normal(k2, (k, n)) * 0.4).astype(dtype)
    q = QuantConfig(w_bits=4, in_bits=8, adc_bits=10)
    ws = quant.weight_scales(w.astype(jnp.float32), q)
    kw = dict(w_bits=4, in_bits=8, adc_bits=10, bits_per_cell=1,
              rows_per_adc=32)
    ref = deepnet_stream_ref(x_int, w.astype(jnp.float32), ws, **kw)
    out = deepnet_stream(x_int, w.astype(jnp.float32), ws.astype(jnp.float32),
                         block_b=min(b, 8), block_n=min(n, 32),
                         interpret=True, **kw)
    assert jnp.max(jnp.abs(out - ref)) <= 0.05


def test_engine_kernel_path_matches_reference_path():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (96, 80)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(5), (16, 96))
    for mode in ["expansion", "deepnet"]:
        qc = QuantConfig(w_bits=4, in_bits=8, adc_bits=10)
        cfg_r = eng.EngineConfig(tile_rows=32, tile_cols=64, mode=mode,
                                 quant=qc)
        cfg_k = eng.EngineConfig(tile_rows=32, tile_cols=64, mode=mode,
                                 quant=qc, use_kernel=True)
        pw = eng.program(w, cfg_r)
        y_r = eng.matmul(x, pw, cfg_r)
        y_k = eng.matmul(x, pw, cfg_k)
        assert jnp.allclose(y_r, y_k, atol=1e-4), mode


# ---------------------------------------------------------------------------
# Deep-net overlap reads: write-plane leakage as a traced kernel operand
# ---------------------------------------------------------------------------

# leakage in units of the ADC LSB: steady state, below one code (the
# paper's "negligible common-mode" regime, Fig. 3c), and well above it
# (where the ADC visibly digitizes the offset — parity must still hold)
_LEAK_LSB = [0.0, 0.4, 3.5]

_LEAK_SWEEP = [
    # (in_bits, adc_bits, bits_per_cell)
    (4, 6, 1),                                     # fast lane
    pytest.param(8, 8, 1, marks=_slow),
    pytest.param(10, 12, 1, marks=_slow),
    pytest.param(6, 5, 2, marks=_slow),            # coarse ADC, multi-bit
]


@pytest.mark.parametrize("leak_lsb", _LEAK_LSB)
@pytest.mark.parametrize("ib,ab,bpc", _LEAK_SWEEP)
def test_crossbar_mac_leak_parity_vs_ref(leak_lsb, ib, ab, bpc):
    """Kernel with a pre-ADC leak operand == oracle with the same leak."""
    b, k, n, s, rpa = 4, 64, 32, 2, 32
    key = jax.random.PRNGKey(ib * 100 + ab)
    k1, k2, k3 = jax.random.split(key, 3)
    lo, hi = -(2 ** (ib - 1)), 2 ** (ib - 1)
    x_int = jax.random.randint(k1, (b, k), lo, hi).astype(jnp.int32)
    base = 2 ** bpc
    pos = _codes(k2, (s, k, n), base)
    neg = _codes(k3, (s, k, n), base)
    lsb = rpa * (base - 1) / (2.0 ** ab - 1.0)
    leak = leak_lsb * lsb
    kw = dict(in_bits=ib, adc_bits=ab, bits_per_cell=bpc, rows_per_adc=rpa)
    ref = crossbar_mac_ref(x_int, pos, neg, leak_codes=leak, **kw)
    out = crossbar_mac(x_int, pos, neg, leak, block_b=min(b, 8),
                       block_n=min(n, 32), interpret=True, **kw)
    tol = lsb * (k // rpa) * s * 4 + 1e-3
    assert jnp.max(jnp.abs(out - ref)) <= tol


@pytest.mark.parametrize("mode", ["expansion", "deepnet"])
def test_engine_kernel_path_serves_nonzero_leak(mode):
    """use_kernel traffic stays on the Pallas path at leak != 0 (the
    overlap window is the hot path — no silent reference fallback) and
    matches matmul_reference at the same leak."""
    qc = QuantConfig(w_bits=4, in_bits=8, adc_bits=10)
    cfg_r = eng.EngineConfig(tile_rows=32, tile_cols=64, mode=mode, quant=qc)
    cfg_k = eng.EngineConfig(tile_rows=32, tile_cols=64, mode=mode,
                             quant=qc, use_kernel=True)
    w = jax.random.normal(jax.random.PRNGKey(3), (128, 80)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(7), (16, 128))
    pw = eng.program(w, cfg_r)
    lsb = cfg_r.rows_per_adc / (2.0 ** qc.adc_bits - 1.0)
    for leak in [0.0, 0.4 * lsb, 3.5 * lsb]:
        before = dict(eng.path_calls)
        y_k = eng.matmul(x, pw, cfg_k, leak_codes=leak)
        assert eng.path_calls["kernel"] == before["kernel"] + 1
        assert eng.path_calls["reference"] == before["reference"]
        y_r = eng.matmul_reference(x, pw, cfg_r, leak_codes=leak)
        assert jnp.allclose(y_k, y_r, atol=1e-4), (mode, leak)


def test_leak_zero_is_bitwise_identical_python_or_traced():
    """leak = 0.0 (the default, python float, or a device scalar) keeps the
    kernel output bit-identical — the operand plumbing costs nothing in
    steady state."""
    qc = QuantConfig(w_bits=4, in_bits=8, adc_bits=10)
    cfg_k = eng.EngineConfig(tile_rows=32, tile_cols=64, mode="deepnet",
                             quant=qc, use_kernel=True)
    w = jax.random.normal(jax.random.PRNGKey(11), (96, 48)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(13), (8, 96))
    pw = eng.program(w, cfg_k)
    y_default = eng.matmul(x, pw, cfg_k)
    y_py = eng.matmul(x, pw, cfg_k, leak_codes=0.0)
    y_traced = eng.matmul(x, pw, cfg_k, leak_codes=jnp.float32(0.0))
    assert jnp.array_equal(y_default, y_py)
    assert jnp.array_equal(y_default, y_traced)


def test_leak_value_changes_do_not_retrace():
    """The leak operand is traced, so one jitted closure serves every
    leak value — the serving tier flips it per decode step for free."""
    qc = QuantConfig(w_bits=4, in_bits=6, adc_bits=8)
    cfg_k = eng.EngineConfig(tile_rows=32, tile_cols=32, mode="deepnet",
                             quant=qc, use_kernel=True)
    w = jax.random.normal(jax.random.PRNGKey(17), (64, 32)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(19), (8, 64))
    pw = eng.program(w, cfg_k)
    traces = []

    @jax.jit
    def f(leak):
        traces.append(1)                 # host-side: bumps per trace only
        return eng.matmul(x, pw, cfg_k, leak_codes=leak)

    y0 = f(jnp.float32(0.0))
    y1 = f(jnp.float32(2.5))
    assert len(traces) == 1
    assert not jnp.array_equal(y0, y1)   # 2.5 codes > one 8-bit ADC LSB


def test_odd_row_tile_fallback_warns_once_and_matches_reference():
    """Expansion mode with an odd row-tile count: conversions fall back to
    per-plane groups at the MODE'S full scale (matching the reference),
    and the grouping change is warned exactly once per geometry.  The
    coarse-ADC config makes a wrong full scale visible: digitizing
    against r*(base-1) instead of 2r*(base-1) would show up as O(1)
    output error, not ulps."""
    cb_ops._FALLBACK_WARNED.clear()
    qc = QuantConfig(w_bits=4, in_bits=6, adc_bits=5, bits_per_cell=2)
    cfg_r = eng.EngineConfig(tile_rows=32, tile_cols=64, mode="expansion",
                             quant=qc)
    cfg_k = eng.EngineConfig(tile_rows=32, tile_cols=64, mode="expansion",
                             quant=qc, use_kernel=True)
    w = jax.random.normal(jax.random.PRNGKey(0), (96, 80)) * 0.3  # t = 3
    x = jax.random.normal(jax.random.PRNGKey(5), (16, 96))
    pw = eng.program(w, cfg_r)
    with pytest.warns(UserWarning, match="cannot pair"):
        y_k = eng.matmul(x, pw, cfg_k)
    y_r = eng.matmul(x, pw, cfg_r)
    assert jnp.allclose(y_k, y_r, atol=1e-4)
    # same geometry again: warned already, stays quiet
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        eng.matmul(x, pw, cfg_k)
    assert not [w_ for w_ in rec if "cannot pair" in str(w_.message)]


def test_stream_linear_matches_engine_linear():
    x = jax.random.normal(jax.random.PRNGKey(8), (16, 96))
    w = jax.random.normal(jax.random.PRNGKey(9), (96, 80)) * 0.3
    cfg = eng.EngineConfig(tile_rows=32, tile_cols=64, mode="deepnet",
                           quant=QuantConfig(w_bits=4, in_bits=8,
                                             adc_bits=10))
    assert jnp.allclose(stream_linear(x, w, cfg), eng.linear(x, w, cfg),
                        atol=1e-4)


def test_kernel_nonaligned_shapes_via_ops():
    """ops.py must pad/unpad odd shapes correctly."""
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 70))
    w = jax.random.normal(jax.random.PRNGKey(2), (70, 33)) * 0.5
    qc = QuantConfig(w_bits=4, in_bits=8, adc_bits=12)
    cfg_k = eng.EngineConfig(tile_rows=32, tile_cols=32, mode="deepnet",
                             quant=qc, use_kernel=True)
    cfg_r = eng.EngineConfig(tile_rows=32, tile_cols=32, mode="deepnet",
                             quant=qc)
    pw = eng.program(w, cfg_r)
    y_k = eng.matmul(x, pw, cfg_k)
    y_r = eng.matmul(x, pw, cfg_r)
    assert y_k.shape == (5, 33)
    assert jnp.allclose(y_k, y_r, atol=1e-4)
