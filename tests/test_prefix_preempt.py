"""Prefix-shared copy-on-write KV pages + preemptive paged scheduling:
end-to-end acceptance tests.

The invariants this file pins are the PR's exit criteria:

  * N requests with a common prompt head peak at strictly fewer
    distinct pages than N private copies, with every token stream
    bit-exact vs the dense-cache oracle;
  * copy-on-write really protects the page owner: a request whose
    prompt diverges INSIDE a shared page writes its own tokens into a
    private copy, and the owner's stream is unchanged;
  * under pool saturation with ``preemption=True``, higher-QoS arrivals
    evict the lowest-QoS resident, the victim re-admits through
    chunked prefill and completes its exact stream — zero drops, zero
    retraces across the preempt/re-admit boundary;
  * ``set_weights`` shrinking a tenant's page budget below its usage —
    including refcounted shared pages — gates only NEW admissions, and
    ``kv_report``/``qos_report`` distinguish owned vs shared pages.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import obs
from repro.configs import get_config
from repro.core.engine import EngineConfig
from repro.core.quant import QuantConfig
from repro.models.model import ModelConfig, build_model
from repro.serve.engine import BatchScheduler, Request


def _model(**overrides):
    cfg = get_config("qwen3_4b", smoke=True)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def _prompt(seed, vocab, plen):
    return jax.random.randint(jax.random.PRNGKey(seed), (plen,), 0,
                              vocab - 1).astype(jnp.int32)


def _serve_staggered(sched, reqs, stagger, max_steps=400):
    """Submit ``reqs`` in waves (``stagger[i]`` = step to submit request
    i at — later waves can alias the prefix pages earlier waves
    registered), drain, and track peak distinct pages + conservation.

    Returns ``(streams, peak_pages, finished_requests)``.
    """
    pool = sched._lanes["A"].pool
    done, finished = {}, []
    peak = [0]
    pending = sorted(zip(stagger, reqs), key=lambda x: x[0])
    steps = 0
    while (len(done) < len(reqs)) and steps < max_steps:
        while pending and pending[0][0] <= steps:
            sched.submit(pending.pop(0)[1])
        for r in sched.step():
            done[r.rid] = r.out
            finished.append(r)
        if pool is not None:
            assert pool.conservation_ok()
            peak[0] = max(peak[0], pool.pages_in_use)
        steps += 1
    assert len(done) == len(reqs), f"stalled: {len(done)}/{len(reqs)}"
    return done, peak[0], finished


# -- shared-prefix page savings, bit-exact ------------------------------------

def test_shared_prefix_uses_fewer_pages_with_bit_exact_streams():
    """Four requests sharing a 16-token head: the prefix-sharing pool
    must peak strictly below the private-pages baseline while every
    stream matches the dense oracle token-for-token."""
    cfg, m, params = _model()
    head = _prompt(777, cfg.vocab, 16)
    prompts = [jnp.concatenate([head, _prompt(900 + i, cfg.vocab, 4 + 2 * i)])
               for i in range(4)]
    # request 0 prefills and registers its pages first (plen 20, chunk 4
    # -> 5 steps); the rest arrive after and can alias the head
    stagger = [0, 6, 6, 6]
    arms = {}
    peaks = {}
    for name, kw in (("dense", dict(kv="dense")),
                     ("private", dict(kv="paged", page_size=8)),
                     ("shared", dict(kv="paged", page_size=8,
                                     prefix_share=True))):
        if name == "shared":
            obs.reset()
        sched = BatchScheduler(m, params, n_slots=4, max_len=32, **kw)
        reqs = [Request(rid=i, prompt=p, max_new=4)
                for i, p in enumerate(prompts)]
        arms[name], peaks[name], _ = _serve_staggered(sched, reqs, stagger)
        if name == "shared":
            assert sched.metrics.total("serve_kv_pages_shared_total",
                                       tenant="A") >= 2 * 3
            assert sched.metrics.total("serve_kv_shared_tokens_total",
                                       tenant="A") >= 16 * 3
            pool = sched._lanes["A"].pool
            assert pool.pages_in_use == 0       # fully drained
            assert pool.prefix_entries == 0     # index left with pages
            reg = obs.registry()
            assert reg.total("serve_jit_traces_total",
                             closure="decode", tenant="A") == 1
            assert reg.total("serve_jit_retraces_total") == 0
    assert arms["shared"] == arms["dense"]
    assert arms["private"] == arms["dense"]
    assert peaks["shared"] < peaks["private"]


def test_cow_protects_the_owner_on_sub_page_divergence():
    """Request 1 matches request 0's prompt for 12 of 16 tokens —
    divergence INSIDE the second page.  The pool aliases the page and
    privatizes it copy-on-write before request 1's own tokens land, so
    request 0 (still decoding from the original page) keeps its exact
    dense-oracle stream.  Without the copy this corrupts r0's cache."""
    cfg, m, params = _model()
    p0 = _prompt(42, cfg.vocab, 16)
    p1 = jnp.concatenate([p0[:12], _prompt(43, cfg.vocab, 8)])
    stagger = [0, 5]            # r0's prefill (4 steps) completes first
    arms = {}
    for name, kw in (("dense", dict(kv="dense")),
                     ("shared", dict(kv="paged", page_size=8,
                                     prefix_share=True))):
        sched = BatchScheduler(m, params, n_slots=2, max_len=32, **kw)
        reqs = [Request(rid=0, prompt=p0, max_new=12),
                Request(rid=1, prompt=p1, max_new=6)]
        arms[name], _, _ = _serve_staggered(sched, reqs, stagger)
        if name == "shared":
            assert sched.metrics.total("serve_kv_cow_total",
                                       tenant="A") == 1
            assert sched.metrics.total("serve_kv_shared_tokens_total",
                                       tenant="A") == 12
    assert arms["shared"] == arms["dense"]


# -- preemption under saturation ----------------------------------------------

def test_preemption_admits_high_qos_and_drops_nothing():
    """A pool sized for ~2 resident requests, 4 slots: two low-QoS
    requests saturate it, two high-QoS arrivals preempt one of them,
    the victims re-admit when pages free up, and all four complete
    their exact dense-oracle streams — zero drops, zero retraces."""
    cfg, m, params = _model()
    prompts = [_prompt(500 + i, cfg.vocab, 20) for i in range(4)]
    qos = (1.0, 1.0, 4.0, 4.0)

    def reqs():
        return [Request(rid=i, prompt=p, max_new=5, qos=q)
                for i, (p, q) in enumerate(zip(prompts, qos))]

    dense = BatchScheduler(m, params, n_slots=4, max_len=32, kv="dense")
    ref, _, _ = _serve_staggered(dense, reqs(), [0, 0, 0, 0])

    obs.reset()
    sched = BatchScheduler(m, params, n_slots=4, max_len=32, page_size=8,
                           kv_pages=8, preemption=True)
    # low-QoS pair first: by the time the high-QoS pair arrives they
    # hold 6 of 8 pages and are mid-decode
    done, _, finished = _serve_staggered(sched, reqs(), [0, 0, 8, 8])
    assert done == ref
    preempted = [r for r in finished if r.preemptions]
    assert preempted and all(r.qos == 1.0 for r in preempted)
    assert sched.metrics.total("serve_preemptions_total",
                               tenant="A") == sum(
        r.preemptions for r in finished)
    pool = sched._lanes["A"].pool
    assert pool.pages_in_use == 0 and pool.conservation_ok()
    reg = obs.registry()
    assert reg.total("serve_jit_traces_total",
                     closure="decode", tenant="A") == 1
    assert reg.total("serve_jit_retraces_total") == 0


def test_preemption_without_higher_qos_keeps_fifo():
    """Equal QoS everywhere: preemption must never fire (strictly-lower
    rule), degrading to the ordinary FIFO backpressure."""
    cfg, m, params = _model()
    prompts = [_prompt(520 + i, cfg.vocab, 20) for i in range(3)]
    sched = BatchScheduler(m, params, n_slots=4, max_len=32, page_size=8,
                           kv_pages=8, preemption=True)
    reqs = [Request(rid=i, prompt=p, max_new=5) for i, p in
            enumerate(prompts)]
    done, _, finished = _serve_staggered(sched, reqs, [0, 0, 0])
    assert all(r.preemptions == 0 for r in finished)
    assert sched.metrics.total("serve_preemptions_total", tenant="A") == 0


def test_preempted_request_reshares_its_prefix_on_readmission():
    """prefix_share + preemption compose: the victim's head pages stay
    alive through the other sharer's refcount, so its re-admission
    aliases them again instead of re-prefilling — and every stream
    still matches the dense oracle."""
    cfg, m, params = _model()
    head = _prompt(600, cfg.vocab, 16)
    p0 = jnp.concatenate([head, _prompt(601, cfg.vocab, 4)])
    p1 = jnp.concatenate([head, _prompt(602, cfg.vocab, 4)])
    # small enough (1 page) that ONE eviction admits it — the other
    # sharer stays resident, keeping the head pages alive and indexed
    p2 = _prompt(603, cfg.vocab, 4)

    def reqs():
        return [Request(rid=0, prompt=p0, max_new=12, qos=1.0),
                Request(rid=1, prompt=p1, max_new=12, qos=1.0),
                Request(rid=2, prompt=p2, max_new=5, qos=4.0)]

    stagger = [0, 6, 9]
    dense = BatchScheduler(m, params, n_slots=3, max_len=32, kv="dense")
    ref, _, _ = _serve_staggered(dense, reqs(), stagger)

    sched = BatchScheduler(m, params, n_slots=3, max_len=32, page_size=8,
                           kv_pages=6, prefix_share=True, preemption=True)
    done, _, finished = _serve_staggered(sched, reqs(), stagger)
    assert done == ref
    assert sched.metrics.total("serve_preemptions_total", tenant="A") >= 1
    # head shared at the follower's first admission AND again when the
    # victim re-admitted: >= 2 shared-page events of 2 pages each
    assert sched.metrics.total("serve_kv_pages_shared_total",
                               tenant="A") >= 4
    victim = [r for r in finished if r.preemptions]
    # the victim aliased the head at its first admission AND at
    # re-admission: 16 shared positions each time
    assert victim and any(r.shared_tokens >= 32 for r in victim)


# -- set_weights x shared pages (multi-tenant) --------------------------------

TINY = ModelConfig(
    name="tiny", family="dense", n_layers=2, d_model=32, n_heads=2,
    n_kv=2, head_dim=16, d_ff=64, vocab=128, backend="crossbar",
    dtype=jnp.float32,
    xbar=EngineConfig(tile_rows=32, tile_cols=32, mode="deepnet",
                      quant=QuantConfig(w_bits=4, in_bits=6, adc_bits=12)))


def test_set_weights_budget_shrink_below_shared_usage_gates_new_only():
    """Shrinking tenant B's page budget below its pages_in_use while
    some of those pages are refcounted (shared) must not evict anything:
    resident requests keep decoding on their exact pages, only NEW
    admissions gate, and the reports split owned vs shared."""
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    sched = BatchScheduler(model, params, n_slots=2, max_len=16,
                           tenants={"A": params, "B": params},
                           page_size=4, kv_pages=8, prefix_share=True)
    head = _prompt(700, TINY.vocab, 8)
    b0 = Request(rid=0, prompt=head, max_new=6, model_id="B")
    sched.submit(b0)
    for _ in range(3):                     # prefill (2 chunks) + register
        sched.step()
    b1 = Request(rid=1, prompt=jnp.concatenate(
        [head, _prompt(701, TINY.vocab, 2)]), max_new=6, model_id="B")
    sched.submit(b1)
    sched.step()
    pool = sched._lanes["B"].pool
    assert pool.pages_shared == 2          # b1 aliased b0's head pages
    used = pool.pages_in_use
    pages_before = {r: pool.row_pages(r) for r in range(2)}
    sched.set_weights({"A": 3.0, "B": 1.0})
    assert pool.budget < used              # shrunk below live usage
    # nothing evicted: both residents keep their exact pages and emit
    out0, out1 = len(b0.out), len(b1.out)
    sched.step()
    assert {r: pool.row_pages(r) for r in range(2)} == pages_before
    assert len(b0.out) == out0 + 1 and len(b1.out) == out1 + 1
    rep = sched.kv_report()["B"]
    assert rep["pages_in_use"] == used
    assert rep["pages_shared"] == 2
    assert rep["pages_owned"] == used - 2
    qrep = sched.qos_report()["B"]
    assert qrep["pages_shared"] == 2
    assert qrep["pages_owned"] == used - 2
    assert qrep["pages_in_use"] > qrep["page_budget"]
    # a NEW admission is gated while usage exceeds the budget...
    b2 = Request(rid=2, prompt=_prompt(702, TINY.vocab, 5), max_new=2,
                 model_id="B")
    sched.submit(b2)
    sched.step()
    assert b2 in sched._lanes["B"].queue   # queued, not dropped
    # ...and admits once the residents drain under the new cap
    done = {}
    for _ in range(60):
        for r in sched.step():
            done[r.rid] = r.out
        assert pool.conservation_ok()
        if len(done) == 3:
            break
    assert set(done) == {0, 1, 2}
    assert pool.pages_in_use == 0


def test_flags_require_paged_kv():
    cfg, m, params = _model()
    with pytest.raises(ValueError, match="paged"):
        BatchScheduler(m, params, n_slots=2, max_len=32, kv="dense",
                       prefix_share=True)
    with pytest.raises(ValueError, match="paged"):
        BatchScheduler(m, params, n_slots=2, max_len=32, kv="dense",
                       preemption=True)
