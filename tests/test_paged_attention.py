"""Paged-attention kernel CI: interpret-mode bit-exactness vs the ref.py
oracle AND the dense-cache SDPA at equal logical contents — the contract
the paged serving tier rests on (see kernels/paged_attention/kernel.py).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.paged_attention import paged_attention, paged_attention_ref
from repro.kernels.paged_attention.ref import gather_pages
from repro.models.layers import AttnConfig, _chunked_sdpa

jax.config.update("jax_enable_x64", False)

_slow = pytest.mark.slow  # interpret-mode sweeps: CI full lane only; one
# point of each sweep stays unmarked so the PR fast lane keeps a
# kernel-correctness assertion


def _case(key, b, sq, hq, kv, hd, ps, ppr, n_pages, dtype):
    """Random paged K/V contents with per-row ragged depths/tables."""
    kq, kk, kv_, kt, kl = jax.random.split(key, 5)
    q = jax.random.normal(kq, (b, sq, hq, hd)).astype(dtype)
    kp = jax.random.normal(kk, (n_pages + 1, ps, kv, hd)).astype(dtype)
    vp = jax.random.normal(kv_, (n_pages + 1, ps, kv, hd)).astype(dtype)
    # each row owns a distinct page run; trailing entries null (0)
    maxlen = ps * ppr
    pt = jnp.zeros((b, ppr), jnp.int32)
    nxt = 1
    lens = []
    for r in range(b):
        depth = int(jax.random.randint(jax.random.fold_in(kl, r), (),
                                       sq, maxlen + 1))
        npg = -(-depth // ps)
        pt = pt.at[r, :npg].set(jnp.arange(nxt, nxt + npg))
        nxt += npg
        lens.append(depth)
    assert nxt - 1 <= n_pages
    kv_len = jnp.asarray(lens, jnp.int32)
    return q, kp, vp, pt, kv_len, kv_len - sq


SWEEP = [
    # (b, sq, hq, kv, hd, ps, ppr)
    (2, 4, 4, 2, 8, 8, 3),        # smallest: runs in the fast lane
    pytest.param(3, 1, 8, 8, 16, 4, 4, marks=_slow),   # MHA decode, sq=1
    pytest.param(4, 6, 6, 2, 8, 16, 2, marks=_slow),   # GQA g=3
    pytest.param(1, 8, 4, 1, 32, 8, 4, marks=_slow),   # MQA
]


@pytest.mark.parametrize("b,sq,hq,kv,hd,ps,ppr", SWEEP)
def test_paged_kernel_bit_exact_vs_ref_and_dense(b, sq, hq, kv, hd, ps,
                                                 ppr):
    """The tripod the serving tier stands on: kernel == ref oracle ==
    dense-path SDPA over the gathered view, BITWISE."""
    key = jax.random.PRNGKey(b * 100 + ps)
    q, kp, vp, pt, kv_len, q_off = _case(key, b, sq, hq, kv, hd, ps, ppr,
                                         n_pages=b * ppr, dtype=jnp.bfloat16)
    ref = paged_attention_ref(q, kp, vp, pt, kv_len, q_off)
    ker = paged_attention(q, kp, vp, pt, kv_len, q_off, interpret=True)
    assert jnp.array_equal(ker, ref)
    cfg = AttnConfig(d_model=hq * hd, n_heads=hq, n_kv=kv, head_dim=hd)
    gk = gather_pages(kp, pt)
    gv = gather_pages(vp, pt)
    dense = _chunked_sdpa(q, gk, gv, cfg, kv_len=kv_len, q_offset=q_off)
    assert jnp.array_equal(ref, dense)
    assert jnp.array_equal(ker, dense)


@pytest.mark.parametrize("dtype", [
    jnp.bfloat16,
    pytest.param(jnp.float32, marks=_slow)])
def test_paged_kernel_dtypes(dtype):
    q, kp, vp, pt, kv_len, q_off = _case(jax.random.PRNGKey(7), 2, 4, 4,
                                         2, 8, 8, 3, n_pages=6, dtype=dtype)
    ref = paged_attention_ref(q, kp, vp, pt, kv_len, q_off)
    ker = paged_attention(q, kp, vp, pt, kv_len, q_off, interpret=True)
    assert ker.dtype == dtype
    assert jnp.array_equal(ker, ref)


def test_paged_kernel_fp8_cache_upcasts_like_dense_path():
    """fp8 K/V pages upcast to the query dtype inside the dot — the same
    branch the dense path takes (models/layers._sdpa)."""
    if not hasattr(jnp, "float8_e4m3fn"):
        pytest.skip("no fp8 dtype in this jax build")
    q, kp, vp, pt, kv_len, q_off = _case(jax.random.PRNGKey(9), 2, 4, 4,
                                         2, 8, 8, 3, n_pages=6,
                                         dtype=jnp.bfloat16)
    kp8 = kp.astype(jnp.float8_e4m3fn)
    vp8 = vp.astype(jnp.float8_e4m3fn)
    ref = paged_attention_ref(q, kp8, vp8, pt, kv_len, q_off)
    ker = paged_attention(q, kp8, vp8, pt, kv_len, q_off, interpret=True)
    assert ker.dtype == q.dtype
    assert jnp.array_equal(ker, ref)
    cfg = AttnConfig(d_model=4 * 8, n_heads=4, n_kv=2, head_dim=8)
    dense = _chunked_sdpa(q, gather_pages(kp8, pt), gather_pages(vp8, pt),
                          cfg, kv_len=kv_len, q_offset=q_off)
    assert jnp.array_equal(ker, dense)


def test_null_page_contents_never_leak_into_output():
    """Poisoning the null page must not change any output: every
    position the table routes to page 0 is excluded by the length mask
    with an exact softmax zero."""
    q, kp, vp, pt, kv_len, q_off = _case(jax.random.PRNGKey(11), 2, 4, 4,
                                         2, 8, 8, 3, n_pages=6,
                                         dtype=jnp.bfloat16)
    clean = paged_attention(q, kp, vp, pt, kv_len, q_off, interpret=True)
    kp_p = kp.at[0].set(jnp.asarray(1e4, kp.dtype))
    vp_p = vp.at[0].set(jnp.asarray(-1e4, vp.dtype))
    poisoned = paged_attention(q, kp_p, vp_p, pt, kv_len, q_off,
                               interpret=True)
    assert jnp.array_equal(clean, poisoned)


def test_aliased_page_tables_bit_exact_with_materialized_copies():
    """The prefix-sharing contract: two rows whose tables point at the
    SAME physical pages (refcounted prefix sharing) must produce output
    bitwise identical to two rows reading private copies of those
    pages.  Gathers are read-only, so aliasing is invisible to both the
    kernel and the oracle — the scheduler's COW machinery exists purely
    to keep *writes* off shared pages."""
    key = jax.random.PRNGKey(21)
    b, sq, hq, kv, hd, ps, ppr = 2, 4, 4, 2, 8, 8, 3
    kq, kk, kv_ = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, sq, hq, hd)).astype(jnp.bfloat16)
    kp = jax.random.normal(kk, (8, ps, kv, hd)).astype(jnp.bfloat16)
    vp = jax.random.normal(kv_, (8, ps, kv, hd)).astype(jnp.bfloat16)
    # aliased: both rows share pages 1,2 for their prefix, own tails 3/4
    pt_alias = jnp.asarray([[1, 2, 3], [1, 2, 4]], jnp.int32)
    # materialized: row 1's prefix copied into private pages 5,6
    kp_mat = kp.at[5].set(kp[1]).at[6].set(kp[2])
    vp_mat = vp.at[5].set(vp[1]).at[6].set(vp[2])
    pt_mat = jnp.asarray([[1, 2, 3], [5, 6, 4]], jnp.int32)
    kv_len = jnp.asarray([ps * 3, ps * 3 - 2], jnp.int32)
    q_off = kv_len - sq
    for fn in (paged_attention_ref,
               lambda *a: paged_attention(*a, interpret=True)):
        aliased = fn(q, kp, vp, pt_alias, kv_len, q_off)
        materialized = fn(q, kp_mat, vp_mat, pt_mat, kv_len, q_off)
        assert jnp.array_equal(aliased, materialized)


def test_shape_validation_errors():
    q, kp, vp, pt, kv_len, q_off = _case(jax.random.PRNGKey(1), 2, 4, 4,
                                         2, 8, 8, 3, n_pages=6,
                                         dtype=jnp.bfloat16)
    with pytest.raises(ValueError, match="page_table rows"):
        paged_attention(q, kp, vp, pt[:1], kv_len, q_off)
    with pytest.raises(ValueError, match="head_dim"):
        paged_attention(q[..., :4], kp, vp, pt, kv_len, q_off)
    with pytest.raises(ValueError, match="shape"):
        paged_attention(q, kp, vp, pt, kv_len[:1], q_off)
