"""Multi-tenant plane multiplexing: two resident checkpoints served from
the two tile planes of one executor, per-tenant fingerprints/versions,
tenant-targeted hot-swap (read-under-write re-purposed for multi-tenancy),
and the multi-tenant BatchScheduler."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core.engine import EngineConfig
from repro.core.executor import CrossbarExecutor
from repro.core.quant import QuantConfig
from repro.models.model import ModelConfig, build_model
from repro.serve.engine import BatchScheduler, Request
from repro.serve.hotswap import HotSwapper, finetune_delta

CFG = EngineConfig(tile_rows=32, tile_cols=32, mode="deepnet",
                   quant=QuantConfig(w_bits=4, in_bits=8, adc_bits=10))

TINY = ModelConfig(
    name="tiny", family="dense", n_layers=2, d_model=32, n_heads=2,
    n_kv=2, head_dim=16, d_ff=64, vocab=128, backend="crossbar",
    dtype=jnp.float32,
    xbar=EngineConfig(tile_rows=32, tile_cols=32, mode="deepnet",
                      quant=QuantConfig(w_bits=4, in_bits=6, adc_bits=12)))


def _w(key, k, n):
    return jax.random.normal(jax.random.PRNGKey(key), (k, n)) * 0.3


def _cold(w):
    ex = CrossbarExecutor(CFG)
    ex.program_params({"head": w})
    return ex


# -- executor-level tenant addressing -----------------------------------------

def test_two_tenants_read_their_own_planes_bit_exact():
    w_a, w_b = _w(1, 64, 48), _w(2, 64, 48)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 64))
    ex = CrossbarExecutor(CFG)
    ex.program_params({"head": w_a})                  # tenant A
    ex.program_params({"head": w_b}, tenant="B")      # tenant B, twin plane
    assert ex.tenants == ["A", "B"]
    # each tenant's read is bit-exact with a dedicated single-tenant
    # executor programmed from the same checkpoint...
    assert jnp.array_equal(ex.linear(x, w_a, "head", tenant="A"),
                           _cold(w_a).linear(x, w_a, "head"))
    assert jnp.array_equal(ex.linear(x, w_b, "head", tenant="B"),
                           _cold(w_b).linear(x, w_b, "head"))
    # ...from HALF the physical devices of two dedicated plane pairs
    assert ex.n_devices_physical * 2 == (
        _cold(w_a).n_devices_physical + _cold(w_b).n_devices_physical)


def test_ambient_read_tenant_scope_routes_reads_and_fingerprints():
    w_a, w_b = _w(4, 64, 32), _w(5, 64, 32)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 64))
    ex = CrossbarExecutor(CFG)
    ex.program_params({"head": w_a})
    ex.program_params({"head": w_b}, tenant="B")
    y_a = ex.linear(x, w_a, "head", tenant="A")
    with ex.read_tenant("B"):
        assert jnp.array_equal(ex.linear(x, w_b, "head"),
                               _cold(w_b).linear(x, w_b, "head"))
        assert ex.fingerprint() == _cold(w_b).fingerprint()
    # scope restores: default reads are tenant A again
    assert jnp.array_equal(ex.linear(x, w_a, "head"), y_a)
    assert ex.fingerprint() == _cold(w_a).fingerprint()
    with pytest.raises(ValueError, match="unknown tenant"):
        with ex.read_tenant("C"):
            pass


def test_per_tenant_fingerprints_and_versions_are_isolated():
    w_a, w_b = _w(7, 64, 32), _w(8, 64, 32)
    ex = CrossbarExecutor(CFG)
    ex.program_params({"head": w_a})
    fp_a = ex.fingerprint()
    assert ex.version("A") == 1 and ex.version("B") == 0
    # programming tenant B leaves tenant A's identity untouched
    ex.program_params({"head": w_b}, tenant="B")
    assert ex.fingerprint(tenant="A") == fp_a
    assert ex.version("A") == 1 and ex.version("B") == 1
    assert ex.fingerprint(tenant="B") != fp_a
    assert ex.fingerprints(tenant="B") == {
        "head": ex.fingerprint("head", tenant="B")}
    # programmed_version stays the tenant-A counter (dashboards compare it)
    assert ex.programmed_version == 1


def test_tenant_shapes_must_match_the_shared_stack():
    ex = CrossbarExecutor(CFG)
    ex.program_params({"head": _w(9, 64, 32)})
    with pytest.raises(ValueError, match="tile geometry"):
        ex.program_params({"head": _w(10, 32, 32)}, tenant="B")


def test_program_params_rejects_second_tree_per_tenant():
    ex = CrossbarExecutor(CFG)
    ex.program_params({"head": _w(11, 64, 32)}, tenant="B")
    with pytest.raises(RuntimeError, match="tenant 'B'"):
        ex.program_params({"head": _w(12, 64, 32)}, tenant="B")


def test_tenant_b_swap_under_tenant_a_reads():
    """The tentpole invariant at executor scale: reprogramming tenant B
    never perturbs tenant A (fingerprint or arithmetic), B's own reads
    pause while its planes are mid-write, and promotion is atomic."""
    w_a, w_b, w_b2 = _w(13, 96, 48), _w(14, 96, 48), _w(15, 96, 48)
    x = jax.random.normal(jax.random.PRNGKey(16), (3, 96))
    ex = CrossbarExecutor(CFG)
    ex.program_params({"head": w_a})
    ex.program_params({"head": w_b}, tenant="B")
    fp_a, fp_b = ex.fingerprint(tenant="A"), ex.fingerprint(tenant="B")
    y_a = ex.linear(x, w_a, "head", tenant="A")

    plan = ex.begin_swap({"head": w_b2}, tenant="B")
    assert plan.in_place and plan.tenant == "B"
    ex.write_chunks(1)
    assert not plan.done
    # mid-write: A serves untouched; B's wordlines drive write pulses
    assert jnp.array_equal(ex.linear(x, w_a, "head", tenant="A"), y_a)
    assert ex.fingerprint(tenant="A") == fp_a
    with pytest.raises(RuntimeError, match="mid-write"):
        ex.linear(x, w_b, "head", tenant="B")
    # B's resident identity is still the OLD checkpoint until promote
    assert ex.fingerprint(tenant="B") == fp_b
    while not plan.done:
        ex.write_chunks(8)
    ex.promote()
    assert ex.fingerprint(tenant="A") == fp_a
    assert ex.fingerprint(tenant="B") == _cold(w_b2).fingerprint()
    assert ex.version("B") == 2 and ex.version("A") == 1
    assert jnp.array_equal(ex.linear(x, w_b2, "head", tenant="B"),
                           _cold(w_b2).linear(x, w_b2, "head"))
    assert jnp.array_equal(ex.linear(x, w_a, "head", tenant="A"), y_a)


def test_tenant_b_swap_abort_keeps_old_b_planes():
    w_a, w_b = _w(17, 64, 32), _w(18, 64, 32)
    x = jax.random.normal(jax.random.PRNGKey(19), (2, 64))
    ex = CrossbarExecutor(CFG)
    ex.program_params({"head": w_a})
    ex.program_params({"head": w_b}, tenant="B")
    ex.begin_swap({"head": w_b + 0.1}, tenant="B")
    ex.write_chunks(64)
    ex.abort_swap()
    # staged planes were buffered in the plan, never on the pair: B still
    # serves its old checkpoint after the abort
    assert jnp.array_equal(ex.linear(x, w_b, "head", tenant="B"),
                           _cold(w_b).linear(x, w_b, "head"))
    assert ex.version("B") == 1


def test_live_deploy_tenant_b_via_chunked_swap():
    """begin_swap(tenant='B') with no resident B is a live deploy onto
    the free twin planes — the scheduler uses this to bring a second
    model online under tenant A's traffic."""
    w_a, w_b = _w(20, 64, 32), _w(21, 64, 32)
    x = jax.random.normal(jax.random.PRNGKey(22), (2, 64))
    ex = CrossbarExecutor(CFG)
    ex.program_params({"head": w_a})
    assert ex.tenants == ["A"]
    hs = HotSwapper(ex, {"head": w_b}, chunks_per_step=2, tenant="B")
    while not hs.done:
        hs.step()
    hs.promote()
    assert ex.tenants == ["A", "B"]
    assert jnp.array_equal(ex.linear(x, w_b, "head", tenant="B"),
                           _cold(w_b).linear(x, w_b, "head"))
    rep = hs.report()
    assert rep["tenant"] == "B" and rep["policy"] == "overlapped"


def test_new_tenant_deploy_refused_while_swap_in_flight():
    """A first-time tenant claims the twin slots — the write target of an
    in-flight tenant-A swap; admitting it would make promote() fail
    half-applied (mixed planes).  Must be refused up front."""
    w_a = _w(25, 64, 32)
    ex = CrossbarExecutor(CFG)
    ex.program_params({"head": w_a})
    ex.begin_swap({"head": w_a + 0.1})
    ex.write_chunks(64)                 # fully staged, ready to promote
    with pytest.raises(RuntimeError, match="while a hot-swap is in"):
        ex.program_params({"head": _w(26, 64, 32)}, tenant="B")
    ex.promote()                        # promotion still lands cleanly
    assert ex.version("A") == 2
    ex.program_params({"head": _w(26, 64, 32)}, tenant="B")
    assert ex.tenants == ["A", "B"]


def test_shadow_swap_blocked_while_twin_resident_and_evict_frees_it():
    w_a, w_b = _w(23, 64, 32), _w(24, 64, 32)
    ex = CrossbarExecutor(CFG)
    ex.program_params({"head": w_a})
    ex.program_params({"head": w_b}, tenant="B")
    # tenant A has no free write plane while B is resident
    with pytest.raises(RuntimeError, match="no free write plane"):
        ex.begin_swap({"head": w_a + 0.1})
    with pytest.raises(ValueError, match="anchors"):
        ex.evict_tenant("A")
    ex.evict_tenant("B")
    assert ex.tenants == ["A"]
    with pytest.raises(RuntimeError, match="not resident"):
        ex.fingerprint(tenant="B")
    ex.swap({"head": w_a + 0.1})        # the shadow slot is free again
    assert ex.version("A") == 2


# -- scheduler-level multiplexing ---------------------------------------------

def _params_pair(delta_seed=7):
    model = build_model(TINY)
    params_a = model.init(jax.random.PRNGKey(0))
    params_b = finetune_delta(params_a, scale=0.05, seed=delta_seed)
    return model, params_a, params_b


def _submit(sched, model_id, n_req, max_new=4, seed0=0):
    for i in range(n_req):
        p = jax.random.randint(jax.random.PRNGKey(seed0 + i), (5,), 0,
                               TINY.vocab - 1).astype(jnp.int32)
        sched.submit(Request(rid=seed0 + i, prompt=p, max_new=max_new,
                             model_id=model_id))


def _drain(sched, n_req, max_steps=200):
    done, steps = [], 0
    while len(done) < n_req and steps < max_steps:
        done += sched.step()
        steps += 1
    return done


def test_multiplexed_scheduler_matches_dedicated_single_tenant():
    """Both tenants' token streams from ONE multiplexed executor are
    bit-identical to two dedicated single-tenant schedulers — at half
    the physical device count."""
    model_m, params_a, params_b = _params_pair()
    sched = BatchScheduler(model_m, params_a, n_slots=2, max_len=24,
                           tenants={"A": params_a, "B": params_b})
    assert sched.tenants == ["A", "B"]
    _submit(sched, "A", 2, seed0=0)
    _submit(sched, "B", 2, seed0=100)
    done = _drain(sched, 4)
    assert len(done) == 4
    mux = {r.rid: r.out for r in done}

    for tenant, params, seed0 in (("A", params_a, 0), ("B", params_b, 100)):
        model_d = build_model(TINY)
        ded = BatchScheduler(model_d, params, n_slots=2, max_len=24)
        _submit(ded, "A", 2, seed0=seed0)
        for r in _drain(ded, 2):
            assert r.out == mux[r.rid], (tenant, r.rid)
        # dedicated pair burns its own full stack per checkpoint
        assert (model_d.executor.n_devices_physical
                == model_m.executor.n_devices_physical)


def test_scheduler_rejects_multiplex_on_digital_backend():
    cfg = dataclasses.replace(TINY, backend="digital")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(RuntimeError, match="crossbar"):
        BatchScheduler(model, params, n_slots=2, max_len=24,
                       tenants={"A": params, "B": params})


def test_scheduler_requires_anchor_tenant():
    model, params_a, params_b = _params_pair()
    with pytest.raises(ValueError, match="tenant 'A'"):
        BatchScheduler(model, params_a, n_slots=2, max_len=24,
                       tenants={"B": params_b})


def test_scheduler_rejects_unknown_model_id():
    model, params_a, _ = _params_pair()
    sched = BatchScheduler(model, params_a, n_slots=2, max_len=24)
    with pytest.raises(ValueError, match="unknown tenant"):
        sched.submit(Request(rid=0, prompt=jnp.zeros(3, jnp.int32),
                             max_new=2, model_id="B"))


def test_tenant_b_hot_swap_under_tenant_a_traffic_drops_nothing():
    """The acceptance scenario: B's planes reprogram in chunks between
    A's decode steps; A's stream is bit-identical to a swap-free run,
    zero A-requests drop, B pauses and resumes on the new checkpoint."""
    model, params_a, params_b = _params_pair()
    params_b2 = finetune_delta(params_a, scale=0.09, seed=31)

    # reference: same multiplexed config, no swap — A's expected stream
    model_r, _, _ = _params_pair()
    ref = BatchScheduler(model_r, params_a, n_slots=2, max_len=24,
                         tenants={"A": params_a, "B": params_b})
    _submit(ref, "A", 2, max_new=8, seed0=0)
    ref_out = {r.rid: r.out for r in _drain(ref, 2)}

    sched = BatchScheduler(model, params_a, n_slots=2, max_len=24,
                           tenants={"A": params_a, "B": params_b})
    _submit(sched, "A", 2, max_new=8, seed0=0)
    _submit(sched, "B", 1, max_new=3, seed0=200)
    done = []
    for _ in range(2):
        done += sched.step()
    fp_a = model.executor.fingerprint(tenant="A")
    sched.begin_hot_swap(params_b2, chunks_per_step=6, tenant="B")
    assert sched._lanes["B"].paused
    steps = 0
    while (sched.swap_in_flight or len(done) < 3) and steps < 200:
        done += sched.step()
        steps += 1
    assert len(done) == 3                      # zero dropped, either tenant
    for r in done:
        if r.model_id == "A":
            assert r.out == ref_out[r.rid]     # A's stream unperturbed
            assert len(r.out) == 8
    assert not sched._lanes["B"].paused
    assert model.executor.fingerprint(tenant="A") == fp_a
    cold = CrossbarExecutor(TINY.xbar)
    cold.program_params(params_b2)
    assert model.executor.fingerprint(tenant="B") == cold.fingerprint()
    (rep,) = sched.swap_history
    assert rep["tenant"] == "B" and rep["policy"] == "overlapped"
    assert rep["decode_steps_during_swap"] > 0
    assert rep["sustains_2x_during_swap"]
