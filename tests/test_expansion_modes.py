"""Per-weight expansion-mode serving (PR 6): fused-pair bank semantics,
mode-policy resolution, IR-drop-aware auto-selection, and the mixed-mode
read path of the executor."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core import engine as eng
from repro.core import ir_drop, modes
from repro.core.engine import EngineConfig
from repro.core.executor import CrossbarExecutor
from repro.core.modes import BankState, StackState
from repro.core.quant import QuantConfig
from repro.core.timing import PAPER


def _stack_cfg(r=8, m=6):
    return modes.StackConfig(rows_per_plane=r, n_cols=m)


def _pair(key, r=8, m=6):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    g_top = jax.random.uniform(k1, (r, m), minval=1e-5, maxval=1e-4)
    g_bot = jax.random.uniform(k2, (r, m), minval=1e-5, maxval=1e-4)
    v_top = jax.random.uniform(k3, (r,), maxval=PAPER.v_read)
    v_bot = jax.random.uniform(k4, (r,), maxval=PAPER.v_read)
    return StackState(g_top, g_bot, jnp.bool_(True)), v_top, v_bot


# -- fused-pair bank ops vs the N=2 StackState originals ----------------------

def test_bank_expansion_mac_bit_exact_vs_stack_at_n2():
    cfg = _stack_cfg()
    pair, v_top, v_bot = _pair(jax.random.PRNGKey(0))
    bank = modes.bank_from_pair(pair)
    assert jnp.array_equal(
        modes.bank_expansion_mac(bank, v_top, v_bot, cfg),
        modes.expansion_mac(pair, v_top, v_bot, cfg))
    # and through the exact nodal solve
    assert jnp.array_equal(
        modes.bank_expansion_mac_ir(bank, v_top, v_bot, cfg),
        modes.expansion_mac_ir(pair, v_top, v_bot, cfg))


def test_bank_fused_pair_selects_planes_in_tall_bank():
    cfg = _stack_cfg()
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    g = jnp.stack([jax.random.uniform(k, (8, 6), minval=1e-5, maxval=1e-4)
                   for k in ks[:4]])
    v_top = jax.random.uniform(ks[4], (8,), maxval=PAPER.v_read)
    v_bot = jax.random.uniform(ks[5], (8,), maxval=PAPER.v_read)
    bank = BankState(g, jnp.int32(0))
    # fusing planes (1, 3) of an N=4 bank reads exactly those two planes
    pair = StackState(g[1], g[3], jnp.bool_(True))
    got = modes.bank_expansion_mac(bank, v_top, v_bot, cfg,
                                   idx_top=1, idx_bot=3)
    assert jnp.array_equal(got, modes.expansion_mac(pair, v_top, v_bot, cfg))
    # indices may be traced: a jitted closure rotates the fused pair
    # without re-lowering
    jitted = jax.jit(lambda b, it, ib: modes.bank_expansion_mac(
        b, v_top, v_bot, cfg, idx_top=it, idx_bot=ib))
    assert jnp.allclose(jitted(bank, jnp.int32(1), jnp.int32(3)), got,
                        rtol=1e-6, atol=0.0)


@pytest.mark.parametrize("r,m", [
    (4, 4), (6, 5),
    pytest.param(8, 8, marks=pytest.mark.slow),
    pytest.param(10, 10, marks=pytest.mark.slow),
    pytest.param(12, 6, marks=pytest.mark.slow),
])
def test_expansion_mac_ir_matches_nodal_solve(r, m):
    """The mode ops' IR-aware MAC is literally the shared-column nodal
    solve — across tile geometries, both at pair and bank scale."""
    cfg = _stack_cfg(r, m)
    pair, v_top, v_bot = _pair(jax.random.PRNGKey(r * m), r, m)
    i_ref, _, _ = ir_drop.solve_crossstack(
        pair.g_top, pair.g_bot, v_top, v_bot, cfg.params.r_wire)
    assert jnp.array_equal(
        modes.expansion_mac_ir(pair, v_top, v_bot, cfg), i_ref)
    assert jnp.array_equal(
        modes.bank_expansion_mac_ir(modes.bank_from_pair(pair),
                                    v_top, v_bot, cfg), i_ref)
    # sanity: at these conductances the ideal (zero-wire) MAC upper-bounds
    # the solved currents, and the IR solve stays within 5% of it
    i_ideal = modes.expansion_mac(pair, v_top, v_bot, cfg)
    assert jnp.all(i_ref <= i_ideal + 1e-12)
    assert jnp.all(1.0 - i_ref / i_ideal < 0.05)


@pytest.mark.parametrize("r,m", [
    (5, 4),
    pytest.param(10, 10, marks=pytest.mark.slow),
])
def test_mode_ir_report_recomputes_from_raw_solves(r, m):
    """mode_ir_report's scores are exactly the mean per-column
    ir_drop_loss of the raw planar / crossstack solves at the all-SET,
    full-drive worst case."""
    rep = ir_drop.mode_ir_report(r, m)
    assert (rep["tile_rows"], rep["tile_cols"]) == (r, m)  # under the cap
    g_half = jnp.full((r, m), PAPER.g_set)
    g_full = jnp.full((2 * r, m), PAPER.g_set)
    v_half = jnp.full((r,), PAPER.v_read)
    v_full = jnp.full((2 * r,), PAPER.v_read)
    i_ideal = ir_drop.ideal_currents(
        ir_drop._series(g_full, PAPER.r_on_transistor), v_full)
    i_pl, _, _ = ir_drop.solve_planar(g_full, v_full, PAPER.r_wire)
    i_cs, _, _ = ir_drop.solve_crossstack(g_half, g_half, v_half, v_half,
                                          PAPER.r_wire)
    dev_pl = float(ir_drop.ir_drop_loss(i_pl, i_ideal).mean())
    dev_cs = float(ir_drop.ir_drop_loss(i_cs, i_ideal).mean())
    assert rep["dev_deepnet"] == pytest.approx(dev_pl, rel=1e-6)
    assert rep["dev_expansion"] == pytest.approx(dev_cs, rel=1e-6)
    assert rep["ir_drop_reduction"] == pytest.approx(
        1.0 - dev_cs / dev_pl, rel=1e-6)
    # expansion's shorter shared column must win at every geometry
    assert rep["dev_expansion"] < rep["dev_deepnet"]


def test_capped_geometry_preserves_small_tiles_and_caps_large():
    assert ir_drop.capped_geometry(10, 10) == (10, 10)
    r, m = ir_drop.capped_geometry(128, 128)
    assert 3 * r * m <= 1024
    assert r >= 2 and m >= 2


# -- executor: mode policy resolution and the mixed-mode read path ------------

XBAR = EngineConfig(tile_rows=16, tile_cols=16, mode="deepnet",
                    quant=QuantConfig(w_bits=4, in_bits=8, adc_bits=10))


def _params(key=0, d=32, d_ff=48):
    ks = iter(jax.random.split(jax.random.PRNGKey(key), 8))

    def w(*shape):
        return jax.random.normal(next(ks), shape) * 0.3

    return {
        "blocks": {"attn": {"wq": w(2, d, d)},
                   "mlp": {"wi": w(2, d, d_ff), "wo": w(2, d_ff, d)}},
        "head": w(d, 2 * d),
    }


def test_auto_policy_fuses_attention_and_head_keeps_mlp_deepnet():
    ex = CrossbarExecutor(XBAR)
    ex.program_params(_params(), mode_policy="auto")
    assert ex.mode_for("blocks.0.attn.wq") == "expansion"
    assert ex.mode_for("blocks.1.attn.wq") == "expansion"
    assert ex.mode_for("head") == "expansion"
    assert ex.mode_for("blocks.0.mlp.wi") == "deepnet"
    assert ex.mode_for("blocks.1.mlp.wo") == "deepnet"
    rep = ex.mode_report()
    assert rep["aggregate"]["n_expansion"] == 3
    assert rep["aggregate"]["n_deepnet"] == 4
    for name, entry in rep["layers"].items():
        assert entry["mode"] == ex.mode_for(name)
        assert entry["fused"] == (entry["mode"] == "expansion")
        assert entry["reason"].startswith("auto:")
    res = ex.residency()["A"]["modes"]
    assert res == {"expansion": 3, "deepnet": 4}


def test_auto_policy_on_paper_geometry_meets_22pct_claim():
    """The acceptance number: on the paper's 10x10x2 prototype geometry
    the expansion layout cuts worst-case IR drop >= 20% (paper: 22%)."""
    cfg10 = dataclasses.replace(XBAR, tile_rows=10, tile_cols=10)
    ex = CrossbarExecutor(cfg10)
    ex.program_params(_params(d=20, d_ff=60), mode_policy="auto")
    agg = ex.mode_report()["aggregate"]
    assert agg["n_expansion"] > 0 and agg["n_deepnet"] > 0
    assert agg["ir_drop_reduction_expansion"] >= 0.20


def test_named_and_fragment_mode_policy_resolution():
    ex = CrossbarExecutor(XBAR)
    ex.program_params(_params(), mode_policy={
        "blocks.0.attn.wq": "expansion",   # exact name
        "mlp.wi": "expansion",             # dotted fragment, both layers
        "default": "deepnet",
    })
    assert ex.mode_for("blocks.0.attn.wq") == "expansion"
    assert ex.mode_for("blocks.0.mlp.wi") == "expansion"
    assert ex.mode_for("blocks.1.mlp.wi") == "expansion"
    assert ex.mode_for("blocks.1.attn.wq") == "deepnet"  # default
    assert ex.mode_for("head") == "deepnet"


def test_odd_row_tile_count_refuses_expansion_under_auto():
    # d=16 at tile_rows=16 -> a single row-tile: nothing to pair across
    # the two planes, so auto falls back to deep-net even for attention
    ex = CrossbarExecutor(XBAR)
    ex.program_params({"blocks": {"attn": {"wq": jax.random.normal(
        jax.random.PRNGKey(0), (2, 16, 16)) * 0.3}}}, mode_policy="auto")
    assert ex.mode_for("blocks.0.attn.wq") == "deepnet"
    reason = ex.mode_report()["layers"]["blocks.0.attn.wq"]["reason"]
    assert "row-tile" in reason


def test_fused_reads_bit_exact_vs_expansion_engine():
    """A fused weight's executor read equals engine.matmul under the
    expansion cfg; a deep-net weight's read is untouched — one executor,
    both modes, no re-programming between reads."""
    ex = CrossbarExecutor(XBAR)
    p = _params()
    ex.program_params(p, mode_policy="auto")
    x = jax.random.normal(jax.random.PRNGKey(9), (3, 32))
    exp_cfg = dataclasses.replace(XBAR, mode="expansion")
    w_attn = p["blocks"]["attn"]["wq"][0]
    y = ex.linear(x, w_attn, "blocks.0.attn.wq")
    assert jnp.array_equal(
        y, eng.matmul(x, eng.program(w_attn, exp_cfg), exp_cfg))
    w_mlp = p["blocks"]["mlp"]["wi"][0]
    y2 = ex.linear(x, w_mlp, "blocks.0.mlp.wi")
    assert jnp.array_equal(
        y2, eng.matmul(x, eng.program(w_mlp, XBAR), XBAR))


def test_mode_is_physical_layout_conflict_on_reprogram():
    ex = CrossbarExecutor(XBAR)
    p = _params()
    ex.program_params(p, mode_policy="auto")
    # a policy-free re-walk expresses no preference: pure cache hit
    ex.program_params(p)
    # demanding the opposite layout for a resident weight must refuse —
    # mode is how the planes were physically programmed
    with pytest.raises(RuntimeError, match="physical plane layout"):
        ex.program_params(p, mode_policy={"default": "auto",
                                          "blocks.0.attn.wq": "deepnet"})


def test_fused_residency_consumes_both_planes():
    # stack_planes=2: one expansion-fused weight fills the whole bank,
    # so a second tenant cannot join on those grids
    ex = CrossbarExecutor(XBAR)
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 16)) * 0.3
    ex.program_params({"head": w}, mode_policy="expansion")
    with pytest.raises(RuntimeError, match="stack is full"):
        ex.program_params({"head": w}, tenant="B")
    # deep-net layout leaves the second plane free for tenant B
    ex2 = CrossbarExecutor(XBAR)
    ex2.program_params({"head": w}, mode_policy="deepnet")
    ex2.program_params({"head": w}, tenant="B")
    assert ex2.tenants == ["A", "B"]


def test_fused_anchor_refuses_hot_swap():
    ex = CrossbarExecutor(XBAR)
    p = _params()
    ex.program_params(p, mode_policy="auto")
    with pytest.raises(RuntimeError, match="expansion-fused"):
        ex.begin_swap(p)
    # an all-deep-net tenant still swaps
    ex2 = CrossbarExecutor(XBAR)
    ex2.program_params(p, mode_policy="deepnet")
    plan = ex2.begin_swap(p)
    assert plan is not None


def test_invalid_policy_values_refused():
    ex = CrossbarExecutor(XBAR)
    with pytest.raises(ValueError, match="mode"):
        ex.program_params(_params(), mode_policy="sideways")
    with pytest.raises(ValueError, match="mode"):
        ex.program_params(_params(),
                          mode_policy={"default": "sideways"})
