"""Zero-downtime weight hot-swap under a live generation loop.

Deploys qwen3-4b (smoke) on the crossbar backend, starts a greedy decode
loop, then swaps in fine-tuned params WHILE tokens keep streaming: the
new weights program onto the write-shadow planes between decode steps
(deep-net mode: reads never stop) and an atomic flip promotes them.

Run: PYTHONPATH=src python examples/hotswap_deploy.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.engine import EngineConfig
from repro.core.quant import QuantConfig
from repro.models.model import build_model
from repro.serve.engine import make_decode_step, make_prefill_step
from repro.serve.hotswap import HotSwapper, finetune_delta

cfg = dataclasses.replace(
    get_config("qwen3-4b", smoke=True), backend="crossbar",
    xbar=EngineConfig(tile_rows=64, tile_cols=128, mode="deepnet",
                      quant=QuantConfig(w_bits=8, in_bits=10, adc_bits=14)))
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

# "fine-tuned" checkpoint: the serving params plus a small delta (on a
# fleet this would come from checkpoint/manager.py)
params_ft = finetune_delta(params, scale=0.02, seed=7)

ex = model.executor
ex.program_params(params)
print(f"programmed v{ex.programmed_version}: {ex.n_resident} plane pairs, "
      f"{ex.n_devices} devices, fingerprint={ex.fingerprint()}")

prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                             cfg.vocab - 1).astype(jnp.int32)
cache = model.init_cache(2, 48)
tok, cache = make_prefill_step(model)(params, {"tokens": prompts}, cache)
decode = make_decode_step(model)

hs = None
for step in range(24):
    if step == 8:   # new checkpoint lands mid-generation
        hs = HotSwapper(ex, params_ft, chunks_per_step=8)
        print(f"step {step}: hot-swap begins "
              f"({hs.plan.total_chunks} shadow chunks)")
    if hs is not None and not hs.promoted:
        hs.step()   # shadow planes program BETWEEN decode steps
        if hs.done:
            params = hs.promote()   # atomic flip, zero dropped tokens
            print(f"step {step}: promoted -> v{ex.programmed_version}, "
                  f"fingerprint={ex.fingerprint()}")
    tok, cache = decode(params, tok, cache)
    if hs is not None and not hs.promoted:
        hs.note_decode_step()   # a token batch served DURING programming
    marker = "*" if hs is not None and hs.promoted else " "
    print(f"step {step:2d}{marker} tokens={tok[:, 0].tolist()}")

rep = hs.report(batch_size=prompts.shape[0])
print(f"\nswap window: {rep['decode_steps_during_swap']} decode steps "
      f"served during programming (wall {rep['wall_swap_s']:.2f}s)")
print(f"device-time: overlapped throughput during swap "
      f"{rep['throughput_ratio_overlap_vs_stop_world']:.2f}x "
      f"stop-the-world; steady-state read-under-write overlap "
      f"{rep['overlap_frac_steady_state'] * 100:.1f}% (paper ~29%)")
