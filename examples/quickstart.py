"""CrossStack quickstart: program a weight matrix onto stacked crossbar
pairs and run it in both operating modes.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core import pipeline as pipe
from repro.core.quant import QuantConfig
from repro.core.timing import PAPER, deepnet_speedup

key = jax.random.PRNGKey(0)
W = jax.random.normal(key, (256, 128)) * 0.3
x = jax.random.normal(jax.random.PRNGKey(1), (8, 256))
ref = x @ W

print("=== CrossStack quickstart ===")
print(f"device corners: R_set={PAPER.r_set/1e3:.0f}k  "
      f"R_reset={PAPER.r_reset/1e3:.0f}k  t_read={PAPER.t_read*1e9:.0f}ns  "
      f"t_write={PAPER.t_write*1e9:.0f}ns")

for mode in ("expansion", "deepnet"):
    for bits in (8, 4, 2):
        cfg = eng.EngineConfig(
            tile_rows=64, tile_cols=64, mode=mode,
            quant=QuantConfig(w_bits=bits, in_bits=8, adc_bits=12))
        pw = eng.program(W, cfg)           # "write" weights to conductances
        y = eng.matmul(x, pw, cfg)         # analog read-out (digital twin)
        err = float(jnp.abs(y - ref).max() / jnp.abs(ref).max())
        print(f"mode={mode:9s} w_bits={bits}  rel err={err:.4f}  "
              f"devices={pw.n_devices}")

print("\n=== weight residency (program once, read forever) ===")
# The deployment path: CrossbarExecutor programs a params tree onto
# resident tiles once; every later call is a read-only bit-serial MAC.
from repro.core.executor import CrossbarExecutor  # noqa: E402

ex = CrossbarExecutor(eng.EngineConfig(tile_rows=64, tile_cols=64,
                                       mode="deepnet"))
ex.program_params({"head": W})
with ex.activate():
    from repro.core.executor import crossbar_linear
    y = crossbar_linear(x, W, "head")
print(f"resident grids={ex.n_resident}  devices={ex.n_devices}  "
      f"programmed={ex.stats['programmed']}  "
      f"rel err={float(jnp.abs(y - ref).max() / jnp.abs(ref).max()):.4f}")
print("(models route every linear this way with backend='crossbar'; "
      "see launch/serve.py --backend crossbar)")

print("\ndeep-net pipeline (paper §V): read of layer l overlaps write of "
      "layer l+1")
for b in (1, 4, 10, 16):
    print(f"  {b:2d}-bit inputs: speedup {deepnet_speedup(b)*100:.1f}%"
          + ("   <- paper's 29% claim" if b == 10 else ""))

rep = pipe.latency_report(100, 10)
print(f"\n100-layer, 10-bit conv: serial {rep['t_serial_us']:.2f}us vs "
      f"deep-net {rep['t_deepnet_us']:.2f}us "
      f"({rep['speedup_frac']*100:.1f}% faster)")
