"""Multi-tenant plane multiplexing: two checkpoints, one crossbar.

Deploys qwen3-4b (smoke) TWICE onto one crossbar executor — checkpoint A
on the read-active planes, checkpoint B on the stacked twins — and
serves both tenants' request streams interleaved from the same physical
stacks (the paper's user-reconfigurable plane pair, §III, as a serving
tier).  Mid-run, tenant B's checkpoint is hot-swapped: its planes
reprogram in t_write-costed chunks between tenant A's decode steps, A's
traffic never pauses, and B resumes on the new weights at the atomic
promotion boundary.

Run: PYTHONPATH=src python examples/multiplex_serve.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.engine import EngineConfig
from repro.core.quant import QuantConfig
from repro.models.model import build_model
from repro.serve.engine import BatchScheduler, Request
from repro.serve.hotswap import finetune_delta

cfg = dataclasses.replace(
    get_config("qwen3-4b", smoke=True), backend="crossbar",
    xbar=EngineConfig(tile_rows=64, tile_cols=128, mode="deepnet",
                      quant=QuantConfig(w_bits=4, in_bits=10, adc_bits=10)))
model = build_model(cfg)
params_a = model.init(jax.random.PRNGKey(0))
# tenant B: a different checkpoint (on a fleet: checkpoint/manager.py)
params_b = finetune_delta(params_a, scale=0.05, seed=3)

sched = BatchScheduler(model, params_a, n_slots=2, max_len=48,
                       tenants={"A": params_a, "B": params_b})
ex = model.executor
print(f"multiplexed: tenants={ex.tenants} share {ex.n_resident} plane "
      f"pairs, {ex.n_devices_physical} physical devices (1.0x one "
      f"deployment's stacks; two dedicated arrays would burn 2.0x)")
for t in ex.tenants:
    print(f"  tenant {t}: v{ex.version(t)} "
          f"fingerprint={ex.fingerprint(tenant=t)}")

for rid in range(8):
    prompt = jax.random.randint(jax.random.PRNGKey(10 + rid), (6,), 0,
                                cfg.vocab - 1).astype(jnp.int32)
    sched.submit(Request(rid=rid, prompt=prompt, max_new=10,
                         model_id="AB"[rid % 2]))

params_b2 = finetune_delta(params_a, scale=0.08, seed=9)
done, steps, swapped = [], 0, False
while len(done) < 8 and steps < 400:
    if steps == 6 and not swapped:   # new B checkpoint lands mid-serving
        hs = sched.begin_hot_swap(params_b2, chunks_per_step=4, tenant="B")
        swapped = True
        print(f"step {steps}: tenant-B hot-swap begins "
              f"({hs.plan.total_chunks} chunks program between tenant A's "
              f"decode steps; B's lane pauses for the write window)")
    for r in sched.step():
        done.append(r)
        print(f"step {steps:3d}: req {r.rid} [tenant {r.model_id}] "
              f"finished -> {r.out[:6]}...")
    steps += 1

(rep,) = sched.swap_history
print(f"\ntenant-B swap promoted at step boundary: "
      f"B now v{ex.version('B')} fingerprint={ex.fingerprint(tenant='B')} "
      f"(A untouched at v{ex.version('A')})")
print(f"swap window: {rep['decode_steps_during_swap']} tenant-A decode "
      f"steps served during B's programming (wall "
      f"{rep['wall_swap_s']:.2f}s, zero dropped)")
print(f"device-time: throughput during swap "
      f"{rep['throughput_ratio_overlap_vs_stop_world']:.2f}x "
      f"stop-the-world (>=2x: {rep['sustains_2x_during_swap']})")
